"""Model-zoo module-resilience profiler benchmark (DESIGN.md §2.12).

The paper profiles ONE CNN layer-by-layer; this lane profiles the
model zoo module-by-module.  For each architecture it runs
``repro.approx.profiles.profile_architecture``: a single-family sweep
of the committed library over every module family (attention q/k/v/o,
MLP up/gate/down, MoE experts, SSM projections, cross-attention, ...)
as ONE banked compiled program, a most-to-least-tolerant family
ranking, and a per-module policy selected under a declarative
``MaxDrop`` bound on the workload primary.  Writes
``benchmarks/results/BENCH_profiles.json`` then enforces four gates
in-benchmark (record first, so a failed gate still leaves evidence):

  * **coverage** — >= 4 architectures beyond ResNet are profiled,
    including at least one MoE and one SSM (mamba-bearing) model;
  * **selection** — every profiled architecture yields a selected
    per-module policy whose measured drop stays inside ``MaxDrop``;
  * **bit identity** — on the MoE and SSM reference archs, the banked
    module sweep (exact-LUT ``fill`` padding) reproduces the
    sequential golden-base evaluation metric-for-metric;
  * **single program** — the banked sweep traces exactly ONE program,
    and a truncated row set traces the same count (O(1) compiled
    programs per sweep, independent of grid size).

Quick mode (CI) profiles 5 reduced LM archs with a 3-multiplier
power-spread; full mode widens the library subset and adds
deepseek-v2-236b (MLA+MoE), llava-next-34b (VLM), nemotron-4-15b and a
ResNet-8 profile on the paper's own classification workload.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.approx.dse import verify_assignments
from repro.approx.modules import (FILL_EXACT, ModuleMap,
                                  module_sweep_assignments)
from repro.approx.profiles import profile_architecture, profile_zoo
from repro.approx.workload import classification, lm_fidelity
from repro.core.library import get_default_library
from repro.launch.compile_cache import trace_audit
from repro.models import resnet

from .common import emit
from .resilience_common import case_study_names

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_profiles.json")

#: MaxDrop bound on the primary metric (logit_mae vs the f32 / golden
#: reference).  The all-exact uniform always satisfies it (drop == 0),
#: so an arch failing the selection gate means the selector broke, not
#: that the bound was too tight.
MAX_DROP = 0.05
MIN_ARCHS_GATE = 4

#: Reduced zoo slices per mode: (arch, model-family label).  Quick
#: keeps one representative per family axis the gates care about.
QUICK_ARCHS = [
    ("qwen1.5-0.5b", "dense"),
    ("qwen3-moe-30b-a3b", "moe"),
    ("mamba2-780m", "ssm"),
    ("jamba-v0.1-52b", "hybrid"),
    ("whisper-large-v3", "encdec"),
]
FULL_EXTRA_ARCHS = [
    ("deepseek-v2-236b", "moe"),
    ("llava-next-34b", "vlm"),
    ("nemotron-4-15b", "dense"),
]
#: The bit-identity / trace-count reference archs (satellite: MoE and
#: mamba2), checked with a 2-multiplier sub-grid to bound wall-clock.
IDENTITY_ARCHS = ("qwen3-moe-30b-a3b", "mamba2-780m")


def _multipliers(lib, quick: bool) -> list[str]:
    if quick:
        return ["mul8u_exact", "mul8u_trunc6", "mul8u_trunc3"]
    names = case_study_names(lib, 5)
    if "mul8u_exact" not in names:
        names.insert(0, "mul8u_exact")
    return names


def _lm_workload(arch: str):
    wl = lm_fidelity(arch, batch=2, seq_len=8, n_batches=1)
    from repro.configs import get_config
    cfg = get_config(arch).reduced()
    mmap = ModuleMap.for_config(cfg, batch=2, seq_len=8)
    return wl, mmap


def _identity_check(wl, mmap, lib, mults) -> dict:
    """Banked-vs-sequential bit identity + O(1) trace count on one
    arch's module sweep (the in-benchmark twin of
    ``tests/test_modules.py``'s gate, run on the shipped library)."""
    grid = module_sweep_assignments(mmap, mults)
    lowered = [mmap.lower(a) for _f, _m, a in grid]
    with trace_audit() as tc_full:
        banked = verify_assignments(wl, lowered, mmap.layer_counts, lib,
                                    layers=mmap.layers, fill=FILL_EXACT)
    sequential = verify_assignments(wl, lowered, mmap.layer_counts, lib,
                                    batch=False, layers=mmap.layers,
                                    fill=FILL_EXACT)
    bit = all(b.metrics == s.metrics
              and b.network_rel_power == s.network_rel_power
              for b, s in zip(banked, sequential))
    with trace_audit() as tc_half:
        verify_assignments(wl, lowered[:2], mmap.layer_counts, lib,
                           layers=mmap.layers, fill=FILL_EXACT)
    return {"bit_identical": bool(bit), "rows": len(lowered),
            "traced_full": tc_full.traced_programs,
            "traced_truncated": tc_half.traced_programs}


def run(quick: bool = False) -> dict:
    lib = get_default_library()
    mults = _multipliers(lib, quick)
    for n in mults:
        lib.lut(n)              # warm LUT packing outside the timers
    emit("profiles/multipliers", 0.0, f"n={len(mults)}")

    archs = QUICK_ARCHS + ([] if quick else FULL_EXTRA_ARCHS)
    profiles = {}
    for arch, family in archs:
        t0 = time.perf_counter()
        wl, mmap = _lm_workload(arch)
        prof = profile_architecture(wl, mmap, lib, mults, arch=arch,
                                    model_family=family,
                                    max_drop=MAX_DROP)
        dt = time.perf_counter() - t0
        sel = (f"power={prof.selected['power']:.3f}"
               if prof.selected else "none")
        emit(f"profiles/{arch}", dt * 1e6,
             f"modules={len(prof.modules)};most_tolerant="
             f"{prof.ranking[0]};{sel}")
        profiles[arch] = prof

    if not quick:               # the paper's own family, full runs only
        cfg = resnet.resnet_config(8)
        import jax
        params = resnet.init_params(jax.random.PRNGKey(0), cfg)
        wl = classification(cfg, params, eval_n=32, batch=32,
                            fidelity=True)
        mmap = ModuleMap.for_config(cfg, batch=32)
        t0 = time.perf_counter()
        prof = profile_architecture(wl, mmap, lib, mults,
                                    arch="resnet8-cifar",
                                    model_family="resnet",
                                    max_drop=MAX_DROP)
        emit("profiles/resnet8-cifar", (time.perf_counter() - t0) * 1e6,
             f"modules={len(prof.modules)}")
        profiles["resnet8-cifar"] = prof

    identity = {}
    for arch in IDENTITY_ARCHS:
        wl, mmap = _lm_workload(arch)
        t0 = time.perf_counter()
        identity[arch] = _identity_check(wl, mmap, lib, mults[1:3])
        emit(f"profiles/identity_{arch}",
             (time.perf_counter() - t0) * 1e6,
             f"bit={identity[arch]['bit_identical']};"
             f"traced={identity[arch]['traced_full']}")

    beyond_resnet = [a for a in profiles if a != "resnet8-cifar"]
    fam_of = dict(archs)
    gates = {
        "coverage": (len(beyond_resnet) >= MIN_ARCHS_GATE
                     and any(fam_of[a] == "moe" for a in beyond_resnet)
                     and any(fam_of[a] in ("ssm", "hybrid")
                             and "ssm.in_proj" in profiles[a].modules
                             for a in beyond_resnet)),
        "selection": all(
            p.selected is not None
            and p.selected["quality_drop"] <= p.max_drop + 1e-9
            for p in profiles.values()),
        "bit_identity": all(c["bit_identical"]
                            for c in identity.values()),
        "single_program": all(
            c["traced_full"] == c["traced_truncated"] == 1
            for c in identity.values()),
    }

    record = {
        "quick": quick,
        "max_drop": MAX_DROP,
        "multipliers": mults,
        "zoo": profile_zoo(profiles),
        "identity_checks": identity,
        "gates": gates,
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    emit("profiles/bench_record", 0.0, BENCH_PATH)

    failed = sorted(g for g, ok in gates.items() if not ok)
    if failed:
        raise SystemExit(
            f"arch_profiles gates failed: {failed} (see {BENCH_PATH})")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI slice: 5 reduced archs, 3 multipliers")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick)


if __name__ == "__main__":
    main()
