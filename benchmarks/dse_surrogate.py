"""Surrogate-guided vs exact-sweep DSE benchmark (DESIGN.md §2.11).

The heterogeneous DSE's predict stage historically measured every
candidate circuit against every layer exactly — O(n_layers ×
n_circuits) device evaluations.  The surrogate predict stage
(``explore_heterogeneous(predictor="surrogate")``) measures only a
power-spread ``train_fraction`` of the candidates, fits the QoR MLP on
those rows, predicts the rest, and verifies exactly.  This benchmark
runs BOTH paths end-to-end on the trained ResNet-8 / synthetic
CIFAR-10 case study at n_circuits >= 100 (the committed library's
8-bit multipliers plus a widened broken-array grid) and writes
``benchmarks/results/BENCH_dse.json`` with three gates, enforced
in-benchmark after the record is written:

  * **speedup** — end-to-end surrogate-guided DSE wall-clock
    (surrogate predict + exact verify) must be >= 3x the exact-sweep
    beam's;
  * **fidelity** — per-layer Spearman rho between surrogate-predicted
    and exactly-measured quality over the UNSEEN circuits (the ones
    the surrogate never measured; ApproxGNN's evaluation protocol)
    must average >= 0.9;
  * **front quality** — every point on the exact-predict beam's
    verified Pareto front must be matched or dominated by a
    surrogate-guided verified point (quality no worse, power no
    higher).

The workload primary is ``logit_mae`` vs the golden-int8 reference
(``classification(fidelity=True)``): continuous where a small-eval
top-1 accuracy quantizes to 1/eval_n steps and starves rank statistics.
The surrogate path runs FIRST, so any jit compile reuse between the
two runs makes the measured speedup conservative, never inflated.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.approx.dse import explore_heterogeneous, pareto_points
from repro.approx.ranking import spearman
from repro.approx.surrogate import fit_surrogate
from repro.approx.workload import classification
from repro.core.families import bam_multiplier
from repro.core.library import get_default_library
from repro.models import resnet

from .common import emit
from .resilience_common import trained_resnet

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_dse.json")

SPEEDUP_GATE = 3.0
FIDELITY_GATE = 0.9


def widen_candidate_set(lib, n_circuits: int) -> list[str]:
    """All 8-bit multipliers, grown to ``n_circuits`` with a denser
    broken-array grid than the committed library ships (exhaustive
    16-bit-input error evaluation makes each new entry ~ms)."""
    names = [e.name for e in lib.select(kind="multiplier", width=8)]
    exact = lib.entry("mul8u_exact").netlist
    for h in range(0, 7):
        for v in range(0, 15):
            if len(names) >= n_circuits:
                return names
            if h == 0 and v == 0:
                continue                   # the exact multiplier itself
            nl = bam_multiplier(8, h, v)
            if nl.name in lib.entries:
                continue
            lib.add_netlist(nl, "multiplier", 8, "bam", exact)
            names.append(nl.name)
    return names


def _measured_matrix(points, layers, names) -> np.ndarray:
    """(n_layers, n_names) primary-metric matrix from per-layer
    DesignPoints (NaN where unmeasured)."""
    li = {l: j for j, l in enumerate(layers)}
    ni = {n: i for i, n in enumerate(names)}
    out = np.full((len(layers), len(names)), np.nan)
    for p in points:
        if p.layer in li and p.multiplier in ni:
            out[li[p.layer], ni[p.multiplier]] = p.accuracy
    return out


def _front(points) -> list:
    """Verified (logit_mae min, power min) Pareto front, cheapest
    first."""
    return sorted(pareto_points(points, ("logit_mae", "power")),
                  key=lambda p: p.network_rel_power)


def _front_dict(points) -> list[dict]:
    return [{"multiplier": p.multiplier,
             "logit_mae": round(p.accuracy, 6),
             "network_rel_power": round(p.network_rel_power, 6),
             "accuracy": round(float(p.metrics.get("accuracy", np.nan)),
                               6),
             "assignment": dict(p.assignment)} for p in points]


def _matches_or_dominates(sur_front, exact_front,
                          eps: float = 1e-9) -> tuple[bool, list[dict]]:
    """Every exact-front point must have a surrogate-front point at
    <= its quality (min primary) and <= its power."""
    misses = []
    for e in exact_front:
        if not any(s.accuracy <= e.accuracy + eps
                   and s.network_rel_power <= e.network_rel_power + eps
                   for s in sur_front):
            misses.append({"logit_mae": e.accuracy,
                           "network_rel_power": e.network_rel_power})
    return not misses, misses


def run(n_circuits: int = 108, quick: bool = False,
        train_fraction: float = 0.25, quality_bound: float = 1.0,
        top_k: int = 8) -> dict:
    lib = get_default_library()
    names = widen_candidate_set(lib, n_circuits)
    emit("dse/candidates", 0.0, f"n={len(names)}")

    cfg, params = trained_resnet(8)
    eval_n = 32 if quick else 64
    wl = classification(cfg, params, eval_n=eval_n, batch=32,
                        fidelity=True)
    counts = resnet.layer_mult_counts(cfg)
    for n in names:                 # warm LUT packing for both paths
        lib.lut(n)

    # -- surrogate-guided DSE (first: compile reuse can only help the
    # exact run, keeping the measured speedup conservative) -----------
    t0 = time.perf_counter()
    res_sur = explore_heterogeneous(
        wl, counts, lib, multipliers=names,
        quality_bound=quality_bound, top_k=top_k, batch=True,
        predictor="surrogate", train_fraction=train_fraction)
    t_sur = time.perf_counter() - t0
    emit("dse/surrogate_end_to_end", t_sur * 1e6,
         f"n_train={res_sur.surrogate['n_train'] + res_sur.surrogate['n_val']}")

    # -- exact-sweep DSE (the historical path) -------------------------
    t0 = time.perf_counter()
    res_exact = explore_heterogeneous(
        wl, counts, lib, multipliers=names,
        quality_bound=quality_bound, top_k=top_k, batch=True)
    t_exact = time.perf_counter() - t0
    speedup = t_exact / t_sur if t_sur > 0 else float("inf")
    emit("dse/exact_end_to_end", t_exact * 1e6,
         f"speedup={speedup:.2f}")

    # -- predicted-vs-measured fidelity on UNSEEN circuits -------------
    # the surrogate run's per_layer points are exactly its measured
    # training rows; refitting on them is deterministic, so this
    # predictor is the one the run used
    predictor = fit_surrogate(res_sur.per_layer, lib,
                              res_sur.baseline_accuracy,
                              direction="min")
    seen = set(predictor.train_names) | set(predictor.val_names)
    unseen = [n for n in names if n not in seen]
    layers = tuple(counts)
    predicted = predictor.predict_quality(unseen, lib)
    measured = _measured_matrix(res_exact.per_layer, layers, unseen)
    rho = {}
    for j, layer in enumerate(layers):
        ok = ~np.isnan(measured[j])
        rho[layer] = spearman(predicted[j][ok], measured[j][ok])
    valid = [v for v in rho.values() if not np.isnan(v)]
    mean_rho = float(np.mean(valid)) if valid else float("nan")
    min_rho = float(np.min(valid)) if valid else float("nan")
    emit("dse/fidelity", 0.0,
         f"mean_rho={mean_rho:.4f};min_rho={min_rho:.4f};"
         f"n_unseen={len(unseen)}")

    # -- verified front quality ----------------------------------------
    front_sur = _front(res_sur.heterogeneous)
    front_exact = _front(res_exact.heterogeneous)
    front_ok, front_misses = _matches_or_dominates(front_sur, front_exact)
    emit("dse/front", 0.0,
         f"ok={front_ok};sur={len(front_sur)};exact={len(front_exact)}")

    record = {
        "benchmark": "dse_surrogate",
        "quick": quick,
        "backend": jax.default_backend(),
        "n_circuits": len(names),
        "n_layers": len(layers),
        "eval_n": eval_n,
        "train_fraction": train_fraction,
        "quality_bound": quality_bound,
        "top_k": top_k,
        "workload_primary": "logit_mae",
        "surrogate": res_sur.surrogate,
        "end_to_end": {
            "surrogate_s": round(t_sur, 3),
            "exact_s": round(t_exact, 3),
            "speedup": round(speedup, 2),
            "gate": SPEEDUP_GATE,
            "evals_surrogate": (res_sur.surrogate["n_train"]
                                + res_sur.surrogate["n_val"])
            * len(layers),
            "evals_exact": len(names) * len(layers),
        },
        "fidelity": {
            "protocol": "per-layer Spearman rho, unseen circuits only",
            "n_unseen": len(unseen),
            "per_layer_rho": {k: (None if np.isnan(v) else round(v, 4))
                              for k, v in rho.items()},
            "mean_rho": round(mean_rho, 4),
            "min_rho": round(min_rho, 4),
            "gate": FIDELITY_GATE,
        },
        "front": {
            "surrogate": _front_dict(front_sur),
            "exact": _front_dict(front_exact),
            "matches_or_dominates": front_ok,
            "misses": front_misses,
            "selected_surrogate": (
                round(res_sur.selected.network_rel_power, 6)
                if res_sur.selected else None),
            "selected_exact": (
                round(res_exact.selected.network_rel_power, 6)
                if res_exact.selected else None),
        },
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    emit("dse/bench_record", 0.0, BENCH_PATH)

    # record is written first so CI failures still upload the artifact
    if speedup < SPEEDUP_GATE:
        raise SystemExit(
            f"surrogate-guided DSE speedup {speedup:.2f}x is below the "
            f"{SPEEDUP_GATE}x gate (see {BENCH_PATH})")
    if not (mean_rho >= FIDELITY_GATE):
        raise SystemExit(
            f"predicted-vs-measured per-layer Spearman (mean "
            f"{mean_rho:.4f}) is below the {FIDELITY_GATE} gate "
            f"(see {BENCH_PATH})")
    if not front_ok:
        raise SystemExit(
            "surrogate-guided verified front fails to match or "
            f"dominate the exact-predict front (see {BENCH_PATH})")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small eval set (CI); the committed trained "
                         "checkpoint is restored either way")
    ap.add_argument("--n-circuits", type=int, default=108)
    ap.add_argument("--train-fraction", type=float, default=0.25)
    ap.add_argument("--quality-bound", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=8)
    args = ap.parse_args()
    run(n_circuits=args.n_circuits, quick=args.quick,
        train_fraction=args.train_fraction,
        quality_bound=args.quality_bound, top_k=args.top_k)
