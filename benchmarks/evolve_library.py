"""Device-resident CGP library generation benchmark (DESIGN.md §2.9).

Library growth is bounded by fitness evaluation: the legacy engine
simulates ONE candidate per ``Netlist.eval_words`` call, so the search
spends its life in per-candidate python dispatch.  The population
engine stacks a whole generation's offspring into flat genome arrays
and scores them in ONE Pallas program with the error metric reduced on
device.  This benchmark writes
``benchmarks/results/BENCH_evolve.json`` recording:

  * candidate-evals/sec of the device engine vs the sequential numpy
    engine on the same population (the headline throughput record) —
    the run FAILS unless the device engine clears a >= 3x speedup on
    CPU (interpret mode; a real accelerator only widens the gap),
  * the metric bit-identity gate: device-reduced er/mae/wce (exact
    integer sums finished in float64) and the host-reduced fallback
    metrics must equal the numpy engine's float64 values EXACTLY on
    every candidate — the run FAILS otherwise,
  * circuits/sec + archive-size-vs-wall-clock trajectory of a fused
    ``evolve_ladder`` sweep (every rung's improved parents timestamped
    as they are admitted),
  * library growth at equal budget: a tiny-budget ``device``-engine
    build must admit MORE evolved entries than the legacy build (no
    parent thinning + composed pickup) — the run FAILS otherwise.

``--quick`` (CI mode) shrinks populations and generations; every gate
is deterministic (fixed seeds).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.cgp import CgpParams, mutate, pad_nodes
from repro.core.evolve_pop import DEVICE_METRICS, PopEvaluator, \
    evolve_ladder
from repro.core.library import build_default_library
from repro.core.metrics import METRIC_NAMES
from repro.core.seeds import array_multiplier

from .common import emit

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_evolve.json")

SPEEDUP_GATE = 3.0


def _population(seed_nl, p: int, seed: int = 11):
    rng = np.random.default_rng(seed)
    return [mutate(seed_nl, rng, 5) for _ in range(p)]


def _throughput(ev: PopEvaluator, pop, iters: int) -> float:
    """Candidate evaluations per second over ``iters`` scoring calls."""
    ev.errors_of(pop)              # warmup (device: compile)
    t0 = time.perf_counter()
    for _ in range(iters):
        ev.errors_of(pop)
    dt = time.perf_counter() - t0
    return len(pop) * iters / dt


def run(quick: bool = False) -> dict:
    pop_size = 32 if quick else 64
    samples = 4096 if quick else 8192
    iters = 3 if quick else 5
    gens = 15 if quick else 60

    exact = array_multiplier(8)
    seed_nl = pad_nodes(exact, exact.n_nodes, seed=7)
    pop = _population(seed_nl, pop_size)
    params = CgpParams(metric="mae", e_max=256.0, search_samples=samples,
                       seed=3)

    # -- throughput: device vs sequential numpy ------------------------
    ev_np = PopEvaluator(exact, params, engine="numpy")
    ev_dev = PopEvaluator(exact, params, engine="device")
    eps_np = _throughput(ev_np, pop, iters)
    eps_dev = _throughput(ev_dev, pop, iters)
    speedup = eps_dev / eps_np
    emit("evolve/evals_per_s_numpy", 1e6 * pop_size / eps_np,
         f"{eps_np:.0f}/s")
    emit("evolve/evals_per_s_device", 1e6 * pop_size / eps_dev,
         f"{eps_dev:.0f}/s")
    emit("evolve/speedup", 0.0, f"{speedup:.2f}x")

    # -- metric bit-identity across engines ----------------------------
    identity = {}
    for metric in METRIC_NAMES:
        p_m = CgpParams(metric=metric, search_samples=samples, seed=3)
        e_np = PopEvaluator(exact, p_m, engine="numpy").errors_of(pop)
        e_dev = PopEvaluator(exact, p_m, engine="device").errors_of(pop)
        identity[metric] = bool(np.array_equal(e_np, e_dev))
    metrics_identical = all(identity.values())
    emit("evolve/metric_identity", 0.0,
         "exact" if metrics_identical else f"MISMATCH {identity}")

    # -- fused ladder: circuits/sec + archive trajectory ---------------
    max_out = float((2 ** 8 - 1) ** 2)
    ladder = [max_out * (2.0 ** -e) for e in np.linspace(14, 4, 4)]
    lp = CgpParams(metric="mae", generations=gens, search_samples=samples,
                   seed=5)
    trajectory = []
    t0 = time.perf_counter()

    def stamp(_run, _nl, _err, _area):
        trajectory.append({
            "t_s": round(time.perf_counter() - t0, 4),
            "archive_size": len(trajectory) + 1})

    ev_lad = PopEvaluator(exact, lp, engine="device")
    results = evolve_ladder(seed_nl, exact, ladder, lp, engine="device",
                            on_candidate=stamp, evaluator=ev_lad)
    ladder_s = time.perf_counter() - t0
    n_circuits = len(trajectory) + len(results)
    emit("evolve/ladder", 1e6 * ladder_s,
         f"{n_circuits} circuits, {n_circuits / ladder_s:.2f}/s")

    # -- archive growth at equal budget --------------------------------
    t0 = time.perf_counter()
    lib_legacy = build_default_library("tiny")
    legacy_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    lib_dev = build_default_library("tiny", engine="device")
    device_s = time.perf_counter() - t0
    n_ev_legacy = len([e for e in lib_legacy.entries.values()
                       if e.source == "evolved"])
    n_ev_dev = len([e for e in lib_dev.entries.values()
                    if e.source == "evolved"])
    grew = n_ev_dev > n_ev_legacy
    emit("evolve/library_tiny_legacy", 1e6 * legacy_s,
         f"{len(lib_legacy.entries)} entries ({n_ev_legacy} evolved)")
    emit("evolve/library_tiny_device", 1e6 * device_s,
         f"{len(lib_dev.entries)} entries ({n_ev_dev} evolved)")

    record = {
        "bench": "evolve_library",
        "quick": quick,
        "backend": jax.default_backend(),
        "pop_size": pop_size,
        "search_samples": samples,
        "throughput": {
            "evals_per_s_numpy": round(eps_np, 1),
            "evals_per_s_device": round(eps_dev, 1),
            "speedup": round(speedup, 3),
            "gate": SPEEDUP_GATE,
        },
        "metric_identity": identity,
        "device_metrics": list(DEVICE_METRICS),
        "ladder": {
            "rungs": len(ladder),
            "generations": gens,
            "wall_s": round(ladder_s, 3),
            "circuits": n_circuits,
            "circuits_per_s": round(n_circuits / ladder_s, 3),
            "candidate_evals": ev_lad.n_scored,
            "archive_vs_wall_clock": trajectory,
        },
        "library_tiny": {
            "legacy": {"entries": len(lib_legacy.entries),
                       "evolved": n_ev_legacy,
                       "wall_s": round(legacy_s, 3)},
            "device": {"entries": len(lib_dev.entries),
                       "evolved": n_ev_dev,
                       "wall_s": round(device_s, 3)},
            "grew": grew,
        },
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    emit("evolve/bench_record", 0.0, BENCH_PATH)

    # gates AFTER the record is on disk
    if not metrics_identical:
        raise SystemExit(
            "FAIL: device engine metrics are not bit-identical to the "
            f"numpy engine: {identity} (see {BENCH_PATH})")
    if speedup < SPEEDUP_GATE:
        raise SystemExit(
            f"FAIL: device engine speedup {speedup:.2f}x is below the "
            f"{SPEEDUP_GATE:.0f}x candidate-evals/sec gate "
            f"(see {BENCH_PATH})")
    if not grew:
        raise SystemExit(
            f"FAIL: device-engine tiny build admitted {n_ev_dev} "
            f"evolved entries vs {n_ev_legacy} legacy — the population "
            f"ladder must grow the archive at equal budget "
            f"(see {BENCH_PATH})")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: smaller populations/generations")
    args = ap.parse_args()
    run(quick=args.quick)
