"""Heterogeneous vs. uniform Pareto benchmark (DESIGN.md §2.5).

The paper's Table II picks ONE multiplier for the whole network; the
heterogeneous engine composes a different multiplier per conv layer
(autoAx-style two-stage DSE: per-layer component models -> layer-wise
Pareto pruning + beam composition -> exact batched verification through
``policy_bank_eval``).  This benchmark runs both on the trained
ResNet-8 / synthetic CIFAR-10 case study and writes
``benchmarks/results/BENCH_heterogeneous.json`` recording:

  * the uniform Table II front and the verified heterogeneous points,
  * a heterogeneous point that **dominates** the best uniform
    all-layers point under the same quality bound (strictly lower
    power at >= accuracy) — the run FAILS if none exists,
  * the equal-assignment consistency check: the heterogeneous engine
    restricted to uniform rows must be bit-identical to sequential
    ``ApproxPolicy(overrides=...)`` evaluations of the same policies
    (the CI divergence gate), and
  * the batched-vs-sequential verification wall-clock speedup
    (one ``policy_bank_eval`` program vs. K sequential policy evals).

``--quick`` (CI mode) skips training and shrinks the eval set; all
checks are deterministic either way (seeded synthetic data).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.approx.dse import (DesignPoint, ExploreResult,
                              explore_heterogeneous, select_multiplier,
                              verify_assignments)
from repro.approx.layers import ApproxPolicy, policy_bank_eval, policy_for_lane
from repro.approx.resilience import all_layers_sweep
from repro.approx.specs import BackendSpec, PolicyBank
from repro.core.library import get_default_library
from repro.models import resnet

from .common import emit
from .resilience_common import case_study_names, make_eval_fn, trained_resnet

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_heterogeneous.json")


def _point_dict(p: DesignPoint) -> dict:
    d = {"multiplier": p.multiplier,
         "accuracy": round(p.accuracy, 6),
         "network_rel_power": round(p.network_rel_power, 6)}
    if p.assignment is not None:
        d["assignment"] = dict(p.assignment)
    return d


def _downgrade_candidates(lib, names, counts, base_mult: str,
                          cap: int = 14) -> list[dict]:
    """Assignments that keep the uniform pick everywhere but downgrade
    layers to strictly cheaper candidates — power strictly below the
    uniform point by construction, so whichever downgrade the network
    tolerates verifies at >= its accuracy.  Single-layer downgrades
    cover every layer (largest counts first: biggest power win when the
    layer turns out insensitive); pair downgrades cover the smallest
    two layers (likeliest to verify)."""
    base_power = lib.entries[base_mult].rel_power
    cheaper = sorted(
        (m for m in names if lib.entries[m].rel_power < base_power),
        key=lambda m: lib.entries[m].rel_power)
    if not cheaper:
        return []
    big_first = sorted(counts, key=counts.get, reverse=True)
    small_first = big_first[::-1]
    out = []
    # thin but near-certain wins first: downgrade the smallest layer(s)
    for m in cheaper[:3]:
        for k in (1, 2):
            a = {l: base_mult for l in counts}
            for l in small_first[:k]:
                a[l] = m
            if a not in out:
                out.append(a)
    # big wins when tolerated: one large layer at a time
    for l in big_first:
        for m in cheaper[:3]:
            a = {k: base_mult for k in counts}
            a[l] = m
            if a not in out:
                out.append(a)
    return out[:cap]


def run(n_mult: int = 8, quick: bool = False, quality_bound: float = 0.02,
        top_k: int = 8) -> dict:
    lib = get_default_library()
    # both modes use the TRAINED checkpoint (committed; restores in
    # seconds) — heterogeneous composition needs a real per-layer
    # sensitivity signal, which an untrained network cannot provide.
    # --quick only shrinks the eval set.
    cfg, params = trained_resnet(8)
    if quick:
        eval_fn = make_eval_fn(cfg, params, eval_n=64, batch=64)
    else:
        eval_fn = make_eval_fn(cfg, params)

    names = case_study_names(lib, n_mult)
    # aggressive truncations: uniformly fatal, but the cheap lanes the
    # heterogeneous search mixes into insensitive layers
    for extra in ("mul8u_trunc4", "mul8u_trunc3", "mul8u_trunc2"):
        if extra in lib.entries and extra not in names:
            names.append(extra)
    counts = resnet.layer_mult_counts(cfg)
    for n in names:                    # warm LUTs so no path pays packing
        lib.lut(n)

    # -- uniform axis (Table II, batched) ------------------------------
    baseline = eval_fn(ApproxPolicy(default=BackendSpec.golden()))
    rows_uniform = all_layers_sweep(eval_fn, counts, names, lib,
                                    mode="lut", batch=True)
    uniform_result = ExploreResult(
        baseline_accuracy=baseline,
        all_layers=[DesignPoint.from_row(r) for r in rows_uniform])
    uniform_best = select_multiplier(uniform_result, quality_bound)

    # -- heterogeneous axis (two-stage DSE) ----------------------------
    extra = ([] if uniform_best is None else
             _downgrade_candidates(lib, names, counts,
                                   uniform_best.multiplier))
    hetero_result = explore_heterogeneous(
        eval_fn, counts, lib, multipliers=names,
        quality_bound=quality_bound, top_k=top_k,
        extra_assignments=extra, batch=True)
    emit("heterogeneous/candidates", 0.0,
         f"n={len(hetero_result.heterogeneous)}")

    # -- equal-assignment consistency (CI divergence gate) -------------
    layers = tuple(counts)
    upb = PolicyBank.uniform(names, layers, lib)
    accs_bank = np.asarray(policy_bank_eval(eval_fn.traceable, upb,
                                            mode="lut"))
    accs_seq = np.asarray([eval_fn(policy_for_lane(upb, p).materialize(lib))
                           for p in range(upb.n_policies)],
                          dtype=accs_bank.dtype)
    equal_assignment_identical = bool((accs_bank == accs_seq).all())
    emit("heterogeneous/equal_assignment", 0.0,
         f"bit_identical={equal_assignment_identical}")

    # -- batched vs sequential verification speedup --------------------
    verify_assignments_list = [dict(p.assignment)
                               for p in hetero_result.heterogeneous]
    t0 = time.perf_counter()
    pts_bat = verify_assignments(eval_fn, verify_assignments_list, counts,
                                 lib, mode="lut", batch=True)
    bat_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    pts_seq = verify_assignments(eval_fn, verify_assignments_list, counts,
                                 lib, mode="lut", batch=False)
    seq_s = time.perf_counter() - t0
    verify_identical = [p.accuracy for p in pts_bat] == \
                       [p.accuracy for p in pts_seq]
    speedup = seq_s / bat_s if bat_s > 0 else float("inf")
    emit("heterogeneous/verify_batched", bat_s * 1e6,
         f"k={len(pts_bat)};speedup={speedup:.2f};"
         f"bit_identical={verify_identical}")

    # -- dominance: hetero beats the best uniform point ----------------
    dominating = None
    if uniform_best is not None:
        floor = baseline - quality_bound
        for p in sorted(hetero_result.heterogeneous,
                        key=lambda p: p.network_rel_power):
            if (p.network_rel_power < uniform_best.network_rel_power
                    and p.accuracy >= uniform_best.accuracy
                    and p.accuracy >= floor):
                dominating = p
                break
    if dominating is not None:
        emit("heterogeneous/dominating_point", 0.0,
             f"power={dominating.network_rel_power:.4f}"
             f"<{uniform_best.network_rel_power:.4f};"
             f"acc={dominating.accuracy:.4f}"
             f">={uniform_best.accuracy:.4f}")

    record = {
        "benchmark": "heterogeneous_pareto",
        "n_mult": len(names),
        "multipliers": names,
        "quick": quick,
        "quality_bound": quality_bound,
        "baseline_accuracy": round(baseline, 6),
        "backend": jax.default_backend(),
        "uniform": [_point_dict(p) for p in sorted(
            uniform_result.all_layers,
            key=lambda p: p.network_rel_power)],
        "uniform_best": (_point_dict(uniform_best)
                         if uniform_best else None),
        "heterogeneous": [_point_dict(p) for p in sorted(
            hetero_result.heterogeneous,
            key=lambda p: p.network_rel_power)],
        "selected": (_point_dict(hetero_result.selected)
                     if hetero_result.selected else None),
        "dominating": (_point_dict(dominating) if dominating else None),
        "equal_assignment_bit_identical": equal_assignment_identical,
        "verification": {
            "k": len(pts_bat),
            "sequential_s": round(seq_s, 4),
            "batched_s": round(bat_s, 4),
            "speedup": round(speedup, 2),
            "bit_identical": verify_identical,
        },
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    emit("heterogeneous/bench_record", 0.0, BENCH_PATH)

    # record is written first so CI failures still upload the artifact
    if not equal_assignment_identical:
        raise SystemExit(
            "heterogeneous engine diverged from sequential evaluation "
            "at equal (uniform) assignments — the bit-identical "
            f"contract is broken (see {BENCH_PATH})")
    if not verify_identical:
        raise SystemExit(
            "batched verification diverged from sequential policy "
            f"evaluation (see {BENCH_PATH})")
    if uniform_best is not None and dominating is None:
        raise SystemExit(
            "no heterogeneous point dominates the best uniform point "
            f"under quality bound {quality_bound} (see {BENCH_PATH})")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-mult", type=int, default=None,
                    help="candidate count (default: 8, or 12 with "
                         "--quick where the sweep is cheap)")
    ap.add_argument("--quick", action="store_true",
                    help="small eval set (CI); both modes restore the "
                         "committed trained checkpoint")
    ap.add_argument("--quality-bound", type=float, default=0.02)
    ap.add_argument("--top-k", type=int, default=8)
    args = ap.parse_args()
    n_mult = (args.n_mult if args.n_mult is not None
              else (12 if args.quick else 8))
    run(n_mult=n_mult, quick=args.quick,
        quality_bound=args.quality_bound, top_k=args.top_k)
