"""Kernel micro-benchmarks: exact vs LUT-gather vs low-rank approximate
matmul (jnp lowering; the Pallas interpret path is correctness-only on
CPU), plus the bit-parallel netlist simulator vs naive evaluation.

These are CPU wall-times — NOT the roofline numbers (those come from the
dry-run cost analysis); they document the relative algorithmic weight
of the three emulation strategies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.backend import MatmulBackend, backend_matmul
from repro.core import seeds
from repro.core.luts import decompose_lut, exact_mul_lut
from repro.core.netlist import exhaustive_inputs
from repro.kernels import ops

from .common import emit, time_call

M, K, N = 256, 512, 256


def run() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    lut = exact_mul_lut(8)
    fac = decompose_lut(lut, 4)

    backends = {
        "bf16": MatmulBackend(mode="bf16"),
        "int8": MatmulBackend(mode="int8"),
        "lut_gather": MatmulBackend(mode="lut", lut=lut),
        "lowrank_r4": MatmulBackend(mode="lowrank",
                                    factors_u=np.asarray(fac.u),
                                    factors_v=np.asarray(fac.v)),
    }
    for name, be in backends.items():
        fn = jax.jit(lambda a, b, _be=be: backend_matmul(a, b, _be))
        fn(x, w).block_until_ready()
        us = time_call(lambda: fn(x, w).block_until_ready(), iters=3)
        emit(f"kernel/approx_matmul/{name}", us, f"{M}x{K}x{N}")

    # bitsim: exhaustive 8x8 multiplier eval (65 536 vectors)
    nl = seeds.array_multiplier(8)
    planes = exhaustive_inputs(16)
    us_np = time_call(lambda: nl.eval_words(planes), iters=3)
    emit("kernel/bitsim/numpy_bitparallel", us_np, "65536 vectors")
    us_k = time_call(lambda: ops.bitsim(nl, planes), iters=3)
    emit("kernel/bitsim/pallas_interpret", us_k, "65536 vectors")


if __name__ == "__main__":
    run()
