"""Kernel lane: fused single-program datapath vs the two-step
quantize→gather pipeline (DESIGN.md §2.10), spec-first.

For each bench shape and datapath contract the suite times the SAME
``BackendSpec`` under ``variant="ref"`` (two-step: calibrate/quantize,
LUT gather, dequant as separate jit-fused ops) and ``variant="fused"``
(the whole chain inside one Pallas program plus a thin f32 epilogue),
checks bit-identity between the two, pulls the roofline terms
(flops / bytes accessed → operational intensity) from the compiled
programs' cost analysis, and audits trace counts through
``repro.launch.compile_cache.trace_audit``.

The record lands in ``benchmarks/results/BENCH_kernels.json`` — the
fallback input for ``benchmarks.roofline`` when no 512-device dry-run
results exist — and the run FAILS (nonzero) when any variant pair
diverges bitwise or the fused geomean speedup drops below
``SPEEDUP_GATE``.

    PYTHONPATH=src python -m benchmarks.kernel_bench [--quick]

The bit-parallel netlist-simulator timing lane (bitsim vs numpy) rides
along unchanged at the end.
"""
from __future__ import annotations

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.backend import backend_matmul
from repro.approx.specs import BackendSpec
from repro.core import seeds
from repro.core.library import build_default_library
from repro.core.netlist import exhaustive_inputs
from repro.kernels import ops
from repro.launch.compile_cache import trace_audit

from .common import emit, time_call

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_kernels.json")

# (M, K, N) — small-batch decode-like, wide square, and the historical
# activation-heavy shape.  --quick drops the largest.
SHAPES = ((4, 512, 512), (8, 1024, 1024), (256, 512, 256))
SHAPES_QUICK = ((4, 512, 512), (8, 1024, 1024))

# Acceptance gate: geomean fused-vs-two-step wall-time ratio on CPU.
SPEEDUP_GATE = 1.2

# Datapath contracts under test: (tag, multiplier name, bit_width).
# The composed entries are registered on the tiny library below.
CONTRACTS = (
    ("lut8", "mul8u_trunc2", None),
    ("composed16", "mul16u_c_mul8u_trunc6_loa4", 16),
)
CONTRACTS_FULL = CONTRACTS + (
    ("composed12", "mul12u_c_mul8u_trunc2_trunc3", 12),
)


def _library():
    lib = build_default_library("tiny")
    lib.add_composed("mul8u_trunc6", 16, "loa4", samples=512)
    lib.add_composed("mul8u_trunc2", 12, "trunc3", samples=512)
    return lib


def _cost_terms(fn, x, w) -> dict:
    """flops / bytes-accessed roofline terms from the compiled program."""
    cost = jax.jit(fn).lower(x, w).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):      # older per-computation form
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0) or 0.0)
    bytes_ = float(cost.get("bytes accessed", 0.0) or 0.0)
    return {"flops": flops, "bytes": bytes_,
            "oi": (flops / bytes_) if bytes_ else 0.0}


def _bench_pair(lib, tag, mult, bw, shape) -> dict:
    m, k, n = shape
    rng = np.random.default_rng(hash((tag, shape)) % 2**32)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))

    def _fn(variant):
        be = BackendSpec(mode="lut", multiplier=mult, variant=variant,
                         bit_width=bw).materialize(lib)
        return jax.jit(lambda a, b, _be=be: backend_matmul(a, b, _be))

    # two_step = the Pallas quantize-then-gather pipeline the fused
    # program replaces (same kernel family, separately programmed);
    # the pure-jnp "ref" variant rides along as a context row.
    ref, two_step, fused = _fn("ref"), _fn("pallas"), _fn("fused")
    ref_out = np.asarray(ref(x, w).block_until_ready())
    with trace_audit() as tc_two:
        two_out = np.asarray(two_step(x, w).block_until_ready())
    with trace_audit() as tc_fused:
        fused_out = np.asarray(fused(x, w).block_until_ready())

    bit_identical = bool(np.array_equal(two_out, fused_out)
                         and np.array_equal(ref_out, fused_out))
    us_ref = time_call(lambda: ref(x, w).block_until_ready())
    us_two = time_call(lambda: two_step(x, w).block_until_ready())
    us_fused = time_call(lambda: fused(x, w).block_until_ready())

    def _spec_fn(variant):
        be = BackendSpec(mode="lut", multiplier=mult, variant=variant,
                         bit_width=bw).materialize(lib)
        return lambda a, b, _be=be: backend_matmul(a, b, _be)

    entry = {
        "contract": tag,
        "multiplier": mult,
        "shape": f"{m}x{k}x{n}",
        "ref_us": us_ref,
        "two_step_us": us_two,
        "fused_us": us_fused,
        "speedup": us_two / us_fused,
        "bit_identical": bit_identical,
        "traces": {"two_step": tc_two.traced_programs,
                   "fused": tc_fused.traced_programs},
        "roofline": {"two_step": _cost_terms(_spec_fn("pallas"), x, w),
                     "fused": _cost_terms(_spec_fn("fused"), x, w)},
    }
    emit(f"kernel/fused_vs_two_step/{tag}/{m}x{k}x{n}", us_fused,
         f"two_step={us_two:.1f}us;ref={us_ref:.1f}us;"
         f"speedup={entry['speedup']:.2f}x;identical={bit_identical}")
    return entry


def _bench_bitsim() -> dict:
    # bitsim: exhaustive 8x8 multiplier eval (65 536 vectors)
    nl = seeds.array_multiplier(8)
    planes = exhaustive_inputs(16)
    us_np = time_call(lambda: nl.eval_words(planes), iters=3)
    emit("kernel/bitsim/numpy_bitparallel", us_np, "65536 vectors")
    us_k = time_call(lambda: ops.bitsim(nl, planes), iters=3)
    emit("kernel/bitsim/pallas_interpret", us_k, "65536 vectors")
    return {"numpy_us": us_np, "pallas_us": us_k, "vectors": 65536}


def run(quick: bool = False) -> dict:
    lib = _library()
    shapes = SHAPES_QUICK if quick else SHAPES
    contracts = CONTRACTS if quick else CONTRACTS_FULL

    entries = [_bench_pair(lib, tag, mult, bw, shape)
               for tag, mult, bw in contracts
               for shape in shapes]

    speedups = [e["speedup"] for e in entries]
    geomean = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
    all_identical = all(e["bit_identical"] for e in entries)
    record = {
        "backend": jax.default_backend(),
        "quick": quick,
        "speedup_gate": SPEEDUP_GATE,
        "geomean_speedup": geomean,
        "bit_identical": all_identical,
        "entries": entries,
        "bitsim": _bench_bitsim(),
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    emit("kernel/fused_geomean_speedup", 0.0,
         f"{geomean:.2f}x;gate={SPEEDUP_GATE}x;identical={all_identical}")

    # gates AFTER the record is on disk — CI keeps it as the triage
    # artifact (upload-artifact if: always())
    if not all_identical:
        bad = [e for e in entries if not e["bit_identical"]]
        raise AssertionError(
            "fused datapath diverged bitwise from the two-step pipeline: "
            + ", ".join(f"{e['contract']}@{e['shape']}" for e in bad))
    if geomean < SPEEDUP_GATE:
        raise AssertionError(
            f"fused geomean speedup {geomean:.2f}x below the "
            f"{SPEEDUP_GATE}x gate ({BENCH_PATH})")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer shapes/contracts (CI lane)")
    ap.add_argument("--compile-cache", action="store_true",
                    help="enable the persistent JAX compilation cache "
                         "(launch.compile_cache) before benchmarking")
    args = ap.parse_args()
    if args.compile_cache:
        from repro.launch.compile_cache import enable_compile_cache
        enable_compile_cache()
    print("name,us_per_call,derived")
    run(quick=args.quick)


if __name__ == "__main__":
    main()
