"""Paper Table I: number of approximate implementations per circuit
kind and bit-width in the library."""
from __future__ import annotations

from repro.core.library import get_default_library

from .common import emit, time_call


def run() -> None:
    lib = get_default_library()
    us = time_call(lib.counts_table, iters=3)
    for row in lib.counts_table():
        emit(f"table_I/{row['circuit']}_{row['bit_width']}b", us,
             f"n={row['n_implementations']}")


if __name__ == "__main__":
    run()
