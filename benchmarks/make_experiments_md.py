"""Regenerate the §Dry-run, §Roofline, §Heterogeneous, §Wide,
§Objectives, §Serve, §Evolve, §Kernels and §DSE tables of
EXPERIMENTS.md from the result JSONs (idempotent; §Perf and prose are
maintained by hand between the markers)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")
SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load() -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    out.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9),
                            r.get("multi_pod", False)))
    return out


def fmt_bytes(n):
    if n is None:
        return "-"
    return f"{n / 1e9:.2f}"


def dryrun_table(results) -> str:
    rows = ["| arch | shape | mesh | compile | peak GB/dev | "
            "collective GB/dev | status |",
            "|---|---|---|---|---|---|---|"]
    for r in results:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - |"
                        f" - | FAIL: {r.get('error', '?')[:60]} |")
            continue
        coll = r["collectives"].get("total_bytes", 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {r['compile_s']}s | {r['memory']['peak_gb']:.1f} "
            f"| {fmt_bytes(coll)} | OK |")
    return "\n".join(rows)


def roofline_table(results) -> str:
    rows = ["| arch | shape | kind | compute s | memory s | collective s"
            " | bottleneck | useful FLOPs ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if not r.get("ok") or r.get("multi_pod"):
            continue  # roofline table is single-pod per the brief
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | **{rf['bottleneck']}** "
            f"| {rf['useful_flops_ratio']:.3f} "
            f"| {rf['roofline_fraction']:.4f} |")
    return "\n".join(rows)


HILLCLIMB = os.path.join(os.path.dirname(__file__), "results",
                         "hillclimb")

_PERF_NOTES = {
    ".A1_moeblocks": "A1 block-local MoE dispatch (moe_blocks=16)",
    ".A2_flash": "A2 = A1 + chunked attention",
    ".A3_cap1": "A3 = A1 + capacity_factor 1.0",
    ".B1_flashmla": "B1 chunked (flash) MLA attention",
    ".B2_moeblocks": "B2 = B1 + block-local MoE dispatch",
    ".B3_losschunk": "B3 = B2 + loss_chunk 256",
    ".C1_prepared": "C1 offline-prepared bf16 weight tables (rank 4)",
    ".C2_rank2": "C2 = prepared + rank 2",
    ".C3_int8_reference": "C3 reference: exact-int8 datapath (no emulation)",
}


def perf_table() -> str:
    cells = [("qwen3-moe-30b-a3b", "train_4k"),
             ("deepseek-v2-236b", "train_4k"),
             ("yi-34b", "decode_32k")]
    out = []
    for arch, shape in cells:
        base_p = os.path.join(RESULTS, f"{arch}_{shape}_sp.json")
        if not os.path.exists(base_p):
            continue
        rows = [f"**{arch} / {shape}**", "",
                "| variant | compute s | memory s | collective s | "
                "bottleneck | useful | roofline frac | peak GB |",
                "|---|---|---|---|---|---|---|---|"]
        entries = [("baseline", json.load(open(base_p)))]
        for tag, note in _PERF_NOTES.items():
            p = os.path.join(HILLCLIMB, f"{arch}_{shape}_sp{tag}.json")
            if os.path.exists(p):
                entries.append((note, json.load(open(p))))
        for name, r in entries:
            if not r.get("ok"):
                rows.append(f"| {name} | - | - | - | FAIL | - | - | - |")
                continue
            rf = r["roofline"]
            rows.append(
                f"| {name} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f}"
                f" | {rf['collective_s']:.3f} | {rf['bottleneck']} "
                f"| {rf['useful_flops_ratio']:.3f} "
                f"| {rf['roofline_fraction']:.4f} "
                f"| {r['memory']['peak_gb']:.1f} |")
        out.append("\n".join(rows))
    return "\n\n".join(out) if out else "(hillclimb results pending)"


HETERO_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "BENCH_heterogeneous.json")


def hetero_table() -> str:
    """Uniform-vs-heterogeneous front from BENCH_heterogeneous.json
    (written by `python -m benchmarks.heterogeneous_pareto`)."""
    if not os.path.exists(HETERO_PATH):
        return "(run `python -m benchmarks.heterogeneous_pareto` first)"
    with open(HETERO_PATH) as f:
        r = json.load(f)
    rows = [f"Baseline (golden int8) accuracy "
            f"{100 * r['baseline_accuracy']:.2f}%, quality bound "
            f"{100 * r['quality_bound']:.1f} points, "
            f"{r['n_mult']} candidate multipliers"
            f"{' (quick)' if r.get('quick') else ''}.", "",
            "| axis | point | power% | acc% |",
            "|---|---|---|---|"]
    if r.get("uniform_best"):
        u = r["uniform_best"]
        rows.append(f"| uniform best | {u['multiplier']} "
                    f"| {100 * u['network_rel_power']:.1f} "
                    f"| {100 * u['accuracy']:.2f} |")
    floor = r["baseline_accuracy"] - r["quality_bound"]
    hetero = r.get("heterogeneous", [])
    survivors = [h for h in hetero if h["accuracy"] >= floor]
    for h in survivors[:8]:
        rows.append(f"| heterogeneous | {h['multiplier']} "
                    f"| {100 * h['network_rel_power']:.1f} "
                    f"| {100 * h['accuracy']:.2f} |")
    rows += ["", f"{len(hetero)} candidates verified, {len(survivors)} "
             "within the bound (prediction proposes, exact batched "
             "verification disposes)."]
    if r.get("dominating"):
        d = r["dominating"]
        rows += ["", f"Dominating point: {d['multiplier']} at "
                 f"{100 * d['network_rel_power']:.1f}% power / "
                 f"{100 * d['accuracy']:.2f}% accuracy — strictly below "
                 f"the best uniform point at ≥ its accuracy."]
    v = r.get("verification")
    if v:
        rows += ["", f"Exact verification of {v['k']} candidates: "
                 f"{v['sequential_s']}s sequential vs {v['batched_s']}s "
                 f"batched ({v['speedup']}x, bit_identical="
                 f"{v['bit_identical']})."]
    return "\n".join(rows)


WIDE_PATH = os.path.join(os.path.dirname(__file__), "results",
                         "BENCH_wide.json")


def wide_table() -> str:
    """Mixed-width Pareto front from BENCH_wide.json (written by
    `python -m benchmarks.wide_width_pareto`)."""
    if not os.path.exists(WIDE_PATH):
        return "(run `python -m benchmarks.wide_width_pareto` first)"
    with open(WIDE_PATH) as f:
        r = json.load(f)
    ev = r["evaluation"]
    rows = [f"Baseline (golden int8) accuracy "
            f"{100 * r['baseline_accuracy']:.2f}%, quality bound "
            f"{100 * r['quality_bound']:.1f} points, "
            f"{ev['n_candidates']} candidates "
            f"({ev['n_wide']} composed wide)"
            f"{' (quick)' if r.get('quick') else ''}.  Power is vs "
            "exact 8-bit (`rel_power_map(ref='mul8u_exact')`); "
            "fidelity is mean |logit error| vs the f32 model.", "",
            "| front | multiplier | W | power% | acc% | logit MAE |",
            "|---|---|---|---|---|---|"]
    for kind, key in (("accuracy", "pareto_front_accuracy"),
                      ("fidelity", "pareto_front_fidelity")):
        for p in r.get(key, []):
            fid = p.get("logit_mae_vs_f32")
            rows.append(
                f"| {kind} | {p['multiplier']} | {p['bit_width']} "
                f"| {100 * p['network_rel_power']:.1f} "
                f"| {100 * p['accuracy']:.2f} "
                f"| {fid if fid is not None else '-'} |")
    beyond = r.get("wide_beyond_8bit_fidelity", [])
    if beyond:
        rows += ["", f"{len(beyond)} composed wide point(s) beat every "
                 "8-bit candidate's fidelity within the bound — the "
                 "quantization-noise axis the 8-bit sweep cannot "
                 f"reach: {', '.join(beyond)}."]
    rows += ["", f"Composed-wide sweep: {ev['wide_sequential_s']}s "
             f"sequential vs {ev['wide_batched_s']}s in one banked "
             f"program ({ev['speedup']}x, bit_identical="
             f"{ev['bit_identical']})."]
    return "\n".join(rows)


OBJECTIVES_PATH = os.path.join(os.path.dirname(__file__), "results",
                               "BENCH_objectives.json")


def objectives_table() -> str:
    """Multi-objective fronts from BENCH_objectives.json (written by
    `python -m benchmarks.objectives_pareto`)."""
    if not os.path.exists(OBJECTIVES_PATH):
        return "(run `python -m benchmarks.objectives_pareto` first)"
    with open(OBJECTIVES_PATH) as f:
        r = json.load(f)
    rn, dec = r["resnet"], r["decoder"]
    rows = [f"ResNet-8 sweep over {len(rn['candidates'])} candidates, "
            f"objectives {tuple(rn['objectives'])}"
            f"{' (quick)' if r.get('quick') else ''}; 2-d front "
            f"bit-identical to the pre-§2.7 sweep: "
            f"{rn['bit_identical_2d']}.", "",
            "| front | multiplier | acc% | power% | delay% |",
            "|---|---|---|---|---|"]
    for kind, key in (("acc×power", "pareto_2d"),
                      ("acc×power×delay", "pareto_3d")):
        for p in rn.get(key, []):
            rows.append(
                f"| {kind} | {p['multiplier']} "
                f"| {100 * p['accuracy']:.2f} "
                f"| {100 * p['power']:.1f} "
                f"| {100 * p['delay']:.1f} |"
                if "delay" in p else
                f"| {kind} | {p['multiplier']} "
                f"| {100 * p['accuracy']:.2f} "
                f"| {100 * p['power']:.1f} | - |")
    rows += ["", f"Decoder scenario: `{dec['workload']}` "
             f"({dec['arch']}, reduced) over {len(dec['candidates'])} "
             f"candidates, objectives {tuple(dec['objectives'])} — "
             f"banked sweep bit-identical to sequential: "
             f"{dec['bit_identical']} ({dec['speedup']}x).", "",
             "| front | multiplier | logit MAE | top-1 agree | power% "
             "| delay% |", "|---|---|---|---|---|---|"]
    for p in dec.get("pareto_3d", []):
        rows.append(f"| mae×power×delay | {p['multiplier']} "
                    f"| {p['logit_mae']:.6f} "
                    f"| {p['top1_agreement']:.2f} "
                    f"| {100 * p['power']:.1f} "
                    f"| {100 * p['delay']:.1f} |")
    if dec.get("selected"):
        s = dec["selected"]
        rows += ["", f"Declarative pick (`select(..., "
                 f"constraints={{'logit_mae': MaxDrop(0.05)}}, "
                 f"minimize='power')`): {s['multiplier']} at "
                 f"{100 * s['power']:.1f}% power, logit MAE "
                 f"{s['logit_mae']:.6f}."]
    return "\n".join(rows)


SERVE_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_serve.json")


def serve_table() -> str:
    """Continuous-batching serve load from BENCH_serve.json (written by
    `python -m benchmarks.serve_load`)."""
    if not os.path.exists(SERVE_PATH):
        return "(run `python -m benchmarks.serve_load` first)"
    with open(SERVE_PATH) as f:
        r = json.load(f)
    rows = [f"`{r['arch']}` (reduced), {r['n_slots']} slots, "
            f"{len(r['multiplier_bank'])}-multiplier fixed bank"
            f"{' (quick)' if r.get('quick') else ''}.  Poisson "
            "arrivals; each level draws request policies from that "
            "many distinct tenant accelerator selections (uniform + "
            "one heterogeneous per-layer policy at ≥4).", "",
            "| concurrent policies | requests | tok/s | p50 ms | "
            "p99 ms | decode steps | decode traces |",
            "|---|---|---|---|---|---|---|"]
    for lv in r.get("levels", []):
        rows.append(
            f"| {lv['n_policies']} | {lv['n_requests']} "
            f"| {lv['tokens_per_s']} | {lv['p50_ms']} | {lv['p99_ms']} "
            f"| {lv['decode_steps']} "
            f"| {lv['trace_counts']['decode']} |")
    rows += ["", f"O(1)-programs gate (decode traces stay at 1 across "
             f"all levels): **{r['trace_gate_o1_programs']}**.  "
             f"Bit-identity vs per-request sequential `generate` over "
             f"{r['bit_identity_requests']} requests: "
             f"**{r['bit_identity']}**."]
    return "\n".join(rows)


EVOLVE_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "BENCH_evolve.json")


def evolve_table() -> str:
    """Device-resident library generation from BENCH_evolve.json
    (written by `python -m benchmarks.evolve_library`)."""
    if not os.path.exists(EVOLVE_PATH):
        return "(run `python -m benchmarks.evolve_library` first)"
    with open(EVOLVE_PATH) as f:
        r = json.load(f)
    th, lad, lib = r["throughput"], r["ladder"], r["library_tiny"]
    ident = r["metric_identity"]
    rows = [f"Population of {r['pop_size']} mul8 candidates scored on "
            f"{r['search_samples']} search vectors, `{r['backend']}` "
            f"backend{' (quick)' if r.get('quick') else ''}.", "",
            "| engine | candidate evals/s |",
            "|---|---|",
            f"| numpy (sequential) | {th['evals_per_s_numpy']:.0f} |",
            f"| device (one fused program) "
            f"| {th['evals_per_s_device']:.0f} |", "",
            f"Speedup **{th['speedup']:.2f}×** "
            f"(gate ≥{th['gate']:.0f}×).  Metric bit-identity across "
            f"engines: "
            f"{'**exact** on all ' + str(len(ident)) + ' metrics' if all(ident.values()) else 'MISMATCH ' + str(ident)} "
            f"(er/mae/wce reduce on device: {tuple(r['device_metrics'])}).",
            "",
            f"Fused e_max ladder ({lad['rungs']} rungs × "
            f"{lad['generations']} generations, one device program per "
            f"generation): {lad['circuits']} circuits in "
            f"{lad['wall_s']}s ({lad['circuits_per_s']}/s, "
            f"{lad['candidate_evals']} candidate evaluations).", "",
            "| tiny-budget build | entries | evolved | wall s |",
            "|---|---|---|---|",
            f"| legacy chained ladder | {lib['legacy']['entries']} "
            f"| {lib['legacy']['evolved']} "
            f"| {lib['legacy']['wall_s']} |",
            f"| device population ladder | {lib['device']['entries']} "
            f"| {lib['device']['evolved']} "
            f"| {lib['device']['wall_s']} |", "",
            f"Archive growth at equal generation budget (no parent "
            f"thinning + composed pickup): **{lib['grew']}**."]
    return "\n".join(rows)


DSE_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_dse.json")


def dse_table() -> str:
    """Surrogate-guided vs exact-sweep DSE from BENCH_dse.json (written
    by `python -m benchmarks.dse_surrogate`)."""
    if not os.path.exists(DSE_PATH):
        return "(run `python -m benchmarks.dse_surrogate` first)"
    with open(DSE_PATH) as f:
        r = json.load(f)
    e2e, fid, fr = r["end_to_end"], r["fidelity"], r["front"]
    sur = r["surrogate"]
    rows = [f"{r['n_circuits']} candidate multipliers × "
            f"{r['n_layers']} layers, trained ResNet-8, primary "
            f"`{r['workload_primary']}` (vs golden int8), train "
            f"fraction {r['train_fraction']}"
            f"{' (quick)' if r.get('quick') else ''}.  The surrogate "
            f"measures {sur['n_train'] + sur['n_val']} circuits "
            f"exactly, predicts the rest, widens the beam bound by the "
            f"held-out calibration band "
            f"({sur['calibration']:.4f}), and verifies exactly.", "",
            "| predict stage | layer evals | end-to-end s | speedup |",
            "|---|---|---|---|",
            f"| exact sweep | {e2e['evals_exact']} "
            f"| {e2e['exact_s']} | 1.00× |",
            f"| surrogate | {e2e['evals_surrogate']} "
            f"| {e2e['surrogate_s']} | **{e2e['speedup']}×** |", "",
            f"Predicted-vs-measured per-layer Spearman ρ over the "
            f"{fid['n_unseen']} unseen circuits: mean "
            f"**{fid['mean_rho']}** (min {fid['min_rho']}, gate ≥ "
            f"{fid['gate']}).  Verified fronts: surrogate "
            f"{len(fr['surrogate'])} points, exact "
            f"{len(fr['exact'])} points, matches-or-dominates "
            f"**{fr['matches_or_dominates']}**.", "",
            "| front | multiplier | logit MAE | power% |",
            "|---|---|---|---|"]
    for kind, key in (("surrogate", "surrogate"), ("exact", "exact")):
        for p in fr[key]:
            rows.append(f"| {kind} | {p['multiplier']} "
                        f"| {p['logit_mae']:.6f} "
                        f"| {100 * p['network_rel_power']:.1f} |")
    return "\n".join(rows)


KERNELS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "BENCH_kernels.json")


def kernels_table() -> str:
    """Fused-vs-two-step kernel lane from BENCH_kernels.json (written
    by `python -m benchmarks.kernel_bench`)."""
    if not os.path.exists(KERNELS_PATH):
        return "(run `python -m benchmarks.kernel_bench` first)"
    with open(KERNELS_PATH) as f:
        r = json.load(f)
    rows = [f"Fused quantize→gather→accumulate→dequant datapath vs the "
            f"two-step Pallas pipeline, `{r['backend']}` backend"
            f"{' (quick)' if r.get('quick') else ''}.  Every pair is "
            f"checked for BIT-identity before timing.", "",
            "| contract | shape | two-step µs | fused µs | speedup | "
            "OI two-step | OI fused | identical |",
            "|---|---|---|---|---|---|---|---|"]
    for e in r["entries"]:
        rows.append(
            f"| {e['contract']} | {e['shape']} "
            f"| {e['two_step_us']:.0f} | {e['fused_us']:.0f} "
            f"| {e['speedup']:.2f}× "
            f"| {e['roofline']['two_step']['oi']:.3f} "
            f"| {e['roofline']['fused']['oi']:.3f} "
            f"| {e['bit_identical']} |")
    bs = r["bitsim"]
    rows += ["",
             f"Geomean speedup **{r['geomean_speedup']:.2f}×** "
             f"(gate ≥{r['speedup_gate']}×), bit-identical across all "
             f"entries: **{r['bit_identical']}**.  Bitsim lane: numpy "
             f"{bs['numpy_us']:.0f}µs vs Pallas-interpret "
             f"{bs['pallas_us']:.0f}µs over {bs['vectors']} vectors."]
    return "\n".join(rows)


PROFILES_PATH = os.path.join(os.path.dirname(__file__), "results",
                             "BENCH_profiles.json")


def profiles_table() -> str:
    """Model-zoo module-resilience profiles from BENCH_profiles.json
    (written by `python -m benchmarks.arch_profiles`)."""
    if not os.path.exists(PROFILES_PATH):
        return "(run `python -m benchmarks.arch_profiles` first)"
    with open(PROFILES_PATH) as f:
        r = json.load(f)
    archs = r["zoo"]["archs"]
    idc = r["identity_checks"]
    rows = [f"{len(archs)} architectures × {len(r['multipliers'])} "
            f"library multipliers, one banked compiled program per "
            f"module sweep{' (quick)' if r.get('quick') else ''}.  "
            f"Selected = cheapest per-module policy with primary-metric "
            f"drop ≤ {r['max_drop']} (golden-int8 baseline); power is "
            f"network-relative.", "",
            "| arch | family | modules | most tolerant | least "
            "tolerant | selected power% | drop |",
            "|---|---|---|---|---|---|---|"]
    for name, p in archs.items():
        sel = p["selected"]
        sel_pow = f"{100 * sel['power']:.1f}" if sel else "—"
        sel_drop = f"{sel['quality_drop']:.4f}" if sel else "—"
        rows.append(f"| {name} | {p['model_family']} "
                    f"| {len(p['modules'])} | {p['ranking'][0]} "
                    f"| {p['ranking'][-1]} | {sel_pow} | {sel_drop} |")
    fam = sorted(r["zoo"]["family_mean_drop"].items(),
                 key=lambda kv: kv[1])
    rows += ["", "| module family | mean drop across zoo |",
             "|---|---|"]
    rows += [f"| {f} | {d:.4f} |" for f, d in fam]
    ident = "; ".join(
        f"{a}: bit_identical={c['bit_identical']}, "
        f"{c['rows']}-row sweep traced {c['traced_full']} program(s)"
        for a, c in idc.items())
    rows += ["", f"Banked-vs-sequential identity gates — {ident}."]
    return "\n".join(rows)


def replace_section(text: str, marker: str, body: str) -> str:
    begin = f"<!-- BEGIN AUTO {marker} -->"
    end = f"<!-- END AUTO {marker} -->"
    if begin not in text:
        return text + f"\n{begin}\n{body}\n{end}\n"
    pre = text.split(begin)[0]
    post = text.split(end)[1]
    return pre + begin + "\n" + body + "\n" + end + post


def main() -> None:
    results = load()
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    text = open(path).read() if os.path.exists(path) else "# EXPERIMENTS\n"
    text = replace_section(text, "DRYRUN", dryrun_table(results))
    text = replace_section(text, "ROOFLINE", roofline_table(results))
    text = replace_section(text, "PERF", perf_table())
    text = replace_section(text, "HETERO", hetero_table())
    text = replace_section(text, "WIDE", wide_table())
    text = replace_section(text, "OBJECTIVES", objectives_table())
    text = replace_section(text, "SERVE", serve_table())
    text = replace_section(text, "EVOLVE", evolve_table())
    text = replace_section(text, "KERNELS", kernels_table())
    text = replace_section(text, "DSE", dse_table())
    text = replace_section(text, "PROFILES", profiles_table())
    with open(path, "w") as f:
        f.write(text)
    ok = sum(1 for r in results if r.get("ok"))
    print(f"wrote {path}: {ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
