"""Objective-first DSE benchmark (DESIGN.md §2.7).

The paper's library "forms Pareto fronts with respect to several error
metrics, power consumption and other circuit parameters"; this
benchmark exercises the Workload/Objective layer that makes those axes
pluggable at NETWORK level and writes
``benchmarks/results/BENCH_objectives.json`` recording:

  * the trained ResNet-8 / synthetic CIFAR-10 sweep (one banked
    program) Pareto'd over ``("accuracy", "power")`` AND over
    ``("accuracy", "power", "delay")`` — the extra circuit axis the
    N-dimensional front opens,
  * the 2-D-FRONT BIT-IDENTITY GATE: the generic N-d ``pareto_points``
    restricted to the legacy ``(accuracy, power)`` pair must reproduce
    the pre-refactor sweep algorithm exactly — membership, order and
    values (the run FAILS otherwise),
  * a decoder-LM scenario: ``lm_fidelity`` over a registered config
    (reduced ``qwen1.5-0.5b``) swept through the SAME banked engine
    and Pareto'd over ``("logit_mae", "power", "delay")`` — a 3-axis
    front over a workload that measures no classification accuracy at
    all, with a sequential-vs-banked bit-identity gate, and
  * a declarative ``select(...)`` pick on each scenario.

``--quick`` (CI mode) shrinks the ResNet eval set; the decoder config
is smoke-sized either way.  All checks are deterministic (seeded
synthetic data + committed checkpoint).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.approx.dse import DesignPoint, explore
from repro.approx.objectives import MaxDrop, select, value_of
from repro.approx.workload import lm_fidelity
from repro.core.library import get_default_library

from .common import emit
from .resilience_common import case_study_names, make_eval_fn, trained_resnet

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_objectives.json")

DECODER_ARCH = "qwen1.5-0.5b"


def _legacy_pareto_2d(points):
    """The pre-§2.7 (accuracy max, power min) sweep, verbatim — the
    reference side of the bit-identity gate."""
    pts = sorted(points, key=lambda p: (p.network_rel_power, -p.accuracy))
    front, best_acc, i = [], float("-inf"), 0
    while i < len(pts):
        j = i
        power = pts[i].network_rel_power
        while j < len(pts) and pts[j].network_rel_power == power:
            j += 1
        acc_max = pts[i].accuracy
        if acc_max > best_acc:
            front.extend(p for p in pts[i:j] if p.accuracy == acc_max)
            best_acc = acc_max
        i = j
    return front


def _point_dict(p: DesignPoint, axes) -> dict:
    d = {"multiplier": p.multiplier}
    for a in axes:
        d[a] = round(value_of(p, a), 6)
    return d


def run(n_mult: int = 8, quick: bool = False,
        quality_bound: float = 0.02) -> dict:
    lib = get_default_library()

    # -- ResNet scenario: accuracy x power x delay ---------------------
    cfg, params = trained_resnet(8)
    eval_n = 64 if quick else 256
    wl = make_eval_fn(cfg, params, eval_n=eval_n, batch=64)
    names = case_study_names(lib, n_mult)
    # aggressive truncations keep the accuracy axis from saturating on
    # the synthetic eval set, so the fronts stay non-degenerate
    for extra in ("mul8u_trunc5", "mul8u_trunc4"):
        if extra in lib.entries and extra not in names:
            names.append(extra)

    t0 = time.perf_counter()
    result = explore(workload=wl, library=lib, multipliers=names,
                     mode="lut", per_layer=False, batch=True,
                     objectives=("accuracy", "power", "delay"))
    sweep_s = time.perf_counter() - t0

    front_2d = result.pareto(objectives=("accuracy", "power"))
    legacy_2d = _legacy_pareto_2d(result.all_layers)
    identical_2d = [id(p) for p in front_2d] == [id(p) for p in legacy_2d]
    front_3d = result.pareto()
    emit("objectives/resnet_sweep", sweep_s * 1e6,
         f"n={len(names)};front2d={len(front_2d)};"
         f"front3d={len(front_3d)};bit_identical={identical_2d}")

    pick = select(result, constraints={"accuracy": MaxDrop(quality_bound)},
                  minimize="power", axis="all_layers")

    # -- decoder-LM scenario: logit_mae x power x delay ----------------
    lm_wl = lm_fidelity(DECODER_ARCH, batch=2, seq_len=16, n_batches=2)
    lm_names = names[:min(len(names), 6)]
    t0 = time.perf_counter()
    lm_result = explore(workload=lm_wl, library=lib,
                        multipliers=lm_names, mode="lut",
                        per_layer=False, batch=True,
                        objectives=("logit_mae", "power", "delay"))
    lm_bat_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    lm_seq = explore(workload=lm_wl, library=lib, multipliers=lm_names,
                     mode="lut", per_layer=False, batch=False,
                     objectives=("logit_mae", "power", "delay"))
    lm_seq_s = time.perf_counter() - t0
    lm_identical = [p.metrics for p in lm_result.all_layers] == \
                   [p.metrics for p in lm_seq.all_layers]
    lm_front = lm_result.pareto()
    lm_speedup = lm_seq_s / lm_bat_s if lm_bat_s > 0 else float("inf")
    emit("objectives/lm_fidelity_sweep", lm_bat_s * 1e6,
         f"n={len(lm_names)};front3d={len(lm_front)};"
         f"speedup={lm_speedup:.2f};bit_identical={lm_identical}")

    lm_pick = select(lm_result,
                     constraints={"logit_mae": MaxDrop(0.05)},
                     minimize="power", axis="all_layers")

    axes_rn = ("accuracy", "power", "delay")
    axes_lm = ("logit_mae", "top1_agreement", "power", "delay")
    record = {
        "benchmark": "objectives_pareto",
        "quick": quick,
        "backend": jax.default_backend(),
        "quality_bound": quality_bound,
        "resnet": {
            "workload": wl.name,
            "objectives": list(result.objectives),
            "baseline_metrics": result.baseline_metrics,
            "candidates": names,
            "sweep": [_point_dict(p, axes_rn)
                      for p in result.all_layers],
            "pareto_2d": [_point_dict(p, ("accuracy", "power"))
                          for p in front_2d],
            "pareto_3d": [_point_dict(p, axes_rn) for p in front_3d],
            "bit_identical_2d": identical_2d,
            "selected": _point_dict(pick, axes_rn) if pick else None,
            "sweep_s": round(sweep_s, 4),
        },
        "decoder": {
            "workload": lm_wl.name,
            "arch": DECODER_ARCH,
            "objectives": list(lm_result.objectives),
            "baseline_metrics": lm_result.baseline_metrics,
            "candidates": lm_names,
            "sweep": [_point_dict(p, axes_lm)
                      for p in lm_result.all_layers],
            "pareto_3d": [_point_dict(p, axes_lm) for p in lm_front],
            "bit_identical": lm_identical,
            "selected": (_point_dict(lm_pick, axes_lm)
                         if lm_pick else None),
            "batched_s": round(lm_bat_s, 4),
            "sequential_s": round(lm_seq_s, 4),
            "speedup": round(lm_speedup, 2),
        },
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    emit("objectives/bench_record", 0.0, BENCH_PATH)

    # record is written first so CI failures still upload the artifact
    if not identical_2d:
        raise SystemExit(
            "generic N-d pareto_points diverged from the pre-refactor "
            f"(accuracy, power) sweep (see {BENCH_PATH})")
    if not lm_identical:
        raise SystemExit(
            "banked LM fidelity sweep diverged from sequential "
            f"evaluation (see {BENCH_PATH})")
    if not lm_front:
        raise SystemExit(
            f"empty 3-axis decoder fidelity front (see {BENCH_PATH})")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-mult", type=int, default=8,
                    help="case-study candidate count")
    ap.add_argument("--quick", action="store_true",
                    help="small ResNet eval set (CI); restores the "
                         "committed trained checkpoint either way")
    ap.add_argument("--quality-bound", type=float, default=0.02)
    args = ap.parse_args()
    run(n_mult=args.n_mult, quick=args.quick,
        quality_bound=args.quality_bound)
