"""Paper Fig. 2: power vs MAE Pareto front of 8-bit multipliers —
evolved circuits must trade off at least as well as the manual
truncation/BAM families at comparable power."""
from __future__ import annotations

from repro.core.library import get_default_library

from .common import emit, time_call


def run() -> None:
    lib = get_default_library()
    us = time_call(lambda: lib.pareto_front("multiplier", 8, "mae"),
                   iters=3)
    front = lib.pareto_front("multiplier", 8, "mae")
    for e in front:
        emit(f"fig_2/front/{e.name}", us,
             f"power={e.rel_power:.4f};mae={e.errors.mae:.3f};"
             f"src={e.source}")
    # dominance check: fraction of manual circuits strictly dominated by
    # some front circuit (the Fig. 2 "blue beats red" claim)
    manual = [e for e in lib.select("multiplier", 8)
              if e.source in ("truncation", "bam")]
    dominated = 0
    for m in manual:
        if any(f.rel_power <= m.rel_power and f.errors.mae < m.errors.mae
               for f in front):
            dominated += 1
    emit("fig_2/manual_dominated_fraction", us,
         f"{dominated}/{len(manual)}")


if __name__ == "__main__":
    run()
