"""Low-rank decomposability of the case-study multipliers (DESIGN.md
§4.2): for each selected multiplier, the rank needed for the emulation
error (decomposition MAE) to fall below 10% of the circuit's own MAE —
the knob that converts the VPU-gather emulation into MXU matmuls."""
from __future__ import annotations

import time

from repro.approx.ranking import kendall, spearman
from repro.core.library import get_default_library
from repro.core.luts import rank_profile

from .common import emit


def run() -> None:
    lib = get_default_library()
    sel = lib.case_study_selection(per_metric=10)
    circuit_mae, r1_mae = [], []
    for e in sel:
        t0 = time.time()
        lut = lib.lut(e.name)
        prof = rank_profile(lut, 8)
        us = (time.time() - t0) * 1e6
        tol = max(0.25, 0.1 * e.errors.mae)
        need = next((p["rank"] for p in prof if p["mae"] <= tol), ">8")
        emit(f"rank/{e.name}", us,
             f"circuit_mae={e.errors.mae:.3f};rank_needed={need};"
             f"mae_r1={prof[0]['mae']:.3f};mae_r4={prof[3]['mae']:.3f}")
        circuit_mae.append(e.errors.mae)
        r1_mae.append(prof[0]["mae"])
    # does the circuit's own error rank-predict how hard its LUT is to
    # decompose?  (same tie-aware helpers as the surrogate fidelity gate)
    emit("rank/error_vs_rank1_correlation", 0.0,
         f"spearman={spearman(circuit_mae, r1_mae):.4f};"
         f"kendall={kendall(circuit_mae, r1_mae):.4f};n={len(sel)}")


if __name__ == "__main__":
    run()
