"""Shared trained-model fixture for the resilience benchmarks: trains
ResNet-8 on synthetic CIFAR once and caches the checkpoint.

``make_eval_fn`` returns the shipped ``classification`` Workload
(DESIGN.md §2.7) — callable like the historical scalar eval, with the
traceable core the batched (``batch=True``) resilience engines need."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.approx.workload import Workload, classification
from repro.data.synthetic import CifarBatches
from repro.models import resnet
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import Trainer, TrainLoopConfig
from repro.train.optimizer import OptimizerConfig

from repro.data.synthetic import DATA_VERSION

CKPT_DIR = os.path.join(os.path.dirname(__file__), "results",
                        f"resnet8_ckpt_v{DATA_VERSION}")
TRAIN_STEPS = 320


def case_study_names(lib, n_mult: int) -> list[str]:
    """The paper's candidate set: Pareto selection capped at ``n_mult``,
    plus the truncation/BAM baselines Table II always reports."""
    sel = lib.case_study_selection(per_metric=10)
    names = [e.name for e in sel][:n_mult]
    for extra in ("mul8u_trunc7", "mul8u_trunc6", "mul8u_bam_h0_v4"):
        if extra in lib.entries and extra not in names:
            names.append(extra)
    return names


def trained_resnet(depth: int = 8):
    cfg = resnet.resnet_config(depth)
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(CKPT_DIR, keep=1)
    if mgr.latest_step() is not None and depth == 8:
        (params, _), _ = mgr.restore((params, params))
        return cfg, params
    train_data = CifarBatches("train", 4096, 64)

    def batches():
        while True:
            for b in train_data.epoch():
                yield {"images": jnp.asarray(b["images"]),
                       "labels": jnp.asarray(b["labels"])}

    trainer = Trainer(lambda p, b: resnet.loss_fn(p, b, cfg), params,
                      OptimizerConfig(lr=3e-3, warmup_steps=20,
                                      total_steps=TRAIN_STEPS,
                                      weight_decay=1e-4),
                      TrainLoopConfig(total_steps=TRAIN_STEPS,
                                      ckpt_every=10 ** 9,
                                      ckpt_dir="/tmp/repro_bench_tmp",
                                      log_every=10 ** 9))
    trainer.run(batches(), log=lambda s: None)
    params = trainer.params
    if depth == 8:
        mgr.save(TRAIN_STEPS, (params, params))
    return cfg, params


def make_eval_fn(cfg, params, eval_n: int = 256, batch: int = 64
                 ) -> Workload:
    """Accuracy evaluator over the synthetic test set — the shipped
    ``classification`` workload: call it like a function for the
    sequential path, or hand it to ``batch=True`` sweeps to evaluate a
    whole multiplier bank in one compiled program."""
    return classification(cfg, params, eval_n=eval_n, batch=batch)
