"""Paper Table II: multiplier characterization x classification accuracy
with the same approximate multiplier in every conv layer (trained
ResNet-8 on synthetic CIFAR; evolved + truncation + BAM entries)."""
from __future__ import annotations

import time

from repro.approx.resilience import all_layers_sweep
from repro.core.library import get_default_library
from repro.models import resnet

from .common import emit
from .resilience_common import make_eval_fn, trained_resnet


def run(n_mult: int = 8) -> None:
    lib = get_default_library()
    cfg, params = trained_resnet(8)
    eval_fn = make_eval_fn(cfg, params)

    from repro.approx.layers import ApproxPolicy
    from repro.approx.backend import MatmulBackend
    t0 = time.time()
    acc_f32 = eval_fn(ApproxPolicy(default=MatmulBackend(mode="f32")))
    acc_int8 = eval_fn(ApproxPolicy(default=MatmulBackend(mode="int8")))
    us = (time.time() - t0) / 2 * 1e6
    emit("table_II/float", us, f"acc={acc_f32:.4f};power=1.0")
    emit("table_II/8bit_exact_golden", us,
         f"acc={acc_int8:.4f};power=1.0")

    sel = lib.case_study_selection(per_metric=10)
    names = [e.name for e in sel][:n_mult]
    # always include the paper's baselines
    for extra in ("mul8u_trunc7", "mul8u_trunc6", "mul8u_bam_h0_v4"):
        if extra in lib.entries and extra not in names:
            names.append(extra)
    counts = resnet.layer_mult_counts(cfg)
    rows = all_layers_sweep(eval_fn, counts, names, lib, mode="lut")
    for r in sorted(rows, key=lambda r: -r.network_rel_power):
        emit(f"table_II/{r.multiplier}", us,
             f"acc={r.accuracy:.4f};power={r.network_rel_power:.4f};"
             f"mae={r.errors['mae']:.3f};wce={r.errors['wce']:.0f};"
             f"er={r.errors['er']:.4f}")


if __name__ == "__main__":
    run()
