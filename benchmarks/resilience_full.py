"""Paper Table II: multiplier characterization x classification accuracy
with the same approximate multiplier in every conv layer (trained
ResNet-8 on synthetic CIFAR; evolved + truncation + BAM entries).
Runs through the ``explore()`` DSE facade and reports the multiplier
``select_multiplier`` would deploy for a 1-point accuracy budget."""
from __future__ import annotations

import time

from repro.approx.dse import explore, select_multiplier
from repro.approx.layers import ApproxPolicy
from repro.approx.specs import BackendSpec
from repro.core.library import get_default_library
from repro.models import resnet

from .common import emit
from .resilience_common import make_eval_fn, trained_resnet


def run(n_mult: int = 8) -> None:
    lib = get_default_library()
    cfg, params = trained_resnet(8)
    eval_fn = make_eval_fn(cfg, params)

    t0 = time.time()
    acc_f32 = eval_fn(ApproxPolicy(default=BackendSpec.exact("f32")))
    us = (time.time() - t0) * 1e6
    emit("table_II/float", us, f"acc={acc_f32:.4f};power=1.0")

    sel = lib.case_study_selection(per_metric=10)
    names = [e.name for e in sel][:n_mult]
    # always include the paper's baselines
    for extra in ("mul8u_trunc7", "mul8u_trunc6", "mul8u_bam_h0_v4"):
        if extra in lib.entries and extra not in names:
            names.append(extra)
    counts = resnet.layer_mult_counts(cfg)
    result = explore(eval_fn, counts, lib, multipliers=names, mode="lut",
                     per_layer=False)
    emit("table_II/8bit_exact_golden", us,
         f"acc={result.baseline_accuracy:.4f};power=1.0")
    for r in sorted(result.all_layers, key=lambda r: -r.network_rel_power):
        emit(f"table_II/{r.multiplier}", us,
             f"acc={r.accuracy:.4f};power={r.network_rel_power:.4f};"
             f"mae={r.errors['mae']:.3f};wce={r.errors['wce']:.0f};"
             f"er={r.errors['er']:.4f}")
    pick = select_multiplier(result, max_accuracy_drop=0.01)
    if pick is not None:
        emit(f"table_II/selected/{pick.multiplier}", us,
             f"acc={pick.accuracy:.4f};power={pick.network_rel_power:.4f}")


if __name__ == "__main__":
    run()
