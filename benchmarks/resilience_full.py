"""Paper Table II: multiplier characterization x classification accuracy
with the same approximate multiplier in every conv layer (trained
ResNet-8 on synthetic CIFAR; evolved + truncation + BAM entries).

Runs the all-layers sweep BOTH ways — sequentially (one jit trace per
multiplier, the pre-batching engine) and batched (one ``LutBank``
program, DESIGN.md §2.4) — writes the wall-clock comparison to
``benchmarks/results/BENCH_resilience.json`` (the committed copy is a
point-in-time snapshot; CI regenerates and uploads it as an artifact
each run), then FAILS if the accuracies disagree, so a broken
bit-identical contract can never pass CI silently.  Table II rows and
the multiplier ``select_multiplier`` would deploy for a 1-point
accuracy budget are emitted from the batched result.

``--quick`` (CI mode) skips the 320-step training run and shrinks the
eval set; the sequential-vs-batched comparison is unaffected because
both paths share the model and eval set.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.approx.dse import DesignPoint, ExploreResult, select_multiplier
from repro.approx.layers import ApproxPolicy
from repro.approx.resilience import all_layers_sweep
from repro.approx.specs import BackendSpec
from repro.core.library import get_default_library
from repro.models import resnet

from .common import emit
from .resilience_common import case_study_names, make_eval_fn, trained_resnet

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_resilience.json")


def run(n_mult: int = 8, quick: bool = False) -> dict:
    lib = get_default_library()
    if quick:
        cfg = resnet.resnet_config(8)
        params = resnet.init_params(jax.random.PRNGKey(0), cfg)
        eval_fn = make_eval_fn(cfg, params, eval_n=32, batch=32)
    else:
        cfg, params = trained_resnet(8)
        eval_fn = make_eval_fn(cfg, params)

    t0 = time.time()
    acc_f32 = eval_fn(ApproxPolicy(default=BackendSpec.exact("f32")))
    us = (time.time() - t0) * 1e6
    emit("table_II/float", us, f"acc={acc_f32:.4f};power=1.0")

    names = case_study_names(lib, n_mult)
    counts = resnet.layer_mult_counts(cfg)
    for n in names:                     # warm LUTs so neither path pays
        lib.lut(n)

    # -- sequential vs batched all-layers sweep ------------------------
    t0 = time.perf_counter()
    rows_seq = all_layers_sweep(eval_fn, counts, names, lib, mode="lut")
    seq_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows_bat = all_layers_sweep(eval_fn, counts, names, lib, mode="lut",
                                batch=True)
    bat_s = time.perf_counter() - t0
    identical = [r.accuracy for r in rows_seq] == \
                [r.accuracy for r in rows_bat]
    speedup = seq_s / bat_s if bat_s > 0 else float("inf")
    emit("resilience/all_layers_sequential", seq_s * 1e6,
         f"n_mult={len(names)}")
    emit("resilience/all_layers_batched", bat_s * 1e6,
         f"n_mult={len(names)};speedup={speedup:.2f};"
         f"bit_identical={identical}")

    record = {
        "benchmark": "resilience_all_layers_sweep",
        "n_mult": len(names),
        "multipliers": names,
        "quick": quick,
        "eval_n": 32 if quick else 256,
        "sequential_s": round(seq_s, 4),
        "batched_s": round(bat_s, 4),
        "speedup": round(speedup, 2),
        "bit_identical": identical,
        "backend": jax.default_backend(),
        "rows": [{"multiplier": r.multiplier,
                  "accuracy": round(r.accuracy, 6),
                  "network_rel_power": round(r.network_rel_power, 6)}
                 for r in rows_bat],
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    emit("resilience/bench_record", 0.0, BENCH_PATH)
    if not identical:                  # record written first for triage
        raise SystemExit(
            "batched sweep diverged from the sequential path — the "
            f"bit-identical contract is broken (see {BENCH_PATH})")

    # -- Table II from the batched rows (no third sweep) ---------------
    baseline = eval_fn(ApproxPolicy(default=BackendSpec.golden()))
    result = ExploreResult(
        baseline_accuracy=baseline,
        all_layers=[DesignPoint.from_row(r) for r in rows_bat])
    emit("table_II/8bit_exact_golden", us,
         f"acc={result.baseline_accuracy:.4f};power=1.0")
    for r in sorted(result.all_layers, key=lambda r: -r.network_rel_power):
        emit(f"table_II/{r.multiplier}", us,
             f"acc={r.accuracy:.4f};power={r.network_rel_power:.4f};"
             f"mae={r.errors['mae']:.3f};wce={r.errors['wce']:.0f};"
             f"er={r.errors['er']:.4f}")
    pick = select_multiplier(result, max_accuracy_drop=0.01)
    if pick is not None:
        emit(f"table_II/selected/{pick.multiplier}", us,
             f"acc={pick.accuracy:.4f};power={pick.network_rel_power:.4f}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-mult", type=int, default=None,
                    help="candidate count (default: 8, or 16 with "
                         "--quick where the sweep is cheap)")
    ap.add_argument("--quick", action="store_true",
                    help="untrained model + small eval set (CI)")
    args = ap.parse_args()
    n_mult = (args.n_mult if args.n_mult is not None
              else (16 if args.quick else 8))
    run(n_mult=n_mult, quick=args.quick)
