"""Paper Fig. 4: accuracy drop vs power drop when approximate
multipliers are inserted into ONE layer of ResNet-8 at a time; layers
with a larger multiplier share should show proportionally larger
impact.  Runs through the ``explore()`` DSE facade on the batched
resilience engine (``batch=True``): each layer evaluates the whole
multiplier bank in one compiled program — O(n_layers) programs instead
of O(n_layers * n_mult) traces (DESIGN.md §2.4)."""
from __future__ import annotations

import time

from repro.approx.dse import explore
from repro.core.library import get_default_library
from repro.models import resnet

from .common import emit
from .resilience_common import make_eval_fn, trained_resnet


def run(n_mult: int = 3) -> None:
    lib = get_default_library()
    cfg, params = trained_resnet(8)
    eval_fn = make_eval_fn(cfg, params)
    sel = lib.case_study_selection(per_metric=10)
    # spread: near-exact, mid, aggressive
    names = [sel[1].name, sel[len(sel) // 2].name, sel[-1].name][:n_mult]
    counts = resnet.layer_mult_counts(cfg)
    t0 = time.time()
    result = explore(eval_fn, counts, lib, multipliers=names, mode="lut",
                     all_layers=False, batch=True)
    rows = result.per_layer
    us = (time.time() - t0) / max(len(rows), 1) * 1e6
    for r in rows:
        emit(f"fig_4/{r.layer}/{r.multiplier}", us,
             f"acc={r.accuracy:.4f};share={r.mult_share:.4f};"
             f"net_power={r.network_rel_power:.4f}")


if __name__ == "__main__":
    run()
