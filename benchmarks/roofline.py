"""Roofline summary (deliverable g): reads the dry-run JSONs and prints
the per-cell three-term roofline table.  The dry-run itself
(repro.launch.dryrun) must have been run first — it needs the
512-device placeholder env and therefore lives in its own process.

When no dry-run results exist, the suite falls back to the kernel
lane's cost-analysis terms (``BENCH_kernels.json``, written by
``benchmarks.kernel_bench``): per-shape flops / bytes-accessed /
operational intensity for the fused and two-step datapaths, so
``--only roofline`` produces a real table on any machine instead of a
NO_RESULTS stub."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")
KERNELS_JSON = os.path.join(os.path.dirname(__file__), "results",
                            "BENCH_kernels.json")


def load_results() -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def _run_kernel_fallback() -> bool:
    """Kernel-lane roofline terms when the 512-device dry-run is absent."""
    if not os.path.exists(KERNELS_JSON):
        return False
    with open(KERNELS_JSON) as f:
        record = json.load(f)
    for e in record.get("entries", []):
        for variant in ("two_step", "fused"):
            rf = e.get("roofline", {}).get(variant)
            if not rf:
                continue
            us = e.get(f"{variant}_us", 0.0)
            emit(f"roofline/kernel/{e['contract']}/{e['shape']}/{variant}",
                 us,
                 f"flops={rf['flops']:.3e};bytes={rf['bytes']:.3e};"
                 f"oi={rf['oi']:.4f};"
                 f"achieved_gflops={rf['flops'] / max(us, 1e-9) * 1e-3:.2f}")
    return True


def run() -> None:
    results = load_results()
    if not results:
        if _run_kernel_fallback():
            return
        emit("roofline/NO_RESULTS", 0.0,
             "run benchmarks/run_dryrun_sweep.sh or "
             "benchmarks.kernel_bench first")
        return
    for r in results:
        tag = f"{r['arch']}/{r['shape']}/{'mp' if r['multi_pod'] else 'sp'}"
        if not r.get("ok"):
            emit(f"roofline/{tag}", 0.0, f"FAIL:{r.get('error', '?')[:60]}")
            continue
        if r.get("multi_pod") or not r.get("probe_details"):
            # multi-pod cells are compile-only (no unrolled probes):
            # report the deliverable facts, not roofline terms
            emit(f"roofline/{tag}", r["compile_s"] * 1e6,
                 f"compile_only;mem_gb={r['memory']['peak_gb']:.1f}")
            continue
        rf = r["roofline"]
        emit(f"roofline/{tag}", r["compile_s"] * 1e6,
             f"bottleneck={rf['bottleneck']};"
             f"compute={rf['compute_s']:.4f}s;"
             f"memory={rf['memory_s']:.4f}s;"
             f"collective={rf['collective_s']:.4f}s;"
             f"frac={rf['roofline_fraction']:.4f};"
             f"useful={rf['useful_flops_ratio']:.3f};"
             f"mem_gb={r['memory']['peak_gb']:.1f}")


if __name__ == "__main__":
    run()
