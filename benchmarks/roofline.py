"""Roofline summary (deliverable g): reads the dry-run JSONs and prints
the per-cell three-term roofline table.  The dry-run itself
(repro.launch.dryrun) must have been run first — it needs the
512-device placeholder env and therefore lives in its own process."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_results() -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run() -> None:
    results = load_results()
    if not results:
        emit("roofline/NO_RESULTS", 0.0,
             "run benchmarks/run_dryrun_sweep.sh first")
        return
    for r in results:
        tag = f"{r['arch']}/{r['shape']}/{'mp' if r['multi_pod'] else 'sp'}"
        if not r.get("ok"):
            emit(f"roofline/{tag}", 0.0, f"FAIL:{r.get('error', '?')[:60]}")
            continue
        if r.get("multi_pod") or not r.get("probe_details"):
            # multi-pod cells are compile-only (no unrolled probes):
            # report the deliverable facts, not roofline terms
            emit(f"roofline/{tag}", r["compile_s"] * 1e6,
                 f"compile_only;mem_gb={r['memory']['peak_gb']:.1f}")
            continue
        rf = r["roofline"]
        emit(f"roofline/{tag}", r["compile_s"] * 1e6,
             f"bottleneck={rf['bottleneck']};"
             f"compute={rf['compute_s']:.4f}s;"
             f"memory={rf['memory_s']:.4f}s;"
             f"collective={rf['collective_s']:.4f}s;"
             f"frac={rf['roofline_fraction']:.4f};"
             f"useful={rf['useful_flops_ratio']:.3f};"
             f"mem_gb={r['memory']['peak_gb']:.1f}")


if __name__ == "__main__":
    run()
