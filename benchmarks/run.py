"""Benchmark harness: one module per paper table/figure plus the
framework's kernel/rank/roofline analyses.  Prints
``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only table_I,fig_2,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

SUITES = {
    "table_I": ("benchmarks.library_stats", "Table I: library counts"),
    "fig_2": ("benchmarks.pareto_front", "Fig 2: 8-bit mult Pareto front"),
    "fig_4": ("benchmarks.resilience_per_layer",
              "Fig 4: per-layer resilience"),
    "table_II": ("benchmarks.resilience_full",
                 "Table II: multiplier x accuracy"),
    "heterogeneous_pareto": ("benchmarks.heterogeneous_pareto",
                             "heterogeneous vs uniform Pareto "
                             "(BENCH_heterogeneous.json)"),
    "wide_width_pareto": ("benchmarks.wide_width_pareto",
                          "composed 12/16-bit mixed-width Pareto "
                          "(BENCH_wide.json)"),
    "objectives_pareto": ("benchmarks.objectives_pareto",
                          "multi-metric objective fronts "
                          "(BENCH_objectives.json)"),
    "kernels": ("benchmarks.kernel_bench", "kernel micro-benchmarks"),
    "rank": ("benchmarks.rank_analysis", "LUT low-rank analysis"),
    "roofline": ("benchmarks.roofline", "dry-run roofline table"),
    "serve": ("benchmarks.serve_load",
              "continuous-batching serve load (BENCH_serve.json)"),
    "evolve": ("benchmarks.evolve_library",
               "device-resident CGP library generation "
               "(BENCH_evolve.json)"),
    "dse": ("benchmarks.dse_surrogate",
            "surrogate-guided vs exact-sweep DSE (BENCH_dse.json)"),
    "profiles": ("benchmarks.arch_profiles",
                 "model-zoo module-resilience profiles "
                 "(BENCH_profiles.json)"),
}

# module-name aliases: every suite is addressable by its module's
# basename too (--only kernel_bench == --only kernels); aliases resolve
# to the canonical key so a default run never executes a suite twice.
ALIASES = {mod.split(".")[-1]: key for key, (mod, _) in SUITES.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (canonical keys "
                         f"{list(SUITES)} or module-name aliases "
                         f"{sorted(set(ALIASES) - set(SUITES))})")
    ap.add_argument("--compile-cache", action="store_true",
                    help="enable the persistent JAX compilation cache "
                         "(repro.launch.compile_cache) so repeated "
                         "invocations skip XLA recompiles")
    args = ap.parse_args()
    if args.compile_cache:
        from repro.launch.compile_cache import enable_compile_cache
        print(f"# compile cache: {enable_compile_cache()}",
              file=sys.stderr)
    todo = (args.only.split(",") if args.only else list(SUITES))
    todo = list(dict.fromkeys(ALIASES.get(k, k) for k in todo))

    print("name,us_per_call,derived")
    failed = []
    for key in todo:
        mod_name, desc = SUITES[key]
        print(f"# {key}: {desc}", file=sys.stderr, flush=True)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:
            failed.append(key)
            traceback.print_exc()
            print(f"{key}/SUITE_FAILED,0,", flush=True)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
