"""Continuous-batching serve load generator (DESIGN.md §2.8).

Drives the multi-tenant ``ContinuousEngine`` with Poisson request
arrivals whose ``ServeConfig`` policies are drawn from a mixed
distribution of approximate-multiplier selections (uniform per-tenant
picks plus, at higher concurrency, a heterogeneous per-layer policy —
the autoAx deployment story: every application ships its own selected
accelerator).  Writes ``benchmarks/results/BENCH_serve.json``:

  * per concurrency level (1/2/4[/8] distinct in-flight policies):
    tokens/s, p50/p99 request latency, decode-step count, and the
    engine's cumulative trace counts;
  * the **O(1)-programs gate**: total decode traces across the whole
    sweep must not grow with the number of distinct policies (the bank
    is fixed up front, so exactly ONE decode program serves every
    level);
  * the **bit-identity gate**: every request's continuous-batched
    token stream must equal per-request sequential ``Engine.generate``
    under the equivalent materialized policy, token for token.

The run exits non-zero when either gate fails (the CI ``bench-serve``
job's failure condition).  ``--quick`` shrinks request counts and
levels; gates are identical.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.approx.layers import ApproxPolicy
from repro.approx.specs import BackendSpec
from repro.configs import get_config
from repro.core.library import get_default_library
from repro.models.registry import input_extras, model_fns
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig

from .common import emit

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_serve.json")

MULTIPLIERS = ["mul8u_exact", "mul8u_trunc7", "mul8u_trunc6",
               "mul8u_trunc5", "mul8u_bam_h0_v4", "mul8u_bam_h1_v4",
               "mul8u_trunc4", "mul8u_bam_h0_v2"]
PROMPT_LENS = (4, 6, 8)                 # fixed set -> bounded prefill traces


def _uniform_policy(mult: str) -> str:
    return ApproxPolicy(default=BackendSpec(
        mode="lut", multiplier=mult, ste=False)).to_json()


def _hetero_policy(attn_mult: str, rest_mult: str) -> str:
    """Different multiplier on attention vs everything else — one
    request carrying a per-layer (explore_heterogeneous-style)
    selection."""
    return ApproxPolicy(
        default=BackendSpec(mode="lut", multiplier=rest_mult, ste=False),
        overrides=[("*attn*", BackendSpec(mode="lut",
                                          multiplier=attn_mult,
                                          ste=False))]).to_json()


def _policy_set(n: int) -> list:
    """n distinct policies: None (engine default) + uniform picks, the
    last replaced by a heterogeneous per-layer policy when n >= 4."""
    policies: list = [None]
    policies += [_uniform_policy(m) for m in MULTIPLIERS[1:n]]
    if n >= 4:
        policies[-1] = _hetero_policy(MULTIPLIERS[1], MULTIPLIERS[2])
    return policies[:n]


def _drive(engine, requests, mean_interarrival_steps: float, seed: int
           ) -> dict:
    """Submit ``requests`` (prompt, ServeConfig) on a Poisson arrival
    process measured in decode-step units and run the engine dry."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_interarrival_steps, len(requests))
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    start_step = engine.step_count
    rids, i = [], 0
    t0 = time.perf_counter()
    while i < len(requests) or not engine.scheduler.idle:
        while i < len(requests) and \
                engine.step_count - start_step >= arrivals[i]:
            prompt, serve = requests[i]
            rids.append(engine.submit(prompt, serve))
            i += 1
        engine.step()
        if engine.step_count - start_step > 100_000:
            raise RuntimeError("load did not drain")
    wall = time.perf_counter() - t0
    finished = engine.scheduler.finished
    lat_ms = [(finished[r].finished_at - finished[r].submitted_at) * 1e3
              for r in rids]
    n_tokens = sum(len(finished[r].tokens) for r in rids)
    return {"rids": rids, "wall_s": wall, "n_tokens": n_tokens,
            "steps": engine.step_count - start_step,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99))}


def _make_requests(n_requests: int, policies: list, vocab: int,
                   seed: int) -> list:
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        prompt = rng.integers(
            0, vocab, (int(rng.choice(PROMPT_LENS)),)).astype(np.int32)
        temp = 0.0 if i % 2 == 0 else 0.8
        serve = ServeConfig(
            max_new_tokens=int(rng.integers(3, 8)), temperature=temp,
            seed=int(rng.integers(0, 1 << 16)),
            policy=policies[i % len(policies)])
        reqs.append((prompt, serve))
    return reqs


def run(quick: bool = False, arch: str = "qwen1.5-0.5b") -> dict:
    lib = get_default_library()
    cfg = get_config(arch).reduced()
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(0), cfg)

    levels = [1, 2, 4] if quick else [1, 2, 4, 8]
    n_requests = 8 if quick else 24
    # ONE engine, bank fixed to the multiplier superset: every level
    # (and every distinct-policy count) must reuse the same compiled
    # decode program — the O(1) gate measures exactly this.
    engine = ContinuousEngine(cfg, params, library=lib,
                              multipliers=MULTIPLIERS, n_slots=4,
                              capacity=max(PROMPT_LENS) + 8,
                              block_size=4)

    # warmup: compile the decode step and one prefill per prompt length
    for plen in PROMPT_LENS:
        engine.submit(np.zeros(plen, np.int32),
                      ServeConfig(max_new_tokens=2))
    engine.run()
    warm_traces = dict(engine.trace_counts)

    results, all_reqs = [], []
    for n_pol in levels:
        reqs = _make_requests(n_requests, _policy_set(n_pol), cfg.vocab,
                              seed=100 + n_pol)
        stats = _drive(engine, reqs, mean_interarrival_steps=2.0,
                       seed=200 + n_pol)
        all_reqs.extend(zip(stats.pop("rids"), reqs))
        level = {"n_policies": n_pol, "n_requests": n_requests,
                 "tokens_per_s": round(stats["n_tokens"]
                                       / stats["wall_s"], 1),
                 "p50_ms": round(stats["p50_ms"], 2),
                 "p99_ms": round(stats["p99_ms"], 2),
                 "decode_steps": stats["steps"],
                 "trace_counts": dict(engine.trace_counts)}
        results.append(level)
        emit(f"serve/policies_{n_pol}",
             stats["wall_s"] / max(stats["steps"], 1) * 1e6,
             f"tok_s={level['tokens_per_s']} p50_ms={level['p50_ms']} "
             f"p99_ms={level['p99_ms']}")

    # O(1)-programs gate: decode trace count did not grow after warmup
    trace_gate = (engine.trace_counts["decode"]
                  == warm_traces["decode"] == 1)

    # bit-identity gate: replay every request sequentially under the
    # equivalent materialized policy
    ref_engines: dict = {}
    finished = engine.scheduler.finished
    bit_identity = True
    mismatches = []
    extras = input_extras(cfg, 1) or None
    for rid, (prompt, serve) in all_reqs:
        key = serve.policy if isinstance(serve.policy, str) \
            else json.dumps(serve.policy, sort_keys=True) \
            if serve.policy else None
        if key not in ref_engines:
            ref_engines[key] = Engine(cfg, params,
                                      engine.lane_policy(serve),
                                      library=lib)
        ref = ref_engines[key].generate(prompt[None], serve,
                                        extras=extras)[0]
        got = np.asarray(finished[rid].tokens, np.int32)
        if not np.array_equal(ref, got):
            bit_identity = False
            mismatches.append({"rid": rid, "got": got.tolist(),
                               "ref": ref.tolist()})

    record = {
        "arch": arch, "quick": quick, "n_slots": 4,
        "multiplier_bank": MULTIPLIERS,
        "levels": results,
        "warmup_traces": warm_traces,
        "final_traces": dict(engine.trace_counts),
        "trace_gate_o1_programs": trace_gate,
        "bit_identity": bit_identity,
        "bit_identity_requests": len(all_reqs),
        "mismatches": mismatches[:5],
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    emit("serve/bit_identity", 0.0, str(bit_identity))
    emit("serve/trace_gate", 0.0, str(trace_gate))

    # record is written first so CI failures still upload the artifact
    if not bit_identity:
        raise SystemExit(
            "continuous-batched mixed-policy decode diverged from "
            f"sequential generate on {len(mismatches)} request(s) "
            f"(see {BENCH_PATH})")
    if not trace_gate:
        raise SystemExit(
            "decode trace count grew with concurrent-policy count — "
            f"the O(1)-compiled-programs contract is broken "
            f"(see {BENCH_PATH})")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small request counts / levels (CI); gates "
                         "are identical")
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()
    run(quick=args.quick, arch=args.arch)
