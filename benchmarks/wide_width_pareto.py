"""Wide-width (composed 12/16-bit) Pareto benchmark (DESIGN.md §2.6).

The paper's extended library spans 8..128-bit circuits, but only the
8-bit rows were executable until the composed datapath: W-bit products
decompose into tiled 8x8 LUT partial products reduced by library adder
trees, so 12/16-bit multipliers evaluate end to end through the SAME
banked engine as the 8-bit sweeps.  This benchmark runs the trained
ResNet-8 / synthetic CIFAR-10 case study over a MIXED-width candidate
set and writes ``benchmarks/results/BENCH_wide.json`` recording:

  * the all-layers sweep over 8-bit + composed 12/16-bit candidates in
    ONE banked program, with per-point accuracy and power rebased onto
    the common ``mul8u_exact`` reference
    (``power.rel_power_map(..., ref=...)`` — a 16-bit composed
    multiplier really costs ~4 tiles + reduction tree),
  * the composed-16-bit-vs-sequential evaluation speedup: the WIDE
    candidates evaluated in one banked program vs one compiled program
    per candidate — the "batched-vs-sequential" record CI tracks,
  * the bit-identity gate: batched mixed-width accuracies must equal
    sequential per-spec evaluation exactly (the run FAILS otherwise),
  * the Pareto front over widths at a fixed quality bound — on
    accuracy (the Table II convention) AND on *fidelity* (mean |logit
    error| vs the f32 model, one more banked program): classification
    accuracy saturates on the synthetic eval set, while fidelity
    resolves the quantization-noise axis where 12/16-bit datapaths
    beat every 8-bit circuit — a wide point must win the fidelity
    front within the bound or the run FAILS.  (Fidelity at 16 bits
    includes the emulator's deterministic f32 recombination floor at
    large K — DESIGN.md §2.6 — which is still orders of magnitude
    below every 8-bit circuit's error, so the gate is decided by the
    datapath, not the floor.)

``--quick`` (CI mode) shrinks the eval set; all checks are
deterministic (seeded synthetic data + committed checkpoint).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.approx.dse import DesignPoint, ExploreResult, pareto_points
from repro.approx.layers import ApproxPolicy
from repro.approx.power import rel_power_map
from repro.approx.resilience import all_layers_sweep
from repro.approx.specs import BackendSpec
from repro.core.library import get_default_library
from repro.models import resnet

from .common import emit
from .resilience_common import case_study_names, make_eval_fn, trained_resnet

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_wide.json")

# composed wide candidates: (tile, width, reduce) — exact tiles probe
# the pure quantization axis, truncated tiles + LOA reduction the
# approximate one (the paper's wide array-multiplier construction)
WIDE_RECIPES = (
    ("mul8u_exact", 16, "loa4"),
    ("mul8u_exact", 12, "loa4"),
    ("mul8u_trunc6", 16, "loa4"),
    ("mul8u_trunc5", 12, "loa4"),
    ("mul8u_trunc4", 16, "loa4"),
)


def _point_dict(p: DesignPoint, width: int) -> dict:
    return {"multiplier": p.multiplier, "bit_width": width,
            "accuracy": round(p.accuracy, 6),
            "network_rel_power": round(p.network_rel_power, 6)}


def _fidelity_workload(cfg, params, eval_n: int, batch: int):
    """Mean |logit error| vs the f32 model (lower = better fidelity):
    the continuous axis where quantization width shows — accuracy
    saturates on the synthetic eval set long before 16-bit precision
    is exhausted.  Built on the shipped ``logit_fidelity`` workload
    (DESIGN.md §2.7), which this benchmark's inline helper graduated
    into — same computation, same values."""
    import jax.numpy as jnp

    from repro.approx.workload import logit_fidelity
    from repro.data.synthetic import CifarBatches

    data = CifarBatches("test", eval_n, batch)
    images = jnp.asarray(np.stack(
        [b["images"] for b in data.eval_batches()]))

    def forward(policy, img):
        return resnet.forward(params, img, cfg, policy)

    return logit_fidelity(
        forward, [images[i] for i in range(images.shape[0])],
        name="resnet_fidelity")


def run(n_mult: int = 6, quick: bool = False,
        quality_bound: float = 0.02) -> dict:
    lib = get_default_library()
    cfg, params = trained_resnet(8)
    eval_n, batch = (64, 64) if quick else (256, 64)
    eval_fn = make_eval_fn(cfg, params, eval_n=eval_n, batch=batch)
    counts = resnet.layer_mult_counts(cfg)

    narrow = case_study_names(lib, n_mult)
    wide = []
    for tile, width, reduce in WIDE_RECIPES:
        if tile in lib.entries:
            wide.append(lib.add_composed(tile, width, reduce).name)
    names = narrow + wide
    widths = {n: lib.entry(n).width for n in names}
    for n in names:                    # warm tile LUTs out of the timing
        lib.tile_lut(n)
    rp = rel_power_map(lib, names, ref="mul8u_exact")

    baseline = eval_fn(ApproxPolicy(default=BackendSpec.golden()))

    # -- composed-wide vs sequential speedup (the record's headline):
    #    the WIDE candidates in one banked program vs one compiled
    #    program per candidate — both pay the composed 4x-gather cost,
    #    so the delta is pure batching --------------------------------
    t0 = time.perf_counter()
    wide_rows_bat = all_layers_sweep(eval_fn, counts, wide, lib,
                                     mode="lut", batch=True,
                                     rel_power=rp)
    bat_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    wide_rows_seq = all_layers_sweep(eval_fn, counts, wide, lib,
                                     mode="lut", rel_power=rp)
    seq_s = time.perf_counter() - t0
    wide_identical = [r.accuracy for r in wide_rows_bat] == \
                     [r.accuracy for r in wide_rows_seq]
    speedup = seq_s / bat_s if bat_s > 0 else float("inf")
    emit("wide/sweep_batched", bat_s * 1e6,
         f"n_wide={len(wide)};speedup={speedup:.2f};"
         f"bit_identical={wide_identical}")

    # -- mixed-width sweep: ONE banked program + bit-identity gate
    #    (the sequential side reuses the timed wide rows above —
    #    composed sequential evaluations are the expensive half) ------
    rows_bat = all_layers_sweep(eval_fn, counts, names, lib, mode="lut",
                                batch=True, rel_power=rp)
    rows_seq = all_layers_sweep(eval_fn, counts, narrow, lib,
                                mode="lut", rel_power=rp) + wide_rows_seq
    bit_identical = [r.accuracy for r in rows_bat] == \
                    [r.accuracy for r in rows_seq]
    emit("wide/mixed_sweep", 0.0,
         f"n={len(names)};bit_identical={bit_identical}")

    # -- fidelity axis (one more banked program) ----------------------
    fid_wl = _fidelity_workload(cfg, params, eval_n, batch)
    fid_rows = all_layers_sweep(fid_wl, counts, names, lib,
                                mode="lut", batch=True, rel_power=rp)
    fidelity = {r.multiplier: r.metrics["logit_mae"] for r in fid_rows}

    result = ExploreResult(
        baseline_accuracy=baseline,
        all_layers=[DesignPoint.from_row(r) for r in rows_bat])
    floor = baseline - quality_bound
    within = [p for p in result.all_layers if p.accuracy >= floor]
    front = pareto_points(within)
    # fidelity front within the accuracy bound: reuse the Pareto sweep
    # with fidelity (negated: pareto_points maximizes accuracy)
    fid_points = [DesignPoint(
        multiplier=p.multiplier, layer="all",
        accuracy=-fidelity[p.multiplier],
        network_rel_power=p.network_rel_power,
        multiplier_rel_power=p.multiplier_rel_power,
        mult_share=1.0) for p in within]
    fid_front = pareto_points(fid_points)
    best8_fid = min((fidelity[p.multiplier] for p in within
                     if widths[p.multiplier] == 8),
                    default=float("inf"))
    wide_beyond_8bit = [
        p.multiplier for p in within
        if widths[p.multiplier] > 8 and fidelity[p.multiplier] < best8_fid]
    emit("wide/pareto", 0.0,
         f"acc_front={len(front)};fid_front={len(fid_front)};"
         f"wide_beyond_8bit={len(wide_beyond_8bit)}")

    def _sweep_dict(p):
        d = _point_dict(p, widths[p.multiplier])
        d["logit_mae_vs_f32"] = round(fidelity[p.multiplier], 6)
        return d

    record = {
        "benchmark": "wide_width_pareto",
        "quick": quick,
        "backend": jax.default_backend(),
        "quality_bound": quality_bound,
        "baseline_accuracy": round(baseline, 6),
        "candidates": [
            {"multiplier": n, "bit_width": widths[n],
             "rel_power_vs_mul8u_exact": round(rp[n], 4)}
            for n in names],
        "sweep": [_sweep_dict(p)
                  for p in sorted(result.all_layers,
                                  key=lambda p: p.network_rel_power)],
        "pareto_front_accuracy": [_point_dict(p, widths[p.multiplier])
                                  for p in front],
        "pareto_front_fidelity": [_sweep_dict(
            next(q for q in within if q.multiplier == p.multiplier))
            for p in fid_front],
        "wide_beyond_8bit_fidelity": wide_beyond_8bit,
        "evaluation": {
            "n_candidates": len(names),
            "n_wide": len(wide),
            "mixed_bit_identical": bit_identical,
            "wide_sequential_s": round(seq_s, 4),
            "wide_batched_s": round(bat_s, 4),
            "speedup": round(speedup, 2),
            "bit_identical": wide_identical,
        },
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    emit("wide/bench_record", 0.0, BENCH_PATH)

    # record is written first so CI failures still upload the artifact
    if not (bit_identical and wide_identical):
        raise SystemExit(
            "mixed-width banked sweep diverged from sequential "
            f"per-spec evaluation (see {BENCH_PATH})")
    if wide and not wide_beyond_8bit:
        raise SystemExit(
            "no composed wide point beat every 8-bit candidate's "
            f"fidelity within the quality bound (see {BENCH_PATH})")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-mult", type=int, default=6,
                    help="8-bit candidate count (wide recipes ride on "
                         "top)")
    ap.add_argument("--quick", action="store_true",
                    help="small eval set (CI); restores the committed "
                         "trained checkpoint either way")
    ap.add_argument("--quality-bound", type=float, default=0.02)
    args = ap.parse_args()
    run(n_mult=args.n_mult, quick=args.quick,
        quality_bound=args.quality_bound)
