"""CGP evolution demo (paper Sec. II/III): evolve approximate 8-bit
multipliers from the exact array multiplier across an error ladder and
print the resulting power/error trade-off curve.

    PYTHONPATH=src python examples/evolve_multiplier.py [--generations 400]
"""
import argparse
import time

import numpy as np

from repro.core import seeds
from repro.core.cgp import CgpParams, evolve, pad_nodes
from repro.core.cost import evaluate_cost


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--generations", type=int, default=400)
    ap.add_argument("--ladder", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    exact = seeds.array_multiplier(8)
    ref_cost = evaluate_cost(exact)
    print(f"seed: exact 8-bit array multiplier "
          f"({ref_cost.n_gates} gates, power {ref_cost.power:.1f})")
    print(f"{'e_max(MAE)':>12}{'MAE':>10}{'WCE':>8}{'ER%':>7}"
          f"{'power%':>8}{'gates':>7}{'time':>7}")

    max_out = float((2 ** 8 - 1) ** 2)
    parent = exact
    for i, exp in enumerate(np.linspace(13, 6, args.ladder)):
        e_max = max_out * (2.0 ** -exp)
        t0 = time.time()
        padded = pad_nodes(parent, exact.n_nodes, seed=args.seed + i)
        res = evolve(padded, exact,
                     CgpParams(metric="mae", e_max=e_max,
                               generations=args.generations,
                               seed=args.seed + i))
        parent = res.netlist
        dt = time.time() - t0
        c = evaluate_cost(res.netlist)
        print(f"{e_max:>12.2f}{res.errors.mae:>10.2f}"
              f"{res.errors.wce:>8.0f}{100 * res.errors.er:>7.1f}"
              f"{100 * res.cost_power / ref_cost.power:>8.1f}"
              f"{c.n_gates:>7}{dt:>6.1f}s")
    print("\nLower power at higher permitted error — the library's "
          "Pareto front is the union of many such runs (Fig. 2).")


if __name__ == "__main__":
    main()
