"""Quickstart: the library -> Pareto selection -> approximate matmul.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's flow end to end on one page with the spec-first API:
  1. load (or build) the approximate-circuit library,
  2. select case-study multipliers per the paper's Pareto rule,
  3. name datapaths as serializable ``BackendSpec``s, materialize them
     against the library (cached), and run a matmul through the
     emulated approximate datapath vs the exact int8 accelerator,
  4. show the TPU-native low-rank emulation agreeing with the bit-true
     LUT emulation, and ship the chosen config as policy JSON,
  5. run the objective-first DSE (DESIGN.md §2.7): a named-metric
     Workload explored over pluggable axes (quality x power x delay)
     with a declarative constraint-based selection.
"""
import numpy as np
import jax.numpy as jnp

from repro.core.library import get_default_library
from repro.approx import ApproxPolicy, BackendSpec, backend_matmul

lib = get_default_library()
print(f"library: {len(lib.entries)} circuits")
for row in lib.counts_table():
    print(f"  {row['circuit']:<11} {row['bit_width']:>4}b : "
          f"{row['n_implementations']}")

# --- the paper's selection rule (Sec. III): Pareto per metric, spread
# over power, union + dedup ------------------------------------------------
sel = lib.case_study_selection(per_metric=10)
print(f"\ncase-study multipliers ({len(sel)}):")
print(f"{'name':<18}{'power%':>8}{'MAE':>10}{'WCE':>8}{'ER%':>8}")
for e in sel[:12]:
    print(f"{e.name:<18}{100 * e.rel_power:>8.1f}{e.errors.mae:>10.2f}"
          f"{e.errors.wce:>8.0f}{100 * e.errors.er:>8.1f}")

# --- run a layer on the emulated accelerator --------------------------------
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
y_exact = backend_matmul(x, w, BackendSpec.golden())

# specs are names: frozen, hashable, JSON round-trippable — the arrays
# only exist in the cached materialization, never in the spec itself
mult = sel[min(3, len(sel) - 1)].name
spec_lut = BackendSpec.from_library(mult, mode="lut")
spec_lr = BackendSpec.from_library(mult, mode="lowrank")
be_lut = spec_lut.materialize(lib)
be_lr = spec_lr.materialize(lib)
assert spec_lr.materialize(lib) is be_lr      # cached: one trace per spec
y_lut = backend_matmul(x, w, be_lut)
y_lr = backend_matmul(x, w, be_lr)

err_vs_exact = float(jnp.abs(y_lut - y_exact).mean())
err_emulation = float(jnp.abs(y_lr - y_lut).mean())
print(f"\nmultiplier {mult} (power "
      f"{100 * lib.entries[mult].rel_power:.1f}%, rank {be_lr.rank}):")
print(f"  |approx - exact| mean   = {err_vs_exact:.4f}  "
      f"(the circuit's arithmetic error)")
print(f"  |lowrank - LUT| mean    = {err_emulation:.4f}  "
      f"(TPU emulation error — should be much smaller)")
assert err_emulation < max(err_vs_exact, 1e-3) or err_vs_exact == 0

# --- ship the chosen accelerator configuration ------------------------------
policy = ApproxPolicy(default=BackendSpec.golden(),
                      overrides=[("s*_conv*", spec_lr)])
blob = policy.to_json()
assert ApproxPolicy.from_json(blob).cache_key() == policy.cache_key()
print(f"\npolicy JSON ({len(blob)} bytes) round-trips — ready for "
      f"checkpoints and per-request serving")

# --- objective-first DSE: named metrics x pluggable axes (§2.7) -------------
from repro.approx import MaxDrop, Workload, explore, select

y_f32 = x @ w                                 # exact f32 reference
wl = Workload(
    name="toy_fidelity",
    fn=lambda policy: {"proj_mae": float(
        jnp.abs(policy.matmul("proj", x, w) - y_f32).mean())},
    metrics=("proj_mae",), directions={"proj_mae": "min"},
    layer_counts={"proj": x.shape[0] * x.shape[1] * w.shape[1]})

names = [e.name for e in sel[:4]]
result = explore(workload=wl, library=lib, multipliers=names,
                 per_layer=False,
                 objectives=("proj_mae", "power", "delay"))
front = result.pareto()                       # 3-axis non-dominated front
best = select(result, constraints={"proj_mae": MaxDrop(0.05)},
              minimize="power", axis="all_layers")
print(f"\nobjective-first DSE over {result.objectives}: "
      f"{len(front)}/{len(names)} points on the front")
for p in front:
    print(f"  {p.multiplier:<18} mae={p.metrics['proj_mae']:.4f} "
          f"power={100 * p.network_rel_power:.1f}% "
          f"delay={100 * p.costs['delay']:.1f}%")
if best is not None:
    print(f"selected (mae within 0.05 of int8 baseline, min power): "
          f"{best.multiplier}")

print("\nOK")
