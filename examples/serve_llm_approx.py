"""Serve a small LM with batched requests on the emulated
approximate-multiplier accelerator, comparing datapaths:
float (bf16) vs exact-int8 vs approximate (lowrank emulation).

    PYTHONPATH=src python examples/serve_llm_approx.py [--batch 4]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.library import get_default_library
from repro.launch.steps import serve_policy, train_policy
from repro.models.registry import model_fns
from repro.serve.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)

    lib = get_default_library()
    # mildest non-exact Pareto multiplier: on an *untrained* model the
    # logit gaps are tiny, so a large-MAE circuit trivially flips
    # argmaxes — the mild one demonstrates faithful emulation instead
    front = lib.pareto_front("multiplier", 8, "mae")
    mult = min((e for e in front if e.source != "exact"),
               key=lambda e: e.errors.mae).name
    entry = lib.entries[mult]
    print(f"[serve] {args.arch} (reduced), approximate multiplier: "
          f"{mult} (power {100 * entry.rel_power:.1f}%, "
          f"MAE {entry.errors.mae:.2f})")

    # ONE engine; the accelerator is selected PER REQUEST by shipping a
    # serialized ApproxPolicy in the ServeConfig (spec-first API) — the
    # engine keeps a jitted step pair per distinct policy.
    engine = Engine(cfg, params, train_policy(), library=lib)
    logits = {}
    for name, policy in [
        ("bf16 (float)", train_policy()),
        ("int8 exact (golden)", serve_policy(mult, "int8")),
        ("approx lowrank", serve_policy(mult, "lowrank")),
    ]:
        scfg = ServeConfig(max_new_tokens=args.max_new,
                           policy=policy.to_json_dict())
        t0 = time.time()
        out = engine.generate(prompts, scfg)
        dt = time.time() - t0
        import jax.numpy as jnp
        cache = fns.init_cache(cfg, args.batch, args.prompt_len + 1)
        prefill, _ = engine._steps_for(
            engine._request_policy(scfg))
        lg, _ = prefill(params, {"tokens": jnp.asarray(prompts)}, cache)
        logits[name] = np.asarray(lg)
        print(f"  {name:<22} {args.batch * args.max_new / dt:>7.1f} tok/s "
              f"first tokens: {out[0][:6]}")

    ref = logits["int8 exact (golden)"]
    scale = np.abs(ref).max() + 1e-9
    for name in ("bf16 (float)", "approx lowrank"):
        err = np.abs(logits[name] - ref).max() / scale
        print(f"  max |logit delta| vs int8 golden — {name}: {err:.4f}")
    print("  (untrained model: logit margins are ~0, so token streams "
          "diverge under ANY perturbation; the logit deltas above show "
          "the emulated datapath tracks the golden int8 path, scaled by "
          "the chosen circuit's arithmetic error)")


if __name__ == "__main__":
    main()
