"""End-to-end driver (paper Sec. IV case study): train ResNet on
synthetic CIFAR-10, then run the resilience analysis with library
multipliers — per-layer (Fig. 4), all-layers (Table II), and the
beyond-paper heterogeneous composition (a different multiplier per
layer, selected by the two-stage DSE and fine-tuned under STE).

    PYTHONPATH=src python examples/train_resnet_approx.py \
        [--depth 8] [--steps 300] [--n-mult 6] [--full]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.dse import explore, explore_heterogeneous, select_multiplier
from repro.approx.resilience import BankableEval
from repro.approx.specs import BackendSpec
from repro.core.library import get_default_library
from repro.data.synthetic import CifarBatches
from repro.models import resnet
from repro.train.loop import Trainer, TrainLoopConfig
from repro.train.optimizer import OptimizerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--depth", type=int, default=8)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--train-n", type=int, default=4096)
    ap.add_argument("--eval-n", type=int, default=512)
    ap.add_argument("--n-mult", type=int, default=6,
                    help="case-study multipliers to sweep")
    ap.add_argument("--full", action="store_true",
                    help="sweep ALL case-study multipliers per layer")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_resnet_ckpt")
    args = ap.parse_args()

    cfg = resnet.resnet_config(args.depth)
    print(f"[resnet] training {cfg.name} on synthetic CIFAR-10")
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    train_data = CifarBatches("train", args.train_n, args.batch)
    eval_data = CifarBatches("test", args.eval_n, args.batch)

    def loss_fn(p, batch):
        return resnet.loss_fn(p, batch, cfg)

    def batches():
        while True:
            for b in train_data.epoch():
                yield {"images": jnp.asarray(b["images"]),
                       "labels": jnp.asarray(b["labels"])}

    trainer = Trainer(loss_fn, params,
                      OptimizerConfig(lr=3e-3, warmup_steps=20,
                                      total_steps=args.steps,
                                      weight_decay=1e-4),
                      TrainLoopConfig(total_steps=args.steps,
                                      ckpt_every=100,
                                      ckpt_dir=args.ckpt_dir,
                                      log_every=25))
    t0 = time.time()
    trainer.run(batches())
    params = trainer.params
    print(f"[resnet] trained in {time.time() - t0:.0f}s")

    # --- float / int8 reference accuracies (paper: 83.42% -> 82.85%) ---
    eval_batches = list(eval_data.eval_batches())
    eval_images = jnp.asarray(np.stack([b["images"] for b in eval_batches]))
    eval_labels = jnp.asarray(np.stack([b["labels"] for b in eval_batches]))

    def traceable(policy):
        accs = [jnp.mean((jnp.argmax(
            resnet.forward(params, eval_images[i], cfg, policy), -1)
            == eval_labels[i]).astype(jnp.float32))
            for i in range(eval_images.shape[0])]
        return jnp.mean(jnp.stack(accs))

    eval_fn = BankableEval(
        fn=lambda policy: float(jax.jit(lambda: traceable(policy))()),
        traceable=traceable)

    from repro.approx.layers import ApproxPolicy
    acc_f32 = eval_fn(ApproxPolicy(default=BackendSpec.exact("f32")))
    print(f"[resnet] accuracy: float={100 * acc_f32:.2f}%")

    # --- resilience analysis through the DSE facade --------------------
    lib = get_default_library()
    sel = lib.case_study_selection(per_metric=10)
    mults = [e.name for e in sel]
    if not args.full:
        mults = mults[:: max(1, len(mults) // args.n_mult)][:args.n_mult]
    counts = resnet.layer_mult_counts(cfg)

    cache: dict = {}
    print(f"\n[Table II-style] all conv layers, {len(mults)} multipliers:")
    result = explore(eval_fn, counts, lib, multipliers=mults, mode="lut",
                     per_layer=False, batch=True, cache=cache)
    acc_int8 = result.baseline_accuracy
    print(f"[resnet] 8-bit exact (golden) accuracy: {100 * acc_int8:.2f}%")
    print(f"{'multiplier':<20}{'power%':>8}{'MAE':>10}{'acc%':>8}")
    print(f"{'8-bit exact':<20}{100.0:>8.1f}{0.0:>10.2f}"
          f"{100 * acc_int8:>8.2f}")
    rows = result.all_layers
    for r in sorted(rows, key=lambda r: -r.network_rel_power):
        print(f"{r.multiplier:<20}{100 * r.network_rel_power:>8.1f}"
              f"{r.errors['mae']:>10.2f}{100 * r.accuracy:>8.2f}")

    pick = select_multiplier(result, max_accuracy_drop=0.01)
    if pick is not None:
        print(f"\n[autoAx-style selection] within a 1-point accuracy "
              f"budget, deploy {pick.multiplier} "
              f"(power {100 * pick.network_rel_power:.1f}%, "
              f"acc {100 * pick.accuracy:.2f}%)")
        print(f"  policy JSON: {pick.policy().to_json()}")

    print(f"\n[Fig. 4-style] per-layer sweep "
          f"(one layer approximated at a time):")
    worst = min(rows, key=lambda r: r.accuracy)
    layer_result = explore(eval_fn, counts, lib,
                           multipliers=[worst.multiplier], mode="lut",
                           all_layers=False, batch=True, cache=cache)
    print(f"{'layer':<18}{'mult share%':>12}{'acc%':>8}")
    for r in sorted(layer_result.per_layer, key=lambda r: -r.mult_share):
        print(f"{r.layer:<18}{100 * r.mult_share:>12.1f}"
              f"{100 * r.accuracy:>8.2f}")
    print("\n[resnet] claim check: the layer with the largest multiplier "
          "share should cause the largest accuracy drop when approximated")

    # --- heterogeneous composition + approximate-aware fine-tune -------
    print(f"\n[heterogeneous DSE] composing a different multiplier per "
          f"layer (quality bound 1 point):")
    hetero = explore_heterogeneous(eval_fn, counts, lib,
                                   multipliers=mults, mode="lut",
                                   quality_bound=0.01, batch=True,
                                   cache=cache)
    for p in sorted(hetero.heterogeneous,
                    key=lambda p: p.network_rel_power):
        print(f"  {p.multiplier:<14}{100 * p.network_rel_power:>8.1f}%"
              f"{100 * p.accuracy:>8.2f}%")
    pick_h = hetero.selected
    if pick_h is None:
        print("  no heterogeneous point within the bound; "
              "skipping fine-tune")
        return
    print(f"[heterogeneous DSE] selected "
          f"(power {100 * pick_h.network_rel_power:.1f}%, "
          f"acc {100 * pick_h.accuracy:.2f}%):")
    for layer, m in pick_h.assignment:
        print(f"    {layer:<18}{m}")
    hetero_policy = pick_h.policy().materialize(lib)
    print(f"  policy JSON: {pick_h.policy().to_json()}")

    # fine-tune WITH the heterogeneous datapath in the loss (STE
    # gradients): the network adapts to the approximation it will run
    # on, recovering part of the drop — beyond-paper, the paper itself
    # performs no retraining.
    ft_steps = max(20, args.steps // 10)
    trainer_ft = Trainer(
        lambda p, batch: resnet.loss_fn(p, batch, cfg, hetero_policy),
        params,
        OptimizerConfig(lr=3e-4, warmup_steps=5, total_steps=ft_steps,
                        weight_decay=1e-4),
        TrainLoopConfig(total_steps=ft_steps, ckpt_every=10 ** 9,
                        ckpt_dir=args.ckpt_dir + "_hetero",
                        log_every=10 ** 9))
    t0 = time.time()
    trainer_ft.run(batches())
    params_ft = trainer_ft.params

    def acc_under(p, policy):
        fwd = jax.jit(lambda pp, im: resnet.forward(pp, im, cfg, policy))
        accs = [np.mean(np.argmax(np.asarray(
            fwd(p, jnp.asarray(b["images"]))), -1) == b["labels"])
            for b in eval_batches]
        return float(np.mean(accs))

    acc_post = acc_under(params_ft, hetero_policy)
    print(f"[heterogeneous fine-tune] {ft_steps} steps in "
          f"{time.time() - t0:.0f}s: accuracy under the heterogeneous "
          f"datapath {100 * pick_h.accuracy:.2f}% -> {100 * acc_post:.2f}%")

    # ship weights + the per-layer accelerator configuration together:
    # the policy rides in the checkpoint manifest metadata
    from repro.train.checkpoint import CheckpointManager
    mgr = CheckpointManager(args.ckpt_dir + "_hetero", keep=1)
    mgr.save(ft_steps, params_ft, policy=pick_h.policy())
    print(f"[heterogeneous fine-tune] checkpoint + policy saved to "
          f"{args.ckpt_dir}_hetero")


if __name__ == "__main__":
    main()
