#!/usr/bin/env python
"""Docs lint: verify that the repo's markdown front doors don't rot.

Checks, for each markdown file given on the command line (default:
README.md DESIGN.md):

  1. every relative markdown link ``[text](path)`` points at a file or
     directory that exists (http(s)/mailto links are skipped);
  2. every ``DESIGN.md §N[.M]`` section cited from a Python docstring
     under src/ or benchmarks/ resolves to a ``§N[.M]`` heading that
     actually exists in DESIGN.md (the §-citation convention used
     throughout the codebase).

Exit code 0 when clean, 1 with a per-problem report otherwise — wired
into the CI docs job next to ``python -m compileall``.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)#\s]+)(?:#[^)]*)?\)")
CITE_RE = re.compile(r"DESIGN\.md\s+(§[0-9]+(?:\.[0-9]+)?)")
HEADING_RE = re.compile(r"^#{1,6}\s.*?(§[0-9]+(?:\.[0-9]+)?)", re.M)


def check_links(md_path: Path) -> list[str]:
    problems = []
    text = md_path.read_text()
    for target in LINK_RE.findall(text):
        if re.match(r"[a-z]+:", target):      # http:, https:, mailto:
            continue
        resolved = (md_path.parent / target).resolve()
        if not resolved.exists():
            problems.append(f"{md_path.name}: broken link -> {target}")
    return problems


def check_design_citations(design_path: Path) -> list[str]:
    headings = set(HEADING_RE.findall(design_path.read_text()))
    problems = []
    for py in sorted((ROOT / "src").rglob("*.py")) + \
            sorted((ROOT / "benchmarks").glob("*.py")):
        for cite in CITE_RE.findall(py.read_text()):
            if cite not in headings:
                problems.append(
                    f"{py.relative_to(ROOT)}: cites DESIGN.md {cite} "
                    "but no such § heading exists")
    return problems


def main(argv: list[str]) -> int:
    md_files = [Path(a) for a in argv] or [ROOT / "README.md",
                                           ROOT / "DESIGN.md"]
    problems: list[str] = []
    for md in md_files:
        if not md.exists():
            problems.append(f"missing documentation file: {md}")
            continue
        problems += check_links(md)
    design = ROOT / "DESIGN.md"
    if design.exists():
        problems += check_design_citations(design)
    for p in problems:
        print(f"[docs] {p}", file=sys.stderr)
    if not problems:
        print(f"[docs] OK: {', '.join(m.name for m in md_files)} links + "
              "DESIGN.md § citations all resolve")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
