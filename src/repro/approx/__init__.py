from .quant import QuantParams, quantize, dequantize, calibrate
from .registry import (Datapath, available_datapaths, get_datapath,
                       register_datapath)
from .specs import (BackendSpec, LutBank, MaterializedBackend, bank_for,
                    canonicalize, materialize, materialize_cache_stats,
                    clear_materialize_cache)
from .backend import MatmulBackend, as_backend, backend_matmul
from .layers import ApproxPolicy, bank_eval, spec_of
from .resilience import BankableEval, can_bank
from .dse import (DesignPoint, ExploreResult, explore, pareto_points,
                  select_multiplier)
