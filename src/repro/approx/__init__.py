from .quant import QuantParams, quantize, dequantize, calibrate
from .power import (cost_axes_map, network_costs_for_assignment,
                    rel_power_map)
from .objectives import (AtLeast, AtMost, MaxDrop, Objective,
                         UnknownObjectiveError, available_objectives,
                         ensure_objective, get_objective,
                         register_objective, select, value_of)
from .workload import (Workload, as_workload, classification,
                       layer_mult_counts, lm_fidelity,
                       lm_layer_mult_counts, lm_perplexity,
                       logit_fidelity)
from .modules import (EXACT_FAMILIES, FILL_EXACT, MODULE_FAMILIES,
                      ModuleMap, module_of, module_policy_bank,
                      module_sweep_assignments)
from .profiles import (ArchProfile, ModuleRow, profile_architecture,
                       profile_zoo)
from .registry import (Datapath, available_datapaths, composed_product,
                       get_datapath, register_datapath)
from .specs import (BackendSpec, LutBank, MaterializedBackend, PolicyBank,
                    bank_for, canonicalize, materialize,
                    materialize_cache_stats, clear_materialize_cache)
from .backend import MatmulBackend, as_backend, backend_matmul
from .layers import (ApproxPolicy, bank_eval, policy_bank_eval,
                     policy_for_lane, spec_of)
from .resilience import BankableEval, LayerComponents, can_bank
from .ranking import kendall, per_layer_spearman, rankdata, spearman
from .surrogate import (FEATURE_NAMES, SurrogateConfig,
                        SurrogatePredictor, circuit_features,
                        feature_matrix, fit_surrogate,
                        surrogate_components, train_subset)
from .dse import (DesignPoint, ExploreResult, compose_assignments,
                  explore, explore_heterogeneous, pareto_points,
                  select_multiplier, select_point, verify_assignments)
