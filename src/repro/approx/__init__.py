from .quant import QuantParams, quantize, dequantize, calibrate
from .backend import MatmulBackend, backend_matmul
from .layers import ApproxPolicy
