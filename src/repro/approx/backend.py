"""Matmul backends: the accelerator datapath being emulated.

Every projection matmul in every model flows through ``backend_matmul``.
Modes:

  * ``f32`` / ``bf16`` — exact float (the paper's pre-quantization net)
  * ``int8``           — exact uint8-quantized datapath (the paper's
                         "golden" 8-bit multiplier)
  * ``lut``            — approximate multiplier, bit-true 256x256 LUT
                         emulation (TFApprox port; paper-faithful)
  * ``lowrank``        — approximate multiplier, rank-R factored LUT:
                         R 256-entry table lookups + R MXU matmuls
                         (TPU-native adaptation, DESIGN.md §4.2)

Gradients: straight-through estimator — backward pass is the exact f32
matmul VJP, enabling beyond-paper approximate-aware training (the paper
itself performs no retraining).

int32 accumulation of raw uint8 code products is bit-safe for
K < 2^31 / 255^2 = 33 030, which covers every assigned architecture
(max contraction dim = 24 576, nemotron-4-15b d_ff).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .quant import QuantParams, calibrate, quantize

MAX_LUT_K = 33030


@dataclass(frozen=True, eq=False)  # eq=False: id-hash (ndarray fields)
class MatmulBackend:
    mode: str = "bf16"                       # f32|bf16|int8|lut|lowrank
    multiplier: str = "mul8u_exact"          # library entry name
    lut: Optional[np.ndarray] = None         # (256,256) int32 product LUT
    factors_u: Optional[np.ndarray] = None   # (R,256) f32
    factors_v: Optional[np.ndarray] = None   # (R,256) f32
    rank: int = 0
    block_m: int = 512                       # LUT-emulation row blocking
    ste: bool = True                         # straight-through gradients
    use_pallas: bool = False                 # route through Pallas kernels

    @staticmethod
    def exact(mode: str = "bf16") -> "MatmulBackend":
        return MatmulBackend(mode=mode)

    @staticmethod
    def from_library(
        name: str,
        mode: str = "lut",
        rank: Optional[int] = None,
        library=None,
        use_pallas: bool = False,
    ) -> "MatmulBackend":
        """Build a backend emulating library multiplier ``name``."""
        from repro.core.library import get_default_library
        from repro.core.luts import decompose_lut, rank_for_tolerance
        lib = library if library is not None else get_default_library()
        lut = np.asarray(lib.lut(name), dtype=np.int32)
        if rank is None:
            # pick R so decomposition error is negligible next to the
            # circuit's own error (floor 0.25 LSB^2 for near-exact circuits)
            mult_mae = max(lib.entries[name].errors.mae, 0.0)
            tol = max(0.25, 0.1 * mult_mae)
            rank = rank_for_tolerance(lut, tol, max_rank=16)
        fac = decompose_lut(lut, rank)
        return MatmulBackend(
            mode=mode, multiplier=name, lut=lut,
            factors_u=np.asarray(fac.u), factors_v=np.asarray(fac.v),
            rank=int(rank), use_pallas=use_pallas,
        )


# ----------------------------------------------------------------------
# Quantized kernels (operate on uint8 codes stored as int32)
# ----------------------------------------------------------------------
def _int8_exact_q(qa: jax.Array, qw: jax.Array, za, zw) -> jax.Array:
    """Exact Σ (qa-za)(qw-zw) with int32 accumulation."""
    acc = jax.lax.dot_general(
        qa, qw, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    k = qa.shape[1]
    row = jnp.sum(qa, axis=1, dtype=jnp.int32)        # (M,)
    col = jnp.sum(qw, axis=0, dtype=jnp.int32)        # (N,)
    return acc - zw * row[:, None] - za * col[None, :] + k * za * zw


def _lut_gather_block(qa_blk: jax.Array, qw: jax.Array, flat_lut: jax.Array
                      ) -> jax.Array:
    """Σ_k LUT[qa, qw] for one row block. (mb,K) x (K,N) -> (mb,N) i32."""
    idx = qa_blk[:, :, None] * 256 + qw[None, :, :]        # (mb,K,N)
    prods = jnp.take(flat_lut, idx, axis=0)                 # (mb,K,N) i32
    return jnp.sum(prods, axis=1, dtype=jnp.int32)


def _lut_matmul_q(qa: jax.Array, qw: jax.Array, flat_lut: jax.Array,
                  block_m: int) -> jax.Array:
    """Blocked bit-true LUT matmul on codes. (M,K) x (K,N) -> (M,N) i32."""
    m, k = qa.shape
    if k > MAX_LUT_K:
        raise ValueError(f"K={k} exceeds int32-safe LUT accumulation bound")
    mb = min(block_m, m)
    pad = (-m) % mb
    qa_p = jnp.pad(qa, ((0, pad), (0, 0)))
    blocks = qa_p.reshape(-1, mb, k)
    out = jax.lax.map(
        lambda blk: _lut_gather_block(blk, qw, flat_lut), blocks)
    return out.reshape(-1, out.shape[-1])[:m]


def _lowrank_matmul_q(qa: jax.Array, qw: jax.Array, u: jax.Array,
                      v: jax.Array) -> jax.Array:
    """Σ_k Σ_r U[r,qa]V[r,qw]  ==  Σ_r tableU_r(qa) @ tableV_r(qw).
    (M,K) x (K,N) -> (M,N) f32; R batched MXU matmuls."""
    ua = jnp.take(u, qa, axis=1)   # (R,M,K) f32
    vw = jnp.take(v, qw, axis=1)   # (R,K,N) f32
    return jnp.einsum("rmk,rkn->mn", ua, vw,
                      preferred_element_type=jnp.float32)


def _approx_sum_q(qa, qw, backend: MatmulBackend) -> jax.Array:
    """Σ_k approx_mul(qa, qw) on raw codes, by emulation mode."""
    if backend.mode == "lut":
        if backend.use_pallas:
            from repro.kernels.ops import approx_matmul_lut
            return approx_matmul_lut(qa, qw, jnp.asarray(backend.lut))
        flat = jnp.asarray(backend.lut, dtype=jnp.int32).reshape(-1)
        return _lut_matmul_q(qa, qw, flat, backend.block_m)
    if backend.mode == "lowrank":
        if backend.use_pallas:
            from repro.kernels.ops import lowrank_matmul
            return lowrank_matmul(qa, qw, jnp.asarray(backend.factors_u),
                                  jnp.asarray(backend.factors_v))
        return _lowrank_matmul_q(qa, qw, jnp.asarray(backend.factors_u),
                                 jnp.asarray(backend.factors_v))
    raise ValueError(backend.mode)


def _quantized_matmul(x2d: jax.Array, w: jax.Array,
                      backend: MatmulBackend) -> jax.Array:
    qp_a = calibrate(x2d)
    qp_w = calibrate(w)
    qa = quantize(x2d, qp_a)
    qw = quantize(w, qp_w)
    za, zw = qp_a.zero_point, qp_w.zero_point
    k = x2d.shape[1]
    if backend.mode == "int8":
        acc = _int8_exact_q(qa, qw, za, zw).astype(jnp.float32)
    else:
        s = _approx_sum_q(qa, qw, backend).astype(jnp.float32)
        row = jnp.sum(qa, axis=1, dtype=jnp.int32).astype(jnp.float32)
        col = jnp.sum(qw, axis=0, dtype=jnp.int32).astype(jnp.float32)
        zaf, zwf = za.astype(jnp.float32), zw.astype(jnp.float32)
        acc = s - zwf * row[:, None] - zaf * col[None, :] + k * zaf * zwf
    return acc * (qp_a.scale * qp_w.scale)


# ----------------------------------------------------------------------
# Public entry point with STE gradients
# ----------------------------------------------------------------------
def _forward_2d(x2d: jax.Array, w: jax.Array, backend: MatmulBackend
                ) -> jax.Array:
    if backend.mode == "f32":
        return jnp.dot(x2d, w, preferred_element_type=jnp.float32)
    if backend.mode == "bf16":
        return jnp.dot(x2d.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    return _quantized_matmul(x2d.astype(jnp.float32),
                             w.astype(jnp.float32), backend)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _ste_matmul(x2d, w, backend):
    return _forward_2d(x2d, w, backend)


def _ste_fwd(x2d, w, backend):
    return _forward_2d(x2d, w, backend), (x2d, w)


def _ste_bwd(backend, res, g):
    x2d, w = res
    g = g.astype(jnp.float32)
    dx = jnp.dot(g, w.T.astype(jnp.float32)).astype(x2d.dtype)
    dw = jnp.dot(x2d.T.astype(jnp.float32), g).astype(w.dtype)
    return dx, dw


_ste_matmul.defvjp(_ste_fwd, _ste_bwd)


# ----------------------------------------------------------------------
# Prepared weights (beyond-paper serving optimization, EXPERIMENTS §Perf)
# ----------------------------------------------------------------------
# The weight-side rank tables V_r(q_w) are STATIC per checkpoint: a real
# deployment precomputes them offline.  ``prepare_weight`` replaces a
# projection weight leaf with {tabs: (R,K,N) bf16, colsum, scales},
# turning per-step work into R plain matmuls — no weight requantization,
# no f32 table gather, 2 bytes/element instead of 4.
def prepare_weight(w, backend: MatmulBackend) -> dict:
    w = jnp.asarray(w, jnp.float32)
    qp_w = calibrate(w)
    qw = quantize(w, qp_w)
    v = jnp.asarray(backend.factors_v)            # (R,256)
    tabs = jnp.take(v, qw, axis=1).astype(jnp.bfloat16)   # (R,K,N)
    colsum = jnp.sum(qw, axis=0, dtype=jnp.int32).astype(jnp.float32)
    return {
        "tabs": tabs,
        "colsum": colsum,
        "w_scale": qp_w.scale,
        "w_zp": qp_w.zero_point.astype(jnp.float32),
    }


def is_prepared(w) -> bool:
    return isinstance(w, dict) and "tabs" in w


def _prepared_matmul(x2d: jax.Array, pw: dict,
                     backend: MatmulBackend) -> jax.Array:
    qp_a = calibrate(x2d)
    qa = quantize(x2d, qp_a)
    u = jnp.asarray(backend.factors_u)            # (R,256)
    ua = jnp.take(u, qa, axis=1).astype(jnp.bfloat16)     # (R,M,K)
    y_q = jax.lax.dot_general(
        ua, pw["tabs"], (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).sum(axis=0)   # (M,N)
    k = x2d.shape[1]
    row = jnp.sum(qa, axis=1, dtype=jnp.int32).astype(jnp.float32)
    zaf = qp_a.zero_point.astype(jnp.float32)
    acc = (y_q - pw["w_zp"] * row[:, None] - zaf * pw["colsum"][None, :]
           + k * zaf * pw["w_zp"])
    return acc * (qp_a.scale * pw["w_scale"])


_PROJECTION_LEAVES = frozenset({
    "wq", "wk", "wv", "wo", "wi", "wg", "in_proj", "out_proj",
    "wuq", "wdq", "wqr", "wdkv", "wuk", "wuv", "wkr", "img_proj",
})


def prepare_tree(params, backend: MatmulBackend):
    """Pre-pack every projection weight in a param pytree for lowrank
    serving (DESIGN.md §4.2, §Perf).  Handles stacked leading dims
    (scan groups, experts) by vmapping ``prepare_weight``."""
    def pack(v):
        fn = prepare_weight
        for _ in range(v.ndim - 2):
            fn = jax.vmap(fn, in_axes=(0, None))
        return fn(v, backend)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if (k in _PROJECTION_LEAVES and hasattr(v, "ndim")
                        and v.ndim >= 2):
                    out[k] = pack(v)
                else:
                    out[k] = walk(v)
            return out
        return node

    return walk(params)


def backend_matmul(x: jax.Array, w, backend: Optional[MatmulBackend] = None
                   ) -> jax.Array:
    """x: (..., K) @ w: (K, N) -> (..., N) f32 through the selected
    accelerator datapath.  ``w`` may be a prepared-weight dict."""
    backend = backend or MatmulBackend()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2d = x.reshape(-1, k)
    if is_prepared(w):
        y = _prepared_matmul(x2d.astype(jnp.float32), w, backend)
        return y.reshape(*lead, y.shape[-1])
    if backend.mode in ("f32", "bf16") or not backend.ste:
        y = _forward_2d(x2d, w, backend)
    else:
        y = _ste_matmul(x2d, w, backend)
    return y.reshape(*lead, w.shape[-1])
