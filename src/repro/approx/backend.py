"""Matmul backends: the accelerator datapath being emulated.

Every projection matmul in every model flows through ``backend_matmul``.
Modes (each a registered datapath, see ``repro.approx.registry``):

  * ``f32`` / ``bf16`` — exact float (the paper's pre-quantization net)
  * ``int8``           — exact uint8-quantized datapath (the paper's
                         "golden" 8-bit multiplier)
  * ``lut``            — approximate multiplier, bit-true 256x256 LUT
                         emulation (TFApprox port; paper-faithful)
  * ``lowrank``        — approximate multiplier, rank-R factored LUT:
                         R 256-entry table lookups + R MXU matmuls
                         (TPU-native adaptation, DESIGN.md §4.2)

The preferred handle is a ``repro.approx.specs.BackendSpec`` (or the
``MaterializedBackend`` it caches to); the legacy ndarray-carrying
``MatmulBackend`` remains as a deprecation shim and is converted on
entry.  Datapath selection goes through the registry — there is no
mode if/elif chain here, so new datapaths plug in without editing this
module (DESIGN.md §2).

Gradients: straight-through estimator — backward pass is the exact f32
matmul VJP, enabling beyond-paper approximate-aware training (the paper
itself performs no retraining).

int32 accumulation of raw uint8 code products is bit-safe for
K < 2^31 / 255^2 = 33 030, which covers every assigned architecture
(max contraction dim = 24 576, nemotron-4-15b d_ff).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .quant import QuantParams, calibrate, quantize
from .registry import MAX_LUT_K, get_datapath
from .specs import BackendSpec, MaterializedBackend, materialize


# ----------------------------------------------------------------------
# Legacy shim (pre-spec API): id-hashed dataclass carrying raw arrays.
# Prefer BackendSpec everywhere new; this stays so existing call sites
# and tests keep working unchanged.
# ----------------------------------------------------------------------
@dataclass(frozen=True, eq=False)  # eq=False: id-hash (ndarray fields)
class MatmulBackend:
    mode: str = "bf16"                       # f32|bf16|int8|lut|lowrank
    multiplier: str = "mul8u_exact"          # library entry name
    lut: Optional[np.ndarray] = None         # (256,256) int32 product LUT
    factors_u: Optional[np.ndarray] = None   # (R,256) f32
    factors_v: Optional[np.ndarray] = None   # (R,256) f32
    rank: int = 0
    block_m: int = 512                       # LUT-emulation row blocking
    ste: bool = True                         # straight-through gradients
    use_pallas: bool = False                 # route through Pallas kernels

    @staticmethod
    def exact(mode: str = "bf16") -> "MatmulBackend":
        return MatmulBackend(mode=mode)

    @staticmethod
    def from_library(
        name: str,
        mode: str = "lut",
        rank: Optional[int] = None,
        library=None,
        use_pallas: bool = False,
    ) -> "MatmulBackend":
        """Deprecated: use ``BackendSpec.from_library(...).materialize()``.
        Builds a legacy backend emulating library multiplier ``name``."""
        warnings.warn(
            "MatmulBackend.from_library is deprecated; use "
            "BackendSpec.from_library(name, ...).materialize(library)",
            DeprecationWarning, stacklevel=2)
        from repro.core.library import get_default_library
        from .registry import pack_lowrank, pack_lut
        lib = library if library is not None else get_default_library()
        spec = BackendSpec(mode=mode, multiplier=name, rank=rank,
                           variant="pallas" if use_pallas else "ref")
        lut = pack_lut(spec, lib)["lut"]
        lr = pack_lowrank(spec, lib)     # shares the auto-rank heuristic
        return MatmulBackend(
            mode=mode, multiplier=name, lut=lut,
            factors_u=lr["u"], factors_v=lr["v"],
            rank=int(lr["u"].shape[0]), use_pallas=use_pallas,
        )

    def to_spec(self) -> BackendSpec:
        """Best-effort serializable spec: faithful whenever the arrays
        came from a library (every non-test call site); the single
        source of truth for the legacy-field -> spec mapping."""
        return BackendSpec(
            mode=self.mode, multiplier=self.multiplier,
            rank=(int(self.rank) or None), block_m=self.block_m,
            ste=self.ste,
            variant="pallas" if self.use_pallas else "ref")


BackendLike = Union[None, BackendSpec, MaterializedBackend, MatmulBackend]


def as_backend(backend: BackendLike) -> MaterializedBackend:
    """Coerce any accepted backend handle to a MaterializedBackend."""
    if backend is None:
        return materialize(BackendSpec())
    if isinstance(backend, MaterializedBackend):
        return backend
    if isinstance(backend, BackendSpec):
        return materialize(backend)
    if isinstance(backend, MatmulBackend):
        return _from_legacy(backend)
    raise TypeError(f"not a backend: {type(backend).__name__}")


def _from_legacy(be: MatmulBackend) -> MaterializedBackend:
    spec = be.to_spec()
    if not spec.is_quantized:
        return materialize(spec)
    dp = get_datapath(spec.datapath_name)
    if not dp.needs_library:                 # int8: no consts to carry
        return materialize(spec)
    # Raw arrays were attached by hand — wrap them uncached (id-hash
    # semantics identical to the legacy class).
    consts: dict = {}
    if be.mode.startswith("lut"):
        if be.lut is None:
            raise ValueError("legacy lut backend without a LUT")
        consts = {"lut": np.asarray(be.lut, np.int32),
                  "block_m": int(be.block_m)}
    elif be.mode.startswith("lowrank"):
        if be.factors_u is None or be.factors_v is None:
            raise ValueError("legacy lowrank backend without factors")
        consts = {"u": np.asarray(be.factors_u, np.float32),
                  "v": np.asarray(be.factors_v, np.float32)}
    else:
        raise ValueError(f"legacy backend mode {be.mode!r} needs a spec")
    return MaterializedBackend(spec=spec, datapath=dp, consts=consts)


# ----------------------------------------------------------------------
# Quantized execution (operates on uint8 codes stored as int32)
# ----------------------------------------------------------------------
def _quantized_matmul(x2d: jax.Array, w: jax.Array,
                      backend: MaterializedBackend) -> jax.Array:
    dp = backend.datapath
    if getattr(dp, "fused", False):
        # single-program datapath (DESIGN.md §2.10): calibration,
        # quantization, gather, accumulation and dequant all live in
        # the datapath's one fused kernel — hand it the float operands.
        return dp.forward_fused(x2d, w, backend.consts)
    # operand width of the emulated datapath (8 for the paper's
    # baseline; 12/16 for composed wide entries, DESIGN.md §2.6).  May
    # be a traced per-lane scalar inside a mixed-width banked eval.
    bits = backend.consts.get("bits", 8)
    qp_a = calibrate(x2d, bits=bits)
    qp_w = calibrate(w, bits=bits)
    qa = quantize(x2d, qp_a)
    qw = quantize(w, qp_w)
    za, zw = qp_a.zero_point, qp_w.zero_point
    k = x2d.shape[1]
    s = dp.forward_q(qa, qw, backend.consts)
    if dp.exact_int32:
        # exact datapath: Σ (qa-za)(qw-zw) with int32 accumulation
        row = jnp.sum(qa, axis=1, dtype=jnp.int32)        # (M,)
        col = jnp.sum(qw, axis=0, dtype=jnp.int32)        # (N,)
        acc = (s - zw * row[:, None] - za * col[None, :]
               + k * za * zw).astype(jnp.float32)
    else:
        s = s.astype(jnp.float32)
        row = jnp.sum(qa, axis=1, dtype=jnp.int32).astype(jnp.float32)
        col = jnp.sum(qw, axis=0, dtype=jnp.int32).astype(jnp.float32)
        zaf, zwf = za.astype(jnp.float32), zw.astype(jnp.float32)
        # trunc is an exact identity on these integer-valued products
        # but pins each one to its own f32 rounding, so XLA/LLVM cannot
        # contract mul+sub into a single-rounding FMA — without it the
        # result depends on the surrounding compilation context and the
        # variants stop being bit-identical (see kernels/fused_matmul
        # ``_dequant`` for the full rationale).
        t_row = jnp.trunc(zwf * row[:, None])
        t_col = jnp.trunc(zaf * col[None, :])
        t_k = jnp.trunc(k * zaf * zwf)
        acc = s - t_row - t_col + t_k
    return acc * (qp_a.scale * qp_w.scale)


# ----------------------------------------------------------------------
# Public entry point with STE gradients
# ----------------------------------------------------------------------
def _forward_2d(x2d: jax.Array, w: jax.Array,
                backend: MaterializedBackend) -> jax.Array:
    if backend.mode == "f32":
        return jnp.dot(x2d, w, preferred_element_type=jnp.float32)
    if backend.mode == "bf16":
        return jnp.dot(x2d.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    return _quantized_matmul(x2d.astype(jnp.float32),
                             w.astype(jnp.float32), backend)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _ste_matmul(x2d, w, backend):
    return _forward_2d(x2d, w, backend)


def _ste_fwd(x2d, w, backend):
    return _forward_2d(x2d, w, backend), (x2d, w)


def _ste_bwd(backend, res, g):
    x2d, w = res
    g = g.astype(jnp.float32)
    dx = jnp.dot(g, w.T.astype(jnp.float32)).astype(x2d.dtype)
    dw = jnp.dot(x2d.T.astype(jnp.float32), g).astype(w.dtype)
    return dx, dw


_ste_matmul.defvjp(_ste_fwd, _ste_bwd)


# ----------------------------------------------------------------------
# Prepared weights (beyond-paper serving optimization, EXPERIMENTS §Perf)
# ----------------------------------------------------------------------
# The weight-side rank tables V_r(q_w) are STATIC per checkpoint: a real
# deployment precomputes them offline.  ``prepare_weight`` replaces a
# projection weight leaf with {tabs: (R,K,N) bf16, colsum, scales},
# turning per-step work into R plain matmuls — no weight requantization,
# no f32 table gather, 2 bytes/element instead of 4.
def prepare_weight(w, backend: BackendLike) -> dict:
    mb = as_backend(backend)
    w = jnp.asarray(w, jnp.float32)
    qp_w = calibrate(w)
    qw = quantize(w, qp_w)
    v = jnp.asarray(mb.consts["v"])               # (R,256)
    tabs = jnp.take(v, qw, axis=1).astype(jnp.bfloat16)   # (R,K,N)
    colsum = jnp.sum(qw, axis=0, dtype=jnp.int32).astype(jnp.float32)
    return {
        "tabs": tabs,
        "colsum": colsum,
        "w_scale": qp_w.scale,
        "w_zp": qp_w.zero_point.astype(jnp.float32),
    }


def is_prepared(w) -> bool:
    return isinstance(w, dict) and "tabs" in w


def _prepared_matmul(x2d: jax.Array, pw: dict,
                     backend: MaterializedBackend) -> jax.Array:
    qp_a = calibrate(x2d)
    qa = quantize(x2d, qp_a)
    u = jnp.asarray(backend.consts["u"])          # (R,256)
    ua = jnp.take(u, qa, axis=1).astype(jnp.bfloat16)     # (R,M,K)
    y_q = jax.lax.dot_general(
        ua, pw["tabs"], (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).sum(axis=0)   # (M,N)
    k = x2d.shape[1]
    row = jnp.sum(qa, axis=1, dtype=jnp.int32).astype(jnp.float32)
    zaf = qp_a.zero_point.astype(jnp.float32)
    acc = (y_q - pw["w_zp"] * row[:, None] - zaf * pw["colsum"][None, :]
           + k * zaf * pw["w_zp"])
    return acc * (qp_a.scale * pw["w_scale"])


_PROJECTION_LEAVES = frozenset({
    "wq", "wk", "wv", "wo", "wi", "wg", "in_proj", "out_proj",
    "wuq", "wdq", "wqr", "wdkv", "wuk", "wuv", "wkr", "img_proj",
})


def prepare_tree(params, backend: BackendLike):
    """Pre-pack every projection weight in a param pytree for lowrank
    serving (DESIGN.md §4.2, §Perf).  Handles stacked leading dims
    (scan groups, experts) by vmapping ``prepare_weight``."""
    mb = as_backend(backend)

    def pack(v):
        fn = prepare_weight
        for _ in range(v.ndim - 2):
            fn = jax.vmap(fn, in_axes=(0, None))
        return fn(v, mb)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if (k in _PROJECTION_LEAVES and hasattr(v, "ndim")
                        and v.ndim >= 2):
                    out[k] = pack(v)
                else:
                    out[k] = walk(v)
            return out
        return node

    return walk(params)


def backend_matmul(x: jax.Array, w, backend: BackendLike = None
                   ) -> jax.Array:
    """x: (..., K) @ w: (K, N) -> (..., N) f32 through the selected
    accelerator datapath.  ``backend`` may be a BackendSpec, a
    MaterializedBackend, a legacy MatmulBackend or None (bf16);
    ``w`` may be a prepared-weight dict."""
    mb = as_backend(backend)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2d = x.reshape(-1, k)
    if is_prepared(w):
        y = _prepared_matmul(x2d.astype(jnp.float32), w, mb)
        return y.reshape(*lead, y.shape[-1])
    if not mb.spec.is_quantized or not mb.ste:
        y = _forward_2d(x2d, w, mb)
    else:
        y = _ste_matmul(x2d, w, mb)
    return y.reshape(*lead, w.shape[-1])
