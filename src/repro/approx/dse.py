"""autoAx-style design-space exploration facade (DESIGN.md §2.3, §2.5).

The paper's workflow — library → Pareto selection → per-layer resilience
sweep → pick the multiplier for the application — as one call, in the
spirit of autoAx (Mrazek et al., 2019: automated search of approximate
circuits for a quality bound):

    result = explore(eval_fn, layer_counts, library,
                     quality_bound=0.01)
    point = select_multiplier(result, max_accuracy_drop=0.01)
    policy = point.policy()          # ship it: policy.to_json()

``explore`` runs the per-layer (Fig. 4) and all-layers (Table II)
sweeps on top of ``repro.approx.resilience`` with a policy-keyed eval
cache, so repeated explorations (and the shared exact baseline) never
re-evaluate the same configuration; backend materialization is cached
per (library, spec) so sweeps share jit traces.

``explore_heterogeneous`` goes beyond the paper's single-multiplier
endpoint: a two-stage autoAx-style search that composes a DIFFERENT
multiplier per layer (prediction from per-layer component models +
layer-wise Pareto pruning + beam composition, then exact batched
verification of the shortlist through ``policy_bank_eval``), growing
``ExploreResult`` with a ``heterogeneous`` axis whose points carry full
per-layer assignments (DESIGN.md §2.5).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Mapping, Optional, Sequence

import numpy as np

from . import objectives as objectives_mod
from .layers import ApproxPolicy, policy_bank_eval, policy_for_lane
from .objectives import get_objective
from .power import (auto_rel_power, cost_axes_map,
                    network_costs_for_assignment,
                    network_power_for_assignment, rel_power_map)
from .resilience import (LayerComponents, ResilienceRow, _unstack_metrics,
                         all_layers_sweep, can_bank, per_layer_sweep)
from .specs import BackendSpec, PolicyBank
from .workload import Workload, as_workload

DEFAULT_OBJECTIVES = ("accuracy", "power")


@dataclass(frozen=True)
class DesignPoint:
    """One evaluated configuration of the design space.

    Uniform points set ``layer`` to a layer name or "all";
    heterogeneous points set ``layer="hetero"`` and carry the full
    per-layer composition in ``assignment`` (layer name -> multiplier
    name, ordered).

    ``metrics`` holds every named workload quality metric measured at
    this point; ``accuracy`` is the legacy scalar alias for the
    workload's PRIMARY metric (DESIGN.md §2.7).  ``costs`` holds the
    library-derived area/delay axes next to the power columns, so
    objective tuples like ``("accuracy", "power", "delay")`` resolve
    off the point alone."""
    multiplier: str
    layer: str                  # layer name, "all", or "hetero"
    accuracy: float
    network_rel_power: float
    multiplier_rel_power: float
    mult_share: float
    spec: Optional[BackendSpec] = None
    errors: dict = field(default_factory=dict)
    assignment: Optional[tuple[tuple[str, str], ...]] = None
    # datapath the assignment was VERIFIED under; policy() reproduces it
    mode: str = "lut"
    variant: str = "ref"
    metrics: dict = field(default_factory=dict)
    costs: dict = field(default_factory=dict)

    @staticmethod
    def from_row(r: ResilienceRow) -> "DesignPoint":
        return DesignPoint(
            multiplier=r.multiplier, layer=r.layer, accuracy=r.accuracy,
            network_rel_power=r.network_rel_power,
            multiplier_rel_power=r.multiplier_rel_power,
            mult_share=r.mult_share, spec=r.spec, errors=dict(r.errors),
            metrics=dict(r.metrics), costs=dict(r.costs))

    @staticmethod
    def from_assignment(assignment: Mapping[str, str], accuracy: float,
                        network_rel_power: float,
                        mode: str = "lut",
                        variant: str = "ref",
                        metrics: Optional[Mapping[str, float]] = None,
                        costs: Optional[Mapping[str, float]] = None
                        ) -> "DesignPoint":
        """A verified heterogeneous composition as a design point; the
        distinct multipliers are summarized in ``multiplier``, the
        exact per-layer mapping preserved in ``assignment``, and the
        datapath it was measured under in ``mode``/``variant``."""
        distinct = tuple(dict.fromkeys(assignment.values()))
        label = (distinct[0] if len(distinct) == 1
                 else f"hetero[{len(distinct)}]")
        return DesignPoint(
            multiplier=label, layer="hetero", accuracy=accuracy,
            network_rel_power=network_rel_power,
            multiplier_rel_power=network_rel_power, mult_share=1.0,
            spec=None, assignment=tuple(assignment.items()),
            mode=mode, variant=variant,
            metrics=dict(metrics or {}), costs=dict(costs or {}))

    def policy(self, base: Optional[BackendSpec] = None) -> ApproxPolicy:
        """Deployable policy for this point: the multiplier everywhere
        ("all"), one override per assigned layer ("hetero", on the
        ``mode``/``variant`` datapath the point was verified under), or
        only the swept layer over an exact base."""
        if self.assignment is not None:
            return ApproxPolicy(
                default=base or BackendSpec.golden(),
                overrides=[(layer, BackendSpec(mode=self.mode,
                                               multiplier=m,
                                               variant=self.variant))
                           for layer, m in self.assignment])
        spec = self.spec or BackendSpec(mode="lut",
                                        multiplier=self.multiplier)
        if self.layer == "all":
            return ApproxPolicy(default=spec)
        return ApproxPolicy(default=base or BackendSpec.golden(),
                            overrides=[(self.layer, spec)])

    def to_dict(self) -> dict:
        return {
            "multiplier": self.multiplier, "layer": self.layer,
            "accuracy": self.accuracy,
            "network_rel_power": self.network_rel_power,
            "multiplier_rel_power": self.multiplier_rel_power,
            "mult_share": self.mult_share,
            "spec": self.spec.to_dict() if self.spec else None,
            "errors": dict(self.errors),
            "assignment": (dict(self.assignment)
                           if self.assignment is not None else None),
            "mode": self.mode, "variant": self.variant,
            "metrics": dict(self.metrics),
            "costs": dict(self.costs),
        }

    @staticmethod
    def from_dict(d: Mapping) -> "DesignPoint":
        """Inverse of ``to_dict`` (accepts pre-§2.7 dicts without
        metrics/costs)."""
        assignment = d.get("assignment")
        return DesignPoint(
            multiplier=d["multiplier"], layer=d["layer"],
            accuracy=float(d["accuracy"]),
            network_rel_power=float(d["network_rel_power"]),
            multiplier_rel_power=float(d["multiplier_rel_power"]),
            mult_share=float(d["mult_share"]),
            spec=(BackendSpec.from_dict(d["spec"])
                  if d.get("spec") else None),
            errors=dict(d.get("errors") or {}),
            assignment=(tuple(assignment.items())
                        if assignment is not None else None),
            mode=d.get("mode", "lut"), variant=d.get("variant", "ref"),
            metrics=dict(d.get("metrics") or {}),
            costs=dict(d.get("costs") or {}))


def pareto_points(points: list[DesignPoint],
                  objectives: Optional[Sequence[str]] = None
                  ) -> list[DesignPoint]:
    """Non-dominated front over named ``objectives`` (default: the
    legacy accuracy-max / network-power-min pair).  Delegates to the
    N-dimensional ``repro.approx.objectives.pareto_points``, whose
    2-axis default is bit-identical — membership AND order — to the
    historical sweep here (ties on all axes are mutually
    non-dominating and all kept, matching
    ``ApproxLibrary.pareto_front`` semantics)."""
    return objectives_mod.pareto_points(
        points, objectives if objectives is not None
        else DEFAULT_OBJECTIVES)


@dataclass
class ExploreResult:
    """DSE result: axes of measured design points over one workload.

    ``baseline_metrics`` carries EVERY metric the workload measured on
    the golden datapath; ``baseline_accuracy`` is the legacy scalar
    alias for the PRIMARY one (``primary``, direction-aware through
    the objectives registry).  ``objectives`` records the axis tuple
    the exploration was asked to Pareto over — ``pareto()`` uses it by
    default."""

    baseline_accuracy: float            # exact int8 golden datapath
    all_layers: list[DesignPoint] = field(default_factory=list)
    per_layer: list[DesignPoint] = field(default_factory=list)
    heterogeneous: list[DesignPoint] = field(default_factory=list)
    selected: Optional[DesignPoint] = None
    baseline_metrics: dict = field(default_factory=dict)
    objectives: tuple = DEFAULT_OBJECTIVES
    primary: str = "accuracy"
    # surrogate predict-stage record (DESIGN.md §2.11): training split,
    # calibration band, fidelity diagnostics.  None on exact-predict
    # explorations — and absent from their JSON, so pre-surrogate
    # round-trips stay byte-identical.
    surrogate: Optional[dict] = None

    def _primary_direction(self) -> str:
        try:
            return get_objective(self.primary).direction
        except KeyError:
            return "max"

    def _primary_value(self, p: DesignPoint) -> float:
        return float(p.metrics.get(self.primary, p.accuracy))

    def pareto(self, axis: str = "all_layers",
               objectives: Optional[Sequence[str]] = None
               ) -> list[DesignPoint]:
        """Non-dominated front of one axis ("all_layers",
        "heterogeneous") or of their union ("combined"), over
        ``objectives`` (default: the exploration's own tuple)."""
        objs = tuple(objectives) if objectives is not None \
            else self.objectives
        if axis == "combined":
            return pareto_points(self.all_layers + self.heterogeneous,
                                 objs)
        return pareto_points(getattr(self, axis), objs)

    def within(self, max_accuracy_drop: float,
               axis: str = "all_layers") -> list[DesignPoint]:
        """Points whose PRIMARY metric stays within
        ``max_accuracy_drop`` of the baseline, in the primary's own
        direction (a min-primary like logit-MAE may RISE at most that
        much)."""
        pts = (self.all_layers + self.heterogeneous
               if axis == "combined" else getattr(self, axis))
        if self._primary_direction() == "min":
            ceiling = self.baseline_accuracy + max_accuracy_drop
            return [p for p in pts if self._primary_value(p) <= ceiling]
        floor = self.baseline_accuracy - max_accuracy_drop
        return [p for p in pts if self._primary_value(p) >= floor]

    def to_json_dict(self) -> dict:
        # persist the DIRECTIONS of the axes this result reasons with:
        # workload metrics only register when their Workload is
        # constructed, so a restoring process would otherwise fall
        # back to "max" for a min-primary (logit MAE, perplexity) and
        # silently invert every quality bound
        directions = {}
        for name in (*self.objectives, self.primary,
                     *self.baseline_metrics):
            try:
                directions[name] = get_objective(name).direction
            except KeyError:
                pass
        out = {
            "baseline_accuracy": self.baseline_accuracy,
            "all_layers": [p.to_dict() for p in self.all_layers],
            "per_layer": [p.to_dict() for p in self.per_layer],
            "heterogeneous": [p.to_dict() for p in self.heterogeneous],
            "selected": self.selected.to_dict() if self.selected else None,
            "baseline_metrics": dict(self.baseline_metrics),
            "objectives": list(self.objectives),
            "primary": self.primary,
            "objective_directions": directions,
        }
        if self.surrogate is not None:
            out["surrogate"] = dict(self.surrogate)
        return out

    @staticmethod
    def from_json_dict(d: Mapping) -> "ExploreResult":
        """Inverse of ``to_json_dict`` (accepts pre-§2.7 dicts):
        ``ExploreResult.from_json_dict(json.loads(blob))`` restores a
        shipped exploration, round-tripping every design point and
        re-registering the axes' directions so ``pareto``/``within``/
        ``select`` behave identically in a fresh process (a conflicting
        live registration raises rather than silently winning)."""
        from .objectives import ensure_objective
        for name, direction in (d.get("objective_directions")
                                or {}).items():
            ensure_objective(name, direction)
        return ExploreResult(
            baseline_accuracy=float(d["baseline_accuracy"]),
            all_layers=[DesignPoint.from_dict(p)
                        for p in d.get("all_layers", [])],
            per_layer=[DesignPoint.from_dict(p)
                       for p in d.get("per_layer", [])],
            heterogeneous=[DesignPoint.from_dict(p)
                           for p in d.get("heterogeneous", [])],
            selected=(DesignPoint.from_dict(d["selected"])
                      if d.get("selected") else None),
            baseline_metrics=dict(d.get("baseline_metrics") or {}),
            objectives=tuple(d.get("objectives") or DEFAULT_OBJECTIVES),
            primary=d.get("primary", "accuracy"),
            surrogate=(dict(d["surrogate"])
                       if d.get("surrogate") is not None else None))


def _seed_cache(cache: dict, rows: list[ResilienceRow], golden) -> None:
    """Store batched-sweep results under the SAME policy cache keys the
    sequential path would use, so later sequential (or widened)
    explorations over the same cache dict hit instead of re-running.
    Cache values are metric DICTS (the ``Workload.cached`` convention,
    DESIGN.md §2.7)."""
    for r in rows:
        if r.spec is None:
            continue
        if r.layer == "all":
            policy = ApproxPolicy(default=r.spec)
        else:
            policy = ApproxPolicy(default=golden,
                                  overrides=[(r.layer, r.spec)])
        cache.setdefault(policy.cache_key(), dict(r.metrics))


def explore(
    eval_fn: Optional[Callable[[ApproxPolicy], float]] = None,
    layer_counts: Optional[dict[str, int]] = None,
    library=None,
    multipliers: Optional[list[str]] = None,
    mode: str = "lut",
    variant: str = "ref",
    quality_bound: Optional[float] = None,
    per_layer: bool = True,
    all_layers: bool = True,
    cache: Optional[dict] = None,
    batch: bool = False,
    sharding=None,
    rel_power=None,
    workload: Optional[Workload] = None,
    objectives: Optional[Sequence[str]] = None,
) -> ExploreResult:
    """One-call DSE: baseline + Table II + Fig. 4 sweeps over the
    library's case-study multipliers (or ``multipliers``), with cached
    evaluations.

    ``multipliers`` may mix operand widths (8-bit entries alongside
    composed 12/16-bit ones, DESIGN.md §2.6); batched sweeps stay O(1)
    compiled programs either way, and mixed sets are auto-rebased onto
    one comparable power axis (``power.auto_rel_power``; pass
    ``rel_power`` to choose the reference yourself).

    Sequential (default) evaluation runs one ``eval_fn`` call per design
    point through a policy-keyed cache: pass the same ``cache`` dict
    across calls to resume or widen an exploration without re-running
    finished points.

    ``batch=True`` switches to the batched resilience engine: the
    multiplier axis is packed into a ``LutBank`` and each sweep runs as
    O(1) compiled programs (`DESIGN.md §2.4`), bit-identical accuracies
    to the sequential path.  Batching needs a
    ``repro.approx.resilience.BankableEval`` (an eval with a traceable
    core) and a bankable datapath (lut family); anything else — legacy
    plain-callable evals, ``mode="lowrank"`` — silently falls back to
    the sequential path, so ``batch=True`` is always safe to request.
    A batched sweep evaluates the whole bank even on a warm cache (it
    is one program, not n lookups) but writes every result back into
    ``cache`` under sequential-compatible keys, so mixed
    batched-then-sequential workflows never re-evaluate.  ``sharding``
    optionally spreads the bank axis across devices
    (``repro.launch.mesh.bank_sharding``).

    **Objective-first calling convention (DESIGN.md §2.7):** pass a
    ``workload=`` (any ``repro.approx.workload.Workload`` — shipped
    adapters cover classification, LM logit fidelity and perplexity)
    instead of ``eval_fn``, and optionally ``objectives=`` naming the
    axes to Pareto over (workload metrics, ``power``/``area``/
    ``delay`` cost axes, library error statistics):

        result = explore(workload=lm_fidelity("qwen1.5-0.5b"),
                         objectives=("logit_mae", "power", "delay"))
        front = result.pareto()          # 3-axis non-dominated front

    ``layer_counts`` defaults to the workload's own; every design
    point carries the full metric dict next to the legacy scalar
    columns.  Plain ``eval_fn`` call sites behave exactly as before
    (single ``accuracy`` metric, 2-axis fronts, bit-identical).

    If ``quality_bound`` is given, ``result.selected`` is the
    lowest-power all-layers point whose PRIMARY metric stays within
    that drop (direction-aware; see ``objectives.select`` for the
    fully declarative endpoint).
    """
    wl = as_workload(workload if workload is not None else eval_fn)
    if layer_counts is None:
        layer_counts = wl.layer_counts
        if layer_counts is None:
            raise TypeError(
                "explore() needs layer_counts (the workload carries "
                "none)")
    if objectives is not None:
        for name in objectives:
            get_objective(name)             # fail fast on unknown axes
    if library is None:
        from repro.core.library import get_default_library
        library = get_default_library()
    if multipliers is None:
        multipliers = [e.name for e in library.case_study_selection()]
    cache = cache if cache is not None else {}
    run = wl.cached(cache)
    batch = batch and can_bank(wl, mode, variant)

    golden = BackendSpec.golden().materialize()
    baseline_metrics = run.measure(ApproxPolicy(default=golden))

    result = ExploreResult(
        baseline_accuracy=baseline_metrics[wl.primary],
        baseline_metrics=baseline_metrics,
        objectives=(tuple(objectives) if objectives is not None
                    else (wl.primary, "power")),
        primary=wl.primary)
    if all_layers:
        rows = all_layers_sweep(wl if batch else run, layer_counts,
                                multipliers, library, mode=mode,
                                variant=variant, batch=batch,
                                sharding=sharding, rel_power=rel_power)
        if batch:
            _seed_cache(cache, rows, golden)
        result.all_layers = [DesignPoint.from_row(r) for r in rows]
    if per_layer:
        rows = per_layer_sweep(wl if batch else run, layer_counts,
                               multipliers, library, mode=mode,
                               base=golden, variant=variant, batch=batch,
                               sharding=sharding, rel_power=rel_power)
        if batch:
            _seed_cache(cache, rows, golden)
        result.per_layer = [DesignPoint.from_row(r) for r in rows]
    if quality_bound is not None and result.all_layers:
        result.selected = select_multiplier(result, quality_bound)
    return result


def select_multiplier(result: ExploreResult,
                      max_accuracy_drop: float,
                      baseline: Optional[float] = None
                      ) -> Optional[DesignPoint]:
    """The paper's endpoint: the lowest-power circuit whose all-layers
    PRIMARY metric stays within ``max_accuracy_drop`` of the golden
    int8 baseline (direction-aware: a min-primary may rise at most
    that much).  Returns None when no candidate meets the bound.  The
    declarative generalization is ``repro.approx.objectives.select``,
    which this delegates to.
    """
    return objectives_mod.select(
        result,
        constraints={result.primary: _budget(result, max_accuracy_drop,
                                             baseline)},
        minimize="power", axis="all_layers")


def _budget(result: ExploreResult, drop: float,
            baseline: Optional[float] = None):
    """``max_accuracy_drop`` as an absolute constraint on the result's
    primary axis, in its own direction (absolute — not ``MaxDrop`` —
    so an explicit ``baseline`` override is honored)."""
    base = (baseline if baseline is not None
            else result.baseline_accuracy)
    if result._primary_direction() == "min":
        return objectives_mod.AtMost(base + drop)
    return objectives_mod.AtLeast(base - drop)


def select_point(result: ExploreResult, max_accuracy_drop: float,
                 axis: str = "combined") -> Optional[DesignPoint]:
    """Generalized endpoint over any result axis (default: uniform ∪
    heterogeneous): the lowest-power verified point within the
    (direction-aware) primary-metric budget."""
    return objectives_mod.select(
        result,
        constraints={result.primary: _budget(result, max_accuracy_drop)},
        minimize="power", axis=axis)


# ----------------------------------------------------------------------
# Heterogeneous two-stage DSE (DESIGN.md §2.5)
# ----------------------------------------------------------------------
def compose_assignments(components: LayerComponents,
                        quality_bound: Optional[float] = None,
                        power_budget: Optional[float] = None,
                        beam_width: int = 8,
                        top_k: int = 8) -> list[np.ndarray]:
    """Prediction-stage composition: layer-wise Pareto pruning followed
    by a beam search over layers (largest multiplication counts first).

    Beam states accumulate predicted accuracy drop (additive model) and
    assigned power; states exceeding the drop threshold are cut, and
    the beam keeps both the lowest-power and the lowest-drop frontiers
    so a cheap-but-damaged prefix cannot starve the search.  The beam
    runs at a LADDER of thresholds around ``quality_bound`` (0.5×, 1×,
    2×) and unions the results: the additive model is deliberately
    pessimistic (per-layer drops rarely compound fully), so verifying a
    band around the predicted bound is how the exact stage recovers
    compositions the prediction would wrongly cut — the autoAx
    predict-then-verify discipline.  Returns up to ``top_k`` distinct
    assignment rows (indices into ``components.multipliers``) ordered
    by predicted power — the shortlist the verification stage measures.
    """
    thresholds = ([quality_bound * 0.5, quality_bound, quality_bound * 2]
                  if quality_bound is not None else [None])
    out, seen = [], set()
    for threshold in thresholds:
        for row in _beam_once(components, threshold, beam_width, top_k):
            if power_budget is not None and \
                    components.predict_power(row) > power_budget:
                continue
            key = tuple(row.tolist())
            if key not in seen:
                seen.add(key)
                out.append(row)
    # tie-break toward better predicted quality IN THE PRIMARY'S OWN
    # DIRECTION (a min-primary's predict_accuracy is higher-is-worse)
    sign = 1.0 if components.direction == "min" else -1.0
    out.sort(key=lambda r: (components.predict_power(r),
                            sign * components.predict_accuracy(r)))
    return out[:top_k]


def _beam_once(components: LayerComponents, threshold: Optional[float],
               beam_width: int, top_k: int) -> list[np.ndarray]:
    fronts = components.layer_pareto()
    d = components.drop()
    order = sorted(range(len(components.layers)),
                   key=lambda j: -components.counts[j])
    # state: (assigned_power_sum, drop_sum, {layer_idx: mult_idx})
    states: list[tuple[float, float, dict]] = [(0.0, 0.0, {})]
    for j in order:
        nxt = []
        for pw, dr, part in states:
            for i in fronts[j]:
                dr2 = dr + float(d[j, i])
                if threshold is not None and dr2 > threshold:
                    continue
                nxt.append((pw + components.counts[j]
                            * float(components.rel_power[i]), dr2,
                            {**part, j: i}))
        if not nxt:
            # bound infeasible at this layer: keep the least-damaging
            # candidate so the search always returns something
            for pw, dr, part in states:
                i = min(fronts[j],
                        key=lambda i: (float(d[j, i]),
                                       float(components.rel_power[i])))
                nxt.append((pw + components.counts[j]
                            * float(components.rel_power[i]),
                            dr + float(d[j, i]), {**part, j: i}))
        by_power = sorted(nxt, key=lambda s: (s[0], s[1]))[:beam_width]
        by_drop = sorted(nxt, key=lambda s: (s[1], s[0]))[:beam_width]
        seen_ids = set()
        states = []
        for s in by_power + by_drop:
            key = tuple(sorted(s[2].items()))
            if key not in seen_ids:
                seen_ids.add(key)
                states.append(s)
    states.sort(key=lambda s: (s[0], s[1]))
    out, seen = [], set()
    for pw, dr, part in states:
        row = np.asarray([part[j] for j in range(len(components.layers))],
                         dtype=np.int32)
        key = tuple(row.tolist())
        if key in seen:
            continue
        seen.add(key)
        out.append(row)
        if len(out) >= top_k:
            break
    return out


def verify_assignments(
    eval_fn: Callable[[ApproxPolicy], float],
    assignments: list[Mapping[str, str]],
    layer_counts: dict[str, int],
    library,
    mode: str = "lut",
    variant: str = "ref",
    batch: bool = True,
    sharding=None,
    assign_sharding=None,
    cache: Optional[dict] = None,
    rel_power=None,
    layers: Optional[tuple] = None,
    fill: Optional[str] = None,
) -> list[DesignPoint]:
    """Verification stage: measure every candidate assignment EXACTLY.

    Batched (default, when the eval and datapath support it): the
    assignments pack into a ``PolicyBank`` and evaluate through
    ``policy_bank_eval`` in one compiled program.  Sequential fallback
    evaluates ``policy_for_lane`` per candidate through the policy
    cache.  Either way results land in ``cache`` under
    sequential-compatible policy keys, and power is the exact
    count-weighted ``network_power_for_assignment``.

    ``layers`` pins the bank's layer axis explicitly and ``fill`` pads
    partially-covering rows with a named multiplier — the module-axis
    lowering path (DESIGN.md §2.12) passes the full tag axis plus
    ``fill="mul8u_exact"`` so disjoint module-family assignments share
    one banked program while staying bit-identical to a golden-base
    sequential policy.
    """
    if not assignments:
        return []
    wl = as_workload(eval_fn)
    if layers is None:
        layers = tuple(dict.fromkeys(
            l for a in assignments for l in a))
    pbank = PolicyBank.from_assignments(assignments, library,
                                        layers=layers, fill=fill)
    batch = batch and can_bank(wl, mode, variant)
    if batch:
        out = policy_bank_eval(
            wl.traceable_metrics, pbank, mode=mode, variant=variant,
            sharding=sharding, assign_sharding=assign_sharding)
        lanes = _unstack_metrics(out, wl.metrics, pbank.n_policies)
    else:
        run = wl.cached(cache) if cache is not None else wl
        lanes = [run.measure(policy_for_lane(pbank, p, mode=mode,
                                             variant=variant))
                 for p in range(pbank.n_policies)]
    if cache is not None:
        for p, metrics in enumerate(lanes):
            cache.setdefault(
                policy_for_lane(pbank, p, mode=mode,
                                variant=variant).cache_key(),
                dict(metrics))
    if rel_power is None:
        rel_power = (auto_rel_power(library, pbank.bank.names)
                     or rel_power_map(library, pbank.bank.names))
    cost_map = cost_axes_map(library, pbank.bank.names)
    points = []
    for p, metrics in enumerate(lanes):
        a = pbank.assignment(p)
        points.append(DesignPoint.from_assignment(
            a, metrics[wl.primary],
            network_power_for_assignment(layer_counts, a, rel_power),
            mode=mode, variant=variant, metrics=metrics,
            costs=network_costs_for_assignment(layer_counts, a,
                                               cost_map)))
    return points


def explore_heterogeneous(
    eval_fn: Callable[[ApproxPolicy], float],
    layer_counts: dict[str, int],
    library=None,
    multipliers: Optional[list[str]] = None,
    mode: str = "lut",
    variant: str = "ref",
    quality_bound: float = 0.01,
    power_budget: Optional[float] = None,
    beam_width: int = 8,
    top_k: int = 8,
    components: Optional[LayerComponents] = None,
    extra_assignments: Optional[list[Mapping[str, str]]] = None,
    cache: Optional[dict] = None,
    batch: bool = True,
    sharding=None,
    assign_sharding=None,
    rel_power=None,
    predictor: str = "exact",
    train_fraction: float = 0.25,
    surrogate_config=None,
) -> ExploreResult:
    """Two-stage heterogeneous DSE (autoAx-style, DESIGN.md §2.5).

    Width-generic (DESIGN.md §2.6): ``multipliers`` may mix 8-bit and
    composed 12/16-bit entries, so compositions can pick a DIFFERENT
    width per layer; mixed sets auto-rebase power onto a common
    reference (``power.auto_rel_power``) in both the component models
    and the verified points — pass ``rel_power`` to pick the
    reference yourself.

    Stage 1 (predict): run the per-layer sweep (batched when the eval
    supports it) and distill it into ``LayerComponents`` — or reuse
    ``components`` from a previous exploration.  Layer-wise Pareto
    pruning keeps only per-layer non-dominated multipliers, and a beam
    search composes up to ``top_k`` full assignments whose *predicted*
    (additive-drop) accuracy stays within ``quality_bound`` of the
    golden baseline, optionally under a ``power_budget`` ceiling.

    ``predictor="surrogate"`` (DESIGN.md §2.11) replaces the full
    exact sweep with the learned predict stage: only a deterministic
    power-spread ``train_fraction`` of the candidates is measured
    exactly (those rows still land on ``result.per_layer``), a small
    MLP trained on them predicts the rest of the component matrix,
    and the beam's quality threshold widens by the surrogate's
    held-out calibration band so prediction error enlarges the
    shortlist rather than cutting good compositions.  The training
    record rides on ``result.surrogate``.  Stage 2 and the final
    selection are exact either way — and ``predictor="exact"`` (the
    default) is the historical path, bit-identical.

    Stage 2 (verify): the shortlist — plus any ``extra_assignments`` —
    is measured EXACTLY in one ``policy_bank_eval`` program (sequential
    fallback mirrors ``explore(batch=...)`` semantics).  Verified
    points land on ``result.heterogeneous`` with exact count-weighted
    power, and ``result.selected`` is the lowest-power verified point
    within ``quality_bound`` (and ``power_budget`` when given).

    Returns an ``ExploreResult`` whose ``per_layer`` axis holds the
    stage-1 sweep (empty when ``components`` was supplied).
    """
    wl = as_workload(eval_fn)
    if library is None:
        from repro.core.library import get_default_library
        library = get_default_library()
    if multipliers is None:
        multipliers = [e.name for e in library.case_study_selection()]
    cache = cache if cache is not None else {}
    run = wl.cached(cache)

    if predictor not in ("exact", "surrogate"):
        raise ValueError(
            f"predictor must be 'exact' or 'surrogate', got {predictor!r}")

    golden = BackendSpec.golden().materialize()
    per_layer_points: list[DesignPoint] = []
    baseline_metrics: dict = {}
    surrogate_record: Optional[dict] = None
    beam_bound = quality_bound
    if components is None:
        baseline_metrics = run.measure(ApproxPolicy(default=golden))
        baseline = baseline_metrics[wl.primary]
        do_batch = batch and can_bank(wl, mode, variant)
        if predictor == "surrogate":
            from .surrogate import surrogate_components
            components, sur, rows = surrogate_components(
                wl if do_batch else run, layer_counts, multipliers,
                library, baseline, direction=wl.primary_direction,
                train_fraction=train_fraction, mode=mode,
                variant=variant, base=golden, batch=do_batch,
                sharding=sharding, rel_power=rel_power,
                config=surrogate_config)
            # predict-then-verify discipline: the beam screens on
            # predictions, so its band must absorb the surrogate's
            # held-out error — the exact verify stage still gates the
            # final selection on the un-widened bound
            beam_bound = quality_bound + sur.calibration
            surrogate_record = {**sur.summary(),
                                "train_fraction": train_fraction,
                                "beam_bound": beam_bound}
        else:
            rows = per_layer_sweep(wl if do_batch else run, layer_counts,
                                   multipliers, library, mode=mode,
                                   base=golden, variant=variant,
                                   batch=do_batch, sharding=sharding,
                                   rel_power=rel_power)
            components = LayerComponents.from_rows(
                rows, layer_counts, baseline,
                direction=wl.primary_direction)
        if do_batch:
            _seed_cache(cache, rows, golden)
        per_layer_points = [DesignPoint.from_row(r) for r in rows]
    baseline = components.baseline

    candidates = compose_assignments(components,
                                     quality_bound=beam_bound,
                                     power_budget=power_budget,
                                     beam_width=beam_width, top_k=top_k)
    if beam_bound != quality_bound:
        # the widened band admits cheaper-but-riskier compositions that
        # can crowd the power-ordered shortlist; union in the un-widened
        # beam's shortlist so conservative compositions stay verified —
        # verification is one banked program, so the extra rows are
        # nearly free
        seen_rows = {tuple(r.tolist()) for r in candidates}
        for row in compose_assignments(components,
                                       quality_bound=quality_bound,
                                       power_budget=power_budget,
                                       beam_width=beam_width,
                                       top_k=top_k):
            if tuple(row.tolist()) not in seen_rows:
                seen_rows.add(tuple(row.tolist()))
                candidates.append(row)
    assignments = [
        {l: components.multipliers[i]
         for l, i in zip(components.layers, row)}
        for row in candidates]
    for extra in (extra_assignments or []):
        a = dict(extra)
        if a not in assignments:
            assignments.append(a)

    hetero = verify_assignments(
        wl, assignments, layer_counts, library, mode=mode,
        variant=variant, batch=batch, sharding=sharding,
        assign_sharding=assign_sharding, cache=cache,
        rel_power=rel_power)

    result = ExploreResult(baseline_accuracy=baseline,
                           per_layer=per_layer_points,
                           heterogeneous=hetero,
                           baseline_metrics=baseline_metrics,
                           objectives=(wl.primary, "power"),
                           primary=wl.primary,
                           surrogate=surrogate_record)
    constraints = {wl.primary: _budget(result, quality_bound)}
    if power_budget is not None:
        constraints["power"] = objectives_mod.AtMost(power_budget)
    result.selected = objectives_mod.select(
        result, constraints, minimize="power", axis="heterogeneous")
    return result
