"""autoAx-style design-space exploration facade (DESIGN.md §2.3).

The paper's workflow — library → Pareto selection → per-layer resilience
sweep → pick the multiplier for the application — as one call, in the
spirit of autoAx (Mrazek et al., 2019: automated search of approximate
circuits for a quality bound):

    result = explore(eval_fn, layer_counts, library,
                     quality_bound=0.01)
    point = select_multiplier(result, max_accuracy_drop=0.01)
    policy = point.policy()          # ship it: policy.to_json()

``explore`` runs the per-layer (Fig. 4) and all-layers (Table II)
sweeps on top of ``repro.approx.resilience`` with a policy-keyed eval
cache, so repeated explorations (and the shared exact baseline) never
re-evaluate the same configuration; backend materialization is cached
per (library, spec) so sweeps share jit traces.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from .layers import ApproxPolicy
from .resilience import (ResilienceRow, all_layers_sweep, can_bank,
                         per_layer_sweep)
from .specs import BackendSpec


@dataclass(frozen=True)
class DesignPoint:
    """One evaluated configuration of the design space."""
    multiplier: str
    layer: str                  # layer name, or "all"
    accuracy: float
    network_rel_power: float
    multiplier_rel_power: float
    mult_share: float
    spec: Optional[BackendSpec] = None
    errors: dict = field(default_factory=dict)

    @staticmethod
    def from_row(r: ResilienceRow) -> "DesignPoint":
        return DesignPoint(
            multiplier=r.multiplier, layer=r.layer, accuracy=r.accuracy,
            network_rel_power=r.network_rel_power,
            multiplier_rel_power=r.multiplier_rel_power,
            mult_share=r.mult_share, spec=r.spec, errors=dict(r.errors))

    def policy(self, base: Optional[BackendSpec] = None) -> ApproxPolicy:
        """Deployable policy for this point: the multiplier everywhere
        ("all"), or only in the swept layer over an exact base."""
        spec = self.spec or BackendSpec(mode="lut",
                                        multiplier=self.multiplier)
        if self.layer == "all":
            return ApproxPolicy(default=spec)
        return ApproxPolicy(default=base or BackendSpec.golden(),
                            overrides=[(self.layer, spec)])

    def to_dict(self) -> dict:
        return {
            "multiplier": self.multiplier, "layer": self.layer,
            "accuracy": self.accuracy,
            "network_rel_power": self.network_rel_power,
            "multiplier_rel_power": self.multiplier_rel_power,
            "mult_share": self.mult_share,
            "spec": self.spec.to_dict() if self.spec else None,
            "errors": dict(self.errors),
        }


def pareto_points(points: list[DesignPoint]) -> list[DesignPoint]:
    """Non-dominated on (accuracy max, network power min), by power.
    Ties on both axes are mutually non-dominating and all kept,
    matching ``ApproxLibrary.pareto_front`` semantics."""
    pts = sorted(points, key=lambda p: (p.network_rel_power, -p.accuracy))
    front: list[DesignPoint] = []
    best_acc = float("-inf")
    i = 0
    while i < len(pts):
        j = i
        power = pts[i].network_rel_power
        while j < len(pts) and pts[j].network_rel_power == power:
            j += 1
        acc_max = pts[i].accuracy
        if acc_max > best_acc:
            front.extend(p for p in pts[i:j] if p.accuracy == acc_max)
            best_acc = acc_max
        i = j
    return front


@dataclass
class ExploreResult:
    baseline_accuracy: float            # exact int8 golden datapath
    all_layers: list[DesignPoint] = field(default_factory=list)
    per_layer: list[DesignPoint] = field(default_factory=list)
    selected: Optional[DesignPoint] = None

    def pareto(self) -> list[DesignPoint]:
        return pareto_points(self.all_layers)

    def within(self, max_accuracy_drop: float) -> list[DesignPoint]:
        floor = self.baseline_accuracy - max_accuracy_drop
        return [p for p in self.all_layers if p.accuracy >= floor]

    def to_json_dict(self) -> dict:
        return {
            "baseline_accuracy": self.baseline_accuracy,
            "all_layers": [p.to_dict() for p in self.all_layers],
            "per_layer": [p.to_dict() for p in self.per_layer],
            "selected": self.selected.to_dict() if self.selected else None,
        }


def _cached_eval(eval_fn: Callable[[ApproxPolicy], float],
                 cache: dict) -> Callable[[ApproxPolicy], float]:
    def run(policy: ApproxPolicy) -> float:
        key = policy.cache_key()
        if key not in cache:
            cache[key] = float(eval_fn(policy))
        return cache[key]
    return run


def _seed_cache(cache: dict, rows: list[ResilienceRow], golden) -> None:
    """Store batched-sweep results under the SAME policy cache keys the
    sequential path would use, so later sequential (or widened)
    explorations over the same cache dict hit instead of re-running."""
    for r in rows:
        if r.spec is None:
            continue
        if r.layer == "all":
            policy = ApproxPolicy(default=r.spec)
        else:
            policy = ApproxPolicy(default=golden,
                                  overrides=[(r.layer, r.spec)])
        cache.setdefault(policy.cache_key(), r.accuracy)


def explore(
    eval_fn: Callable[[ApproxPolicy], float],
    layer_counts: dict[str, int],
    library=None,
    multipliers: Optional[list[str]] = None,
    mode: str = "lut",
    variant: str = "ref",
    quality_bound: Optional[float] = None,
    per_layer: bool = True,
    all_layers: bool = True,
    cache: Optional[dict] = None,
    batch: bool = False,
    sharding=None,
) -> ExploreResult:
    """One-call DSE: baseline + Table II + Fig. 4 sweeps over the
    library's case-study multipliers (or ``multipliers``), with cached
    evaluations.

    Sequential (default) evaluation runs one ``eval_fn`` call per design
    point through a policy-keyed cache: pass the same ``cache`` dict
    across calls to resume or widen an exploration without re-running
    finished points.

    ``batch=True`` switches to the batched resilience engine: the
    multiplier axis is packed into a ``LutBank`` and each sweep runs as
    O(1) compiled programs (`DESIGN.md §2.4`), bit-identical accuracies
    to the sequential path.  Batching needs a
    ``repro.approx.resilience.BankableEval`` (an eval with a traceable
    core) and a bankable datapath (lut family); anything else — legacy
    plain-callable evals, ``mode="lowrank"`` — silently falls back to
    the sequential path, so ``batch=True`` is always safe to request.
    A batched sweep evaluates the whole bank even on a warm cache (it
    is one program, not n lookups) but writes every result back into
    ``cache`` under sequential-compatible keys, so mixed
    batched-then-sequential workflows never re-evaluate.  ``sharding``
    optionally spreads the bank axis across devices
    (``repro.launch.mesh.bank_sharding``).

    If ``quality_bound`` is given, ``result.selected`` is the
    lowest-power all-layers point within that accuracy drop.
    """
    if library is None:
        from repro.core.library import get_default_library
        library = get_default_library()
    if multipliers is None:
        multipliers = [e.name for e in library.case_study_selection()]
    cache = cache if cache is not None else {}
    run = _cached_eval(eval_fn, cache)
    batch = batch and can_bank(eval_fn, mode, variant)

    golden = BackendSpec.golden().materialize()
    baseline = run(ApproxPolicy(default=golden))

    result = ExploreResult(baseline_accuracy=baseline)
    if all_layers:
        rows = all_layers_sweep(eval_fn if batch else run, layer_counts,
                                multipliers, library, mode=mode,
                                variant=variant, batch=batch,
                                sharding=sharding)
        if batch:
            _seed_cache(cache, rows, golden)
        result.all_layers = [DesignPoint.from_row(r) for r in rows]
    if per_layer:
        rows = per_layer_sweep(eval_fn if batch else run, layer_counts,
                               multipliers, library, mode=mode,
                               base=golden, variant=variant, batch=batch,
                               sharding=sharding)
        if batch:
            _seed_cache(cache, rows, golden)
        result.per_layer = [DesignPoint.from_row(r) for r in rows]
    if quality_bound is not None and result.all_layers:
        result.selected = select_multiplier(result, quality_bound)
    return result


def select_multiplier(result: ExploreResult,
                      max_accuracy_drop: float,
                      baseline: Optional[float] = None
                      ) -> Optional[DesignPoint]:
    """The paper's endpoint: the lowest-power circuit whose all-layers
    accuracy stays within ``max_accuracy_drop`` of the golden int8
    baseline.  Returns None when no candidate meets the bound."""
    floor = (baseline if baseline is not None
             else result.baseline_accuracy) - max_accuracy_drop
    ok = [p for p in result.all_layers if p.accuracy >= floor]
    if not ok:
        return None
    return min(ok, key=lambda p: (p.network_rel_power, -p.accuracy))
