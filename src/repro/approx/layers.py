"""Layer-level integration: injection policy + approx dense/conv.

``ApproxPolicy`` maps layer names to ``MatmulBackend``s — the unit of
the paper's resilience analysis ("only one layer was modified and one
type of approximate multiplier was used in each experiment").  Models
route every projection through ``policy.matmul(name, x, w)`` and report
their multiplication counts per layer for the power model.
"""
from __future__ import annotations

import fnmatch
import re
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from .backend import MatmulBackend, backend_matmul


@dataclass
class ApproxPolicy:
    """default backend + per-layer-pattern overrides (fnmatch globs,
    first match wins)."""
    default: MatmulBackend = field(default_factory=MatmulBackend)
    overrides: list[tuple[str, MatmulBackend]] = field(default_factory=list)

    def backend_for(self, name: str) -> MatmulBackend:
        for pat, be in self.overrides:
            if fnmatch.fnmatch(name, pat):
                return be
        return self.default

    def matmul(self, name: str, x: jax.Array, w: jax.Array) -> jax.Array:
        return backend_matmul(x, w, self.backend_for(name))

    def with_override(self, pattern: str, backend: MatmulBackend
                      ) -> "ApproxPolicy":
        return ApproxPolicy(default=self.default,
                            overrides=[(pattern, backend)] + list(self.overrides))


EXACT_POLICY = ApproxPolicy(default=MatmulBackend(mode="f32"))


def dense(policy: ApproxPolicy, name: str, x: jax.Array, w: jax.Array,
          b: Optional[jax.Array] = None) -> jax.Array:
    y = policy.matmul(name, x, w)
    if b is not None:
        y = y + b
    return y


def conv2d(policy: ApproxPolicy, name: str, x: jax.Array, w: jax.Array,
           stride: int = 1, padding: str = "SAME",
           b: Optional[jax.Array] = None) -> jax.Array:
    """NHWC conv via im2col + backend matmul, so the multiplier
    emulation covers convolutions exactly as TFApprox's AxConv2D does.

    x: (B,H,W,Cin), w: (kh,kw,Cin,Cout).
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, Ho, Wo, kh*kw*cin) with feature dim ordered (cin, kh, kw)
    bsz, ho, wo, feat = patches.shape
    # conv_general_dilated_patches yields features ordered as
    # (cin, kh, kw); reorder w to match.
    w2d = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    y = policy.matmul(name, patches.reshape(-1, feat), w2d)
    y = y.reshape(bsz, ho, wo, cout)
    if b is not None:
        y = y + b
    return y


def conv_mult_count(x_shape, w_shape, stride: int = 1) -> int:
    """Number of scalar multiplications in this conv (power model)."""
    bsz, h, w_, cin = x_shape
    kh, kw, _, cout = w_shape
    ho, wo = h // stride, w_ // stride
    return bsz * ho * wo * kh * kw * cin * cout


def dense_mult_count(x_shape, w_shape) -> int:
    m = 1
    for d in x_shape[:-1]:
        m *= d
    k, n = w_shape
    return m * k * n
