"""Layer-level integration: injection policy + approx dense/conv.

``ApproxPolicy`` maps layer-name glob patterns to backends — the unit of
the paper's resilience analysis ("only one layer was modified and one
type of approximate multiplier was used in each experiment").  Models
route every projection through ``policy.matmul(name, x, w)`` and report
their multiplication counts per layer for the power model.

Policy entries may be ``BackendSpec``s (serializable names of a
configuration), the ``MaterializedBackend``s they cache to, or legacy
``MatmulBackend``s.  ``to_json``/``from_json`` round-trip the policy as
specs, so a chosen accelerator configuration ships inside checkpoints
and serve requests (DESIGN.md §2.2); ``materialize`` binds every entry
to a library once so jitted evals share traces.
"""
from __future__ import annotations

import fnmatch
import json
import warnings
from dataclasses import dataclass, field
from typing import Optional, Union

import jax
import jax.numpy as jnp

from .backend import BackendLike, MatmulBackend, as_backend, backend_matmul
from .registry import get_datapath
from .specs import (BackendSpec, LutBank, MaterializedBackend, PolicyBank,
                    canonicalize)


def spec_of(backend: BackendLike) -> BackendSpec:
    """Best-effort serializable spec for any backend handle (legacy
    backends describe themselves via ``MatmulBackend.to_spec``)."""
    if backend is None:
        return BackendSpec()
    if isinstance(backend, BackendSpec):
        return backend
    if isinstance(backend, MaterializedBackend):
        return backend.spec
    if isinstance(backend, MatmulBackend):
        return backend.to_spec()
    raise TypeError(f"not a backend: {type(backend).__name__}")


@dataclass
class ApproxPolicy:
    """default backend + per-layer-pattern overrides (fnmatch globs,
    first match wins)."""
    default: BackendLike = field(default_factory=MatmulBackend)
    overrides: list[tuple[str, BackendLike]] = field(default_factory=list)

    def backend_for(self, name: str) -> BackendLike:
        for pat, be in self.overrides:
            if fnmatch.fnmatch(name, pat):
                return be
        return self.default

    def matmul(self, name: str, x: jax.Array, w: jax.Array) -> jax.Array:
        return backend_matmul(x, w, self.backend_for(name))

    def with_override(self, pattern: str, backend: BackendLike
                      ) -> "ApproxPolicy":
        return ApproxPolicy(default=self.default,
                            overrides=[(pattern, backend)] + list(self.overrides))

    # -- spec-first API -------------------------------------------------
    def materialize(self, library=None) -> "ApproxPolicy":
        """Bind every entry to ``library`` via the materialization cache
        so repeated evals of equal policies share backend objects (and
        therefore jit traces)."""
        def mat(be: BackendLike) -> MaterializedBackend:
            if isinstance(be, MaterializedBackend):
                return be
            if isinstance(be, MatmulBackend):
                # preserve hand-attached arrays instead of rebuilding
                # by multiplier name from the library
                return as_backend(be)
            return spec_of(be).materialize(library)
        return ApproxPolicy(
            default=mat(self.default),
            overrides=[(p, mat(be)) for p, be in self.overrides])

    def cache_key(self) -> tuple:
        """Hashable identity of this policy.  Spec-level (canonicalized
        per datapath) for spec/canonical entries; backends carrying
        hand-attached arrays (which a spec cannot describe) are salted
        with the backend object itself — id-hashed AND kept alive by
        the key, so a recycled id can never alias a stale cache hit."""
        def key_of(be: BackendLike):
            spec = canonicalize(spec_of(be))
            if isinstance(be, MaterializedBackend) and not be.canonical:
                return (spec, be)
            if isinstance(be, MatmulBackend) and (
                    be.lut is not None or be.factors_u is not None):
                return (spec, be)
            return spec
        return (key_of(self.default),
                tuple((p, key_of(be)) for p, be in self.overrides))

    # -- serialization --------------------------------------------------
    def to_json_dict(self) -> dict:
        def ser(be: BackendLike) -> dict:
            unfaithful = (
                (isinstance(be, MaterializedBackend) and not be.canonical)
                or (isinstance(be, MatmulBackend) and (
                    be.lut is not None or be.factors_u is not None)))
            if unfaithful:
                warnings.warn(
                    "serializing a backend with hand-attached arrays by "
                    "its spec; the arrays themselves are not captured — "
                    "deserialization rebuilds from the library by "
                    f"multiplier name ({spec_of(be).multiplier!r})",
                    UserWarning, stacklevel=3)
            return spec_of(be).to_dict()
        return {
            "default": ser(self.default),
            "overrides": [[p, ser(be)] for p, be in self.overrides],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), sort_keys=True)

    @staticmethod
    def from_json_dict(d: dict) -> "ApproxPolicy":
        return ApproxPolicy(
            default=BackendSpec.from_dict(d["default"]),
            overrides=[(p, BackendSpec.from_dict(s))
                       for p, s in d.get("overrides", [])])

    @staticmethod
    def from_json(s: Union[str, dict]) -> "ApproxPolicy":
        if isinstance(s, str):
            s = json.loads(s)
        return ApproxPolicy.from_json_dict(s)


EXACT_POLICY = ApproxPolicy(default=MatmulBackend(mode="f32"))


# ----------------------------------------------------------------------
# Banked (vmapped) evaluation — the batched resilience engine's core
# (DESIGN.md §2.4)
# ----------------------------------------------------------------------
def _bank_lane_backend(lut: jax.Array, bank: LutBank, mode: str,
                       variant: str, mask=None, bits=None,
                       reduce_code=None) -> MaterializedBackend:
    """Backend for ONE vmap lane: a ``mode``-datapath backend whose LUT
    const is a traced ``(256, 256)`` slice of the bank (any datapath
    declaring ``bankable`` consumes ``consts['lut']`` this way).
    ``ste=False`` because banked evaluation is forward-only — routing
    around the custom_vjp wrapper keeps traced consts out of its
    non-differentiable spec argument (the forward math is identical
    either way).

    Width-generic banks (``bank.any_wide``) additionally thread the
    lane's traced ``bits`` (quantization width) and 2W-bit product
    ``mask`` (0 = narrow lane) plus the bank's static reduction tree,
    so one compiled program mixes 8-bit and composed 12/16-bit lanes
    (DESIGN.md §2.6).  Under the ``fused`` variant the lane's traced
    ``reduce_code`` rides along too — the fused composed kernel takes
    the reduction tree as runtime data, which is what lets a
    mixed-reduce bank compile to one program (DESIGN.md §2.10)."""
    dp = get_datapath(mode if variant == "ref" else f"{mode}_{variant}")
    spec = BackendSpec(mode=mode, multiplier="<bank>",
                       block_m=bank.block_m, ste=False, variant=variant)
    consts: dict = {"lut": lut, "block_m": bank.block_m}
    if bank.any_wide:
        from repro.core.families import parse_reduce
        consts.update(composed=True, bits=bits, mask=mask,
                      reduce=parse_reduce(bank.reduce))
        if reduce_code is not None:
            consts["reduce_code"] = reduce_code
    return MaterializedBackend(spec=spec, datapath=dp, consts=consts)


def _check_bank_variant(bank: LutBank, variant: str) -> None:
    """A mixed-reduce bank encodes per-lane shift/add trees, which only
    the runtime-tree fused engines can select inside one program; the
    static-tree variants would silently run every lane under one tree."""
    if bank.is_mixed_reduce and variant != "fused":
        raise ValueError(
            f"bank mixes reduction trees ({sorted(set(bank.reduces))}); "
            f"the {variant!r} variant compiles one static tree — run "
            "mixed-reduce banks under variant='fused'")


def _lane_sharding(sharding):
    """1-D sharding for a wide bank's per-lane aux arrays, derived
    from the bank's (n, 256, 256) sharding (None when not derivable,
    e.g. a non-NamedSharding)."""
    from jax.sharding import NamedSharding
    if isinstance(sharding, NamedSharding):
        from repro.launch.mesh import lane_sharding
        return lane_sharding(sharding)
    return None


def bank_eval(fn, bank: LutBank, *, mode: str = "lut",
              variant: str = "ref",
              base: Optional[BackendLike] = None,
              layer_pattern: Optional[str] = None,
              sharding=None):
    """Evaluate ``fn(policy)`` for every multiplier in ``bank`` in ONE
    compiled program (``jit(vmap(...))`` over the bank axis).

    ``fn`` must be traceable (pure jax: arrays in, arrays out — no
    ``float()``/numpy on traced values).  ``mode``/``variant`` select
    the registered datapath the lanes run through (it must declare
    ``bankable``; see ``repro.approx.resilience.can_bank``).  Lane ``i``
    sees a policy whose swept entry emulates ``bank.names[i]``:

      * ``layer_pattern=None`` — the banked backend is the policy
        default (all-layers sweep, Table II);
      * ``layer_pattern='s1_b0_conv1'`` — only that layer is banked and
        the rest run ``base`` (per-layer sweep, Fig. 4; default golden
        int8).

    The bank axis threads through the model by vmap batching: layers
    before the first banked matmul stay unbatched (computed once and
    shared), everything downstream carries the lane axis.  Under the
    ``pallas`` variant the custom batching rule of
    ``repro.kernels.ops.approx_matmul_lut`` collapses the vmapped LUT
    into the banked kernel, one grid step per multiplier.

    ``sharding`` (an optional ``jax.sharding.Sharding`` for the
    ``(n_mult, 256, 256)`` bank) places lanes across devices; see
    ``repro.launch.mesh.bank_sharding``.  Returns ``fn``'s output
    stacked along a new leading ``n_mult`` axis.
    """
    luts = jnp.asarray(bank.luts)
    if sharding is not None:
        luts = jax.device_put(luts, sharding)
    if layer_pattern is not None and base is None:
        base = BackendSpec.golden().materialize()

    def policy_for(mb):
        if layer_pattern is None:
            return ApproxPolicy(default=mb)
        return ApproxPolicy(default=base,
                            overrides=[(layer_pattern, mb)])

    _check_bank_variant(bank, variant)
    if bank.any_wide:
        # mixed-width bank: per-lane quantization width + product mask
        # (selector + 2W-bit truncation) and reduce code ride the
        # vmapped axis (DESIGN.md §2.6, §2.10)
        bits = jnp.asarray(bank.lane_bits, jnp.int32)
        masks = jnp.asarray(bank.lane_masks, jnp.uint32)
        codes = jnp.asarray(bank.lane_reduce_codes, jnp.int32)
        if sharding is not None:
            aux = _lane_sharding(sharding)
            if aux is not None:
                bits = jax.device_put(bits, aux)
                masks = jax.device_put(masks, aux)
                codes = jax.device_put(codes, aux)

        def lane_w(lut, lane_bits, lane_mask, lane_code):
            mb = _bank_lane_backend(lut, bank, mode, variant,
                                    mask=lane_mask, bits=lane_bits,
                                    reduce_code=lane_code)
            return fn(policy_for(mb))

        return jax.jit(jax.vmap(lane_w))(luts, bits, masks, codes)

    def lane(lut):
        return fn(policy_for(_bank_lane_backend(lut, bank, mode,
                                                variant)))

    return jax.jit(jax.vmap(lane))(luts)


def bank_assignment_overrides(bank: LutBank, luts, assign_row, layers,
                              *, mode: str = "lut", variant: str = "ref",
                              lane_bits=None, lane_masks=None,
                              lane_codes=None
                              ) -> list[tuple[str, MaterializedBackend]]:
    """Traced per-layer policy overrides for ONE lane of a banked
    program: layer ``layers[j]`` runs a backend whose LUT const is the
    gathered slice ``luts[assign_row[j]]``.  ``luts`` / ``assign_row``
    (and, for width-generic banks, ``lane_bits`` / ``lane_masks``) are
    traced arrays; ``bank`` supplies only static metadata (block_m,
    any_wide, reduce).  Shared by ``policy_bank_eval`` (one vmap lane
    per candidate policy) and the continuous-batching serve engine
    (one vmap lane per request slot) — both get O(1) compiled programs
    regardless of how many distinct assignments are in flight."""
    overrides = []
    for j, layer in enumerate(layers):
        lut = jnp.take(luts, assign_row[j], axis=0)       # (256,256)
        if bank.any_wide:
            # width-generic: each layer gathers its multiplier's
            # quantization width + product mask (and, for the fused
            # variant, reduce code) alongside the tile LUT
            # (DESIGN.md §2.6, §2.10)
            mb = _bank_lane_backend(
                lut, bank, mode, variant,
                mask=jnp.take(lane_masks, assign_row[j]),
                bits=jnp.take(lane_bits, assign_row[j]),
                reduce_code=(None if lane_codes is None else
                             jnp.take(lane_codes, assign_row[j], axis=0)))
        else:
            mb = _bank_lane_backend(lut, bank, mode, variant)
        overrides.append((layer, mb))
    return overrides


def policy_for_lane(pbank: PolicyBank, p: int, *, mode: str = "lut",
                    variant: str = "ref",
                    base: Optional[BackendLike] = None) -> ApproxPolicy:
    """The sequential (serializable) policy lane ``p`` of a
    ``policy_bank_eval`` stands for: ``base`` (golden int8 by default)
    everywhere, with layer ``j`` overridden to multiplier
    ``pbank.bank.names[pbank.assign[p, j]]``.  Evaluating this policy
    sequentially is bit-identical to lane ``p`` of the banked program —
    the contract tests and benchmarks assert."""
    base = base if base is not None else BackendSpec.golden().materialize()
    return ApproxPolicy(default=base,
                        overrides=pbank.spec_overrides(p, mode=mode,
                                                       variant=variant))


def policy_bank_eval(fn, pbank: PolicyBank, *, mode: str = "lut",
                     variant: str = "ref",
                     base: Optional[BackendLike] = None,
                     sharding=None, assign_sharding=None):
    """Evaluate ``fn(policy)`` for every *heterogeneous* assignment row
    of ``pbank`` in ONE compiled program (``jit(vmap(...))`` over the
    policy axis) — the per-layer generalization of ``bank_eval``.

    Where ``bank_eval`` lane ``i`` runs ONE multiplier in the swept
    entry, ``policy_bank_eval`` lane ``p`` composes a different
    multiplier per named layer: layer ``j`` gathers its own LUT lane
    ``luts[assign[p, j]]`` from the shared bank, so K heterogeneous
    policies over D distinct multipliers cost one program and D LUTs of
    device memory regardless of K.  Layers not named in ``pbank.layers``
    run ``base`` (default golden int8) unbatched.

    ``fn`` must be traceable (see ``bank_eval``); ``mode``/``variant``
    select the registered datapath, which must declare ``bankable``
    (under the ``pallas`` variant the custom batching rule of
    ``repro.kernels.ops.approx_matmul_lut`` collapses each layer's
    gathered LUT lanes into the banked kernel).  ``sharding``
    optionally places the ``(n_mult, 256, 256)`` bank, and
    ``assign_sharding`` the ``(n_policies, n_layers)`` assignment
    matrix (``repro.launch.mesh.policy_sharding``) — sharding the
    assignment's leading axis makes XLA partition the whole vmapped
    program per policy lane.

    Returns ``fn``'s output stacked along a new leading ``n_policies``
    axis, bit-identical per lane to the sequential evaluation of
    ``policy_for_lane(pbank, p)``.
    """
    luts = jnp.asarray(pbank.bank.luts)
    if sharding is not None:
        luts = jax.device_put(luts, sharding)
    assign = jnp.asarray(pbank.assign, dtype=jnp.int32)
    if assign_sharding is not None:
        assign = jax.device_put(assign, assign_sharding)
    if base is None:
        base = BackendSpec.golden().materialize()
    _check_bank_variant(pbank.bank, variant)
    any_wide = pbank.bank.any_wide
    bank_bits = jnp.asarray(pbank.bank.lane_bits, jnp.int32)
    bank_masks = jnp.asarray(pbank.bank.lane_masks, jnp.uint32)
    bank_codes = jnp.asarray(pbank.bank.lane_reduce_codes, jnp.int32)

    def lane(assign_row):
        overrides = bank_assignment_overrides(
            pbank.bank, luts, assign_row, pbank.layers,
            mode=mode, variant=variant,
            lane_bits=bank_bits if any_wide else None,
            lane_masks=bank_masks if any_wide else None,
            lane_codes=bank_codes if any_wide else None)
        policy = ApproxPolicy(default=base, overrides=overrides)
        return fn(policy)

    return jax.jit(jax.vmap(lane))(assign)


def dense(policy: ApproxPolicy, name: str, x: jax.Array, w: jax.Array,
          b: Optional[jax.Array] = None) -> jax.Array:
    y = policy.matmul(name, x, w)
    if b is not None:
        y = y + b
    return y


def conv2d(policy: ApproxPolicy, name: str, x: jax.Array, w: jax.Array,
           stride: int = 1, padding: str = "SAME",
           b: Optional[jax.Array] = None) -> jax.Array:
    """NHWC conv via im2col + backend matmul, so the multiplier
    emulation covers convolutions exactly as TFApprox's AxConv2D does.

    x: (B,H,W,Cin), w: (kh,kw,Cin,Cout).
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, Ho, Wo, kh*kw*cin) with feature dim ordered (cin, kh, kw)
    bsz, ho, wo, feat = patches.shape
    # conv_general_dilated_patches yields features ordered as
    # (cin, kh, kw); reorder w to match.
    w2d = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    y = policy.matmul(name, patches.reshape(-1, feat), w2d)
    y = y.reshape(bsz, ho, wo, cout)
    if b is not None:
        y = y + b
    return y


def conv_output_size(size: int, kernel: int, stride: int,
                     padding: str) -> int:
    """Spatial output size matching ``jax.lax`` conv semantics."""
    if padding == "SAME":
        return -(-size // stride)                 # ceil(size / stride)
    if padding == "VALID":
        if size < kernel:
            return 0
        return (size - kernel) // stride + 1
    raise ValueError(f"unsupported padding {padding!r}")


def conv_mult_count(x_shape, w_shape, stride: int = 1,
                    padding: str = "SAME") -> int:
    """Number of scalar multiplications in this conv (power model),
    for the output dims ``conv2d`` actually produces."""
    bsz, h, w_, cin = x_shape
    kh, kw, _, cout = w_shape
    ho = conv_output_size(h, kh, stride, padding)
    wo = conv_output_size(w_, kw, stride, padding)
    return bsz * ho * wo * kh * kw * cin * cout


def dense_mult_count(x_shape, w_shape) -> int:
    m = 1
    for d in x_shape[:-1]:
        m *= d
    k, n = w_shape
    return m * k * n
