"""Module-axis approximation: a stable taxonomy over every matmul call
site in ``repro.models`` (DESIGN.md §2.12).

The paper's resilience analysis assigns approximate multipliers per
*layer*; across a 2026 model zoo the natural unit is the *module
family* — "all attention query projections", "all MoE expert FFNs",
"all SSM input projections" — regardless of which block, prefix, or
architecture a call site lives in.  This module provides:

  * ``MODULE_FAMILIES`` + ``module_of(tag)`` — the taxonomy and the
    classifier mapping every layer tag the models emit (``attn.wq``,
    ``moe.shared.wi``, ``mamba.in_proj``, ``s0_b1_conv2``, ...) onto a
    stable family key;
  * ``ModuleMap`` — the per-model binding: which tags exist, which
    family each belongs to, and how many MACs each runs
    (``repro.approx.workload.layer_mult_counts``), with ``lower()``
    translating module-keyed assignments into the per-layer-tag
    assignments the whole PR-3 ``PolicyBank`` machinery understands;
  * ``module_policy_bank`` — packs module-keyed assignments into ONE
    ``PolicyBank`` (disjoint family coverage padded with an exact-LUT
    ``fill``), so mixed-module sweeps run as O(1) banked compiled
    programs via ``policy_bank_eval``, bit-identical to the per-layer
    lowering by construction.

Two taxonomy keys never classify a call site: ``moe.router`` and
``ssm.scan``.  The router einsum and the SSM state scan are exact by
design (``repro.models`` keeps norms/routing/attention-score einsums in
f32 — the paper's scope is multipliers inside projection/conv MACs), so
they are listed for completeness and rejected at lowering time.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Mapping, Optional, Sequence

MODULE_FAMILIES = (
    "attention.q", "attention.k", "attention.v", "attention.o",
    "mlp.up", "mlp.gate", "mlp.down",
    "moe.router", "moe.expert",
    "ssm.scan", "ssm.in_proj", "ssm.out_proj",
    "cross_attention", "conv", "embed", "head",
)

#: Families that name exact (non-approximable) computations: no model
#: emits a matmul call site for them, and ``ModuleMap.lower`` rejects
#: assignments touching them.
EXACT_FAMILIES = ("moe.router", "ssm.scan")

_RESNET_CONV = re.compile(r"^s\d+_b\d+_(conv\d+|proj)$")

_ATTN_LEAF = {"wq": "attention.q", "wk": "attention.k",
              "wv": "attention.v", "wo": "attention.o"}
_MLA_LEAF = {"wdq": "attention.q", "wuq": "attention.q",
             "wqr": "attention.q",
             "wdkv": "attention.k", "wuk": "attention.k",
             "wkr": "attention.k",
             "wuv": "attention.v", "wo": "attention.o"}
_FFN_LEAF = {"wi": "mlp.up", "wg": "mlp.gate", "wo": "mlp.down"}


def module_of(tag: str) -> str:
    """Classify a layer tag into its module family.

    Covers every call-site name the shipped models emit (guarded by a
    counts-vs-``probe_layer_tags`` identity test per architecture);
    unknown tags raise so taxonomy drift fails loudly instead of
    silently landing in the wrong power bucket."""
    if tag == "head":
        return "head"
    if tag == "img_proj":
        return "embed"            # modality projection into the embedding
    if tag == "conv_init" or _RESNET_CONV.match(tag):
        return "conv"
    owner, _, leaf = tag.rpartition(".")
    base = owner.rsplit(".", 1)[-1]   # "enc.attn" -> "attn"
    if base == "xattn":
        return "cross_attention"
    if base == "mamba" and leaf in ("in_proj", "out_proj"):
        return f"ssm.{leaf}"
    if base == "attn" and leaf in _ATTN_LEAF:
        return _ATTN_LEAF[leaf]
    if base == "mla" and leaf in _MLA_LEAF:
        return _MLA_LEAF[leaf]
    if base == "moe" and leaf in _FFN_LEAF:
        return "moe.expert"       # routed expert weights, all projections
    if base in ("ffn", "shared") and leaf in _FFN_LEAF:
        return _FFN_LEAF[leaf]    # dense FFN / DeepSeek shared experts
    raise ValueError(f"unknown layer tag {tag!r}: not covered by the "
                     "module taxonomy (see repro.approx.modules)")


@dataclass(frozen=True)
class ModuleMap:
    """A model's layer tags bound to the module taxonomy.

    ``layers`` fixes the per-layer axis order (the ``PolicyBank.layers``
    every lowered assignment shares); ``layer_module[tag]`` is the
    family; ``layer_counts[tag]`` the MAC count feeding the power /
    area / delay cost axes unchanged."""

    layers: tuple[str, ...]
    layer_module: Mapping[str, str]
    layer_counts: Mapping[str, int]

    @property
    def modules(self) -> tuple[str, ...]:
        """Families present in this model, in first-layer order."""
        return tuple(dict.fromkeys(self.layer_module[l]
                                   for l in self.layers))

    def module_layers(self, family: str) -> tuple[str, ...]:
        return tuple(l for l in self.layers
                     if self.layer_module[l] == family)

    def module_counts(self) -> dict[str, int]:
        """Per-family MAC counts (the module-axis analogue of
        ``layer_counts`` — what the composition stage weighs by)."""
        out: dict[str, int] = {}
        for l in self.layers:
            f = self.layer_module[l]
            out[f] = out.get(f, 0) + int(self.layer_counts[l])
        return out

    def module_shares(self) -> dict[str, float]:
        total = sum(self.layer_counts[l] for l in self.layers)
        return {f: c / total for f, c in self.module_counts().items()}

    def lower(self, module_assignment: Mapping[str, str]
              ) -> dict[str, str]:
        """Module-keyed assignment -> per-layer-tag assignment.

        Keys must be families present in this model; ``EXACT_FAMILIES``
        and absent families raise (an assignment that silently binds
        zero call sites would report golden quality at golden power and
        poison a Pareto front)."""
        present = set(self.modules)
        lowered: dict[str, str] = {}
        for family, mult in module_assignment.items():
            if family in EXACT_FAMILIES:
                raise ValueError(
                    f"module family {family!r} is exact by design "
                    "(no approximate matmul call sites)")
            if family not in present:
                raise ValueError(
                    f"module family {family!r} has no call sites in "
                    f"this model (present: {sorted(present)})")
            for l in self.module_layers(family):
                lowered[l] = mult
        return lowered

    def lower_many(self, assignments: Sequence[Mapping[str, str]]
                   ) -> list[dict[str, str]]:
        return [self.lower(a) for a in assignments]

    @staticmethod
    def from_layer_counts(layer_counts: Mapping[str, int]) -> "ModuleMap":
        layers = tuple(layer_counts)
        return ModuleMap(
            layers=layers,
            layer_module={l: module_of(l) for l in layers},
            layer_counts={l: int(layer_counts[l]) for l in layers})

    @staticmethod
    def for_config(cfg, batch: int = 1, seq_len: int = 16,
                   validate: bool = True) -> "ModuleMap":
        """Build the map for a ``ResNetConfig`` or any ``LMConfig``
        from the unified MAC accounting.  ``validate=True`` (LM
        configs) abstractly traces one prefill (``probe_layer_tags``,
        no FLOPs) and asserts the counted tags are exactly the call
        sites the model hits — the drift guard between the analytic
        counts and the real forward."""
        from .workload import layer_mult_counts
        counts = layer_mult_counts(cfg, batch=batch, seq_len=seq_len)
        if validate and not hasattr(cfg, "widths"):
            import jax

            from repro.models.registry import model_fns, probe_layer_tags
            fns = model_fns(cfg)
            params = jax.eval_shape(
                lambda k: fns.init_params(k, cfg), jax.random.PRNGKey(0))
            tags = set(probe_layer_tags(cfg, params))
            if tags != set(counts):
                raise AssertionError(
                    f"MAC accounting drift for {cfg.name}: counted "
                    f"{sorted(set(counts) - tags)} not hit by the "
                    f"forward; hit {sorted(tags - set(counts))} not "
                    "counted")
        return ModuleMap.from_layer_counts(counts)


#: The exact 8-bit LUT row: bit-identical to the golden int8 datapath
#: (it tabulates the same products), so padding a partial lowered row
#: with it keeps the lane equal to the sequential golden-base policy.
FILL_EXACT = "mul8u_exact"


def module_policy_bank(mmap: ModuleMap,
                       module_assignments: Sequence[Mapping[str, str]],
                       library=None, fill: str = FILL_EXACT,
                       block_m: int = 512):
    """Pack module-keyed assignments into ONE ``PolicyBank`` over the
    full per-layer axis (rows padded with ``fill`` where a family
    leaves tags unassigned).  Returns ``(pbank, lowered)`` where
    ``lowered[i]`` is the per-layer dict row ``i`` stands for —
    evaluate with ``repro.approx.layers.policy_bank_eval`` for the O(1)
    banked program, or ``policy_for_lane`` sequentially."""
    from .specs import PolicyBank
    lowered = mmap.lower_many(module_assignments)
    pbank = PolicyBank.from_assignments(
        lowered, library, layers=mmap.layers, block_m=block_m, fill=fill)
    return pbank, lowered


def module_sweep_assignments(mmap: ModuleMap,
                             multipliers: Sequence[str],
                             families: Optional[Sequence[str]] = None
                             ) -> list[tuple[str, str, dict[str, str]]]:
    """The single-family sweep grid: ``(family, multiplier,
    {family: multiplier})`` for every present family x multiplier —
    the module-axis analogue of the paper's Fig. 4 per-layer sweep."""
    fams = tuple(families) if families is not None else mmap.modules
    return [(f, m, {f: m}) for f in fams for m in multipliers]
