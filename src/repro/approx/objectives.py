"""Objective registry: named DSE axes with direction and provenance
(DESIGN.md §2.7).

The paper's library "forms Pareto fronts with respect to several error
metrics, power consumption and other circuit parameters" — an axis of a
design-space exploration is therefore a *named* quantity with a
direction (maximize or minimize) and a provenance:

  * ``workload`` — measured by running the model (accuracy, logit MAE,
    perplexity, ...; a ``repro.approx.workload.Workload`` registers its
    metrics here when constructed).  Surrogate/predicted metrics (the
    ApproxGNN discipline) register exactly the same way — provenance is
    a label, not a dispatch mechanism, so predicted axes slot in where
    measured ones go.
  * ``cost`` — derived from the library's gate-level cost model
    (``power``, ``area``, ``delay``; DESIGN.md §4.4), threaded onto
    design points by the resilience sweeps.
  * ``library`` — the library's circuit-level error statistics
    (``er``/``mae``/``mse``/``mre``/``wce``/``wcre``, paper Sec. II-A),
    read off the design point's ``errors`` dict.

``pareto_points`` computes the non-dominated front over ANY tuple of
registered axes (N-dimensional); for the legacy 2-axis
``("accuracy", "power")`` case it is bit-identical — values AND order —
to the historical accuracy-max/power-min sweep in ``repro.approx.dse``.
``select`` is the declarative endpoint:

    select(result, constraints={"accuracy": MaxDrop(0.01)},
           minimize="power")

Everything here is duck-typed over design points (``metrics``/
``costs``/``errors`` dicts plus the legacy ``accuracy``/
``network_rel_power`` scalars), so it imports nothing from the DSE
layer and surrogate result types can participate unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping, Optional, Sequence, Union

DIRECTIONS = ("max", "min")
SOURCES = ("workload", "cost", "library")


class UnknownObjectiveError(KeyError):
    """Objective name not in the registry (carries the known names)."""

    def __init__(self, name: str):
        self.name = name
        super().__init__(
            f"unknown objective {name!r}; registered axes: "
            f"{available_objectives()} — workload metrics register "
            "automatically when the Workload is constructed, or call "
            "repro.approx.objectives.ensure_objective(name, direction)")

    def __str__(self) -> str:  # KeyError.__str__ would repr() the message
        return self.args[0]


@dataclass(frozen=True)
class Objective:
    """One named DSE axis.

    ``getter`` extracts the axis value from a design point; it is the
    FALLBACK — a value measured into the point's ``metrics`` dict under
    this name always wins (see ``value_of``), which is how a workload
    metric that shadows a library statistic name stays the measured
    quantity."""

    name: str
    direction: str                       # "max" | "min"
    source: str                          # "workload" | "cost" | "library"
    getter: Optional[Callable[[Any], float]] = None

    def __post_init__(self):
        if self.direction not in DIRECTIONS:
            raise ValueError(f"direction must be one of {DIRECTIONS}, "
                             f"got {self.direction!r}")
        if self.source not in SOURCES:
            raise ValueError(f"source must be one of {SOURCES}, "
                             f"got {self.source!r}")

    @property
    def sign(self) -> float:
        """Multiplier turning the axis into minimize-convention."""
        return 1.0 if self.direction == "min" else -1.0


_REGISTRY: dict[str, Objective] = {}


def register_objective(obj: Objective, overwrite: bool = False) -> Objective:
    if not overwrite and obj.name in _REGISTRY:
        existing = _REGISTRY[obj.name]
        if existing.direction != obj.direction:
            raise ValueError(
                f"objective {obj.name!r} already registered with "
                f"direction {existing.direction!r} (tried "
                f"{obj.direction!r}); pass overwrite=True to replace")
        return existing
    _REGISTRY[obj.name] = obj
    return obj


def ensure_objective(name: str, direction: str,
                     source: str = "workload") -> Objective:
    """Idempotent registration — the hook Workload adapters (and
    surrogate models) use to declare their metric axes.  Re-ensuring
    with a conflicting direction raises; a matching one is a no-op."""
    return register_objective(Objective(name=name, direction=direction,
                                        source=source))


def get_objective(name: str) -> Objective:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownObjectiveError(name) from None


def available_objectives() -> list[str]:
    return sorted(_REGISTRY)


def value_of(point: Any, name: str) -> float:
    """Extract axis ``name`` from a design point.

    Resolution order: (1) the point's workload-measured ``metrics``
    dict (a measured value always wins), (2) the registered objective's
    source-specific getter.  Raises ``UnknownObjectiveError`` for
    unregistered names and a descriptive ``KeyError`` when the point
    simply does not carry the axis."""
    metrics = getattr(point, "metrics", None)
    if metrics and name in metrics:
        return float(metrics[name])
    obj = get_objective(name)
    if obj.getter is None:
        raise KeyError(
            f"objective {obj.name!r} ({obj.source}) was not measured "
            f"into this point's metrics ({sorted(metrics or {})}) and "
            "has no derived getter")
    return float(obj.getter(point))


# ----------------------------------------------------------------------
# Built-in axes
# ----------------------------------------------------------------------
def _accuracy_getter(point):
    metrics = getattr(point, "metrics", None)
    if metrics:
        # the point WAS measured, by a workload that produced no
        # "accuracy" metric — its scalar ``accuracy`` column aliases a
        # DIFFERENT (possibly minimize-direction) primary, and reading
        # it as accuracy-max would silently invert the axis
        raise KeyError(
            "'accuracy' was not among this point's measured metrics "
            f"({sorted(metrics)}); name the workload's own metrics as "
            "objectives instead")
    # pre-§2.7 points (no metrics dict) carry accuracy in the scalar
    return point.accuracy


def _power_getter(point):
    return point.network_rel_power


def _cost_getter(name: str):
    def get(point):
        costs = getattr(point, "costs", None) or {}
        if name not in costs:
            raise KeyError(
                f"cost axis {name!r} is not on this point (has "
                f"{sorted(costs)}); area/delay are threaded by the "
                "resilience sweeps — points built by hand or loaded "
                "from pre-§2.7 JSON lack them")
        return costs[name]
    return get


def _library_getter(name: str):
    def get(point):
        errors = getattr(point, "errors", None) or {}
        if name not in errors:
            raise KeyError(
                f"library error statistic {name!r} is not on this "
                f"point (has {sorted(errors)}); heterogeneous points "
                "mix circuits and carry no single-circuit error stats")
        return errors[name]
    return get


register_objective(Objective("accuracy", "max", "workload",
                             getter=_accuracy_getter))
register_objective(Objective("power", "min", "cost", getter=_power_getter))
register_objective(Objective("area", "min", "cost",
                             getter=_cost_getter("area")))
register_objective(Objective("delay", "min", "cost",
                             getter=_cost_getter("delay")))
for _stat in ("er", "mae", "mse", "mre", "wce", "wcre"):
    register_objective(Objective(_stat, "min", "library",
                                 getter=_library_getter(_stat)))


# ----------------------------------------------------------------------
# N-dimensional Pareto front
# ----------------------------------------------------------------------
def _resolve(objectives) -> list[Objective]:
    out = []
    for o in objectives:
        out.append(o if isinstance(o, Objective) else get_objective(o))
    if not out:
        raise ValueError("need at least one objective")
    return out


def pareto_points(points: Sequence[Any],
                  objectives: Sequence[Union[str, Objective]] = (
                      "accuracy", "power")) -> list:
    """Non-dominated subset of ``points`` over named ``objectives``.

    Dominance is the standard weak form: ``q`` dominates ``p`` when it
    is at least as good on every axis and strictly better on one, each
    axis compared in its registered direction.  Ties on ALL axes are
    mutually non-dominating and all kept.

    The returned front is ordered by the signed axis values from the
    LAST objective to the first — for the legacy 2-axis
    ``("accuracy", "power")`` call this is (power ascending, accuracy
    descending), bit-identical (membership AND order) to the historical
    sweep in ``repro.approx.dse.pareto_points``.  Complexity is
    O(n² · k); sweep fronts are hundreds of points, not millions.
    """
    objs = _resolve(objectives)
    pts = list(points)
    vals = [tuple(o.sign * value_of(p, o.name) for o in objs)
            for p in pts]

    def dominated(i: int) -> bool:
        vi = vals[i]
        for j, vj in enumerate(vals):
            if j == i:
                continue
            if all(a <= b for a, b in zip(vj, vi)) \
                    and any(a < b for a, b in zip(vj, vi)):
                return True
        return False

    front = [i for i in range(len(pts)) if not dominated(i)]
    front.sort(key=lambda i: tuple(reversed(vals[i])))
    return [pts[i] for i in front]


# ----------------------------------------------------------------------
# Declarative selection
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class MaxDrop:
    """Within ``drop`` of the exploration's baseline value for the
    axis, in the axis's own direction: a max-axis value may fall at
    most ``drop`` below the baseline, a min-axis value may rise at most
    ``drop`` above it (the paper's accuracy-budget constraint,
    generalized)."""
    drop: float


@dataclass(frozen=True)
class AtLeast:
    bound: float


@dataclass(frozen=True)
class AtMost:
    bound: float


Constraint = Union[MaxDrop, AtLeast, AtMost, float, int]


def _baseline_value(result, name: str) -> float:
    if result is None:
        raise ValueError(
            f"MaxDrop({name!r}) is relative to an exploration baseline "
            "— pass the ExploreResult (satisfies(..., result=...)) or "
            "use the absolute AtLeast/AtMost constraints")
    baseline = getattr(result, "baseline_metrics", None) or {}
    if name in baseline:
        return float(baseline[name])
    primary = getattr(result, "primary", "accuracy")
    if name in ("accuracy", primary):
        return float(result.baseline_accuracy)
    if name == "power":
        return 1.0          # golden datapath power, by convention
    raise ValueError(
        f"MaxDrop({name!r}) needs a baseline value, but the result's "
        f"baseline_metrics has only {sorted(baseline)} — use "
        "AtLeast/AtMost for axes the baseline run does not measure")


def satisfies(point: Any, name: str, constraint: Constraint,
              result=None) -> bool:
    """True when ``point`` meets ``constraint`` on axis ``name``.  A
    bare number is shorthand for ``MaxDrop(number)``."""
    if isinstance(constraint, (int, float)):
        constraint = MaxDrop(float(constraint))
    v = value_of(point, name)
    if isinstance(constraint, AtLeast):
        return v >= constraint.bound
    if isinstance(constraint, AtMost):
        return v <= constraint.bound
    if isinstance(constraint, MaxDrop):
        base = _baseline_value(result, name)
        if get_objective(name).direction == "max":
            return v >= base - constraint.drop
        return v <= base + constraint.drop
    raise TypeError(f"not a constraint: {constraint!r}")


def select(result, constraints: Optional[Mapping[str, Constraint]] = None,
           minimize: Optional[str] = None,
           maximize: Optional[str] = None,
           axis: str = "combined"):
    """Declarative DSE endpoint over an ``ExploreResult``-shaped object:
    among the points of ``axis`` ("all_layers", "per_layer",
    "heterogeneous", or "combined" = uniform ∪ heterogeneous) that
    satisfy every constraint, the one optimizing ``minimize`` /
    ``maximize`` (exactly one must be given).  Ties break toward better
    constraint-axis values in declaration order — with
    ``constraints={"accuracy": MaxDrop(d)}, minimize="power"`` this
    reproduces the paper's ``select_multiplier`` endpoint exactly.
    Returns ``None`` when no point qualifies.
    """
    if (minimize is None) == (maximize is None):
        raise ValueError("pass exactly one of minimize= / maximize=")
    target = get_objective(minimize if minimize is not None else maximize)
    sign = 1.0 if minimize is not None else -1.0
    constraints = dict(constraints or {})
    for name in constraints:
        get_objective(name)             # fail fast on unknown axes

    if axis == "combined":
        points = list(result.all_layers) + list(result.heterogeneous)
    else:
        points = list(getattr(result, axis))
    ok = [p for p in points
          if all(satisfies(p, n, c, result)
                 for n, c in constraints.items())]
    if not ok:
        return None

    tie_axes = [get_objective(n) for n in constraints if n != target.name]

    def key(p):
        return ((sign * value_of(p, target.name),)
                + tuple(o.sign * value_of(p, o.name) for o in tie_axes))

    return min(ok, key=key)
