"""Power model: relative multiplier power aggregated over a network.

The paper reports "power consumption of multipliers in convolutional
layers" relative to the exact 8-bit datapath (Table II / Fig. 4).  Given
per-layer multiplication counts and the per-layer multiplier assignment,
the relative power is the count-weighted mean of the multipliers'
relative powers.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class LayerPower:
    name: str
    mult_count: int
    multiplier: str
    rel_power: float


def network_relative_power(layers: list[LayerPower]) -> float:
    total = sum(l.mult_count for l in layers)
    if total == 0:
        return 1.0
    return sum(l.mult_count * l.rel_power for l in layers) / total


def per_layer_share(layers: list[LayerPower]) -> dict[str, float]:
    total = sum(l.mult_count for l in layers)
    return {l.name: l.mult_count / total for l in layers}
