"""Power model: relative multiplier power aggregated over a network.

The paper reports "power consumption of multipliers in convolutional
layers" relative to the exact 8-bit datapath (Table II / Fig. 4).  Given
per-layer multiplication counts and the per-layer multiplier assignment,
the relative power is the count-weighted mean of the multipliers'
relative powers.

``network_power_for_assignment`` is the heterogeneous-composition entry
point (DESIGN.md §2.5): it scores an arbitrary layer-name -> multiplier
mapping, which is how both the per-layer resilience rows (a one-layer
assignment) and the heterogeneous DSE (a full assignment) account power
through ONE code path.

Cross-width accounting (DESIGN.md §2.6): ``rel_power`` in the library
is *same-width* relative (a 16-bit entry's power over the exact 16-bit
multiplier) — the paper's Table II convention.  Mixed-width sweeps need
a COMMON reference, so ``rel_power_map(..., ref=...)`` rebases every
entry onto one circuit's absolute 45 nm power (typically
``mul8u_exact``, the golden datapath): a composed 16-bit multiplier
then correctly costs ~4x an 8-bit one (four tiles + the reduction
tree) instead of looking same-priced.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping, Optional


@dataclass(frozen=True)
class LayerPower:
    name: str
    mult_count: int
    multiplier: str
    rel_power: float


def network_relative_power(layers: list[LayerPower]) -> float:
    total = sum(l.mult_count for l in layers)
    if total == 0:
        return 1.0
    return sum(l.mult_count * l.rel_power for l in layers) / total


def per_layer_share(layers: list[LayerPower]) -> dict[str, float]:
    total = sum(l.mult_count for l in layers)
    if total == 0:
        # mirror network_relative_power's zero-mult guard: no
        # multiplications means no layer owns a share of them
        return {l.name: 0.0 for l in layers}
    return {l.name: l.mult_count / total for l in layers}


def rel_power_map(library, names,
                  ref: Optional[str] = None) -> dict[str, float]:
    """Per-multiplier relative power for a candidate set.

    ``ref=None`` reads the library's same-width ``rel_power`` (the
    paper's convention — correct for single-width sweeps).  With
    ``ref`` set (e.g. ``"mul8u_exact"``), every entry is rebased onto
    that circuit's absolute 45 nm power, making MIXED-WIDTH candidate
    sets comparable on one axis: ``power(name) / power(ref)``.
    Raises ``UnknownCircuitError`` on missing names.
    """
    if ref is None:
        return {n: library.entry(n).rel_power for n in names}
    ref_power = library.entry(ref).cost.power
    if ref_power <= 0:
        raise ValueError(f"reference circuit {ref!r} has no power")
    return {n: library.entry(n).cost.power / ref_power for n in names}


def auto_rel_power(library, names) -> Optional[dict[str, float]]:
    """Default power map for a candidate set: None for single-width
    sets (the library's same-width convention applies), a
    common-reference ``rel_power_map`` for MIXED-width sets — without
    this, a 16-bit entry's rel_power (vs exact *16-bit*) would be
    silently compared against 8-bit entries' (vs exact 8-bit) and a
    ~5x-more-expensive circuit could win "lowest power".  The
    reference is the narrowest width's exact multiplier; raises when
    the library lacks it (pass an explicit ``rel_power`` then).
    """
    widths = {library.entry(n).width for n in names}
    if len(widths) <= 1:
        return None
    ref = f"mul{min(widths)}u_exact"
    if ref not in library.entries:
        raise ValueError(
            f"mixed-width candidate set (widths {sorted(widths)}) "
            f"needs a common power reference, but {ref!r} is not in "
            "the library — pass rel_power=rel_power_map(library, "
            "names, ref=<your reference circuit>)")
    return rel_power_map(library, names, ref=ref)


COST_AXES = ("area", "delay")


def cost_axes_map(library, names) -> dict[str, dict[str, float]]:
    """Per-multiplier relative AREA and DELAY for a candidate set — the
    library-derived cost axes beyond power (DESIGN.md §2.7, the paper's
    "other circuit parameters").

    Each entry is normalized against the exact multiplier of ITS OWN
    width (``mul{W}u_exact``), mirroring the library's same-width
    ``rel_power`` convention; when the library lacks that entry (tiny
    demo libraries, composed widths) the reference cost is synthesized
    from an exact array multiplier of that width — the same fallback
    ``ApproxLibrary.add_composed`` uses for ``rel_power`` — so every
    value in one map stays on the same relative scale (never raw
    µm²/ps mixed with ~1.0 ratios).  Resilience sweeps thread these
    onto every row/point so objective tuples like
    ``("accuracy", "power", "delay")`` resolve without re-touching the
    library."""
    refs: dict[int, Any] = {}
    out: dict[str, dict[str, float]] = {}
    for n in names:
        entry = library.entry(n)
        if entry.width not in refs:
            ref_name = f"mul{entry.width}u_exact"
            if ref_name in library.entries:
                refs[entry.width] = library.entry(ref_name).cost
            else:
                from repro.core.cost import evaluate_cost
                from repro.core.seeds import array_multiplier
                refs[entry.width] = evaluate_cost(
                    array_multiplier(entry.width))
        ref = refs[entry.width]
        out[n] = {
            "area": (entry.cost.area / ref.area if ref.area > 0
                     else entry.cost.area),
            "delay": (entry.cost.delay / ref.delay if ref.delay > 0
                      else entry.cost.delay),
        }
    return out


def network_costs_for_assignment(
    layer_counts: Mapping[str, int],
    assignment: Mapping[str, str],
    cost_map: Mapping[str, Mapping[str, float]],
    base: Optional[Mapping[str, float]] = None,
) -> dict[str, float]:
    """Network-level area/delay of a heterogeneous assignment, through
    the same one-code-path discipline as
    ``network_power_for_assignment``: AREA aggregates like power (the
    count-weighted mean over layers, unassigned layers at the exact
    datapath's 1.0), DELAY is the critical path — the MAX over the
    datapaths in use (an accelerator's multiplier array clocks at its
    slowest circuit)."""
    base = dict(base) if base is not None else {a: 1.0 for a in COST_AXES}
    layers, delays = [], []
    for name, count in layer_counts.items():
        if name in assignment:
            c = cost_map[assignment[name]]
            layers.append(LayerPower(name, count, assignment[name],
                                     c["area"]))
            delays.append(c["delay"])
        else:
            layers.append(LayerPower(name, count, "exact", base["area"]))
            delays.append(base["delay"])
    # the exact datapath's delay only bounds the path when some layer
    # actually runs it; a fully-assigned network clocks at its own
    # slowest circuit, which may beat the exact multiplier
    return {"area": network_relative_power(layers),
            "delay": max(delays, default=base["delay"])}


def network_power_for_assignment(
    layer_counts: Mapping[str, int],
    assignment: Mapping[str, str],
    rel_power: Mapping[str, float],
    base_multiplier: str = "exact",
    base_rel_power: float = 1.0,
) -> float:
    """Count-weighted network power of a heterogeneous assignment.

    ``assignment`` maps layer names to multiplier names and may cover
    any subset of ``layer_counts``; unassigned layers run the base
    (exact) datapath at ``base_rel_power``.  ``rel_power`` maps each
    assigned multiplier name to its relative power (e.g.
    ``{e.name: e.rel_power for e in library.entries.values()}``).
    """
    layers = []
    for name, count in layer_counts.items():
        if name in assignment:
            mult = assignment[name]
            layers.append(LayerPower(name, count, mult, rel_power[mult]))
        else:
            layers.append(LayerPower(name, count, base_multiplier,
                                     base_rel_power))
    return network_relative_power(layers)


def grouped_mult_counts(layer_counts: Mapping[str, int],
                        groups: Mapping[str, str]) -> dict[str, int]:
    """Aggregate per-layer MAC counts by a group key — e.g. module
    families via ``repro.approx.modules.ModuleMap.layer_module``
    (DESIGN.md §2.12).  Grouped counts drop into the same
    ``network_power_for_assignment`` / ``LayerComponents`` arithmetic
    as per-layer counts: power is linear in counts, so summing within
    a group before weighting is exact."""
    out: dict[str, int] = {}
    for layer, count in layer_counts.items():
        g = groups[layer]
        out[g] = out.get(g, 0) + int(count)
    return out
