"""Power model: relative multiplier power aggregated over a network.

The paper reports "power consumption of multipliers in convolutional
layers" relative to the exact 8-bit datapath (Table II / Fig. 4).  Given
per-layer multiplication counts and the per-layer multiplier assignment,
the relative power is the count-weighted mean of the multipliers'
relative powers.

``network_power_for_assignment`` is the heterogeneous-composition entry
point (DESIGN.md §2.5): it scores an arbitrary layer-name -> multiplier
mapping, which is how both the per-layer resilience rows (a one-layer
assignment) and the heterogeneous DSE (a full assignment) account power
through ONE code path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping


@dataclass(frozen=True)
class LayerPower:
    name: str
    mult_count: int
    multiplier: str
    rel_power: float


def network_relative_power(layers: list[LayerPower]) -> float:
    total = sum(l.mult_count for l in layers)
    if total == 0:
        return 1.0
    return sum(l.mult_count * l.rel_power for l in layers) / total


def per_layer_share(layers: list[LayerPower]) -> dict[str, float]:
    total = sum(l.mult_count for l in layers)
    if total == 0:
        # mirror network_relative_power's zero-mult guard: no
        # multiplications means no layer owns a share of them
        return {l.name: 0.0 for l in layers}
    return {l.name: l.mult_count / total for l in layers}


def network_power_for_assignment(
    layer_counts: Mapping[str, int],
    assignment: Mapping[str, str],
    rel_power: Mapping[str, float],
    base_multiplier: str = "exact",
    base_rel_power: float = 1.0,
) -> float:
    """Count-weighted network power of a heterogeneous assignment.

    ``assignment`` maps layer names to multiplier names and may cover
    any subset of ``layer_counts``; unassigned layers run the base
    (exact) datapath at ``base_rel_power``.  ``rel_power`` maps each
    assigned multiplier name to its relative power (e.g.
    ``{e.name: e.rel_power for e in library.entries.values()}``).
    """
    layers = []
    for name, count in layer_counts.items():
        if name in assignment:
            mult = assignment[name]
            layers.append(LayerPower(name, count, mult, rel_power[mult]))
        else:
            layers.append(LayerPower(name, count, base_multiplier,
                                     base_rel_power))
    return network_relative_power(layers)
