"""Per-architecture module-resilience profiles (DESIGN.md §2.12).

The paper's Table II asks, for one CNN, "which layers tolerate which
approximate multipliers".  ``profile_architecture`` asks the 2026
model-zoo version: for each *module family* of an architecture
(attention q/k/v/o, MLP up/gate/down, MoE experts, SSM projections,
cross-attention, conv, ...), how much quality does each library
multiplier cost, and what is the cheapest per-module composition that
stays inside a declarative ``MaxDrop`` bound?

Pipeline (all exact measurements — no surrogate here):

  1. baseline: the workload on the golden int8 datapath;
  2. module sweep: every ``(family, multiplier)`` single-family
     assignment, lowered through ``ModuleMap.lower`` and evaluated as
     ONE ``policy_bank_eval`` program (``verify_assignments`` with the
     full tag axis + exact-LUT ``fill``) — O(1) compiled programs per
     sweep, bit-identical to sequential golden-base policies;
  3. ranking: families ordered most- to least-tolerant by mean
     direction-aware quality drop across the library;
  4. selection: the sweep rows distill into module-level
     ``LayerComponents`` (families as "layers", MAC-weighted), the
     beam composes candidate per-module assignments, uniform rows are
     added, the shortlist is exactly verified in one more banked
     program, and ``objectives.select`` picks the lowest-power point
     under ``MaxDrop(max_drop)`` on the primary metric.

``profile_zoo`` runs this across architectures and
``benchmarks/arch_profiles.py`` publishes the result
(``BENCH_profiles.json`` / EXPERIMENTS.md PROFILES).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence

from .dse import ExploreResult, compose_assignments, verify_assignments
from .layers import ApproxPolicy
from .modules import FILL_EXACT, ModuleMap, module_sweep_assignments
from .objectives import MaxDrop, get_objective, select
from .power import auto_rel_power, rel_power_map
from .resilience import LayerComponents, ResilienceRow
from .specs import BackendSpec
from .workload import Workload, as_workload


@dataclass
class ModuleRow:
    """One module-sweep measurement: ONLY ``module`` runs
    ``multiplier`` (every other call site golden int8)."""
    module: str
    multiplier: str
    quality: float              # primary metric at this point
    quality_drop: float         # direction-aware drop vs baseline, >= 0
    network_rel_power: float
    multiplier_rel_power: float
    mult_share: float           # fraction of network MACs in the family
    metrics: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"module": self.module, "multiplier": self.multiplier,
                "quality": self.quality,
                "quality_drop": self.quality_drop,
                "network_rel_power": self.network_rel_power,
                "multiplier_rel_power": self.multiplier_rel_power,
                "mult_share": self.mult_share,
                "metrics": dict(self.metrics)}

    @staticmethod
    def from_dict(d: dict) -> "ModuleRow":
        return ModuleRow(**d)


@dataclass
class ArchProfile:
    """One architecture's resilience profile over its module families."""
    arch: str
    model_family: str           # dense | moe | ssm | hybrid | encdec |
                                # vlm | resnet
    workload: str
    primary: str
    direction: str
    max_drop: float
    baseline_metrics: dict
    modules: tuple
    module_shares: dict
    rows: list                  # [ModuleRow]
    ranking: tuple              # most -> least tolerant family
    selected: Optional[dict]    # {"modules", "layers", "power",
                                #  "metrics", "quality_drop"}

    def to_dict(self) -> dict:
        return {"arch": self.arch, "model_family": self.model_family,
                "workload": self.workload, "primary": self.primary,
                "direction": self.direction, "max_drop": self.max_drop,
                "baseline_metrics": dict(self.baseline_metrics),
                "modules": list(self.modules),
                "module_shares": dict(self.module_shares),
                "rows": [r.to_dict() for r in self.rows],
                "ranking": list(self.ranking),
                "selected": self.selected}

    @staticmethod
    def from_dict(d: dict) -> "ArchProfile":
        d = dict(d)
        d["rows"] = [ModuleRow.from_dict(r) for r in d["rows"]]
        d["modules"] = tuple(d["modules"])
        d["ranking"] = tuple(d["ranking"])
        return ArchProfile(**d)


def _drop(value: float, baseline: float, direction: str) -> float:
    d = (baseline - value) if direction == "max" else (value - baseline)
    return max(0.0, float(d))


def profile_architecture(
    workload: Workload,
    mmap: ModuleMap,
    library,
    multipliers: Sequence[str],
    *,
    arch: Optional[str] = None,
    model_family: str = "",
    max_drop: float = 0.05,
    mode: str = "lut",
    variant: str = "ref",
    batch: bool = True,
    sharding=None,
    assign_sharding=None,
    beam_width: int = 8,
    top_k: int = 8,
    fill: str = FILL_EXACT,
) -> ArchProfile:
    """Sweep ``multipliers`` over every module family of one model and
    select the cheapest per-module policy under ``MaxDrop(max_drop)``
    on the workload's primary metric.  See the module docstring for the
    pipeline; all measurements are exact."""
    wl = as_workload(workload)
    direction = wl.primary_direction
    golden = ApproxPolicy(default=BackendSpec.golden().materialize())
    baseline = wl.measure(golden)
    base_q = baseline[wl.primary]

    rel_power = (auto_rel_power(library, multipliers)
                 or rel_power_map(library, multipliers))
    shares = mmap.module_shares()

    # -- 2. module sweep: one banked program over the whole grid -------
    grid = module_sweep_assignments(mmap, multipliers)
    points = verify_assignments(
        wl, [mmap.lower(a) for _f, _m, a in grid], mmap.layer_counts,
        library, mode=mode, variant=variant, batch=batch,
        sharding=sharding, assign_sharding=assign_sharding,
        layers=mmap.layers, fill=fill)
    rows = [
        ModuleRow(
            module=f, multiplier=m,
            quality=float(pt.metrics[wl.primary]),
            quality_drop=_drop(pt.metrics[wl.primary], base_q, direction),
            network_rel_power=float(pt.network_rel_power),
            multiplier_rel_power=float(rel_power[m]),
            mult_share=float(shares[f]),
            metrics=dict(pt.metrics))
        for (f, m, _a), pt in zip(grid, points)]

    # -- 3. tolerance ranking ------------------------------------------
    fams = mmap.modules
    mean_drop = {f: sum(r.quality_drop for r in rows if r.module == f)
                 / max(1, sum(1 for r in rows if r.module == f))
                 for f in fams}
    ranking = tuple(sorted(fams, key=lambda f: (mean_drop[f], f)))

    # -- 4. MaxDrop-constrained per-module selection -------------------
    comp_rows = [ResilienceRow(
        multiplier=r.multiplier, layer=r.module, accuracy=r.quality,
        network_rel_power=r.network_rel_power,
        multiplier_rel_power=r.multiplier_rel_power,
        mult_share=r.mult_share, metrics=dict(r.metrics)) for r in rows]
    components = LayerComponents.from_rows(
        comp_rows, mmap.module_counts(), base_q, direction=direction)
    composed = compose_assignments(components, quality_bound=max_drop,
                                   beam_width=beam_width, top_k=top_k)
    candidates = [
        {f: components.multipliers[row[j]]
         for j, f in enumerate(components.layers)} for row in composed]
    candidates += [{f: m for f in fams} for m in multipliers]  # uniforms
    seen: set = set()
    module_assignments = []
    for a in candidates:
        key = tuple(sorted(a.items()))
        if key not in seen:
            seen.add(key)
            module_assignments.append(a)
    verified = verify_assignments(
        wl, mmap.lower_many(module_assignments), mmap.layer_counts,
        library, mode=mode, variant=variant, batch=batch,
        sharding=sharding, assign_sharding=assign_sharding,
        layers=mmap.layers, fill=fill)
    result = ExploreResult(
        baseline_accuracy=base_q, heterogeneous=list(verified),
        baseline_metrics=dict(baseline), primary=wl.primary)
    chosen = select(result, {wl.primary: MaxDrop(max_drop)},
                    minimize="power", axis="heterogeneous")
    selected = None
    if chosen is not None:
        idx = verified.index(chosen)
        selected = {
            "modules": dict(module_assignments[idx]),
            "layers": {l: m for l, m in (chosen.assignment or ())},
            "power": float(chosen.network_rel_power),
            "metrics": dict(chosen.metrics),
            "quality_drop": _drop(chosen.metrics[wl.primary], base_q,
                                  direction),
        }

    get_objective(wl.primary)       # primary registered — fail fast
    return ArchProfile(
        arch=arch or wl.name, model_family=model_family,
        workload=wl.name, primary=wl.primary, direction=direction,
        max_drop=float(max_drop), baseline_metrics=dict(baseline),
        modules=fams, module_shares=shares, rows=rows, ranking=ranking,
        selected=selected)


def profile_zoo(profiles: Mapping[str, ArchProfile]) -> dict:
    """Serialize a zoo of profiles (arch name -> ``ArchProfile``) into
    one JSON-ready record, plus cross-architecture family aggregates
    (mean quality drop per family over every arch that has it)."""
    fam_drops: dict[str, list] = {}
    for p in profiles.values():
        for r in p.rows:
            fam_drops.setdefault(r.module, []).append(r.quality_drop)
    return {
        "archs": {name: p.to_dict() for name, p in profiles.items()},
        "family_mean_drop": {f: sum(v) / len(v)
                             for f, v in fam_drops.items()},
    }
