"""Unsigned affine quantization for approximate-multiplier emulation.

The library's multipliers are *unsigned* W-bit (mul8u/mul12u/mul16u
families), so both operands are quantized asymmetrically to
[0, 2^W - 1]:

    q = clip(round(x / s) + zp, 0, 2^W - 1),      x ≈ s * (q - zp)

and an exact product decomposes as

    (qa - za)(qw - zw) = qa*qw - za*qw - zw*qa + za*zw .

Only the qa*qw term flows through the (approximate) multiplier; the
correction terms are row/column sums computed exactly — this mirrors how
a real accelerator datapath applies zero-point corrections outside the
MAC array, and is exactly how TFApprox composes with TF quantization.

Quantization is *dynamic* per-tensor by default (scales derived from the
tensor inside the jitted computation); static calibrated params can be
passed instead.

``bits`` is width-generic (DESIGN.md §2.6): 8 for the paper's baseline
datapath, 12/16 for composed wide datapaths.  It may be a Python int
(the common, statically-known case) or a traced scalar — mixed-width
LUT banks vmap ``calibrate`` over a per-lane ``bits`` array so one
compiled program quantizes every lane at its own width.  At
``bits=8`` the arithmetic is bit-identical to the historical uint8
path (``qmax = exp2(8) - 1`` is exactly ``255.0`` in float32).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Bits = Union[int, jax.Array]


class QuantParams(NamedTuple):
    scale: jax.Array        # scalar f32
    zero_point: jax.Array   # scalar int32 in [0, qmax]
    qmax: jax.Array = 255.0  # scalar f32, 2^bits - 1


#: Widths a TRACED ``bits`` scalar may take (the bankable datapath
#: widths).  Static Python-int widths are unrestricted.
TRACED_WIDTHS = (8, 12, 16)


def qmax_for(bits: Bits) -> jax.Array:
    """``2^bits - 1`` as an f32 scalar (exact for every width <= 24);
    traceable when ``bits`` is a per-lane scalar in ``TRACED_WIDTHS``."""
    if isinstance(bits, int):
        return jnp.float32((1 << bits) - 1)
    preds = [jnp.asarray(bits) == b for b in TRACED_WIDTHS]
    vals = [jnp.float32((1 << b) - 1) for b in TRACED_WIDTHS]
    return jnp.select(preds, vals, vals[-1])


def calibrate(x: jax.Array, bits: Bits = 8,
              eps: float = 1e-8) -> QuantParams:
    """Min/max affine calibration to the full unsigned ``bits`` range.

    A traced ``bits`` (mixed-width bank lane) selects among
    CONSTANT-divisor scale computations — one per ``TRACED_WIDTHS``
    entry — rather than dividing by a runtime ``qmax``: XLA folds
    division by a compile-time constant differently (reciprocal
    strength reduction) from a runtime division, and the banked engine
    promises every lane is bit-identical to static calibration at that
    lane's width.
    """
    lo = jnp.minimum(jnp.min(x), 0.0).astype(jnp.float32)
    hi = jnp.maximum(jnp.max(x), 0.0).astype(jnp.float32)
    qmax = qmax_for(bits)
    if isinstance(bits, int):
        scale = jnp.maximum((hi - lo) / qmax, eps)
    else:
        scale = jnp.select(
            [jnp.asarray(bits) == b for b in TRACED_WIDTHS],
            [jnp.maximum((hi - lo) / jnp.float32((1 << b) - 1), eps)
             for b in TRACED_WIDTHS],
            jnp.maximum((hi - lo) / jnp.float32(
                (1 << TRACED_WIDTHS[-1]) - 1), eps))
    zp = jnp.clip(jnp.round(-lo / scale), 0, qmax).astype(jnp.int32)
    return QuantParams(scale=scale, zero_point=zp, qmax=qmax)


def scalar_params(qp_a: QuantParams, qp_w: QuantParams) -> tuple:
    """The flat ``(sa, za, sw, zw, qmax)`` scalar tuple an operand pair
    hands to the fused kernels (DESIGN.md §2.10) — calibration happens
    OUTSIDE the kernel (cheap min/max over each operand, traced-width
    select included), the per-tile quantize/dequant arithmetic inside.
    Both operands share one ``qmax`` because they share ``bits``.  Each
    scalar batches independently under ``vmap`` (mixed-width banks batch
    every entry; a shared-activation bank batches only the weight-side
    pair), which is what lets the fused ops' bank-collapse rules keep
    shared operands unbatched."""
    return (qp_a.scale, qp_a.zero_point, qp_w.scale, qp_w.zero_point,
            qp_a.qmax)


def quantize(x: jax.Array, qp: QuantParams) -> jax.Array:
    q = jnp.round(x.astype(jnp.float32) / qp.scale) + qp.zero_point
    return jnp.clip(q, 0, qp.qmax).astype(jnp.int32)


def dequantize(q: jax.Array, qp: QuantParams) -> jax.Array:
    return (q - qp.zero_point).astype(jnp.float32) * qp.scale


def fake_quant(x: jax.Array, qp: Optional[QuantParams] = None,
               bits: Bits = 8) -> jax.Array:
    """Quantize-dequantize round trip (for QAT-style experiments)."""
    qp = qp or calibrate(x, bits=bits)
    return dequantize(quantize(x, qp), qp)
