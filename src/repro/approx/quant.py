"""uint8 affine quantization for approximate-multiplier emulation.

The library's multipliers are *unsigned* 8-bit (mul8u family), so both
operands are quantized asymmetrically to [0, 255]:

    q = clip(round(x / s) + zp, 0, 255),      x ≈ s * (q - zp)

and an exact product decomposes as

    (qa - za)(qw - zw) = qa*qw - za*qw - zw*qa + za*zw .

Only the qa*qw term flows through the (approximate) multiplier; the
correction terms are row/column sums computed exactly — this mirrors how
a real accelerator datapath applies zero-point corrections outside the
MAC array, and is exactly how TFApprox composes with TF quantization.

Quantization is *dynamic* per-tensor by default (scales derived from the
tensor inside the jitted computation); static calibrated params can be
passed instead.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class QuantParams(NamedTuple):
    scale: jax.Array        # scalar f32
    zero_point: jax.Array   # scalar int32 in [0, 255]


def calibrate(x: jax.Array, eps: float = 1e-8) -> QuantParams:
    """Min/max affine calibration to the full uint8 range."""
    lo = jnp.minimum(jnp.min(x), 0.0).astype(jnp.float32)
    hi = jnp.maximum(jnp.max(x), 0.0).astype(jnp.float32)
    scale = jnp.maximum((hi - lo) / 255.0, eps)
    zp = jnp.clip(jnp.round(-lo / scale), 0, 255).astype(jnp.int32)
    return QuantParams(scale=scale, zero_point=zp)


def quantize(x: jax.Array, qp: QuantParams) -> jax.Array:
    q = jnp.round(x.astype(jnp.float32) / qp.scale) + qp.zero_point
    return jnp.clip(q, 0, 255).astype(jnp.int32)


def dequantize(q: jax.Array, qp: QuantParams) -> jax.Array:
    return (q - qp.zero_point).astype(jnp.float32) * qp.scale


def fake_quant(x: jax.Array, qp: Optional[QuantParams] = None) -> jax.Array:
    """Quantize-dequantize round trip (for QAT-style experiments)."""
    qp = qp or calibrate(x)
    return dequantize(quantize(x, qp), qp)
