"""Rank statistics for predicted-vs-measured fidelity (DESIGN.md §2.11).

The surrogate predict stage (autoAx / ApproxGNN discipline) is judged
by how well it RANKS candidates, not by absolute error: the beam only
consumes orderings, so the fidelity gates report Spearman's rho and
Kendall's tau between predicted and measured quality — the evaluation
protocol both follow-up papers use.  One shared implementation serves
the surrogate fidelity gates (``benchmarks/dse_surrogate.py``), the
library rank analyses (``benchmarks/rank_analysis.py``), and the unit
tests (validated against scipy on small cases).

All functions are tie-aware: ranks are midranks (average of the
positions a tied group spans, scipy's ``rankdata(method="average")``),
Spearman is the Pearson correlation of midranks, and Kendall is
tau-b (tie-corrected denominator).  Constant inputs have no defined
correlation; both return ``nan`` then (scipy's convention) — callers
gating on a correlation should filter or map those explicitly.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def rankdata(values: Sequence[float]) -> np.ndarray:
    """Midranks (1-based): ties share the average of the positions
    they span — ``rankdata([10, 20, 20, 30]) == [1, 2.5, 2.5, 4]``."""
    v = np.asarray(values, dtype=np.float64)
    if v.ndim != 1:
        raise ValueError(f"rankdata expects a 1-d array, got {v.shape}")
    order = np.argsort(v, kind="stable")
    ranks = np.empty(v.size, dtype=np.float64)
    i = 0
    while i < v.size:
        j = i
        while j + 1 < v.size and v[order[j + 1]] == v[order[i]]:
            j += 1
        ranks[order[i:j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    return ranks


def _as_pair(x, y) -> tuple[np.ndarray, np.ndarray]:
    xa = np.asarray(x, dtype=np.float64).reshape(-1)
    ya = np.asarray(y, dtype=np.float64).reshape(-1)
    if xa.size != ya.size:
        raise ValueError(f"length mismatch: {xa.size} vs {ya.size}")
    return xa, ya


def spearman(x, y) -> float:
    """Spearman's rho: Pearson correlation of midranks.  ``nan`` when
    either input is constant (or shorter than 2) — there is no
    ordering to correlate then."""
    xa, ya = _as_pair(x, y)
    if xa.size < 2:
        return float("nan")
    rx, ry = rankdata(xa), rankdata(ya)
    sx, sy = rx.std(), ry.std()
    if sx == 0.0 or sy == 0.0:
        return float("nan")
    return float(((rx - rx.mean()) * (ry - ry.mean())).mean() / (sx * sy))


def kendall(x, y) -> float:
    """Kendall's tau-b (tie-corrected): (concordant − discordant) /
    sqrt((n0 − tx)(n0 − ty)) over all pairs.  O(n²) — fidelity gates
    correlate tens-to-hundreds of candidates, not millions.  ``nan``
    when either input is constant."""
    xa, ya = _as_pair(x, y)
    n = xa.size
    if n < 2:
        return float("nan")
    dx = np.sign(xa[:, None] - xa[None, :])
    dy = np.sign(ya[:, None] - ya[None, :])
    iu = np.triu_indices(n, k=1)
    sx, sy = dx[iu], dy[iu]
    concordant_minus_discordant = float((sx * sy).sum())
    n0 = n * (n - 1) / 2.0
    tx = float((sx == 0).sum())
    ty = float((sy == 0).sum())
    denom = np.sqrt((n0 - tx) * (n0 - ty))
    if denom == 0.0:
        return float("nan")
    return concordant_minus_discordant / denom


def per_layer_spearman(predicted: np.ndarray, measured: np.ndarray,
                       layers: Sequence[str]) -> dict[str, float]:
    """Row-wise Spearman between two (n_layers, n_candidates) quality
    matrices, keyed by layer name — the per-layer fidelity report of
    the surrogate gates (ApproxGNN's evaluation protocol).  Layers
    whose measured column is constant come back ``nan``."""
    p = np.asarray(predicted, dtype=np.float64)
    m = np.asarray(measured, dtype=np.float64)
    if p.shape != m.shape or p.shape[0] != len(layers):
        raise ValueError(
            f"shape mismatch: predicted {p.shape}, measured {m.shape}, "
            f"{len(layers)} layers")
    return {name: spearman(p[j], m[j]) for j, name in enumerate(layers)}
