"""Pluggable datapath registry (DESIGN.md §2.1).

A *datapath* is the arithmetic core of the accelerator being emulated:
given uint8 operand codes it returns the raw accumulated products
``Σ_k mul(qa[m,k], qw[k,n])``.  Zero-point correction, scaling and the
straight-through gradient wrapper live in ``repro.approx.backend`` and
are shared by every datapath, so registering a new datapath is the ONLY
step needed to plug a new emulation strategy (Booth/stochastic circuits,
per-layer rank schedules, ...) into every model, sweep and serve path.

Built-in datapaths registered here:

  * ``int8``    — exact uint8 datapath (the paper's golden reference);
                  int32-exact correction arithmetic
  * ``lut``     — bit-true 256x256 LUT emulation (TFApprox port)
  * ``lowrank`` — rank-R factored LUT: R table lookups + R MXU matmuls

Pallas variants (``lut_pallas``, ``lowrank_pallas``) are registered by
``repro.kernels.datapaths`` and resolved lazily on first lookup, so the
core package never imports the kernel layer eagerly.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

MAX_LUT_K = 33030  # int32-safe accumulation bound: 2^31 / 255^2


class Datapath:
    """Protocol/base class for registered datapaths.

    ``pack(spec, library)`` runs once per (spec, library) on the host and
    returns the device-constant dict consumed by ``forward_q``; the
    result is cached by ``repro.approx.specs.materialize``.
    ``exact_int32`` datapaths return int32 sums whose zero-point
    correction must stay in int32 (bit-exact); the rest are corrected in
    float32.  ``needs_library`` controls whether materialization binds
    the consts to a specific ``ApproxLibrary``.
    """

    name: str = "?"
    exact_int32: bool = False
    needs_library: bool = True
    # spec fields this datapath actually reads in pack()/forward_q();
    # fields outside this set are canonicalized away in cache keys so
    # equivalent configurations share one materialization + jit trace.
    spec_fields: tuple = ("multiplier", "rank", "block_m")
    # True when forward_q stays correct (and efficient) with a vmapped
    # per-multiplier LUT const — the batched resilience engine only
    # banks datapaths that declare it (DESIGN.md §2.4).
    bankable: bool = False

    def pack(self, spec, library) -> dict:
        return {}

    def forward_q(self, qa: jax.Array, qw: jax.Array, consts: dict
                  ) -> jax.Array:
        raise NotImplementedError


_REGISTRY: dict[str, Datapath] = {}


def register_datapath(name: str) -> Callable[[type], type]:
    """Class decorator: instantiate and register under ``name``."""
    def deco(cls: type) -> type:
        inst = cls()
        inst.name = name
        _REGISTRY[name] = inst
        return cls
    return deco


def get_datapath(name: str) -> Datapath:
    if name not in _REGISTRY and name.endswith("_pallas"):
        # Pallas variants live in the kernel layer; import on demand.
        import repro.kernels.datapaths  # noqa: F401  (registers on import)
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown datapath {name!r}; available: "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_datapaths() -> list[str]:
    return sorted(_REGISTRY)


# ----------------------------------------------------------------------
# Shared pack helpers
# ----------------------------------------------------------------------
def _resolve_rank(spec, library, lut: np.ndarray) -> int:
    """spec.rank, or the smallest R whose decomposition error is
    negligible next to the circuit's own error (floor 0.25 LSB^2)."""
    from repro.core.luts import rank_for_tolerance
    if spec.rank:
        return int(spec.rank)
    mult_mae = max(library.entries[spec.multiplier].errors.mae, 0.0)
    tol = max(0.25, 0.1 * mult_mae)
    return int(rank_for_tolerance(lut, tol, max_rank=16))


def pack_lut(spec, library) -> dict:
    lut = np.asarray(library.lut(spec.multiplier), dtype=np.int32)
    return {"lut": lut, "block_m": int(spec.block_m)}


def pack_lowrank(spec, library) -> dict:
    from repro.core.luts import decompose_lut
    lut = np.asarray(library.lut(spec.multiplier), dtype=np.int32)
    fac = decompose_lut(lut, _resolve_rank(spec, library, lut))
    return {"u": np.asarray(fac.u), "v": np.asarray(fac.v)}


# ----------------------------------------------------------------------
# Built-in datapaths
# ----------------------------------------------------------------------
@register_datapath("int8")
class Int8Datapath(Datapath):
    """Exact Σ qa·qw with int32 accumulation (golden 8-bit datapath)."""

    exact_int32 = True
    needs_library = False
    spec_fields = ()

    def forward_q(self, qa, qw, consts):
        return jax.lax.dot_general(
            qa, qw, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )


def _lut_gather_block(qa_blk: jax.Array, qw: jax.Array, flat_lut: jax.Array
                      ) -> jax.Array:
    """Σ_k LUT[qa, qw] for one row block. (mb,K) x (K,N) -> (mb,N) i32."""
    idx = qa_blk[:, :, None] * 256 + qw[None, :, :]        # (mb,K,N)
    prods = jnp.take(flat_lut, idx, axis=0)                 # (mb,K,N) i32
    return jnp.sum(prods, axis=1, dtype=jnp.int32)


@register_datapath("lut")
class LutDatapath(Datapath):
    """Blocked bit-true LUT matmul on codes. (M,K) x (K,N) -> (M,N) i32."""

    spec_fields = ("multiplier", "block_m")
    bankable = True

    def pack(self, spec, library) -> dict:
        return pack_lut(spec, library)

    def forward_q(self, qa, qw, consts):
        m, k = qa.shape
        if k > MAX_LUT_K:
            raise ValueError(
                f"K={k} exceeds int32-safe LUT accumulation bound")
        flat = jnp.asarray(consts["lut"], dtype=jnp.int32).reshape(-1)
        mb = min(consts["block_m"], m)
        pad = (-m) % mb
        qa_p = jnp.pad(qa, ((0, pad), (0, 0)))
        blocks = qa_p.reshape(-1, mb, k)
        out = jax.lax.map(
            lambda blk: _lut_gather_block(blk, qw, flat), blocks)
        return out.reshape(-1, out.shape[-1])[:m]


@register_datapath("lowrank")
class LowRankDatapath(Datapath):
    """Σ_k Σ_r U[r,qa]V[r,qw]  ==  Σ_r tableU_r(qa) @ tableV_r(qw).
    (M,K) x (K,N) -> (M,N) f32; R batched MXU matmuls."""

    spec_fields = ("multiplier", "rank")

    def pack(self, spec, library) -> dict:
        return pack_lowrank(spec, library)

    def forward_q(self, qa, qw, consts):
        u = jnp.asarray(consts["u"])
        v = jnp.asarray(consts["v"])
        ua = jnp.take(u, qa, axis=1)   # (R,M,K) f32
        vw = jnp.take(v, qw, axis=1)   # (R,K,N) f32
        return jnp.einsum("rmk,rkn->mn", ua, vw,
                          preferred_element_type=jnp.float32)
