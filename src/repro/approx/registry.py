"""Pluggable datapath registry (DESIGN.md §2.1).

A *datapath* is the arithmetic core of the accelerator being emulated:
given uint8 operand codes it returns the raw accumulated products
``Σ_k mul(qa[m,k], qw[k,n])``.  Zero-point correction, scaling and the
straight-through gradient wrapper live in ``repro.approx.backend`` and
are shared by every datapath, so registering a new datapath is the ONLY
step needed to plug a new emulation strategy (Booth/stochastic circuits,
per-layer rank schedules, ...) into every model, sweep and serve path.

Built-in datapaths registered here:

  * ``int8``    — exact uint8 datapath (the paper's golden reference);
                  int32-exact correction arithmetic
  * ``lut``     — bit-true 256x256 LUT emulation (TFApprox port)
  * ``lowrank`` — rank-R factored LUT: R table lookups + R MXU matmuls

Pallas variants (``lut_pallas``, ``lowrank_pallas``) are registered by
``repro.kernels.datapaths`` and resolved lazily on first lookup, so the
core package never imports the kernel layer eagerly.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

MAX_LUT_K = 33030  # int32-safe accumulation bound: 2^31 / 255^2
# Composed wide products accumulate as two 16-bit limbs (DESIGN.md
# §2.6): each limb is < 2^16, so int32 limb sums stay exact for up to
# 2^31 / (2^16 - 1) contraction terms.
MAX_COMPOSED_K = (1 << 31) // ((1 << 16) - 1)  # = 32768


class Datapath:
    """Protocol/base class for registered datapaths.

    ``pack(spec, library)`` runs once per (spec, library) on the host and
    returns the device-constant dict consumed by ``forward_q``; the
    result is cached by ``repro.approx.specs.materialize``.
    ``exact_int32`` datapaths return int32 sums whose zero-point
    correction must stay in int32 (bit-exact); the rest are corrected in
    float32.  ``needs_library`` controls whether materialization binds
    the consts to a specific ``ApproxLibrary``.
    """

    name: str = "?"
    exact_int32: bool = False
    needs_library: bool = True
    # True for single-program datapaths (DESIGN.md §2.10): the backend
    # hands them the FLOAT operands via ``forward_fused(x2d, w, consts)``
    # and they calibrate/quantize/gather/dequant inside one kernel —
    # ``forward_q`` (codes in, raw sums out) is never called.
    fused: bool = False
    # spec fields this datapath actually reads in pack()/forward_q();
    # fields outside this set are canonicalized away in cache keys so
    # equivalent configurations share one materialization + jit trace.
    spec_fields: tuple = ("multiplier", "rank", "block_m")
    # True when forward_q stays correct (and efficient) with a vmapped
    # per-multiplier LUT const — the batched resilience engine only
    # banks datapaths that declare it (DESIGN.md §2.4).
    bankable: bool = False

    def pack(self, spec, library) -> dict:
        return {}

    def forward_q(self, qa: jax.Array, qw: jax.Array, consts: dict
                  ) -> jax.Array:
        raise NotImplementedError


_REGISTRY: dict[str, Datapath] = {}


def register_datapath(name: str) -> Callable[[type], type]:
    """Class decorator: instantiate and register under ``name``."""
    def deco(cls: type) -> type:
        inst = cls()
        inst.name = name
        _REGISTRY[name] = inst
        return cls
    return deco


def get_datapath(name: str) -> Datapath:
    if name not in _REGISTRY and name.endswith(("_pallas", "_fused")):
        # Pallas variants live in the kernel layer; import on demand.
        import repro.kernels.datapaths  # noqa: F401  (registers on import)
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown datapath {name!r}; available: "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_datapaths() -> list[str]:
    return sorted(_REGISTRY)


# ----------------------------------------------------------------------
# Shared pack helpers
# ----------------------------------------------------------------------
def _resolve_rank(spec, library, lut: np.ndarray) -> int:
    """spec.rank, or the smallest R whose decomposition error is
    negligible next to the circuit's own error (floor 0.25 LSB^2)."""
    from repro.core.luts import rank_for_tolerance
    if spec.rank:
        return int(spec.rank)
    mult_mae = max(library.entry(spec.multiplier).errors.mae, 0.0)
    tol = max(0.25, 0.1 * mult_mae)
    return int(rank_for_tolerance(lut, tol, max_rank=16))


def _validate_reduce(spec, comp) -> tuple:
    """The parsed reduction of the entry's composition recipe, checked
    against the spec's ``reduce_adder`` declaration when present."""
    from repro.core.families import parse_reduce
    reduce = parse_reduce(comp["reduce"])
    declared = getattr(spec, "reduce_adder", None)
    if declared is not None and parse_reduce(declared) != reduce:
        raise ValueError(
            f"spec declares reduce_adder={declared!r} but composed "
            f"entry {spec.multiplier!r} reduces with "
            f"{comp['reduce']!r}")
    return reduce


def pack_lut(spec, library) -> dict:
    """Device consts for the (width-generic) LUT datapaths.

    8-bit entries pack their own 256x256 LUT (the historical path,
    bit-identical).  Composed wide entries pack the composition TILE's
    256x256 LUT plus the composition descriptor — operand width
    (``bits``), the static ``composed`` dispatch flag, the per-lane
    ``wide`` selector and the parsed ``reduce`` tree — which the
    composed engines (ref + Pallas) consume (DESIGN.md §2.6).
    """
    entry = library.entry(spec.multiplier,
                          bit_width=getattr(spec, "bit_width", None))
    comp = library.composition_of(spec.multiplier)
    lut = np.asarray(library.tile_lut(spec.multiplier), dtype=np.int32)
    consts = {"lut": lut, "block_m": int(spec.block_m)}
    if comp is not None:
        consts.update(composed=True, bits=int(entry.width),
                      mask=int(lane_mask_np(entry.width)),
                      reduce=_validate_reduce(spec, comp))
    elif getattr(spec, "reduce_adder", None) is not None:
        raise ValueError(
            f"reduce_adder={spec.reduce_adder!r} is only meaningful "
            f"for composed wide entries; {spec.multiplier!r} is "
            f"{entry.width}-bit and materializes directly")
    return consts


def pack_lowrank(spec, library) -> dict:
    from repro.core.luts import decompose_lut
    lut = np.asarray(library.lut(spec.multiplier), dtype=np.int32)
    fac = decompose_lut(lut, _resolve_rank(spec, library, lut))
    return {"u": np.asarray(fac.u), "v": np.asarray(fac.v)}


# ----------------------------------------------------------------------
# Built-in datapaths
# ----------------------------------------------------------------------
@register_datapath("int8")
class Int8Datapath(Datapath):
    """Exact Σ qa·qw with int32 accumulation (golden 8-bit datapath)."""

    exact_int32 = True
    needs_library = False
    spec_fields = ()

    def forward_q(self, qa, qw, consts):
        return jax.lax.dot_general(
            qa, qw, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )


def _lut_gather_block(qa_blk: jax.Array, qw: jax.Array, flat_lut: jax.Array
                      ) -> jax.Array:
    """Σ_k LUT[qa, qw] for one row block. (mb,K) x (K,N) -> (mb,N) i32."""
    idx = qa_blk[:, :, None] * 256 + qw[None, :, :]        # (mb,K,N)
    prods = jnp.take(flat_lut, idx, axis=0)                 # (mb,K,N) i32
    return jnp.sum(prods, axis=1, dtype=jnp.int32)


# ----------------------------------------------------------------------
# Composed wide products: tiled 8x8 partial products + shift/add tree
# (DESIGN.md §2.6).  Shared by the ref datapath and the Pallas kernels.
# ----------------------------------------------------------------------
def reduce_apply(a: jax.Array, b: jax.Array, reduce: tuple) -> jax.Array:
    """One reduction-tree adder on uint32 values — the vectorized
    semantics of the library's adder families, bit-identical to the
    gate-level generators in ``repro.core.families`` (every tree node
    value fits its netlist adder's width, so no wraparound diverges).
    """
    kind, k = reduce
    if kind == "exact":
        return a + b
    if kind == "trunc":
        return ((a >> k) + (b >> k)) << k
    if kind == "loa":
        mask = jnp.uint32((1 << k) - 1)
        carry = (a >> (k - 1)) & (b >> (k - 1)) & jnp.uint32(1)
        return ((a | b) & mask) | ((((a >> k) + (b >> k)) + carry) << k)
    raise ValueError(f"unknown reduction kind {kind!r}")


def composed_reduce(pp00, pp01, pp10, pp11, reduce: tuple) -> jax.Array:
    """uint32 shift/add tree over the four digit products:
    ``p = ADD(ADD(pp00, ADD(pp01, pp10) << 8), pp11 << 16)`` — the
    same tree ``repro.core.families.composed_multiplier`` builds in
    gates.  NOTE: the gate netlist keeps only the low 2W output bits;
    callers must apply ``product_mask(bits)`` to match it (a W=12
    tile that over-estimates can push the tree past 2^24)."""
    s1 = reduce_apply(pp01, pp10, reduce)
    s2 = reduce_apply(pp00, s1 << 8, reduce)
    return reduce_apply(s2, pp11 << 16, reduce)


#: Order fixing the integer encoding of reduction kinds for the fused
#: kernels (``encode_reduce``); index == wire value.
REDUCE_KINDS = ("exact", "trunc", "loa")


def encode_reduce(reduce: tuple) -> tuple[int, int]:
    """A parsed ``(kind, k)`` reduction as two small ints — the runtime
    encoding the fused kernels consume (DESIGN.md §2.10).  Making the
    adder family DATA instead of a static kernel parameter is what
    collapses per-reduce program splits: one compiled fused program
    serves every adder family, so mixed-reduce banks stay O(1)."""
    kind, k = reduce
    if kind not in REDUCE_KINDS:
        raise ValueError(f"unknown reduction kind {kind!r}")
    return (REDUCE_KINDS.index(kind), int(k))


def reduce_apply_dyn(a: jax.Array, b: jax.Array, kind: jax.Array,
                     k: jax.Array) -> jax.Array:
    """``reduce_apply`` with the reduction selected by runtime scalars
    ``(kind, k)`` (see ``encode_reduce``).  All three adder families are
    computed and selected — integer ops, so each branch's value is
    bit-identical to its static sibling (the price is ~3x the adder
    ALU work, negligible next to the digit-product gathers)."""
    kind = jnp.asarray(kind, jnp.int32)
    k = jnp.asarray(k, jnp.uint32)
    exact = a + b
    hs = ((a >> k) + (b >> k))
    trunc = hs << k
    km = jnp.maximum(k, jnp.uint32(1))       # loa guard: k >= 1 by parse
    mask = (jnp.uint32(1) << km) - jnp.uint32(1)
    carry = (a >> (km - 1)) & (b >> (km - 1)) & jnp.uint32(1)
    loa = ((a | b) & mask) | ((hs + carry) << k)
    return jnp.where(kind == 0, exact, jnp.where(kind == 1, trunc, loa))


def composed_reduce_dyn(pp00, pp01, pp10, pp11, kind, k) -> jax.Array:
    """``composed_reduce`` with a runtime-selected adder family — the
    same shift/add tree, every node through ``reduce_apply_dyn``."""
    s1 = reduce_apply_dyn(pp01, pp10, kind, k)
    s2 = reduce_apply_dyn(pp00, s1 << 8, kind, k)
    return reduce_apply_dyn(s2, pp11 << 16, kind, k)


def product_mask(bits) -> jax.Array:
    """uint32 mask keeping the composed netlist's 2W output bits
    (``0xFFFFFF`` at W=12, ``0xFFFFFFFF`` at W=16).  Traceable in
    ``bits``; computed as a right-shift of all-ones so no shift ever
    reaches the full register width."""
    if isinstance(bits, int):
        return jnp.uint32((1 << (2 * bits)) - 1 if bits < 16
                          else 0xFFFFFFFF)
    shift = (32 - 2 * jnp.asarray(bits, jnp.uint32))
    return jnp.uint32(0xFFFFFFFF) >> shift


def lane_mask_np(bits) -> np.ndarray:
    """Host-side per-lane selector-and-mask of the banked composed
    engine: 0 for narrow (8-bit) lanes — "take the plain tile sum" —
    and the 2W-bit ``product_mask`` for wide lanes.  The single source
    of the bits→mask rule for ``pack_lut`` and ``LutBank.lane_masks``
    (``product_mask`` is its traced sibling for in-graph widths)."""
    bits = np.asarray(bits, np.int64)
    masks = np.where(bits >= 16, 0xFFFFFFFF, (1 << (2 * bits)) - 1)
    return np.where(bits > 8, masks, 0).astype(np.uint32)


def composed_product(qa: jax.Array, qw: jax.Array, flat_lut: jax.Array,
                     reduce: tuple, bits: int = 16) -> jax.Array:
    """Elementwise composed product of W-bit codes (any broadcastable
    shapes) as exact uint32, truncated to the netlist's 2W output bits
    — the scalar semantics the bitsim oracle tests pin down."""
    def pp(x, y):
        return jnp.take(flat_lut, x * 256 + y, axis=0).astype(jnp.uint32)
    a0, a1 = qa & 255, qa >> 8
    w0, w1 = qw & 255, qw >> 8
    return composed_reduce(pp(a0, w0), pp(a0, w1), pp(a1, w0),
                           pp(a1, w1), reduce) & product_mask(bits)


def _composed_gather_block(qa_blk: jax.Array, qw: jax.Array,
                           flat_lut: jax.Array, mask, reduce: tuple
                           ) -> jax.Array:
    """Composed-product row block: (mb,K) x (K,N) -> (mb,N) f32.

    Wide products are truncated to the lane's ``mask`` (the netlist's
    2W output bits), split into two 16-bit limbs accumulated exactly
    in int32 (``K <= MAX_COMPOSED_K``), then recombined in f32.
    ``mask == 0`` marks a narrow lane: it takes the plain 8-bit tile
    sum (`pp00` alone), which keeps narrow lanes of a mixed-width bank
    bit-identical to the historical 8-bit path."""
    a0, a1 = qa_blk & 255, qa_blk >> 8
    w0, w1 = qw & 255, qw >> 8
    mask = jnp.asarray(mask, jnp.uint32)

    def pp(x, y):                                        # (mb,K,N) i32
        idx = x[:, :, None] * 256 + y[None, :, :]
        return jnp.take(flat_lut, idx, axis=0)

    pp00 = pp(a0, w0)
    p = composed_reduce(pp00.astype(jnp.uint32),
                        pp(a0, w1).astype(jnp.uint32),
                        pp(a1, w0).astype(jnp.uint32),
                        pp(a1, w1).astype(jnp.uint32), reduce) & mask
    lo = (p & jnp.uint32(0xFFFF)).astype(jnp.int32)
    hi = (p >> 16).astype(jnp.int32)
    s_lo = jnp.sum(lo, axis=1, dtype=jnp.int32).astype(jnp.float32)
    s_hi = jnp.sum(hi, axis=1, dtype=jnp.int32).astype(jnp.float32)
    s00 = jnp.sum(pp00, axis=1, dtype=jnp.int32).astype(jnp.float32)
    return jnp.where(mask != 0, s_lo + 65536.0 * s_hi, s00)


def composed_forward(qa: jax.Array, qw: jax.Array, lut: jax.Array,
                     mask, reduce: tuple, block_m: int) -> jax.Array:
    """Blocked composed matmul on codes (ref datapath core):
    (M,K) x (K,N) -> (M,N) f32."""
    m, k = qa.shape
    if k > MAX_COMPOSED_K:
        raise ValueError(
            f"K={k} exceeds int32-safe composed limb accumulation "
            f"bound {MAX_COMPOSED_K}")
    flat = jnp.asarray(lut, dtype=jnp.int32).reshape(-1)
    mb = min(block_m, m)
    pad = (-m) % mb
    qa_p = jnp.pad(qa, ((0, pad), (0, 0)))
    blocks = qa_p.reshape(-1, mb, k)
    out = jax.lax.map(
        lambda blk: _composed_gather_block(blk, qw, flat, mask, reduce),
        blocks)
    return out.reshape(-1, out.shape[-1])[:m]


@register_datapath("lut")
class LutDatapath(Datapath):
    """Blocked bit-true LUT matmul on codes — width-generic.

    8-bit (``composed`` unset): (M,K) x (K,N) -> (M,N) i32, the
    historical bit-identical path.  Composed wide (DESIGN.md §2.6):
    digit products through the 256x256 TILE LUT, reduced by the
    spec'd shift/add tree, limb-accumulated -> (M,N) f32.
    """

    spec_fields = ("multiplier", "block_m", "bit_width", "reduce_adder")
    bankable = True

    def pack(self, spec, library) -> dict:
        return pack_lut(spec, library)

    def forward_q(self, qa, qw, consts):
        m, k = qa.shape
        if consts.get("composed"):
            return composed_forward(qa, qw, consts["lut"],
                                    consts["mask"], consts["reduce"],
                                    min(consts["block_m"], m))
        if k > MAX_LUT_K:
            raise ValueError(
                f"K={k} exceeds int32-safe LUT accumulation bound")
        flat = jnp.asarray(consts["lut"], dtype=jnp.int32).reshape(-1)
        mb = min(consts["block_m"], m)
        pad = (-m) % mb
        qa_p = jnp.pad(qa, ((0, pad), (0, 0)))
        blocks = qa_p.reshape(-1, mb, k)
        out = jax.lax.map(
            lambda blk: _lut_gather_block(blk, qw, flat), blocks)
        return out.reshape(-1, out.shape[-1])[:m]


@register_datapath("lowrank")
class LowRankDatapath(Datapath):
    """Σ_k Σ_r U[r,qa]V[r,qw]  ==  Σ_r tableU_r(qa) @ tableV_r(qw).
    (M,K) x (K,N) -> (M,N) f32; R batched MXU matmuls."""

    spec_fields = ("multiplier", "rank")

    def pack(self, spec, library) -> dict:
        return pack_lowrank(spec, library)

    def forward_q(self, qa, qw, consts):
        u = jnp.asarray(consts["u"])
        v = jnp.asarray(consts["v"])
        ua = jnp.take(u, qa, axis=1)   # (R,M,K) f32
        vw = jnp.take(v, qw, axis=1)   # (R,K,N) f32
        return jnp.einsum("rmk,rkn->mn", ua, vw,
                          preferred_element_type=jnp.float32)
