"""Resilience analysis driver (paper Sec. IV, Fig. 4 and Table II).

Given an evaluation closure ``eval_fn(policy) -> accuracy`` and the
model's per-layer multiplication counts, sweeps approximate multipliers
  * one layer at a time (Fig. 4 — layer sensitivity), and
  * across all layers at once (Table II — accuracy vs. power trade-off),
reporting classification accuracy together with the network-level
relative multiplier power.  The non-swept layers use the exact int8
datapath, the paper's golden reference.

Backends are built spec-first: each multiplier name becomes a
``BackendSpec`` materialized once against the library, so every policy
the sweep evaluates shares the same backend objects (one jit trace per
multiplier instead of one per policy instance).  The ``explore()``
facade in ``repro.approx.dse`` wraps both sweeps with result caching
and Pareto selection.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from .backend import BackendLike
from .layers import ApproxPolicy
from .power import LayerPower, network_relative_power
from .specs import BackendSpec, MaterializedBackend


@dataclass
class ResilienceRow:
    multiplier: str
    layer: str                 # layer name or "all"
    accuracy: float
    network_rel_power: float   # count-weighted multiplier power
    multiplier_rel_power: float
    mult_share: float          # fraction of network mults in this layer
    errors: dict = field(default_factory=dict)
    spec: Optional[BackendSpec] = None


def _backends_for(multiplier_names, library, mode: str, rank=None,
                  variant: str = "ref") -> dict[str, MaterializedBackend]:
    out = {}
    for name in multiplier_names:
        spec = BackendSpec(mode=mode, multiplier=name, rank=rank,
                           variant=variant)
        out[name] = spec.materialize(library)
    return out


def per_layer_sweep(
    eval_fn: Callable[[ApproxPolicy], float],
    layer_counts: dict[str, int],
    multiplier_names: list[str],
    library,
    mode: str = "lut",
    base: Optional[BackendLike] = None,
    variant: str = "ref",
) -> list[ResilienceRow]:
    """Fig. 4: one layer approximated at a time."""
    base = base if base is not None else BackendSpec.golden().materialize()
    backends = _backends_for(multiplier_names, library, mode,
                             variant=variant)
    total = sum(layer_counts.values())
    rows = []
    for layer, count in layer_counts.items():
        for mname, be in backends.items():
            policy = ApproxPolicy(default=base, overrides=[(layer, be)])
            acc = float(eval_fn(policy))
            entry = library.entries[mname]
            pw = [LayerPower(l, c, mname if l == layer else "exact",
                             entry.rel_power if l == layer else 1.0)
                  for l, c in layer_counts.items()]
            rows.append(ResilienceRow(
                multiplier=mname, layer=layer, accuracy=acc,
                network_rel_power=network_relative_power(pw),
                multiplier_rel_power=entry.rel_power,
                mult_share=count / total,
                errors=entry.errors.as_dict(),
                spec=be.spec,
            ))
    return rows


def all_layers_sweep(
    eval_fn: Callable[[ApproxPolicy], float],
    layer_counts: dict[str, int],
    multiplier_names: list[str],
    library,
    mode: str = "lut",
    variant: str = "ref",
) -> list[ResilienceRow]:
    """Table II: the same multiplier in every (conv) layer."""
    backends = _backends_for(multiplier_names, library, mode,
                             variant=variant)
    rows = []
    for mname, be in backends.items():
        policy = ApproxPolicy(default=be)
        acc = float(eval_fn(policy))
        entry = library.entries[mname]
        rows.append(ResilienceRow(
            multiplier=mname, layer="all", accuracy=acc,
            network_rel_power=entry.rel_power,
            multiplier_rel_power=entry.rel_power,
            mult_share=1.0,
            errors=entry.errors.as_dict(),
            spec=be.spec,
        ))
    return rows
