"""Resilience analysis driver (paper Sec. IV, Fig. 4 and Table II).

Given an evaluation handle — a ``repro.approx.workload.Workload``, a
``BankableEval``, or a plain ``eval_fn(policy) -> accuracy`` closure
(all normalized through ``as_workload``, DESIGN.md §2.7) — and the
model's per-layer multiplication counts, sweeps approximate multipliers
  * one layer at a time (Fig. 4 — layer sensitivity), and
  * across all layers at once (Table II — accuracy vs. power trade-off),
reporting classification accuracy together with the network-level
relative multiplier power.  The non-swept layers use the exact int8
datapath, the paper's golden reference.

Backends are built spec-first: each multiplier name becomes a
``BackendSpec`` materialized once against the library, so every policy
the sweep evaluates shares the same backend objects (one jit trace per
multiplier instead of one per policy instance).

Both sweeps also run **batched** (``batch=True``): the multiplier axis
is packed into a ``LutBank`` and evaluated under ``jax.vmap`` in O(1)
compiled programs per sweep (one for all-layers, one per layer for
per-layer) instead of O(n_mult) traces — bit-identical accuracies to
the sequential path (DESIGN.md §2.4).  Batching requires a traceable
evaluation function; wrap yours in ``BankableEval``.  The ``explore()``
facade in ``repro.approx.dse`` wraps both sweeps with result caching
and Pareto selection.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .backend import BackendLike
from .layers import ApproxPolicy, bank_eval
from .power import (auto_rel_power, cost_axes_map,
                    network_costs_for_assignment,
                    network_power_for_assignment)
from .registry import get_datapath
from .specs import BackendSpec, MaterializedBackend, bank_for
from .workload import Workload, as_workload


@dataclass
class ResilienceRow:
    """One sweep measurement.  ``metrics`` carries EVERY named quality
    metric the workload measured (DESIGN.md §2.7); ``accuracy`` is the
    legacy scalar alias for the workload's PRIMARY metric (named for
    the paper's classification case — it holds e.g. a logit-MAE for
    fidelity workloads).  ``costs`` carries the library-derived
    area/delay axes next to the power columns."""

    multiplier: str
    layer: str                 # layer name or "all"
    accuracy: float            # = metrics[workload.primary]
    network_rel_power: float   # count-weighted multiplier power
    multiplier_rel_power: float
    mult_share: float          # fraction of network mults in this layer
    errors: dict = field(default_factory=dict)
    spec: Optional[BackendSpec] = None
    metrics: dict = field(default_factory=dict)
    costs: dict = field(default_factory=dict)


@dataclass
class BankableEval:
    """An evaluation function in both calling conventions the sweeps
    understand.  Subsumed by ``repro.approx.workload.Workload`` (the
    multi-metric generalization, DESIGN.md §2.7) — the sweeps
    normalize either through ``as_workload``; BankableEval remains the
    lightest way to hand over a single scalar accuracy.

    ``fn(policy) -> float`` is the sequential closure (free to jit
    internally, call numpy, return a Python float).  ``traceable`` is
    its pure-jax core — arrays in, a scalar accuracy array out, no
    side effects — which the batched engine wraps in ``jit(vmap(...))``
    over the multiplier bank.  The two must compute the same number for
    the same policy; the batched path is then bit-identical to the
    sequential one by construction.  Calling the object delegates to
    ``fn``, so a ``BankableEval`` drops into every sequential call site
    unchanged.
    """

    fn: Callable[[ApproxPolicy], float]
    traceable: Callable[[ApproxPolicy], "object"]

    def __call__(self, policy: ApproxPolicy) -> float:
        return self.fn(policy)


def can_bank(eval_fn, mode: str, variant: str = "ref") -> bool:
    """True when ``(eval_fn, mode, variant)`` supports the batched
    engine: the eval exposes a traceable core and the datapath declares
    ``bankable`` (lut-family; lowrank/int8 do not bank)."""
    if getattr(eval_fn, "traceable", None) is None:
        return False
    name = mode if variant == "ref" else f"{mode}_{variant}"
    try:
        return bool(get_datapath(name).bankable)
    except KeyError:
        return False


def _backends_for(multiplier_names, library, mode: str, rank=None,
                  variant: str = "ref") -> dict[str, MaterializedBackend]:
    out = {}
    for name in multiplier_names:
        spec = BackendSpec(mode=mode, multiplier=name, rank=rank,
                           variant=variant)
        out[name] = spec.materialize(library)
    return out


def _row(library, mname, layer, metrics, primary, layer_counts, spec,
         rel_power=None, cost_map=None) -> ResilienceRow:
    entry = library.entry(mname)
    # rel_power overrides rebase power onto a common reference for
    # mixed-width sweeps (power.rel_power_map, DESIGN.md §2.6); the
    # default is the library's same-width convention
    rp = (rel_power[mname] if rel_power is not None
          else entry.rel_power)
    acc = float(metrics[primary])
    total = sum(layer_counts.values())
    if layer == "all":
        assignment = {l: mname for l in layer_counts}
        return ResilienceRow(
            multiplier=mname, layer="all", accuracy=acc,
            network_rel_power=rp,
            multiplier_rel_power=rp,
            mult_share=1.0, errors=entry.errors.as_dict(), spec=spec,
            metrics=dict(metrics),
            costs=(network_costs_for_assignment(layer_counts, assignment,
                                                cost_map)
                   if cost_map is not None else {}))
    # a per-layer row is the one-layer special case of a heterogeneous
    # assignment; both score power (and area/delay) through the same
    # component model
    return ResilienceRow(
        multiplier=mname, layer=layer, accuracy=acc,
        network_rel_power=network_power_for_assignment(
            layer_counts, {layer: mname}, {mname: rp}),
        multiplier_rel_power=rp,
        mult_share=layer_counts[layer] / total,
        errors=entry.errors.as_dict(), spec=spec,
        metrics=dict(metrics),
        costs=(network_costs_for_assignment(layer_counts, {layer: mname},
                                            cost_map)
               if cost_map is not None else {}))


# ----------------------------------------------------------------------
# Per-layer component models (autoAx-style, DESIGN.md §2.5)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class LayerComponents:
    """Per-layer quality/power component models distilled from the
    Fig. 4 per-layer sweep rows — the prediction stage of the two-stage
    heterogeneous DSE (autoAx: compose per-layer measurements into
    network-level estimates, then verify the shortlist exactly).

    ``quality[j, i]`` is the measured network accuracy with ONLY layer
    ``layers[j]`` running multiplier ``multipliers[i]`` (everything else
    golden int8); ``rel_power[i]`` is the multiplier's relative power.
    The composition model is additive in accuracy *drops* (clipped at
    zero: measurement noise must not predict improvements) and exact in
    power (count-weighted mean, the same arithmetic the verified points
    report).
    """

    layers: tuple[str, ...]
    multipliers: tuple[str, ...]
    quality: "np.ndarray"           # (n_layers, n_mult) accuracies
    rel_power: "np.ndarray"         # (n_mult,)
    counts: tuple[int, ...]         # per layers[j] mult counts
    total_count: int                # whole-network mult count
    baseline: float                 # golden int8 accuracy
    direction: str = "max"          # primary metric direction (§2.7):
                                    # "min" primaries (logit MAE,
                                    # perplexity) flip the drop sign

    @staticmethod
    def from_rows(rows: "list[ResilienceRow]", layer_counts: dict,
                  baseline: float,
                  direction: str = "max") -> "LayerComponents":
        """Distill per-layer sweep rows (any order, any coverage) into
        component matrices.  Missing (layer, multiplier) cells fall back
        to the baseline accuracy (no measured evidence of damage)."""
        layers = tuple(dict.fromkeys(
            r.layer for r in rows if r.layer != "all"))
        mults = tuple(dict.fromkeys(
            r.multiplier for r in rows if r.layer != "all"))
        li = {l: j for j, l in enumerate(layers)}
        mi = {m: i for i, m in enumerate(mults)}
        quality = np.full((len(layers), len(mults)), baseline)
        rel_power = np.ones(len(mults))
        for r in rows:
            if r.layer == "all":
                continue
            quality[li[r.layer], mi[r.multiplier]] = r.accuracy
            rel_power[mi[r.multiplier]] = r.multiplier_rel_power
        return LayerComponents(
            layers=layers, multipliers=mults, quality=quality,
            rel_power=rel_power,
            counts=tuple(int(layer_counts[l]) for l in layers),
            total_count=int(sum(layer_counts.values())),
            baseline=float(baseline), direction=direction)

    def drop(self) -> "np.ndarray":
        """(n_layers, n_mult) per-layer quality DEGRADATIONS, clipped
        >= 0 — baseline − quality for maximize primaries, quality −
        baseline for minimize ones (a fidelity workload's MAE *rises*
        under approximation)."""
        if self.direction == "min":
            return np.maximum(self.quality - self.baseline, 0.0)
        return np.maximum(self.baseline - self.quality, 0.0)

    def predict_accuracy(self, assign: "np.ndarray") -> float:
        """Additive-drop estimate of the primary metric for one
        assignment row (indices into ``multipliers``)."""
        d = self.drop()
        total = float(sum(d[j, i] for j, i in enumerate(assign)))
        return (self.baseline + total if self.direction == "min"
                else self.baseline - total)

    def predict_power(self, assign: "np.ndarray") -> float:
        """Exact count-weighted power of one assignment row (layers
        outside ``layers`` are golden int8 at rel power 1.0)."""
        assigned = sum(c * self.rel_power[i]
                       for c, i in zip(self.counts, assign))
        rest = self.total_count - sum(self.counts)
        if self.total_count == 0:
            return 1.0
        return float((assigned + rest) / self.total_count)

    def layer_pareto(self) -> list[list[int]]:
        """Per layer: multiplier indices non-dominated on
        (accuracy-drop min, power min) — the layer-wise pruning stage.
        Candidates are returned sorted by ascending power."""
        d = self.drop()
        fronts = []
        for j in range(len(self.layers)):
            order = sorted(range(len(self.multipliers)),
                           key=lambda i: (self.rel_power[i], d[j, i]))
            front: list[int] = []
            best = float("inf")
            for i in order:
                if d[j, i] < best:
                    front.append(i)
                    best = d[j, i]
            fronts.append(front)
        return fronts


def per_layer_sweep(
    eval_fn: Callable[[ApproxPolicy], float],
    layer_counts: dict[str, int],
    multiplier_names: list[str],
    library,
    mode: str = "lut",
    base: Optional[BackendLike] = None,
    variant: str = "ref",
    batch: bool = False,
    sharding=None,
    rel_power=None,
) -> list[ResilienceRow]:
    """Fig. 4: one layer approximated at a time.

    Sequential (default): one ``eval_fn`` call — and typically one jit
    trace — per (layer, multiplier) pair.  Batched (``batch=True``,
    requires a ``BankableEval``): the multiplier axis is packed into a
    ``LutBank`` and each layer evaluates ALL candidates in one compiled
    program — O(n_layers) programs total instead of
    O(n_layers * n_mult).  Accuracies are bit-identical between the two
    paths; ``sharding`` optionally spreads the bank axis across devices
    (``repro.launch.mesh.bank_sharding``).

    ``multiplier_names`` may MIX operand widths (8-bit entries next to
    composed 12/16-bit ones, DESIGN.md §2.6) — the bank stays one
    compiled program per layer either way, and power is auto-rebased
    onto a common reference (``power.auto_rel_power``) unless an
    explicit ``rel_power`` map is given.
    """
    wl = as_workload(eval_fn)
    base = base if base is not None else BackendSpec.golden().materialize()
    if rel_power is None:
        rel_power = auto_rel_power(library, multiplier_names)
    cost_map = cost_axes_map(library, multiplier_names)
    backends = _backends_for(multiplier_names, library, mode,
                             variant=variant)
    rows = []
    if batch:
        wl = _require_bankable(wl, mode, variant)
        bank = bank_for(multiplier_names, library)
        for layer in layer_counts:
            lanes = _unstack_metrics(
                bank_eval(wl.traceable_metrics, bank, mode=mode,
                          variant=variant, base=base,
                          layer_pattern=layer, sharding=sharding),
                wl.metrics, len(multiplier_names))
            for mname, metrics in zip(multiplier_names, lanes):
                rows.append(_row(library, mname, layer, metrics,
                                 wl.primary, layer_counts,
                                 backends[mname].spec, rel_power,
                                 cost_map))
        return rows
    for layer in layer_counts:
        for mname, be in backends.items():
            policy = ApproxPolicy(default=base, overrides=[(layer, be)])
            rows.append(_row(library, mname, layer, wl.measure(policy),
                             wl.primary, layer_counts, be.spec,
                             rel_power, cost_map))
    return rows


def all_layers_sweep(
    eval_fn: Callable[[ApproxPolicy], float],
    layer_counts: dict[str, int],
    multiplier_names: list[str],
    library,
    mode: str = "lut",
    variant: str = "ref",
    batch: bool = False,
    sharding=None,
    rel_power=None,
) -> list[ResilienceRow]:
    """Table II: the same multiplier in every (conv) layer.

    Sequential (default): one ``eval_fn`` call per multiplier.  Batched
    (``batch=True``, requires a ``BankableEval``): ONE compiled program
    evaluates the whole ``LutBank`` — O(1) traces/compiles regardless
    of ``len(multiplier_names)``, bit-identical accuracies to the
    sequential path.  ``sharding`` optionally spreads the bank axis
    across devices.

    Width-generic: mixed 8/12/16-bit candidate sets bank into the same
    O(1) program (per-lane widths ride the vmapped axis, DESIGN.md
    §2.6), with power auto-rebased onto a common reference
    (``power.auto_rel_power``) unless ``rel_power`` overrides it.
    """
    wl = as_workload(eval_fn)
    if rel_power is None:
        rel_power = auto_rel_power(library, multiplier_names)
    cost_map = cost_axes_map(library, multiplier_names)
    backends = _backends_for(multiplier_names, library, mode,
                             variant=variant)
    if batch:
        wl = _require_bankable(wl, mode, variant)
        bank = bank_for(multiplier_names, library)
        lanes = _unstack_metrics(
            bank_eval(wl.traceable_metrics, bank, mode=mode,
                      variant=variant, sharding=sharding),
            wl.metrics, len(multiplier_names))
        return [_row(library, mname, "all", metrics, wl.primary,
                     layer_counts, backends[mname].spec, rel_power,
                     cost_map)
                for mname, metrics in zip(multiplier_names, lanes)]
    rows = []
    for mname, be in backends.items():
        policy = ApproxPolicy(default=be)
        rows.append(_row(library, mname, "all", wl.measure(policy),
                         wl.primary, layer_counts, be.spec, rel_power,
                         cost_map))
    return rows


def _unstack_metrics(out, metric_names, n: int) -> list[dict]:
    """Split a banked evaluation's stacked metric dict ``{metric:
    (n,) array}`` into one float dict per lane, in workload metric
    order."""
    arrs = {m: np.asarray(out[m]) for m in metric_names}
    return [{m: float(arrs[m][i]) for m in metric_names}
            for i in range(n)]


def _require_bankable(eval_fn, mode: str, variant: str) -> Workload:
    wl = as_workload(eval_fn)
    if not can_bank(wl, mode, variant):
        raise ValueError(
            "batch=True needs a bank-traceable evaluation (a Workload "
            "with traceable_metrics, or a BankableEval) and a bankable "
            f"datapath; got {type(eval_fn).__name__} with mode={mode!r} "
            f"variant={variant!r}.  Wrap your eval in "
            "BankableEval/Workload or use explore(batch=True), which "
            "falls back to the sequential path.")
    return wl
