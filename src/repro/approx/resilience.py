"""Resilience analysis driver (paper Sec. IV, Fig. 4 and Table II).

Given an evaluation closure ``eval_fn(policy) -> accuracy`` and the
model's per-layer multiplication counts, sweeps approximate multipliers
  * one layer at a time (Fig. 4 — layer sensitivity), and
  * across all layers at once (Table II — accuracy vs. power trade-off),
reporting classification accuracy together with the network-level
relative multiplier power.  The non-swept layers use the exact int8
datapath, the paper's golden reference.

Backends are built spec-first: each multiplier name becomes a
``BackendSpec`` materialized once against the library, so every policy
the sweep evaluates shares the same backend objects (one jit trace per
multiplier instead of one per policy instance).

Both sweeps also run **batched** (``batch=True``): the multiplier axis
is packed into a ``LutBank`` and evaluated under ``jax.vmap`` in O(1)
compiled programs per sweep (one for all-layers, one per layer for
per-layer) instead of O(n_mult) traces — bit-identical accuracies to
the sequential path (DESIGN.md §2.4).  Batching requires a traceable
evaluation function; wrap yours in ``BankableEval``.  The ``explore()``
facade in ``repro.approx.dse`` wraps both sweeps with result caching
and Pareto selection.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .backend import BackendLike
from .layers import ApproxPolicy, bank_eval
from .power import auto_rel_power, network_power_for_assignment
from .registry import get_datapath
from .specs import BackendSpec, MaterializedBackend, bank_for


@dataclass
class ResilienceRow:
    multiplier: str
    layer: str                 # layer name or "all"
    accuracy: float
    network_rel_power: float   # count-weighted multiplier power
    multiplier_rel_power: float
    mult_share: float          # fraction of network mults in this layer
    errors: dict = field(default_factory=dict)
    spec: Optional[BackendSpec] = None


@dataclass
class BankableEval:
    """An evaluation function in both calling conventions the sweeps
    understand.

    ``fn(policy) -> float`` is the sequential closure (free to jit
    internally, call numpy, return a Python float).  ``traceable`` is
    its pure-jax core — arrays in, a scalar accuracy array out, no
    side effects — which the batched engine wraps in ``jit(vmap(...))``
    over the multiplier bank.  The two must compute the same number for
    the same policy; the batched path is then bit-identical to the
    sequential one by construction.  Calling the object delegates to
    ``fn``, so a ``BankableEval`` drops into every sequential call site
    unchanged.
    """

    fn: Callable[[ApproxPolicy], float]
    traceable: Callable[[ApproxPolicy], "object"]

    def __call__(self, policy: ApproxPolicy) -> float:
        return self.fn(policy)


def can_bank(eval_fn, mode: str, variant: str = "ref") -> bool:
    """True when ``(eval_fn, mode, variant)`` supports the batched
    engine: the eval exposes a traceable core and the datapath declares
    ``bankable`` (lut-family; lowrank/int8 do not bank)."""
    if getattr(eval_fn, "traceable", None) is None:
        return False
    name = mode if variant == "ref" else f"{mode}_{variant}"
    try:
        return bool(get_datapath(name).bankable)
    except KeyError:
        return False


def _backends_for(multiplier_names, library, mode: str, rank=None,
                  variant: str = "ref") -> dict[str, MaterializedBackend]:
    out = {}
    for name in multiplier_names:
        spec = BackendSpec(mode=mode, multiplier=name, rank=rank,
                           variant=variant)
        out[name] = spec.materialize(library)
    return out


def _row(library, mname, layer, acc, layer_counts, spec,
         rel_power=None) -> ResilienceRow:
    entry = library.entry(mname)
    # rel_power overrides rebase power onto a common reference for
    # mixed-width sweeps (power.rel_power_map, DESIGN.md §2.6); the
    # default is the library's same-width convention
    rp = (rel_power[mname] if rel_power is not None
          else entry.rel_power)
    total = sum(layer_counts.values())
    if layer == "all":
        return ResilienceRow(
            multiplier=mname, layer="all", accuracy=acc,
            network_rel_power=rp,
            multiplier_rel_power=rp,
            mult_share=1.0, errors=entry.errors.as_dict(), spec=spec)
    # a per-layer row is the one-layer special case of a heterogeneous
    # assignment; both score power through the same component model
    return ResilienceRow(
        multiplier=mname, layer=layer, accuracy=acc,
        network_rel_power=network_power_for_assignment(
            layer_counts, {layer: mname}, {mname: rp}),
        multiplier_rel_power=rp,
        mult_share=layer_counts[layer] / total,
        errors=entry.errors.as_dict(), spec=spec)


# ----------------------------------------------------------------------
# Per-layer component models (autoAx-style, DESIGN.md §2.5)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class LayerComponents:
    """Per-layer quality/power component models distilled from the
    Fig. 4 per-layer sweep rows — the prediction stage of the two-stage
    heterogeneous DSE (autoAx: compose per-layer measurements into
    network-level estimates, then verify the shortlist exactly).

    ``quality[j, i]`` is the measured network accuracy with ONLY layer
    ``layers[j]`` running multiplier ``multipliers[i]`` (everything else
    golden int8); ``rel_power[i]`` is the multiplier's relative power.
    The composition model is additive in accuracy *drops* (clipped at
    zero: measurement noise must not predict improvements) and exact in
    power (count-weighted mean, the same arithmetic the verified points
    report).
    """

    layers: tuple[str, ...]
    multipliers: tuple[str, ...]
    quality: "np.ndarray"           # (n_layers, n_mult) accuracies
    rel_power: "np.ndarray"         # (n_mult,)
    counts: tuple[int, ...]         # per layers[j] mult counts
    total_count: int                # whole-network mult count
    baseline: float                 # golden int8 accuracy

    @staticmethod
    def from_rows(rows: "list[ResilienceRow]", layer_counts: dict,
                  baseline: float) -> "LayerComponents":
        """Distill per-layer sweep rows (any order, any coverage) into
        component matrices.  Missing (layer, multiplier) cells fall back
        to the baseline accuracy (no measured evidence of damage)."""
        layers = tuple(dict.fromkeys(
            r.layer for r in rows if r.layer != "all"))
        mults = tuple(dict.fromkeys(
            r.multiplier for r in rows if r.layer != "all"))
        li = {l: j for j, l in enumerate(layers)}
        mi = {m: i for i, m in enumerate(mults)}
        quality = np.full((len(layers), len(mults)), baseline)
        rel_power = np.ones(len(mults))
        for r in rows:
            if r.layer == "all":
                continue
            quality[li[r.layer], mi[r.multiplier]] = r.accuracy
            rel_power[mi[r.multiplier]] = r.multiplier_rel_power
        return LayerComponents(
            layers=layers, multipliers=mults, quality=quality,
            rel_power=rel_power,
            counts=tuple(int(layer_counts[l]) for l in layers),
            total_count=int(sum(layer_counts.values())),
            baseline=float(baseline))

    def drop(self) -> "np.ndarray":
        """(n_layers, n_mult) per-layer accuracy drops, clipped >= 0."""
        return np.maximum(self.baseline - self.quality, 0.0)

    def predict_accuracy(self, assign: "np.ndarray") -> float:
        """Additive-drop estimate for one assignment row (indices into
        ``multipliers``)."""
        d = self.drop()
        return self.baseline - float(
            sum(d[j, i] for j, i in enumerate(assign)))

    def predict_power(self, assign: "np.ndarray") -> float:
        """Exact count-weighted power of one assignment row (layers
        outside ``layers`` are golden int8 at rel power 1.0)."""
        assigned = sum(c * self.rel_power[i]
                       for c, i in zip(self.counts, assign))
        rest = self.total_count - sum(self.counts)
        if self.total_count == 0:
            return 1.0
        return float((assigned + rest) / self.total_count)

    def layer_pareto(self) -> list[list[int]]:
        """Per layer: multiplier indices non-dominated on
        (accuracy-drop min, power min) — the layer-wise pruning stage.
        Candidates are returned sorted by ascending power."""
        d = self.drop()
        fronts = []
        for j in range(len(self.layers)):
            order = sorted(range(len(self.multipliers)),
                           key=lambda i: (self.rel_power[i], d[j, i]))
            front: list[int] = []
            best = float("inf")
            for i in order:
                if d[j, i] < best:
                    front.append(i)
                    best = d[j, i]
            fronts.append(front)
        return fronts


def per_layer_sweep(
    eval_fn: Callable[[ApproxPolicy], float],
    layer_counts: dict[str, int],
    multiplier_names: list[str],
    library,
    mode: str = "lut",
    base: Optional[BackendLike] = None,
    variant: str = "ref",
    batch: bool = False,
    sharding=None,
    rel_power=None,
) -> list[ResilienceRow]:
    """Fig. 4: one layer approximated at a time.

    Sequential (default): one ``eval_fn`` call — and typically one jit
    trace — per (layer, multiplier) pair.  Batched (``batch=True``,
    requires a ``BankableEval``): the multiplier axis is packed into a
    ``LutBank`` and each layer evaluates ALL candidates in one compiled
    program — O(n_layers) programs total instead of
    O(n_layers * n_mult).  Accuracies are bit-identical between the two
    paths; ``sharding`` optionally spreads the bank axis across devices
    (``repro.launch.mesh.bank_sharding``).

    ``multiplier_names`` may MIX operand widths (8-bit entries next to
    composed 12/16-bit ones, DESIGN.md §2.6) — the bank stays one
    compiled program per layer either way, and power is auto-rebased
    onto a common reference (``power.auto_rel_power``) unless an
    explicit ``rel_power`` map is given.
    """
    base = base if base is not None else BackendSpec.golden().materialize()
    if rel_power is None:
        rel_power = auto_rel_power(library, multiplier_names)
    backends = _backends_for(multiplier_names, library, mode,
                             variant=variant)
    rows = []
    if batch:
        traceable = _require_bankable(eval_fn, mode, variant)
        bank = bank_for(multiplier_names, library)
        for layer in layer_counts:
            accs = np.asarray(bank_eval(traceable, bank, mode=mode,
                                        variant=variant, base=base,
                                        layer_pattern=layer,
                                        sharding=sharding))
            for mname, acc in zip(multiplier_names, accs):
                rows.append(_row(library, mname, layer, float(acc),
                                 layer_counts, backends[mname].spec,
                                 rel_power))
        return rows
    for layer in layer_counts:
        for mname, be in backends.items():
            policy = ApproxPolicy(default=base, overrides=[(layer, be)])
            acc = float(eval_fn(policy))
            rows.append(_row(library, mname, layer, acc, layer_counts,
                             be.spec, rel_power))
    return rows


def all_layers_sweep(
    eval_fn: Callable[[ApproxPolicy], float],
    layer_counts: dict[str, int],
    multiplier_names: list[str],
    library,
    mode: str = "lut",
    variant: str = "ref",
    batch: bool = False,
    sharding=None,
    rel_power=None,
) -> list[ResilienceRow]:
    """Table II: the same multiplier in every (conv) layer.

    Sequential (default): one ``eval_fn`` call per multiplier.  Batched
    (``batch=True``, requires a ``BankableEval``): ONE compiled program
    evaluates the whole ``LutBank`` — O(1) traces/compiles regardless
    of ``len(multiplier_names)``, bit-identical accuracies to the
    sequential path.  ``sharding`` optionally spreads the bank axis
    across devices.

    Width-generic: mixed 8/12/16-bit candidate sets bank into the same
    O(1) program (per-lane widths ride the vmapped axis, DESIGN.md
    §2.6), with power auto-rebased onto a common reference
    (``power.auto_rel_power``) unless ``rel_power`` overrides it.
    """
    if rel_power is None:
        rel_power = auto_rel_power(library, multiplier_names)
    backends = _backends_for(multiplier_names, library, mode,
                             variant=variant)
    if batch:
        traceable = _require_bankable(eval_fn, mode, variant)
        bank = bank_for(multiplier_names, library)
        accs = np.asarray(bank_eval(traceable, bank, mode=mode,
                                    variant=variant, sharding=sharding))
        return [_row(library, mname, "all", float(acc), layer_counts,
                     backends[mname].spec, rel_power)
                for mname, acc in zip(multiplier_names, accs)]
    rows = []
    for mname, be in backends.items():
        policy = ApproxPolicy(default=be)
        acc = float(eval_fn(policy))
        rows.append(_row(library, mname, "all", acc, layer_counts,
                         be.spec, rel_power))
    return rows


def _require_bankable(eval_fn, mode: str, variant: str):
    if not can_bank(eval_fn, mode, variant):
        raise ValueError(
            "batch=True needs a BankableEval (an eval_fn with a "
            "traceable core) and a bankable datapath; "
            f"got {type(eval_fn).__name__} with mode={mode!r} "
            f"variant={variant!r}.  Wrap your eval in BankableEval or "
            "use explore(batch=True), which falls back to the "
            "sequential path.")
    return eval_fn.traceable
