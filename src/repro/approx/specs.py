"""Serializable backend specs + cached materialization (DESIGN.md §2.2).

``BackendSpec`` is the *name* of an accelerator datapath configuration:
a frozen, value-hashable, JSON round-trippable record (mode, multiplier,
rank, blocking, STE, kernel variant).  It carries no arrays, so it can
live in configs, checkpoints, serve requests and cache keys.

``spec.materialize(library)`` binds the spec to a concrete
``ApproxLibrary`` and returns a ``MaterializedBackend`` holding the
packed device constants (LUTs / low-rank factors).  Materialization is
LRU-cached per (library, spec): resilience sweeps and the serve engine
that reference the same multiplier twice get the SAME backend object
back, so downstream ``jax.jit`` tracing caches hit instead of
re-tracing per backend instance (the failure mode of the legacy
id-hashed ``MatmulBackend``).
"""
from __future__ import annotations

import json
import weakref
from collections import OrderedDict
from dataclasses import asdict, dataclass, field, replace
from typing import Any, Mapping, Optional

import numpy as np

from .registry import Datapath, get_datapath

_EXACT_MODES = ("f32", "bf16")
_VARIANTS = ("ref", "pallas", "fused")


@dataclass(frozen=True)
class BackendSpec:
    """Value-hashable description of one emulated datapath.

    ``mode`` selects the registered datapath ("f32"/"bf16" bypass
    quantization entirely); ``variant`` selects the kernel
    implementation ("ref" = jnp reference, "pallas" = Pallas kernel).
    ``rank=None`` means auto (smallest R with negligible decomposition
    error, resolved at pack time).

    Width-generic datapaths (DESIGN.md §2.6): ``bit_width`` declares
    the multiplier's operand width (None = infer from the library
    entry; a set value is VALIDATED against the entry at pack time),
    and ``reduce_adder`` optionally declares the composed shift/add
    tree's adder family ("exact", "loa4", "trunc3", or a library adder
    name) — also validated against the composed entry's recipe, so a
    policy JSON carries the full datapath description self-contained.
    """

    mode: str = "bf16"
    multiplier: str = "mul8u_exact"
    rank: Optional[int] = None
    block_m: int = 512
    ste: bool = True
    variant: str = "ref"
    bit_width: Optional[int] = None
    reduce_adder: Optional[str] = None

    def __post_init__(self):
        if self.variant not in _VARIANTS:
            raise ValueError(f"variant must be one of {_VARIANTS}, "
                             f"got {self.variant!r}")
        if self.bit_width is not None and not 8 <= self.bit_width <= 16:
            raise ValueError(
                f"bit_width must be in [8, 16] (8-bit direct LUTs, "
                f"composed tiles above), got {self.bit_width}")
        if self.reduce_adder is not None:
            from repro.core.families import parse_reduce
            parse_reduce(self.reduce_adder)   # raises on bad tokens

    # -- constructors ---------------------------------------------------
    @staticmethod
    def exact(mode: str = "bf16") -> "BackendSpec":
        return BackendSpec(mode=mode)

    @staticmethod
    def golden() -> "BackendSpec":
        """The paper's exact 8-bit reference datapath."""
        return BackendSpec(mode="int8")

    @staticmethod
    def from_library(multiplier: str, mode: str = "lut",
                     rank: Optional[int] = None,
                     variant: str = "ref",
                     bit_width: Optional[int] = None) -> "BackendSpec":
        return BackendSpec(mode=mode, multiplier=multiplier, rank=rank,
                           variant=variant, bit_width=bit_width)

    # -- derived --------------------------------------------------------
    @property
    def is_quantized(self) -> bool:
        return self.mode not in _EXACT_MODES

    @property
    def datapath_name(self) -> str:
        return (self.mode if self.variant == "ref"
                else f"{self.mode}_{self.variant}")

    def with_(self, **changes) -> "BackendSpec":
        return replace(self, **changes)

    # -- serialization --------------------------------------------------
    def to_dict(self) -> dict:
        return asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "BackendSpec":
        known = {f for f in BackendSpec.__dataclass_fields__}
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown BackendSpec fields: {sorted(extra)}")
        return BackendSpec(**dict(d))

    @staticmethod
    def from_json(s: str) -> "BackendSpec":
        return BackendSpec.from_dict(json.loads(s))

    # -- materialization ------------------------------------------------
    def materialize(self, library=None) -> "MaterializedBackend":
        """Bind to ``library`` through the process-wide LRU cache: equal
        (canonicalized) specs get the SAME backend object back, which is
        what lets sequential sweeps share one jit trace per multiplier.
        Batched sweeps bypass per-spec materialization entirely — the
        whole candidate axis packs into one ``LutBank`` instead."""
        return materialize(self, library)


@dataclass(frozen=True, eq=False)  # id-hash: cache guarantees uniqueness
class MaterializedBackend:
    """A spec bound to packed device constants.  ``canonical`` marks
    instances built by ``materialize`` (consts derived from the spec +
    a library) — only those may be identified by spec alone in policy
    cache keys; ad-hoc wrappers around hand-attached arrays are not."""

    spec: BackendSpec
    datapath: Optional[Datapath]       # None for f32/bf16
    consts: Mapping[str, Any] = field(default_factory=dict)
    canonical: bool = False

    @property
    def mode(self) -> str:
        return self.spec.mode

    @property
    def ste(self) -> bool:
        return self.spec.ste

    @property
    def multiplier(self) -> str:
        return self.spec.multiplier

    @property
    def rank(self) -> int:
        """Effective rank after auto-resolution (0 if not low-rank)."""
        u = self.consts.get("u")
        return int(u.shape[0]) if u is not None else int(self.spec.rank or 0)


# ----------------------------------------------------------------------
# Materialization cache
# ----------------------------------------------------------------------
_CACHE: "OrderedDict[tuple[int, BackendSpec], MaterializedBackend]" = \
    OrderedDict()
_CACHE_MAX = 256
_FINALIZED: set[int] = set()
_STATS = {"hits": 0, "misses": 0}


def _evict_library(lid: int) -> None:
    _FINALIZED.discard(lid)
    for k in [k for k in _CACHE if k[0] == lid]:
        del _CACHE[k]
    for k in [k for k in _BANK_CACHE if k[0] == lid]:
        del _BANK_CACHE[k]


def _library_key(library) -> int:
    lid = id(library)
    if lid not in _FINALIZED:
        _FINALIZED.add(lid)
        # evict on library GC so a recycled id can never alias
        weakref.finalize(library, _evict_library, lid)
    return lid


_SPEC_FIELD_DEFAULTS = {"multiplier": "mul8u_exact", "rank": None,
                        "block_m": 512, "bit_width": None,
                        "reduce_adder": None}


def canonicalize(spec: BackendSpec) -> BackendSpec:
    """Reset fields the spec's datapath never reads to their defaults,
    so equivalent configurations share one materialization / cache key
    (e.g. every int8 spec collapses to ``BackendSpec.golden()``).
    Serialization keeps the full spec; only caches canonicalize."""
    if not spec.is_quantized:
        return replace(spec, variant="ref", **_SPEC_FIELD_DEFAULTS)
    try:
        dp = get_datapath(spec.datapath_name)
    except KeyError:
        return spec
    relevant = getattr(dp, "spec_fields",
                       tuple(_SPEC_FIELD_DEFAULTS))
    changes = {f: d for f, d in _SPEC_FIELD_DEFAULTS.items()
               if f not in relevant and getattr(spec, f) != d}
    return replace(spec, **changes) if changes else spec


def materialize(spec: BackendSpec, library=None) -> MaterializedBackend:
    """Pack ``spec`` against ``library`` (default library if None),
    LRU-cached so equal specs share one backend object; the key is the
    canonicalized spec, so specs differing only in fields their
    datapath ignores share one materialization."""
    spec = canonicalize(spec)
    if not spec.is_quantized:
        key = (0, spec)
        datapath = None
    else:
        datapath = get_datapath(spec.datapath_name)
        if datapath.needs_library:
            if library is None:
                from repro.core.library import get_default_library
                library = get_default_library()
            key = (_library_key(library), spec)
        else:
            key = (0, spec)
    hit = _CACHE.get(key)
    if hit is not None:
        _STATS["hits"] += 1
        _CACHE.move_to_end(key)
        return hit
    _STATS["misses"] += 1
    consts = datapath.pack(spec, library) if datapath is not None else {}
    mb = MaterializedBackend(spec=spec, datapath=datapath, consts=consts,
                             canonical=True)
    _CACHE[key] = mb
    while len(_CACHE) > _CACHE_MAX:
        _CACHE.popitem(last=False)
    return mb


# ----------------------------------------------------------------------
# LutBank: the library axis as one device constant (DESIGN.md §2.4)
# ----------------------------------------------------------------------
@dataclass(frozen=True, eq=False)  # id-hash: cache guarantees uniqueness
class LutBank:
    """A stack of tile LUTs — the *multiplier axis* of a resilience
    sweep packed as one ``(n_mult, 256, 256)`` int32 device constant.

    Banks are what the batched resilience engine vmaps over: lane ``i``
    of a banked evaluation runs the model with ``luts[i]`` in every (or
    one) layer, bit-identical to materializing ``specs[i]`` and
    evaluating sequentially.  Build through ``bank_for`` to share banks
    across sweeps of the same (library, names, block_m) — the bank
    analogue of the per-spec materialization cache.

    Width-generic (DESIGN.md §2.6): lanes may MIX operand widths.  An
    8-bit lane's slice is its own product LUT; a composed wide lane's
    slice is its composition TILE's 256x256 LUT, with the lane's
    operand width recorded in ``bit_widths`` (the banked engines
    quantize and compose per lane from these).  All wide lanes of one
    bank must share a reduction tree (``reduce``) — the shift/add tree
    is compiled statically into the one banked program.
    """

    names: tuple[str, ...]
    luts: np.ndarray                  # (n_mult, 256, 256) int32 tiles
    block_m: int = 512
    bit_widths: Optional[tuple[int, ...]] = None   # None = all 8-bit
    reduce: str = "exact"
    #: Per-lane reduction trees (DESIGN.md §2.10).  ``None`` means every
    #: wide lane shares the static ``reduce`` (the historical contract
    #: the static-tree banked engines compile).  A tuple records each
    #: lane's own tree; only the ``fused`` variant can evaluate such a
    #: bank in one program (its kernel takes the tree as runtime data).
    reduces: Optional[tuple[str, ...]] = None

    def __post_init__(self):
        if self.luts.ndim != 3 or self.luts.shape[1:] != (256, 256):
            raise ValueError(
                f"LutBank wants (n, 256, 256) LUTs, got {self.luts.shape}"
                " — banked sweeps run on 256x256 tile LUTs (8-bit "
                "entries directly, composed wide entries via their "
                "tile; DESIGN.md §2.6)")
        if len(self.names) != self.luts.shape[0]:
            raise ValueError("one name per LUT slice required")
        if self.bit_widths is not None:
            if len(self.bit_widths) != len(self.names):
                raise ValueError("one bit width per lane required")
            from repro.approx.quant import TRACED_WIDTHS
            bad = sorted(set(self.bit_widths) - set(TRACED_WIDTHS))
            if bad:
                # the traced calibrate select would silently fall back
                # to its widest branch for any other width
                raise ValueError(
                    f"unsupported lane widths {bad}; banked engines "
                    f"run per-lane widths from {TRACED_WIDTHS}")
        if self.reduces is not None and len(self.reduces) != len(self.names):
            raise ValueError("one reduce per lane required")

    @property
    def n_mult(self) -> int:
        return len(self.names)

    @property
    def is_mixed_reduce(self) -> bool:
        """True when lanes carry more than one distinct reduction tree
        — only the runtime-tree ``fused`` engines can bank such a set."""
        if self.reduces is None:
            return False
        from repro.core.families import parse_reduce
        return len({parse_reduce(r) for r in self.reduces}) > 1

    @property
    def lane_reduce_codes(self) -> np.ndarray:
        """(n_mult, 2) int32 ``encode_reduce`` codes, one per lane (the
        runtime reduction selectors of the fused composed kernels;
        uniform banks repeat the shared ``reduce``)."""
        from repro.core.families import parse_reduce

        from .registry import encode_reduce
        rs = (self.reduces if self.reduces is not None
              else (self.reduce,) * self.n_mult)
        return np.asarray([encode_reduce(parse_reduce(r)) for r in rs],
                          dtype=np.int32)

    @property
    def lane_bits(self) -> np.ndarray:
        """(n_mult,) per-lane operand widths (int32)."""
        if self.bit_widths is None:
            return np.full(self.n_mult, 8, dtype=np.int32)
        return np.asarray(self.bit_widths, dtype=np.int32)

    @property
    def any_wide(self) -> bool:
        """True when any lane runs the composed (>8-bit) datapath —
        the static dispatch bit of the banked engines."""
        return bool((self.lane_bits > 8).any())

    @property
    def lane_masks(self) -> np.ndarray:
        """(n_mult,) uint32 per-lane 2W-bit product masks (0 marks a
        narrow lane — the banked engines' selector-and-truncation,
        matching the composed netlist's output width)."""
        from .registry import lane_mask_np
        return lane_mask_np(self.lane_bits)

    def spec(self, i: int, mode: str = "lut",
             variant: str = "ref") -> BackendSpec:
        """The serializable spec lane ``i`` of a banked sweep stands
        for (``bit_width``/``reduce_adder`` left to library inference,
        matching the specs sequential sweeps build)."""
        return BackendSpec(mode=mode, multiplier=self.names[i],
                           block_m=self.block_m, variant=variant)

    @staticmethod
    def from_library(names, library=None, block_m: int = 512,
                     mixed_reduce: bool = False) -> "LutBank":
        """Pack a (possibly mixed-width) candidate set: 8-bit entries
        contribute their own LUT, composed wide entries their tile's.
        By default raises when wide lanes disagree on the reduction
        tree (the static-tree banked engines compile ONE shift/add
        tree) — split such sweeps into one bank per reduction, or pass
        ``mixed_reduce=True`` to record per-lane trees for the runtime-
        tree ``fused`` engines (DESIGN.md §2.10)."""
        from repro.core.families import parse_reduce
        if library is None:
            from repro.core.library import get_default_library
            library = get_default_library()
        from repro.approx.quant import TRACED_WIDTHS
        names = tuple(names)
        luts, widths, reduces = [], [], {}
        for n in names:
            entry = library.entry(n)
            comp = library.composition_of(n)
            if entry.width not in TRACED_WIDTHS:
                raise ValueError(
                    f"bank lane {n!r} is {entry.width}-bit; banked "
                    f"sweeps support widths {TRACED_WIDTHS} (per-lane "
                    "width is selected at runtime from this set)")
            luts.append(np.asarray(library.tile_lut(n), dtype=np.int32))
            widths.append(int(entry.width))
            if comp is not None:
                reduces[n] = comp["reduce"]
        reduce = "exact"
        per_lane: Optional[tuple] = None
        if reduces:
            parsed = {parse_reduce(r) for r in reduces.values()}
            if len(parsed) > 1:
                if not mixed_reduce:
                    raise ValueError(
                        "mixed reduction trees in one bank: "
                        f"{sorted(set(reduces.values()))} — a banked "
                        "sweep compiles one static shift/add tree; "
                        "sweep each reduction family in its own bank, "
                        "or pass mixed_reduce=True to bank them "
                        "through the runtime-tree fused engines")
                per_lane = tuple(reduces.get(n, "exact") for n in names)
            else:
                reduce = next(iter(reduces.values()))
        return LutBank(names=names, luts=np.stack(luts), block_m=block_m,
                       bit_widths=tuple(widths), reduce=reduce,
                       reduces=per_lane)


# ----------------------------------------------------------------------
# PolicyBank: heterogeneous per-layer assignments over one LutBank
# (DESIGN.md §2.5)
# ----------------------------------------------------------------------
@dataclass(frozen=True, eq=False)  # id-hash: ndarray field
class PolicyBank:
    """K heterogeneous per-layer multiplier assignments sharing one
    ``LutBank`` — the *policy axis* of a heterogeneous sweep.

    ``assign[p, j]`` is the index into ``bank.names`` of the multiplier
    policy ``p`` uses in layer ``layers[j]``; layers not named here run
    the evaluation's base backend (golden int8 by default).  Row ``p``
    therefore stands for the serializable
    ``ApproxPolicy(default=base, overrides=spec_overrides(p))``, and
    ``repro.approx.layers.policy_bank_eval`` evaluates every row in one
    compiled program by gathering each layer's LUT lane
    ``luts[assign[:, j]]`` through the banked kernel — bit-identical to
    K sequential override evaluations.
    """

    bank: LutBank
    layers: tuple[str, ...]
    assign: np.ndarray                # (n_policies, n_layers) intp

    def __post_init__(self):
        a = np.asarray(self.assign, dtype=np.int32)
        if a.ndim != 2 or a.shape[1] != len(self.layers):
            raise ValueError(
                f"assign must be (n_policies, {len(self.layers)}), "
                f"got {a.shape}")
        if a.size and (a.min() < 0 or a.max() >= self.bank.n_mult):
            raise ValueError(
                f"assign indices must be in [0, {self.bank.n_mult}); "
                f"got range [{a.min()}, {a.max()}]")
        object.__setattr__(self, "assign", a)

    @property
    def n_policies(self) -> int:
        return int(self.assign.shape[0])

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def assignment(self, p: int) -> dict[str, str]:
        """Row ``p`` as a layer-name -> multiplier-name mapping."""
        return {layer: self.bank.names[self.assign[p, j]]
                for j, layer in enumerate(self.layers)}

    def spec_overrides(self, p: int, mode: str = "lut",
                       variant: str = "ref"
                       ) -> list[tuple[str, BackendSpec]]:
        """Serializable ``ApproxPolicy`` overrides for row ``p`` (layer
        order preserved; first-match-wins is irrelevant because layer
        names are exact, disjoint patterns)."""
        return [(layer, BackendSpec(mode=mode, multiplier=name,
                                    block_m=self.bank.block_m,
                                    variant=variant))
                for layer, name in self.assignment(p).items()]

    @staticmethod
    def from_assignments(assignments, library=None,
                         layers=None, block_m: int = 512,
                         fill: Optional[str] = None) -> "PolicyBank":
        """Pack layer->multiplier mappings into one shared bank.

        ``assignments`` is a sequence of dicts; ``layers`` defaults to
        the union of their keys in first-appearance order.  Every
        mapping must cover every layer (partial policies are expressed
        by leaving the layer out of ``layers``, not out of one row) —
        unless ``fill`` names a multiplier, in which case a row's
        unassigned layers run that multiplier.  ``fill="mul8u_exact"``
        keeps filled lanes bit-identical to the golden-int8 base the
        sequential evaluations default to (the exact 8-bit LUT computes
        the same products), which is how module-family assignments with
        disjoint layer coverage share one bank (DESIGN.md §2.12).  The
        distinct multiplier names are deduplicated into a single
        ``bank_for``-cached ``LutBank``.
        """
        assignments = list(assignments)
        if layers is None:
            layers = []
            for a in assignments:
                for name in a:
                    if name not in layers:
                        layers.append(name)
        layers = tuple(layers)
        names: list[str] = []
        rows: list[Mapping[str, str]] = []
        for a in assignments:
            missing = [l for l in layers if l not in a]
            if missing and fill is None:
                raise ValueError(
                    f"assignment {a!r} misses layers {missing} "
                    "(pass fill=<multiplier name> to pad partial rows)")
            row = dict(a) if not missing else {
                **{l: fill for l in missing}, **a}
            rows.append(row)
            for l in layers:
                if row[l] not in names:
                    names.append(row[l])
        bank = bank_for(names, library, block_m=block_m)
        index = {n: i for i, n in enumerate(bank.names)}
        assign = np.asarray([[index[r[l]] for l in layers]
                             for r in rows], dtype=np.int32)
        return PolicyBank(bank=bank, layers=layers, assign=assign)

    @staticmethod
    def uniform(names, layers, library=None,
                block_m: int = 512) -> "PolicyBank":
        """One row per multiplier name, assigned to every layer — the
        heterogeneous engine restricted to uniform policies (the
        equal-assignment consistency axis CI checks)."""
        names = list(names)
        return PolicyBank.from_assignments(
            [{l: n for l in layers} for n in names],
            library=library, layers=layers, block_m=block_m)

    @staticmethod
    def from_policies(policies, layers, library=None,
                      block_m: int = 512, mode: str = "lut"
                      ) -> "PolicyBank":
        """Bank assembly from *request* policies (DESIGN.md §2.8): each
        ``ApproxPolicy`` is resolved over ``layers`` via
        ``policy_assignment`` (fnmatch semantics, so uniform and
        partially-overridden policies both work), the distinct
        multiplier names deduplicate into one shared ``LutBank``, and
        row ``p`` of the result is policy ``p``'s per-layer lane
        assignment — the serve engine's request→lane mapping."""
        assignments = [policy_assignment(p, layers, mode=mode,
                                         block_m=block_m)
                       for p in policies]
        return PolicyBank.from_assignments(assignments, library=library,
                                           layers=tuple(layers),
                                           block_m=block_m)


def policy_assignment(policy, layers, *, mode: str = "lut",
                      block_m: int = 512) -> dict[str, str]:
    """Resolve an ``ApproxPolicy`` to a layer-tag → multiplier-name
    mapping over ``layers`` — the per-request half of serve-time bank
    assembly.  Every layer must resolve to a banked ``mode`` spec with
    the bank's ``block_m``; anything else (an f32 default, a lowrank
    override, a mismatched blocking) cannot ride a LUT-bank lane and
    raises with the offending layer named."""
    from .layers import spec_of   # runtime import: layers imports us
    out: dict[str, str] = {}
    for layer in layers:
        spec = spec_of(policy.backend_for(layer))
        if spec.mode != mode:
            raise ValueError(
                f"policy resolves layer {layer!r} to mode "
                f"{spec.mode!r}; mixed-policy serving batches every "
                f"request through the banked {mode!r} datapath — "
                f"express the request as a {mode!r}-mode policy "
                "(multiplier='mul8u_exact' emulates the exact product)")
        if spec.block_m != block_m:
            raise ValueError(
                f"policy resolves layer {layer!r} with block_m="
                f"{spec.block_m}, but the shared bank blocks at "
                f"{block_m} — one banked program compiles one blocking")
        out[layer] = spec.multiplier
    return out


_BANK_CACHE: "OrderedDict[tuple, LutBank]" = OrderedDict()
_BANK_CACHE_MAX = 16


def bank_for(names, library=None, block_m: int = 512,
             mixed_reduce: bool = False) -> LutBank:
    """LRU-cached ``LutBank.from_library``: repeated sweeps over the
    same candidate set (all-layers then per-layer, or explore() called
    twice) reuse one packed bank instead of restacking LUTs."""
    if library is None:
        from repro.core.library import get_default_library
        library = get_default_library()
    key = (_library_key(library), tuple(names), int(block_m),
           bool(mixed_reduce))
    hit = _BANK_CACHE.get(key)
    if hit is not None:
        _BANK_CACHE.move_to_end(key)
        return hit
    bank = LutBank.from_library(names, library, block_m=block_m,
                                mixed_reduce=mixed_reduce)
    _BANK_CACHE[key] = bank
    while len(_BANK_CACHE) > _BANK_CACHE_MAX:
        _BANK_CACHE.popitem(last=False)
    return bank


def materialize_cache_stats() -> dict:
    return {"hits": _STATS["hits"], "misses": _STATS["misses"],
            "size": len(_CACHE)}


def clear_materialize_cache() -> None:
    _CACHE.clear()
    _STATS["hits"] = _STATS["misses"] = 0
