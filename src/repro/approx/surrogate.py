"""Surrogate predict stage for the heterogeneous DSE (DESIGN.md §2.11).

The two-stage ``explore_heterogeneous`` (predict → verify, DESIGN.md
§2.5) historically built its prediction-stage component models from a
FULL exact per-layer sweep: O(n_layers × n_circuits) device
evaluations, the named scaling wall for thousands-of-circuits libraries
× 50+-layer models.  This module replaces that sweep with the autoAx
move (Mrazek et al., 2019) in ApproxGNN's feature style (Vlcek &
Mrazek, 2025): train a small model on a SUBSAMPLE of exact sweep rows,
predict per-layer quality for every other circuit from features the
library already carries, and keep the exact batched verification as the
safety net.

Three layers:

  * ``circuit_features`` / ``feature_matrix`` — a fixed-width vector
    per ``CircuitEntry``: the six error statistics from
    ``core.metrics`` (log-compressed — wce/mse span orders of
    magnitude), the cost axes (rel power, area, delay), width/source
    tags, and netlist-structure terms (active-gate histogram, logic
    depth, node count) from ``core.netlist``.  Structure-only features
    double as the input of the learned COST head, which must work for
    circuits whose error/cost reports don't exist yet.
  * ``fit_surrogate`` — trains a small JAX MLP mapping a circuit's
    feature vector to its per-layer quality-DROP vector, on any list of
    exact sweep rows (``ResilienceRow`` or ``DesignPoint`` duck-typed:
    ``.layer``/``.multiplier``/``.accuracy``) — ``ExploreResult`` and
    ``BENCH_heterogeneous`` rows are valid corpora as-is.  A
    deterministic held-out split yields per-layer Spearman fidelity
    diagnostics and a CALIBRATION band: the quantile of the held-out
    |total predicted drop − total measured drop| residuals, which the
    beam adds to its quality threshold so the surrogate's error widens
    the shortlist instead of silently cutting good compositions.
  * ``surrogate_components`` — the drop-in predict stage: sweep a
    deterministic power-spread subset of the candidate multipliers
    exactly, fit, predict the rest, and return a ``LayerComponents``
    where measured cells stay exact and unmeasured ones are surrogate
    predictions.  Power is NOT predicted here — the library's
    count-weighted power model is already exact and free (the learned
    cost head is reported as a fidelity diagnostic for the
    unseen-circuit case, not used for accounting).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.gates import N_FUNCS
from .power import auto_rel_power
from .ranking import spearman
from .resilience import LayerComponents, ResilienceRow, per_layer_sweep

# ----------------------------------------------------------------------
# Feature extraction
# ----------------------------------------------------------------------
_SOURCES = ("exact", "evolved", "truncation", "bam", "loa", "composed")

FEATURE_NAMES: tuple[str, ...] = (
    tuple(f"log1p_{m}" for m in
          ("er", "mae", "mse", "mre", "wce", "wcre"))
    + ("rel_power", "log1p_area", "log1p_delay")
    + ("width_over_8",)
    + tuple(f"src_{s}" for s in _SOURCES)
    + tuple(f"gate_frac_{f}" for f in range(N_FUNCS))
    + ("log1p_n_active", "log1p_depth", "n_i_over_16", "n_o_over_16")
)

# structure-only block (width/source/gates/depth/io) — everything after
# the error statistics and cost axes; the learned cost head trains on
# this slice alone, since for a genuinely unseen circuit the error and
# cost reports are exactly what doesn't exist yet
STRUCTURE_SLICE = slice(9, None)


def circuit_features(entry) -> np.ndarray:
    """Fixed-width float64 feature vector for one ``CircuitEntry``, in
    ``FEATURE_NAMES`` order."""
    nl = entry.netlist
    n_active = nl.n_active()
    hist = nl.gate_histogram().astype(np.float64)
    frac = hist / max(n_active, 1)
    parts = [
        np.log1p(entry.errors.as_vector()),
        np.array([entry.rel_power,
                  np.log1p(entry.cost.area),
                  np.log1p(entry.cost.delay)]),
        np.array([entry.width / 8.0]),
        np.array([1.0 if entry.source == s else 0.0 for s in _SOURCES]),
        frac,
        np.array([np.log1p(n_active), np.log1p(nl.logic_depth()),
                  nl.n_i / 16.0, nl.n_o / 16.0]),
    ]
    vec = np.concatenate(parts)
    assert vec.shape == (len(FEATURE_NAMES),)
    return vec


def feature_matrix(entries: Sequence) -> np.ndarray:
    """(n_entries, n_features) feature matrix."""
    return np.stack([circuit_features(e) for e in entries])


# ----------------------------------------------------------------------
# Model
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class SurrogateConfig:
    """Hyperparameters of the QoR surrogate.  The defaults are sized
    for the regime this stage lives in — tens of training circuits,
    O(10) layers — where a small full-batch MLP with weight decay is
    the right capacity."""

    hidden: tuple[int, ...] = (32, 32)
    epochs: int = 1500
    lr: float = 1e-2
    weight_decay: float = 1e-4
    seed: int = 0
    val_fraction: float = 0.2
    calibration_quantile: float = 0.9
    ridge_lambda: float = 1e-2      # learned cost head regularizer

    def as_dict(self) -> dict:
        return {
            "hidden": list(self.hidden), "epochs": self.epochs,
            "lr": self.lr, "weight_decay": self.weight_decay,
            "seed": self.seed, "val_fraction": self.val_fraction,
            "calibration_quantile": self.calibration_quantile,
            "ridge_lambda": self.ridge_lambda,
        }


def _init_params(rng: np.random.Generator, sizes: Sequence[int]) -> list:
    import jax.numpy as jnp

    params = []
    for fan_in, fan_out in zip(sizes[:-1], sizes[1:]):
        w = rng.normal(0.0, 1.0 / np.sqrt(fan_in), (fan_in, fan_out))
        params.append((jnp.asarray(w, jnp.float32),
                       jnp.zeros((fan_out,), jnp.float32)))
    return params


def _apply(params: list, x):
    import jax.numpy as jnp

    h = x
    for w, b in params[:-1]:
        h = jnp.tanh(h @ w + b)
    w, b = params[-1]
    return h @ w + b


def _train_mlp(params: list, x: np.ndarray, y: np.ndarray,
               cfg: SurrogateConfig) -> list:
    """Full-batch Adam on MSE + L2; one jitted ``fori_loop`` over
    epochs.  Deterministic: fixed init seed, fixed data, CPU-exact."""
    import jax
    import jax.numpy as jnp

    xj = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    b1, b2, eps = 0.9, 0.999, 1e-8

    def loss_fn(p):
        pred = _apply(p, xj)
        l2 = sum(jnp.sum(w * w) for w, _ in p)
        return jnp.mean((pred - yj) ** 2) + cfg.weight_decay * l2

    def step(i, state):
        p, m, v = state
        g = jax.grad(loss_fn)(p)
        m = jax.tree_util.tree_map(
            lambda mi, gi: b1 * mi + (1 - b1) * gi, m, g)
        v = jax.tree_util.tree_map(
            lambda vi, gi: b2 * vi + (1 - b2) * gi * gi, v, g)
        t = (i + 1).astype(jnp.float32)
        p = jax.tree_util.tree_map(
            lambda pi, mi, vi: pi - cfg.lr * (mi / (1 - b1 ** t))
            / (jnp.sqrt(vi / (1 - b2 ** t)) + eps), p, m, v)
        return p, m, v

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    final, _, _ = jax.jit(
        lambda p: jax.lax.fori_loop(
            0, cfg.epochs, step,
            (p, zeros, jax.tree_util.tree_map(jnp.zeros_like, p))))(params)
    return jax.tree_util.tree_map(np.asarray, final)


def _standardize(x: np.ndarray, mu: np.ndarray,
                 sigma: np.ndarray) -> np.ndarray:
    return (x - mu) / sigma


def _stats(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    mu = x.mean(axis=0)
    sigma = np.maximum(x.std(axis=0), 1e-8)
    return mu, sigma


# ----------------------------------------------------------------------
# Predictor
# ----------------------------------------------------------------------
@dataclass
class SurrogatePredictor:
    """Trained QoR (+ cost) surrogate over one workload's layers.

    ``predict_drop`` maps circuit names to a (n_layers, n_names)
    matrix of predicted primary-metric DEGRADATIONS (clipped >= 0,
    the ``LayerComponents.drop`` convention); ``predict_quality``
    re-bases onto the baseline in the primary's direction.
    ``calibration`` is the held-out quantile of |total predicted −
    total measured| drop — the band the beam adds to its quality
    threshold (DESIGN.md §2.11)."""

    layers: tuple[str, ...]
    baseline: float
    direction: str
    params: list
    x_mu: np.ndarray
    x_sigma: np.ndarray
    y_mu: np.ndarray
    y_sigma: np.ndarray
    train_names: tuple[str, ...]
    val_names: tuple[str, ...]
    calibration: float
    config: SurrogateConfig
    cost_coef: Optional[np.ndarray] = None
    cost_mean: float = 0.0
    diagnostics: dict = field(default_factory=dict)

    def _features(self, names: Sequence[str], library) -> np.ndarray:
        return feature_matrix([library.entry(n) for n in names])

    def predict_drop(self, names: Sequence[str], library) -> np.ndarray:
        """(n_layers, n_names) predicted per-layer drops, >= 0."""
        x = _standardize(self._features(names, library),
                         self.x_mu, self.x_sigma)
        import jax.numpy as jnp

        pred = np.asarray(_apply(self.params, jnp.asarray(x, jnp.float32)))
        pred = pred * self.y_sigma + self.y_mu          # (n_names, n_layers)
        return np.maximum(pred.T.astype(np.float64), 0.0)

    def predict_quality(self, names: Sequence[str], library) -> np.ndarray:
        """(n_layers, n_names) predicted primary-metric values — the
        ``LayerComponents.quality`` convention (a min primary RISES by
        the drop, a max primary falls)."""
        d = self.predict_drop(names, library)
        return (self.baseline + d if self.direction == "min"
                else self.baseline - d)

    def predict_rel_power(self, names: Sequence[str], library) -> np.ndarray:
        """Learned cost head: relative power from STRUCTURE-ONLY
        features (ridge on log power) — the unseen-circuit estimate.
        Accounting everywhere else uses the library's exact values;
        this exists for circuits that don't have them yet."""
        if self.cost_coef is None:
            raise ValueError("predictor was fit without a cost head")
        x = _standardize(self._features(names, library),
                         self.x_mu, self.x_sigma)[:, STRUCTURE_SLICE]
        return np.exp(x @ self.cost_coef + self.cost_mean)

    def summary(self) -> dict:
        """JSON-able training/fidelity record (rides on
        ``ExploreResult.surrogate`` and ``BENCH_dse.json``)."""
        return {
            "layers": list(self.layers),
            "n_train": len(self.train_names),
            "n_val": len(self.val_names),
            "train_names": list(self.train_names),
            "val_names": list(self.val_names),
            "calibration": self.calibration,
            "direction": self.direction,
            "config": self.config.as_dict(),
            **self.diagnostics,
        }


def _rows_to_matrix(rows, baseline: float, direction: str):
    """Group duck-typed sweep rows (``.layer``/``.multiplier``/
    ``.accuracy``; per-layer rows only) into (layers, names, drop
    matrix (n_names, n_layers)).  Missing cells mean "no measured
    damage" — zero drop, the ``LayerComponents.from_rows`` fallback."""
    layers = tuple(dict.fromkeys(
        r.layer for r in rows if r.layer not in ("all", "hetero")))
    names = tuple(dict.fromkeys(
        r.multiplier for r in rows if r.layer not in ("all", "hetero")))
    li = {l: j for j, l in enumerate(layers)}
    ni = {n: i for i, n in enumerate(names)}
    drops = np.zeros((len(names), len(layers)), dtype=np.float64)
    for r in rows:
        if r.layer in ("all", "hetero"):
            continue
        d = (r.accuracy - baseline if direction == "min"
             else baseline - r.accuracy)
        drops[ni[r.multiplier], li[r.layer]] = max(float(d), 0.0)
    return layers, names, drops


def _split_indices(names: Sequence[str], library,
                   val_fraction: float) -> tuple[list[int], list[int]]:
    """Deterministic held-out split: order circuits along the power
    axis (name-tiebroken) and hold out every k-th — the validation set
    then spans the cheap-to-accurate range instead of clustering."""
    order = sorted(range(len(names)),
                   key=lambda i: (library.entry(names[i]).rel_power,
                                  names[i]))
    n_val = int(round(val_fraction * len(names)))
    if n_val == 0 or len(names) - n_val < 2:
        return list(order), []
    k = max(2, len(names) // n_val)
    val = [order[i] for i in range(1, len(names), k)][:n_val]
    train = [i for i in order if i not in val]
    return train, val


def fit_surrogate(rows, library, baseline: float,
                  direction: str = "max",
                  config: Optional[SurrogateConfig] = None
                  ) -> SurrogatePredictor:
    """Train the QoR surrogate on exact per-layer sweep rows.

    ``rows`` is any list of ``ResilienceRow`` or ``DesignPoint``
    objects (duck-typed); "all"/"hetero" rows are ignored.  Quality is
    learned as standardized per-layer DROP vectors from standardized
    circuit features; a deterministic held-out split provides the
    calibration band and per-layer Spearman diagnostics, and a ridge
    cost head on the structure-only feature block learns relative
    power for the unseen-circuit case.
    """
    cfg = config or SurrogateConfig()
    layers, names, drops = _rows_to_matrix(rows, baseline, direction)
    if not layers or len(names) < 3:
        raise ValueError(
            f"fit_surrogate needs per-layer rows over >= 3 circuits; "
            f"got {len(names)} circuits x {len(layers)} layers")
    x_all = feature_matrix([library.entry(n) for n in names])
    tr, va = _split_indices(names, library, cfg.val_fraction)

    x_mu, x_sigma = _stats(x_all[tr])
    y_mu, y_sigma = _stats(drops[tr])
    xs = _standardize(x_all, x_mu, x_sigma)
    ys = _standardize(drops, y_mu, y_sigma)

    rng = np.random.default_rng(cfg.seed)
    sizes = [x_all.shape[1], *cfg.hidden, len(layers)]
    params = _train_mlp(_init_params(rng, sizes), xs[tr], ys[tr], cfg)

    pred = SurrogatePredictor(
        layers=layers, baseline=float(baseline), direction=direction,
        params=params, x_mu=x_mu, x_sigma=x_sigma, y_mu=y_mu,
        y_sigma=y_sigma,
        train_names=tuple(names[i] for i in tr),
        val_names=tuple(names[i] for i in va),
        calibration=0.0, config=cfg)

    # learned cost head (structure-only ridge on log rel power)
    rp = np.array([library.entry(n).rel_power for n in names])
    y_log = np.log(np.maximum(rp, 1e-6))
    xsr = xs[tr][:, STRUCTURE_SLICE]
    lam = cfg.ridge_lambda
    pred.cost_mean = float(y_log[tr].mean())
    yc = y_log[tr] - pred.cost_mean
    pred.cost_coef = np.linalg.solve(
        xsr.T @ xsr + lam * np.eye(xsr.shape[1]), xsr.T @ yc)

    # held-out calibration + fidelity diagnostics (falls back to the
    # train split for tiny corpora — flagged, since train residuals
    # understate the band)
    hold = va if va else tr
    d_pred = pred.predict_drop([names[i] for i in hold], library)
    d_true = drops[hold].T
    total_res = np.abs(d_pred.sum(axis=0) - d_true.sum(axis=0))
    cell_res = np.abs(d_pred - d_true)
    pred.calibration = float(np.quantile(total_res,
                                         cfg.calibration_quantile))
    rp_pred = pred.predict_rel_power([names[i] for i in hold], library)
    pred.diagnostics = {
        "holdout": "val" if va else "train",
        "cell_residual_q": float(np.quantile(
            cell_res, cfg.calibration_quantile)),
        "total_residual_mean": float(total_res.mean()),
        "val_spearman": {
            layer: spearman(d_pred[j], d_true[j])
            for j, layer in enumerate(layers)},
        "power_spearman": spearman(rp_pred, rp[hold]),
    }
    return pred


# ----------------------------------------------------------------------
# Predict-stage orchestration
# ----------------------------------------------------------------------
def train_subset(multipliers: Sequence[str], library,
                 train_fraction: float,
                 rel_power: Optional[dict] = None) -> list[str]:
    """Deterministic training subset: candidates sorted along the
    power axis, then evenly spaced indices including both endpoints —
    the subsample sees the whole cheap-to-exact range, which is what
    makes the drop regression interpolative rather than extrapolative.
    At least 6 circuits (or all of them, below that)."""
    def rp(name: str) -> float:
        if rel_power is not None and name in rel_power:
            return float(rel_power[name])
        return float(library.entry(name).rel_power)

    ordered = sorted(multipliers, key=lambda n: (rp(n), n))
    n = len(ordered)
    n_train = max(6, int(np.ceil(train_fraction * n)))
    if n_train >= n:
        return list(ordered)
    idx = np.unique(np.round(np.linspace(0, n - 1, n_train)).astype(int))
    return [ordered[i] for i in idx]


def surrogate_components(
    eval_fn: Callable,
    layer_counts: dict[str, int],
    multipliers: Sequence[str],
    library,
    baseline: float,
    direction: str = "max",
    train_fraction: float = 0.25,
    mode: str = "lut",
    variant: str = "ref",
    base=None,
    batch: bool = False,
    sharding=None,
    rel_power=None,
    config: Optional[SurrogateConfig] = None,
) -> tuple[LayerComponents, SurrogatePredictor, list[ResilienceRow]]:
    """The surrogate predict stage as a ``LayerComponents`` factory.

    Runs the exact per-layer sweep ONLY over a deterministic
    power-spread ``train_fraction`` of the candidates, fits the
    surrogate on those rows, and predicts quality for the rest:
    ``quality[j, i]`` holds the exact measurement where one exists
    and the surrogate prediction otherwise.  Relative power stays the
    library's exact accounting for EVERY candidate (it costs nothing).
    Returns ``(components, predictor, measured_rows)`` — the rows feed
    result caches and ``per_layer`` reporting exactly like the full
    sweep's would.
    """
    multipliers = list(multipliers)
    rp_map = (rel_power if rel_power is not None
              else auto_rel_power(library, multipliers))
    names_tr = train_subset(multipliers, library, train_fraction,
                            rel_power=rp_map)
    rows = per_layer_sweep(eval_fn, layer_counts, names_tr, library,
                           mode=mode, base=base, variant=variant,
                           batch=batch, sharding=sharding,
                           rel_power=rp_map)
    predictor = fit_surrogate(rows, library, baseline,
                              direction=direction, config=config)

    layers = tuple(layer_counts)
    quality = predictor.predict_quality(multipliers, library)
    # exact measurements override their own predictions — the surrogate
    # only speaks for circuits the sweep never touched
    li = {l: j for j, l in enumerate(layers)}
    mi = {m: i for i, m in enumerate(multipliers)}
    for r in rows:
        if r.layer in ("all", "hetero"):
            continue
        quality[li[r.layer], mi[r.multiplier]] = r.accuracy

    rel = np.array([
        rp_map[n] if rp_map is not None else library.entry(n).rel_power
        for n in multipliers])
    components = LayerComponents(
        layers=layers, multipliers=tuple(multipliers), quality=quality,
        rel_power=rel,
        counts=tuple(int(layer_counts[l]) for l in layers),
        total_count=int(sum(layer_counts.values())),
        baseline=float(baseline), direction=direction)
    return components, predictor, rows
