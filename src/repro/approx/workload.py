"""Workload layer: model + eval data + NAMED quality metrics
(DESIGN.md §2.7).

A ``Workload`` bundles everything the DSE needs to measure application-
level quality under an ``ApproxPolicy``, in both calling conventions
the sweeps understand (subsuming the older scalar ``BankableEval``):

  * ``fn(policy) -> {metric: float}`` — the sequential closure (free to
    jit internally, call numpy, return Python floats), and
  * ``traceable_metrics(policy) -> {metric: jax scalar}`` — its
    pure-jax core, which the batched engines wrap in ``jit(vmap(...))``
    over a multiplier bank (DESIGN.md §2.4).

Metric names are registered as ``workload``-provenance axes in
``repro.approx.objectives`` at construction, each with a direction, so
``explore(workload=..., objectives=(...))`` can Pareto over any mix of
quality metrics and library cost axes.  ``primary`` names the metric
legacy scalar call sites read: ``workload(policy)`` returns
``float(fn(policy)[primary])`` and the scalar-only ``.traceable``
property projects the traceable core the same way, so a ``Workload``
drops into every ``eval_fn=``-shaped call site unchanged.

Shipped adapters (built on ``repro.models``):

  * ``classification(cfg, params)`` — ResNet / synthetic-CIFAR top-1
    accuracy, the paper's case study (the historical behavior);
  * ``logit_fidelity(forward, inputs)`` — generic logit-MAE + top-1
    agreement vs the f32 model (the continuous quality axis where
    datapath width shows; DESIGN.md §2.6);
  * ``lm_fidelity(cfg)`` / ``lm_perplexity(cfg)`` — the same fidelity
    metrics, and loss/perplexity, for any registered decoder-family LM
    config (``repro.configs.get_config``/``repro.models.registry``),
    so resilience analysis and DSE run over LM scenarios, not just
    ResNet.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Mapping, Optional, Sequence, Union

from .layers import ApproxPolicy, EXACT_POLICY
from .objectives import ensure_objective

MetricFn = Callable[[ApproxPolicy], Mapping[str, Any]]


@dataclass
class Workload:
    """A named evaluation scenario: policy in, metric dict out.

    ``metrics`` fixes the metric names (and their order in sweep rows);
    ``directions`` maps each to "max"/"min" (default "max") and is
    registered into the objectives registry at construction.
    ``layer_counts`` optionally carries the model's per-layer
    multiplication counts so ``explore(workload=...)`` needs no second
    argument.  ``traceable_metrics`` may be ``None`` — the workload
    then runs on the sequential sweep paths only (``batch=True``
    requests fall back, exactly like a plain-callable eval)."""

    name: str
    fn: MetricFn
    metrics: tuple[str, ...]
    primary: Optional[str] = None
    traceable_metrics: Optional[MetricFn] = None
    directions: Mapping[str, str] = field(default_factory=dict)
    layer_counts: Optional[dict[str, int]] = None

    def __post_init__(self):
        if not self.metrics:
            raise ValueError("a Workload needs at least one metric")
        if self.primary is None:
            self.primary = self.metrics[0]
        if self.primary not in self.metrics:
            raise ValueError(f"primary {self.primary!r} not among "
                             f"metrics {self.metrics}")
        for m in self.metrics:
            ensure_objective(m, self.directions.get(m, "max"),
                             source="workload")

    # -- calling conventions -------------------------------------------
    def measure(self, policy: ApproxPolicy) -> dict[str, float]:
        """Sequential evaluation: every metric as a Python float, in
        ``metrics`` order."""
        out = self.fn(policy)
        return {m: float(out[m]) for m in self.metrics}

    def __call__(self, policy: ApproxPolicy) -> float:
        """Legacy scalar convention: the primary metric's value."""
        return float(self.fn(policy)[self.primary])

    @property
    def primary_direction(self) -> str:
        return self.directions.get(self.primary, "max")

    @property
    def traceable(self):
        """Scalar-primary projection of the traceable core — the shape
        ``bank_eval``/``policy_bank_eval`` call sites and ``can_bank``
        historically expect (None when the workload has no traceable
        core; unused metric computations are dead-code-eliminated by
        XLA)."""
        if self.traceable_metrics is None:
            return None
        tm, primary = self.traceable_metrics, self.primary
        return lambda policy: tm(policy)[primary]

    def cached(self, cache: dict) -> "Workload":
        """The same workload through a policy-keyed metric-dict cache
        (the ``explore()`` resume/widen mechanism)."""
        def fn(policy: ApproxPolicy) -> dict[str, float]:
            key = policy.cache_key()
            if key not in cache:
                cache[key] = self.measure(policy)
            return cache[key]
        return replace(self, fn=fn)


def as_workload(eval_fn) -> Workload:
    """Normalize any sweep evaluation handle into a ``Workload``:

      * a ``Workload`` passes through unchanged;
      * a ``BankableEval`` (anything with ``fn`` + ``traceable``
        attributes) becomes a single-metric ``accuracy`` workload whose
        traceable core is preserved for the batched engines;
      * a plain callable becomes a sequential-only ``accuracy``
        workload.

    This is the shim that keeps every pre-§2.7 ``eval_fn(policy) ->
    float`` call site working across the sweeps and the DSE facade."""
    if isinstance(eval_fn, Workload):
        return eval_fn
    traceable = getattr(eval_fn, "traceable", None)
    seq = getattr(eval_fn, "fn", eval_fn)
    if not callable(seq):
        raise TypeError(f"not an evaluation function: {eval_fn!r}")
    return Workload(
        name=getattr(eval_fn, "name", None)
        or getattr(eval_fn, "__name__", type(eval_fn).__name__),
        fn=lambda policy: {"accuracy": seq(policy)},
        metrics=("accuracy",),
        traceable_metrics=(None if traceable is None else
                           (lambda policy: {"accuracy": traceable(policy)})),
        directions={"accuracy": "max"})


# ----------------------------------------------------------------------
# Shipped adapters
# ----------------------------------------------------------------------
def classification(cfg, params, *, eval_n: int = 256, batch: int = 64,
                   name: Optional[str] = None,
                   fidelity: bool = False) -> Workload:
    """ResNet / synthetic-CIFAR top-1 accuracy — the paper's case-study
    quality metric, as a bank-traceable workload (drop-in for the
    historical ``BankableEval`` the resilience benchmarks built by
    hand).

    ``fidelity=True`` adds ``logit_mae`` (minimize, PRIMARY) against
    the golden-int8 reference logits: the continuous quality axis the
    surrogate predict stage trains and gates on (DESIGN.md §2.11) —
    top-1 accuracy quantizes to 1/eval_n steps, which starves rank
    statistics of resolution while logit MAE keeps moving.  Accuracy
    stays measured on every point either way."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data.synthetic import CifarBatches
    from repro.models import resnet

    data = CifarBatches("test", eval_n, batch)
    eval_batches = list(data.eval_batches())
    images = jnp.asarray(np.stack([b["images"] for b in eval_batches]))
    labels = jnp.asarray(np.stack([b["labels"] for b in eval_batches]))

    ref = None
    if fidelity:
        from .specs import BackendSpec
        golden = ApproxPolicy(default=BackendSpec.golden().materialize())
        ref = [jax.jit(lambda i=i: resnet.forward(
            params, images[i], cfg, golden))() for i in range(images.shape[0])]

    def traceable_metrics(policy):
        logits = [resnet.forward(params, images[i], cfg, policy)
                  for i in range(images.shape[0])]
        accs = [jnp.mean((jnp.argmax(l, -1) == labels[i])
                         .astype(jnp.float32))
                for i, l in enumerate(logits)]
        out = {"accuracy": jnp.mean(jnp.stack(accs))}
        if ref is not None:
            maes = [jnp.mean(jnp.abs(l - r)) for l, r in zip(logits, ref)]
            out["logit_mae"] = jnp.mean(jnp.stack(maes))
        return out

    def fn(policy):
        out = jax.jit(lambda: traceable_metrics(policy))()
        return {k: float(v) for k, v in out.items()}

    base_name = f"classification[resnet{getattr(cfg, 'depth', '')}]"
    if not fidelity:
        return Workload(
            name=name or base_name,
            fn=fn, metrics=("accuracy",),
            traceable_metrics=traceable_metrics,
            directions={"accuracy": "max"},
            layer_counts=resnet.layer_mult_counts(cfg))
    return Workload(
        name=name or f"{base_name}+fidelity",
        fn=fn, metrics=("logit_mae", "accuracy"), primary="logit_mae",
        traceable_metrics=traceable_metrics,
        directions={"logit_mae": "min", "accuracy": "max"},
        layer_counts=resnet.layer_mult_counts(cfg))


def logit_fidelity(forward, inputs: Sequence[Any], *,
                   ref_policy: ApproxPolicy = EXACT_POLICY,
                   name: str = "logit_fidelity",
                   layer_counts: Optional[dict[str, int]] = None
                   ) -> Workload:
    """Logit fidelity vs a reference datapath (default: exact f32).

    ``forward(policy, x) -> logits`` is the model closure; ``inputs``
    the eval batches.  Metrics:

      * ``logit_mae`` (minimize) — mean over batches of the per-batch
        mean |logits − reference|, the continuous axis where
        quantization/datapath width shows while top-1 accuracy
        saturates (DESIGN.md §2.6);
      * ``top1_agreement`` (maximize) — fraction of argmax decisions
        matching the reference.

    The reference logits are computed once, eagerly, at construction.
    """
    import jax
    import jax.numpy as jnp

    inputs = list(inputs)
    ref = [forward(ref_policy, x) for x in inputs]

    def traceable_metrics(policy):
        maes, agree = [], []
        for x, r in zip(inputs, ref):
            logits = forward(policy, x)
            maes.append(jnp.mean(jnp.abs(logits - r)))
            agree.append(jnp.mean(
                (jnp.argmax(logits, -1) == jnp.argmax(r, -1))
                .astype(jnp.float32)))
        return {"logit_mae": jnp.mean(jnp.stack(maes)),
                "top1_agreement": jnp.mean(jnp.stack(agree))}

    def fn(policy):
        out = jax.jit(lambda: traceable_metrics(policy))()
        return {k: float(v) for k, v in out.items()}

    return Workload(name=name, fn=fn,
                    metrics=("logit_mae", "top1_agreement"),
                    primary="logit_mae",
                    traceable_metrics=traceable_metrics,
                    directions={"logit_mae": "min",
                                "top1_agreement": "max"},
                    layer_counts=layer_counts)


def _lm_setup(cfg, params, seed: int):
    """Resolve (cfg, params, model fns) for the LM adapters; ``cfg``
    may be an ``LMConfig`` or a registered arch name (resolved through
    ``repro.configs.get_config(...).reduced()`` so adapters stay
    smoke-test sized by default)."""
    import jax

    from repro.models.registry import model_fns

    if isinstance(cfg, str):
        from repro.configs import get_config
        cfg = get_config(cfg).reduced()
    if cfg.family == "encdec":
        raise ValueError(
            "the LM workload adapters drive decoder-family configs "
            "(dense/moe/ssm/hybrid/vlm); encoder-decoder models need "
            "audio/encoder inputs — build a logit_fidelity workload "
            "with your own forward closure instead")
    fns = model_fns(cfg)
    if params is None:
        params = fns.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params, fns


def _lm_token_batches(cfg, batch: int, seq_len: int, n_batches: int,
                      seed: int):
    import jax.numpy as jnp

    from repro.data.synthetic import token_stream

    out = []
    for i in range(n_batches):
        tokens, targets = token_stream(cfg.vocab, batch, seq_len,
                                       step=i, seed=seed)
        out.append({"tokens": jnp.asarray(tokens),
                    "targets": jnp.asarray(targets)})
    return out


def lm_layer_mult_counts(cfg, batch: int, seq_len: int) -> dict[str, int]:
    """Per-layer-tag multiplication counts for a dense decoder forward
    (the power model's weights).  Layer *tags* are shared across the
    scanned blocks ("attn.wq", "ffn.wi", ...; see
    ``repro.models.common``), so each tag's count aggregates over all
    ``n_layers`` — a per-tag policy override applies to that projection
    in EVERY block, and its power share accounts for all of them.
    Families with mixers beyond attention (ssm/moe/hybrid) should pass
    explicit counts for their extra tags."""
    from .layers import dense_mult_count

    t = batch * seq_len
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    counts = {
        "attn.wq": dense_mult_count((t, d), (d, h * hd)),
        "attn.wk": dense_mult_count((t, d), (d, hk * hd)),
        "attn.wv": dense_mult_count((t, d), (d, hk * hd)),
        "attn.wo": dense_mult_count((t, h * hd), (h * hd, d)),
        "ffn.wi": dense_mult_count((t, d), (d, cfg.d_ff)),
        "ffn.wo": dense_mult_count((t, cfg.d_ff), (cfg.d_ff, d)),
    }
    if cfg.act == "silu":
        counts["ffn.wg"] = dense_mult_count((t, d), (d, cfg.d_ff))
    return {k: v * cfg.n_layers for k, v in counts.items()}


def lm_fidelity(cfg: Union[str, Any], params=None, *, batch: int = 2,
                seq_len: int = 16, n_batches: int = 2,
                seed: int = 0) -> Workload:
    """Decoder logit fidelity vs the f32 model: prefill the LM on
    deterministic synthetic token batches and compare the last-position
    logits against the exact-datapath reference — ``logit_mae``
    (minimize, primary) + ``top1_agreement`` (maximize), the metric
    pair previously inlined in ``benchmarks/wide_width_pareto.py``, now
    over ANY registered decoder config."""
    cfg, params, fns = _lm_setup(cfg, params, seed)
    batches = _lm_token_batches(cfg, batch, seq_len, n_batches, seed)

    def forward(policy, b):
        cache = fns.init_cache(cfg, batch, seq_len)
        logits, _ = fns.forward_prefill(params, b, cache, cfg, policy)
        return logits

    return logit_fidelity(
        forward, batches, name=f"lm_fidelity[{cfg.name}]",
        layer_counts=lm_layer_mult_counts(cfg, batch, seq_len))


def lm_perplexity(cfg: Union[str, Any], params=None, *, batch: int = 2,
                  seq_len: int = 16, n_batches: int = 2,
                  seed: int = 0) -> Workload:
    """Decoder LM loss/perplexity on deterministic synthetic token
    batches: ``perplexity`` (minimize, primary) = exp(mean CE loss),
    plus the raw ``loss``.  An untrained tiny config still yields a
    meaningful *relative* axis — approximation error moves the loss."""
    import jax
    import jax.numpy as jnp

    cfg, params, fns = _lm_setup(cfg, params, seed)
    batches = _lm_token_batches(cfg, batch, seq_len, n_batches, seed)

    def traceable_metrics(policy):
        losses = [fns.forward_train(params, b, cfg, policy)
                  for b in batches]
        loss = jnp.mean(jnp.stack(losses))
        return {"perplexity": jnp.exp(loss), "loss": loss}

    def fn(policy):
        out = jax.jit(lambda: traceable_metrics(policy))()
        return {k: float(v) for k, v in out.items()}

    return Workload(name=f"lm_perplexity[{cfg.name}]", fn=fn,
                    metrics=("perplexity", "loss"), primary="perplexity",
                    traceable_metrics=traceable_metrics,
                    directions={"perplexity": "min", "loss": "min"},
                    layer_counts=lm_layer_mult_counts(cfg, batch,
                                                      seq_len))
