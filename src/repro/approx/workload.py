"""Workload layer: model + eval data + NAMED quality metrics
(DESIGN.md §2.7).

A ``Workload`` bundles everything the DSE needs to measure application-
level quality under an ``ApproxPolicy``, in both calling conventions
the sweeps understand (subsuming the older scalar ``BankableEval``):

  * ``fn(policy) -> {metric: float}`` — the sequential closure (free to
    jit internally, call numpy, return Python floats), and
  * ``traceable_metrics(policy) -> {metric: jax scalar}`` — its
    pure-jax core, which the batched engines wrap in ``jit(vmap(...))``
    over a multiplier bank (DESIGN.md §2.4).

Metric names are registered as ``workload``-provenance axes in
``repro.approx.objectives`` at construction, each with a direction, so
``explore(workload=..., objectives=(...))`` can Pareto over any mix of
quality metrics and library cost axes.  ``primary`` names the metric
legacy scalar call sites read: ``workload(policy)`` returns
``float(fn(policy)[primary])`` and the scalar-only ``.traceable``
property projects the traceable core the same way, so a ``Workload``
drops into every ``eval_fn=``-shaped call site unchanged.

Shipped adapters (built on ``repro.models``):

  * ``classification(cfg, params)`` — ResNet / synthetic-CIFAR top-1
    accuracy, the paper's case study (the historical behavior);
  * ``logit_fidelity(forward, inputs)`` — generic logit-MAE + top-1
    agreement vs the f32 model (the continuous quality axis where
    datapath width shows; DESIGN.md §2.6);
  * ``lm_fidelity(cfg)`` / ``lm_perplexity(cfg)`` — the same fidelity
    metrics, and loss/perplexity, for any registered decoder-family LM
    config (``repro.configs.get_config``/``repro.models.registry``),
    so resilience analysis and DSE run over LM scenarios, not just
    ResNet.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Mapping, Optional, Sequence, Union

from .layers import ApproxPolicy, EXACT_POLICY
from .objectives import ensure_objective

MetricFn = Callable[[ApproxPolicy], Mapping[str, Any]]


@dataclass
class Workload:
    """A named evaluation scenario: policy in, metric dict out.

    ``metrics`` fixes the metric names (and their order in sweep rows);
    ``directions`` maps each to "max"/"min" (default "max") and is
    registered into the objectives registry at construction.
    ``layer_counts`` optionally carries the model's per-layer
    multiplication counts so ``explore(workload=...)`` needs no second
    argument.  ``traceable_metrics`` may be ``None`` — the workload
    then runs on the sequential sweep paths only (``batch=True``
    requests fall back, exactly like a plain-callable eval)."""

    name: str
    fn: MetricFn
    metrics: tuple[str, ...]
    primary: Optional[str] = None
    traceable_metrics: Optional[MetricFn] = None
    directions: Mapping[str, str] = field(default_factory=dict)
    layer_counts: Optional[dict[str, int]] = None

    def __post_init__(self):
        if not self.metrics:
            raise ValueError("a Workload needs at least one metric")
        if self.primary is None:
            self.primary = self.metrics[0]
        if self.primary not in self.metrics:
            raise ValueError(f"primary {self.primary!r} not among "
                             f"metrics {self.metrics}")
        for m in self.metrics:
            ensure_objective(m, self.directions.get(m, "max"),
                             source="workload")

    # -- calling conventions -------------------------------------------
    def measure(self, policy: ApproxPolicy) -> dict[str, float]:
        """Sequential evaluation: every metric as a Python float, in
        ``metrics`` order."""
        out = self.fn(policy)
        return {m: float(out[m]) for m in self.metrics}

    def __call__(self, policy: ApproxPolicy) -> float:
        """Legacy scalar convention: the primary metric's value."""
        return float(self.fn(policy)[self.primary])

    @property
    def primary_direction(self) -> str:
        return self.directions.get(self.primary, "max")

    @property
    def traceable(self):
        """Scalar-primary projection of the traceable core — the shape
        ``bank_eval``/``policy_bank_eval`` call sites and ``can_bank``
        historically expect (None when the workload has no traceable
        core; unused metric computations are dead-code-eliminated by
        XLA)."""
        if self.traceable_metrics is None:
            return None
        tm, primary = self.traceable_metrics, self.primary
        return lambda policy: tm(policy)[primary]

    def cached(self, cache: dict) -> "Workload":
        """The same workload through a policy-keyed metric-dict cache
        (the ``explore()`` resume/widen mechanism)."""
        def fn(policy: ApproxPolicy) -> dict[str, float]:
            key = policy.cache_key()
            if key not in cache:
                cache[key] = self.measure(policy)
            return cache[key]
        return replace(self, fn=fn)


def as_workload(eval_fn) -> Workload:
    """Normalize any sweep evaluation handle into a ``Workload``:

      * a ``Workload`` passes through unchanged;
      * a ``BankableEval`` (anything with ``fn`` + ``traceable``
        attributes) becomes a single-metric ``accuracy`` workload whose
        traceable core is preserved for the batched engines;
      * a plain callable becomes a sequential-only ``accuracy``
        workload.

    This is the shim that keeps every pre-§2.7 ``eval_fn(policy) ->
    float`` call site working across the sweeps and the DSE facade."""
    if isinstance(eval_fn, Workload):
        return eval_fn
    traceable = getattr(eval_fn, "traceable", None)
    seq = getattr(eval_fn, "fn", eval_fn)
    if not callable(seq):
        raise TypeError(f"not an evaluation function: {eval_fn!r}")
    return Workload(
        name=getattr(eval_fn, "name", None)
        or getattr(eval_fn, "__name__", type(eval_fn).__name__),
        fn=lambda policy: {"accuracy": seq(policy)},
        metrics=("accuracy",),
        traceable_metrics=(None if traceable is None else
                           (lambda policy: {"accuracy": traceable(policy)})),
        directions={"accuracy": "max"})


# ----------------------------------------------------------------------
# Shipped adapters
# ----------------------------------------------------------------------
def classification(cfg, params, *, eval_n: int = 256, batch: int = 64,
                   name: Optional[str] = None,
                   fidelity: bool = False) -> Workload:
    """ResNet / synthetic-CIFAR top-1 accuracy — the paper's case-study
    quality metric, as a bank-traceable workload (drop-in for the
    historical ``BankableEval`` the resilience benchmarks built by
    hand).

    ``fidelity=True`` adds ``logit_mae`` (minimize, PRIMARY) against
    the golden-int8 reference logits: the continuous quality axis the
    surrogate predict stage trains and gates on (DESIGN.md §2.11) —
    top-1 accuracy quantizes to 1/eval_n steps, which starves rank
    statistics of resolution while logit MAE keeps moving.  Accuracy
    stays measured on every point either way."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data.synthetic import CifarBatches
    from repro.models import resnet

    data = CifarBatches("test", eval_n, batch)
    eval_batches = list(data.eval_batches())
    images = jnp.asarray(np.stack([b["images"] for b in eval_batches]))
    labels = jnp.asarray(np.stack([b["labels"] for b in eval_batches]))

    ref = None
    if fidelity:
        from .specs import BackendSpec
        golden = ApproxPolicy(default=BackendSpec.golden().materialize())
        ref = [jax.jit(lambda i=i: resnet.forward(
            params, images[i], cfg, golden))() for i in range(images.shape[0])]

    def traceable_metrics(policy):
        logits = [resnet.forward(params, images[i], cfg, policy)
                  for i in range(images.shape[0])]
        accs = [jnp.mean((jnp.argmax(l, -1) == labels[i])
                         .astype(jnp.float32))
                for i, l in enumerate(logits)]
        out = {"accuracy": jnp.mean(jnp.stack(accs))}
        if ref is not None:
            maes = [jnp.mean(jnp.abs(l - r)) for l, r in zip(logits, ref)]
            out["logit_mae"] = jnp.mean(jnp.stack(maes))
        return out

    def fn(policy):
        out = jax.jit(lambda: traceable_metrics(policy))()
        return {k: float(v) for k, v in out.items()}

    base_name = f"classification[resnet{getattr(cfg, 'depth', '')}]"
    if not fidelity:
        return Workload(
            name=name or base_name,
            fn=fn, metrics=("accuracy",),
            traceable_metrics=traceable_metrics,
            directions={"accuracy": "max"},
            layer_counts=resnet.layer_mult_counts(cfg))
    return Workload(
        name=name or f"{base_name}+fidelity",
        fn=fn, metrics=("logit_mae", "accuracy"), primary="logit_mae",
        traceable_metrics=traceable_metrics,
        directions={"logit_mae": "min", "accuracy": "max"},
        layer_counts=resnet.layer_mult_counts(cfg))


def logit_fidelity(forward, inputs: Sequence[Any], *,
                   ref_policy: ApproxPolicy = EXACT_POLICY,
                   name: str = "logit_fidelity",
                   layer_counts: Optional[dict[str, int]] = None
                   ) -> Workload:
    """Logit fidelity vs a reference datapath (default: exact f32).

    ``forward(policy, x) -> logits`` is the model closure; ``inputs``
    the eval batches.  Metrics:

      * ``logit_mae`` (minimize) — mean over batches of the per-batch
        mean |logits − reference|, the continuous axis where
        quantization/datapath width shows while top-1 accuracy
        saturates (DESIGN.md §2.6);
      * ``top1_agreement`` (maximize) — fraction of argmax decisions
        matching the reference.

    The reference logits are computed once, eagerly, at construction.
    """
    import jax
    import jax.numpy as jnp

    inputs = list(inputs)
    ref = [forward(ref_policy, x) for x in inputs]

    def traceable_metrics(policy):
        maes, agree = [], []
        for x, r in zip(inputs, ref):
            logits = forward(policy, x)
            maes.append(jnp.mean(jnp.abs(logits - r)))
            agree.append(jnp.mean(
                (jnp.argmax(logits, -1) == jnp.argmax(r, -1))
                .astype(jnp.float32)))
        return {"logit_mae": jnp.mean(jnp.stack(maes)),
                "top1_agreement": jnp.mean(jnp.stack(agree))}

    def fn(policy):
        out = jax.jit(lambda: traceable_metrics(policy))()
        return {k: float(v) for k, v in out.items()}

    return Workload(name=name, fn=fn,
                    metrics=("logit_mae", "top1_agreement"),
                    primary="logit_mae",
                    traceable_metrics=traceable_metrics,
                    directions={"logit_mae": "min",
                                "top1_agreement": "max"},
                    layer_counts=layer_counts)


def _lm_setup(cfg, params, seed: int):
    """Resolve (cfg, params, model fns) for the LM adapters; ``cfg``
    may be an ``LMConfig`` or a registered arch name (resolved through
    ``repro.configs.get_config(...).reduced()`` so adapters stay
    smoke-test sized by default).  Every registered family works —
    non-token inputs (whisper frame embeddings, llava image embeddings)
    come from ``registry.input_extras`` and are merged into each eval
    batch."""
    import jax

    from repro.models.registry import model_fns

    if isinstance(cfg, str):
        from repro.configs import get_config
        cfg = get_config(cfg).reduced()
    fns = model_fns(cfg)
    if params is None:
        params = fns.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params, fns


def _lm_token_batches(cfg, batch: int, seq_len: int, n_batches: int,
                      seed: int):
    import jax.numpy as jnp

    from repro.data.synthetic import token_stream
    from repro.models.registry import input_extras

    extras = input_extras(cfg, batch)
    out = []
    for i in range(n_batches):
        tokens, targets = token_stream(cfg.vocab, batch, seq_len,
                                       step=i, seed=seed)
        out.append({"tokens": jnp.asarray(tokens),
                    "targets": jnp.asarray(targets), **extras})
    return out


# ----------------------------------------------------------------------
# Unified MAC accounting (the Workload.layer_counts protocol;
# DESIGN.md §2.12)
# ----------------------------------------------------------------------
def _merge_counts(dst: dict, src: Mapping[str, int], scale: int = 1):
    for tag, c in src.items():
        dst[tag] = dst.get(tag, 0) + int(c) * scale


def _attn_counts(cfg, t: int, prefix: str = "attn") -> dict[str, int]:
    from .layers import dense_mult_count
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        f"{prefix}.wq": dense_mult_count((t, d), (d, h * hd)),
        f"{prefix}.wk": dense_mult_count((t, d), (d, hk * hd)),
        f"{prefix}.wv": dense_mult_count((t, d), (d, hk * hd)),
        f"{prefix}.wo": dense_mult_count((t, h * hd), (h * hd, d)),
    }


def _mla_counts(cfg, t: int) -> dict[str, int]:
    from .layers import dense_mult_count
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ql, kl = cfg.q_lora, cfg.kv_lora
    return {
        "mla.wdq": dense_mult_count((t, d), (d, ql)),
        "mla.wuq": dense_mult_count((t, ql), (ql, h * dn)),
        "mla.wqr": dense_mult_count((t, ql), (ql, h * dr)),
        "mla.wdkv": dense_mult_count((t, d), (d, kl)),
        "mla.wuk": dense_mult_count((t, kl), (kl, h * dn)),
        "mla.wuv": dense_mult_count((t, kl), (kl, h * dv)),
        "mla.wkr": dense_mult_count((t, d), (d, dr)),
        "mla.wo": dense_mult_count((t, h * dv), (h * dv, d)),
    }


def _ffn_counts(cfg, t: int, prefix: str = "ffn",
                d_ff: Optional[int] = None) -> dict[str, int]:
    from .layers import dense_mult_count
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    counts = {
        f"{prefix}.wi": dense_mult_count((t, d), (d, f)),
        f"{prefix}.wo": dense_mult_count((t, f), (f, d)),
    }
    if cfg.act == "silu":
        counts[f"{prefix}.wg"] = dense_mult_count((t, d), (d, f))
    return counts


def _moe_counts(cfg, t: int) -> dict[str, int]:
    """Expert MACs mirror the sort-based dispatch exactly: every expert
    processes its full capacity buffer (zero-padded slots multiply
    too), so the per-projection cost is ``nb * E * C * d * f`` with the
    same blocked/unblocked capacity arithmetic as ``models.moe``.  The
    router einsum stays exact (f32) and carries no approximate MACs."""
    import math
    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    nb = cfg.moe_blocks
    if nb > 1 and t % nb == 0 and t // nb >= k:
        tb = t // nb
    else:
        nb, tb = 1, t
    cap = int(min(tb * k,
                  max(math.ceil(tb * k / e * cfg.capacity_factor), 4)))
    per = nb * e * cap
    counts = {"moe.wi": per * d * f, "moe.wo": per * f * d}
    if cfg.act == "silu":
        counts["moe.wg"] = per * d * f
    if cfg.n_shared_experts > 0:
        counts.update(_ffn_counts(cfg, t, prefix="moe.shared",
                                  d_ff=f * cfg.n_shared_experts))
    return counts


def _mamba_counts(cfg, t: int) -> dict[str, int]:
    from .layers import dense_mult_count

    from repro.models.mamba2 import ssm_dims
    dd = ssm_dims(cfg)
    d, di = cfg.d_model, dd["d_inner"]
    d_proj = 2 * di + 2 * dd["n"] + dd["n_heads"]
    return {
        "mamba.in_proj": dense_mult_count((t, d), (d, d_proj)),
        "mamba.out_proj": dense_mult_count((t, di), (di, d)),
    }


def _encdec_mult_counts(cfg, batch: int, seq_len: int) -> dict[str, int]:
    from .layers import dense_mult_count
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    t_enc = batch * cfg.enc_frames
    t_dec = batch * seq_len
    counts: dict[str, int] = {}
    _merge_counts(counts, _attn_counts(cfg, t_enc, prefix="enc.attn"),
                  cfg.n_enc_layers)
    _merge_counts(counts, _ffn_counts(cfg, t_enc, prefix="enc.ffn"),
                  cfg.n_enc_layers)
    _merge_counts(counts, _attn_counts(cfg, t_dec, prefix="dec.attn"),
                  cfg.n_layers)
    _merge_counts(counts, _ffn_counts(cfg, t_dec, prefix="dec.ffn"),
                  cfg.n_layers)
    # Cross-attention: queries/output over decoder positions, cross-KV
    # over encoder frames, once per decoder layer.
    _merge_counts(counts, {
        "xattn.wq": dense_mult_count((t_dec, d), (d, h * hd)),
        "xattn.wk": dense_mult_count((t_enc, d), (d, h * hd)),
        "xattn.wv": dense_mult_count((t_enc, d), (d, h * hd)),
        "xattn.wo": dense_mult_count((t_dec, h * hd), (h * hd, d)),
    }, cfg.n_layers)
    return counts


def _resnet_mult_counts(cfg, batch: int) -> dict[str, int]:
    from .layers import conv_mult_count, dense_mult_count
    counts: dict[str, int] = {}
    size = cfg.image_size
    counts["conv_init"] = conv_mult_count((batch, size, size, 3),
                                          (3, 3, 3, cfg.widths[0]))
    cin = cfg.widths[0]
    for s, width in enumerate(cfg.widths):
        for b in range(cfg.n_blocks):
            stride = 2 if (s > 0 and b == 0) else 1
            out_size = size // stride
            counts[f"s{s}_b{b}_conv1"] = conv_mult_count(
                (batch, size, size, cin), (3, 3, cin, width), stride)
            counts[f"s{s}_b{b}_conv2"] = conv_mult_count(
                (batch, out_size, out_size, width), (3, 3, width, width))
            if cin != width:
                counts[f"s{s}_b{b}_proj"] = conv_mult_count(
                    (batch, size, size, cin), (1, 1, cin, width), stride)
            size = out_size
            cin = width
    counts["head"] = dense_mult_count((batch, cfg.widths[-1]),
                                      (cfg.widths[-1], cfg.n_classes))
    return counts


def layer_mult_counts(cfg, batch: int = 1,
                      seq_len: int = 16) -> dict[str, int]:
    """Per-layer-tag multiplication counts for ANY model the repo ships
    — the single MAC-accounting implementation behind the
    ``Workload.layer_counts`` protocol (DESIGN.md §2.12).

    ``cfg`` is a ``ResNetConfig`` (``seq_len`` ignored) or any
    ``LMConfig`` family (dense/moe/ssm/hybrid/vlm/encdec).  Layer tags
    are shared across scanned blocks ("attn.wq", "moe.wi", ...), so
    each tag's count aggregates over every block that uses it —
    mirroring ``models.decoder.block_pattern`` slot by slot — and
    non-token inputs count the way the adapters feed them
    (``registry.input_extras``): vlm prefixes ``n_img_tokens`` image
    positions (plus the ``img_proj`` projection itself), encdec runs
    the encoder over ``enc_frames`` per batch element.  Exact einsums
    (norms, attention scores, the MoE router, the SSM scan) carry no
    approximate MACs and do not appear."""
    if hasattr(cfg, "widths"):          # ResNetConfig, without an import
        return _resnet_mult_counts(cfg, batch)
    if cfg.family == "encdec":
        return _encdec_mult_counts(cfg, batch, seq_len)

    from repro.models.decoder import block_pattern

    # vlm image embeddings are PREPENDED to the token sequence, so every
    # decoder projection also runs over those positions.
    extra = cfg.n_img_tokens if cfg.family == "vlm" else 0
    t = batch * (seq_len + extra)
    pattern = block_pattern(cfg)
    reps = cfg.n_layers // len(pattern)
    per_group: dict[str, int] = {}
    for mixer, ffn_kind in pattern:
        if mixer == "attn":
            _merge_counts(per_group, _attn_counts(cfg, t))
        elif mixer == "mla":
            _merge_counts(per_group, _mla_counts(cfg, t))
        else:
            _merge_counts(per_group, _mamba_counts(cfg, t))
        if ffn_kind == "ffn":
            _merge_counts(per_group, _ffn_counts(cfg, t))
        elif ffn_kind == "moe":
            _merge_counts(per_group, _moe_counts(cfg, t))
    counts = {tag: c * reps for tag, c in per_group.items()}
    if cfg.family == "vlm" and cfg.n_img_tokens > 0:
        from .layers import dense_mult_count
        counts["img_proj"] = dense_mult_count(
            (batch * cfg.n_img_tokens, cfg.d_model),
            (cfg.d_model, cfg.d_model))
    return counts


def lm_layer_mult_counts(cfg, batch: int, seq_len: int) -> dict[str, int]:
    """Pre-§2.12 name for ``layer_mult_counts`` on LM configs (kept as
    a shim for existing call sites)."""
    return layer_mult_counts(cfg, batch=batch, seq_len=seq_len)


def lm_fidelity(cfg: Union[str, Any], params=None, *, batch: int = 2,
                seq_len: int = 16, n_batches: int = 2,
                seed: int = 0) -> Workload:
    """Decoder logit fidelity vs the f32 model: prefill the LM on
    deterministic synthetic token batches and compare the last-position
    logits against the exact-datapath reference — ``logit_mae``
    (minimize, primary) + ``top1_agreement`` (maximize), the metric
    pair previously inlined in ``benchmarks/wide_width_pareto.py``, now
    over ANY registered decoder config."""
    from repro.models.registry import prompt_extra_len

    cfg, params, fns = _lm_setup(cfg, params, seed)
    batches = _lm_token_batches(cfg, batch, seq_len, n_batches, seed)
    max_len = seq_len + prompt_extra_len(cfg, batches[0])

    def forward(policy, b):
        cache = fns.init_cache(cfg, batch, max_len)
        logits, _ = fns.forward_prefill(params, b, cache, cfg, policy)
        return logits

    return logit_fidelity(
        forward, batches, name=f"lm_fidelity[{cfg.name}]",
        layer_counts=layer_mult_counts(cfg, batch, seq_len))


def lm_perplexity(cfg: Union[str, Any], params=None, *, batch: int = 2,
                  seq_len: int = 16, n_batches: int = 2,
                  seed: int = 0) -> Workload:
    """Decoder LM loss/perplexity on deterministic synthetic token
    batches: ``perplexity`` (minimize, primary) = exp(mean CE loss),
    plus the raw ``loss``.  An untrained tiny config still yields a
    meaningful *relative* axis — approximation error moves the loss."""
    import jax
    import jax.numpy as jnp

    cfg, params, fns = _lm_setup(cfg, params, seed)
    batches = _lm_token_batches(cfg, batch, seq_len, n_batches, seed)

    def traceable_metrics(policy):
        losses = [fns.forward_train(params, b, cfg, policy)
                  for b in batches]
        loss = jnp.mean(jnp.stack(losses))
        return {"perplexity": jnp.exp(loss), "loss": loss}

    def fn(policy):
        out = jax.jit(lambda: traceable_metrics(policy))()
        return {k: float(v) for k, v in out.items()}

    return Workload(name=f"lm_perplexity[{cfg.name}]", fn=fn,
                    metrics=("perplexity", "loss"), primary="perplexity",
                    traceable_metrics=traceable_metrics,
                    directions={"perplexity": "min", "loss": "min"},
                    layer_counts=layer_mult_counts(cfg, batch, seq_len))
