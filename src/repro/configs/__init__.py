"""Architecture registry: ``get_config(arch_id)`` for every assigned
architecture (plus the paper's own ResNet-CIFAR family).

Dry-run cells = ARCHS x SHAPES, minus the long_500k skips recorded in
``repro.configs.shapes`` / DESIGN.md §5.
"""
from __future__ import annotations

import importlib

from repro.models.common import LMConfig

from .shapes import SHAPES, ShapeSpec, batch_specs, shape_applicable

ARCHS = {
    "llava-next-34b": "llava_next_34b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-780m": "mamba2_780m",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "nemotron-4-15b": "nemotron_4_15b",
    "yi-34b": "yi_34b",
    "qwen3-14b": "qwen3_14b",
    "whisper-large-v3": "whisper_large_v3",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def get_config(arch: str) -> LMConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {list(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.config()


# §Perf-winning production settings (EXPERIMENTS.md §Perf): pass as
# --override to launch.dryrun / apply via steps.apply_overrides.
# moe_blocks should equal the data-parallel shard count of the mesh.
TUNED_OVERRIDES = {
    "qwen3-moe-30b-a3b": {"moe_blocks": 16, "capacity_factor": 1.0},
    "deepseek-v2-236b": {"moe_blocks": 16, "attn_impl": "chunked"},
    "jamba-v0.1-52b": {"moe_blocks": 16},
    # dense 32k-prefill cells: chunked attention removes the S^2 HBM term
    "yi-34b": {"attn_impl": "chunked"},
    "llava-next-34b": {"attn_impl": "chunked"},
    "qwen3-14b": {"attn_impl": "chunked"},
    "nemotron-4-15b": {"attn_impl": "chunked"},
}


def all_cells():
    """Yields (arch, shape_name) for every applicable dry-run cell and
    (arch, shape_name, reason) skips."""
    cells, skips = [], []
    for arch in ARCHS:
        cfg = get_config(arch)
        for sname, spec in SHAPES.items():
            if shape_applicable(cfg, spec):
                cells.append((arch, sname))
            else:
                skips.append((arch, sname,
                              "full-attention arch skips long_500k "
                              "(needs sub-quadratic attention)"))
    return cells, skips
