"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed
top-6 [arXiv:2405.04434; hf].

d_ff=1536 is the per-expert (and per-shared-expert) hidden dim.  The
listed 128H/kv=128 maps to MLA with 128 query heads over a 512-dim
compressed KV latent + 64-dim shared rope key.
"""
from repro.models.common import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=1536,
        vocab=102400,
        head_dim=128,           # qk nope dim
        act="silu",
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1536,
        use_mla=True,
        kv_lora=512,
        q_lora=1536,
        rope_head_dim=64,
        v_head_dim=128,
    )
