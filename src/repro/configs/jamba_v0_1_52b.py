"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].

32 layers in 4 groups of 8: attention at slot 4 of each group, Mamba
elsewhere; MoE FFN on odd slots (every other layer), dense FFN on even.
Jamba's SSM uses d_state=16.
"""
from repro.models.common import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=65536,
        head_dim=128,
        act="silu",
        n_experts=16,
        top_k=2,
        moe_d_ff=14336,
        attn_period=8,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=256,
    )
