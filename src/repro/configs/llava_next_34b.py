"""llava-next-34b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone = yi-34b dims (60L / 7168 / 56H kv8 / 20480 / 64000).  The
vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings at d_model (anyres tiling happens upstream
of the backbone); a learned projection fuses them into the sequence.
"""
from repro.models.common import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab=64000,
        head_dim=128,
        act="silu",
        rope_theta=5_000_000.0,
        n_img_tokens=576,   # one anyres base tile of 24x24 patches
    )
