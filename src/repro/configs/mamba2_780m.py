"""mamba2-780m [ssm] — SSD (state-space duality)
[arXiv:2405.21060; unverified].  Attention-free: 48 SSD blocks,
d_model=1536, ssm_state=128, expand 2, head_dim 64 (d_ff=0)."""
from repro.models.common import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=1,            # unused (attention-free)
        n_kv_heads=1,
        d_ff=0,
        vocab=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=256,
        conv_width=4,
    )
