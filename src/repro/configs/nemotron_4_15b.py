"""nemotron-4-15b [dense] — GQA, squared-ReLU FFN (no gate)
[arXiv:2402.16819; unverified]."""
from repro.models.common import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="nemotron-4-15b",
        family="dense",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=24576,
        vocab=256000,
        head_dim=128,
        act="relu2",
    )
