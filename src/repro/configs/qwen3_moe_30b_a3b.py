"""qwen3-moe-30b-a3b [moe] — 128 experts top-8
[hf:Qwen/Qwen3-30B-A3B; hf].  d_ff=768 is the per-expert hidden dim;
qwen3 family uses per-head qk RMSNorm."""
from repro.models.common import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=768,
        vocab=151936,
        head_dim=128,
        qk_norm=True,
        act="silu",
        n_experts=128,
        top_k=8,
        moe_d_ff=768,
        rope_theta=1_000_000.0,
    )
