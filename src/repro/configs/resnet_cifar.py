"""ResNet-8..50 on CIFAR-10 — the paper's own case-study family."""
from repro.models.resnet import ResNetConfig, resnet_config

DEPTHS = (8, 14, 20, 26, 32, 38, 44, 50)


def config(depth: int = 8) -> ResNetConfig:
    return resnet_config(depth)
