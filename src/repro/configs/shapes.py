"""The four assigned input shapes (seq_len x global_batch) and the
ShapeDtypeStruct builders for every (arch x shape) dry-run cell.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
KV cache of seq_len); ``prefill_32k`` lowers the prefill serve step;
``train_4k`` lowers ``train_step``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import LMConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid
# (see DESIGN.md §5 — the 8 pure full-attention archs skip it).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: LMConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: LMConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the data batch of this (arch, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "vlm":
            s_img = cfg.n_img_tokens
            return {
                "tokens": _sds((b, s - s_img), jnp.int32),
                "targets": _sds((b, s - s_img), jnp.int32),
                "img_embeds": _sds((b, s_img, cfg.d_model), jnp.float32),
            }
        if cfg.family == "encdec":
            return {
                "frames": _sds((b, cfg.enc_frames, cfg.d_model),
                               jnp.float32),
                "tokens": _sds((b, s), jnp.int32),
                "targets": _sds((b, s), jnp.int32),
            }
        return {"tokens": _sds((b, s), jnp.int32),
                "targets": _sds((b, s), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.family == "vlm":
            s_img = cfg.n_img_tokens
            return {
                "tokens": _sds((b, s - s_img), jnp.int32),
                "img_embeds": _sds((b, s_img, cfg.d_model), jnp.float32),
            }
        if cfg.family == "encdec":
            return {
                "frames": _sds((b, cfg.enc_frames, cfg.d_model),
                               jnp.float32),
                "tokens": _sds((b, s), jnp.int32),
            }
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode: one token against a cache of seq_len
    return {"token": _sds((b,), jnp.int32)}
