"""whisper-large-v3 [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].

The mel/conv frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, 1500, d_model).  Backbone:
32-layer encoder + 32-layer decoder with cross-attention, sinusoidal
absolute positions (no RoPE), GELU FFN.
"""
from repro.models.common import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,           # decoder layers
        n_enc_layers=32,
        enc_frames=1500,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51866,
        head_dim=64,
        act="gelu",
        use_rope=False,
    )
