"""CLI: build and persist the default approximate-circuit library.

    PYTHONPATH=src python -m repro.core.build_library --budget small

``--engine device`` regenerates the evolved rows with the
population-parallel generational ladder (DESIGN.md §2.9) — one fused
device evaluation per generation, every improved feasible parent
admitted, plus composed 12/16-bit rows over the evolved Pareto tiles.
"""
from __future__ import annotations

import argparse
import time

from .library import DEFAULT_LIBRARY_PATH, build_default_library


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", choices=("tiny", "small", "full"),
                    default="small")
    ap.add_argument("--engine", choices=("legacy", "numpy", "device"),
                    default="legacy",
                    help="evolutionary search backend: sequential "
                         "chained ladder ('legacy') or the "
                         "population-parallel generational ladder "
                         "('numpy'/'device')")
    ap.add_argument("--out", default=DEFAULT_LIBRARY_PATH)
    args = ap.parse_args()

    t0 = time.time()
    lib = build_default_library(args.budget, progress=True,
                                engine=args.engine)
    lib.save(args.out)
    print(f"built {len(lib.entries)} circuits in {time.time() - t0:.1f}s "
          f"-> {args.out}")
    for row in lib.counts_table():
        print(f"  {row['circuit']:<12} {row['bit_width']:>4}b : "
              f"{row['n_implementations']}")


if __name__ == "__main__":
    main()
