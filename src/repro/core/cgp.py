"""Cartesian Genetic Programming engine (paper Sec. II-B/II-C).

(1+lambda) evolutionary strategy over integer netlists:
  (i)   select the best-scored circuit (the parent),
  (ii)  create lambda offspring by mutating h genes,
  (iii) evaluate, repeat.

Single-objective mode (Sec. II-C): minimize circuit cost (weighted gate
area) subject to the chosen error metric staying within [e_min, e_max].
Running the engine across a ladder of e_max values yields the library's
power x error trade-off curve; a Pareto archive collects all
non-dominated (power, error) points seen during every run.

Evaluation cost is dominated by circuit simulation, so during the search
we simulate a fixed subsample of the input space (fast, fitness-rank
faithful) and re-evaluate exhaustively before a circuit is admitted to
the archive — mirroring how the paper separates search-time fitness from
final verification.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from . import gates
from .cost import evaluate_cost
from .metrics import (ErrorReport, error_report_from_values,
                      evaluate_errors, METRIC_NAMES)
from .netlist import (Netlist, exhaustive_inputs, pack_operands,
                      random_input_planes, unpack_outputs,
                      unpack_outputs_object)


@dataclass
class CgpParams:
    lam: int = 4                  # lambda offspring per generation
    h: int = 5                    # mutated genes per offspring (paper: h=5)
    generations: int = 300
    metric: str = "mae"           # error metric constrained during search
    e_max: float = 0.0            # upper bound on the metric
    e_min: float = 0.0
    search_samples: int = 8192    # subsampled vectors during search
    seed: int = 0


@dataclass
class EvolvedCircuit:
    netlist: Netlist
    errors: ErrorReport
    cost_area: float
    cost_power: float


def search_planes(n_i: int, search_samples: int,
                  rng: np.random.Generator):
    """Search-time input sample as bit-planes: ``(planes, num)``.

    Exhaustive when the 2^n_i space fits ``search_samples`` (n_i <= 24),
    a sorted without-replacement subsample when it doesn't, and for
    wider circuits uniform random *bit-planes* over exactly the n_i-bit
    domain.  The plane-based wide path replaces the old 63-bit integer
    draw, which never exercised input bits >= 63 (bit 63 of a 64-bit
    operand pair was constant zero, and every plane past bit 63 was
    silently dropped by the uint64 shift in ``pack_operands``).
    """
    space = 1 << n_i if n_i <= 24 else None
    if space is not None and space <= search_samples:
        vecs = np.arange(space, dtype=np.uint64)
        return pack_operands([vecs], [n_i]), space
    if space is not None:
        vecs = rng.choice(space, size=search_samples, replace=False)
        vecs = np.sort(vecs).astype(np.uint64)
        return pack_operands([vecs], [n_i]), search_samples
    return random_input_planes(n_i, search_samples, rng), search_samples


def unpack_values(planes: np.ndarray, n_o: int, num: int) -> np.ndarray:
    """Output planes -> float64 values; exact uint64 unpack for
    n_o <= 64, big-int (object) unpack beyond that."""
    if n_o <= 64:
        return unpack_outputs(planes, n_o, num).astype(np.float64)
    return unpack_outputs_object(planes, n_o, num).astype(np.float64)


class _Evaluator:
    """Caches exact outputs; scores candidates on a fixed vector subset."""

    def __init__(self, exact: Netlist, params: CgpParams):
        self.exact = exact
        self.n_i = exact.n_i
        self.metric = params.metric
        if self.metric not in METRIC_NAMES:
            raise ValueError(f"unknown metric {self.metric}")
        rng = np.random.default_rng(params.seed + 7919)
        self.planes, self.num = search_planes(
            self.n_i, params.search_samples, rng)
        self.exact_vals = unpack_values(
            exact.eval_words(self.planes), exact.n_o, self.num)

    def error_of(self, cand: Netlist) -> float:
        vals = unpack_values(
            cand.eval_words(self.planes), cand.n_o, self.num)
        rep = error_report_from_values(vals, self.exact_vals, exhaustive=False)
        return rep.get(self.metric)


def mutate(nl: Netlist, rng: np.random.Generator, h: int) -> Netlist:
    """Point-mutate h genes; always produces a valid netlist."""
    funcs = nl.funcs.copy()
    in0 = nl.in0.copy()
    in1 = nl.in1.copy()
    outputs = nl.outputs.copy()
    n, n_i, n_o = nl.n_nodes, nl.n_i, nl.n_o
    n_genes = 3 * n + n_o
    for g in rng.integers(0, n_genes, size=h):
        g = int(g)
        if g < n:  # function gene
            funcs[g] = rng.integers(0, gates.N_FUNCS)
        elif g < 2 * n:  # in0 gene
            j = g - n
            in0[j] = rng.integers(0, n_i + j) if (n_i + j) > 0 else 0
        elif g < 3 * n:  # in1 gene
            j = g - 2 * n
            in1[j] = rng.integers(0, n_i + j) if (n_i + j) > 0 else 0
        else:  # output gene
            outputs[g - 3 * n] = rng.integers(0, n_i + n)
    return Netlist(n_i=n_i, n_o=n_o, funcs=funcs, in0=in0, in1=in1,
                   outputs=outputs, name=nl.name)


@dataclass(order=True)
class _Score:
    """Lexicographic: feasibility first, then cost (feasible) or error."""
    infeasible: float
    primary: float


def _score(error: float, cost_area: float, e_min: float, e_max: float) -> _Score:
    if e_min <= error <= e_max:
        return _Score(0.0, cost_area)
    # infeasible: drive error toward the window
    gap = error - e_max if error > e_max else e_min - error
    return _Score(1.0, gap)


def evolve(
    seed_netlist: Netlist,
    exact: Netlist,
    params: CgpParams,
    on_candidate: Optional[Callable[[Netlist, float, float], None]] = None,
) -> EvolvedCircuit:
    """Single-objective (1+lambda) run. Returns the best feasible circuit
    (falls back to the seed if nothing feasible was found).

    on_candidate(netlist, error, area) is called for every *improved*
    parent — the Pareto archive hooks in here.
    """
    rng = np.random.default_rng(params.seed)
    ev = _Evaluator(exact, params)

    parent = seed_netlist
    p_err = ev.error_of(parent)
    p_cost = evaluate_cost(parent)
    p_score = _score(p_err, p_cost.area, params.e_min, params.e_max)
    best_feasible: Optional[Netlist] = parent if p_score.infeasible == 0 else None

    for _gen in range(params.generations):
        improved = False
        for _k in range(params.lam):
            child = mutate(parent, rng, params.h)
            c_err = ev.error_of(child)
            c_area = evaluate_cost(child).area
            c_score = _score(c_err, c_area, params.e_min, params.e_max)
            if c_score <= p_score:  # allow neutral drift
                if c_score < p_score:
                    improved = True
                parent, p_err, p_score = child, c_err, c_score
                if c_score.infeasible == 0:
                    best_feasible = child
        if improved and on_candidate is not None and p_score.infeasible == 0:
            on_candidate(parent, p_err, evaluate_cost(parent).area)

    final = best_feasible if best_feasible is not None else seed_netlist
    final = final.compact()
    errors = evaluate_errors(final, exact)
    cost = evaluate_cost(final)
    return EvolvedCircuit(netlist=final, errors=errors,
                          cost_area=cost.area, cost_power=cost.power)


def pad_nodes(nl: Netlist, n_total: int, seed: int = 0) -> Netlist:
    """Append inactive random nodes up to ``n_total`` (CGP benefits from
    neutral genetic material; compacted seeds would otherwise starve)."""
    n, n_i = nl.n_nodes, nl.n_i
    if n >= n_total:
        return nl
    rng = np.random.default_rng(seed)
    extra = n_total - n
    funcs = np.concatenate([nl.funcs,
                            rng.integers(0, gates.N_FUNCS, extra)])
    lim = n_i + n + np.arange(extra)
    in0 = np.concatenate([nl.in0, rng.integers(0, lim)])
    in1 = np.concatenate([nl.in1, rng.integers(0, lim)])
    return Netlist(n_i=n_i, n_o=nl.n_o, funcs=funcs.astype(np.int32),
                   in0=in0.astype(np.int32), in1=in1.astype(np.int32),
                   outputs=nl.outputs, name=nl.name)


def dominates(p: tuple, q: tuple) -> bool:
    """p dominates q (minimization, paper Sec. II-C definition)."""
    return all(a <= b for a, b in zip(p, q)) and any(a < b for a, b in zip(p, q))


class ParetoArchive:
    """Archive of non-dominated points (minimization on every objective)."""

    def __init__(self):
        self.points: list[tuple] = []
        self.payloads: list = []

    def add(self, point: tuple, payload) -> bool:
        for q in self.points:
            if dominates(q, point) or q == point:
                return False
        keep = [i for i, q in enumerate(self.points) if not dominates(point, q)]
        self.points = [self.points[i] for i in keep] + [point]
        self.payloads = [self.payloads[i] for i in keep] + [payload]
        return True

    def __len__(self) -> int:
        return len(self.points)
