"""Hardware cost estimation for CGP netlists.

"The cost is estimated as the sum of weighted areas of the gates used in
the circuit" (paper Sec. III).  We implement exactly that, plus a power
estimate (sum of per-gate reference powers over *active* gates) and a
critical-path delay estimate (longest weighted path), using the 45 nm
tables in ``gates.py``.  The paper's tables report power relative to the
exact circuit; `relative_power` provides that directly.
"""
from __future__ import annotations

from dataclasses import dataclass, asdict

import numpy as np

from . import gates
from .netlist import Netlist


@dataclass(frozen=True)
class CostReport:
    area: float        # um^2, sum of active gate areas
    power: float       # uW at reference activity
    delay: float       # ps, critical path
    n_gates: int       # active non-trivial gates (excl. wires/constants)

    def as_dict(self) -> dict:
        return asdict(self)


def evaluate_cost(nl: Netlist) -> CostReport:
    active = nl.active_mask()
    funcs = nl.funcs[active]
    area = float(gates.GATE_AREA[funcs].sum())
    power = float(gates.GATE_POWER[funcs].sum())
    nontrivial = np.isin(
        funcs, [gates.AND, gates.OR, gates.XOR, gates.NAND, gates.NOR,
                gates.XNOR, gates.NOT]
    )
    n_gates = int(nontrivial.sum())

    # critical path: longest accumulated delay from any primary input
    n, n_i = nl.n_nodes, nl.n_i
    arrival = np.zeros(n_i + n, dtype=np.float64)
    for j in range(n):
        if not active[j]:
            continue
        f = int(nl.funcs[j])
        t = 0.0
        if gates.GATE_ARITY[f] >= 1:
            t = max(t, arrival[int(nl.in0[j])])
        if gates.GATE_ARITY[f] >= 2:
            t = max(t, arrival[int(nl.in1[j])])
        arrival[n_i + j] = t + float(gates.GATE_DELAY[f])
    delay = float(max((arrival[int(s)] for s in nl.outputs), default=0.0))
    return CostReport(area=area, power=power, delay=delay, n_gates=n_gates)


def relative_power(nl: Netlist, reference: Netlist) -> float:
    """Power of ``nl`` relative to ``reference`` (1.0 = same power)."""
    ref = evaluate_cost(reference).power
    if ref <= 0:
        return 0.0
    return evaluate_cost(nl).power / ref
