"""Population-parallel CGP engine on the device bitsim (DESIGN.md §2.9).

The legacy ``cgp.evolve`` loop simulates ONE candidate per
``Netlist.eval_words`` call; fitness evaluation dominates the search, so
library generation throughput is capped by per-candidate python
dispatch.  This engine makes the (1+λ) step *generational*: all λ
offspring mutate from the same parent and are scored together —
``engine="device"`` runs the whole population through ONE
``bitsim_pop_pallas`` program and reduces the search metric on device
(exact integer sums, finished in float64 on host, so scores are
bit-identical to the numpy engine and the two engines walk identical
search trajectories at a fixed seed).

``evolve_ladder`` fuses a whole ladder of e_max-targeted searches into
one generation-synchronous sweep: every rung contributes λ offspring to
a single fused population per generation, and the population axis can
be sharded across devices via ``launch/mesh.pop_sharding`` (shard_map
over the candidate axis; netlist slices split, input planes replicated).

Search/verify split: everything here scores candidates on the sampled
search planes; admission to a library re-verifies exhaustively
(``metrics.evaluate_errors``) exactly like the sequential engine.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..kernels.bitsim import bitsim_pop_pallas
from ..kernels.ops import split_planes64
from .cgp import (CgpParams, EvolvedCircuit, _Score, _score, mutate,
                  search_planes, unpack_values)
from .cost import evaluate_cost
from .metrics import (METRIC_NAMES, error_report_from_values,
                      evaluate_errors)
from .netlist import Netlist, stack_netlists, unpack_outputs

# metrics whose reduction runs on device with EXACT integer arithmetic
# (chunked int32 partial sums finished in float64 on host); the rest
# simulate on device and reduce on host from the transferred values.
DEVICE_METRICS = ("er", "mae", "wce")

# population counts are padded up to a multiple of this so the jit
# cache sees one shape per (netlist-geometry, λ-bucket) instead of one
# per population size.
POP_PAD = 8

# exact int32 chunked sums need diff < 2^n_o and chunk * 2^n_o < 2^31
_REDUCE_MAX_N_O = 24
# values transfer as uint32, so the device engine caps at 32 outputs
_DEVICE_MAX_N_O = 32


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pop_values(out32: jax.Array, n_o: int) -> jax.Array:
    """(P, n_o, W32) uint32 output planes -> (P, 32*W32) uint32 values.

    Lane L bit k is vector 32*L + k (the ``split_planes64`` layout), so
    a plain reshape restores vector order; output bit b contributes
    2^b.  Accumulates plane by plane to avoid a (P, n_o, num) temp.
    """
    p, _, w32 = out32.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    vals = jnp.zeros((p, w32 * 32), dtype=jnp.uint32)
    for b in range(n_o):
        bits = ((out32[:, b, :, None] >> shifts)
                & jnp.uint32(1)).reshape(p, w32 * 32)
        vals = vals | (bits << jnp.uint32(b))
    return vals


def _values_core(funcs, in0, in1, outs, planes32, *, n_nodes, n_i, n_o,
                 interpret):
    out = bitsim_pop_pallas(funcs, in0, in1, outs, planes32,
                            n_nodes=n_nodes, n_i=n_i, n_o=n_o,
                            interpret=interpret)
    return _pop_values(out, n_o)


def _reduce_core(funcs, in0, in1, outs, planes32, exact_u32, *, n_nodes,
                 n_i, n_o, num, interpret):
    """Population sim + on-device error reduction.

    Returns (ne, wce, sums): per-candidate count of differing vectors,
    max |diff|, and chunked partial sums of |diff| — all EXACT int32
    (chunk size (2^31-1) >> n_o bounds every partial sum below 2^31),
    so the float64 host finish reproduces the numpy metric bit for bit.
    """
    vals = _values_core(funcs, in0, in1, outs, planes32, n_nodes=n_nodes,
                        n_i=n_i, n_o=n_o, interpret=interpret)
    numpad = vals.shape[1]
    valid = jnp.arange(numpad) < num
    diff = jnp.abs(vals.astype(jnp.int32) - exact_u32.astype(jnp.int32))
    diff = jnp.where(valid[None, :], diff, 0)
    ne = jnp.sum(diff != 0, axis=1, dtype=jnp.int32)
    wce = jnp.max(diff, axis=1)
    chunk = max(1, (2 ** 31 - 1) >> n_o)
    pad = (-numpad) % chunk
    diffp = jnp.pad(diff, ((0, 0), (0, pad)))
    sums = diffp.reshape(diff.shape[0], -1, chunk).sum(
        axis=2, dtype=jnp.int32)
    return ne, wce, sums


_device_reduce = jax.jit(
    _reduce_core,
    static_argnames=("n_nodes", "n_i", "n_o", "num", "interpret"))
_device_values = jax.jit(
    _values_core, static_argnames=("n_nodes", "n_i", "n_o", "interpret"))


@functools.lru_cache(maxsize=None)
def _sharded_reduce(mesh, axis, n_nodes, n_i, n_o, num, interpret):
    """shard_map'd ``_reduce_core``: candidate axis split across
    ``axis``, planes + exact values replicated on every device."""
    from jax.experimental.shard_map import shard_map
    inner = functools.partial(_reduce_core, n_nodes=n_nodes, n_i=n_i,
                              n_o=n_o, num=num, interpret=interpret)
    return jax.jit(shard_map(
        inner, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None),
                  P(axis, None), P(None, None), P(None)),
        out_specs=(P(axis), P(axis), P(axis, None)),
        check_rep=False))


@functools.lru_cache(maxsize=None)
def _sharded_values(mesh, axis, n_nodes, n_i, n_o, interpret):
    from jax.experimental.shard_map import shard_map
    inner = functools.partial(_values_core, n_nodes=n_nodes, n_i=n_i,
                              n_o=n_o, interpret=interpret)
    return jax.jit(shard_map(
        inner, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None),
                  P(axis, None), P(None, None)),
        out_specs=P(axis, None),
        check_rep=False))


class PopEvaluator:
    """Scores candidate *populations* against one exact oracle.

    engine='numpy'  — per-candidate ``Netlist.eval_words`` host loop
                      (the sequential baseline).
    engine='device' — ONE ``bitsim_pop_pallas`` program per call;
                      er/mae/wce reduce on device (bit-identical floats
                      to the numpy engine), other metrics reduce on
                      host from device-computed values.

    ``sharding`` (a ``launch/mesh.pop_sharding`` NamedSharding) splits
    the population axis across devices via shard_map; population sizes
    are padded to a multiple of lcm(POP_PAD, axis size).  Instrumented:
    ``n_scored`` candidates / ``n_calls`` evaluation calls.
    """

    def __init__(self, exact: Netlist, params: CgpParams,
                 engine: str = "numpy",
                 sharding: Optional[NamedSharding] = None,
                 interpret: Optional[bool] = None):
        if engine not in ("numpy", "device"):
            raise ValueError(f"unknown engine {engine!r} "
                             "(expected 'numpy' or 'device')")
        if params.metric not in METRIC_NAMES:
            raise ValueError(f"unknown metric {params.metric}")
        self.engine = engine
        self.metric = params.metric
        self.exact = exact
        self.n_i, self.n_o = exact.n_i, exact.n_o
        rng = np.random.default_rng(params.seed + 7919)
        self.planes64, self.num = search_planes(
            self.n_i, params.search_samples, rng)
        exact_planes = exact.eval_words(self.planes64)
        self.exact_vals = unpack_values(exact_planes, self.n_o, self.num)
        self.sharding = sharding
        self.n_scored = 0
        self.n_calls = 0
        if engine == "device":
            if self.n_o > _DEVICE_MAX_N_O:
                raise ValueError(
                    f"device engine caps at {_DEVICE_MAX_N_O} output "
                    f"bits (got {self.n_o}); use engine='numpy' for "
                    "wider circuits")
            self.interpret = _interpret() if interpret is None \
                else interpret
            self.planes32 = jnp.asarray(split_planes64(self.planes64))
            numpad = self.planes32.shape[1] * 32
            buf = np.zeros(numpad, dtype=np.uint32)
            buf[:self.num] = unpack_outputs(
                exact_planes, self.n_o, self.num).astype(np.uint32)
            self.exact_u32 = jnp.asarray(buf)

    # -- scoring --------------------------------------------------------
    def errors_of(self, pop: Sequence[Netlist]) -> np.ndarray:
        """(len(pop),) float64 of ``params.metric`` per candidate —
        identical values from both engines."""
        pop = list(pop)
        self.n_scored += len(pop)
        self.n_calls += 1
        if self.engine == "numpy":
            out = np.empty(len(pop), dtype=np.float64)
            for k, nl in enumerate(pop):
                vals = unpack_values(nl.eval_words(self.planes64),
                                     self.n_o, self.num)
                out[k] = error_report_from_values(
                    vals, self.exact_vals, exhaustive=False
                ).get(self.metric)
            return out
        return self._device_errors(pop)

    def _padded(self, pop: list):
        axis = None
        pad_to = POP_PAD
        if self.sharding is not None and len(self.sharding.spec) \
                and self.sharding.spec[0] is not None:
            axis = self.sharding.spec[0]
            pad_to = int(np.lcm(POP_PAD,
                                self.sharding.mesh.shape[axis]))
        pp = -(-len(pop) // pad_to) * pad_to
        return pop + [pop[0]] * (pp - len(pop)), axis

    def _device_errors(self, pop: list) -> np.ndarray:
        p = len(pop)
        pop_p, axis = self._padded(pop)
        funcs, in0, in1, outs = stack_netlists(pop_p)
        n_nodes = funcs.shape[1]
        arrs = (jnp.asarray(funcs), jnp.asarray(in0), jnp.asarray(in1),
                jnp.asarray(outs))
        if self.metric in DEVICE_METRICS and self.n_o <= _REDUCE_MAX_N_O:
            if axis is not None:
                fn = _sharded_reduce(self.sharding.mesh, axis, n_nodes,
                                     self.n_i, self.n_o, self.num,
                                     self.interpret)
                ne, wce, sums = fn(*arrs, self.planes32, self.exact_u32)
            else:
                ne, wce, sums = _device_reduce(
                    *arrs, self.planes32, self.exact_u32,
                    n_nodes=n_nodes, n_i=self.n_i, n_o=self.n_o,
                    num=self.num, interpret=self.interpret)
            ne, wce, sums = (np.asarray(ne), np.asarray(wce),
                             np.asarray(sums))
            if self.metric == "er":
                vals = ne.astype(np.float64) / self.num
            elif self.metric == "wce":
                vals = wce.astype(np.float64)
            else:   # mae: exact integer total, float64 division
                vals = (sums.astype(np.int64).sum(axis=1)
                        .astype(np.float64) / self.num)
            return vals[:p]
        # host-reduced fallback (mse/mre/wcre, or n_o in 25..32): the
        # simulation still runs as one device program.
        if axis is not None:
            fn = _sharded_values(self.sharding.mesh, axis, n_nodes,
                                 self.n_i, self.n_o, self.interpret)
            vals32 = np.asarray(fn(*arrs, self.planes32))
        else:
            vals32 = np.asarray(_device_values(
                *arrs, self.planes32, n_nodes=n_nodes, n_i=self.n_i,
                n_o=self.n_o, interpret=self.interpret))
        out = np.empty(p, dtype=np.float64)
        for k in range(p):
            v = vals32[k, :self.num].astype(np.float64)
            out[k] = error_report_from_values(
                v, self.exact_vals, exhaustive=False).get(self.metric)
        return out


# ----------------------------------------------------------------------
# Generational (1+λ) search
# ----------------------------------------------------------------------
def _select(scores: list) -> int:
    """Best offspring index; ties resolve to the lowest index so both
    engines (and any future parallel scorer) agree deterministically."""
    return min(range(len(scores)),
               key=lambda i: (scores[i].infeasible, scores[i].primary, i))


def evolve_pop(
    seed_netlist: Netlist,
    exact: Netlist,
    params: CgpParams,
    engine: str = "numpy",
    on_candidate: Optional[Callable[[Netlist, float, float], None]] = None,
    evaluator: Optional[PopEvaluator] = None,
    sharding: Optional[NamedSharding] = None,
) -> EvolvedCircuit:
    """Generational (1+λ) run: all λ offspring mutate from the SAME
    parent and score in one ``PopEvaluator`` call (one device program
    when engine='device').  NOTE the deliberate semantic difference
    from ``cgp.evolve``, whose offspring chain within a generation —
    the generational step is what makes population scoring possible.
    Fixed seed ⇒ identical result from both engines.
    """
    rng = np.random.default_rng(params.seed)
    ev = evaluator if evaluator is not None else \
        PopEvaluator(exact, params, engine=engine, sharding=sharding)
    parent = seed_netlist
    p_err = float(ev.errors_of([parent])[0])
    p_score = _score(p_err, evaluate_cost(parent).area,
                     params.e_min, params.e_max)
    best_feasible: Optional[Netlist] = \
        parent if p_score.infeasible == 0 else None

    for _gen in range(params.generations):
        children = [mutate(parent, rng, params.h)
                    for _ in range(params.lam)]
        errs = ev.errors_of(children)
        areas = [evaluate_cost(c).area for c in children]
        scores = [_score(float(errs[k]), areas[k], params.e_min,
                         params.e_max) for k in range(params.lam)]
        k = _select(scores)
        if scores[k] <= p_score:   # allow neutral drift
            improved = scores[k] < p_score
            parent, p_err, p_score = children[k], float(errs[k]), scores[k]
            if p_score.infeasible == 0:
                best_feasible = parent
                if improved and on_candidate is not None:
                    on_candidate(parent, p_err, areas[k])

    final = best_feasible if best_feasible is not None else seed_netlist
    final = final.compact()
    errors = evaluate_errors(final, exact)   # exhaustive re-verify
    cost = evaluate_cost(final)
    return EvolvedCircuit(netlist=final, errors=errors,
                          cost_area=cost.area, cost_power=cost.power)


@dataclass
class _Run:
    e_max: float
    rng: np.random.Generator
    parent: Netlist
    p_err: float
    p_score: _Score
    best_feasible: Optional[Netlist]


def evolve_ladder(
    seed_netlist: Netlist,
    exact: Netlist,
    e_max_ladder: Sequence[float],
    params: CgpParams,
    engine: str = "device",
    on_candidate: Optional[
        Callable[[int, Netlist, float, float], None]] = None,
    sharding: Optional[NamedSharding] = None,
    evaluator: Optional[PopEvaluator] = None,
) -> list:
    """The whole e_max ladder as ONE generation-synchronous sweep.

    Every rung runs an independent generational (1+λ) search from the
    shared seed; per generation all rungs' offspring fuse into a single
    (len(ladder) * λ) population scored in one evaluator call — the
    population axis shards across devices via
    ``launch/mesh.pop_sharding``.  Rung i is trajectory-identical to
    ``evolve_pop(seed, exact, replace(params, e_max=ladder[i],
    seed=params.seed + i), evaluator=<shared>)``.

    ``on_candidate(rung_index, netlist, err, area)`` fires for every
    improved feasible parent.  Returns one ``EvolvedCircuit`` per rung
    (ladder sorted ascending), each exhaustively re-verified.
    """
    ladder = sorted(float(e) for e in e_max_ladder)
    ev = evaluator if evaluator is not None else \
        PopEvaluator(exact, params, engine=engine, sharding=sharding)
    seed_err = float(ev.errors_of([seed_netlist])[0])
    seed_area = evaluate_cost(seed_netlist).area
    runs = []
    for i, e_max in enumerate(ladder):
        sc = _score(seed_err, seed_area, params.e_min, e_max)
        runs.append(_Run(
            e_max=e_max, rng=np.random.default_rng(params.seed + i),
            parent=seed_netlist, p_err=seed_err, p_score=sc,
            best_feasible=seed_netlist if sc.infeasible == 0 else None))

    lam = params.lam
    for _gen in range(params.generations):
        pop = [mutate(r.parent, r.rng, params.h)
               for r in runs for _ in range(lam)]
        errs = ev.errors_of(pop)
        for ri, r in enumerate(runs):
            ch = pop[ri * lam:(ri + 1) * lam]
            es = errs[ri * lam:(ri + 1) * lam]
            areas = [evaluate_cost(c).area for c in ch]
            scores = [_score(float(es[k]), areas[k], params.e_min,
                             r.e_max) for k in range(lam)]
            k = _select(scores)
            if scores[k] <= r.p_score:
                improved = scores[k] < r.p_score
                r.parent, r.p_err, r.p_score = \
                    ch[k], float(es[k]), scores[k]
                if r.p_score.infeasible == 0:
                    r.best_feasible = r.parent
                    if improved and on_candidate is not None:
                        on_candidate(ri, r.parent, r.p_err, areas[k])

    out = []
    for r in runs:
        final = (r.best_feasible if r.best_feasible is not None
                 else seed_netlist).compact()
        errors = evaluate_errors(final, exact)   # exhaustive re-verify
        cost = evaluate_cost(final)
        out.append(EvolvedCircuit(netlist=final, errors=errors,
                                  cost_area=cost.area,
                                  cost_power=cost.power))
    return out
