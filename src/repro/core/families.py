"""Analytic (manually-designed) approximate circuit families.

These are the classic ad-hoc designs the paper benchmarks its evolved
circuits against (Sec. IV, Table II):

  * truncated multipliers  — drop the k LSBs of both operands
  * BAM multipliers        — broken-array multiplier [Mahdiani et al.],
                             horizontal break h (drop first h partial-
                             product rows) + vertical break v (drop all
                             partial products of weight < v)
  * LOA adders             — lower-part OR adder: low k bits are OR'd,
                             upper part is an exact adder seeded with
                             the AND of the top low-part bits
  * truncated adders       — drop the k LSBs entirely

All are generated as gate-level netlists so they flow through the same
cost/error pipeline as the evolved circuits.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from . import gates
from .netlist import Netlist
from .seeds import _Builder


def masked_array_multiplier(
    width: int, keep: Callable[[int, int], bool], name: str
) -> Netlist:
    """Array multiplier generating only the partial products for which
    ``keep(i, j)`` is true (row i = bit i of operand B, column j = bit j
    of operand A; weight = i + j). Dropped products contribute 0."""
    w = width
    b = _Builder(2 * w)

    def pp(i: int, j: int):
        if keep(i, j):
            return b.gate(gates.AND, b.inp(j), b.inp(w + i))
        return None

    zero = None

    def z():
        nonlocal zero
        if zero is None:
            zero = b.const0()
        return zero

    rows = [[pp(i, j) for j in range(w)] for i in range(w)]
    outs: list = [rows[0][0] if rows[0][0] is not None else z()]
    row = rows[0][1:]
    for i in range(1, w):
        nxt: list = []
        carry = None
        for j in range(w):
            acc = row[j] if j < len(row) else None
            p = rows[i][j]
            terms = [t for t in (p, acc, carry) if t is not None]
            if len(terms) == 0:
                s, c = None, None
            elif len(terms) == 1:
                s, c = terms[0], None
            elif len(terms) == 2:
                s, c = b.half_adder(terms[0], terms[1])
            else:
                s, c = b.full_adder(terms[0], terms[1], terms[2])
            if j == 0:
                outs.append(s if s is not None else z())
            else:
                nxt.append(s)
            carry = c
        nxt.append(carry)  # may be None; padded below
        row = nxt
    for s in row:
        outs.append(s if s is not None else z())
    outs = [o for o in outs]
    while len(outs) < 2 * w:
        outs.append(z())
    nl = b.finish(outs[: 2 * w], 2 * w, name)
    return nl.compact()


def truncated_multiplier(width: int, k: int) -> Netlist:
    """Truncate k LSBs of both operands (paper's 'Truncated (width-k)-bit')."""
    return masked_array_multiplier(
        width, lambda i, j: i >= k and j >= k, f"mul{width}u_trunc{width - k}"
    )


def bam_multiplier(width: int, h: int, v: int) -> Netlist:
    """Broken-array multiplier with horizontal break h, vertical break v."""
    return masked_array_multiplier(
        width, lambda i, j: i >= h and (i + j) >= v, f"mul{width}u_bam_h{h}_v{v}"
    )


def loa_adder(width: int, k: int) -> Netlist:
    """Lower-part OR adder: s_i = a_i | b_i for i < k; carry into the
    upper exact ripple part is a_{k-1} & b_{k-1}."""
    if not 0 < k < width:
        raise ValueError("0 < k < width required")
    b = _Builder(2 * width)
    outs: list[int] = []
    for i in range(k):
        outs.append(b.gate(gates.OR, b.inp(i), b.inp(width + i)))
    carry = b.gate(gates.AND, b.inp(k - 1), b.inp(width + k - 1))
    for i in range(k, width):
        s, carry = b.full_adder(b.inp(i), b.inp(width + i), carry)
        outs.append(s)
    outs.append(carry)
    return b.finish(outs, width + 1, f"add{width}u_loa{k}")


def truncated_adder(width: int, k: int) -> Netlist:
    """Drop the k LSBs entirely (outputs 0), exact ripple above."""
    if not 0 < k < width:
        raise ValueError("0 < k < width required")
    b = _Builder(2 * width)
    zero = b.const0()
    outs: list[int] = [zero] * k
    s, carry = b.half_adder(b.inp(k), b.inp(width + k))
    outs.append(s)
    for i in range(k + 1, width):
        s, carry = b.full_adder(b.inp(i), b.inp(width + i), carry)
        outs.append(s)
    outs.append(carry)
    return b.finish(outs, width + 1, f"add{width}u_trunc{k}")


# ----------------------------------------------------------------------
# Composed wide multipliers (tiled 8x8 partial products, DESIGN.md §2.6)
# ----------------------------------------------------------------------
#: Operand width of the partial-product tile every composed multiplier
#: is built from — the library's 8-bit LUT machinery executes it.
TILE_BITS = 8

REDUCE_KINDS = ("exact", "loa", "trunc")


def parse_reduce(token: str) -> tuple[str, int]:
    """Normalize a reduction-adder descriptor to ``(kind, k)``.

    Accepted forms: ``"exact"``, ``"loa4"``/``"trunc3"`` (family + low
    part width), or a library adder entry name like ``"add32u_loa4"``
    (the width prefix is the tree node's width, chosen by the builder,
    so only the family suffix matters here).
    """
    t = token.strip().lower()
    if t.startswith("add") and "_" in t:
        t = t.split("_", 1)[1]
    if t == "exact":
        return ("exact", 0)
    for kind in ("loa", "trunc"):
        if t.startswith(kind):
            digits = t[len(kind):]
            if digits.isdigit() and int(digits) > 0:
                return (kind, int(digits))
    raise ValueError(
        f"unknown reduction adder {token!r}; expected 'exact', "
        "'loa<k>', 'trunc<k>' or a library adder name like "
        "'add32u_loa4'")


def reduce_tag(token: str) -> str:
    """Canonical short tag of a reduction descriptor ('exact', 'loa4')."""
    kind, k = parse_reduce(token)
    return kind if kind == "exact" else f"{kind}{k}"


def _embed(b: _Builder, nl: Netlist, inputs: list) -> list:
    """Append ``nl``'s gates to builder ``b`` with its primary inputs
    wired to the given builder signals; returns builder signals for
    ``nl``'s outputs.  The embedded copy is gate-for-gate identical to
    the stand-alone netlist, so composed circuits inherit the tile's
    exact cost and function.

    Operand reads respect gate arity (like ``Netlist.eval_words``):
    compacted CGP netlists keep stale indices in UNUSED operand slots
    (e.g. a NOT gate's ``in1`` pointing at a dropped node), which must
    not be dereferenced."""
    if len(inputs) != nl.n_i:
        raise ValueError(f"{nl.name or 'netlist'} wants {nl.n_i} inputs, "
                         f"got {len(inputs)}")
    node_sig: list = []

    def src(s: int) -> int:
        s = int(s)
        return inputs[s] if s < nl.n_i else node_sig[s - nl.n_i]

    for j in range(nl.n_nodes):
        f = int(nl.funcs[j])
        arity = int(gates.GATE_ARITY[f])
        a = src(nl.in0[j]) if arity >= 1 else 0
        bb = src(nl.in1[j]) if arity >= 2 else 0
        node_sig.append(b.gate(f, a, bb))
    return [src(s) for s in nl.outputs]


def _reduce_adder_netlist(width: int, kind: str, k: int) -> Netlist:
    from .seeds import ripple_carry_adder
    if kind == "exact":
        return ripple_carry_adder(width)
    if kind == "loa":
        return loa_adder(width, k)
    if kind == "trunc":
        return truncated_adder(width, k)
    raise ValueError(f"unknown reduction adder kind {kind!r}")


def composed_multiplier(tile: Netlist, width: int,
                        reduce: str = "exact",
                        name: str = "") -> Netlist:
    """W-bit multiplier composed from 8x8 ``tile`` partial products.

    Operands split into base-256 digits ``a = a0 + 256*a1`` (the high
    digit has ``width - 8`` bits; the tile's upper input bits are tied
    to 0).  The four digit products ``pp_ij = tile(a_i, b_j)`` reduce
    through a shift/add tree whose every node is a ``reduce``-family
    adder (exact ripple / LOA / truncated — the same generators the
    library characterizes):

        s1 = ADD(pp01, pp10)            # 16-bit node
        s2 = ADD(pp00, s1 << 8)         # 25-bit node
        p  = ADD(s2, pp11 << 16)        # 32-bit node, low 2W bits kept

    This is the gate-level ground truth of the composed datapath: the
    executable engine (``repro.kernels.composed_matmul``) must be
    bit-identical to ``bitsim`` of this netlist on every operand pair
    (DESIGN.md §2.6).
    """
    if tile.n_i != 2 * TILE_BITS or tile.n_o != 2 * TILE_BITS:
        raise ValueError(
            f"composition tile must be an {TILE_BITS}x{TILE_BITS} "
            f"multiplier (16 in / 16 out); got {tile.n_i} in / "
            f"{tile.n_o} out ({tile.name!r})")
    if not TILE_BITS < width <= 2 * TILE_BITS:
        raise ValueError(
            f"composed width must be in ({TILE_BITS}, {2 * TILE_BITS}]; "
            f"got {width}")
    kind, k = parse_reduce(reduce)
    if kind != "exact" and not 0 < k < 2 * TILE_BITS:
        # the narrowest tree node is the 16-bit s1 adder: k must fit
        # EVERY node or the vectorized engine semantics would diverge
        raise ValueError(
            f"reduction adder low part k={k} must be in "
            f"(0, {2 * TILE_BITS}) to fit every tree node")
    b = _Builder(2 * width)
    zero = b.const0()
    hi_w = width - TILE_BITS

    def digits(base: int) -> tuple[list, list]:
        lo = [b.inp(base + t) for t in range(TILE_BITS)]
        hi = ([b.inp(base + TILE_BITS + t) for t in range(hi_w)]
              + [zero] * (TILE_BITS - hi_w))
        return lo, hi

    a0, a1 = digits(0)
    b0, b1 = digits(width)
    pp00 = _embed(b, tile, a0 + b0)
    pp01 = _embed(b, tile, a0 + b1)
    pp10 = _embed(b, tile, a1 + b0)
    pp11 = _embed(b, tile, a1 + b1)

    def add(x: list, y: list) -> list:
        w = max(len(x), len(y))
        x = x + [zero] * (w - len(x))
        y = y + [zero] * (w - len(y))
        return _embed(b, _reduce_adder_netlist(w, kind, k), x + y)

    s1 = add(pp01, pp10)                          # 17 bits
    s2 = add(pp00, [zero] * TILE_BITS + s1)       # 26 bits
    p = add(s2, [zero] * (2 * TILE_BITS) + pp11)  # 33 bits; top bits 0
    outs = (p + [zero] * (2 * width))[: 2 * width]
    name = name or (f"mul{width}u_c_{tile.name or 'tile'}_"
                    f"{reduce_tag(reduce)}")
    return b.finish(outs, 2 * width, name).compact()
