"""Analytic (manually-designed) approximate circuit families.

These are the classic ad-hoc designs the paper benchmarks its evolved
circuits against (Sec. IV, Table II):

  * truncated multipliers  — drop the k LSBs of both operands
  * BAM multipliers        — broken-array multiplier [Mahdiani et al.],
                             horizontal break h (drop first h partial-
                             product rows) + vertical break v (drop all
                             partial products of weight < v)
  * LOA adders             — lower-part OR adder: low k bits are OR'd,
                             upper part is an exact adder seeded with
                             the AND of the top low-part bits
  * truncated adders       — drop the k LSBs entirely

All are generated as gate-level netlists so they flow through the same
cost/error pipeline as the evolved circuits.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from . import gates
from .netlist import Netlist
from .seeds import _Builder


def masked_array_multiplier(
    width: int, keep: Callable[[int, int], bool], name: str
) -> Netlist:
    """Array multiplier generating only the partial products for which
    ``keep(i, j)`` is true (row i = bit i of operand B, column j = bit j
    of operand A; weight = i + j). Dropped products contribute 0."""
    w = width
    b = _Builder(2 * w)

    def pp(i: int, j: int):
        if keep(i, j):
            return b.gate(gates.AND, b.inp(j), b.inp(w + i))
        return None

    zero = None

    def z():
        nonlocal zero
        if zero is None:
            zero = b.const0()
        return zero

    rows = [[pp(i, j) for j in range(w)] for i in range(w)]
    outs: list = [rows[0][0] if rows[0][0] is not None else z()]
    row = rows[0][1:]
    for i in range(1, w):
        nxt: list = []
        carry = None
        for j in range(w):
            acc = row[j] if j < len(row) else None
            p = rows[i][j]
            terms = [t for t in (p, acc, carry) if t is not None]
            if len(terms) == 0:
                s, c = None, None
            elif len(terms) == 1:
                s, c = terms[0], None
            elif len(terms) == 2:
                s, c = b.half_adder(terms[0], terms[1])
            else:
                s, c = b.full_adder(terms[0], terms[1], terms[2])
            if j == 0:
                outs.append(s if s is not None else z())
            else:
                nxt.append(s)
            carry = c
        nxt.append(carry)  # may be None; padded below
        row = nxt
    for s in row:
        outs.append(s if s is not None else z())
    outs = [o for o in outs]
    while len(outs) < 2 * w:
        outs.append(z())
    nl = b.finish(outs[: 2 * w], 2 * w, name)
    return nl.compact()


def truncated_multiplier(width: int, k: int) -> Netlist:
    """Truncate k LSBs of both operands (paper's 'Truncated (width-k)-bit')."""
    return masked_array_multiplier(
        width, lambda i, j: i >= k and j >= k, f"mul{width}u_trunc{width - k}"
    )


def bam_multiplier(width: int, h: int, v: int) -> Netlist:
    """Broken-array multiplier with horizontal break h, vertical break v."""
    return masked_array_multiplier(
        width, lambda i, j: i >= h and (i + j) >= v, f"mul{width}u_bam_h{h}_v{v}"
    )


def loa_adder(width: int, k: int) -> Netlist:
    """Lower-part OR adder: s_i = a_i | b_i for i < k; carry into the
    upper exact ripple part is a_{k-1} & b_{k-1}."""
    if not 0 < k < width:
        raise ValueError("0 < k < width required")
    b = _Builder(2 * width)
    outs: list[int] = []
    for i in range(k):
        outs.append(b.gate(gates.OR, b.inp(i), b.inp(width + i)))
    carry = b.gate(gates.AND, b.inp(k - 1), b.inp(width + k - 1))
    for i in range(k, width):
        s, carry = b.full_adder(b.inp(i), b.inp(width + i), carry)
        outs.append(s)
    outs.append(carry)
    return b.finish(outs, width + 1, f"add{width}u_loa{k}")


def truncated_adder(width: int, k: int) -> Netlist:
    """Drop the k LSBs entirely (outputs 0), exact ripple above."""
    if not 0 < k < width:
        raise ValueError("0 < k < width required")
    b = _Builder(2 * width)
    zero = b.const0()
    outs: list[int] = [zero] * k
    s, carry = b.half_adder(b.inp(k), b.inp(width + k))
    outs.append(s)
    for i in range(k + 1, width):
        s, carry = b.full_adder(b.inp(i), b.inp(width + i), carry)
        outs.append(s)
    outs.append(carry)
    return b.finish(outs, width + 1, f"add{width}u_trunc{k}")
