"""Gate set and 45 nm technology cost tables.

The CGP function set Γ used throughout the library matches the paper
(Sec. II-B, Fig. 1): identity, not, and, or, xor, nand, nor, xnor,
const0, const1.  Each gate carries an (area, leakage+dynamic power at a
reference activity, delay) triple loosely modeled on a 45 nm standard-cell
library (NanGate45-like relative magnitudes).  The paper reports circuit
power *relative to the exact multiplier*, so only the relative magnitudes
of these numbers matter for the methodology; we document them here as the
framework's deterministic cost model (DESIGN.md §4.4).
"""
from __future__ import annotations

import numpy as np

# Function codes (match the paper's Fig. 1 ordering).
IDENTITY = 0
NOT = 1
AND = 2
OR = 3
XOR = 4
NAND = 5
NOR = 6
XNOR = 7
CONST0 = 8
CONST1 = 9

N_FUNCS = 10

GATE_NAMES = {
    IDENTITY: "buf",
    NOT: "inv",
    AND: "and2",
    OR: "or2",
    XOR: "xor2",
    NAND: "nand2",
    NOR: "nor2",
    XNOR: "xnor2",
    CONST0: "tie0",
    CONST1: "tie1",
}

# 45 nm-style relative cost model.
#   area  : um^2 (NanGate45-like)
#   power : uW at reference activity (switching + leakage)
#   delay : ps typical corner
GATE_AREA = np.array(
    [1.064, 0.532, 1.064, 1.064, 1.596, 0.798, 0.798, 1.596, 0.0, 0.0]
)
GATE_POWER = np.array(
    [0.72, 0.55, 0.92, 0.98, 1.78, 0.68, 0.70, 1.70, 0.0, 0.0]
)
GATE_DELAY = np.array(
    [28.0, 14.0, 36.0, 38.0, 52.0, 22.0, 24.0, 54.0, 0.0, 0.0]
)

# Number of inputs actually consumed by each function (arity for cost/
# connectivity purposes; the genome always stores two input fields).
GATE_ARITY = np.array([1, 1, 2, 2, 2, 2, 2, 2, 0, 0])


def eval_gate_words(func: int, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Evaluate one gate on bit-packed uint64 word arrays (bit-parallel).

    ``a`` and ``b`` hold one bit per simulated input vector, packed 64 to a
    word.  Constants use all-zeros / all-ones words.
    """
    if func == IDENTITY:
        return a
    if func == NOT:
        return ~a
    if func == AND:
        return a & b
    if func == OR:
        return a | b
    if func == XOR:
        return a ^ b
    if func == NAND:
        return ~(a & b)
    if func == NOR:
        return ~(a | b)
    if func == XNOR:
        return ~(a ^ b)
    if func == CONST0:
        return np.zeros_like(a)
    if func == CONST1:
        return np.full_like(a, np.uint64(0xFFFFFFFFFFFFFFFF))
    raise ValueError(f"unknown gate function {func}")
