"""The approximate-circuit library (paper Sec. III, Table I).

``ApproxLibrary`` stores characterized circuits (genome + six error
metrics + 45 nm cost + power relative to the exact same-width circuit),
supports Pareto-front queries per error metric, the paper's selection
rule ("10 circuits evenly distributed along the power axis" per metric,
union + dedup -> the case-study subset), JSON (de)serialization, and
LUT materialization for the NN emulation backends.

``build_default_library`` populates it from:
  * exact seeds (ripple adders, array multipliers),
  * analytic families (truncated / BAM multipliers, LOA / truncated
    adders) across 8..128-bit widths — these fill the wide-bit-width
    rows of Table I where exhaustive evolution is infeasible,
  * CGP-evolved 8-bit (and optionally 12/16-bit) circuits across a
    ladder of error targets, with every improved feasible parent
    admitted to the archive (this is where the "thousands" of Table I
    entries come from at full budget).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .cgp import CgpParams, ParetoArchive, evolve, pad_nodes
from .cost import CostReport, evaluate_cost
from .families import (TILE_BITS, bam_multiplier, composed_multiplier,
                       loa_adder, reduce_tag, truncated_adder,
                       truncated_multiplier)
from .luts import MAX_LUT_WIDTH, LutWidthError, lut_from_netlist, \
    exact_mul_lut
from .metrics import ErrorReport, METRIC_NAMES, evaluate_errors
from .netlist import Netlist
from .seeds import array_multiplier, ripple_carry_adder


class UnknownCircuitError(KeyError):
    """A library lookup named a circuit that is not in the library."""

    def __init__(self, name: str, library: "ApproxLibrary"):
        self.circuit = name
        hint = ""
        close = sorted(n for n in library.entries
                       if n.startswith(name[:6]))[:6]
        if close:
            hint = f"; closest entries: {close}"
        super().__init__(
            f"unknown circuit {name!r} ({len(library.entries)} entries "
            f"in library){hint}")


class WidthMismatchError(ValueError):
    """A spec's ``bit_width`` disagrees with the library entry's width."""

    def __init__(self, name: str, expected: int, actual: int):
        self.circuit = name
        self.expected = expected
        self.actual = actual
        super().__init__(
            f"circuit {name!r} is {actual}-bit but the spec declares "
            f"bit_width={expected}; drop bit_width to infer it from "
            "the library, or name a circuit of the declared width")

_DATA_DIR = os.path.join(os.path.dirname(__file__), "library_data")
DEFAULT_LIBRARY_PATH = os.path.join(_DATA_DIR, "default_library.json")

# metrics the paper pairs with power for Pareto selection (EP == ER)
SELECTION_METRICS = ("er", "mae", "wce", "mse", "mre")


@dataclass
class CircuitEntry:
    name: str
    kind: str          # 'adder' | 'multiplier'
    width: int
    source: str        # 'exact'|'evolved'|'truncation'|'bam'|'loa'|'composed'
    errors: ErrorReport
    cost: CostReport
    rel_power: float   # power / power(exact same kind+width)
    netlist: Netlist
    # composed wide multipliers carry the recipe the executable engine
    # needs: {"tile": <8-bit multiplier entry name>, "reduce": token}
    # (DESIGN.md §2.6).  None for directly-materializable entries.
    composition: Optional[dict] = None

    def as_dict(self) -> dict:
        d = {
            "name": self.name,
            "kind": self.kind,
            "width": self.width,
            "source": self.source,
            "errors": self.errors.as_dict(),
            "cost": self.cost.as_dict(),
            "rel_power": self.rel_power,
            "netlist": self.netlist.to_dict(),
        }
        if self.composition is not None:
            d["composition"] = dict(self.composition)
        return d

    @staticmethod
    def from_dict(d: dict) -> "CircuitEntry":
        return CircuitEntry(
            name=d["name"],
            kind=d["kind"],
            width=int(d["width"]),
            source=d["source"],
            errors=ErrorReport(**d["errors"]),
            cost=CostReport(**d["cost"]),
            rel_power=float(d["rel_power"]),
            netlist=Netlist.from_dict(d["netlist"]),
            composition=d.get("composition"),
        )


class ApproxLibrary:
    def __init__(self):
        self.entries: dict[str, CircuitEntry] = {}
        self._lut_cache: dict[str, np.ndarray] = {}

    # -- population ----------------------------------------------------
    def add(self, entry: CircuitEntry) -> None:
        self.entries[entry.name] = entry

    def add_netlist(
        self, nl: Netlist, kind: str, width: int, source: str,
        exact: Netlist, name: Optional[str] = None,
    ) -> CircuitEntry:
        name = name or nl.name or f"{kind}{width}_{len(self.entries)}"
        errors = evaluate_errors(nl, exact)
        cost = evaluate_cost(nl)
        ref = evaluate_cost(exact).power
        entry = CircuitEntry(
            name=name, kind=kind, width=width, source=source,
            errors=errors, cost=cost,
            rel_power=(cost.power / ref if ref > 0 else 0.0),
            netlist=nl.compact(),
        )
        self.add(entry)
        return entry

    # -- queries ---------------------------------------------------------
    def entry(self, name: str,
              bit_width: Optional[int] = None) -> CircuitEntry:
        """Validated lookup: raises ``UnknownCircuitError`` for missing
        names (instead of a bare ``KeyError``) and
        ``WidthMismatchError`` when ``bit_width`` is given and
        disagrees with the entry — the spec-side width contract of the
        width-generic datapaths (DESIGN.md §2.6)."""
        e = self.entries.get(name)
        if e is None:
            raise UnknownCircuitError(name, self)
        if bit_width is not None and int(bit_width) != e.width:
            raise WidthMismatchError(name, int(bit_width), e.width)
        return e

    def select(self, kind: Optional[str] = None, width: Optional[int] = None,
               source: Optional[str] = None) -> list[CircuitEntry]:
        out = []
        for e in self.entries.values():
            if kind is not None and e.kind != kind:
                continue
            if width is not None and e.width != width:
                continue
            if source is not None and e.source != source:
                continue
            out.append(e)
        return sorted(out, key=lambda e: (e.kind, e.width, -e.rel_power))

    def counts_table(self) -> list[dict]:
        """Paper Table I: #implementations per (kind, width)."""
        buckets: dict[tuple, int] = {}
        for e in self.entries.values():
            buckets[(e.kind, e.width)] = buckets.get((e.kind, e.width), 0) + 1
        return [
            {"circuit": k, "bit_width": w, "n_implementations": c}
            for (k, w), c in sorted(buckets.items())
        ]

    def pareto_front(self, kind: str, width: int, metric: str) -> list[CircuitEntry]:
        """Non-dominated entries on (rel_power, metric), both minimized.

        Sort-by-power sweep, O(n log n): walking power groups in
        ascending order, a group's minimum-metric entries survive iff
        they strictly improve on every lower-power group's best metric
        (ties on both axes are mutually non-dominating and all kept,
        matching the exhaustive-scan semantics)."""
        pts = sorted(self.select(kind=kind, width=width),
                     key=lambda e: (e.rel_power, e.errors.get(metric)))
        front: list[CircuitEntry] = []
        best = float("inf")     # min metric among strictly lower power
        i = 0
        while i < len(pts):
            j = i
            p = pts[i].rel_power
            while j < len(pts) and pts[j].rel_power == p:
                j += 1
            m_min = pts[i].errors.get(metric)
            if m_min < best:
                front.extend(e for e in pts[i:j]
                             if e.errors.get(metric) == m_min)
                best = m_min
            i = j
        return front

    @staticmethod
    def spread_along_power(entries: list[CircuitEntry], k: int = 10) -> list[CircuitEntry]:
        """k circuits evenly distributed along the power axis (Sec. III)."""
        if len(entries) <= k:
            return list(entries)
        entries = sorted(entries, key=lambda e: e.rel_power)
        lo, hi = entries[0].rel_power, entries[-1].rel_power
        targets = np.linspace(lo, hi, k)
        picked: list[CircuitEntry] = []
        for t in targets:
            best = min(entries, key=lambda e: abs(e.rel_power - t))
            if best not in picked:
                picked.append(best)
        return picked

    def case_study_selection(self, kind: str = "multiplier", width: int = 8,
                             per_metric: int = 10) -> list[CircuitEntry]:
        """The paper's 35-multiplier construction: per metric, 10 Pareto
        circuits evenly spread over power; union; dedup."""
        seen: dict[str, CircuitEntry] = {}
        for metric in SELECTION_METRICS:
            front = self.pareto_front(kind, width, metric)
            for e in self.spread_along_power(front, per_metric):
                seen[e.name] = e
        return sorted(seen.values(), key=lambda e: -e.rel_power)

    # -- LUTs ------------------------------------------------------------
    def lut(self, name: str) -> np.ndarray:
        """(2^w, 2^w) int32 product LUT for a multiplier entry
        (w <= ``MAX_LUT_WIDTH``).  Wide netlists raise
        ``LutWidthError`` pointing at the composed datapath, and
        composed entries (any width) raise ``ValueError`` — they
        execute through ``tile_lut`` / ``composition_of`` (tiled 8x8
        partial products), never a full product table."""
        if name in self._lut_cache:
            return self._lut_cache[name]
        e = self.entry(name)
        if e.kind != "multiplier":
            raise ValueError("LUT emulation is defined for multipliers")
        if e.width > MAX_LUT_WIDTH:
            raise LutWidthError(name, e.width)
        if e.composition is not None:
            # a 12-bit composed entry's full LUT would technically fit
            # the cap, but materializing it means minutes of gate-level
            # simulation over 2^24 pairs for a table the engine never
            # reads — composed entries execute through their tile
            raise ValueError(
                f"{name!r} is a composed entry and executes through "
                "its 256x256 tile LUT — use tile_lut()/"
                "composition_of() instead of a full product LUT "
                "(DESIGN.md §2.6)")
        lut = lut_from_netlist(e.netlist, e.width)
        self._lut_cache[name] = lut
        return lut

    def composition_of(self, name: str) -> Optional[dict]:
        """The composed-datapath recipe of ``name`` (DESIGN.md §2.6):
        ``{"tile": <8-bit multiplier entry>, "reduce": token}`` for
        composed entries, None for directly-materializable 8-bit
        entries.  Wide entries WITHOUT a composition recipe are not
        executable: above ``MAX_LUT_WIDTH`` that is the LUT-size cap
        (``LutWidthError``); at 9..12 bits a full LUT *could*
        materialize but the execution engine runs 256x256 tiles only,
        so the error says that instead of blaming a cap that was not
        hit."""
        e = self.entry(name)
        if e.composition is not None:
            return dict(e.composition)
        if e.kind == "multiplier" and e.width > TILE_BITS:
            if e.width > MAX_LUT_WIDTH:
                raise LutWidthError(name, e.width)
            raise ValueError(
                f"circuit {name!r} is a direct {e.width}-bit "
                "multiplier: its full LUT fits the "
                f"{MAX_LUT_WIDTH}-bit materialization cap, but the "
                "execution engine runs 256x256 tile LUTs only "
                "(8-bit entries directly, wider ones through a "
                "composition recipe).  Register an executable "
                f"composed entry via add_composed(tile, "
                f"width={e.width}, reduce=...) — DESIGN.md §2.6.")
        return None

    def tile_lut(self, name: str) -> np.ndarray:
        """The 256x256 tile LUT that executes entry ``name``: the
        entry's own LUT for 8-bit multipliers, the composition tile's
        LUT for composed wide entries."""
        comp = self.composition_of(name)
        return self.lut(comp["tile"] if comp else name)

    # -- composed wide entries (DESIGN.md §2.6) --------------------------
    def add_composed(self, tile: str, width: int, reduce: str = "exact",
                     name: Optional[str] = None,
                     samples: int = 1 << 14) -> CircuitEntry:
        """Register a W-bit multiplier composed from 8x8 ``tile``
        partial products reduced by ``reduce``-family adders.

        The composed gate-level netlist is built (the bitsim ground
        truth of the executable engine), characterized against the
        exact same-width array multiplier (sampled — 2W input bits is
        beyond exhaustive reach), costed with the 45 nm gate model, and
        admitted with ``source="composed"`` plus the composition
        recipe.  Idempotent per (tile, width, reduce): the derived name
        is deterministic and an existing entry is returned as-is.
        """
        tile_entry = self.entry(tile, bit_width=TILE_BITS)
        if tile_entry.kind != "multiplier":
            raise ValueError(f"composition tile {tile!r} must be a "
                             "multiplier entry")
        name = name or f"mul{width}u_c_{tile}_{reduce_tag(reduce)}"
        if name in self.entries:
            from .families import parse_reduce
            e = self.entries[name]
            same = (e.width == width and e.composition is not None
                    and e.composition.get("tile") == tile
                    and parse_reduce(e.composition.get("reduce",
                                                       "exact"))
                    == parse_reduce(reduce))
            if not same:
                raise ValueError(
                    f"entry {name!r} already exists with a different "
                    f"recipe ({e.width}-bit, composition="
                    f"{e.composition}) than requested ({width}-bit, "
                    f"tile={tile!r}, reduce={reduce!r}) — explicit "
                    "names must not collide across recipes")
            return e
        nl = composed_multiplier(tile_entry.netlist, width, reduce,
                                 name=name)
        exact_name = f"mul{width}u_exact"
        if exact_name in self.entries:
            exact = self.entries[exact_name].netlist
        else:
            exact = array_multiplier(width)
        errors = evaluate_errors(nl, exact, samples=samples)
        cost = evaluate_cost(nl)
        ref = evaluate_cost(exact).power
        entry = CircuitEntry(
            name=name, kind="multiplier", width=width, source="composed",
            errors=errors, cost=cost,
            rel_power=(cost.power / ref if ref > 0 else 0.0),
            netlist=nl,
            composition={"tile": tile, "reduce": reduce})
        self.add(entry)
        return entry

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        payload = {"version": 1,
                   "entries": [e.as_dict() for e in self.entries.values()]}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)

    @staticmethod
    def load(path: str) -> "ApproxLibrary":
        with open(path) as f:
            payload = json.load(f)
        lib = ApproxLibrary()
        for d in payload["entries"]:
            lib.add(CircuitEntry.from_dict(d))
        return lib


# ----------------------------------------------------------------------
# Library construction
# ----------------------------------------------------------------------
def _genome_tag(nl: Netlist) -> str:
    import zlib
    blob = (nl.funcs.tobytes() + nl.in0.tobytes() + nl.in1.tobytes()
            + nl.outputs.tobytes())
    h = zlib.crc32(blob) % (36 ** 4)  # deterministic across processes
    digits = "0123456789ABCDEFGHIJKLMNOPQRSTUVWXYZ"
    s = ""
    for _ in range(4):
        s = digits[h % 36] + s
        h //= 36
    return s


def _evolve_family(
    lib: ApproxLibrary, kind: str, width: int, exact: Netlist,
    e_max_ladder: list[float], metric: str, generations: int, seed: int,
) -> int:
    """Run a ladder of single-objective CGP runs; admit every improved
    feasible parent plus the final circuit of each run."""
    added = 0
    prefix = ("mul" if kind == "multiplier" else "add") + f"{width}u_E"

    parent_seed = exact  # chained ladder: each run starts from the last
    for i, e_max in enumerate(sorted(e_max_ladder)):
        collected: list[Netlist] = []

        def keep(nl: Netlist, err: float, area: float) -> None:
            collected.append(nl)

        params = CgpParams(metric=metric, e_max=e_max,
                           generations=generations, seed=seed + i)
        padded = pad_nodes(parent_seed, exact.n_nodes, seed=seed + 100 + i)
        result = evolve(padded, exact, params, on_candidate=keep)
        parent_seed = result.netlist
        collected.append(result.netlist)
        # thin intermediate parents: keep at most 8 per run, spread over time
        if len(collected) > 8:
            idx = np.linspace(0, len(collected) - 1, 8).astype(int)
            collected = [collected[j] for j in idx]
        for nl in collected:
            nl = nl.compact()
            name = prefix + _genome_tag(nl)
            if name in lib.entries:
                continue
            lib.add_netlist(nl, kind, width, "evolved", exact, name=name)
            added += 1
    return added


def _evolve_family_pop(
    lib: ApproxLibrary, kind: str, width: int, exact: Netlist,
    e_max_ladder: list[float], metric: str, generations: int, seed: int,
    engine: str, sharding=None,
) -> int:
    """Population-parallel ladder (DESIGN.md §2.9): every rung of the
    e_max ladder runs from the shared seed as one generation-synchronous
    sweep — one fused device program per generation scores all
    len(ladder) * λ offspring (sharded across devices when ``sharding``
    is given).  Admits every improved feasible parent of every rung
    plus each rung's final circuit; unlike the legacy chained ladder it
    does NOT thin intermediate parents, which is where the extra
    archive entries at equal generation budget come from."""
    from .evolve_pop import evolve_ladder
    prefix = ("mul" if kind == "multiplier" else "add") + f"{width}u_E"
    collected: list[Netlist] = []

    def keep(_run: int, nl: Netlist, err: float, area: float) -> None:
        collected.append(nl)

    params = CgpParams(metric=metric, generations=generations, seed=seed)
    padded = pad_nodes(exact, exact.n_nodes, seed=seed + 100)
    results = evolve_ladder(padded, exact, e_max_ladder, params,
                            engine=engine, on_candidate=keep,
                            sharding=sharding)
    collected.extend(r.netlist for r in results)
    added = 0
    for nl in collected:
        nl = nl.compact()
        name = prefix + _genome_tag(nl)
        if name in lib.entries:
            continue
        lib.add_netlist(nl, kind, width, "evolved", exact, name=name)
        added += 1
    return added


def build_default_library(budget: str = "small",
                          progress: bool = False,
                          engine: str = "legacy",
                          sharding=None) -> ApproxLibrary:
    """Budgets: 'tiny' (tests, seconds), 'small' (default artifact,
    ~minutes), 'full' (hours — the paper's scale knob).

    ``engine`` picks the evolutionary search backend: 'legacy' keeps
    the sequential chained-ladder ``cgp.evolve`` (byte-stable default
    artifact); 'numpy' / 'device' run the population-parallel
    generational ladder (``evolve_pop.evolve_ladder``, one fused
    evaluation per generation — on device for 'device'), admit every
    improved feasible parent without thinning, and additionally
    register composed 12/16-bit rows over the evolved 8-bit Pareto
    tiles (DESIGN.md §2.9).  ``sharding`` (a ``launch/mesh.
    pop_sharding``) splits the fused population across devices."""
    cfg = {
        "tiny": dict(gens=40, ladder=3, mult_widths=(8,), add_widths=(8,),
                     wide_samples=4096, comp_tiles=1, comp_widths=(12,)),
        "small": dict(gens=250, ladder=8, mult_widths=(8, 12, 16, 32),
                      add_widths=(8, 9, 12, 16, 32, 64, 128),
                      wide_samples=16384, comp_tiles=2,
                      comp_widths=(12, 16)),
        "full": dict(gens=2500, ladder=12, mult_widths=(8, 12, 16, 32),
                     add_widths=(8, 9, 12, 16, 32, 64, 128),
                     wide_samples=65536, comp_tiles=3,
                     comp_widths=(12, 16)),
    }[budget]
    if engine not in ("legacy", "numpy", "device"):
        raise ValueError(f"unknown engine {engine!r} "
                         "(expected 'legacy', 'numpy' or 'device')")
    lib = ApproxLibrary()

    def log(msg: str) -> None:
        if progress:
            print(f"[library] {msg}", flush=True)

    # ---- multipliers -------------------------------------------------
    for w in cfg["mult_widths"]:
        exact = array_multiplier(w)
        lib.add_netlist(exact, "multiplier", w, "exact", exact,
                        name=f"mul{w}u_exact")
        for k in range(1, min(w, 8)):
            lib.add_netlist(truncated_multiplier(w, k), "multiplier", w,
                            "truncation", exact)
        for h in range(0, min(4, w)):
            for v in range(0, min(2 * w - 1, 10)):
                if h == 0 and v == 0:
                    continue
                try:
                    nl = bam_multiplier(w, h, v)
                except Exception:
                    continue
                lib.add_netlist(nl, "multiplier", w, "bam", exact)
        log(f"mul{w}: families done ({len(lib.select('multiplier', w))})")
        # evolution only where exhaustive evaluation is cheap
        if w == 8:
            max_out = float((2 ** w - 1) ** 2)
            ladder = [max_out * (2.0 ** -e) for e in
                      np.linspace(14, 4, cfg["ladder"])]
            if engine == "legacy":
                n = _evolve_family(lib, "multiplier", w, exact, ladder,
                                   "mae", cfg["gens"], seed=1234)
            else:
                n = _evolve_family_pop(lib, "multiplier", w, exact,
                                       ladder, "mae", cfg["gens"],
                                       seed=1234, engine=engine,
                                       sharding=sharding)
            log(f"mul{w}: evolved {n}")

    # composed wide rows over the freshly evolved 8-bit Pareto tiles
    # (population engines only — the legacy build stays byte-stable)
    if engine != "legacy":
        front = [e for e in lib.pareto_front("multiplier", 8, "mae")
                 if e.source == "evolved"]
        for tile in front[:cfg["comp_tiles"]]:
            for cw in cfg["comp_widths"]:
                lib.add_composed(tile.name, cw, reduce="exact",
                                 samples=cfg["wide_samples"])
                log(f"mul{cw}: composed over {tile.name}")

    # ---- adders --------------------------------------------------------
    for w in cfg["add_widths"]:
        exact = ripple_carry_adder(w)
        lib.add_netlist(exact, "adder", w, "exact", exact,
                        name=f"add{w}u_exact")
        for k in range(1, w):
            if k > 16:
                break
            lib.add_netlist(loa_adder(w, k), "adder", w, "loa", exact)
            lib.add_netlist(truncated_adder(w, k), "adder", w, "truncation",
                            exact)
        log(f"add{w}: families done")
        if w == 8:
            max_out = float(2 ** (w + 1) - 1)
            ladder = [max_out * (2.0 ** -e) for e in
                      np.linspace(9, 2, cfg["ladder"])]
            if engine == "legacy":
                n = _evolve_family(lib, "adder", w, exact, ladder, "mae",
                                   cfg["gens"], seed=4321)
            else:
                n = _evolve_family_pop(lib, "adder", w, exact, ladder,
                                       "mae", cfg["gens"], seed=4321,
                                       engine=engine, sharding=sharding)
            log(f"add{w}: evolved {n}")

    return lib


_default_library: Optional[ApproxLibrary] = None


def get_default_library() -> ApproxLibrary:
    """Load the prebuilt artifact, or build a tiny library on miss."""
    global _default_library
    if _default_library is None:
        if os.path.exists(DEFAULT_LIBRARY_PATH):
            _default_library = ApproxLibrary.load(DEFAULT_LIBRARY_PATH)
        else:
            _default_library = build_default_library("tiny")
    return _default_library
