"""LUT construction and low-rank decomposition of approximate multipliers.

The TFApprox-style emulation of an 8-bit approximate multiplier is a
256x256 int32 lookup table.  On TPU we additionally support a *low-rank
decomposition* of that table (DESIGN.md §4.2):

    L[a, b] ≈ sum_r U[r, a] * V[r, b]        (rank-R, via SVD)

which converts the emulated matmul into R per-element 256-entry table
lookups followed by R MXU matmuls.  An exact multiplier is exactly rank
1 (L = a bᵀ); truncation is rank 1; BAM is near-rank-2; evolved circuits
are numerically near-low-rank because their error surfaces are highly
structured.  ``rank_profile`` quantifies, per circuit, the decomposition
MAE as a function of R so callers can pick R such that emulation error
is negligible next to the circuit's own error.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .netlist import Netlist


def exact_mul_lut(width: int = 8) -> np.ndarray:
    n = 1 << width
    a = np.arange(n, dtype=np.int64)
    return (a[:, None] * a[None, :]).astype(np.int32)


def lut_from_netlist(nl: Netlist, width: int = 8) -> np.ndarray:
    """Exhaustive (2^w x 2^w) LUT for a 2w-input multiplier-like netlist.
    Row index = operand A (low input bits), column = operand B."""
    if nl.n_i != 2 * width:
        raise ValueError("netlist is not a two-operand circuit of this width")
    n = 1 << width
    a = np.arange(n, dtype=np.uint64)
    A, B = np.meshgrid(a, a, indexing="ij")
    vals = nl.eval_ints(A.reshape(-1), B.reshape(-1), widths=[width, width])
    return vals.reshape(n, n).astype(np.int64).astype(np.int32)


@dataclass(frozen=True)
class LowRankFactors:
    """L ≈ U^T V with U: (R, n) and V: (R, n), float32."""
    u: np.ndarray  # (R, n)
    v: np.ndarray  # (R, n)

    @property
    def rank(self) -> int:
        return int(self.u.shape[0])

    def reconstruct(self) -> np.ndarray:
        return (self.u.T @ self.v).astype(np.float64)

    def mae_vs(self, lut: np.ndarray) -> float:
        return float(np.abs(self.reconstruct() - lut.astype(np.float64)).mean())


def decompose_lut(lut: np.ndarray, rank: int) -> LowRankFactors:
    """Best rank-R factorization (Eckart-Young, SVD) of the LUT."""
    L = lut.astype(np.float64)
    w, s, vt = np.linalg.svd(L, full_matrices=False)
    r = int(min(rank, s.shape[0]))
    scale = np.sqrt(s[:r])
    u = (w[:, :r] * scale[None, :]).T.astype(np.float32)
    v = (vt[:r, :] * scale[:, None]).astype(np.float32)
    return LowRankFactors(u=u, v=v)


def rank_profile(lut: np.ndarray, max_rank: int = 16) -> list[dict]:
    """Decomposition MAE for R = 1..max_rank (one SVD, truncated views)."""
    L = lut.astype(np.float64)
    w, s, vt = np.linalg.svd(L, full_matrices=False)
    out = []
    recon = np.zeros_like(L)
    for r in range(1, min(max_rank, s.shape[0]) + 1):
        recon += np.outer(w[:, r - 1] * s[r - 1], vt[r - 1, :])
        err = np.abs(recon - L)
        out.append({
            "rank": r,
            "mae": float(err.mean()),
            "wce": float(err.max()),
            "sigma": float(s[r - 1]),
        })
    return out


def rank_for_tolerance(lut: np.ndarray, mae_tol: float, max_rank: int = 64) -> int:
    """Smallest R whose decomposition MAE <= mae_tol (capped at max_rank)."""
    prof = rank_profile(lut, max_rank=max_rank)
    for row in prof:
        if row["mae"] <= mae_tol:
            return int(row["rank"])
    return int(max_rank)
