"""LUT construction and low-rank decomposition of approximate multipliers.

The TFApprox-style emulation of an 8-bit approximate multiplier is a
256x256 int32 lookup table.  On TPU we additionally support a *low-rank
decomposition* of that table (DESIGN.md §4.2):

    L[a, b] ≈ sum_r U[r, a] * V[r, b]        (rank-R, via SVD)

which converts the emulated matmul into R per-element 256-entry table
lookups followed by R MXU matmuls.  An exact multiplier is exactly rank
1 (L = a bᵀ); truncation is rank 1; BAM is near-rank-2; evolved circuits
are numerically near-low-rank because their error surfaces are highly
structured.  ``rank_profile`` quantifies, per circuit, the decomposition
MAE as a function of R so callers can pick R such that emulation error
is negligible next to the circuit's own error.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .netlist import Netlist

#: Widest operand a full product LUT is ever materialized for.  A W-bit
#: LUT holds 2^(2W) int32 entries — 64 MiB at W=12, 16 TiB at W=16 —
#: so wider multipliers must execute through the composed datapath
#: (tiled 8x8 LUT partial products, DESIGN.md §2.6) instead.
MAX_LUT_WIDTH = 12


class LutWidthError(ValueError):
    """Raised when a full product LUT would exceed ``MAX_LUT_WIDTH``.

    Wide multipliers are *executable* — just not as a monolithic table.
    The actionable fix is the composed datapath: register a composed
    entry (``ApproxLibrary.add_composed(tile, width, reduce)``) or name
    one in a ``BackendSpec(multiplier=..., bit_width=W)``; its 8-bit
    tile LUT then drives the tiled 8x8 partial-product engine
    (``repro.kernels.composed_matmul``, DESIGN.md §2.6).
    """

    def __init__(self, name: str, width: int):
        self.circuit = name
        self.width = width
        super().__init__(
            f"cannot materialize a full {width}-bit product LUT for "
            f"{name!r} (2^{2 * width} entries; cap is "
            f"{MAX_LUT_WIDTH}-bit operands).  Wide multipliers run "
            "through the composed datapath instead: register a "
            "composed entry via ApproxLibrary.add_composed(tile, "
            f"width={width}, reduce=...) (tiled 8x8 LUT partial "
            "products reduced by a shift/add tree, DESIGN.md §2.6) "
            "and reference it from a BackendSpec, which packs only "
            "the 256x256 tile LUT.")


def exact_mul_lut(width: int = 8) -> np.ndarray:
    if width > MAX_LUT_WIDTH:
        raise LutWidthError(f"mul{width}u_exact", width)
    n = 1 << width
    a = np.arange(n, dtype=np.int64)
    return (a[:, None] * a[None, :]).astype(np.int32)


def lut_from_netlist(nl: Netlist, width: int = 8) -> np.ndarray:
    """Exhaustive (2^w x 2^w) LUT for a 2w-input multiplier-like netlist.
    Row index = operand A (low input bits), column = operand B."""
    if width > MAX_LUT_WIDTH:
        raise LutWidthError(nl.name or "<netlist>", width)
    if nl.n_i != 2 * width:
        raise ValueError("netlist is not a two-operand circuit of this width")
    n = 1 << width
    a = np.arange(n, dtype=np.uint64)
    A, B = np.meshgrid(a, a, indexing="ij")
    vals = nl.eval_ints(A.reshape(-1), B.reshape(-1), widths=[width, width])
    return vals.reshape(n, n).astype(np.int64).astype(np.int32)


@dataclass(frozen=True)
class LowRankFactors:
    """L ≈ U^T V with U: (R, n) and V: (R, n), float32."""
    u: np.ndarray  # (R, n)
    v: np.ndarray  # (R, n)

    @property
    def rank(self) -> int:
        return int(self.u.shape[0])

    def reconstruct(self) -> np.ndarray:
        return (self.u.T @ self.v).astype(np.float64)

    def mae_vs(self, lut: np.ndarray) -> float:
        return float(np.abs(self.reconstruct() - lut.astype(np.float64)).mean())


def decompose_lut(lut: np.ndarray, rank: int) -> LowRankFactors:
    """Best rank-R factorization (Eckart-Young, SVD) of the LUT."""
    L = lut.astype(np.float64)
    w, s, vt = np.linalg.svd(L, full_matrices=False)
    r = int(min(rank, s.shape[0]))
    scale = np.sqrt(s[:r])
    u = (w[:, :r] * scale[None, :]).T.astype(np.float32)
    v = (vt[:r, :] * scale[:, None]).astype(np.float32)
    return LowRankFactors(u=u, v=v)


def rank_profile(lut: np.ndarray, max_rank: int = 16) -> list[dict]:
    """Decomposition MAE for R = 1..max_rank (one SVD, truncated views)."""
    L = lut.astype(np.float64)
    w, s, vt = np.linalg.svd(L, full_matrices=False)
    out = []
    recon = np.zeros_like(L)
    for r in range(1, min(max_rank, s.shape[0]) + 1):
        recon += np.outer(w[:, r - 1] * s[r - 1], vt[r - 1, :])
        err = np.abs(recon - L)
        out.append({
            "rank": r,
            "mae": float(err.mean()),
            "wce": float(err.max()),
            "sigma": float(s[r - 1]),
        })
    return out


def rank_for_tolerance(lut: np.ndarray, mae_tol: float, max_rank: int = 64) -> int:
    """Smallest R whose decomposition MAE <= mae_tol (capped at max_rank)."""
    prof = rank_profile(lut, max_rank=max_rank)
    for row in prof:
        if row["mae"] <= mae_tol:
            return int(row["rank"])
    return int(max_rank)
