"""Error metrics for approximate circuits (paper Sec. II-A, eqs. 1-6).

All metrics compare an approximate circuit's outputs against the exact
circuit over the full input space (exhaustive, used for <= 20 input
bits) or over a deterministic uniform sample (wider circuits, as in the
library's 32..128-bit entries where exhaustive simulation is infeasible
and the paper points to SAT/BDD analysis — we use sampling and label it).
"""
from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import Optional

import numpy as np

from .netlist import (Netlist, exhaustive_inputs, random_input_planes,
                      unpack_outputs, unpack_outputs_object)

EXHAUSTIVE_LIMIT_BITS = 20
DEFAULT_SAMPLES = 1 << 18

METRIC_NAMES = ("er", "mae", "mse", "mre", "wce", "wcre")


@dataclass(frozen=True)
class ErrorReport:
    er: float      # error rate / error probability (eq. 1)
    mae: float     # mean absolute error (eq. 2)
    mse: float     # mean square error (eq. 3)
    mre: float     # mean relative error (eq. 4)
    wce: float     # worst-case error (eq. 5)
    wcre: float    # worst-case relative error (eq. 6)
    exhaustive: bool = True

    def as_dict(self) -> dict:
        return asdict(self)

    def get(self, name: str) -> float:
        return float(getattr(self, name))

    def as_vector(self) -> np.ndarray:
        """The six error statistics as a float64 vector in METRIC_NAMES
        order — the error-statistics block of the surrogate feature
        vector (DESIGN.md §2.11)."""
        return np.array([self.get(n) for n in METRIC_NAMES], dtype=np.float64)


def error_report_from_values(
    approx: np.ndarray, exact: np.ndarray, exhaustive: bool = True
) -> ErrorReport:
    if approx.dtype == object or exact.dtype == object:
        # exact big-int path (wide circuits): compute diffs exactly, then
        # convert to float for the statistics.
        diff_i = np.abs(approx - exact)
        diff = diff_i.astype(np.float64)
        denom = np.array([max(1, int(e)) for e in exact], dtype=np.float64)
    else:
        approx = np.asarray(approx, dtype=np.float64)
        exact = np.asarray(exact, dtype=np.float64)
        diff = np.abs(approx - exact)
        denom = np.maximum(1.0, exact)
    rel = diff / denom
    n = diff.size
    return ErrorReport(
        er=float((diff != 0).sum() / n),
        mae=float(diff.mean()),
        mse=float((diff * diff).mean()),
        mre=float(rel.mean()),
        wce=float(diff.max(initial=0.0)),
        wcre=float(rel.max(initial=0.0)),
        exhaustive=exhaustive,
    )


def _sample_inputs(n_i: int, num: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    hi = 1 << min(n_i, 63)
    return rng.integers(0, hi, size=num, dtype=np.uint64)


def evaluate_errors(
    approx: Netlist,
    exact: Netlist,
    samples: Optional[int] = None,
    seed: int = 0,
) -> ErrorReport:
    """Compare two netlists with identical interfaces."""
    if approx.n_i != exact.n_i or approx.n_o != exact.n_o:
        raise ValueError("interface mismatch")
    n_i = approx.n_i
    if n_i <= EXHAUSTIVE_LIMIT_BITS and samples is None:
        planes = exhaustive_inputs(n_i)
        num = 1 << n_i
        a_out = unpack_outputs(approx.eval_words(planes), approx.n_o, num)
        e_out = unpack_outputs(exact.eval_words(planes), exact.n_o, num)
        return error_report_from_values(a_out, e_out, exhaustive=True)
    num = samples or DEFAULT_SAMPLES
    if n_i <= 63:
        vecs = _sample_inputs(n_i, num, seed)
        a_out = approx.eval_ints(vecs, widths=[n_i])
        e_out = exact.eval_ints(vecs, widths=[n_i])
        return error_report_from_values(a_out, e_out, exhaustive=False)
    # wide circuits (up to 2x128-bit operands): sample random bit planes
    # and compare with exact big-int arithmetic.
    num = min(num, 1 << 14)  # big-int unpack is python-speed
    rng = np.random.default_rng(seed)
    planes = random_input_planes(n_i, num, rng)
    a_out = unpack_outputs_object(approx.eval_words(planes), approx.n_o, num)
    e_out = unpack_outputs_object(exact.eval_words(planes), exact.n_o, num)
    return error_report_from_values(a_out, e_out, exhaustive=False)


def evaluate_errors_lut(lut_approx: np.ndarray, lut_exact: np.ndarray) -> ErrorReport:
    """Error report for full LUTs (exhaustive by construction)."""
    return error_report_from_values(
        lut_approx.reshape(-1), lut_exact.reshape(-1), exhaustive=True
    )


def wce_within(report: ErrorReport, e_min: float, e_max: float) -> bool:
    """Target error-range check used by single-objective CGP (Sec. II-C)."""
    return e_min <= report.wce <= e_max
