"""CGP netlist representation and bit-parallel evaluation.

A candidate circuit is an integer netlist (the CGP *chromosome*,
Sec. II-B of the paper): ``N`` two-input nodes laid out in a single row
with full levels-back connectivity (equivalent to an ``n_c x n_r`` grid
with levels-back = n_c), ``n_i`` primary inputs and ``n_o`` primary
outputs.  Node ``j`` may read from any primary input or any node with a
smaller index (feed-forward constraint).

Evaluation is *bit-parallel*: each signal holds one bit per simulated
input vector, packed 64 vectors to a uint64 word.  Exhaustive simulation
of an 8x8-bit multiplier (65 536 vectors) therefore touches 1024 words
per signal and runs the whole ~450-gate netlist in well under a
millisecond — this is the same trick the TPU `bitsim` Pallas kernel uses
with 32-bit lanes (DESIGN.md §4.3).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import numpy as np

from . import gates

_ALL_ONES = np.uint64(0xFFFFFFFFFFFFFFFF)


@dataclass(frozen=True)
class Netlist:
    """Immutable CGP genome.

    funcs  : (N,)  int32 gate function codes (gates.IDENTITY..CONST1)
    in0/in1: (N,)  int32 signal indices; signal s < n_i is primary input s,
             otherwise node (s - n_i).  Must satisfy s < n_i + node_index.
    outputs: (n_o,) int32 signal indices feeding the primary outputs.
    """

    n_i: int
    n_o: int
    funcs: np.ndarray
    in0: np.ndarray
    in1: np.ndarray
    outputs: np.ndarray
    name: str = ""

    @property
    def n_nodes(self) -> int:
        return int(self.funcs.shape[0])

    def __post_init__(self):
        for arr_name in ("funcs", "in0", "in1", "outputs"):
            arr = getattr(self, arr_name)
            object.__setattr__(self, arr_name, np.asarray(arr, dtype=np.int32))

    def validate(self) -> None:
        n, n_i = self.n_nodes, self.n_i
        if self.in0.shape != (n,) or self.in1.shape != (n,):
            raise ValueError("input arrays must match node count")
        if np.any(self.funcs < 0) or np.any(self.funcs >= gates.N_FUNCS):
            raise ValueError("invalid function code")
        limit = n_i + np.arange(n, dtype=np.int64)
        if np.any(self.in0 < 0) or np.any(self.in0 >= limit):
            raise ValueError("in0 violates feed-forward constraint")
        if np.any(self.in1 < 0) or np.any(self.in1 >= limit):
            raise ValueError("in1 violates feed-forward constraint")
        if np.any(self.outputs < 0) or np.any(self.outputs >= n_i + n):
            raise ValueError("output index out of range")

    # ------------------------------------------------------------------
    # Structure
    # ------------------------------------------------------------------
    def active_mask(self) -> np.ndarray:
        """Boolean mask over nodes reachable from the primary outputs."""
        n, n_i = self.n_nodes, self.n_i
        active = np.zeros(n, dtype=bool)
        stack = [int(s) - n_i for s in self.outputs if int(s) >= n_i]
        while stack:
            j = stack.pop()
            if j < 0 or active[j]:
                continue
            active[j] = True
            arity = gates.GATE_ARITY[self.funcs[j]]
            if arity >= 1:
                s = int(self.in0[j])
                if s >= n_i:
                    stack.append(s - n_i)
            if arity >= 2:
                s = int(self.in1[j])
                if s >= n_i:
                    stack.append(s - n_i)
        return active

    def n_active(self) -> int:
        mask = self.active_mask()
        arity = gates.GATE_ARITY[self.funcs]
        # identity buffers and constants are free wires in the cost model,
        # but we still count them as "active nodes" for structure reports.
        return int(mask.sum())

    def gate_histogram(self) -> np.ndarray:
        """Active-node counts per gate function code, shape (N_FUNCS,).

        Only nodes reachable from the primary outputs are counted —
        padding/junk genes carry no information about the circuit's
        arithmetic structure.  This is the composition term of the
        surrogate feature vector (DESIGN.md §2.11).
        """
        mask = self.active_mask()
        hist = np.bincount(self.funcs[mask], minlength=gates.N_FUNCS)
        return hist.astype(np.int64)

    def logic_depth(self) -> int:
        """Longest gate-count path from any primary input (or constant
        source) to any primary output, counting only active non-identity,
        non-constant gates — a proxy for the critical-path delay that the
        cost model derives from gate delays.  0 for wire-only circuits.
        """
        n, n_i = self.n_nodes, self.n_i
        active = self.active_mask()
        depth = np.zeros(n_i + n, dtype=np.int64)
        for j in range(n):
            if not active[j]:
                continue
            f = int(self.funcs[j])
            arity = gates.GATE_ARITY[f]
            d = 0
            if arity >= 1:
                d = int(depth[int(self.in0[j])])
            if arity >= 2:
                d = max(d, int(depth[int(self.in1[j])]))
            counts = f not in (gates.IDENTITY, gates.CONST0, gates.CONST1)
            depth[n_i + j] = d + (1 if counts else 0)
        if self.outputs.size == 0:
            return 0
        return int(max(int(depth[int(s)]) for s in self.outputs))

    def compact(self) -> "Netlist":
        """Drop inactive nodes, remapping indices (for storage)."""
        mask = self.active_mask()
        n_i = self.n_i
        old_idx = np.nonzero(mask)[0]
        remap = {int(o) + n_i: i + n_i for i, o in enumerate(old_idx)}

        def m(sig: int) -> int:
            return remap.get(int(sig), int(sig)) if int(sig) >= n_i else int(sig)

        in0 = np.array([m(self.in0[j]) for j in old_idx], dtype=np.int32)
        in1 = np.array([m(self.in1[j]) for j in old_idx], dtype=np.int32)
        outs = np.array([m(s) for s in self.outputs], dtype=np.int32)
        return Netlist(
            n_i=self.n_i,
            n_o=self.n_o,
            funcs=self.funcs[old_idx].copy(),
            in0=in0,
            in1=in1,
            outputs=outs,
            name=self.name,
        )

    # ------------------------------------------------------------------
    # Evaluation
    # ------------------------------------------------------------------
    def eval_words(self, input_words: np.ndarray) -> np.ndarray:
        """Bit-parallel evaluation.

        input_words: (n_i, W) uint64 — bit ``k`` of word ``w`` of row ``i``
        is the value of primary input ``i`` for vector ``64*w + k``.
        Returns (n_o, W) uint64 output bit-planes.
        """
        if input_words.shape[0] != self.n_i:
            raise ValueError("input plane count mismatch")
        W = input_words.shape[1]
        n, n_i = self.n_nodes, self.n_i
        signals = np.empty((n_i + n, W), dtype=np.uint64)
        signals[:n_i] = input_words
        active = self.active_mask()
        zeros = np.zeros(W, dtype=np.uint64)
        for j in range(n):
            if not active[j]:
                continue
            f = int(self.funcs[j])
            a = signals[int(self.in0[j])] if gates.GATE_ARITY[f] >= 1 else zeros
            b = signals[int(self.in1[j])] if gates.GATE_ARITY[f] >= 2 else zeros
            signals[n_i + j] = gates.eval_gate_words(f, a, b)
        out = np.empty((self.n_o, W), dtype=np.uint64)
        for k, s in enumerate(self.outputs):
            out[k] = signals[int(s)]
        return out

    def eval_ints(self, *operands: np.ndarray, widths: Optional[list] = None) -> np.ndarray:
        """Evaluate on integer operands; returns unsigned integer outputs.

        ``operands`` are 1-D integer arrays; ``widths`` gives each operand's
        bit width (defaults to an even split of n_i).  Operand bits are
        little-endian: input 0 is bit 0 of operand 0.
        """
        if widths is None:
            if len(operands) == 0:
                raise ValueError("need operands")
            w = self.n_i // len(operands)
            widths = [w] * len(operands)
        if sum(widths) != self.n_i:
            raise ValueError("operand widths must sum to n_i")
        num = int(np.asarray(operands[0]).shape[0])
        planes = pack_operands(list(operands), widths)
        out_planes = self.eval_words(planes)
        return unpack_outputs(out_planes, self.n_o, num)

    def to_dict(self) -> dict:
        return {
            "n_i": self.n_i,
            "n_o": self.n_o,
            "funcs": self.funcs.tolist(),
            "in0": self.in0.tolist(),
            "in1": self.in1.tolist(),
            "outputs": self.outputs.tolist(),
            "name": self.name,
        }

    @staticmethod
    def from_dict(d: dict) -> "Netlist":
        return Netlist(
            n_i=int(d["n_i"]),
            n_o=int(d["n_o"]),
            funcs=np.asarray(d["funcs"], dtype=np.int32),
            in0=np.asarray(d["in0"], dtype=np.int32),
            in1=np.asarray(d["in1"], dtype=np.int32),
            outputs=np.asarray(d["outputs"], dtype=np.int32),
            name=d.get("name", ""),
        )


def stack_netlists(netlists: list, n_nodes: Optional[int] = None):
    """Stack same-interface netlists into flat population arrays.

    Pads every genome up to ``n_nodes`` (default: the population max)
    with inactive ``const0`` nodes — appended past every referenced
    index, so no output can change — and returns
    ``(funcs, in0, in1, outs)`` int32 arrays of shapes
    ``(P, n_nodes)``/``(P, n_o)``, the layout the population bitsim
    kernel consumes (DESIGN.md §2.9).
    """
    if not netlists:
        raise ValueError("need at least one netlist")
    n_i, n_o = netlists[0].n_i, netlists[0].n_o
    for nl in netlists:
        if nl.n_i != n_i or nl.n_o != n_o:
            raise ValueError("population interfaces must match")
    if n_nodes is None:
        n_nodes = max(nl.n_nodes for nl in netlists)
    if any(nl.n_nodes > n_nodes for nl in netlists):
        raise ValueError("n_nodes smaller than a population member")
    p = len(netlists)
    funcs = np.full((p, n_nodes), gates.CONST0, dtype=np.int32)
    in0 = np.zeros((p, n_nodes), dtype=np.int32)
    in1 = np.zeros((p, n_nodes), dtype=np.int32)
    outs = np.zeros((p, n_o), dtype=np.int32)
    for k, nl in enumerate(netlists):
        n = nl.n_nodes
        funcs[k, :n] = nl.funcs
        in0[k, :n] = nl.in0
        in1[k, :n] = nl.in1
        outs[k] = nl.outputs
    return funcs, in0, in1, outs


# ----------------------------------------------------------------------
# Bit packing helpers
# ----------------------------------------------------------------------
def pack_operands(operands: list, widths: list) -> np.ndarray:
    """Pack integer operand arrays into (sum(widths), W) uint64 bit planes."""
    num = int(np.asarray(operands[0]).shape[0])
    W = (num + 63) // 64
    n_i = sum(widths)
    planes = np.zeros((n_i, W), dtype=np.uint64)
    row = 0
    for op, width in zip(operands, widths):
        vals = np.asarray(op, dtype=np.uint64)
        for b in range(width):
            bits = (vals >> np.uint64(b)) & np.uint64(1)
            padded = np.zeros(W * 64, dtype=np.uint64)
            padded[:num] = bits
            words = padded.reshape(W, 64)
            shifts = np.arange(64, dtype=np.uint64)
            planes[row + b] = (words << shifts).sum(axis=1, dtype=np.uint64)
        row += width
    return planes


def unpack_outputs(planes: np.ndarray, n_o: int, num: int) -> np.ndarray:
    """Inverse of pack_operands for output planes -> (num,) uint64 ints."""
    W = planes.shape[1]
    vals = np.zeros(num, dtype=np.uint64)
    for b in range(n_o):
        words = planes[b]
        bits = ((words[:, None] >> np.arange(64, dtype=np.uint64)[None, :])
                & np.uint64(1)).reshape(-1)[:num]
        vals |= bits << np.uint64(b)
    return vals


def unpack_outputs_object(planes: np.ndarray, n_o: int, num: int) -> np.ndarray:
    """Like unpack_outputs but returns exact Python ints (object dtype),
    supporting arbitrary output widths (e.g. 129-bit adder outputs)."""
    vals = np.array([0] * num, dtype=object)
    for b in range(n_o):
        words = planes[b]
        bits = ((words[:, None] >> np.arange(64, dtype=np.uint64)[None, :])
                & np.uint64(1)).reshape(-1)[:num].astype(np.int64)
        vals += bits.astype(object) << b
    return vals


def random_input_planes(
    n_i: int, num: int, rng: np.random.Generator
) -> np.ndarray:
    """Uniform random bit-planes over the full 2^n_i input space — used
    for sampled evaluation of wide (>20 input bit) circuits."""
    W = (num + 63) // 64
    planes = rng.integers(0, 1 << 63, size=(n_i, W), dtype=np.uint64)
    planes |= rng.integers(0, 2, size=(n_i, W), dtype=np.uint64) << np.uint64(63)
    rem = num % 64
    if rem:
        mask = np.uint64((1 << rem) - 1)
        planes[:, -1] &= mask
    return planes


def exhaustive_inputs(n_i: int) -> np.ndarray:
    """All 2^n_i input vectors as (n_i, 2^n_i/64) uint64 bit planes.

    Vector v assigns bit i of v to primary input i — so for a circuit with
    two w-bit operands, operand A is the low w bits of v and operand B the
    high w bits, matching ``pack_operands`` with a meshgrid ordering.
    """
    if n_i > 24:
        raise ValueError("exhaustive evaluation capped at 24 input bits")
    num = 1 << n_i
    v = np.arange(num, dtype=np.uint64)
    ops = [v]
    return pack_operands(ops, [n_i])
