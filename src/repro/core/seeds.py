"""Exact seed circuits for CGP (Sec. III: "we seeded CGP with
conventional implementations of target arithmetic circuits").

Generators produce gate-level ``Netlist``s for:
  * ripple-carry adders (n-bit + n-bit -> (n+1)-bit)
  * unsigned array multipliers (n-bit x n-bit -> 2n-bit)

Both are built from AND/XOR/OR full-adder cells, the classic structures
the EvoApprox library evolves from.
"""
from __future__ import annotations

import numpy as np

from . import gates
from .netlist import Netlist


class _Builder:
    """Append-only netlist builder; returns signal indices."""

    def __init__(self, n_i: int):
        self.n_i = n_i
        self.funcs: list[int] = []
        self.in0: list[int] = []
        self.in1: list[int] = []

    def inp(self, i: int) -> int:
        assert 0 <= i < self.n_i
        return i

    def gate(self, func: int, a: int, b: int = 0) -> int:
        idx = self.n_i + len(self.funcs)
        assert a < idx and b < idx, "feed-forward violation"
        self.funcs.append(func)
        self.in0.append(a)
        self.in1.append(b)
        return idx

    def const0(self) -> int:
        return self.gate(gates.CONST0, 0, 0)

    def half_adder(self, a: int, b: int) -> tuple[int, int]:
        s = self.gate(gates.XOR, a, b)
        c = self.gate(gates.AND, a, b)
        return s, c

    def full_adder(self, a: int, b: int, cin: int) -> tuple[int, int]:
        s1 = self.gate(gates.XOR, a, b)
        s = self.gate(gates.XOR, s1, cin)
        c1 = self.gate(gates.AND, a, b)
        c2 = self.gate(gates.AND, s1, cin)
        c = self.gate(gates.OR, c1, c2)
        return s, c

    def finish(self, outputs: list[int], n_o: int, name: str) -> Netlist:
        nl = Netlist(
            n_i=self.n_i,
            n_o=n_o,
            funcs=np.asarray(self.funcs, dtype=np.int32),
            in0=np.asarray(self.in0, dtype=np.int32),
            in1=np.asarray(self.in1, dtype=np.int32),
            outputs=np.asarray(outputs, dtype=np.int32),
            name=name,
        )
        nl.validate()
        return nl


def ripple_carry_adder(width: int) -> Netlist:
    """Exact ripple-carry adder: inputs a[0..w-1], b[0..w-1] (little-endian),
    outputs s[0..w] (w+1 bits including carry-out)."""
    b = _Builder(2 * width)
    outs: list[int] = []
    s, c = b.half_adder(b.inp(0), b.inp(width))
    outs.append(s)
    for i in range(1, width):
        s, c = b.full_adder(b.inp(i), b.inp(width + i), c)
        outs.append(s)
    outs.append(c)
    return b.finish(outs, width + 1, f"add{width}_rca_exact")


def array_multiplier(width: int) -> Netlist:
    """Exact unsigned array multiplier (carry-save rows + ripple finish):
    inputs a[0..w-1], b[0..w-1], outputs p[0..2w-1]."""
    w = width
    b = _Builder(2 * w)
    # partial products pp[i][j] = a_j & b_i
    pp = [[b.gate(gates.AND, b.inp(j), b.inp(w + i)) for j in range(w)]
          for i in range(w)]
    outs: list[int] = [pp[0][0]]
    # running row: bits of the accumulated sum above the already-final bits
    row = pp[0][1:]  # w-1 bits, weight 1..w-1 relative to current row base
    for i in range(1, w):
        nxt: list[int] = []
        carry = None
        for j in range(w):
            acc = row[j - 0] if j < len(row) else None
            p = pp[i][j]
            if acc is None and carry is None:
                s, c = p, None
            elif acc is None:
                s, c = b.half_adder(p, carry)
            elif carry is None:
                s, c = b.half_adder(p, acc)
            else:
                s, c = b.full_adder(p, acc, carry)
            if j == 0:
                outs.append(s)
            else:
                nxt.append(s)
            carry = c
        if carry is not None:
            nxt.append(carry)
        row = nxt
    outs.extend(row)
    while len(outs) < 2 * w:
        outs.append(b.const0())
    return b.finish(outs[: 2 * w], 2 * w, f"mul{w}u_array_exact")


def exact_circuit(kind: str, width: int) -> Netlist:
    if kind == "adder":
        return ripple_carry_adder(width)
    if kind == "multiplier":
        return array_multiplier(width)
    raise ValueError(f"unknown circuit kind {kind!r}")
