"""Deterministic synthetic datasets (the container has no network access,
so CIFAR-10 and text corpora are procedurally generated — DESIGN.md §3).

* ``synthetic_cifar``: class-conditional structured images.  Each of the
  10 classes is a distinct mixture of oriented gratings + blob layout,
  plus per-sample noise — learnable by a small CNN but not trivially
  linearly separable, which is what a resilience analysis needs (a model
  whose accuracy responds smoothly to arithmetic error).
* ``token_stream``: a Zipf-distributed Markov token generator for LM
  training smoke runs (real perplexity dynamics, deterministic).
"""
from __future__ import annotations

import numpy as np


DATA_VERSION = 2  # bump to invalidate cached trained checkpoints


def synthetic_cifar(split: str, n: int, seed: int = 0,
                    image_size: int = 32, n_classes: int = 10):
    """Returns (images (n,S,S,3) f32 in [0,1], labels (n,) i32).

    Difficulty is tuned so a small trained CNN lands in the ~80-90%
    range (like CIFAR-10 ResNet-8): heavy per-sample texture jitter,
    low-contrast class signal, strong noise — this is what makes the
    resilience analysis informative (a saturated task hides arithmetic
    error; paper Sec. IV needs graded degradation)."""
    base = 0xC1FA9 if split == "train" else 0x7E57
    rng = np.random.default_rng(base + seed)
    s = image_size
    yy, xx = np.mgrid[0:s, 0:s].astype(np.float32) / s

    # fixed per-class texture parameters (shared across splits!)
    prng = np.random.default_rng(1234)
    freqs = prng.uniform(2.0, 6.0, size=(n_classes, 3))
    angles = prng.uniform(0, np.pi, size=(n_classes, 3))
    phases = prng.uniform(0, 2 * np.pi, size=(n_classes, 3))
    centers = prng.uniform(0.25, 0.75, size=(n_classes, 2))
    colors = prng.uniform(0.4, 1.0, size=(n_classes, 3))

    labels = rng.integers(0, n_classes, size=n).astype(np.int32)
    images = np.empty((n, s, s, 3), dtype=np.float32)
    for i in range(n):
        c = labels[i]
        img = np.zeros((s, s, 3), np.float32)
        jitter = rng.normal(0, 0.22, size=2)
        cx, cy = centers[c] + jitter
        blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / 0.04))
        for ch in range(3):
            a = angles[c, ch] + rng.normal(0, 0.35)
            f = freqs[c, ch] * (1.0 + rng.normal(0, 0.15))
            grating = np.sin(2 * np.pi * f
                             * (xx * np.cos(a) + yy * np.sin(a))
                             + phases[c, ch] + rng.normal(0, 0.8))
            img[:, :, ch] = 0.5 + 0.10 * grating * colors[c, ch] \
                + 0.16 * blob * colors[c, (ch + 1) % 3]
        img += rng.normal(0, 0.16, size=(s, s, 3))
        images[i] = np.clip(img, 0.0, 1.0)
    return images, labels


def token_stream(vocab: int, batch: int, seq_len: int, step: int,
                 seed: int = 0):
    """Deterministic Markov-ish Zipf token batches.
    Returns (tokens (B,S) i32, targets (B,S) i32 = next token)."""
    rng = np.random.default_rng((seed * 1_000_003 + step) & 0x7FFFFFFF)
    v = min(vocab, 32768)
    # zipf-ish marginal
    ranks = np.arange(1, v + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    flat = rng.choice(v, size=batch * (seq_len + 1), p=probs)
    # inject local structure: every 4th token repeats with offset
    flat = flat.reshape(batch, seq_len + 1)
    flat[:, 4::4] = (flat[:, 0:-4:4] + 17) % v
    tokens = flat[:, :-1].astype(np.int32)
    targets = flat[:, 1:].astype(np.int32)
    return tokens, targets


class CifarBatches:
    """Host-side batched iterator with deterministic shuffling."""

    def __init__(self, split: str, n: int, batch: int, seed: int = 0):
        self.images, self.labels = synthetic_cifar(split, n, seed)
        self.batch = batch
        self.n = n
        self._rng = np.random.default_rng(seed + 99)
        self._order = np.arange(n)

    def epoch(self):
        self._rng.shuffle(self._order)
        for i in range(0, self.n - self.batch + 1, self.batch):
            idx = self._order[i:i + self.batch]
            yield {"images": self.images[idx], "labels": self.labels[idx]}

    def eval_batches(self, max_batches: int | None = None):
        count = 0
        for i in range(0, self.n - self.batch + 1, self.batch):
            yield {"images": self.images[i:i + self.batch],
                   "labels": self.labels[i:i + self.batch]}
            count += 1
            if max_batches is not None and count >= max_batches:
                return
