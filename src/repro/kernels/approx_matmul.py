"""Pallas TPU kernel: bit-true LUT-gather approximate matmul.

TPU-native port of TFApprox's GPU texture-LUT emulation (DESIGN.md
§4.5): the full 256x256 int32 product LUT (256 KiB) is pinned in VMEM
for every grid step; operand tiles stream HBM -> VMEM per BlockSpec;
products are vector gathers on the VPU with exact int32 accumulation —
bit-identical to the gate-level netlist, which is what a resilience
analysis must guarantee.

The gather materializes (bm, kc, bn) product cubes, so the k-dimension
is processed in ``K_CHUNK`` slices to bound VMEM:
  VMEM ≈ lut(256K) + a(bm*bk*4) + w(bk*bn*4) + cube(bm*K_CHUNK*bn*4)
       ≈ 0.25 + 0.0625 + 0.0625 + 0.5 MiB  for 128/128/128 tiles.

This kernel intentionally does *not* use the MXU — it exists as the
paper-faithful baseline the low-rank kernel is hill-climbed against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BN, BK = 128, 128, 128
K_CHUNK = 8


def _kernel(a_ref, w_ref, lut_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]          # (BM, BK) int32 codes
    w = w_ref[...]          # (BK, BN) int32 codes
    lut = lut_ref[...]      # (65536,) int32

    def body(c, acc):
        a_c = jax.lax.dynamic_slice(a, (0, c * K_CHUNK), (a.shape[0], K_CHUNK))
        w_c = jax.lax.dynamic_slice(w, (c * K_CHUNK, 0), (K_CHUNK, w.shape[1]))
        idx = a_c[:, :, None] * 256 + w_c[None, :, :]      # (BM,KC,BN)
        prods = jnp.take(lut, idx, axis=0)                  # VPU gather
        return acc + jnp.sum(prods, axis=1, dtype=jnp.int32)

    nk = a.shape[1] // K_CHUNK
    acc = jax.lax.fori_loop(
        0, nk, body, jnp.zeros((a.shape[0], w.shape[1]), jnp.int32))
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def approx_matmul_lut_pallas(qa: jax.Array, qw: jax.Array, lut: jax.Array,
                             interpret: bool = False) -> jax.Array:
    """qa: (M,K) int32 in [0,255]; qw: (K,N) int32; lut: (256,256) int32.
    Returns (M,N) int32 = Σ_k LUT[qa, qw].  M,N,K padded to tiles; the
    K-padding contribution (pad rows hit LUT[0,0]) is subtracted exactly.
    """
    m, k = qa.shape
    k2, n = qw.shape
    assert k == k2
    pm, pn, pk = (-m) % BM, (-n) % BN, (-k) % BK
    qa_p = jnp.pad(qa, ((0, pm), (0, pk)))
    qw_p = jnp.pad(qw, ((0, pk), (0, pn)))
    flat = lut.reshape(-1)
    grid = (qa_p.shape[0] // BM, qw_p.shape[1] // BN, qa_p.shape[1] // BK)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, s: (i, s)),
            pl.BlockSpec((BK, BN), lambda i, j, s: (s, j)),
            pl.BlockSpec((65536,), lambda i, j, s: (0,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qa_p.shape[0], qw_p.shape[1]),
                                       jnp.int32),
        interpret=interpret,
    )(qa_p, qw_p, flat)
    out = out[:m, :n]
    if pk:
        out = out - jnp.int32(pk) * flat[0]  # remove pad-row LUT[0,0] terms
    return out
