"""Pallas TPU kernel: bit-parallel gate-netlist simulator.

Accelerates CGP fitness evaluation (DESIGN.md §4.3): each signal holds
one bit per simulated input vector, packed 32 to a uint32 lane.  The
netlist is encoded as flat int32 arrays (funcs/in0/in1/outputs); the
kernel walks the gates with a ``fori_loop`` + ``lax.switch`` writing a
(n_signals, W) scratch in VMEM, evaluating 32 x W input vectors per
grid step with pure bitwise VPU ops — no gather anywhere.

Exhaustive 8x8-multiplier evaluation = 65 536 vectors = 2048 uint32
words; with W-blocks of 512 lanes a ~500-gate netlist needs a
(~516, 512) uint32 scratch ≈ 1 MiB of VMEM.

``bitsim_pop_pallas`` is the population-vectorized variant behind the
device CGP engine (DESIGN.md §2.9): the netlist arrays gain a leading
population axis and the grid gains a population dimension, so every
offspring of an evolutionary generation simulates in ONE program —
each (candidate, W-block) grid step re-uses the same VMEM scratch and
reads its own netlist slice via the BlockSpec index map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

W_BLOCK = 512


def _make_kernel(n_nodes: int, n_i: int, n_o: int):
    def kernel(funcs_ref, in0_ref, in1_ref, outs_ref, planes_ref, o_ref,
               sig_ref):
        w = planes_ref.shape[1]
        sig_ref[0:n_i, :] = planes_ref[...]
        ones = jnp.full((1, w), 0xFFFFFFFF, dtype=jnp.uint32)
        zeros = jnp.zeros((1, w), dtype=jnp.uint32)

        def gate_body(j, _):
            f = funcs_ref[j]
            a = sig_ref[pl.ds(in0_ref[j], 1), :]
            b = sig_ref[pl.ds(in1_ref[j], 1), :]
            r = jax.lax.switch(f, [
                lambda a, b: a,            # identity
                lambda a, b: ~a,           # not
                lambda a, b: a & b,        # and
                lambda a, b: a | b,        # or
                lambda a, b: a ^ b,        # xor
                lambda a, b: ~(a & b),     # nand
                lambda a, b: ~(a | b),     # nor
                lambda a, b: ~(a ^ b),     # xnor
                lambda a, b: zeros,        # const0
                lambda a, b: ones,         # const1
            ], a, b)
            sig_ref[pl.ds(n_i + j, 1), :] = r
            return 0

        jax.lax.fori_loop(0, n_nodes, gate_body, 0)

        def out_body(o, _):
            o_ref[pl.ds(o, 1), :] = sig_ref[pl.ds(outs_ref[o], 1), :]
            return 0

        jax.lax.fori_loop(0, n_o, out_body, 0)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("n_nodes", "n_i", "n_o", "interpret"))
def bitsim_pallas(funcs: jax.Array, in0: jax.Array, in1: jax.Array,
                  outs: jax.Array, planes: jax.Array, *, n_nodes: int,
                  n_i: int, n_o: int, interpret: bool = False) -> jax.Array:
    """Evaluate a netlist on uint32 bit-planes.

    funcs/in0/in1: (n_nodes,) int32; outs: (n_o,) int32 signal indices;
    planes: (n_i, W) uint32.  Returns (n_o, W) uint32.
    """
    w = planes.shape[1]
    pw = (-w) % W_BLOCK
    planes_p = jnp.pad(planes, ((0, 0), (0, pw)))
    wp = planes_p.shape[1]
    grid = (wp // W_BLOCK,)
    out = pl.pallas_call(
        _make_kernel(n_nodes, n_i, n_o),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((n_o,), lambda i: (0,)),
            pl.BlockSpec((n_i, W_BLOCK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n_o, W_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_o, wp), jnp.uint32),
        scratch_shapes=[
            pltpu.VMEM((n_i + n_nodes, W_BLOCK), jnp.uint32),
        ],
        interpret=interpret,
    )(funcs, in0, in1, outs, planes_p)
    return out[:, :w]


def _make_pop_kernel(n_nodes: int, n_i: int, n_o: int):
    """Population variant of ``_make_kernel``: netlist refs carry a
    leading singleton population-block dim selected by the grid."""

    def kernel(funcs_ref, in0_ref, in1_ref, outs_ref, planes_ref, o_ref,
               sig_ref):
        w = planes_ref.shape[1]
        sig_ref[0:n_i, :] = planes_ref[...]
        ones = jnp.full((1, w), 0xFFFFFFFF, dtype=jnp.uint32)
        zeros = jnp.zeros((1, w), dtype=jnp.uint32)

        def gate_body(j, _):
            f = funcs_ref[0, j]
            a = sig_ref[pl.ds(in0_ref[0, j], 1), :]
            b = sig_ref[pl.ds(in1_ref[0, j], 1), :]
            r = jax.lax.switch(f, [
                lambda a, b: a,            # identity
                lambda a, b: ~a,           # not
                lambda a, b: a & b,        # and
                lambda a, b: a | b,        # or
                lambda a, b: a ^ b,        # xor
                lambda a, b: ~(a & b),     # nand
                lambda a, b: ~(a | b),     # nor
                lambda a, b: ~(a ^ b),     # xnor
                lambda a, b: zeros,        # const0
                lambda a, b: ones,         # const1
            ], a, b)
            sig_ref[pl.ds(n_i + j, 1), :] = r
            return 0

        jax.lax.fori_loop(0, n_nodes, gate_body, 0)

        def out_body(o, _):
            o_ref[0, pl.ds(o, 1), :] = sig_ref[pl.ds(outs_ref[0, o], 1), :]
            return 0

        jax.lax.fori_loop(0, n_o, out_body, 0)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("n_nodes", "n_i", "n_o", "interpret"))
def bitsim_pop_pallas(funcs: jax.Array, in0: jax.Array, in1: jax.Array,
                      outs: jax.Array, planes: jax.Array, *, n_nodes: int,
                      n_i: int, n_o: int,
                      interpret: bool = False) -> jax.Array:
    """Evaluate a POPULATION of netlists on shared uint32 bit-planes.

    funcs/in0/in1: (P, n_nodes) int32; outs: (P, n_o) int32;
    planes: (n_i, W) uint32 shared by every candidate.  Returns
    (P, n_o, W) uint32 — row p bit-identical to ``bitsim_pallas`` on
    candidate p's netlist slice.  Netlists of differing node counts are
    stacked by padding with inactive const0 nodes
    (``repro.core.netlist.stack_netlists``), which cannot change any
    output: padded nodes are appended past every referenced index.
    """
    p = funcs.shape[0]
    w = planes.shape[1]
    pw = (-w) % W_BLOCK
    planes_p = jnp.pad(planes, ((0, 0), (0, pw)))
    wp = planes_p.shape[1]
    grid = (p, wp // W_BLOCK)
    out = pl.pallas_call(
        _make_pop_kernel(n_nodes, n_i, n_o),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_nodes), lambda q, i: (q, 0)),
            pl.BlockSpec((1, n_nodes), lambda q, i: (q, 0)),
            pl.BlockSpec((1, n_nodes), lambda q, i: (q, 0)),
            pl.BlockSpec((1, n_o), lambda q, i: (q, 0)),
            pl.BlockSpec((n_i, W_BLOCK), lambda q, i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, n_o, W_BLOCK), lambda q, i: (q, 0, i)),
        out_shape=jax.ShapeDtypeStruct((p, n_o, wp), jnp.uint32),
        scratch_shapes=[
            pltpu.VMEM((n_i + n_nodes, W_BLOCK), jnp.uint32),
        ],
        interpret=interpret,
    )(funcs, in0, in1, outs, planes_p)
    return out[:, :, :w]
