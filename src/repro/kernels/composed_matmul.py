"""Pallas TPU kernels: composed wide (12/16-bit) LUT matmul.

Width-generic execution (DESIGN.md §2.6): a W-bit approximate multiply
decomposes into base-256 digits ``a = a0 + 256*a1`` and four 8x8 digit
products gathered from the 256x256 TILE LUT pinned in VMEM, reduced by
a shift/add tree whose nodes are library adder semantics
(exact / LOA / truncated — see ``repro.approx.registry.composed_reduce``
and the gate-level ground truth ``repro.core.families.composed_multiplier``).
Products (< 2^32, held in uint32) split into two 16-bit limbs that
accumulate exactly in int32 over K (``K <= MAX_COMPOSED_K``); callers
recombine ``lo + 65536*hi`` in f32 — exact while limb sums stay under
2^24 (K <= 256 at full range), a deterministic f32 rounding floor
beyond that (identical across ref/pallas/banked paths; see DESIGN.md
§2.6).

VMEM budget per program (128/128/128 tiles, K_CHUNK=8):
  lut(256K) + a(bm*bk*4) + w(bk*bn*4) + 4 digit cubes(bm*KC*bn*4)
  ≈ 0.25 + 0.0625 + 0.0625 + 2.0 MiB ≈ 2.4 MiB
— the 4x cube term is the price of the four digit products; the banked
variant pins exactly ONE tile-LUT slice per program (grid over the
multiplier axis), so VMEM stays flat in ``n_mult`` exactly like the
8-bit bank kernel (``lut_bank.py``).

The per-lane ``mask`` doubles as selector and truncation: wide lanes
AND the reduced product with the netlist's 2W output bits (``0xFFFFFF``
at W=12 — an over-estimating tile can push the tree past 2^24, and the
gate-level circuit keeps only 2W bits), while ``mask == 0`` marks a
narrow (8-bit) lane whose result is the plain ``pp00`` tile sum —
bit-identical to the historical single-LUT kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.approx.registry import composed_reduce

from .approx_matmul import BK, BM, BN, K_CHUNK


def _digit_cubes(a, w, lut, c):
    """Four (mb, K_CHUNK, bn) digit-product cubes for k-chunk ``c``."""
    a_c = jax.lax.dynamic_slice(a, (0, c * K_CHUNK),
                                (a.shape[0], K_CHUNK))
    w_c = jax.lax.dynamic_slice(w, (c * K_CHUNK, 0),
                                (K_CHUNK, w.shape[1]))
    a0, a1 = a_c & 255, a_c >> 8
    w0, w1 = w_c & 255, w_c >> 8

    def pp(x, y):
        idx = x[:, :, None] * 256 + y[None, :, :]
        return jnp.take(lut, idx, axis=0)

    return pp(a0, w0), pp(a0, w1), pp(a1, w0), pp(a1, w1)


def _make_kernel(reduce: tuple, banked: bool):
    def kernel(a_ref, w_ref, lut_ref, mask_ref, lo_ref, hi_ref):
        k_step = pl.program_id(3 if banked else 2)

        @pl.when(k_step == 0)
        def _init():
            lo_ref[...] = jnp.zeros_like(lo_ref)
            hi_ref[...] = jnp.zeros_like(hi_ref)

        a = a_ref[...].reshape(-1, a_ref.shape[-1])  # (BM,BK) W-bit codes
        w = w_ref[...]                               # (BK,BN)
        lut = lut_ref[...].reshape(-1)               # (65536,) tile LUT
        mask = mask_ref[0]                           # 2W-bit product mask
        wide = mask != 0

        def body(c, accs):
            acc_lo, acc_hi = accs
            pp00, pp01, pp10, pp11 = _digit_cubes(a, w, lut, c)
            p = composed_reduce(pp00.astype(jnp.uint32),
                                pp01.astype(jnp.uint32),
                                pp10.astype(jnp.uint32),
                                pp11.astype(jnp.uint32), reduce) & mask
            lo = jnp.where(wide, (p & jnp.uint32(0xFFFF)
                                  ).astype(jnp.int32), pp00)
            hi = jnp.where(wide, (p >> 16).astype(jnp.int32), 0)
            return (acc_lo + jnp.sum(lo, axis=1, dtype=jnp.int32),
                    acc_hi + jnp.sum(hi, axis=1, dtype=jnp.int32))

        nk = a.shape[1] // K_CHUNK
        zeros = jnp.zeros((a.shape[0], w.shape[1]), jnp.int32)
        acc_lo, acc_hi = jax.lax.fori_loop(0, nk, body, (zeros, zeros))
        if banked:
            lo_ref[...] += acc_lo[None]
            hi_ref[...] += acc_hi[None]
        else:
            lo_ref[...] += acc_lo
            hi_ref[...] += acc_hi

    return kernel


def _pad_limbs(flat, mask, reduce, pk):
    """Per-bank limb contribution of ONE K-pad row (codes 0): the
    (masked) composed product at (0,0) for wide lanes, the raw tile
    LUT[0,0] for narrow lanes.  flat: (..., 65536); returns (lo, hi)
    broadcast against the output."""
    t00 = flat[..., 0]
    mask = jnp.asarray(mask, jnp.uint32)
    p00 = composed_reduce(*(4 * (t00.astype(jnp.uint32),)),
                          reduce) & mask
    wide = mask != 0
    lo = jnp.where(wide, (p00 & jnp.uint32(0xFFFF)).astype(jnp.int32),
                   t00)
    hi = jnp.where(wide, (p00 >> 16).astype(jnp.int32), 0)
    return jnp.int32(pk) * lo, jnp.int32(pk) * hi


@functools.partial(jax.jit, static_argnames=("reduce", "interpret"))
def composed_matmul_pallas(qa: jax.Array, qw: jax.Array, lut: jax.Array,
                           mask: jax.Array, reduce: tuple = ("exact", 0),
                           interpret: bool = False) -> jax.Array:
    """qa: (M,K) int32 W-bit codes; qw: (K,N) int32; lut: (256,256)
    int32 tile LUT; mask: scalar uint32 2W-bit product mask (0 selects
    the narrow 8-bit path).  Returns (M,N) f32 ``lo + 65536*hi`` with
    exact int32 limb accumulation."""
    m, k = qa.shape
    k2, n = qw.shape
    assert k == k2
    pm, pn, pk = (-m) % BM, (-n) % BN, (-k) % BK
    qa_p = jnp.pad(qa, ((0, pm), (0, pk)))
    qw_p = jnp.pad(qw, ((0, pk), (0, pn)))
    flat = lut.reshape(-1)
    mask_arr = jnp.asarray(mask, jnp.uint32).reshape(1)
    grid = (qa_p.shape[0] // BM, qw_p.shape[1] // BN, qa_p.shape[1] // BK)
    shape = jax.ShapeDtypeStruct((qa_p.shape[0], qw_p.shape[1]),
                                 jnp.int32)
    lo, hi = pl.pallas_call(
        _make_kernel(reduce, banked=False),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, s: (i, s)),
            pl.BlockSpec((BK, BN), lambda i, j, s: (s, j)),
            pl.BlockSpec((65536,), lambda i, j, s: (0,)),
            pl.BlockSpec((1,), lambda i, j, s: (0,)),
        ],
        out_specs=[pl.BlockSpec((BM, BN), lambda i, j, s: (i, j)),
                   pl.BlockSpec((BM, BN), lambda i, j, s: (i, j))],
        out_shape=[shape, shape],
        interpret=interpret,
    )(qa_p, qw_p, flat, mask_arr)
    lo, hi = lo[:m, :n], hi[:m, :n]
    if pk:
        dlo, dhi = _pad_limbs(flat, mask_arr[0], reduce, pk)
        lo, hi = lo - dlo, hi - dhi
    return lo.astype(jnp.float32) + 65536.0 * hi.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("reduce", "interpret"))
def composed_matmul_bank_pallas(qa: jax.Array, qw: jax.Array,
                                luts: jax.Array, mask: jax.Array,
                                reduce: tuple = ("exact", 0),
                                interpret: bool = False) -> jax.Array:
    """Banked composed matmul: one launch for a whole mixed-width bank.

    qa: (M,K) shared or (n,M,K) banked codes; qw: (K,N); luts:
    (n,256,256) tile LUTs; mask: (n,) uint32 per-lane 2W-bit product
    mask (0 = narrow lane).  Returns (n,M,N) f32, bit-identical per
    lane to ``composed_matmul_pallas`` — grid (n, M/BM, N/BN, K/BK)
    with one VMEM-pinned tile-LUT slice per program.
    """
    banked_a = qa.ndim == 3
    n_mult = luts.shape[0]
    m, k = qa.shape[-2:]
    k2, n = qw.shape
    assert k == k2
    assert not banked_a or qa.shape[0] == n_mult
    pm, pn, pk = (-m) % BM, (-n) % BN, (-k) % BK
    a_pad = ((0, 0), (0, pm), (0, pk)) if banked_a else ((0, pm), (0, pk))
    qa_p = jnp.pad(qa, a_pad)
    qw_p = jnp.pad(qw, ((0, pk), (0, pn)))
    flat = luts.reshape(n_mult, -1)
    mask = jnp.asarray(mask, jnp.uint32).reshape(n_mult)
    grid = (n_mult, qa_p.shape[-2] // BM, qw_p.shape[1] // BN,
            qa_p.shape[-1] // BK)
    if banked_a:
        a_spec = pl.BlockSpec((1, BM, BK), lambda b, i, j, s: (b, i, s))
    else:
        a_spec = pl.BlockSpec((BM, BK), lambda b, i, j, s: (i, s))
    shape = jax.ShapeDtypeStruct(
        (n_mult, qa_p.shape[-2], qw_p.shape[1]), jnp.int32)
    lo, hi = pl.pallas_call(
        _make_kernel(reduce, banked=True),
        grid=grid,
        in_specs=[
            a_spec,
            pl.BlockSpec((BK, BN), lambda b, i, j, s: (s, j)),
            pl.BlockSpec((1, 65536), lambda b, i, j, s: (b, 0)),
            pl.BlockSpec((1,), lambda b, i, j, s: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, BM, BN), lambda b, i, j, s: (b, i, j)),
            pl.BlockSpec((1, BM, BN), lambda b, i, j, s: (b, i, j))],
        out_shape=[shape, shape],
        interpret=interpret,
    )(qa_p, qw_p, flat, mask)
    lo, hi = lo[:, :m, :n], hi[:, :m, :n]
    if pk:
        dlo, dhi = _pad_limbs(flat, mask, reduce, pk)
        lo = lo - dlo[:, None, None]
        hi = hi - dhi[:, None, None]
    return lo.astype(jnp.float32) + 65536.0 * hi.astype(jnp.float32)


def composed_matmul_ref(qa: jax.Array, qw: jax.Array, lut: jax.Array,
                        mask, reduce: tuple = ("exact", 0)) -> jax.Array:
    """Pure-jnp oracle for the composed kernels (one unblocked pass)."""
    from repro.approx.registry import _composed_gather_block
    flat = jnp.asarray(lut, jnp.int32).reshape(-1)
    return _composed_gather_block(qa, qw, flat, mask, reduce)
