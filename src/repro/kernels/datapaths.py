"""Pallas-backed datapath registrations (DESIGN.md §2.1, §4).

Imported lazily by ``repro.approx.registry.get_datapath`` the first time
a ``*_pallas`` datapath is requested, so the approx core never depends
on the kernel layer at import time.  The packs are shared with the
reference datapaths — only ``forward_q`` routes through the Pallas
kernels (interpret-mode on CPU, Mosaic on TPU; see ``ops.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.approx.registry import (MAX_COMPOSED_K, Datapath, pack_lowrank,
                                   pack_lut, register_datapath)

from .ops import approx_matmul_lut, composed_matmul_lut, lowrank_matmul


@register_datapath("lut_pallas")
class LutPallasDatapath(Datapath):
    """Bit-true LUT emulation through the Pallas texture-gather kernels
    — width-generic (DESIGN.md §2.6): 8-bit specs run the historical
    single-LUT kernel; composed wide specs run the tiled 8x8
    partial-product kernel on the tile LUT.

    Bankable: under the batched engine's vmap, the ops' custom batching
    rules reroute the whole LUT bank to the banked kernels
    (``lut_bank.py`` / ``composed_matmul.py``, grid over the
    multiplier axis) instead of batching the single-LUT kernel
    lane by lane."""

    # kernel does its own blocking, so block_m is not a spec field
    spec_fields = ("multiplier", "bit_width", "reduce_adder")
    bankable = True

    def pack(self, spec, library) -> dict:
        return pack_lut(spec, library)

    def forward_q(self, qa, qw, consts):
        if consts.get("composed"):
            if qa.shape[-1] > MAX_COMPOSED_K:
                raise ValueError(
                    f"K={qa.shape[-1]} exceeds int32-safe composed "
                    f"limb accumulation bound {MAX_COMPOSED_K}")
            return composed_matmul_lut(qa, qw, jnp.asarray(consts["lut"]),
                                       consts["mask"],
                                       reduce=consts["reduce"])
        return approx_matmul_lut(qa, qw, jnp.asarray(consts["lut"]))


@register_datapath("lowrank_pallas")
class LowRankPallasDatapath(Datapath):
    """Rank-R factored emulation through the Pallas MXU kernel."""

    spec_fields = ("multiplier", "rank")

    def pack(self, spec, library) -> dict:
        return pack_lowrank(spec, library)

    def forward_q(self, qa, qw, consts):
        return lowrank_matmul(qa, qw, jnp.asarray(consts["u"]),
                              jnp.asarray(consts["v"]))
