"""Pallas-backed datapath registrations (DESIGN.md §2.1, §4).

Imported lazily by ``repro.approx.registry.get_datapath`` the first time
a ``*_pallas`` datapath is requested, so the approx core never depends
on the kernel layer at import time.  The packs are shared with the
reference datapaths — only ``forward_q`` routes through the Pallas
kernels (interpret-mode on CPU, Mosaic on TPU; see ``ops.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.approx.quant import calibrate, scalar_params
from repro.approx.registry import (MAX_COMPOSED_K, Datapath, encode_reduce,
                                   pack_lowrank, pack_lut,
                                   register_datapath)

from .ops import (approx_matmul_lut, composed_matmul_lut,
                  fused_composed_matmul_lut, fused_matmul_lut,
                  lowrank_matmul)


@register_datapath("lut_pallas")
class LutPallasDatapath(Datapath):
    """Bit-true LUT emulation through the Pallas texture-gather kernels
    — width-generic (DESIGN.md §2.6): 8-bit specs run the historical
    single-LUT kernel; composed wide specs run the tiled 8x8
    partial-product kernel on the tile LUT.

    Bankable: under the batched engine's vmap, the ops' custom batching
    rules reroute the whole LUT bank to the banked kernels
    (``lut_bank.py`` / ``composed_matmul.py``, grid over the
    multiplier axis) instead of batching the single-LUT kernel
    lane by lane."""

    # kernel does its own blocking, so block_m is not a spec field
    spec_fields = ("multiplier", "bit_width", "reduce_adder")
    bankable = True

    def pack(self, spec, library) -> dict:
        return pack_lut(spec, library)

    def forward_q(self, qa, qw, consts):
        if consts.get("composed"):
            if qa.shape[-1] > MAX_COMPOSED_K:
                raise ValueError(
                    f"K={qa.shape[-1]} exceeds int32-safe composed "
                    f"limb accumulation bound {MAX_COMPOSED_K}")
            return composed_matmul_lut(qa, qw, jnp.asarray(consts["lut"]),
                                       consts["mask"],
                                       reduce=consts["reduce"])
        return approx_matmul_lut(qa, qw, jnp.asarray(consts["lut"]))


@register_datapath("lut_fused")
class LutFusedDatapath(Datapath):
    """Single-program LUT emulation (DESIGN.md §2.10): the backend hands
    this datapath the FLOAT operands and the whole
    quantize → LUT-gather → int32-accumulate → correct/dequant chain
    runs as ONE ``pallas_call`` (plus the thin f32 epilogue), instead of
    the two-step quantize-then-``forward_q`` pipeline.  Bit-identical to
    ``lut``/``lut_pallas`` at every width by the fused kernels'
    differential contract (``tests/test_fused_matmul.py``).

    Bankable: the fused ops' custom batching rules collapse a vmapped
    LUT axis into the banked fused kernels, and — beyond the static-tree
    banked engines — the composed fused kernel takes the reduction tree
    as RUNTIME data (``reduce_code``), so one compiled program can mix
    reduction families across bank lanes (``LutBank.mixed_reduce``)."""

    spec_fields = ("multiplier", "bit_width", "reduce_adder")
    bankable = True
    fused = True

    def pack(self, spec, library) -> dict:
        return pack_lut(spec, library)

    def forward_fused(self, x2d, w, consts):
        bits = consts.get("bits", 8)
        qp_a = calibrate(x2d, bits=bits)
        qp_w = calibrate(w, bits=bits)
        sp = scalar_params(qp_a, qp_w)
        if consts.get("composed"):
            if x2d.shape[-1] > MAX_COMPOSED_K:
                raise ValueError(
                    f"K={x2d.shape[-1]} exceeds int32-safe composed "
                    f"limb accumulation bound {MAX_COMPOSED_K}")
            rcode = consts.get("reduce_code")
            if rcode is None:
                rcode = jnp.asarray(encode_reduce(consts["reduce"]),
                                    jnp.int32)
            return fused_composed_matmul_lut(
                x2d, w, jnp.asarray(consts["lut"]),
                jnp.asarray(consts["mask"], jnp.uint32), rcode, *sp)
        return fused_matmul_lut(x2d, w, jnp.asarray(consts["lut"]), *sp)

    def forward_q(self, qa, qw, consts):
        raise TypeError(
            "lut_fused is a fused datapath: the backend routes float "
            "operands through forward_fused, never quantized codes")


@register_datapath("lowrank_pallas")
class LowRankPallasDatapath(Datapath):
    """Rank-R factored emulation through the Pallas MXU kernel."""

    spec_fields = ("multiplier", "rank")

    def pack(self, spec, library) -> dict:
        return pack_lowrank(spec, library)

    def forward_q(self, qa, qw, consts):
        return lowrank_matmul(qa, qw, jnp.asarray(consts["u"]),
                              jnp.asarray(consts["v"]))
