"""Pallas TPU kernels: FUSED quantize→LUT-gather→accumulate datapath.

One ``pallas_call`` runs the integer half of the approximate-matmul
datapath end to end (DESIGN.md §2.10): float operand tiles stream in,
each tile is affine quantized in-register with pre-calibrated scalar
params (SMEM), partial products are gathered from the VMEM-resident
256x256 product LUT and accumulated exactly in int32 scratch alongside
the zero-point row/col sums, and the final K-step applies the integer
K-pad correction and emits the accumulator plus the row/col sums.
Versus the two-step path (quantize → ``approx_matmul_lut`` →
correct/dequant in XLA) this removes every intermediate int32
code-tensor materialization and HBM round-trip — only the (M,N)
accumulator and the tiny (M,)/(N,) sums leave the program.

The f32 zero-point correction + dequant deliberately stays in the
jitted CALLER, written with the same expression shapes as
``repro.approx.backend._quantized_matmul``: XLA contracts adjacent
same-shape ``mul``+``add`` pairs into single-rounding FMAs, and whether
it does so depends on the surrounding computation — an in-kernel f32
epilogue rounds differently from the two-step pipeline at wide widths
(zero-point products past 2^24), while the caller-side epilogue
compiles to the same broadcast-protected HLO structure as the
reference and stays bit-identical.  Everything UP TO the correction is
integer arithmetic and therefore exact in any compilation context.

Row blocking is shape-adaptive: ``bm = min(128, ceil8(M))`` instead of
the fixed 128 of the code-domain kernels, so decode-like shapes (M of
1..16 rows) stop paying for 128 gathered rows — the dominant term of
the fused-vs-two-step speedup on small-M shapes (BENCH_kernels.json).

Banked variants add the ``LutBank`` lane axis as the outer grid
dimension and DOUBLE-BUFFER the LUT through VMEM scratch: the bank's
LUT stack stays in HBM (``memory_space=ANY``) and each bank's first
tile starts an async DMA of the NEXT bank's 256 KiB slice into the
alternate slot of a ``(2, 65536)`` scratch buffer while the current
slice is consumed — the copy overlaps the whole bank's tile sweep.
Operand tiles ride the pallas pipeline's own automatic double
buffering via their BlockSpecs.  VMEM budget per program stays inside
the repo's ~2.4 MiB envelope (DESIGN.md §2.6):

  8-bit banked:    2*lut(512K) + x/w tiles(128K) + cube(512K)
                   + acc/row/col(~68K) + out(64K)            ≈ 1.3 MiB
  composed banked: 2*lut(512K) + tiles(128K) + 4 cubes(1.0M)
                   + 2 acc limbs(132K) + outs(128K)          ≈ 1.9 MiB

(the composed kernels drop K_CHUNK 8→4 to fit the 4 digit cubes next
to the second LUT slot; chunking is int-associative so it cannot
change results).

The composed variants take the reduction tree as RUNTIME data — an
``encode_reduce`` ``(kind, k)`` int pair in SMEM, applied via
``composed_reduce_dyn`` — so one compiled program serves every adder
family and mixed-reduce banks collapse to a single trace (the
per-width/per-reduce program splits the trace audit in
``launch/compile_cache.py`` measures).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.approx.registry import (MAX_COMPOSED_K, MAX_LUT_K,
                                   composed_reduce_dyn)

from .approx_matmul import BK, BM, BN, K_CHUNK

#: K-chunk of the composed fused kernels: 4 digit cubes per chunk must
#: coexist with the second LUT scratch slot (module docstring budget).
CK_CHUNK = 4


def _row_block(m: int) -> int:
    """Shape-adaptive row block: full 128 rows for large M, the 8-row
    f32 tile floor for decode-like shapes (no 128-row gather padding)."""
    return max(8, min(BM, ((m + 7) // 8) * 8))


def _quant_tile(v, scale, zp, qmax):
    """In-kernel ``repro.approx.quant.quantize`` on one f32 tile —
    identical op/dtype order (round, +int32 zp in f32, clip, cast)."""
    q = jnp.round(v / scale) + zp
    return jnp.clip(q, 0, qmax).astype(jnp.int32)


def _k_masked(qa, qw, k_step, k, pk):
    """Zero the codes of K-padding columns (static no-op when pk == 0)
    so pad products hit LUT[0, 0] — subtracted exactly in the integer
    epilogue — and contribute nothing to the zero-point row/col sums."""
    if not pk:
        return qa, qw
    base = k_step * BK
    ia = base + jax.lax.broadcasted_iota(jnp.int32, (1, BK), 1)
    iw = base + jax.lax.broadcasted_iota(jnp.int32, (BK, 1), 0)
    return jnp.where(ia < k, qa, 0), jnp.where(iw < k, qw, 0)


def _dequant(s, row, col, za, zw, sa, sw, k: int):
    """Caller-side f32 correction + dequant: the exact expression of
    ``backend._quantized_matmul``'s non-exact branch.  s: (M,N) f32;
    row: (M,) i32; col: (N,) i32.

    Each correction product passes through ``jnp.trunc`` before the
    subtract chain.  In interpret mode the pallas program is INLINE
    HLO, and XLA's CPU backend fuses these ops into the emulation
    graph where LLVM contracts adjacent mul+sub pairs into
    single-rounding FMAs — one f32 ULP off the reference pipeline
    (which rounds each product separately) once zero-point products
    pass 2^24.  ``optimization_barrier`` does NOT reliably block the
    contraction (the emitter sees through its bitcast residue inside a
    fusion), but ``trunc`` does: it interposes a non-foldable
    intrinsic between the mul and the sub, and is an exact identity
    here because every product is mathematically an integer and the
    f32 rounding of an integer is always integer-valued (f32 spacing
    is >= 1 wherever values exceed 2^24)."""
    rowf = row.astype(jnp.float32)
    colf = col.astype(jnp.float32)
    zaf, zwf = za.astype(jnp.float32), zw.astype(jnp.float32)
    t_row = jnp.trunc(zwf * rowf[:, None])
    t_col = jnp.trunc(zaf * colf[None, :])
    t_k = jnp.trunc(k * zaf * zwf)
    acc = s - t_row - t_col + t_k
    return acc * (sa * sw)


def _lut_slot(lut_hbm, buf_ref, sem_ref, b, first_tile, n_mult):
    """Double-buffered LUT access for the banked kernels: at bank ``b``'s
    first tile, prefetch bank ``b+1``'s slice into the alternate slot
    (overlapping b's whole tile sweep) and wait on b's own copy (started
    by bank b-1's prefetch; bank 0 starts its own)."""
    slot = jax.lax.rem(b, 2)

    @pl.when(first_tile & (b == 0))
    def _seed():
        pltpu.make_async_copy(lut_hbm.at[0], buf_ref.at[0],
                              sem_ref.at[0]).start()

    @pl.when(first_tile & (b + 1 < n_mult))
    def _prefetch():
        nxt = jax.lax.rem(b + 1, 2)
        pltpu.make_async_copy(lut_hbm.at[b + 1], buf_ref.at[nxt],
                              sem_ref.at[nxt]).start()

    @pl.when(first_tile)
    def _wait():
        pltpu.make_async_copy(lut_hbm.at[b], buf_ref.at[slot],
                              sem_ref.at[slot]).wait()

    return buf_ref[slot]


# ----------------------------------------------------------------------
# 8-bit fused kernels
# ----------------------------------------------------------------------
def _fused_kernel(x_ref, w_ref, lut_ref, fp_ref, ip_ref,
                  o_ref, row_o, col_o, acc_ref, row_ref, col_ref,
                  *, k, pk, nsteps, bm):
    j, k_step = pl.program_id(1), pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        row_ref[...] = jnp.zeros_like(row_ref)
        col_ref[...] = jnp.zeros_like(col_ref)

    sa, sw, qmax = fp_ref[0], fp_ref[1], fp_ref[2]
    za, zw = ip_ref[0], ip_ref[1]
    qa = _quant_tile(x_ref[...], sa, za, qmax)       # (bm, BK)
    qw = _quant_tile(w_ref[...], sw, zw, qmax)       # (BK, BN)
    qa, qw = _k_masked(qa, qw, k_step, k, pk)
    row_ref[...] += jnp.sum(qa, axis=1, dtype=jnp.int32)[:, None]
    col_ref[...] += jnp.sum(qw, axis=0, dtype=jnp.int32)[None, :]
    lut = lut_ref[...]

    def body(c, acc):
        a_c = jax.lax.dynamic_slice(qa, (0, c * K_CHUNK), (bm, K_CHUNK))
        w_c = jax.lax.dynamic_slice(qw, (c * K_CHUNK, 0),
                                    (K_CHUNK, qw.shape[1]))
        idx = a_c[:, :, None] * 256 + w_c[None, :, :]    # (bm,KC,BN)
        prods = jnp.take(lut, idx, axis=0)                # VPU gather
        return acc + jnp.sum(prods, axis=1, dtype=jnp.int32)

    acc = jax.lax.fori_loop(0, BK // K_CHUNK, body,
                            jnp.zeros((bm, qw.shape[1]), jnp.int32))
    acc_ref[...] += acc

    @pl.when(k_step == nsteps - 1)
    def _fin():
        a = acc_ref[...]
        if pk:
            a = a - jnp.int32(pk) * lut[0]
        o_ref[...] = a

    @pl.when((k_step == nsteps - 1) & (j == 0))
    def _row():
        row_o[...] = row_ref[...]

    @pl.when((k_step == nsteps - 1) & (pl.program_id(0) == 0))
    def _col():
        col_o[...] = col_ref[...]


def _fused_bank_kernel(x_ref, w_ref, lut_hbm, fp_ref, ip_ref,
                       o_ref, row_o, col_o, acc_ref, row_ref, col_ref,
                       buf_ref, sem_ref,
                       *, k, pk, nsteps, bm, n_mult, banked_a):
    b = pl.program_id(0)
    i, j = pl.program_id(1), pl.program_id(2)
    k_step = pl.program_id(3)
    first_tile = (i == 0) & (j == 0) & (k_step == 0)
    lut = _lut_slot(lut_hbm, buf_ref, sem_ref, b, first_tile, n_mult)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        row_ref[...] = jnp.zeros_like(row_ref)
        col_ref[...] = jnp.zeros_like(col_ref)

    sa, sw, qmax = fp_ref[b, 0], fp_ref[b, 1], fp_ref[b, 2]
    za, zw = ip_ref[b, 0], ip_ref[b, 1]
    x = x_ref[...].reshape(-1, x_ref.shape[-1]) if banked_a else x_ref[...]
    qa = _quant_tile(x, sa, za, qmax)                # (bm, BK)
    qw = _quant_tile(w_ref[...], sw, zw, qmax)       # (BK, BN)
    qa, qw = _k_masked(qa, qw, k_step, k, pk)
    row_ref[...] += jnp.sum(qa, axis=1, dtype=jnp.int32)[:, None]
    col_ref[...] += jnp.sum(qw, axis=0, dtype=jnp.int32)[None, :]

    def body(c, acc):
        a_c = jax.lax.dynamic_slice(qa, (0, c * K_CHUNK), (bm, K_CHUNK))
        w_c = jax.lax.dynamic_slice(qw, (c * K_CHUNK, 0),
                                    (K_CHUNK, qw.shape[1]))
        idx = a_c[:, :, None] * 256 + w_c[None, :, :]
        return acc + jnp.sum(jnp.take(lut, idx, axis=0), axis=1,
                             dtype=jnp.int32)

    acc = jax.lax.fori_loop(0, BK // K_CHUNK, body,
                            jnp.zeros((bm, qw.shape[1]), jnp.int32))
    acc_ref[...] += acc

    @pl.when(k_step == nsteps - 1)
    def _fin():
        a = acc_ref[...]
        if pk:
            a = a - jnp.int32(pk) * lut[0]
        o_ref[...] = a[None]

    @pl.when((k_step == nsteps - 1) & (j == 0))
    def _row():
        row_o[...] = row_ref[...][None]

    @pl.when((k_step == nsteps - 1) & (i == 0))
    def _col():
        col_o[...] = col_ref[...][None]


# ----------------------------------------------------------------------
# Composed wide (12/16-bit) fused kernels — runtime reduce (SMEM rcode)
# ----------------------------------------------------------------------
def _digit_body(qa, qw, lut, mask, kind, kd, bm, bn):
    wide = mask != 0

    def body(c, accs):
        acc_lo, acc_hi = accs
        a_c = jax.lax.dynamic_slice(qa, (0, c * CK_CHUNK), (bm, CK_CHUNK))
        w_c = jax.lax.dynamic_slice(qw, (c * CK_CHUNK, 0), (CK_CHUNK, bn))
        a0, a1 = a_c & 255, a_c >> 8
        w0, w1 = w_c & 255, w_c >> 8

        def pp(x, y):
            idx = x[:, :, None] * 256 + y[None, :, :]
            return jnp.take(lut, idx, axis=0)

        pp00 = pp(a0, w0)
        p = composed_reduce_dyn(pp00.astype(jnp.uint32),
                                pp(a0, w1).astype(jnp.uint32),
                                pp(a1, w0).astype(jnp.uint32),
                                pp(a1, w1).astype(jnp.uint32),
                                kind, kd) & mask
        lo = jnp.where(wide, (p & jnp.uint32(0xFFFF)).astype(jnp.int32),
                       pp00)
        hi = jnp.where(wide, (p >> 16).astype(jnp.int32), 0)
        return (acc_lo + jnp.sum(lo, axis=1, dtype=jnp.int32),
                acc_hi + jnp.sum(hi, axis=1, dtype=jnp.int32))

    zeros = jnp.zeros((bm, bn), jnp.int32)
    return jax.lax.fori_loop(0, BK // CK_CHUNK, body, (zeros, zeros))


def _pad_limbs_dyn(t00, mask, kind, kd, pk):
    """Dynamic-reduce sibling of ``composed_matmul._pad_limbs``: the
    limb contribution of ``pk`` K-pad rows (codes 0) per out element."""
    p00 = composed_reduce_dyn(*(4 * (t00.astype(jnp.uint32),)),
                              kind, kd) & mask
    wide = mask != 0
    lo = jnp.where(wide, (p00 & jnp.uint32(0xFFFF)).astype(jnp.int32),
                   t00)
    hi = jnp.where(wide, (p00 >> 16).astype(jnp.int32), 0)
    return jnp.int32(pk) * lo, jnp.int32(pk) * hi


def _fused_composed_kernel(x_ref, w_ref, lut_ref, mask_ref, rc_ref,
                           fp_ref, ip_ref,
                           lo_o, hi_o, row_o, col_o,
                           lo_ref, hi_ref, row_ref, col_ref,
                           *, k, pk, nsteps, bm):
    j, k_step = pl.program_id(1), pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)
        row_ref[...] = jnp.zeros_like(row_ref)
        col_ref[...] = jnp.zeros_like(col_ref)

    sa, sw, qmax = fp_ref[0], fp_ref[1], fp_ref[2]
    za, zw = ip_ref[0], ip_ref[1]
    mask = mask_ref[0]
    kind, kd = rc_ref[0], rc_ref[1]
    qa = _quant_tile(x_ref[...], sa, za, qmax)
    qw = _quant_tile(w_ref[...], sw, zw, qmax)
    qa, qw = _k_masked(qa, qw, k_step, k, pk)
    row_ref[...] += jnp.sum(qa, axis=1, dtype=jnp.int32)[:, None]
    col_ref[...] += jnp.sum(qw, axis=0, dtype=jnp.int32)[None, :]
    lut = lut_ref[...]
    lo, hi = _digit_body(qa, qw, lut, mask, kind, kd, bm, qw.shape[1])
    lo_ref[...] += lo
    hi_ref[...] += hi

    @pl.when(k_step == nsteps - 1)
    def _fin():
        lo_a, hi_a = lo_ref[...], hi_ref[...]
        if pk:
            dlo, dhi = _pad_limbs_dyn(lut[0], mask, kind, kd, pk)
            lo_a, hi_a = lo_a - dlo, hi_a - dhi
        lo_o[...] = lo_a
        hi_o[...] = hi_a

    @pl.when((k_step == nsteps - 1) & (j == 0))
    def _row():
        row_o[...] = row_ref[...]

    @pl.when((k_step == nsteps - 1) & (pl.program_id(0) == 0))
    def _col():
        col_o[...] = col_ref[...]


def _fused_composed_bank_kernel(x_ref, w_ref, lut_hbm, mask_ref, rc_ref,
                                fp_ref, ip_ref,
                                lo_o, hi_o, row_o, col_o,
                                lo_ref, hi_ref, row_ref, col_ref,
                                buf_ref, sem_ref,
                                *, k, pk, nsteps, bm, n_mult, banked_a):
    b = pl.program_id(0)
    i, j = pl.program_id(1), pl.program_id(2)
    k_step = pl.program_id(3)
    first_tile = (i == 0) & (j == 0) & (k_step == 0)
    lut = _lut_slot(lut_hbm, buf_ref, sem_ref, b, first_tile, n_mult)

    @pl.when(k_step == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)
        row_ref[...] = jnp.zeros_like(row_ref)
        col_ref[...] = jnp.zeros_like(col_ref)

    sa, sw, qmax = fp_ref[b, 0], fp_ref[b, 1], fp_ref[b, 2]
    za, zw = ip_ref[b, 0], ip_ref[b, 1]
    mask = mask_ref[b]
    kind, kd = rc_ref[b, 0], rc_ref[b, 1]
    x = x_ref[...].reshape(-1, x_ref.shape[-1]) if banked_a else x_ref[...]
    qa = _quant_tile(x, sa, za, qmax)
    qw = _quant_tile(w_ref[...], sw, zw, qmax)
    qa, qw = _k_masked(qa, qw, k_step, k, pk)
    row_ref[...] += jnp.sum(qa, axis=1, dtype=jnp.int32)[:, None]
    col_ref[...] += jnp.sum(qw, axis=0, dtype=jnp.int32)[None, :]
    lo, hi = _digit_body(qa, qw, lut, mask, kind, kd, bm, qw.shape[1])
    lo_ref[...] += lo
    hi_ref[...] += hi

    @pl.when(k_step == nsteps - 1)
    def _fin():
        lo_a, hi_a = lo_ref[...], hi_ref[...]
        if pk:
            dlo, dhi = _pad_limbs_dyn(lut[0], mask, kind, kd, pk)
            lo_a, hi_a = lo_a - dlo, hi_a - dhi
        lo_o[...] = lo_a[None]
        hi_o[...] = hi_a[None]

    @pl.when((k_step == nsteps - 1) & (j == 0))
    def _row():
        row_o[...] = row_ref[...][None]

    @pl.when((k_step == nsteps - 1) & (i == 0))
    def _col():
        col_o[...] = col_ref[...][None]


# ----------------------------------------------------------------------
# Callers
# ----------------------------------------------------------------------
def _pack_scalars(sa, sw, qmax, za, zw, stacked: bool):
    axis = -1 if stacked else 0
    fp = jnp.stack([jnp.asarray(sa, jnp.float32),
                    jnp.asarray(sw, jnp.float32),
                    jnp.asarray(qmax, jnp.float32)], axis=axis)
    ip = jnp.stack([jnp.asarray(za, jnp.int32),
                    jnp.asarray(zw, jnp.int32)], axis=axis)
    return fp, ip


def _check_k(k: int, bound: int, what: str) -> None:
    if k > bound:
        raise ValueError(
            f"K={k} exceeds int32-safe {what} accumulation bound {bound}")


def _pad_operands(x, w, bm, banked_a):
    m, k = x.shape[-2:]
    n = w.shape[1]
    pm, pn, pk = (-m) % bm, (-n) % BN, (-k) % BK
    x_pad = ((0, 0), (0, pm), (0, pk)) if banked_a else ((0, pm), (0, pk))
    return jnp.pad(x, x_pad), jnp.pad(w, ((0, pk), (0, pn))), pk


def _bank_dequant(s, row, col, za, zw, sa, sw, k: int):
    """``_dequant`` over the bank axis, written out with explicit lane
    broadcasting — per-lane scalar op order identical to the unbanked
    path, with the same ``trunc`` anti-FMA guard on each product."""
    rowf = row.astype(jnp.float32)                      # (n, M)
    colf = col.astype(jnp.float32)                      # (n, N)
    zaf = jnp.asarray(za, jnp.int32).astype(jnp.float32)
    zwf = jnp.asarray(zw, jnp.int32).astype(jnp.float32)
    saf = jnp.asarray(sa, jnp.float32)
    swf = jnp.asarray(sw, jnp.float32)
    t_row = jnp.trunc(zwf[:, None, None] * rowf[:, :, None])
    t_col = jnp.trunc(zaf[:, None, None] * colf[:, None, :])
    t_k = jnp.trunc(k * zaf * zwf)
    acc = s - t_row - t_col + t_k[:, None, None]
    return acc * (saf * swf)[:, None, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_matmul_pallas(x, w, lut, sa, za, sw, zw, qmax,
                        interpret: bool = False) -> jax.Array:
    """Fused 8-bit datapath: x (M,K) f32, w (K,N) f32, lut (256,256)
    i32, scalars from ``quant.scalar_params``.  Returns (M,N) f32 —
    bit-identical to quantize → ``approx_matmul_lut`` → correct/dequant.
    """
    m, k = x.shape
    _, n = w.shape
    _check_k(k, MAX_LUT_K, "LUT")
    bm = _row_block(m)
    x_p, w_p, pk = _pad_operands(x, w, bm, banked_a=False)
    fp, ip = _pack_scalars(sa, sw, qmax, za, zw, stacked=False)
    nsteps = x_p.shape[1] // BK
    grid = (x_p.shape[0] // bm, w_p.shape[1] // BN, nsteps)
    mp, np_ = x_p.shape[0], w_p.shape[1]
    acc, row, col = pl.pallas_call(
        functools.partial(_fused_kernel, k=k, pk=pk, nsteps=nsteps, bm=bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, BK), lambda i, j, s: (i, s)),
            pl.BlockSpec((BK, BN), lambda i, j, s: (s, j)),
            pl.BlockSpec((65536,), lambda i, j, s: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[pl.BlockSpec((bm, BN), lambda i, j, s: (i, j)),
                   pl.BlockSpec((bm, 1), lambda i, j, s: (i, 0)),
                   pl.BlockSpec((1, BN), lambda i, j, s: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((mp, np_), jnp.int32),
                   jax.ShapeDtypeStruct((mp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((1, np_), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bm, BN), jnp.int32),
                        pltpu.VMEM((bm, 1), jnp.int32),
                        pltpu.VMEM((1, BN), jnp.int32)],
        interpret=interpret,
    )(x_p, w_p, lut.reshape(-1), fp, ip)
    s = acc[:m, :n].astype(jnp.float32)
    return _dequant(s, row[:m, 0], col[0, :n], za, zw, sa, sw, k)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_matmul_bank_pallas(x, w, luts, sa, za, sw, zw, qmax,
                             interpret: bool = False) -> jax.Array:
    """Banked fused 8-bit datapath: x (M,K) shared or (n,M,K) banked
    f32; luts (n,256,256); scalars (n,) per lane.  Returns (n,M,N) f32,
    bit-identical per lane to ``fused_matmul_pallas`` — LUT slices are
    DMA double-buffered from HBM (module docstring)."""
    banked_a = x.ndim == 3
    n_mult = luts.shape[0]
    m, k = x.shape[-2:]
    _, n = w.shape
    _check_k(k, MAX_LUT_K, "LUT")
    bm = _row_block(m)
    x_p, w_p, pk = _pad_operands(x, w, bm, banked_a)
    fp, ip = _pack_scalars(sa, sw, qmax, za, zw, stacked=True)
    nsteps = x_p.shape[-1] // BK
    grid = (n_mult, x_p.shape[-2] // bm, w_p.shape[1] // BN, nsteps)
    if banked_a:
        x_spec = pl.BlockSpec((1, bm, BK), lambda b, i, j, s: (b, i, s))
    else:
        x_spec = pl.BlockSpec((bm, BK), lambda b, i, j, s: (i, s))
    mp, np_ = x_p.shape[-2], w_p.shape[1]
    acc, row, col = pl.pallas_call(
        functools.partial(_fused_bank_kernel, k=k, pk=pk, nsteps=nsteps,
                          bm=bm, n_mult=n_mult, banked_a=banked_a),
        grid=grid,
        in_specs=[
            x_spec,
            pl.BlockSpec((BK, BN), lambda b, i, j, s: (s, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, BN), lambda b, i, j, s: (b, i, j)),
            pl.BlockSpec((1, bm, 1), lambda b, i, j, s: (b, i, 0)),
            pl.BlockSpec((1, 1, BN), lambda b, i, j, s: (b, 0, j))],
        out_shape=[jax.ShapeDtypeStruct((n_mult, mp, np_), jnp.int32),
                   jax.ShapeDtypeStruct((n_mult, mp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n_mult, 1, np_), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bm, BN), jnp.int32),
                        pltpu.VMEM((bm, 1), jnp.int32),
                        pltpu.VMEM((1, BN), jnp.int32),
                        pltpu.VMEM((2, 65536), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(x_p, w_p, luts.reshape(n_mult, -1), fp, ip)
    s = acc[:, :m, :n].astype(jnp.float32)
    return _bank_dequant(s, row[:, :m, 0], col[:, 0, :n],
                         za, zw, sa, sw, k)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_composed_matmul_pallas(x, w, lut, mask, rcode, sa, za, sw, zw,
                                 qmax, interpret: bool = False
                                 ) -> jax.Array:
    """Fused composed wide (12/16-bit) datapath on floats: digit
    products through the 256x256 tile LUT, runtime ``rcode`` reduce
    tree (``encode_reduce``), int32 limb accumulation, f32 correction.
    mask: scalar uint32 (0 = narrow lane); rcode: (2,) int32."""
    m, k = x.shape
    _, n = w.shape
    _check_k(k, MAX_COMPOSED_K, "composed limb")
    bm = _row_block(m)
    x_p, w_p, pk = _pad_operands(x, w, bm, banked_a=False)
    fp, ip = _pack_scalars(sa, sw, qmax, za, zw, stacked=False)
    nsteps = x_p.shape[1] // BK
    grid = (x_p.shape[0] // bm, w_p.shape[1] // BN, nsteps)
    mp, np_ = x_p.shape[0], w_p.shape[1]
    shape = jax.ShapeDtypeStruct((mp, np_), jnp.int32)
    lo, hi, row, col = pl.pallas_call(
        functools.partial(_fused_composed_kernel, k=k, pk=pk,
                          nsteps=nsteps, bm=bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, BK), lambda i, j, s: (i, s)),
            pl.BlockSpec((BK, BN), lambda i, j, s: (s, j)),
            pl.BlockSpec((65536,), lambda i, j, s: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[pl.BlockSpec((bm, BN), lambda i, j, s: (i, j)),
                   pl.BlockSpec((bm, BN), lambda i, j, s: (i, j)),
                   pl.BlockSpec((bm, 1), lambda i, j, s: (i, 0)),
                   pl.BlockSpec((1, BN), lambda i, j, s: (0, j))],
        out_shape=[shape, shape,
                   jax.ShapeDtypeStruct((mp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((1, np_), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bm, BN), jnp.int32),
                        pltpu.VMEM((bm, BN), jnp.int32),
                        pltpu.VMEM((bm, 1), jnp.int32),
                        pltpu.VMEM((1, BN), jnp.int32)],
        interpret=interpret,
    )(x_p, w_p, lut.reshape(-1),
      jnp.asarray(mask, jnp.uint32).reshape(1),
      jnp.asarray(rcode, jnp.int32).reshape(2), fp, ip)
    s = (lo[:m, :n].astype(jnp.float32)
         + 65536.0 * hi[:m, :n].astype(jnp.float32))
    return _dequant(s, row[:m, 0], col[0, :n], za, zw, sa, sw, k)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_composed_matmul_bank_pallas(x, w, luts, masks, rcodes, sa, za,
                                      sw, zw, qmax,
                                      interpret: bool = False
                                      ) -> jax.Array:
    """Banked fused composed datapath: per-lane masks (n,) uint32 and
    reduce codes (n,2) int32 ride SMEM next to the per-lane quant
    scalars, so ONE program evaluates a mixed-width, mixed-reduce bank
    (n,M,N) — LUT slices DMA double-buffered from HBM."""
    banked_a = x.ndim == 3
    n_mult = luts.shape[0]
    m, k = x.shape[-2:]
    _, n = w.shape
    _check_k(k, MAX_COMPOSED_K, "composed limb")
    bm = _row_block(m)
    x_p, w_p, pk = _pad_operands(x, w, bm, banked_a)
    fp, ip = _pack_scalars(sa, sw, qmax, za, zw, stacked=True)
    nsteps = x_p.shape[-1] // BK
    grid = (n_mult, x_p.shape[-2] // bm, w_p.shape[1] // BN, nsteps)
    if banked_a:
        x_spec = pl.BlockSpec((1, bm, BK), lambda b, i, j, s: (b, i, s))
    else:
        x_spec = pl.BlockSpec((bm, BK), lambda b, i, j, s: (i, s))
    mp, np_ = x_p.shape[-2], w_p.shape[1]
    shape = jax.ShapeDtypeStruct((n_mult, mp, np_), jnp.int32)
    lo, hi, row, col = pl.pallas_call(
        functools.partial(_fused_composed_bank_kernel, k=k, pk=pk,
                          nsteps=nsteps, bm=bm, n_mult=n_mult,
                          banked_a=banked_a),
        grid=grid,
        in_specs=[
            x_spec,
            pl.BlockSpec((BK, BN), lambda b, i, j, s: (s, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, BN), lambda b, i, j, s: (b, i, j)),
            pl.BlockSpec((1, bm, BN), lambda b, i, j, s: (b, i, j)),
            pl.BlockSpec((1, bm, 1), lambda b, i, j, s: (b, i, 0)),
            pl.BlockSpec((1, 1, BN), lambda b, i, j, s: (b, 0, j))],
        out_shape=[shape, shape,
                   jax.ShapeDtypeStruct((n_mult, mp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n_mult, 1, np_), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bm, BN), jnp.int32),
                        pltpu.VMEM((bm, BN), jnp.int32),
                        pltpu.VMEM((bm, 1), jnp.int32),
                        pltpu.VMEM((1, BN), jnp.int32),
                        pltpu.VMEM((2, 65536), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(x_p, w_p, luts.reshape(n_mult, -1),
      jnp.asarray(masks, jnp.uint32).reshape(n_mult),
      jnp.asarray(rcodes, jnp.int32).reshape(n_mult, 2), fp, ip)
    s = (lo[:, :m, :n].astype(jnp.float32)
         + 65536.0 * hi[:, :m, :n].astype(jnp.float32))
    return _bank_dequant(s, row[:, :m, 0], col[:, 0, :n],
                         za, zw, sa, sw, k)
