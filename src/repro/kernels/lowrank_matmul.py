"""Pallas TPU kernel: rank-R factored approximate matmul (MXU path).

The TPU-native adaptation of LUT emulation (DESIGN.md §4.2):

    LUT[a,b] ≈ Σ_r U[r,a] · V[r,b]
    Σ_k LUT[qa[m,k], qw[k,n]] ≈ Σ_r  U_r(qa) @ V_r(qw)

Per grid step the kernel performs two tiny 256-entry table gathers
(one per operand tile) and R MXU matmuls with f32 accumulation.
Arithmetic intensity is R/(R_bytes) ≈ that of an f32 matmul — i.e. this
turns the VPU-gather-bound emulation into an MXU-compute-bound one.

VMEM per step ≈ a(64K) + w(64K) + tables(2*R*1K) + ua/vw(2*R*64K)
             ≈ 1.2 MiB at R=4, 128-tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BN, BK = 128, 128, 128


def _kernel(a_ref, w_ref, u_ref, v_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]            # (BM,BK) int32 codes
    w = w_ref[...]            # (BK,BN) int32 codes
    u = u_ref[...]            # (R,256) f32
    v = v_ref[...]            # (R,256) f32
    ua = jnp.take(u, a, axis=1)       # (R,BM,BK) f32
    vw = jnp.take(v, w, axis=1)       # (R,BK,BN) f32
    acc = jax.lax.dot_general(
        ua, vw, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                  # (R,BM,BN)
    o_ref[...] += jnp.sum(acc, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lowrank_matmul_pallas(qa: jax.Array, qw: jax.Array, u: jax.Array,
                          v: jax.Array, interpret: bool = False) -> jax.Array:
    """qa: (M,K) int32 codes; qw: (K,N); u,v: (R,256) f32.
    Returns (M,N) f32 ≈ Σ_k LUT[qa,qw].  K-padding contributes
    pad * Σ_r U[r,0]V[r,0] per element and is subtracted exactly."""
    m, k = qa.shape
    k2, n = qw.shape
    assert k == k2
    pm, pn, pk = (-m) % BM, (-n) % BN, (-k) % BK
    qa_p = jnp.pad(qa, ((0, pm), (0, pk)))
    qw_p = jnp.pad(qw, ((0, pk), (0, pn)))
    r = u.shape[0]
    grid = (qa_p.shape[0] // BM, qw_p.shape[1] // BN, qa_p.shape[1] // BK)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, s: (i, s)),
            pl.BlockSpec((BK, BN), lambda i, j, s: (s, j)),
            pl.BlockSpec((r, 256), lambda i, j, s: (0, 0)),
            pl.BlockSpec((r, 256), lambda i, j, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qa_p.shape[0], qw_p.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(qa_p, qw_p, u, v)
    out = out[:m, :n]
    if pk:
        corner = jnp.sum(u[:, 0] * v[:, 0])
        out = out - jnp.float32(pk) * corner
    return out
