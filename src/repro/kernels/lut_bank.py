"""Pallas TPU kernel: banked bit-true LUT-gather approximate matmul.

The batched-resilience primitive (DESIGN.md §2.4, §4.5): evaluate the
SAME operand matmul under ``n_mult`` different approximate multipliers
in one kernel launch.  The bank of product LUTs is stacked as
``(n_mult, 256, 256)`` int32 and the grid gets a leading *multiplier*
dimension — each program pins exactly ONE 256 KiB LUT slice in VMEM
(never the whole bank), so VMEM stays flat in ``n_mult``:

  VMEM ≈ lut_slice(256K) + a(bm*bk*4) + w(bk*bn*4)
       + cube(bm*K_CHUNK*bn*4)
       ≈ 0.25 + 0.0625 + 0.0625 + 0.5 MiB   for 128/128/128 tiles,
  identical to the single-LUT kernel's budget (DESIGN.md §4.5).

Activations may be *banked* too: after the first approximated layer of
a swept network the per-multiplier activations diverge, so ``qa`` is
accepted as either ``(M, K)`` (shared codes, first layer / weight-only
divergence) or ``(n_mult, M, K)``; the index map simply reuses the bank
grid coordinate for banked operands and ignores it for shared ones.

The per-bank result is bit-identical to running the single-LUT kernel
(`approx_matmul.py`) once per multiplier — the equivalence contract the
batched resilience engine relies on (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .approx_matmul import BK, BM, BN, K_CHUNK


def _kernel(a_ref, w_ref, lut_ref, o_ref):
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].reshape(-1, a_ref.shape[-1])   # (BM, BK) int32 codes
    w = w_ref[...]                                # (BK, BN) int32 codes
    lut = lut_ref[...].reshape(-1)                # (65536,) one bank slice

    def body(c, acc):
        a_c = jax.lax.dynamic_slice(a, (0, c * K_CHUNK),
                                    (a.shape[0], K_CHUNK))
        w_c = jax.lax.dynamic_slice(w, (c * K_CHUNK, 0),
                                    (K_CHUNK, w.shape[1]))
        idx = a_c[:, :, None] * 256 + w_c[None, :, :]       # (BM,KC,BN)
        prods = jnp.take(lut, idx, axis=0)                   # VPU gather
        return acc + jnp.sum(prods, axis=1, dtype=jnp.int32)

    nk = a.shape[1] // K_CHUNK
    acc = jax.lax.fori_loop(
        0, nk, body, jnp.zeros((a.shape[0], w.shape[1]), jnp.int32))
    o_ref[...] += acc[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def approx_matmul_lut_bank_pallas(qa: jax.Array, qw: jax.Array,
                                  luts: jax.Array,
                                  interpret: bool = False) -> jax.Array:
    """qa: (M,K) or (n,M,K) int32 in [0,255]; qw: (K,N) int32;
    luts: (n,256,256) int32.  Returns (n,M,N) int32 where
    ``out[b] = Σ_k luts[b][qa_b, qw]`` (``qa_b = qa`` when shared).

    Grid is (n, M/BM, N/BN, K/BK) with one VMEM-pinned LUT slice per
    program; the K-padding contribution (pad rows hit LUT[b,0,0]) is
    subtracted exactly per bank.
    """
    banked_a = qa.ndim == 3
    n_mult = luts.shape[0]
    m, k = qa.shape[-2:]
    k2, n = qw.shape
    assert k == k2
    assert not banked_a or qa.shape[0] == n_mult
    pm, pn, pk = (-m) % BM, (-n) % BN, (-k) % BK
    a_pad = ((0, 0), (0, pm), (0, pk)) if banked_a else ((0, pm), (0, pk))
    qa_p = jnp.pad(qa, a_pad)
    qw_p = jnp.pad(qw, ((0, pk), (0, pn)))
    flat = luts.reshape(n_mult, -1)
    grid = (n_mult, qa_p.shape[-2] // BM, qw_p.shape[1] // BN,
            qa_p.shape[-1] // BK)
    if banked_a:
        a_spec = pl.BlockSpec((1, BM, BK), lambda b, i, j, s: (b, i, s))
    else:
        a_spec = pl.BlockSpec((BM, BK), lambda b, i, j, s: (i, s))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            a_spec,
            pl.BlockSpec((BK, BN), lambda b, i, j, s: (s, j)),
            pl.BlockSpec((1, 65536), lambda b, i, j, s: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, BM, BN), lambda b, i, j, s: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (n_mult, qa_p.shape[-2], qw_p.shape[1]), jnp.int32),
        interpret=interpret,
    )(qa_p, qw_p, flat)
    out = out[:, :m, :n]
    if pk:
        # pad rows contribute pk * LUT[b,0,0] to every output element
        out = out - jnp.int32(pk) * flat[:, 0][:, None, None]
    return out
