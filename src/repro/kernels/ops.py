"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs verbatim, which is how they are validated against
the ``ref.py`` oracles.  On a TPU backend the same calls lower to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .approx_matmul import approx_matmul_lut_pallas
from .lowrank_matmul import lowrank_matmul_pallas
from .bitsim import bitsim_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def approx_matmul_lut(qa: jax.Array, qw: jax.Array, lut: jax.Array
                      ) -> jax.Array:
    """Bit-true approximate matmul on uint8 codes. (M,K)x(K,N)->(M,N) i32."""
    return approx_matmul_lut_pallas(qa, qw, lut, interpret=_interpret())


def lowrank_matmul(qa: jax.Array, qw: jax.Array, u: jax.Array, v: jax.Array
                   ) -> jax.Array:
    """Rank-R factored approximate matmul. (M,K)x(K,N)->(M,N) f32."""
    return lowrank_matmul_pallas(qa, qw, u, v, interpret=_interpret())


def bitsim(netlist, planes64: np.ndarray) -> np.ndarray:
    """Evaluate a ``repro.core.netlist.Netlist`` on uint64 bit-planes via
    the Pallas simulator (planes are split to uint32 lanes and rejoined).
    Drop-in equivalent of ``netlist.eval_words``."""
    n_i, w64 = planes64.shape
    lo = (planes64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (planes64 >> np.uint64(32)).astype(np.uint32)
    planes32 = np.empty((n_i, 2 * w64), dtype=np.uint32)
    planes32[:, 0::2] = lo
    planes32[:, 1::2] = hi
    out32 = np.asarray(bitsim_pallas(
        jnp.asarray(netlist.funcs), jnp.asarray(netlist.in0),
        jnp.asarray(netlist.in1), jnp.asarray(netlist.outputs),
        jnp.asarray(planes32),
        n_nodes=netlist.n_nodes, n_i=netlist.n_i, n_o=netlist.n_o,
        interpret=_interpret(),
    ))
    out64 = (out32[:, 0::2].astype(np.uint64)
             | (out32[:, 1::2].astype(np.uint64) << np.uint64(32)))
    return out64
