"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs verbatim, which is how they are validated against
the ``ref.py`` oracles.  On a TPU backend the same calls lower to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.custom_batching
import jax.numpy as jnp
import numpy as np

from .approx_matmul import approx_matmul_lut_pallas
from .composed_matmul import (composed_matmul_bank_pallas,
                              composed_matmul_pallas)
from .fused_matmul import (fused_composed_matmul_bank_pallas,
                           fused_composed_matmul_pallas,
                           fused_matmul_bank_pallas, fused_matmul_pallas)
from .lut_bank import approx_matmul_lut_bank_pallas
from .lowrank_matmul import lowrank_matmul_pallas
from .bitsim import bitsim_pallas, bitsim_pop_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.custom_batching.custom_vmap
def approx_matmul_lut(qa: jax.Array, qw: jax.Array, lut: jax.Array
                      ) -> jax.Array:
    """Bit-true approximate matmul on uint8 codes. (M,K)x(K,N)->(M,N) i32.

    ``vmap`` over the LUT argument does NOT fall back to rank-by-rank
    batching: a custom batching rule reroutes the whole batch to the
    banked kernel (grid over the multiplier axis), which is how the
    batched resilience engine turns an n-multiplier sweep into one
    launch (DESIGN.md §2.4).
    """
    return approx_matmul_lut_pallas(qa, qw, lut, interpret=_interpret())


@approx_matmul_lut.def_vmap
def _approx_matmul_lut_vmap(axis_size, in_batched, qa, qw, lut):
    qa_b, qw_b, lut_b = in_batched
    if qw_b:
        # batched weights (e.g. experts vmapping backend_matmul) are not
        # a LUT bank: keep pallas_call's native parallel batching rule.
        out = jax.vmap(
            lambda a, w, l: approx_matmul_lut_pallas(
                a, w, l, interpret=_interpret()),
            in_axes=(0 if qa_b else None, 0, 0 if lut_b else None),
        )(qa, qw, lut)
        return out, True
    luts = lut if lut_b else jnp.broadcast_to(lut, (axis_size,) + lut.shape)
    out = approx_matmul_lut_bank(qa, qw, luts)
    return out, True


def approx_matmul_lut_bank(qa: jax.Array, qw: jax.Array, luts: jax.Array
                           ) -> jax.Array:
    """Banked bit-true matmul: one launch for a whole LUT bank.
    qa: (M,K) shared or (n,M,K) banked codes; luts: (n,256,256)
    -> (n,M,N) i32, bit-identical per bank to ``approx_matmul_lut``."""
    return approx_matmul_lut_bank_pallas(qa, qw, luts,
                                         interpret=_interpret())


@functools.lru_cache(maxsize=None)
def _composed_op(reduce: tuple):
    """The composed (wide-width) LUT matmul op for one static reduce
    tree, with the same bank-collapsing batching rule as
    ``approx_matmul_lut``: vmap over (lut, wide) routes the whole
    mixed-width bank to the banked composed kernel — one launch, grid
    over the multiplier axis (DESIGN.md §2.6) — instead of batching
    the single-tile kernel lane by lane."""

    @jax.custom_batching.custom_vmap
    def op(qa, qw, lut, mask):
        return composed_matmul_pallas(qa, qw, lut, mask, reduce=reduce,
                                      interpret=_interpret())

    @op.def_vmap
    def _op_vmap(axis_size, in_batched, qa, qw, lut, mask):
        qa_b, qw_b, lut_b, mask_b = in_batched
        if qw_b:
            # batched weights (experts) are not a LUT bank: native rule
            out = jax.vmap(
                lambda a, w, l, mk: composed_matmul_pallas(
                    a, w, l, mk, reduce=reduce, interpret=_interpret()),
                in_axes=(0 if qa_b else None, 0, 0 if lut_b else None,
                         0 if mask_b else None),
            )(qa, qw, lut, mask)
            return out, True
        luts = (lut if lut_b
                else jnp.broadcast_to(lut, (axis_size,) + lut.shape))
        masks = (mask if mask_b
                 else jnp.broadcast_to(jnp.asarray(mask), (axis_size,)))
        out = composed_matmul_bank_pallas(qa, qw, luts, masks,
                                          reduce=reduce,
                                          interpret=_interpret())
        return out, True

    return op


def composed_matmul_lut(qa: jax.Array, qw: jax.Array, lut: jax.Array,
                        mask, reduce: tuple = ("exact", 0)) -> jax.Array:
    """Composed wide approximate matmul on W-bit codes through the
    256x256 tile LUT.  (M,K)x(K,N)->(M,N) f32 (exact int32 limb
    accumulation recombined as ``lo + 65536*hi``).  ``mask`` is the
    per-call (or per vmapped lane) 2W-bit product mask — the composed
    product is truncated to the gate netlist's output width, and
    ``mask == 0`` selects the plain 8-bit tile sum instead."""
    return _composed_op(tuple(reduce))(
        qa, qw, lut, jnp.asarray(mask, jnp.uint32))


def _bcast(v, batched: bool, axis_size: int):
    v = jnp.asarray(v)
    return v if batched else jnp.broadcast_to(v, (axis_size,) + v.shape)


@jax.custom_batching.custom_vmap
def fused_matmul_lut(x: jax.Array, w: jax.Array, lut: jax.Array,
                     sa, za, sw, zw, qmax) -> jax.Array:
    """Fused 8-bit approximate matmul on FLOAT operands: in-kernel
    quantize (pre-calibrated scalars from ``quant.scalar_params``),
    LUT gather, int32 accumulation, f32 correction + dequant — one
    Pallas program, bit-identical to the two-step pipeline
    (DESIGN.md §2.10).  (M,K)x(K,N) -> (M,N) f32.

    Like ``approx_matmul_lut``, a custom batching rule reroutes a vmap
    over (lut, scalars) to the banked fused kernel so bank sweeps stay
    one launch; batched weights keep the native rule."""
    return fused_matmul_pallas(x, w, lut, sa, za, sw, zw, qmax,
                               interpret=_interpret())


@fused_matmul_lut.def_vmap
def _fused_matmul_lut_vmap(axis_size, in_batched, x, w, lut,
                           sa, za, sw, zw, qmax):
    x_b, w_b, lut_b = in_batched[:3]
    if w_b:
        # batched weights (experts) are not a LUT bank: native rule
        out = jax.vmap(
            lambda *a: fused_matmul_pallas(*a, interpret=_interpret()),
            in_axes=tuple(0 if b else None for b in in_batched),
        )(x, w, lut, sa, za, sw, zw, qmax)
        return out, True
    luts = _bcast(lut, lut_b, axis_size)
    scalars = [_bcast(v, b, axis_size)
               for v, b in zip((sa, za, sw, zw, qmax), in_batched[3:])]
    # x stays SHARED (M,K) when unbatched — the banked kernel grids over
    # the lane axis and re-quantizes the shared tile per lane.
    out = fused_matmul_lut_bank(x, w, luts, *scalars)
    return out, True


def fused_matmul_lut_bank(x: jax.Array, w: jax.Array, luts: jax.Array,
                          sa, za, sw, zw, qmax) -> jax.Array:
    """Banked fused matmul: one launch per LUT bank, per-lane quant
    scalars (n,).  x: (M,K) shared or (n,M,K) banked floats;
    luts: (n,256,256) -> (n,M,N) f32, per lane bit-identical to
    ``fused_matmul_lut``.  LUT slices are DMA double-buffered."""
    return fused_matmul_bank_pallas(x, w, luts, sa, za, sw, zw, qmax,
                                    interpret=_interpret())


@jax.custom_batching.custom_vmap
def fused_composed_matmul_lut(x: jax.Array, w: jax.Array,
                              lut: jax.Array, mask, rcode,
                              sa, za, sw, zw, qmax) -> jax.Array:
    """Fused composed wide (12/16-bit) approximate matmul on floats.
    ``mask`` is the 2W-bit product mask (0 = narrow lane) and ``rcode``
    the ``registry.encode_reduce`` (kind, k) int32 pair — the reduce
    tree is RUNTIME data here, so every adder family (and any mix of
    them across vmapped lanes) shares one compiled program, unlike the
    per-reduce ``composed_matmul_lut`` specializations."""
    return fused_composed_matmul_pallas(x, w, lut, mask, rcode,
                                        sa, za, sw, zw, qmax,
                                        interpret=_interpret())


@fused_composed_matmul_lut.def_vmap
def _fused_composed_matmul_lut_vmap(axis_size, in_batched, x, w, lut,
                                    mask, rcode, sa, za, sw, zw, qmax):
    x_b, w_b, lut_b = in_batched[:3]
    if w_b:
        out = jax.vmap(
            lambda *a: fused_composed_matmul_pallas(
                *a, interpret=_interpret()),
            in_axes=tuple(0 if b else None for b in in_batched),
        )(x, w, lut, mask, rcode, sa, za, sw, zw, qmax)
        return out, True
    luts = _bcast(lut, lut_b, axis_size)
    rest = [_bcast(v, b, axis_size)
            for v, b in zip((mask, rcode, sa, za, sw, zw, qmax),
                            in_batched[3:])]
    out = fused_composed_matmul_lut_bank(x, w, luts, *rest)
    return out, True


def fused_composed_matmul_lut_bank(x: jax.Array, w: jax.Array,
                                   luts: jax.Array, masks, rcodes,
                                   sa, za, sw, zw, qmax) -> jax.Array:
    """Banked composed fused matmul: per-lane masks (n,), reduce codes
    (n,2) and quant scalars (n,) in ONE program — mixed-width AND
    mixed-reduce banks evaluate in a single launch."""
    return fused_composed_matmul_bank_pallas(
        x, w, luts, masks, rcodes, sa, za, sw, zw, qmax,
        interpret=_interpret())


def lowrank_matmul(qa: jax.Array, qw: jax.Array, u: jax.Array, v: jax.Array
                   ) -> jax.Array:
    """Rank-R factored approximate matmul. (M,K)x(K,N)->(M,N) f32."""
    return lowrank_matmul_pallas(qa, qw, u, v, interpret=_interpret())


def bitsim(netlist, planes64: np.ndarray) -> np.ndarray:
    """Evaluate a ``repro.core.netlist.Netlist`` on uint64 bit-planes via
    the Pallas simulator (planes are split to uint32 lanes and rejoined).
    Drop-in equivalent of ``netlist.eval_words``."""
    out32 = np.asarray(bitsim_pallas(
        jnp.asarray(netlist.funcs), jnp.asarray(netlist.in0),
        jnp.asarray(netlist.in1), jnp.asarray(netlist.outputs),
        jnp.asarray(split_planes64(planes64)),
        n_nodes=netlist.n_nodes, n_i=netlist.n_i, n_o=netlist.n_o,
        interpret=_interpret(),
    ))
    return join_planes32(out32)


def split_planes64(planes64: np.ndarray) -> np.ndarray:
    """(n, W) uint64 bit-planes -> (n, 2W) uint32 lanes, low word first
    (the lane layout both bitsim kernels consume)."""
    n, w64 = planes64.shape
    lo = (planes64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (planes64 >> np.uint64(32)).astype(np.uint32)
    planes32 = np.empty((n, 2 * w64), dtype=np.uint32)
    planes32[:, 0::2] = lo
    planes32[:, 1::2] = hi
    return planes32


def join_planes32(planes32: np.ndarray) -> np.ndarray:
    """Inverse of ``split_planes64`` on the trailing axis (any rank)."""
    return (planes32[..., 0::2].astype(np.uint64)
            | (planes32[..., 1::2].astype(np.uint64) << np.uint64(32)))


def bitsim_pop(netlists, planes64: np.ndarray) -> np.ndarray:
    """Evaluate a population of same-interface netlists on shared
    uint64 bit-planes in ONE Pallas program (DESIGN.md §2.9).

    Returns (P, n_o, W) uint64 — row p bit-identical to
    ``netlists[p].eval_words(planes64)``.  Mixed node counts are padded
    with inactive const0 nodes (``stack_netlists``).
    """
    from repro.core.netlist import stack_netlists
    funcs, in0, in1, outs = stack_netlists(list(netlists))
    first = netlists[0]
    out32 = np.asarray(bitsim_pop_pallas(
        jnp.asarray(funcs), jnp.asarray(in0), jnp.asarray(in1),
        jnp.asarray(outs), jnp.asarray(split_planes64(planes64)),
        n_nodes=funcs.shape[1], n_i=first.n_i, n_o=first.n_o,
        interpret=_interpret(),
    ))
    return join_planes32(out32)
