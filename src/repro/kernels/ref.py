"""Pure-jnp oracles for every Pallas kernel in this package.

These are the bit-true references the property tests compare against
(`tests/test_kernels.py` sweeps shapes/dtypes and asserts exact
equality for integer paths / allclose for float paths).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def approx_matmul_lut_ref(qa: jax.Array, qw: jax.Array, lut: jax.Array
                          ) -> jax.Array:
    """Σ_k LUT[qa[m,k], qw[k,n]] with int32 accumulation.
    qa: (M,K) int32 codes in [0,255]; qw: (K,N); lut: (256,256) int32."""
    flat = lut.reshape(-1)
    idx = qa[:, :, None] * 256 + qw[None, :, :]
    return jnp.sum(jnp.take(flat, idx, axis=0), axis=1, dtype=jnp.int32)


def approx_matmul_lut_bank_ref(qa: jax.Array, qw: jax.Array,
                               luts: jax.Array) -> jax.Array:
    """Banked oracle: out[b] = Σ_k luts[b][qa_b, qw] with int32
    accumulation.  qa: (M,K) shared codes or (n,M,K) banked codes;
    qw: (K,N); luts: (n,256,256) int32 -> (n,M,N) int32."""
    if qa.ndim == 2:
        return jax.vmap(lambda lut: approx_matmul_lut_ref(qa, qw, lut)
                        )(luts)
    return jax.vmap(lambda qa_b, lut: approx_matmul_lut_ref(qa_b, qw, lut)
                    )(qa, luts)


def composed_matmul_ref(qa: jax.Array, qw: jax.Array, lut: jax.Array,
                        mask, reduce: tuple = ("exact", 0)) -> jax.Array:
    """Composed wide (12/16-bit) oracle: tiled 8x8 digit products
    through the 256x256 tile LUT, shift/add-tree reduced and truncated
    to the 2W-bit ``mask`` (0 = narrow lane), exact int32 limb
    accumulation recombined as f32 (DESIGN.md §2.6).  Shared with the
    ref datapath — see ``composed_matmul.py`` for the kernels."""
    from .composed_matmul import composed_matmul_ref as _impl
    return _impl(qa, qw, lut, mask, reduce)


def _affine_q(v: jax.Array, scale, zp, qmax) -> jax.Array:
    """``repro.approx.quant.quantize`` with explicit scalars (same op
    and dtype order — the fused kernels' in-register quantize)."""
    q = jnp.round(v.astype(jnp.float32) / scale) + zp
    return jnp.clip(q, 0, qmax).astype(jnp.int32)


def _fused_correct(s: jax.Array, qa: jax.Array, qw: jax.Array,
                   za, zw, sa, sw, k: int) -> jax.Array:
    """The f32 zero-point correction + dequant epilogue of
    ``repro.approx.backend._quantized_matmul`` (non-exact branch)."""
    row = jnp.sum(qa, axis=1, dtype=jnp.int32).astype(jnp.float32)
    col = jnp.sum(qw, axis=0, dtype=jnp.int32).astype(jnp.float32)
    zaf = za.astype(jnp.float32)
    zwf = zw.astype(jnp.float32)
    acc = s - zwf * row[:, None] - zaf * col[None, :] + k * zaf * zwf
    return acc * (sa * sw)


def fused_matmul_ref(x: jax.Array, w: jax.Array, lut: jax.Array,
                     sa, za, sw, zw, qmax) -> jax.Array:
    """Oracle for the fused 8-bit datapath (DESIGN.md §2.10): quantize
    with pre-calibrated scalars, LUT-gather matmul, f32 correction +
    dequant — the exact composition the fused Pallas kernel collapses
    into one program.  x: (M,K) f32; w: (K,N) f32 -> (M,N) f32."""
    qa = _affine_q(x, sa, za, qmax)
    qw = _affine_q(w, sw, zw, qmax)
    s = approx_matmul_lut_ref(qa, qw, lut).astype(jnp.float32)
    return _fused_correct(s, qa, qw, za, zw, sa, sw, x.shape[-1])


def fused_matmul_bank_ref(x: jax.Array, w: jax.Array, luts: jax.Array,
                          sa, za, sw, zw, qmax) -> jax.Array:
    """Banked fused oracle: per-lane scalars (n,), x (M,K) shared or
    (n,M,K) banked, luts (n,256,256) -> (n,M,N) f32."""
    return jax.vmap(
        lambda x_b, lut, *s: fused_matmul_ref(x_b, w, lut, *s),
        in_axes=(None if x.ndim == 2 else 0, 0, 0, 0, 0, 0, 0),
    )(x, luts, sa, za, sw, zw, qmax)


def fused_composed_matmul_ref(x: jax.Array, w: jax.Array,
                              lut: jax.Array, mask, sa, za, sw, zw,
                              qmax, reduce: tuple = ("exact", 0)
                              ) -> jax.Array:
    """Oracle for the fused composed wide (12/16-bit) datapath: wide
    quantize, digit-product tile-LUT matmul under the STATIC ``reduce``
    tree, f32 correction.  The fused kernel takes the reduce as runtime
    data (``encode_reduce``), so comparing against this static oracle
    also checks the dynamic-reduce selection."""
    qa = _affine_q(x, sa, za, qmax)
    qw = _affine_q(w, sw, zw, qmax)
    s = composed_matmul_ref(qa, qw, lut, mask, reduce)
    return _fused_correct(s, qa, qw, za, zw, sa, sw, x.shape[-1])


def fused_composed_matmul_bank_ref(x: jax.Array, w: jax.Array,
                                   luts: jax.Array, masks, reduces,
                                   sa, za, sw, zw, qmax) -> jax.Array:
    """Banked composed fused oracle; ``reduces`` is a per-lane sequence
    of static reduce tuples (mixed-reduce banks allowed)."""
    outs = []
    for b in range(luts.shape[0]):
        x_b = x if x.ndim == 2 else x[b]
        outs.append(fused_composed_matmul_ref(
            x_b, w, luts[b], masks[b], sa[b], za[b], sw[b], zw[b],
            qmax[b], tuple(reduces[b])))
    return jnp.stack(outs)


def lowrank_matmul_ref(qa: jax.Array, qw: jax.Array, u: jax.Array,
                       v: jax.Array) -> jax.Array:
    """Σ_r tableU_r(qa) @ tableV_r(qw), f32. u,v: (R,256) f32."""
    ua = jnp.take(u, qa, axis=1)   # (R,M,K)
    vw = jnp.take(v, qw, axis=1)   # (R,K,N)
    return jnp.einsum("rmk,rkn->mn", ua, vw,
                      preferred_element_type=jnp.float32)


def bitsim_ref(funcs: np.ndarray, in0: np.ndarray, in1: np.ndarray,
               out_idx: np.ndarray, planes: jax.Array) -> jax.Array:
    """Bit-parallel netlist evaluation on uint32 word planes.

    planes: (n_i, W) uint32. Returns (n_o, W) uint32.  Gate semantics
    match repro.core.gates (identity, not, and, or, xor, nand, nor,
    xnor, const0, const1).
    """
    n_i, W = planes.shape
    sigs = [planes[i] for i in range(n_i)]
    ones = jnp.full((W,), 0xFFFFFFFF, dtype=jnp.uint32)
    zeros = jnp.zeros((W,), dtype=jnp.uint32)
    for f, a, b in zip(funcs.tolist(), in0.tolist(), in1.tolist()):
        x, y = sigs[a], sigs[b]
        if f == 0:
            r = x
        elif f == 1:
            r = ~x
        elif f == 2:
            r = x & y
        elif f == 3:
            r = x | y
        elif f == 4:
            r = x ^ y
        elif f == 5:
            r = ~(x & y)
        elif f == 6:
            r = ~(x | y)
        elif f == 7:
            r = ~(x ^ y)
        elif f == 8:
            r = zeros
        elif f == 9:
            r = ones
        else:
            raise ValueError(f)
        sigs.append(r)
    return jnp.stack([sigs[int(o)] for o in out_idx])


def bitsim_pop_ref(funcs: np.ndarray, in0: np.ndarray, in1: np.ndarray,
                   out_idx: np.ndarray, planes: jax.Array) -> jax.Array:
    """Population oracle: per-candidate ``bitsim_ref`` stacked.

    funcs/in0/in1: (P, n_nodes); out_idx: (P, n_o); planes: (n_i, W)
    uint32 shared.  Returns (P, n_o, W) uint32 — the reference the
    population kernel (``bitsim_pop_pallas``) must match bit for bit.
    """
    return jnp.stack([
        bitsim_ref(np.asarray(funcs[p]), np.asarray(in0[p]),
                   np.asarray(in1[p]), np.asarray(out_idx[p]), planes)
        for p in range(np.asarray(funcs).shape[0])
    ])
