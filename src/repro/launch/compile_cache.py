"""Compile-time hygiene: persistent compilation cache + trace audit
(DESIGN.md §2.10).

Every benchmark lane and the serve CLI re-trace the same handful of
programs on every process start; on CPU the XLA compile time dwarfs the
first-step run time.  ``enable_compile_cache`` turns on JAX's persistent
compilation cache so repeated invocations (CI re-runs, benchmark
sweeps, serve restarts) hit disk instead of recompiling:

    from repro.launch.compile_cache import enable_compile_cache
    enable_compile_cache()            # benchmarks/results/.jax_cache
    enable_compile_cache("/tmp/cc")   # explicit directory

``JAX_COMPILATION_CACHE_DIR`` in the environment wins over both the
argument and the default, so operators can redirect the cache without
touching code.

``trace_audit`` is the measurement side of the same hygiene story: a
context manager that counts backend compiles and persistent-cache hits
through ``jax.monitoring``, used by ``benchmarks/kernel_bench.py`` to
record trace counts next to wall times and by the O(1)-trace gates in
``tests/test_fused_matmul.py``.
"""
from __future__ import annotations

import contextlib
import os
from dataclasses import dataclass, field

import jax

# Events published by jax/_src/compiler.py and jax/_src/compilation_cache.py.
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
CACHE_HIT_EVENT = "/jax/compilation_cache/cache_hits"

_DEFAULT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))),
    "benchmarks", "results", ".jax_cache")

# Curated XLA flags for reproducible CPU benchmarking.  Kept minimal on
# purpose: the only flag we add by default pins the intra-op threadpool
# so wall times are comparable across CI runners; everything else stays
# at XLA defaults (the fused kernels must win on merit, not flag tuning).
XLA_BENCH_FLAGS = ("--xla_cpu_multi_thread_eigen=false",)


def xla_flags_env(extra: tuple[str, ...] = ()) -> str:
    """Merged ``XLA_FLAGS`` value: existing env flags + curated bench
    flags + ``extra``, deduplicated, order-preserving."""
    flags: list[str] = []
    for chunk in (os.environ.get("XLA_FLAGS", "").split(),
                  XLA_BENCH_FLAGS, extra):
        for f in chunk:
            if f and f not in flags:
                flags.append(f)
    return " ".join(flags)


def enable_compile_cache(cache_dir: str | None = None) -> str:
    """Turn on the persistent compilation cache and return its path.

    Resolution order: ``JAX_COMPILATION_CACHE_DIR`` env var, then the
    ``cache_dir`` argument, then ``benchmarks/results/.jax_cache``.
    The min-compile-time / min-entry-size thresholds are zeroed so even
    the sub-second CPU test programs persist — without this the cache
    silently ignores everything the repro suite compiles.
    """
    d = os.environ.get("JAX_COMPILATION_CACHE_DIR") or cache_dir \
        or _DEFAULT_DIR
    os.makedirs(d, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", d)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    # jax memoizes the cache-enabled decision at the FIRST compile of
    # the process (compilation_cache.is_cache_used); enabling the cache
    # after any jit call would otherwise be a silent no-op, so drop
    # that memo and let the next compile re-check the config.
    try:
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except (ImportError, AttributeError):  # pragma: no cover
        pass
    return d


@dataclass
class TraceCounts:
    """Mutable tally filled in while a ``trace_audit`` block runs."""

    compiles: int = 0
    cache_hits: int = 0
    compile_secs: float = 0.0
    events: list = field(default_factory=list)

    @property
    def traced_programs(self) -> int:
        """Distinct lowered computations: the backend-compile duration
        event fires once per program whether it compiled fresh or came
        out of the persistent cache (a hit additionally bumps
        ``cache_hits``), so this is just the duration-event count."""
        return self.compiles

    @property
    def fresh_compiles(self) -> int:
        """Programs actually compiled by XLA (not served from the
        persistent cache)."""
        return self.compiles - self.cache_hits


@contextlib.contextmanager
def trace_audit():
    """Count backend compiles (and persistent-cache hits) in a block.

    >>> with trace_audit() as counts:
    ...     jax.jit(fn)(x)
    >>> counts.compiles
    1

    ``jax.monitoring`` listeners are global and append-only, so one
    process-wide listener is registered lazily and audits are scoped by
    delta-counting against it.
    """
    _install_listeners()
    start_c = len(_GLOBAL.compile_events)
    start_h = _GLOBAL.cache_hits
    counts = TraceCounts()
    try:
        yield counts
    finally:
        new = _GLOBAL.compile_events[start_c:]
        counts.compiles = len(new)
        counts.compile_secs = float(sum(new))
        counts.cache_hits = _GLOBAL.cache_hits - start_h
        counts.events = list(new)


class _Global:
    def __init__(self):
        self.compile_events: list[float] = []
        self.cache_hits = 0
        self.installed = False


_GLOBAL = _Global()


def _install_listeners() -> None:
    if _GLOBAL.installed:
        return
    _GLOBAL.installed = True

    def _on_duration(event: str, duration: float, **kw) -> None:
        if event == COMPILE_EVENT:
            _GLOBAL.compile_events.append(duration)

    def _on_event(event: str, **kw) -> None:
        if event == CACHE_HIT_EVENT:
            _GLOBAL.cache_hits += 1

    jax.monitoring.register_event_duration_secs_listener(_on_duration)
    jax.monitoring.register_event_listener(_on_event)
