import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) cell on the production meshes and record
memory / cost / collective analysis for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b \
        --shape train_4k [--multi-pod] [--out benchmarks/results/dryrun]

The XLA_FLAGS line above MUST precede any jax import: it materializes
512 host placeholder devices so ``jax.make_mesh`` can build the
(2,16,16) production mesh.  Smoke tests / benches never import this
module and keep seeing 1 device.
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import numpy as np

from repro.configs import ARCHS, all_cells, get_config
from repro.configs.shapes import SHAPES
from repro.launch import hlo_analysis
from repro.launch.mesh import (batch_shardings, cache_shardings, data_axes,
                               axis_size, make_production_mesh,
                               params_shardings, replicated)
from repro.launch.steps import build_cell, build_probes, model_flops
from jax.sharding import NamedSharding, PartitionSpec as P

DEFAULT_OUT = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link


def _sharded_sds(sds_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, shardings_tree)


def cell_shardings(cell, mesh):
    """in/out sharding pytrees for this cell's step function."""
    long_ctx = cell.shape.name == "long_500k"
    if cell.kind == "train":
        params_sds, opt_sds, bspecs = cell.args_sds
        p_sh = params_shardings(params_sds, mesh)
        from repro.train.optimizer import OptState
        opt_sh = OptState(step=replicated(mesh),
                          m=jax.tree.map(lambda x: x, p_sh),
                          v=jax.tree.map(lambda x: x, p_sh))
        b_sh = batch_shardings(bspecs, mesh, microbatched=True)
        in_sh = (p_sh, opt_sh, b_sh)
        out_sh = (p_sh, opt_sh, replicated(mesh))
        return in_sh, out_sh
    if cell.kind == "prefill":
        params_sds, bspecs, cache_sds = cell.args_sds
        p_sh = params_shardings(params_sds, mesh)
        b_sh = batch_shardings(bspecs, mesh)
        c_sh = cache_shardings(cache_sds, mesh, long_ctx)
        dp = data_axes(mesh)
        logits_sh = NamedSharding(mesh, P(dp, None))
        return (p_sh, b_sh, c_sh), (logits_sh, c_sh)
    # decode
    params_sds, token_sds, cache_sds = cell.args_sds
    p_sh = params_shardings(params_sds, mesh)
    c_sh = cache_shardings(cache_sds, mesh, long_ctx)
    dp = data_axes(mesh)
    tok_sh = NamedSharding(
        mesh, P(dp) if cell.shape.global_batch % axis_size(mesh, dp) == 0
        else P())
    logits_sh = NamedSharding(
        mesh, P(dp, None) if cell.shape.global_batch
        % axis_size(mesh, dp) == 0 else P())
    return (p_sh, tok_sh, c_sh), (logits_sh, c_sh)


def probe_shardings(probe, mesh):
    """in-shardings for an analysis probe (out left to XLA)."""
    cell = probe.cell
    if probe.name == "opt":
        params_sds, grads_sds, opt_sds = probe.args_sds
        p_sh = params_shardings(params_sds, mesh)
        from repro.train.optimizer import OptState
        opt_sh = OptState(step=replicated(mesh), m=p_sh,
                          v=jax.tree.map(lambda x: x, p_sh))
        return (p_sh, jax.tree.map(lambda x: x, p_sh), opt_sh)
    if cell.kind == "train":
        params_sds, mb_specs = probe.args_sds
        return (params_shardings(params_sds, mesh),
                batch_shardings(mb_specs, mesh))
    # serve probe: reuse the cell sharding logic
    in_sh, _ = cell_shardings(cell, mesh)
    return in_sh


def _combine_linear(m1: dict, m2: dict, g_full: float) -> dict:
    """Depth extrapolation: probe d1 = fixed + slope, d2 = fixed +
    2*slope; step(L) = fixed + slope*g_full (clamped at >= 0)."""
    out = {}
    for key in m1:
        slope = m2[key] - m1[key]
        fixed = m1[key] - slope
        out[key] = max(0.0, fixed + slope * g_full)
    return out


def run_probes(arch, shape_name, mesh, serve_mult, serve_mode,
               overrides=None, serve_rank: int = 4) -> dict:
    """Compile the shallow unrolled probes; extrapolate to full depth."""
    from repro.configs import get_config
    from repro.models.decoder import block_pattern
    dp = axis_size(mesh, data_axes(mesh))
    probes = build_probes(arch, shape_name, dp, serve_mult, serve_mode,
                          overrides, serve_rank)
    base_cfg = get_config(arch)
    period = (len(block_pattern(base_cfg))
              if base_cfg.family != "encdec" else 1)
    g_full = base_cfg.n_layers / period

    raw: dict[str, dict] = {}
    coll_raw: dict[str, dict] = {}
    details = []
    n_mb = 1
    for probe in probes:
        t0 = time.time()
        with mesh:
            jitted = jax.jit(probe.step_fn,
                             in_shardings=probe_shardings(probe, mesh))
            compiled = jitted.lower(*probe.args_sds).compile()
            cost = compiled.cost_analysis()
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        f = float(cost.get("flops", 0.0)) if cost else 0.0
        b = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
        raw[probe.name] = {"flops": f, "bytes": b,
                           "coll": float(coll.get("total_bytes", 0))}
        coll_raw[probe.name] = {k: v["bytes"] for k, v in coll.items()
                                if k != "total_bytes"}
        n_mb = max(n_mb, probe.cell.microbatches)
        details.append({"probe": probe.name, "depth": probe.depth,
                        "flops": f, "bytes": b,
                        "collective_bytes": raw[probe.name]["coll"],
                        "compile_s": round(time.time() - t0, 1)})

    step = _combine_linear(raw["stack_d1"], raw["stack_d2"], g_full)
    kinds = set(coll_raw["stack_d1"]) | set(coll_raw["stack_d2"])
    coll_kinds = _combine_linear(
        {k: coll_raw["stack_d1"].get(k, 0.0) for k in kinds},
        {k: coll_raw["stack_d2"].get(k, 0.0) for k in kinds}, g_full)

    if "opt" in raw:  # train: n_mb * stack + optimizer
        flops = n_mb * step["flops"] + raw["opt"]["flops"]
        byts = n_mb * step["bytes"] + raw["opt"]["bytes"]
        coll_kinds = {k: n_mb * v for k, v in coll_kinds.items()}
        for k, v in coll_raw["opt"].items():
            coll_kinds[k] = coll_kinds.get(k, 0.0) + v
    else:
        flops, byts = step["flops"], step["bytes"]

    coll_by_kind = {k: {"bytes": v, "count": -1}
                    for k, v in coll_kinds.items()}
    coll_by_kind["total_bytes"] = sum(coll_kinds.values())
    return {"flops_per_device": flops, "bytes_per_device": byts,
            "collectives": coll_by_kind, "probes": details,
            "extrapolation": {"period": period, "groups": g_full,
                              "microbatches": n_mb}}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             serve_mult: str = "auto", serve_mode: str = "lowrank",
             save_hlo: bool = False, out_dir: str = DEFAULT_OUT,
             probes: bool = True, overrides=None, tag_suffix: str = "",
             serve_rank: int = 4) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = axis_size(mesh, data_axes(mesh))
    cell = build_cell(arch, shape_name, dp, serve_mult, serve_mode,
                      overrides, serve_rank)
    in_sh, out_sh = cell_shardings(cell, mesh)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=in_sh,
                         out_shardings=out_sh, donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()

    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    n_chips = int(np.prod(list(mesh.shape.values())))

    flops_dev = float(cost.get("flops", 0.0)) if cost else 0.0
    bytes_dev = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    coll_dev = float(coll.get("total_bytes", 0))
    probe_info = None
    if probes:
        # trip-count-corrected accounting from the unrolled probes
        probe_info = run_probes(arch, shape_name, mesh, serve_mult,
                                serve_mode, overrides, serve_rank)
        flops_dev = probe_info["flops_per_device"]
        bytes_dev = probe_info["bytes_per_device"]
        coll = probe_info["collectives"]
        coll_dev = float(coll.get("total_bytes", 0))

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cell, cell.args_sds[0])
    flops_global = flops_dev * n_chips
    result = {
        "arch": arch,
        "shape": shape_name,
        "overrides": dict(overrides) if overrides else None,
        "tag_suffix": tag_suffix,
        "kind": cell.kind,
        "mesh": "x".join(f"{k}={v}" for k, v in mesh.shape.items()),
        "n_chips": n_chips,
        "multi_pod": multi_pod,
        "microbatches": cell.microbatches,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_gb": (getattr(mem, "temp_size_in_bytes", 0)
                        + getattr(mem, "argument_size_in_bytes", 0))
            / 1e9 if mem else None,
        },
        "flops_per_device": flops_dev,
        "flops_global": flops_global,
        "bytes_per_device": bytes_dev,
        "collectives": coll,
        "probe_details": (probe_info or {}).get("probes"),
        "roofline": {
            **terms,
            "bottleneck": bottleneck.replace("_s", ""),
            "step_time_lower_bound_s": max(terms.values()),
            "model_flops_global": mf,
            "useful_flops_ratio": (mf / flops_global
                                   if flops_global else None),
            "roofline_fraction": (
                (mf / n_chips / PEAK_FLOPS) / max(terms.values())
                if max(terms.values()) > 0 else None),
        },
    }
    if save_hlo:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}"
        with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return result


def save_result(result: dict, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    tag = (f"{result['arch']}_{result['shape']}_"
           f"{'mp' if result['multi_pod'] else 'sp'}"
           + (result.get("tag_suffix") or ""))
    path = os.path.join(out_dir, tag + ".json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS) + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable cell (sequentially)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--serve-mult", default="auto")
    ap.add_argument("--serve-mode", default="lowrank",
                    choices=("lowrank", "lowrank_prepared", "int8",
                             "lut", "bf16"))
    ap.add_argument("--serve-rank", type=int, default=4)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the unrolled analysis probes")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--tag", default="",
                    help="suffix for the result file name")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    if args.all:
        cells, skips = all_cells()
        todo = [(a, s) for a, s in cells]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    for arch, shape in todo:
        tag = (f"{arch}_{shape}_{'mp' if args.multi_pod else 'sp'}"
               + args.tag)
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] skip {tag} (exists)", flush=True)
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            overrides = dict(kv.split("=", 1) for kv in args.override)
            res = run_cell(arch, shape, args.multi_pod, args.serve_mult,
                           args.serve_mode, args.save_hlo, args.out,
                           probes=not args.no_probes, overrides=overrides,
                           tag_suffix=args.tag,
                           serve_rank=args.serve_rank)
        except Exception as e:  # record failures — they are bugs to fix
            res = {"arch": arch, "shape": shape,
                   "multi_pod": args.multi_pod, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        p = save_result(res, args.out)
        if res.get("ok"):
            r = res["roofline"]
            print(f"[dryrun] {tag}: OK compile={res['compile_s']}s "
                  f"bottleneck={r['bottleneck']} "
                  f"lb={r['step_time_lower_bound_s']:.4f}s "
                  f"roofline_frac={r['roofline_fraction']:.3f}"
                  if r["roofline_fraction"] is not None else "", flush=True)
        else:
            print(f"[dryrun] {tag}: FAIL {res['error']}", flush=True)


if __name__ == "__main__":
    main()
