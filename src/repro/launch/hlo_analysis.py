"""HLO-text analysis: collective byte accounting for the roofline.

``cost_analysis()`` has no collective term, so we parse the compiled
module text: build a name -> shape map from every instruction
definition, then for each collective op sum its *operand* bytes (the
data each chip contributes).  The HLO is SPMD — per-chip bytes; the
roofline divides by per-chip link bandwidth (see EXPERIMENTS.md
§Roofline for the accounting convention).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1,
    "f8e5m2": 1, "token": 0, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


class _Def:
    __slots__ = ("name", "shape", "op", "args")

    def __init__(self, name, shape, op, args):
        self.name, self.shape, self.op, self.args = name, shape, op, args


def _parse_def(line: str):
    """Parse '  %name = SHAPE opname(args...' robustly.

    SHAPE is either 'dtype[dims]{layout}' or a tuple '( ... )' (which may
    itself contain parens-free shapes and /*comments*/) — a greedy regex
    here would eat into the op name, so we scan explicitly."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0 or not (s.startswith("%") or s[:eq].replace(".", "").replace(
            "-", "").replace("_", "").isalnum()):
        return None
    name = s[:eq].strip().lstrip("%")
    rhs = s[eq + 3:]
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape = rhs[:end + 1]
        rest = rhs[end + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape = rhs[:sp]
        rest = rhs[sp + 1:].lstrip()
    par = rest.find("(")
    if par <= 0:
        return None
    op = rest[:par]
    if not re.fullmatch(r"[\w\-]+", op):
        return None
    return _Def(name, shape, op, rest[par + 1:])


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string like 'bf16[16,128]{1,0}' or a tuple
    '(f32[2,4], s32[1])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_kind: {"bytes": per-chip operand bytes, "count": n},
    "total_bytes": ...} summed over the module."""
    lines = hlo_text.splitlines()
    defs = [d for d in (_parse_def(ln) for ln in lines) if d is not None]
    shapes = {d.name: d.shape for d in defs}

    out: dict = defaultdict(lambda: {"bytes": 0, "count": 0})
    for d in defs:
        kind = None
        for c in COLLECTIVE_OPS:
            if d.op == c or d.op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        operand_bytes = 0
        for ref in re.finditer(r"%?([\w\.\-]+)", d.args.split(")")[0]):
            name = ref.group(1)
            if name in shapes:
                operand_bytes += shape_bytes(shapes[name])
        if operand_bytes == 0:
            operand_bytes = shape_bytes(d.shape)
        out[kind]["bytes"] += operand_bytes
        out[kind]["count"] += 1
    total = sum(v["bytes"] for v in out.values())
    result = {k: dict(v) for k, v in out.items()}
    result["total_bytes"] = total
    return result


def op_histogram(hlo_text: str, top: int = 20) -> list[tuple[str, int]]:
    """Instruction-count histogram — used to spot remat recompute and
    layout thrash (reshape/transpose storms) during §Perf iterations."""
    counts: dict[str, int] = defaultdict(int)
    for ln in hlo_text.splitlines():
        d = _parse_def(ln)
        if d is not None:
            counts[d.op] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]


def bytes_by_op(hlo_text: str, top: int = 15) -> list[tuple[str, float, int]]:
    """Result-shape bytes aggregated per op kind (profiling aid)."""
    agg: dict[str, list] = defaultdict(lambda: [0, 0])
    for ln in hlo_text.splitlines():
        d = _parse_def(ln)
        if d is None:
            continue
        agg[d.op][0] += shape_bytes(d.shape)
        agg[d.op][1] += 1
    rows = [(op, b, n) for op, (b, n) in agg.items()]
    return sorted(rows, key=lambda r: -r[1])[:top]
