"""Production mesh + sharding rules (DESIGN.md §6).

Mesh: single-pod (data=16, model=16) = 256 chips; multi-pod adds an
outer ``pod`` axis (2, 16, 16) = 512 chips.  ``pod`` behaves as an outer
data-parallel axis whose gradient reduction crosses the DCN.

Parameter sharding is FSDP-style: every weight matrix puts one dim on
``model`` (tensor parallelism / expert parallelism) and one on the
data(-and-pod) axes (ZeRO-3 parameter sharding) — XLA inserts the
just-in-time all-gathers.  Axes are applied only when the dim is
divisible; GQA head counts that don't divide 16 (yi/llava 56H,
qwen3-14b 40H, whisper 20H) simply drop to replicated on that dim
rather than relying on GSPMD padding.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh: Mesh):
    """The data-parallel axes ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


# ----------------------------------------------------------------------
# Parameter sharding rules
# ----------------------------------------------------------------------
# matched against the LAST path component; (model_dim, fsdp_dim) are
# indices into the *trailing* (non-stacked) dims of the leaf.
#   in-proj style (d_in, d_out): model on the output dim, fsdp on input
#   out-proj style (d_in, d_out): model on the input dim, fsdp on output
_OUT_PROJ_NAMES = ("wo", "out_proj", "w_down", "wdown")
_EXPERT_PREFIX = ("wi", "wg", "wo")  # under a "ffn_*/..." moe subtree


def param_pspec(path: str, shape: tuple, mesh: Mesh) -> P:
    fsdp = data_axes(mesh)
    nd = len(shape)
    parts = path.split("/")
    last = parts[-1]
    stacked = 1 if parts and parts[0].endswith("blocks") else 0
    tshape = shape[stacked:]
    tnd = len(tshape)

    def assemble(tspec: list) -> P:
        return P(*([None] * stacked + tspec))

    # prepared-weight leaves (lowrank serving): tabs are (..., R, K, N)
    # and shard like the original weight; aux scalars replicate.
    if last == "tabs":
        parent = parts[-2] if len(parts) >= 2 else ""
        model_dim, fsdp_dim = ((-2, -1) if parent in _OUT_PROJ_NAMES
                               else (-1, -2))
        spec = [None] * tnd
        if _fits(tshape[model_dim], axis_size(mesh, "model")):
            spec[model_dim] = "model"
        elif tnd >= 4 and _fits(tshape[0], axis_size(mesh, "model")):
            spec[0] = "model"          # experts: EP on E
        if spec[fsdp_dim] is None and _fits(tshape[fsdp_dim],
                                            axis_size(mesh, fsdp)):
            spec[fsdp_dim] = fsdp
        return assemble(spec)
    if last in ("colsum", "w_scale", "w_zp"):
        spec = [None] * tnd
        if tnd >= 1 and last == "colsum":
            parent = parts[-2] if len(parts) >= 2 else ""
            if parent not in _OUT_PROJ_NAMES and \
                    _fits(tshape[-1], axis_size(mesh, "model")):
                spec[-1] = "model"
        return assemble(spec)

    if tnd <= 1:
        return assemble([None] * tnd)

    is_moe_leaf = ("moe" in path or "ffn_" in path) and tnd == 3
    if is_moe_leaf:
        # experts (E, d, f): EP on E, fsdp on the widest remaining dim
        spec: list = [None, None, None]
        if _fits(tshape[0], axis_size(mesh, "model")):
            spec[0] = "model"
        wide = 1 + int(tshape[2] >= tshape[1])
        if _fits(tshape[wide], axis_size(mesh, fsdp)):
            spec[wide] = fsdp
        return assemble(spec)

    if last in ("embed", "unembed"):
        v, d = tshape
        spec = [None, None]
        if _fits(v, axis_size(mesh, "model")):
            spec[0] = "model"
            if _fits(d, axis_size(mesh, fsdp)):
                spec[1] = fsdp
        elif _fits(d, axis_size(mesh, "model")):
            spec[1] = "model"
        return assemble(spec)

    if last == "w" and tnd == 4:  # conv kernels (kh,kw,cin,cout): replicate
        return assemble([None] * 4)

    if tnd == 2:
        d_in, d_out = tshape
        model_dim = 0 if last in _OUT_PROJ_NAMES else 1
        fsdp_dim = 1 - model_dim
        spec = [None, None]
        if _fits(tshape[model_dim], axis_size(mesh, "model")):
            spec[model_dim] = "model"
        if _fits(tshape[fsdp_dim], axis_size(mesh, fsdp)):
            spec[fsdp_dim] = fsdp
        return assemble(spec)

    return assemble([None] * tnd)


def _tree_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def params_shardings(params_shapes, mesh: Mesh):
    """pytree of ShapeDtypeStruct -> pytree of NamedSharding."""
    paths, leaves, treedef = _tree_with_paths(params_shapes)
    out = [NamedSharding(mesh, param_pspec(p, l.shape, mesh))
           for p, l in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ----------------------------------------------------------------------
# Activation / batch / cache sharding rules
# ----------------------------------------------------------------------
def batch_pspec(name: str, shape: tuple, mesh: Mesh,
                microbatched: bool = False) -> P:
    dp = data_axes(mesh)
    lead = [None] if microbatched else []
    body = list(shape[1:] if microbatched else shape)
    spec: list = [None] * len(body)
    if body and _fits(body[0], axis_size(mesh, dp)):
        spec[0] = dp
    return P(*(lead + spec))


def cache_pspec(path: str, shape: tuple, mesh: Mesh, long_context: bool
                ) -> P:
    """KV/state cache sharding.  Dense KV (G,B,T,H,D): batch on data,
    sequence on model (long_500k: sequence on (data,model) since B=1).
    MLA ckv (G,B,T,C): batch on data.  Mamba state (G,B,H,P,N): batch on
    data, heads on model.  Conv state (G,B,W,C): batch data, C model."""
    dp = data_axes(mesh)
    last = path.split("/")[-1]
    nd = len(shape)
    spec: list = [None] * nd
    if last == "pos" or nd <= 1:
        return P(*spec)
    # identify batch dim: stacked caches are (G, B, ...); whisper cross
    # kv is (L, B, F, H, D) — batch is dim 1 in both.
    bdim = 1
    if long_context:
        seq_axes = tuple(dp) + ("model",)
        if last in ("k", "v", "ckv", "kr") and nd >= 3:
            if _fits(shape[2], axis_size(mesh, seq_axes)):
                spec[2] = seq_axes
                return P(*spec)
    if _fits(shape[bdim], axis_size(mesh, dp)):
        spec[bdim] = dp
    if last in ("k", "v") and nd == 5:
        if _fits(shape[3], axis_size(mesh, "model")):
            spec[3] = "model"          # kv heads (whisper MHA: 20 -> no)
        elif _fits(shape[2], axis_size(mesh, "model")):
            spec[2] = "model"          # sequence on model
    elif last == "state" and nd == 5:
        if _fits(shape[2], axis_size(mesh, "model")):
            spec[2] = "model"          # ssm heads
    elif last == "conv" and nd == 4:
        if _fits(shape[3], axis_size(mesh, "model")):
            spec[3] = "model"          # conv channels
    return P(*spec)


def cache_shardings(cache_shapes, mesh: Mesh, long_context: bool = False):
    paths, leaves, treedef = _tree_with_paths(cache_shapes)
    out = [NamedSharding(mesh,
                         cache_pspec(p, l.shape, mesh, long_context))
           for p, l in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_shapes, mesh: Mesh, microbatched: bool = False):
    paths, leaves, treedef = _tree_with_paths(batch_shapes)
    out = [NamedSharding(mesh,
                         batch_pspec(p, l.shape, mesh, microbatched))
           for p, l in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ----------------------------------------------------------------------
# Batched-sweep (library-axis) sharding — DESIGN.md §2.4
# ----------------------------------------------------------------------
def sweep_mesh(max_devices: Optional[int] = None) -> Mesh:
    """1-D mesh over the local devices for sharding a resilience
    sweep's *candidate* (multiplier-bank) axis.  Unlike the training
    mesh this is shape-agnostic: every device is data-parallel over
    bank lanes."""
    devs = jax.devices()
    if max_devices is not None:
        devs = devs[:max_devices]
    return Mesh(np.asarray(devs), ("sweep",))


def bank_pspec(n_banks: int, mesh: Mesh, axis: str = "sweep") -> P:
    """PartitionSpec for a ``(n_banks, 256, 256)`` LutBank (or any
    candidate-leading array): shard the leading axis across ``axis``
    when divisible, else replicate — same divisibility policy as the
    parameter rules above."""
    if axis in mesh.axis_names and _fits(n_banks, axis_size(mesh, axis)):
        return P(axis)
    return P()


def bank_sharding(n_banks: int, mesh: Optional[Mesh] = None,
                  axis: str = "sweep") -> NamedSharding:
    """Sharding for the batched resilience engine's bank axis; pass the
    result as ``bank_eval(..., sharding=...)`` /
    ``explore(..., sharding=...)``.  With a default 1-D ``sweep_mesh``
    each device evaluates ``n_banks / n_devices`` multipliers of the
    sweep; XLA partitions the whole vmapped program along the lane
    axis, so activations and per-lane accuracies never materialize on
    one device."""
    mesh = mesh if mesh is not None else sweep_mesh()
    return NamedSharding(mesh, bank_pspec(n_banks, mesh, axis))


def lane_sharding(bank_sh: NamedSharding) -> NamedSharding:
    """Sharding for a wide bank's per-lane aux arrays — the ``(n,)``
    ``bit_widths``/``wide`` selectors a mixed-width bank carries next
    to its ``(n, 256, 256)`` tile LUTs (DESIGN.md §2.6): same mesh,
    leading (lane) axis only.  ``bank_eval`` derives this itself from
    the bank sharding you pass; this helper is for callers placing the
    aux arrays manually."""
    lead = bank_sh.spec[0] if len(bank_sh.spec) else None
    return NamedSharding(bank_sh.mesh, P(lead))


def slot_sharding(n_slots: int, mesh: Optional[Mesh] = None,
                  axis: str = "sweep") -> NamedSharding:
    """Sharding for the continuous-batching engine's *slot* (request
    lane) axis — the leading dim of its per-slot state (tokens,
    lengths, assignment rows, dense cache store).  Pass as
    ``ContinuousEngine(..., sharding=...)``: the LUT bank and block
    pools stay replicated (every lane gathers from them) while the
    slot axis — and therefore the whole vmapped mixed-policy decode
    step — splits across devices, each decoding
    ``n_slots / n_devices`` in-flight requests.  Same divisibility
    policy as ``bank_sharding``: non-divisible counts replicate."""
    mesh = mesh if mesh is not None else sweep_mesh()
    return NamedSharding(mesh, bank_pspec(n_slots, mesh, axis))


def leading_axis_sharding(sharding: NamedSharding,
                          rank: int) -> NamedSharding:
    """Extend a 1-D (leading-axis) sharding to a rank-``rank`` leaf:
    same mesh and leading spec, trailing dims replicated.  Used by the
    serve engine to place each per-slot state leaf — (n_slots,),
    (n_slots, n_layers), (n_slots, *cache_dims) — consistently from
    one ``slot_sharding``."""
    lead = sharding.spec[0] if len(sharding.spec) else None
    return NamedSharding(sharding.mesh,
                         P(*([lead] + [None] * (rank - 1))))


def pop_sharding(n_pop: int, mesh: Optional[Mesh] = None,
                 axis: str = "sweep") -> NamedSharding:
    """Sharding for the population-evolution engine's *candidate* axis —
    the leading dim of the stacked netlist genome arrays
    ``(n_pop, n_nodes)`` a ``PopEvaluator`` scores per generation
    (DESIGN.md §2.9).  Pass as ``PopEvaluator(..., sharding=...)`` /
    ``evolve_ladder(..., sharding=...)``: the input planes and exact
    values stay replicated (every candidate simulates the same
    vectors) while the candidate axis — and therefore the whole
    population bitsim + on-device error reduction — splits across
    devices via shard_map, each scoring ``n_pop / n_devices``
    offspring.  Same divisibility policy as ``bank_sharding``:
    non-divisible counts replicate (the evaluator pads populations to
    a divisible multiple before applying it)."""
    mesh = mesh if mesh is not None else sweep_mesh()
    return NamedSharding(mesh, bank_pspec(n_pop, mesh, axis))


def policy_sharding(n_policies: int, mesh: Optional[Mesh] = None,
                    axis: str = "sweep") -> NamedSharding:
    """Sharding for the heterogeneous engine's *policy* axis — the
    leading dim of a ``PolicyBank`` assignment matrix
    ``(n_policies, n_layers)``.  Pass as
    ``policy_bank_eval(..., assign_sharding=...)`` /
    ``explore_heterogeneous(..., assign_sharding=...)``: the LUT bank
    stays replicated (every lane gathers from it) while the assignment
    rows — and therefore the whole vmapped per-policy program — split
    across devices, each verifying ``n_policies / n_devices``
    candidate compositions.  Same divisibility policy as
    ``bank_sharding``: non-divisible counts replicate."""
    mesh = mesh if mesh is not None else sweep_mesh()
    return NamedSharding(mesh, bank_pspec(n_policies, mesh, axis))


def module_sharding(n_assignments: int, mesh: Optional[Mesh] = None,
                    axis: str = "sweep") -> NamedSharding:
    """Sharding for the module-axis profiler's *assignment* axis — the
    leading dim of a lowered module-assignment matrix (DESIGN.md
    §2.12).  A module-keyed sweep lowers onto a ``PolicyBank`` whose
    rows are (family x multiplier) grid cells padded with the exact
    LUT, so the axis to split is exactly the policy axis: the LUT bank
    stays replicated while each device evaluates
    ``n_assignments / n_devices`` module rows.  Pass as
    ``policy_bank_eval(..., assign_sharding=...)`` /
    ``profile_architecture(..., assign_sharding=...)``.  Same
    divisibility policy as ``bank_sharding``: non-divisible counts
    replicate."""
    mesh = mesh if mesh is not None else sweep_mesh()
    return NamedSharding(mesh, bank_pspec(n_assignments, mesh, axis))
