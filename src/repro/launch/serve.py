"""Serving CLI: batched generation with the approximate-multiplier
datapath.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --mode lowrank --multiplier auto

``--continuous`` runs the same workload through the multi-tenant
``ContinuousEngine`` (paged KV + mixed-policy banked decode,
DESIGN.md §2.8) instead of one static batch.

Throughput reporting separates compile from steady state: a warmup
``generate`` (same shapes) triggers all prefill/decode traces first,
then the timed run reports steady-state decode tok/s alongside the
end-to-end time (which on the first-ever call would be compile-bound
and meaningless as a throughput number).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.steps import serve_policy, train_policy
from repro.models.registry import input_extras, model_fns
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mode", default="lowrank",
                    choices=("bf16", "int8", "lut", "lowrank"))
    ap.add_argument("--multiplier", default="auto")
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--policy-json", default=None,
                    help="path to a serialized ApproxPolicy (overrides "
                         "--mode/--multiplier/--rank)")
    ap.add_argument("--continuous", action="store_true",
                    help="serve through the continuous-batching "
                         "mixed-policy engine (forces --mode lut)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the compile warmup (end-to-end time "
                         "then includes tracing)")
    ap.add_argument("--compile-cache", action="store_true",
                    help="enable the persistent JAX compilation cache "
                         "so serve restarts skip XLA recompiles "
                         "(DESIGN.md §2.10)")
    args = ap.parse_args()
    if args.compile_cache:
        from repro.launch.compile_cache import enable_compile_cache
        print(f"compile cache: {enable_compile_cache()}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extras = input_extras(cfg, args.batch) or None

    if args.continuous:
        _serve_continuous(cfg, params, prompts, args)
        return

    if args.policy_json:
        import json
        from repro.approx.layers import ApproxPolicy
        with open(args.policy_json) as f:
            policy = ApproxPolicy.from_json(json.load(f))
    else:
        policy = (train_policy() if args.mode == "bf16"
                  else serve_policy(args.multiplier, args.mode, args.rank))
    engine = Engine(cfg, params, policy)
    serve_cfg = ServeConfig(max_new_tokens=args.max_new)

    if not args.no_warmup:
        # warmup: same shapes -> all prefill/decode traces compile here
        # (both cache lengths: the timed run's and the prefill-only's)
        t0 = time.time()
        engine.generate(prompts, serve_cfg, extras=extras)
        engine.generate(prompts, ServeConfig(max_new_tokens=1),
                        extras=extras)
        print(f"[serve] warmup (compile) {time.time() - t0:.2f}s")

    t0 = time.time()
    out = engine.generate(prompts, serve_cfg, extras=extras)
    e2e = time.time() - t0
    # steady-state decode rate: subtract the prefill-only time (a
    # max_new=1 generate) from the full run, leaving the decode loop
    t0 = time.time()
    engine.generate(prompts, ServeConfig(max_new_tokens=1),
                    extras=extras)
    prefill_s = time.time() - t0
    n_decode_toks = args.batch * max(args.max_new - 1, 1)
    decode_s = max(e2e - prefill_s, 1e-9)
    print(f"[serve] {args.arch} mode={args.mode} generated {out.shape} "
          f"tokens; end-to-end {e2e:.2f}s "
          f"({args.batch * args.max_new / e2e:.1f} tok/s), "
          f"steady-state decode "
          f"{n_decode_toks / decode_s:.1f} tok/s")
    print(out[:2])


def _serve_continuous(cfg, params, prompts, args) -> None:
    n_slots = min(args.batch, 8)
    capacity = args.prompt_len + args.max_new + \
        (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    engine = ContinuousEngine(cfg, params, n_slots=n_slots,
                              capacity=capacity)
    serve_cfg = ServeConfig(max_new_tokens=args.max_new)

    if not args.no_warmup:
        t0 = time.time()
        engine.submit(prompts[0], serve_cfg)
        engine.run()
        print(f"[serve] warmup (compile) {time.time() - t0:.2f}s "
              f"traces={engine.trace_counts}")

    t0 = time.time()
    rids = [engine.submit(row, serve_cfg) for row in prompts]
    out = engine.run()
    e2e = time.time() - t0
    out = {r: out[r] for r in rids}     # drop the warmup request
    n_toks = sum(len(t) for t in out.values())
    print(f"[serve] {args.arch} continuous n_slots={n_slots} "
          f"generated {n_toks} tokens; end-to-end {e2e:.2f}s "
          f"({n_toks / e2e:.1f} tok/s), "
          f"decode steps={engine.step_count} "
          f"traces={engine.trace_counts}")
    first = next(iter(out.values()))
    print(first)


if __name__ == "__main__":
    main()
