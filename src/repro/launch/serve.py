"""Serving CLI: batched generation with the approximate-multiplier
datapath.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --mode lowrank --multiplier auto
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.steps import serve_policy, train_policy
from repro.models.registry import model_fns
from repro.serve.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mode", default="lowrank",
                    choices=("bf16", "int8", "lut", "lowrank"))
    ap.add_argument("--multiplier", default="auto")
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--policy-json", default=None,
                    help="path to a serialized ApproxPolicy (overrides "
                         "--mode/--multiplier/--rank)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(0), cfg)
    if args.policy_json:
        import json
        from repro.approx.layers import ApproxPolicy
        with open(args.policy_json) as f:
            policy = ApproxPolicy.from_json(json.load(f))
    else:
        policy = (train_policy() if args.mode == "bf16"
                  else serve_policy(args.multiplier, args.mode, args.rank))
    engine = Engine(cfg, params, policy)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = np.full(
            (args.batch, cfg.enc_frames, cfg.d_model), 0.1, np.float32)
    if cfg.family == "vlm":
        extras["img_embeds"] = np.full(
            (args.batch, cfg.n_img_tokens, cfg.d_model), 0.1, np.float32)
    t0 = time.time()
    out = engine.generate(prompts, ServeConfig(max_new_tokens=args.max_new),
                          extras=extras or None)
    dt = time.time() - t0
    print(f"[serve] {args.arch} mode={args.mode} generated "
          f"{out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print(out[:2])


if __name__ == "__main__":
    main()
