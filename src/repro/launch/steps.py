"""Step-function builders for every (arch x shape) cell.

train_4k lowers ``train_step`` (bf16 exact compute — the paper trains in
float); prefill/decode shapes lower serve steps with the quantized
approximate-multiplier backend (the accelerator being modeled), using
the low-rank MXU emulation by default (DESIGN.md §4.2).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import ApproxPolicy
from repro.approx.specs import BackendSpec
from repro.configs import get_config
from repro.configs.shapes import SHAPES, ShapeSpec, batch_specs
from repro.models.common import LMConfig
from repro.models.registry import model_fns
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig


def train_policy() -> ApproxPolicy:
    return ApproxPolicy(default=BackendSpec(mode="bf16").materialize())


def pick_case_multiplier(library=None) -> str:
    """Deterministic pick: Pareto(power x MAE) multiplier nearest 75%
    relative power — the paper's 'interesting' regime (Table II).
    Memoized for the default library so repeated serve_policy('auto')
    calls don't rescan the whole library."""
    if library is None:
        return _pick_default_case_multiplier()
    return _pick_case_multiplier(library)


@functools.lru_cache(maxsize=1)
def _pick_default_case_multiplier() -> str:
    from repro.core.library import get_default_library
    return _pick_case_multiplier(get_default_library())


def _pick_case_multiplier(lib) -> str:
    front = lib.pareto_front("multiplier", 8, "mae")
    cands = [e for e in front if e.source != "exact"]
    if not cands:
        return "mul8u_exact"
    return min(cands, key=lambda e: abs(e.rel_power - 0.75)).name


def serve_policy(multiplier: str = "auto", mode: str = "lowrank",
                 rank: Optional[int] = 4) -> ApproxPolicy:
    """rank=4 default: decomposition MAE is already well below the
    emulated circuit's own MAE for every case-study multiplier (see
    benchmarks/rank_analysis), while weight-side table traffic stays
    4x instead of up-to-16x.  EXPERIMENTS.md §Perf iterates on this."""
    if mode in ("bf16", "int8"):
        return ApproxPolicy(default=BackendSpec(mode=mode).materialize())
    name = pick_case_multiplier() if multiplier == "auto" else multiplier
    # spec materialization is LRU-cached per (library, spec): repeated
    # cells with the same serve config share one backend object (and
    # therefore one trace) without a bespoke cache here.
    spec = BackendSpec(mode=mode, multiplier=name, rank=rank)
    return ApproxPolicy(default=spec.materialize())


@dataclass
class CellSpec:
    arch: str
    shape: ShapeSpec
    cfg: LMConfig
    kind: str                  # train | prefill | decode
    step_fn: Callable
    args_sds: tuple            # ShapeDtypeStructs for .lower(*args)
    donate: tuple
    microbatches: int = 1


def _microbatches(cfg: LMConfig, shape: ShapeSpec, dp: int) -> int:
    n = max(1, shape.global_batch // dp)
    return n


def _mb_specs(specs: dict, n_mb: int) -> dict:
    out = {}
    for k, v in specs.items():
        b = v.shape[0]
        assert b % n_mb == 0, (k, v.shape, n_mb)
        out[k] = jax.ShapeDtypeStruct((n_mb, b // n_mb) + v.shape[1:],
                                      v.dtype)
    return out


def apply_overrides(cfg: LMConfig, overrides) -> LMConfig:
    if not overrides:
        return cfg
    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            typed[k] = v in (True, "true", "True", "1")
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    return dataclasses.replace(cfg, **typed)


def build_cell(arch: str, shape_name: str, dp_size: int,
               serve_mult: str = "auto",
               serve_mode: str = "lowrank",
               overrides=None, serve_rank: Optional[int] = 4) -> CellSpec:
    cfg = apply_overrides(get_config(arch), overrides)
    shape = SHAPES[shape_name]
    fns = model_fns(cfg)

    prepared = serve_mode == "lowrank_prepared"
    if prepared:
        serve_mode = "lowrank"
    if prepared and shape.kind != "train":
        from repro.approx.backend import prepare_tree
        be = serve_policy(serve_mult, "lowrank", serve_rank).default

        def init_prepared(key):
            return prepare_tree(fns.init_params(key, cfg), be)

        params_sds = jax.eval_shape(init_prepared, jax.random.PRNGKey(0))
    else:
        params_sds = jax.eval_shape(
            partial(fns.init_params, cfg=cfg), jax.random.PRNGKey(0))

    if shape.kind == "train":
        policy = train_policy()
        opt_cfg = OptimizerConfig()
        n_mb = _microbatches(cfg, shape, dp_size)

        def loss_fn(params, mb):
            return fns.forward_train(params, mb, cfg, policy)

        step = make_train_step(loss_fn, opt_cfg, microbatches=n_mb)
        from repro.train.optimizer import init_opt_state
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        bspecs = _mb_specs(batch_specs(cfg, shape), n_mb)
        return CellSpec(arch, shape, cfg, "train", step,
                        (params_sds, opt_sds, bspecs), donate=(0, 1),
                        microbatches=n_mb)

    policy = serve_policy(serve_mult, serve_mode, serve_rank)
    if shape.kind == "prefill":
        def prefill_step(params, batch, cache):
            return fns.forward_prefill(params, batch, cache, cfg, policy)

        cache_sds = jax.eval_shape(
            partial(fns.init_cache, cfg, shape.global_batch,
                    shape.seq_len))
        bspecs = batch_specs(cfg, shape)
        return CellSpec(arch, shape, cfg, "prefill", prefill_step,
                        (params_sds, bspecs, cache_sds), donate=(2,))

    # decode: one token against a cache of seq_len
    def decode_step(params, token, cache):
        return fns.forward_decode(params, token, cache, cfg, policy)

    cache_sds = jax.eval_shape(
        partial(fns.init_cache, cfg, shape.global_batch, shape.seq_len))
    bspecs = batch_specs(cfg, shape)
    return CellSpec(arch, shape, cfg, "decode", decode_step,
                    (params_sds, bspecs["token"], cache_sds), donate=(2,))


# ----------------------------------------------------------------------
# Analysis probes: XLA's cost_analysis does not scale while-loop bodies
# by trip count, so scanned programs under-report FLOPs/bytes.  Fully
# unrolling 60-layer stacks is too slow to compile on one CPU core, so
# the roofline instead compiles UNROLLED SHALLOW variants at two depths
# (d1 = one block period, d2 = two periods) and extrapolates linearly —
# exact for depth-homogeneous stacks (every assigned arch repeats an
# identical block period):
#   step(L)    = fixed + per_period * (L / period)
#   per_period = probe(d2) - probe(d1);  fixed = probe(d1) - per_period
#   train      = n_microbatches * step(L) + optimizer_probe
# The scanned full-depth program remains the deliverable
# (compile success + memory_analysis).
# ----------------------------------------------------------------------
@dataclass
class ProbeSpec:
    name: str            # stack_d1 | stack_d2 | opt
    step_fn: Callable
    args_sds: tuple
    cell: "CellSpec"
    depth: int = 0       # layers in this probe (0 = n/a)


def _depth_cfg(cfg: LMConfig, n_layers: int) -> LMConfig:
    updates = dict(n_layers=n_layers, scan_unroll=True)
    if cfg.family == "encdec":
        # scale encoder proportionally so both stacks extrapolate
        frac = n_layers / cfg.n_layers
        updates["n_enc_layers"] = max(1, round(cfg.n_enc_layers * frac))
    return dataclasses.replace(cfg, **updates)


def build_probes(arch: str, shape_name: str, dp_size: int,
                 serve_mult: str = "auto",
                 serve_mode: str = "lowrank",
                 overrides=None,
                 serve_rank: Optional[int] = 4) -> list[ProbeSpec]:
    from repro.models.decoder import block_pattern
    base_cfg = apply_overrides(get_config(arch), overrides)
    shape = SHAPES[shape_name]
    period = (len(block_pattern(base_cfg))
              if base_cfg.family != "encdec" else 1)
    d1, d2 = period, 2 * period
    probes: list[ProbeSpec] = []

    prepared = serve_mode == "lowrank_prepared"
    if prepared:
        serve_mode = "lowrank"

    for name, depth in (("stack_d1", d1), ("stack_d2", d2)):
        cfg = _depth_cfg(base_cfg, depth)
        fns = model_fns(cfg)
        if prepared and shape.kind != "train":
            from repro.approx.backend import prepare_tree
            be = serve_policy(serve_mult, "lowrank", serve_rank).default
            params_sds = jax.eval_shape(
                lambda key, _f=fns, _c=cfg, _b=be: prepare_tree(
                    _f.init_params(key, _c), _b), jax.random.PRNGKey(0))
        else:
            params_sds = jax.eval_shape(
                partial(fns.init_params, cfg=cfg), jax.random.PRNGKey(0))
        if shape.kind == "train":
            policy = train_policy()
            n_mb = _microbatches(cfg, shape, dp_size)

            def fwdbwd(params, mb, _fns=fns, _cfg=cfg, _policy=policy):
                return jax.value_and_grad(
                    lambda p, b: _fns.forward_train(p, b, _cfg, _policy)
                )(params, mb)

            mb_specs = {k: jax.ShapeDtypeStruct(
                (v.shape[0] // n_mb,) + v.shape[1:], v.dtype)
                for k, v in batch_specs(cfg, shape).items()}
            cell = CellSpec(arch, shape, cfg, "train", fwdbwd,
                            (params_sds, mb_specs), donate=(),
                            microbatches=n_mb)
            probes.append(ProbeSpec(name, fwdbwd, (params_sds, mb_specs),
                                    cell, depth))
        else:
            policy = serve_policy(serve_mult, serve_mode, serve_rank)
            if shape.kind == "prefill":
                def serve_fn(params, batch, cache, _fns=fns, _cfg=cfg,
                             _policy=policy):
                    return _fns.forward_prefill(params, batch, cache,
                                                _cfg, _policy)
            else:
                def serve_fn(params, token, cache, _fns=fns, _cfg=cfg,
                             _policy=policy):
                    return _fns.forward_decode(params, token, cache,
                                               _cfg, _policy)
            cache_sds = jax.eval_shape(
                partial(fns.init_cache, cfg, shape.global_batch,
                        shape.seq_len))
            bspecs = batch_specs(cfg, shape)
            args = ((params_sds, bspecs, cache_sds)
                    if shape.kind == "prefill"
                    else (params_sds, bspecs["token"], cache_sds))
            cell = CellSpec(arch, shape, cfg, shape.kind, serve_fn, args,
                            donate=())
            probes.append(ProbeSpec(name, serve_fn, args, cell, depth))

    if shape.kind == "train":
        # optimizer probe at FULL depth (single cheap pass over params)
        cfg = base_cfg
        fns = model_fns(cfg)
        params_sds = jax.eval_shape(
            partial(fns.init_params, cfg=cfg), jax.random.PRNGKey(0))
        from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                           init_opt_state)
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        opt_cfg = OptimizerConfig()

        def opt_step(params, grads, opt_state):
            return adamw_update(params, grads, opt_state, opt_cfg)

        grads_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
            params_sds)
        opt_cell = CellSpec(arch, shape, cfg, "opt", opt_step,
                            (params_sds, grads_sds, opt_sds), donate=())
        probes.append(ProbeSpec("opt", opt_step,
                                (params_sds, grads_sds, opt_sds),
                                opt_cell, 0))
    return probes


# ----------------------------------------------------------------------
# Model-FLOPs accounting (roofline "useful compute" numerator)
# ----------------------------------------------------------------------
def param_count(params_sds, cfg: LMConfig) -> tuple[int, int]:
    """(total, active) parameter counts; active scales expert leaves by
    top_k/n_experts and excludes embedding/unembedding tables.
    Prepared-weight trees count the logical (K,N) weight once — the
    R-stacked tables are an emulation artifact, not model parameters."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params_sds)
    total = active = 0
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        last = key.split("/")[-1]
        if last in ("colsum", "w_scale", "w_zp"):
            continue
        if last == "tabs":   # (..., R, K, N) -> logical K*N weight
            n = int(np.prod(leaf.shape[:-3])) * int(
                np.prod(leaf.shape[-2:]))
            key = "/".join(key.split("/")[:-1])  # classify by parent
        else:
            n = int(np.prod(leaf.shape))
        total += n
        if key.split("/")[-1] in ("embed", "unembed"):
            continue
        is_expert = ("ffn_" in key or "moe" in key) and len(leaf.shape) >= 3 \
            and cfg.n_experts > 0 and leaf.shape[-3] == cfg.n_experts
        if is_expert:
            active += int(n * cfg.top_k / cfg.n_experts)
        else:
            active += n
    return total, active


def model_flops(cell: CellSpec, params_sds) -> float:
    """6·N_active·tokens for training, 2·N_active·tokens for serving."""
    _, n_active = param_count(params_sds, cell.cfg)
    if cell.kind == "train":
        tokens = cell.shape.global_batch * cell.shape.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.shape.global_batch * cell.shape.seq_len
        return 2.0 * n_active * tokens
    tokens = cell.shape.global_batch * 1
    return 2.0 * n_active * tokens
