"""End-to-end training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 50 --batch 8 --seq 128

On this CPU container use ``--reduced`` (smoke-scale config).  On a real
TPU cluster the same entry point drives the full config on the
production mesh (``--mesh single_pod|multi_pod``); the loop, data
pipeline, checkpointing and fault handling are identical.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.data.synthetic import token_stream
from repro.launch.steps import train_policy
from repro.models.registry import model_fns
from repro.train.loop import Trainer, TrainLoopConfig
from repro.train.optimizer import OptimizerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    fns = model_fns(cfg)
    policy = train_policy()

    params = fns.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {args.arch} ({'reduced' if args.reduced else 'full'}) "
          f"params={n_params / 1e6:.1f}M")

    def loss_fn(p, batch):
        return fns.forward_train(p, batch, cfg, policy)

    def batches():
        step = 0
        extras = {}
        while True:
            toks, tgts = token_stream(cfg.vocab, args.batch, args.seq,
                                      step)
            batch = {"tokens": jnp.asarray(toks),
                     "targets": jnp.asarray(tgts)}
            if cfg.family == "vlm":
                batch["img_embeds"] = jnp.full(
                    (args.batch, cfg.n_img_tokens, cfg.d_model), 0.1,
                    jnp.float32)
            if cfg.family == "encdec":
                batch["frames"] = jnp.full(
                    (args.batch, cfg.enc_frames, cfg.d_model), 0.1,
                    jnp.float32)
            if args.microbatches > 1:
                batch = jax.tree.map(
                    lambda x: x.reshape((args.microbatches,
                                         x.shape[0] // args.microbatches)
                                        + x.shape[1:]), batch)
            yield batch
            step += 1

    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, microbatches=args.microbatches,
        ckpt_every=max(10, args.steps // 5), ckpt_dir=args.ckpt_dir,
        log_every=5)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=args.steps // 10,
                              total_steps=args.steps)
    trainer = Trainer(loss_fn, params, opt_cfg, loop_cfg)
    if args.resume and trainer.maybe_resume():
        print(f"[train] resumed from step {trainer.step}")
    hist = trainer.run(batches())
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    print(f"[train] loss {first:.4f} -> {last:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
