"""Shared model substrate: config, norms, RoPE, GQA attention, FFN,
chunked cross-entropy, parameter init.

All projection matmuls route through an ``ApproxPolicy`` so any layer
can run on the emulated approximate-multiplier datapath (the paper's
technique as a first-class feature).  Attention score/value einsums,
norms and routers stay exact, mirroring the paper's scope (multipliers
inside convolution/projection MACs only).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import ApproxPolicy, EXACT_POLICY


@dataclass(frozen=True)
class LMConfig:
    name: str
    family: str              # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    qk_norm: bool = False
    act: str = "silu"        # silu | relu2 | gelu
    use_rope: bool = True    # whisper: sinusoidal absolute instead
    attn_impl: str = "vanilla"   # vanilla | chunked (flash-style)
    kv_chunk: int = 1024         # KV block for chunked attention
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1       # MoE FFN every k-th layer (jamba: 2)
    moe_d_ff: int = 0        # expert hidden dim (deepseek: 1536)
    capacity_factor: float = 1.25
    moe_blocks: int = 0      # >1: block-local dispatch (no global sort
                             # collectives; set to the DP shard count)
    # --- MLA (deepseek) ---
    use_mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- SSM (mamba2 / jamba) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    attn_period: int = 0     # hybrid: 1 attention layer per this many
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_frames: int = 1500
    # --- vlm (llava) ---
    n_img_tokens: int = 0
    # --- training ---
    remat: bool = True
    loss_chunk: int = 1024
    dtype: Any = jnp.bfloat16
    # analysis mode: unroll internal scans so compiled.cost_analysis()
    # counts every iteration (XLA does not scale while-loop bodies by
    # trip count) — used by the dry-run roofline probes only.
    scan_unroll: bool = False

    @property
    def kv_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def reduced(self, **overrides) -> "LMConfig":
        """Smoke-test-sized variant of the same family."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=min(self.d_model, 64),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 128) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=min(self.head_dim, 16),
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            moe_d_ff=min(self.moe_d_ff, 32) if self.moe_d_ff else 0,
            kv_lora=min(self.kv_lora, 32),
            q_lora=min(self.q_lora, 32),
            rope_head_dim=min(self.rope_head_dim, 8),
            v_head_dim=min(self.v_head_dim, 16),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=min(self.ssm_head_dim, 8),
            ssm_chunk=min(self.ssm_chunk, 16),
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_frames=min(self.enc_frames, 24),
            n_img_tokens=min(self.n_img_tokens, 8),
            loss_chunk=64,
            remat=False,
            dtype=jnp.float32,
            # no token dropping in smoke tests: keeps prefill+decode
            # bit-consistent with the single-pass forward
            capacity_factor=8.0,
        )
        if self.attn_period:
            small["attn_period"] = min(self.attn_period,
                                       small["n_layers"])
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ----------------------------------------------------------------------
# Sharding hints (ambient-mesh aware; no-ops in single-device tests)
# ----------------------------------------------------------------------
def _ambient_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def hint_batch(x: jax.Array, dim: int = 0) -> jax.Array:
    """Constrain dim to the data-parallel axes (('pod','data') ∩ mesh).
    Anchors activation sharding so GSPMD doesn't replicate the batch —
    e.g. after vocab-sharded embedding gathers."""
    m = _ambient_mesh()
    if m is None:
        return x
    from jax.sharding import PartitionSpec
    axes = tuple(a for a in ("pod", "data") if a in m.axis_names)
    if not axes:
        return x
    size = 1
    for a in axes:
        size *= m.shape[a]
    if x.shape[dim] % size != 0:
        return x
    spec = [None] * x.ndim
    spec[dim] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def hint_axis(x: jax.Array, dim: int, axis: str = "model") -> jax.Array:
    """Constrain one dim to a named mesh axis (e.g. experts on 'model')."""
    return hint_spec(x, {dim: axis})


def hint_spec(x: jax.Array, dims: dict) -> jax.Array:
    """Constrain several dims at once: {dim: 'model' | 'batch'}.
    'batch' expands to the data-parallel axes.  Dims that don't divide
    are dropped; no-op without an ambient mesh."""
    m = _ambient_mesh()
    if m is None:
        return x
    from jax.sharding import PartitionSpec
    spec = [None] * x.ndim
    any_set = False
    for dim, axis in dims.items():
        if axis == "batch":
            axes = tuple(a for a in ("pod", "data") if a in m.axis_names)
            if not axes:
                continue
            size = 1
            for a in axes:
                size *= m.shape[a]
            if x.shape[dim] % size == 0:
                spec[dim] = axes if len(axes) > 1 else axes[0]
                any_set = True
        elif axis in m.axis_names and x.shape[dim] % m.shape[axis] == 0:
            spec[dim] = axis
            any_set = True
    if not any_set:
        return x
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


# ----------------------------------------------------------------------
# Initialization
# ----------------------------------------------------------------------
def dense_init(key, shape, scale: Optional[float] = None) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * s)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# ----------------------------------------------------------------------
# Norms / activations / RoPE
# ----------------------------------------------------------------------
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * gamma).astype(x.dtype)


def layer_norm(x, gamma, beta, eps):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "relu2":  # squared ReLU (nemotron-4)
        r = jax.nn.relu(x)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def rope_tables(positions: jax.Array, dim: int, theta: float
                ) -> tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 -> cos/sin (..., dim/2) f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B,S,H,D); cos/sin: (S,D/2) or (B,S,D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos_ = cos[None, :, None, :]
        sin_ = sin[None, :, None, :]
    else:
        cos_ = cos[:, :, None, :]
        sin_ = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos_ - x2 * sin_, x2 * cos_ + x1 * sin_], axis=-1
    ).astype(x.dtype)


# ----------------------------------------------------------------------
# Attention (GQA, optional qk-norm / bias, optional KV cache)
# ----------------------------------------------------------------------
def init_attention(key, cfg: LMConfig) -> dict:
    k = split_keys(key, ["wq", "wk", "wv", "wo", "bq", "bk", "bv",
                         "qnorm", "knorm"])
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(k["wq"], (d, h * hd)),
        "wk": dense_init(k["wk"], (d, hk * hd)),
        "wv": dense_init(k["wv"], (d, hk * hd)),
        "wo": dense_init(k["wo"], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hk * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hk * hd,), jnp.float32)
    if cfg.qk_norm:
        p["qnorm"] = jnp.ones((hd,), jnp.float32)
        p["knorm"] = jnp.ones((hd,), jnp.float32)
    return p


def _chunked_grouped_attention(q, k, v, q_pos0, t_valid, kv_chunk: int,
                               unroll: bool = False) -> jax.Array:
    """Flash-style online-softmax attention over KV chunks.

    q: (B,S,H,D); k/v: (B,T,Hkv,D); q_pos0: int32 scalar — absolute
    position of q[0] (causal mask: key_pos <= q_pos0 + i); t_valid:
    number of real keys (pad keys masked).  Never materializes the full
    (S,T) score matrix — working set is (S, kv_chunk) per step, which is
    what collapses the HBM roofline term for long sequences.
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    hk = k.shape[2]
    g = h // hk
    qg = (q.reshape(b, s, hk, g, d) / np.sqrt(d)).astype(q.dtype)

    c = min(kv_chunk, t)
    pad = (-t) % c
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = k.shape[1] // c
    k_c = jnp.moveaxis(k.reshape(b, nc, c, hk, d), 1, 0)
    v_c = jnp.moveaxis(v.reshape(b, nc, c, hk, d), 1, 0)
    idx0 = jnp.arange(nc, dtype=jnp.int32) * c

    q_pos = q_pos0 + jnp.arange(s, dtype=jnp.int32)          # (S,)
    m0 = jnp.full((b, hk, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hk, g, s), jnp.float32)
    a0 = jnp.zeros((b, hk, g, s, d), jnp.float32)

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, i0 = inputs
        scores = jnp.einsum("bskgd,bckd->bkgsc", qg, kc,
                            preferred_element_type=jnp.float32)
        key_pos = i0 + jnp.arange(c, dtype=jnp.int32)         # (C,)
        valid = (key_pos[None, :] <= q_pos[:, None]) \
            & (key_pos[None, :] < t_valid)                    # (S,C)
        scores = jnp.where(valid[None, None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgsc,bckd->bkgsd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k_c, v_c, idx0),
                                  unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]              # (b,hk,g,s,d)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, d)


def _grouped_attention(q, k, v, mask_bias) -> jax.Array:
    """q: (B,S,H,D) k/v: (B,T,Hkv,D); returns (B,S,H,D).
    Grouped einsum — never materializes repeated KV heads."""
    b, s, h, d = q.shape
    hk = k.shape[2]
    g = h // hk
    q = q.reshape(b, s, hk, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(d)
    scores = scores + mask_bias  # (.., S, T) broadcast
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, d)


def attention(params, x, cfg: LMConfig, policy: ApproxPolicy, *,
              positions: jax.Array, cache: Optional[dict] = None,
              layer_tag: str = "attn") -> tuple[jax.Array, Optional[dict]]:
    """x: (B,S,D). cache: {"k": (B,T,Hkv,D), "v": ..., "pos": int32 scalar}
    — decode appends at pos and attends over [0, pos].  Without cache,
    causal self-attention over x."""
    b, s, d = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = policy.matmul(f"{layer_tag}.wq", x, params["wq"])
    k = policy.matmul(f"{layer_tag}.wk", x, params["wk"])
    v = policy.matmul(f"{layer_tag}.wv", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hk, hd)
    v = v.reshape(b, s, hk, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["qnorm"], cfg.norm_eps)
        k = rms_norm(k, params["knorm"], cfg.norm_eps)
    if cfg.use_rope:
        cos, sin = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = q.astype(cfg.dtype)
    k = k.astype(cfg.dtype)
    v = v.astype(cfg.dtype)

    chunked = cfg.attn_impl == "chunked"
    if cache is None:
        # causal within x
        if chunked:
            out = _chunked_grouped_attention(
                q, k, v, jnp.zeros((), jnp.int32), jnp.int32(s),
                cfg.kv_chunk, unroll=cfg.scan_unroll)
        else:
            t = jnp.arange(s)
            mask = (t[None, :] <= t[:, None])
            bias = jnp.where(mask, 0.0, -1e30).astype(jnp.float32)
            out = _grouped_attention(q, k, v, bias)
        new_cache = None
    else:
        pos = cache["pos"]  # int32 scalar: #tokens already in cache
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v, (0, pos, 0, 0))
        if chunked:
            out = _chunked_grouped_attention(
                q, ck, cv, pos, pos + s, cfg.kv_chunk,
                unroll=cfg.scan_unroll)
        else:
            t_len = ck.shape[1]
            t = jnp.arange(t_len)
            valid = t[None, :] <= (pos + jnp.arange(s)[:, None])
            bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)
            out = _grouped_attention(q, ck, cv, bias)
        new_cache = {"k": ck, "v": cv, "pos": pos + s}

    out = out.reshape(b, s, h * hd)
    out = policy.matmul(f"{layer_tag}.wo", out, params["wo"])
    return out.astype(cfg.dtype), new_cache


def init_attention_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       cfg.dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       cfg.dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ----------------------------------------------------------------------
# FFN
# ----------------------------------------------------------------------
def init_ffn(key, cfg: LMConfig, d_ff: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    k = split_keys(key, ["wi", "wg", "wo"])
    p = {"wi": dense_init(k["wi"], (cfg.d_model, d_ff)),
         "wo": dense_init(k["wo"], (d_ff, cfg.d_model))}
    if cfg.act == "silu":  # gated
        p["wg"] = dense_init(k["wg"], (cfg.d_model, d_ff))
    return p


def ffn(params, x, cfg: LMConfig, policy: ApproxPolicy,
        layer_tag: str = "ffn") -> jax.Array:
    hidden = policy.matmul(f"{layer_tag}.wi", x, params["wi"])
    if cfg.act == "silu":
        gate = policy.matmul(f"{layer_tag}.wg", x, params["wg"])
        hidden = jax.nn.silu(gate) * hidden
    else:
        hidden = activation(hidden, cfg.act)
    return policy.matmul(f"{layer_tag}.wo", hidden.astype(cfg.dtype),
                         params["wo"]).astype(cfg.dtype)


# ----------------------------------------------------------------------
# Loss
# ----------------------------------------------------------------------
def chunked_cross_entropy(hidden: jax.Array, w_unembed: jax.Array,
                          targets: jax.Array, chunk: int,
                          mask: Optional[jax.Array] = None,
                          unroll: bool = False) -> jax.Array:
    """Mean CE over (B,S) without materializing (B,S,V) logits: the
    sequence is processed in checkpointed chunks (memory ~ B*chunk*V)."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask_full = jnp.pad(
            mask if mask is not None else jnp.ones((b, s), jnp.float32),
            ((0, 0), (0, pad)))
    else:
        mask_full = (mask if mask is not None
                     else jnp.ones((b, s), jnp.float32))
    n_chunks = hidden.shape[1] // chunk
    hidden_c = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    targets_c = targets.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    mask_c = mask_full.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h, t, m):
        logits = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                            w_unembed.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m), jnp.sum(m)

    def body(carry, xs):
        h, t, m = xs
        l, n = chunk_loss(h, t, m)
        return (carry[0] + l, carry[1] + n), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hidden_c, targets_c, mask_c), unroll=unroll)
    return total / jnp.maximum(count, 1.0)


def logits_from_hidden(hidden: jax.Array, w_unembed: jax.Array) -> jax.Array:
    return jnp.einsum("...d,vd->...v", hidden.astype(jnp.float32),
                      w_unembed.astype(jnp.float32))
