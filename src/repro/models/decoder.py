"""Generic decoder-only LM covering the dense / moe / ssm / hybrid / vlm
families via a per-period block pattern, with ``lax.scan`` over layer
groups (O(1) HLO size for 60-layer stacks) and optional remat.

Block pattern per family:
  dense  : period 1,  [attn + ffn]
  moe    : period 1,  [attn + moe]
  ssm    : period 1,  [mamba]
  hybrid : period = attn_period (jamba: 8), attention at slot
           ``period//2``, MoE on odd slots (1:7 attn:mamba, alternating
           MoE, per the Jamba paper)
  vlm    : dense pattern; image patch embeddings (stub frontend) are
           projected and prepended to the token embeddings.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import ApproxPolicy, EXACT_POLICY

from .common import (LMConfig, attention, chunked_cross_entropy, dense_init,
                     ffn, hint_batch, init_attention, init_attention_cache,
                     init_ffn, logits_from_hidden, rms_norm, split_keys)
from .mamba2 import init_mamba, init_mamba_cache, mamba_block
from .mla import init_mla, init_mla_cache, mla_attention
from .moe import init_moe, moe_ffn

AUX_LOSS_COEF = 0.01


def block_pattern(cfg: LMConfig) -> list[tuple[str, Optional[str]]]:
    """Returns [(mixer, ffn_kind)] per period slot."""
    if cfg.family == "ssm":
        return [("mamba", None)]
    if cfg.family == "hybrid":
        period = cfg.attn_period
        out = []
        for j in range(period):
            mixer = "attn" if j == period // 2 else "mamba"
            ffn_kind = "moe" if (j % 2 == 1 and cfg.n_experts > 0) else "ffn"
            out.append((mixer, ffn_kind))
        return out
    if cfg.family == "moe":
        return [("mla" if cfg.use_mla else "attn", "moe")]
    # dense / vlm / (decoder side of others)
    return [("mla" if cfg.use_mla else "attn", "ffn")]


def _init_mixer(key, kind: str, cfg: LMConfig) -> dict:
    if kind == "attn":
        return init_attention(key, cfg)
    if kind == "mla":
        return init_mla(key, cfg)
    if kind == "mamba":
        return init_mamba(key, cfg)
    raise ValueError(kind)


def _init_ffn(key, kind: Optional[str], cfg: LMConfig) -> Optional[dict]:
    if kind is None:
        return None
    if kind == "ffn":
        return init_ffn(key, cfg)
    if kind == "moe":
        return init_moe(key, cfg)
    raise ValueError(kind)


def init_params(key, cfg: LMConfig) -> dict:
    pattern = block_pattern(cfg)
    period = len(pattern)
    assert cfg.n_layers % period == 0, "n_layers must divide block period"
    n_groups = cfg.n_layers // period
    keys = split_keys(key, ["embed", "unembed", "img", "blocks", "norm"])

    params: dict[str, Any] = {
        "embed": dense_init(keys["embed"], (cfg.vocab, cfg.d_model),
                            scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    params["unembed"] = dense_init(keys["unembed"], (cfg.vocab, cfg.d_model),
                                   scale=0.02)
    if cfg.family == "vlm":
        params["img_proj"] = dense_init(keys["img"],
                                        (cfg.d_model, cfg.d_model))

    bkeys = jax.random.split(keys["blocks"], n_groups)

    def init_group(gk):
        sub = {}
        sks = jax.random.split(gk, 2 * period)
        for j, (mixer, ffn_kind) in enumerate(pattern):
            sub[f"mixer_{j}"] = _init_mixer(sks[2 * j], mixer, cfg)
            sub[f"norm1_{j}"] = jnp.ones((cfg.d_model,), jnp.float32)
            f = _init_ffn(sks[2 * j + 1], ffn_kind, cfg)
            if f is not None:
                sub[f"ffn_{j}"] = f
                sub[f"norm2_{j}"] = jnp.ones((cfg.d_model,), jnp.float32)
        return sub

    params["blocks"] = jax.vmap(init_group)(bkeys)
    return params


def _group_body(cfg: LMConfig, policy: ApproxPolicy, pattern):
    """Returns fn(h, positions, gparams, gcache) -> (h, aux, new_gcache)."""

    def body(h, positions, gparams, gcache):
        aux = jnp.zeros((), jnp.float32)
        new_cache: dict[str, Any] = {}
        for j, (mixer, ffn_kind) in enumerate(pattern):
            hin = rms_norm(h, gparams[f"norm1_{j}"], cfg.norm_eps)
            sub_cache = None if gcache is None else gcache.get(f"mixer_{j}")
            if mixer == "attn":
                y, nc = attention(gparams[f"mixer_{j}"], hin, cfg, policy,
                                  positions=positions, cache=sub_cache,
                                  layer_tag="attn")
            elif mixer == "mla":
                y, nc = mla_attention(gparams[f"mixer_{j}"], hin, cfg,
                                      policy, positions=positions,
                                      cache=sub_cache, layer_tag="mla")
            else:
                y, nc = mamba_block(gparams[f"mixer_{j}"], hin, cfg, policy,
                                    cache=sub_cache, layer_tag="mamba")
            if nc is not None:
                new_cache[f"mixer_{j}"] = nc
            h = h + y
            if ffn_kind is not None:
                hin = rms_norm(h, gparams[f"norm2_{j}"], cfg.norm_eps)
                if ffn_kind == "moe":
                    y, a = moe_ffn(gparams[f"ffn_{j}"], hin, cfg, policy)
                    aux = aux + a
                else:
                    y = ffn(gparams[f"ffn_{j}"], hin, cfg, policy)
                h = h + y
        return h, aux, (new_cache if new_cache else None)

    return body


def _run_stack(params, h, positions, cfg: LMConfig, policy: ApproxPolicy,
               caches=None):
    """Scan the block groups. caches: pytree stacked on leading group dim
    (or None).  Returns (h, aux_total, new_caches)."""
    pattern = block_pattern(cfg)
    body = _group_body(cfg, policy, pattern)

    def scan_fn(carry, xs):
        h, aux = carry
        gparams, gcache = xs
        h, a, nc = body(h, positions, gparams, gcache)
        return (hint_batch(h), aux + a), nc

    fn = jax.checkpoint(scan_fn) if cfg.remat else scan_fn
    (h, aux), new_caches = jax.lax.scan(
        fn, (h, jnp.zeros((), jnp.float32)),
        (params["blocks"], caches), unroll=cfg.scan_unroll)
    return h, aux, new_caches


def _embed_inputs(params, batch, cfg: LMConfig, policy: ApproxPolicy):
    """Returns (h, positions, target_mask)."""
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    mask = None
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = policy.matmul("img_proj", batch["img_embeds"].astype(cfg.dtype),
                            params["img_proj"]).astype(cfg.dtype)
        h = jnp.concatenate([img, h], axis=1)
        b, s_img = img.shape[0], img.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((b, s_img), jnp.float32),
             jnp.ones_like(tokens, jnp.float32)], axis=1)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    return hint_batch(h), positions, mask


# ----------------------------------------------------------------------
# Public steps
# ----------------------------------------------------------------------
def forward_train(params, batch, cfg: LMConfig,
                  policy: ApproxPolicy = EXACT_POLICY) -> jax.Array:
    """batch: tokens (B,S), targets (B,S[+img]) -> scalar loss."""
    h, positions, mask = _embed_inputs(params, batch, cfg, policy)
    h, aux, _ = _run_stack(params, h, positions, cfg, policy)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    targets = batch["targets"]
    if mask is not None:  # vlm: image positions carry no LM loss
        pad = h.shape[1] - targets.shape[1]
        targets = jnp.pad(targets, ((0, 0), (pad, 0)))
    loss = chunked_cross_entropy(h, params["unembed"], targets,
                                 cfg.loss_chunk, mask,
                                 unroll=cfg.scan_unroll)
    return loss + AUX_LOSS_COEF * aux


def init_cache(cfg: LMConfig, batch: int, max_len: int):
    """Stacked (n_groups, ...) cache pytree."""
    pattern = block_pattern(cfg)
    n_groups = cfg.n_layers // len(pattern)

    def one_group(_):
        c = {}
        for j, (mixer, _f) in enumerate(pattern):
            if mixer == "attn":
                c[f"mixer_{j}"] = init_attention_cache(cfg, batch, max_len)
            elif mixer == "mla":
                c[f"mixer_{j}"] = init_mla_cache(cfg, batch, max_len)
            else:
                c[f"mixer_{j}"] = init_mamba_cache(cfg, batch)
        return c

    groups = [one_group(g) for g in range(n_groups)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *groups)


def forward_prefill(params, batch, cache, cfg: LMConfig,
                    policy: ApproxPolicy = EXACT_POLICY):
    """Fill the cache from a prompt; returns (last_logits, new_cache)."""
    h, positions, _ = _embed_inputs(params, batch, cfg, policy)
    h, _aux, new_caches = _run_stack(params, h, positions, cfg, policy,
                                     caches=cache)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(h[:, -1, :], params["unembed"])
    return logits, new_caches


def forward_decode(params, token, cache, cfg: LMConfig,
                   policy: ApproxPolicy = EXACT_POLICY):
    """One decode step. token: (B,) int32. Returns (logits, new_cache)."""
    pos = _cache_pos(cache, cfg)
    h = hint_batch(
        jnp.take(params["embed"], token[:, None], axis=0).astype(cfg.dtype))
    positions = pos + jnp.zeros((1,), jnp.int32)
    h, _aux, new_caches = _run_stack(params, h, positions, cfg, policy,
                                     caches=cache)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(h[:, 0, :], params["unembed"])
    return logits, new_caches


def _cache_pos(cache, cfg: LMConfig) -> jax.Array:
    """Current position from any attention sub-cache (group 0)."""
    pattern = block_pattern(cfg)
    for j, (mixer, _f) in enumerate(pattern):
        if mixer in ("attn", "mla"):
            return cache[f"mixer_{j}"]["pos"][0]
    # pure SSM: position does not matter (no RoPE); use zero
    return jnp.zeros((), jnp.int32)
