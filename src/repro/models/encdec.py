"""Encoder-decoder backbone (Whisper-large-v3 shape).

Per the assignment the audio frontend (mel + conv downsampling) is a
STUB: ``input_specs`` provides precomputed frame embeddings
(B, enc_frames, d_model).  The transformer backbone is complete:
bidirectional encoder, causal decoder with per-layer cross-attention,
sinusoidal absolute positions (``use_rope=False``), self- and cross-KV
caches for serving.  Norms are RMS (deviation from Whisper's LayerNorm,
noted in DESIGN.md — structurally irrelevant for lowering/roofline).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import ApproxPolicy, EXACT_POLICY

from .common import (LMConfig, attention, chunked_cross_entropy, dense_init,
                     ffn, hint_batch, init_attention, init_attention_cache,
                     init_ffn, logits_from_hidden, rms_norm, split_keys)


def sinusoidal_positions(seq: int, dim: int, offset=0) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    inv = 1.0 / (10000.0 ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_cross_attention(key, cfg: LMConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    k = split_keys(key, ["wq", "wk", "wv", "wo"])
    return {
        "wq": dense_init(k["wq"], (d, h * hd)),
        "wk": dense_init(k["wk"], (d, h * hd)),
        "wv": dense_init(k["wv"], (d, h * hd)),
        "wo": dense_init(k["wo"], (h * hd, d)),
    }


def cross_attention(params, x, enc_kv, cfg: LMConfig, policy: ApproxPolicy,
                    layer_tag: str = "xattn") -> jax.Array:
    """x: (B,S,D); enc_kv: {"k": (B,F,H,hd), "v": ...} precomputed from
    the encoder output (the cross-KV cache)."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = policy.matmul(f"{layer_tag}.wq", x, params["wq"]
                      ).reshape(b, s, h, hd).astype(cfg.dtype)
    k, v = enc_kv["k"], enc_kv["v"]
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, s, h * hd)
    return policy.matmul(f"{layer_tag}.wo", out, params["wo"]
                         ).astype(cfg.dtype)


def encode_cross_kv(params, enc_out, cfg: LMConfig, policy: ApproxPolicy,
                    layer_tag: str = "xattn") -> dict:
    b, f, d = enc_out.shape
    h, hd = cfg.n_heads, cfg.head_dim
    k = policy.matmul(f"{layer_tag}.wk", enc_out, params["wk"]
                      ).reshape(b, f, h, hd).astype(cfg.dtype)
    v = policy.matmul(f"{layer_tag}.wv", enc_out, params["wv"]
                      ).reshape(b, f, h, hd).astype(cfg.dtype)
    return {"k": k, "v": v}


def init_params(key, cfg: LMConfig) -> dict:
    keys = split_keys(key, ["embed", "unembed", "enc", "dec"])
    params = {
        "embed": dense_init(keys["embed"], (cfg.vocab, cfg.d_model),
                            scale=0.02),
        "unembed": dense_init(keys["unembed"], (cfg.vocab, cfg.d_model),
                              scale=0.02),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "dec_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }

    def init_enc_layer(k):
        ks = split_keys(k, ["attn", "ffn"])
        return {"attn": init_attention(ks["attn"], cfg),
                "ffn": init_ffn(ks["ffn"], cfg),
                "norm1": jnp.ones((cfg.d_model,), jnp.float32),
                "norm2": jnp.ones((cfg.d_model,), jnp.float32)}

    def init_dec_layer(k):
        ks = split_keys(k, ["attn", "xattn", "ffn"])
        return {"attn": init_attention(ks["attn"], cfg),
                "xattn": init_cross_attention(ks["xattn"], cfg),
                "ffn": init_ffn(ks["ffn"], cfg),
                "norm1": jnp.ones((cfg.d_model,), jnp.float32),
                "norm2": jnp.ones((cfg.d_model,), jnp.float32),
                "norm3": jnp.ones((cfg.d_model,), jnp.float32)}

    params["enc_blocks"] = jax.vmap(init_enc_layer)(
        jax.random.split(keys["enc"], cfg.n_enc_layers))
    params["dec_blocks"] = jax.vmap(init_dec_layer)(
        jax.random.split(keys["dec"], cfg.n_layers))
    return params


def encode(params, frames, cfg: LMConfig, policy: ApproxPolicy) -> jax.Array:
    """frames: (B,F,D) stub embeddings -> encoder hidden (B,F,D)."""
    b, f, d = frames.shape
    h = frames.astype(cfg.dtype) + sinusoidal_positions(f, d).astype(cfg.dtype)
    h = hint_batch(h)
    positions = jnp.arange(f, dtype=jnp.int32)

    def body(carry, lp):
        h = carry
        hin = rms_norm(h, lp["norm1"], cfg.norm_eps)
        # bidirectional: zero mask bias
        y, _ = attention(lp["attn"], hin, cfg, policy, positions=positions,
                         cache=None, layer_tag="enc.attn")
        h = h + y
        hin = rms_norm(h, lp["norm2"], cfg.norm_eps)
        h = h + ffn(lp["ffn"], hin, cfg, policy, layer_tag="enc.ffn")
        return hint_batch(h), None

    fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(fn, h, params["enc_blocks"], unroll=cfg.scan_unroll)
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _decode_stack(params, h, positions, cfg, policy, self_caches, cross_kvs):
    def body(carry, xs):
        h = carry
        lp, scache, xkv = xs
        hin = rms_norm(h, lp["norm1"], cfg.norm_eps)
        y, nc = attention(lp["attn"], hin, cfg, policy, positions=positions,
                          cache=scache, layer_tag="dec.attn")
        h = h + y
        hin = rms_norm(h, lp["norm2"], cfg.norm_eps)
        h = h + cross_attention(lp["xattn"], hin, xkv, cfg, policy)
        hin = rms_norm(h, lp["norm3"], cfg.norm_eps)
        h = h + ffn(lp["ffn"], hin, cfg, policy, layer_tag="dec.ffn")
        return hint_batch(h), nc

    fn = jax.checkpoint(body) if cfg.remat else body
    h, new_caches = jax.lax.scan(
        fn, h, (params["dec_blocks"], self_caches, cross_kvs),
        unroll=cfg.scan_unroll)
    return rms_norm(h, params["dec_norm"], cfg.norm_eps), new_caches


def _embed_tokens(params, tokens, cfg, offset=0):
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    h = h + sinusoidal_positions(tokens.shape[1], cfg.d_model,
                                 offset).astype(cfg.dtype)
    return hint_batch(h)


def forward_train(params, batch, cfg: LMConfig,
                  policy: ApproxPolicy = EXACT_POLICY) -> jax.Array:
    """batch: frames (B,F,D), tokens (B,S), targets (B,S)."""
    enc_out = encode(params, batch["frames"], cfg, policy)

    def xkv_body(_, lp):
        return None, encode_cross_kv(lp["xattn"], enc_out, cfg, policy)

    _, cross_kvs = jax.lax.scan(xkv_body, None, params["dec_blocks"],
                                unroll=cfg.scan_unroll)
    h = _embed_tokens(params, batch["tokens"], cfg)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    # training: no self-cache (full causal attention)
    def body(carry, xs):
        h = carry
        lp, xkv = xs
        hin = rms_norm(h, lp["norm1"], cfg.norm_eps)
        y, _ = attention(lp["attn"], hin, cfg, policy, positions=positions,
                         layer_tag="dec.attn")
        h = h + y
        hin = rms_norm(h, lp["norm2"], cfg.norm_eps)
        h = h + cross_attention(lp["xattn"], hin, xkv, cfg, policy)
        hin = rms_norm(h, lp["norm3"], cfg.norm_eps)
        h = h + ffn(lp["ffn"], hin, cfg, policy, layer_tag="dec.ffn")
        return hint_batch(h), None

    fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(fn, h, (params["dec_blocks"], cross_kvs),
                        unroll=cfg.scan_unroll)
    h = rms_norm(h, params["dec_norm"], cfg.norm_eps)
    return chunked_cross_entropy(h, params["unembed"], batch["targets"],
                                 cfg.loss_chunk, unroll=cfg.scan_unroll)


def init_cache(cfg: LMConfig, batch: int, max_len: int):
    """Self-attention caches for all decoder layers + empty cross slots."""
    caches = [init_attention_cache(cfg, batch, max_len)
              for _ in range(cfg.n_layers)]
    self_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    cross = {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, cfg.n_heads,
                        cfg.head_dim), cfg.dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, cfg.n_heads,
                        cfg.head_dim), cfg.dtype),
    }
    return {"self": self_caches, "cross": cross}


def forward_prefill(params, batch, cache, cfg: LMConfig,
                    policy: ApproxPolicy = EXACT_POLICY):
    """Encode frames, build cross-KV, run prompt through the decoder."""
    enc_out = encode(params, batch["frames"], cfg, policy)

    def xkv_body(_, lp):
        return None, encode_cross_kv(lp["xattn"], enc_out, cfg, policy)

    _, cross_kvs = jax.lax.scan(xkv_body, None, params["dec_blocks"],
                                unroll=cfg.scan_unroll)
    h = _embed_tokens(params, batch["tokens"], cfg)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    h, new_self = _decode_stack(params, h, positions, cfg, policy,
                                cache["self"], cross_kvs)
    logits = logits_from_hidden(h[:, -1, :], params["unembed"])
    return logits, {"self": new_self, "cross": cross_kvs}


def forward_decode(params, token, cache, cfg: LMConfig,
                   policy: ApproxPolicy = EXACT_POLICY):
    pos = cache["self"]["pos"][0]
    h = jnp.take(params["embed"], token[:, None], axis=0).astype(cfg.dtype)
    h = h + sinusoidal_positions(1, cfg.d_model, pos).astype(cfg.dtype)
    positions = pos + jnp.zeros((1,), jnp.int32)
    h, new_self = _decode_stack(params, h, positions, cfg, policy,
                                cache["self"], cache["cross"])
    logits = logits_from_hidden(h[:, 0, :], params["unembed"])
    return logits, {"self": new_self, "cross": cache["cross"]}
