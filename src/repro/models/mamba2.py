"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked quadratic-within / linear-across implementation:
  * intra-chunk term: (C Bᵀ ⊙ L) x̄  with L the causal decay matrix,
  * inter-chunk term: sequential ``lax.scan`` over per-chunk states
    (S/Q steps — O(S·Q) work instead of O(S²)),
  * O(1)-state decode step for long-context serving (the reason this
    arch family runs the ``long_500k`` shape).

Projections flow through the ApproxPolicy; the SSD einsums themselves
stay exact (they are the data-dependent "attention" of the SSM).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import ApproxPolicy

from .common import LMConfig, dense_init, rms_norm, split_keys


def ssm_dims(cfg: LMConfig) -> dict:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n            # x + B + C (single group)
    return dict(d_inner=d_inner, n_heads=n_heads, n=n, conv_dim=conv_dim)


def init_mamba(key, cfg: LMConfig) -> dict:
    dd = ssm_dims(cfg)
    d_in = cfg.d_model
    d_proj = 2 * dd["d_inner"] + 2 * dd["n"] + dd["n_heads"]
    k = split_keys(key, ["in_proj", "out_proj", "conv", "a", "d", "dtb",
                         "norm"])
    return {
        "in_proj": dense_init(k["in_proj"], (d_in, d_proj)),
        "out_proj": dense_init(k["out_proj"], (dd["d_inner"], d_in)),
        "conv_w": (jax.random.normal(k["conv"],
                                     (cfg.conv_width, dd["conv_dim"]),
                                     jnp.float32)
                   / np.sqrt(cfg.conv_width)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, dd["n_heads"],
                                      dtype=jnp.float32)),
        "d_skip": jnp.ones((dd["n_heads"],), jnp.float32),
        "dt_bias": jnp.zeros((dd["n_heads"],), jnp.float32),
        "norm": jnp.ones((dd["d_inner"],), jnp.float32),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. xbc: (B,S,C); w: (W,C).
    state: (B,W-1,C) previous inputs for decode continuity.
    Returns (y, new_state)."""
    b, s, c = xbc.shape
    wlen = w.shape[0]
    if state is None:
        state = jnp.zeros((b, wlen - 1, c), xbc.dtype)
    full = jnp.concatenate([state, xbc], axis=1)       # (B, S+W-1, C)
    y = jnp.zeros((b, s, c), jnp.float32)
    for i in range(wlen):  # W is tiny (4): unrolled shifts, no conv op
        y = y + full[:, i:i + s, :].astype(jnp.float32) * w[i]
    new_state = full[:, -(wlen - 1):, :]
    return jax.nn.silu(y).astype(xbc.dtype), new_state


def _ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int,
                 init_state: Optional[jax.Array] = None,
                 unroll: bool = False) -> tuple[jax.Array, jax.Array]:
    """SSD scan. x: (B,S,H,P); dt: (B,S,H) (post-softplus);
    a: (H,) negative; b_mat/c_mat: (B,S,N).  Returns y: (B,S,H,P) and
    final state (B,H,P,N)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, "seq must divide chunk"
    nc = s // q

    la = dt * a[None, None, :]                       # (B,S,H) log-decay
    xbar = x * dt[..., None]                         # (B,S,H,P)

    la_c = la.reshape(bsz, nc, q, h)
    cum = jnp.cumsum(la_c, axis=2)                   # (B,NC,Q,H)
    x_c = xbar.reshape(bsz, nc, q, h, p)
    b_c = b_mat.reshape(bsz, nc, q, n)
    c_c = c_mat.reshape(bsz, nc, q, n)

    # intra-chunk: M[i,j] = exp(cum_i - cum_j) * (c_i · b_j), i >= j.
    # Mask INSIDE the exponent: exp() of the (positive) anti-causal
    # entries would overflow and poison gradients through jnp.where.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,NC,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    l_mat = jnp.exp(jnp.where(causal, diff, -1e30))
    cb = jnp.einsum("bcin,bcjn->bcij", c_c, b_c,
                    preferred_element_type=jnp.float32)
    m = cb[..., None] * l_mat                               # (B,NC,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, x_c,
                         preferred_element_type=jnp.float32)

    # per-chunk input state: S_c = Σ_j exp(cum_last - cum_j) b_j ⊗ x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,NC,Q,H)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                         decay_to_end, b_c, x_c,
                         preferred_element_type=jnp.float32)

    # inter-chunk: sequential state pass
    chunk_decay = jnp.exp(jnp.sum(la_c, axis=2))            # (B,NC,H)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inputs):
        s_c, dec = inputs                                   # (B,H,P,N),(B,H)
        out_state = state                                    # state BEFORE chunk
        new_state = state * dec[:, :, None, None] + s_c
        return new_state, out_state

    s_seq = jnp.moveaxis(s_chunk, 1, 0)                     # (NC,B,H,P,N)
    d_seq = jnp.moveaxis(chunk_decay, 1, 0)                 # (NC,B,H)
    final_state, prev_states = jax.lax.scan(step, init_state,
                                            (s_seq, d_seq), unroll=unroll)
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,NC,H,P,N)

    # y_inter[i] = exp(cum_i) * c_i · state_{c-1}
    y_inter = jnp.einsum("bcih,bcin,bchpn->bcihp",
                         jnp.exp(cum), c_c, prev_states,
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, final_state


def mamba_block(params, x, cfg: LMConfig, policy: ApproxPolicy, *,
                cache: Optional[dict] = None, layer_tag: str = "mamba"
                ) -> tuple[jax.Array, Optional[dict]]:
    """x: (B,S,D).  cache = {"conv": (B,W-1,C), "state": (B,H,P,N)} for
    O(1) decode; None for full-sequence (training/prefill from zero)."""
    bsz, s, d = x.shape
    dd = ssm_dims(cfg)
    di, h, n, p = dd["d_inner"], dd["n_heads"], dd["n"], cfg.ssm_head_dim

    proj = policy.matmul(f"{layer_tag}.in_proj", x, params["in_proj"])
    z, xs, b_mat, c_mat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    xbc = jnp.concatenate([xs, b_mat, c_mat], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], conv_state)
    xs, b_mat, c_mat = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    xs_h = xs.reshape(bsz, s, h, p).astype(jnp.float32)
    b32 = b_mat.astype(jnp.float32)
    c32 = c_mat.astype(jnp.float32)

    if cache is None:
        y, _final = _ssd_chunked(xs_h, dt, a, b32, c32, cfg.ssm_chunk,
                                 unroll=cfg.scan_unroll)
        new_cache = None
    elif s == 1:
        state = cache["state"]                       # (B,H,P,N)
        dtl = dt[:, 0, :]                            # (B,H)
        dec = jnp.exp(dtl * a[None, :])
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtl, xs_h[:, 0], b32[:, 0])
        state = state * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", c32[:, 0], state)[:, None]
        new_cache = {"conv": new_conv, "state": state}
    else:  # prefill with cache carry-out
        y, final = _ssd_chunked(xs_h, dt, a, b32, c32, cfg.ssm_chunk,
                                unroll=cfg.scan_unroll)
        new_cache = {"conv": new_conv, "state": final}

    y = y + xs_h * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(cfg.dtype), params["norm"], cfg.norm_eps)
    out = policy.matmul(f"{layer_tag}.out_proj", y, params["out_proj"])
    return out.astype(cfg.dtype), new_cache


def init_mamba_cache(cfg: LMConfig, batch: int) -> dict:
    dd = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dd["conv_dim"]),
                          cfg.dtype),
        "state": jnp.zeros((batch, dd["n_heads"], cfg.ssm_head_dim,
                            dd["n"]), jnp.float32),
    }
