"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries and keys/values are produced through low-rank compressions:
  c_q  = x W_dq                (q_lora)
  q    = RMSNorm(c_q) W_uq     per-head [d_nope | d_rope]
  c_kv = x W_dkv               (kv_lora)   <- THIS is the KV cache
  k_nope, v = RMSNorm(c_kv) W_uk / W_uv
  k_rope = x W_kr              single shared rope head
The decode cache stores only (c_kv, k_rope): 512+64 floats per token —
the memory win that makes 32k-context batch-128 decode feasible.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import ApproxPolicy

from .common import (LMConfig, apply_rope, dense_init, rms_norm,
                     rope_tables, split_keys)


def init_mla(key, cfg: LMConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    k = split_keys(key, ["wdq", "wuq", "wqr", "wdkv", "wuk", "wuv", "wkr",
                         "wo", "qn", "kvn"])
    return {
        "wdq": dense_init(k["wdq"], (d, cfg.q_lora)),
        "wuq": dense_init(k["wuq"], (cfg.q_lora, h * dn)),
        "wqr": dense_init(k["wqr"], (cfg.q_lora, h * dr)),
        "wdkv": dense_init(k["wdkv"], (d, cfg.kv_lora)),
        "wuk": dense_init(k["wuk"], (cfg.kv_lora, h * dn)),
        "wuv": dense_init(k["wuv"], (cfg.kv_lora, h * dv)),
        "wkr": dense_init(k["wkr"], (d, dr)),
        "wo": dense_init(k["wo"], (h * dv, d)),
        "qn": jnp.ones((cfg.q_lora,), jnp.float32),
        "kvn": jnp.ones((cfg.kv_lora,), jnp.float32),
    }


def _mla_core(q_n, q_r, k_n, k_r, v, mask_bias, cfg: LMConfig) -> jax.Array:
    """q_n:(B,S,H,dn) q_r:(B,S,H,dr) k_n:(B,T,H,dn) k_r:(B,T,dr)
    v:(B,T,H,dv) -> (B,S,H,dv)."""
    scale = 1.0 / np.sqrt(cfg.head_dim + cfg.rope_head_dim)
    s_n = jnp.einsum("bshd,bthd->bhst", q_n, k_n,
                     preferred_element_type=jnp.float32)
    s_r = jnp.einsum("bshd,btd->bhst", q_r, k_r,
                     preferred_element_type=jnp.float32)
    scores = (s_n + s_r) * scale + mask_bias
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def _mla_core_chunked(q_n, q_r, k_n, k_r, v, q_pos0, t_valid,
                      cfg: LMConfig, unroll: bool = False) -> jax.Array:
    """Flash-style MLA: online softmax over T chunks — never builds the
    (H,S,T) score tensor (the dominant memory-roofline term of the
    deepseek train/prefill cells; see EXPERIMENTS.md §Perf-2)."""
    b, s, h, dn = q_n.shape
    t = k_n.shape[1]
    dv = v.shape[-1]
    scale = 1.0 / np.sqrt(cfg.head_dim + cfg.rope_head_dim)
    c = min(cfg.kv_chunk, t)
    pad = (-t) % c
    if pad:
        k_n = jnp.pad(k_n, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_r = jnp.pad(k_r, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = k_n.shape[1] // c
    kn_c = jnp.moveaxis(k_n.reshape(b, nc, c, h, dn), 1, 0)
    kr_c = jnp.moveaxis(k_r.reshape(b, nc, c, -1), 1, 0)
    v_c = jnp.moveaxis(v.reshape(b, nc, c, h, dv), 1, 0)
    idx0 = jnp.arange(nc, dtype=jnp.int32) * c
    q_pos = q_pos0 + jnp.arange(s, dtype=jnp.int32)

    m0 = jnp.full((b, h, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, dv), jnp.float32)

    def body(carry, inputs):
        m, l, acc = carry
        kn, kr, vc, i0 = inputs
        sc = jnp.einsum("bshd,bchd->bhsc", q_n, kn,
                        preferred_element_type=jnp.float32)
        sc = sc + jnp.einsum("bshd,bcd->bhsc", q_r, kr,
                             preferred_element_type=jnp.float32)
        sc = sc * scale
        key_pos = i0 + jnp.arange(c, dtype=jnp.int32)
        valid = (key_pos[None, :] <= q_pos[:, None]) \
            & (key_pos[None, :] < t_valid)
        sc = jnp.where(valid[None, None], sc, -1e30)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        p = jnp.where(valid[None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhsc,bchd->bhsd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kn_c, kr_c, v_c, idx0), unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2)   # (B,S,H,dv)


def mla_attention(params, x, cfg: LMConfig, policy: ApproxPolicy, *,
                  positions: jax.Array, cache: Optional[dict] = None,
                  layer_tag: str = "mla") -> tuple[jax.Array, Optional[dict]]:
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim

    cq = policy.matmul(f"{layer_tag}.wdq", x, params["wdq"])
    cq = rms_norm(cq, params["qn"], cfg.norm_eps)
    q_n = policy.matmul(f"{layer_tag}.wuq", cq, params["wuq"]
                        ).reshape(b, s, h, dn)
    q_r = policy.matmul(f"{layer_tag}.wqr", cq, params["wqr"]
                        ).reshape(b, s, h, dr)

    ckv = policy.matmul(f"{layer_tag}.wdkv", x, params["wdkv"])
    ckv = rms_norm(ckv, params["kvn"], cfg.norm_eps)
    kr = policy.matmul(f"{layer_tag}.wkr", x, params["wkr"])  # (B,S,dr)

    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    q_r = apply_rope(q_r, cos, sin)
    kr = apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0, :]

    if cache is not None:
        pos = cache["pos"]
        ckv_all = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0))
        kr_all = jax.lax.dynamic_update_slice(
            cache["kr"], kr.astype(cache["kr"].dtype), (0, pos, 0))
        new_cache = {"ckv": ckv_all, "kr": kr_all, "pos": pos + s}
        t_len = ckv_all.shape[1]
        q_pos0, t_valid = pos, pos + s
    else:
        ckv_all, kr_all = ckv, kr
        new_cache = None
        t_len = s
        q_pos0, t_valid = jnp.zeros((), jnp.int32), jnp.int32(s)

    # expand compressed cache to per-head keys/values
    k_n = policy.matmul(f"{layer_tag}.wuk", ckv_all, params["wuk"]
                        ).reshape(b, t_len, h, dn)
    v = policy.matmul(f"{layer_tag}.wuv", ckv_all, params["wuv"]
                      ).reshape(b, t_len, h, dv)

    if cfg.attn_impl == "chunked":
        out = _mla_core_chunked(
            q_n.astype(cfg.dtype), q_r.astype(cfg.dtype),
            k_n.astype(cfg.dtype), kr_all.astype(cfg.dtype),
            v.astype(cfg.dtype), q_pos0, t_valid, cfg,
            unroll=cfg.scan_unroll)
    else:
        t = jnp.arange(t_len)
        valid = t[None, :] <= (q_pos0 + jnp.arange(s)[:, None])
        bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)
        out = _mla_core(q_n.astype(cfg.dtype), q_r.astype(cfg.dtype),
                        k_n.astype(cfg.dtype), kr_all.astype(cfg.dtype),
                        v.astype(cfg.dtype), bias, cfg)
    out = out.reshape(b, s, h * dv)
    out = policy.matmul(f"{layer_tag}.wo", out, params["wo"])
    return out.astype(cfg.dtype), new_cache


def init_mla_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora), cfg.dtype),
        "kr": jnp.zeros((batch, max_len, cfg.rope_head_dim), cfg.dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
