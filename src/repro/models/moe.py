"""Mixture-of-Experts layer with sort-based, capacity-bounded dispatch.

Expert-parallel friendly: expert weights are (E, d, f) tensors sharded
on E over the `model` mesh axis; dispatch builds an (E, C, d) buffer via
sorted scatter (O(T·k) memory — no (T, E) one-hot), expert compute is a
single batched matmul over E (MXU), combine gathers back with routing
weights.  Tokens above a capacity of ``C = ceil(T·k/E · capacity_factor)``
are dropped (standard GShard-style dropping — the auxiliary load-balance
loss keeps drops rare).

Shared experts (DeepSeek-V2) are a dense FFN over all tokens, added to
the routed output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.backend import backend_matmul
from repro.approx.layers import ApproxPolicy

from .common import (LMConfig, activation, dense_init, hint_axis,
                     split_keys)


def init_moe(key, cfg: LMConfig) -> dict:
    e = cfg.n_experts
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    k = split_keys(key, ["router", "wi", "wg", "wo", "shared"])
    p = {
        "router": dense_init(k["router"], (d, e), scale=0.02),
        "wi": dense_init(k["wi"], (e, d, f)),
        "wo": dense_init(k["wo"], (e, f, d)),
    }
    if cfg.act == "silu":
        p["wg"] = dense_init(k["wg"], (e, d, f))
    if cfg.n_shared_experts > 0:
        from .common import init_ffn
        import dataclasses
        shared_ff = f * cfg.n_shared_experts
        p["shared"] = init_ffn(k["shared"], cfg, d_ff=shared_ff)
    return p


def _expert_matmul(policy: ApproxPolicy, name: str, x: jax.Array,
                   w: jax.Array) -> jax.Array:
    """x: (E,C,d) @ w: (E,d,f) -> (E,C,f), through the approx backend
    per expert (vmapped over E)."""
    be = policy.backend_for(name)
    return jax.vmap(lambda xe, we: backend_matmul(xe, we, be))(x, w)


def moe_ffn(params, x, cfg: LMConfig, policy: ApproxPolicy,
            layer_tag: str = "moe") -> tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (B,S,D), aux load-balance loss (scalar f32).

    With ``cfg.moe_blocks > 1`` dispatch runs block-locally (sorted
    scatter within each token block, capacity per block): when blocks
    align with the DP shards, the argsort/cumsum/scatter become
    shard-local and the global-sort collectives disappear
    (EXPERIMENTS.md §Perf-1)."""
    b, s, d = x.shape
    t = b * s
    nb = cfg.moe_blocks
    if nb > 1 and t % nb == 0 and t // nb >= cfg.top_k:
        # vmap over blocks: experts stay replicated within each data
        # shard and XLA all-gathers the (small) expert weights — measured
        # 3.5x better than forcing an EP-sharded scatter target
        # (EXPERIMENTS.md §Perf-1, iteration A1b).
        xb = x.reshape(nb, t // nb, d)
        yb, aux = jax.vmap(
            lambda xe: _moe_tokens(params, xe, cfg, policy, layer_tag))(xb)
        return yb.reshape(b, s, d).astype(x.dtype), jnp.mean(aux)
    y, aux = _moe_tokens(params, x.reshape(t, d), cfg, policy, layer_tag)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _moe_blocked(params, xb, cfg: LMConfig, policy: ApproxPolicy,
                 layer_tag: str) -> tuple[jax.Array, jax.Array]:
    """Block-local dispatch, explicitly batched over blocks so GSPMD
    keeps blocks on the data axes and experts on 'model'.
    xb: (NB, TB, D) -> (NB, TB, D)."""
    from .common import hint_spec
    nb, tb, d = xb.shape
    e, k = cfg.n_experts, cfg.top_k
    xb = hint_spec(xb, {0: "batch"})

    logits = jnp.einsum("btd,de->bte", xb.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)                 # (NB,TB,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[top_ids.reshape(-1)].add(
        1.0 / (nb * tb * k))
    aux = e * jnp.sum(me * ce)

    cap = int(min(tb * k,
                  max(np.ceil(tb * k / e * cfg.capacity_factor), 4)))
    flat_e = top_ids.reshape(nb, tb * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)        # local sort
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    bidx = jnp.arange(nb, dtype=jnp.int32)[:, None]
    counts = jnp.zeros((nb, e), jnp.int32).at[
        bidx, flat_e].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((nb, 1), jnp.int32), jnp.cumsum(counts, axis=-1)[:, :-1]],
        axis=-1)
    pos_in_e = jnp.arange(tb * k, dtype=jnp.int32)[None, :] \
        - jnp.take_along_axis(starts, sorted_e, axis=-1)
    src_token = order // k                                    # (NB, TB*k)

    buf = jnp.zeros((nb, e, cap, d), xb.dtype)
    gathered_x = jnp.take_along_axis(
        xb, src_token[..., None], axis=1)                     # (NB,TB*k,D)
    buf = buf.at[bidx, sorted_e, pos_in_e].set(
        gathered_x.astype(xb.dtype), mode="drop")
    buf = hint_spec(buf, {0: "batch", 1: "model"})

    def emm(name, h, w):
        be = policy.backend_for(name)
        from repro.approx.backend import backend_matmul
        fn = jax.vmap(jax.vmap(backend_matmul, in_axes=(0, 0, None)),
                      in_axes=(0, None, None))
        return fn(h, w, be)                                   # (NB,E,C,f)

    hidden = emm(f"{layer_tag}.wi", buf, params["wi"])
    if cfg.act == "silu":
        gate = emm(f"{layer_tag}.wg", buf, params["wg"])
        hidden = jax.nn.silu(gate) * hidden
    else:
        hidden = activation(hidden, cfg.act)
    out_buf = emm(f"{layer_tag}.wo", hidden.astype(xb.dtype),
                  params["wo"])
    out_buf = hint_spec(out_buf, {0: "batch", 1: "model"})

    in_cap = pos_in_e < cap
    taken = out_buf[bidx, sorted_e,
                    jnp.minimum(pos_in_e, cap - 1)]           # (NB,TB*k,D)
    taken = jnp.where(in_cap[..., None], taken, 0.0)
    slot_out = jnp.zeros((nb, tb * k, d), out_buf.dtype).at[
        bidx, order].set(taken)
    slot_out = slot_out.reshape(nb, tb, k, d)
    y = jnp.sum(slot_out
                * top_w[..., None].astype(slot_out.dtype), axis=2)

    if cfg.n_shared_experts > 0:
        from .common import ffn
        y = y + ffn(params["shared"], xb, cfg, policy,
                    layer_tag=f"{layer_tag}.shared").astype(y.dtype)
    return hint_spec(y.astype(xb.dtype), {0: "batch"}), aux


def _moe_tokens(params, xf, cfg: LMConfig, policy: ApproxPolicy,
                layer_tag: str = "moe") -> tuple[jax.Array, jax.Array]:
    """xf: (T,D) -> (T,D), aux loss."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k

    # --- routing ---
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)             # (T,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # aux loss (Switch-style): E * Σ_e f_e · p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[top_ids.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    # --- sort-based dispatch ---
    # floor of 4 and ceiling of t*k: tiny decode batches would otherwise
    # drop tokens that a full forward pass keeps
    cap = int(min(t * k,
                  max(np.ceil(t * k / e * cfg.capacity_factor), 4)))
    flat_e = top_ids.reshape(-1)                          # (T*k,)
    order = jnp.argsort(flat_e, stable=True)              # (T*k,)
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    src_token = order // k                                 # (T*k,)

    buf = jnp.zeros((e, cap, d), xf.dtype)
    buf = buf.at[sorted_e, pos_in_e].set(
        xf[src_token].astype(xf.dtype), mode="drop")
    if cfg.moe_blocks <= 1:  # (hint not applicable under vmap)
        buf = hint_axis(buf, 0, "model")   # EP: expert dim on 'model'

    # --- expert compute (batched over E; EP shards this axis) ---
    hidden = _expert_matmul(policy, f"{layer_tag}.wi", buf, params["wi"])
    if cfg.act == "silu":
        gate = _expert_matmul(policy, f"{layer_tag}.wg", buf, params["wg"])
        hidden = jax.nn.silu(gate) * hidden
    else:
        hidden = activation(hidden, cfg.act)
    out_buf = _expert_matmul(policy, f"{layer_tag}.wo",
                             hidden.astype(xf.dtype), params["wo"])

    # --- combine ---
    in_cap = pos_in_e < cap
    gathered = out_buf[sorted_e, jnp.minimum(pos_in_e, cap - 1)]
    gathered = jnp.where(in_cap[:, None], gathered, 0.0)
    slot_out = jnp.zeros((t * k, d), out_buf.dtype).at[order].set(gathered)
    slot_out = slot_out.reshape(t, k, d)
    y = jnp.sum(slot_out * top_w[..., None].astype(slot_out.dtype), axis=1)

    # --- shared experts (dense path over all tokens) ---
    if cfg.n_shared_experts > 0:
        from .common import ffn
        y = y + ffn(params["shared"], xf, cfg, policy,
                    layer_tag=f"{layer_tag}.shared").astype(y.dtype)

    return y.astype(xf.dtype), aux
