"""Family dispatch: maps LMConfig.family to init/forward functions,
plus the serving hooks the continuous-batching engine uses to treat
every family uniformly (``input_extras`` for non-token prefill inputs,
``probe_layer_tags`` for the policy call-site names a request policy
must be resolved over, ``prompt_extra_len`` for the prompt positions
those extras occupy in the KV cache)."""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import numpy as np

from . import decoder, encdec
from .common import LMConfig


class ModelFns:
    def __init__(self, init_params, forward_train, init_cache,
                 forward_prefill, forward_decode):
        self.init_params = init_params
        self.forward_train = forward_train
        self.init_cache = init_cache
        self.forward_prefill = forward_prefill
        self.forward_decode = forward_decode


_DECODER = ModelFns(decoder.init_params, decoder.forward_train,
                    decoder.init_cache, decoder.forward_prefill,
                    decoder.forward_decode)
_ENCDEC = ModelFns(encdec.init_params, encdec.forward_train,
                   encdec.init_cache, encdec.forward_prefill,
                   encdec.forward_decode)


def model_fns(cfg: LMConfig) -> ModelFns:
    if cfg.family == "encdec":
        return _ENCDEC
    return _DECODER


# ----------------------------------------------------------------------
# Serving hooks (DESIGN.md §2.8)
# ----------------------------------------------------------------------
def input_extras(cfg: LMConfig, batch: int,
                 fill: float = 0.1) -> dict[str, np.ndarray]:
    """The non-token prefill inputs a family needs (stub embeddings, as
    the frontends are stubs per the assignment): encdec audio frames,
    vlm image embeddings.  Token-only families return ``{}``."""
    if cfg.family == "encdec":
        return {"frames": np.full((batch, cfg.enc_frames, cfg.d_model),
                                  fill, np.float32)}
    if cfg.family == "vlm":
        return {"img_embeds": np.full((batch, cfg.n_img_tokens,
                                       cfg.d_model), fill, np.float32)}
    return {}


def prompt_extra_len(cfg: LMConfig, extras: Optional[dict]) -> int:
    """Extra *prompt positions* the prefill extras occupy in the KV
    cache.  VLM image embeddings are prepended to the token sequence
    (``decoder._embed_inputs``) so they consume cache rows; encdec
    frames feed the encoder side only (cross-KV is a non-sequence
    leaf), so they do not."""
    if cfg.family == "vlm" and extras and "img_embeds" in extras:
        return int(extras["img_embeds"].shape[1])
    return 0


def probe_layer_tags(cfg: LMConfig, params) -> tuple[str, ...]:
    """All ``policy.matmul`` call-site names one prefill step of this
    model hits, in first-call order — abstractly traced (eval_shape),
    so no FLOPs run.  Prefill covers a superset of the decode tags
    (encoder / cross-KV / image-projection tags only fire at prefill);
    scanned blocks share tags, so the list is per-layer-*type*, not
    per-depth.  This is the layer axis a serve request's
    ``ApproxPolicy`` is resolved over (``policy_assignment``)."""
    from repro.approx.layers import ApproxPolicy, MatmulBackend

    seen: list[str] = []

    class _Recorder(ApproxPolicy):
        def backend_for(self, name: str):
            if name not in seen:
                seen.append(name)
            return super().backend_for(name)

    probe = _Recorder(default=MatmulBackend(mode="f32"))
    fns = model_fns(cfg)
    seq = 4
    batch = {"tokens": np.zeros((1, seq), np.int32)}
    batch.update(input_extras(cfg, 1))

    def fn(params, batch):
        cache = fns.init_cache(cfg, 1,
                               seq + prompt_extra_len(cfg, batch) + 1)
        return fns.forward_prefill(params, batch, cache, cfg, probe)

    jax.eval_shape(fn, params, batch)
    return tuple(seen)
