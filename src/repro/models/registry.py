"""Family dispatch: maps LMConfig.family to init/forward functions."""
from __future__ import annotations

from typing import Any, Callable

from . import decoder, encdec
from .common import LMConfig


class ModelFns:
    def __init__(self, init_params, forward_train, init_cache,
                 forward_prefill, forward_decode):
        self.init_params = init_params
        self.forward_train = forward_train
        self.init_cache = init_cache
        self.forward_prefill = forward_prefill
        self.forward_decode = forward_decode


_DECODER = ModelFns(decoder.init_params, decoder.forward_train,
                    decoder.init_cache, decoder.forward_prefill,
                    decoder.forward_decode)
_ENCDEC = ModelFns(encdec.init_params, encdec.forward_train,
                   encdec.init_cache, encdec.forward_prefill,
                   encdec.forward_decode)


def model_fns(cfg: LMConfig) -> ModelFns:
    if cfg.family == "encdec":
        return _ENCDEC
    return _DECODER
