"""CIFAR-style ResNet family (paper Sec. IV, Fig. 3): 3 stages of n
residual blocks with widths 16/32/64 — depth = 6n+2 (ResNet-8 ... 50).

Every convolution runs through ``repro.approx.layers.conv2d`` (im2col +
backend matmul), so any conv layer can be switched to any approximate
multiplier — the exact experiment of the paper.  Normalization is
batch-statistics BN (pure functional; no running stats), which is
adequate for the synthetic-CIFAR reproduction and keeps params a plain
pytree.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import ApproxPolicy, EXACT_POLICY, conv2d
from .common import dense_init, split_keys


@dataclass(frozen=True)
class ResNetConfig:
    n_blocks: int = 1                   # blocks per stage; depth = 6n+2
    widths: tuple = (16, 32, 64)
    n_classes: int = 10
    image_size: int = 32
    norm_eps: float = 1e-5

    @property
    def depth(self) -> int:
        return 6 * self.n_blocks + 2

    @property
    def name(self) -> str:
        return f"resnet{self.depth}"


def resnet_config(depth: int) -> ResNetConfig:
    assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6n+2"
    return ResNetConfig(n_blocks=(depth - 2) // 6)


def _conv_init(key, kh, kw, cin, cout):
    fan = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) \
        * np.sqrt(2.0 / fan)


def init_params(key, cfg: ResNetConfig) -> dict:
    keys = jax.random.split(key, 2 + 3 * cfg.n_blocks * 3 + 4)
    ki = iter(range(len(keys)))
    params = {
        "conv_init": {"w": _conv_init(keys[next(ki)], 3, 3, 3,
                                      cfg.widths[0]),
                      "bn_g": jnp.ones((cfg.widths[0],)),
                      "bn_b": jnp.zeros((cfg.widths[0],))},
    }
    cin = cfg.widths[0]
    for s, width in enumerate(cfg.widths):
        for b in range(cfg.n_blocks):
            blk = {
                "conv1": {"w": _conv_init(keys[next(ki)], 3, 3, cin, width),
                          "bn_g": jnp.ones((width,)),
                          "bn_b": jnp.zeros((width,))},
                "conv2": {"w": _conv_init(keys[next(ki)], 3, 3, width,
                                          width),
                          "bn_g": jnp.ones((width,)),
                          "bn_b": jnp.zeros((width,))},
            }
            if cin != width:
                blk["proj"] = {"w": _conv_init(keys[next(ki)], 1, 1, cin,
                                               width)}
            params[f"s{s}_b{b}"] = blk
            cin = width
    params["head"] = {
        "w": dense_init(keys[next(ki)], (cfg.widths[-1], cfg.n_classes)),
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params


def _bn(x, g, b, eps):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def forward(params, images, cfg: ResNetConfig,
            policy: ApproxPolicy = EXACT_POLICY) -> jax.Array:
    """images: (B,H,W,3) f32 -> logits (B, n_classes)."""
    x = conv2d(policy, "conv_init", images, params["conv_init"]["w"])
    x = _bn(x, params["conv_init"]["bn_g"], params["conv_init"]["bn_b"],
            cfg.norm_eps)
    x = jax.nn.relu(x)
    cin = cfg.widths[0]
    for s, width in enumerate(cfg.widths):
        for b in range(cfg.n_blocks):
            name = f"s{s}_b{b}"
            blk = params[name]
            stride = 2 if (s > 0 and b == 0) else 1
            y = conv2d(policy, f"{name}_conv1", x, blk["conv1"]["w"],
                       stride=stride)
            y = _bn(y, blk["conv1"]["bn_g"], blk["conv1"]["bn_b"],
                    cfg.norm_eps)
            y = jax.nn.relu(y)
            y = conv2d(policy, f"{name}_conv2", y, blk["conv2"]["w"])
            y = _bn(y, blk["conv2"]["bn_g"], blk["conv2"]["bn_b"],
                    cfg.norm_eps)
            if "proj" in blk:
                sc = conv2d(policy, f"{name}_proj", x, blk["proj"]["w"],
                            stride=stride)
            else:
                sc = x
            x = jax.nn.relu(y + sc)
            cin = width
    x = jnp.mean(x, axis=(1, 2))
    return policy.matmul("head", x, params["head"]["w"]) + params["head"]["b"]


def layer_mult_counts(cfg: ResNetConfig, batch: int = 1) -> dict[str, int]:
    """Per-conv-layer multiplication counts (the paper's Fig. 4 shares).
    Layer names match the policy tags in ``forward``.  Shim over the
    unified ``repro.approx.workload.layer_mult_counts`` accounting
    (DESIGN.md §2.12), preserving the historical conv-only contract —
    the unified map also counts the ``head`` matmul."""
    from repro.approx.workload import layer_mult_counts as unified
    counts = unified(cfg, batch=batch)
    counts.pop("head", None)
    return counts


def loss_fn(params, batch, cfg: ResNetConfig,
            policy: ApproxPolicy = EXACT_POLICY) -> jax.Array:
    logits = forward(params, batch["images"], cfg, policy)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(params, batch, cfg: ResNetConfig,
             policy: ApproxPolicy = EXACT_POLICY) -> jax.Array:
    logits = forward(params, batch["images"], cfg, policy)
    return jnp.mean((jnp.argmax(logits, axis=-1) == batch["labels"]
                     ).astype(jnp.float32))
