"""Serving layer: static-batch ``Engine`` and the continuous-batching
multi-tenant stack (``ContinuousEngine`` + ``Scheduler`` +
``PagedKVCache``; DESIGN.md §2.8)."""
from .engine import ContinuousEngine, Engine, ServeConfig
from .kv_cache import CacheLayout, PagedKVCache, cache_layout
from .scheduler import Request, RequestState, Scheduler

__all__ = ["ContinuousEngine", "Engine", "ServeConfig", "CacheLayout",
           "PagedKVCache", "cache_layout", "Request", "RequestState",
           "Scheduler"]
