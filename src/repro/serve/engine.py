"""Batched serving engine: prefill + greedy/temperature decode with a
static KV cache, jitted end-to-end.  The approximate-multiplier backend
(int8 + LUT/lowrank) is selected per request batch via ApproxPolicy —
this is the "accelerator being emulated" serving path.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import ApproxPolicy, EXACT_POLICY
from repro.models.common import LMConfig
from repro.models.registry import model_fns


@dataclass
class ServeConfig:
    max_new_tokens: int = 16
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, cfg: LMConfig, params,
                 policy: ApproxPolicy = EXACT_POLICY):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.fns = model_fns(cfg)
        self._prefill = jax.jit(
            lambda p, b, c: self.fns.forward_prefill(p, b, c, cfg, policy))
        self._decode = jax.jit(
            lambda p, t, c: self.fns.forward_decode(p, t, c, cfg, policy))

    def generate(self, prompts: np.ndarray, serve_cfg: ServeConfig,
                 extras: Optional[dict] = None) -> np.ndarray:
        """prompts: (B, S) int32. Returns (B, max_new_tokens) int32."""
        b, s = prompts.shape
        max_len = s + serve_cfg.max_new_tokens
        cache = self.fns.init_cache(self.cfg, b, max_len)
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        logits, cache = self._prefill(self.params, batch, cache)
        key = jax.random.PRNGKey(serve_cfg.seed)
        out = []
        tok = self._sample(logits, serve_cfg, key)
        out.append(tok)
        for i in range(serve_cfg.max_new_tokens - 1):
            logits, cache = self._decode(self.params, tok, cache)
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, serve_cfg, key)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)

    @staticmethod
    def _sample(logits, serve_cfg: ServeConfig, key) -> jax.Array:
        if serve_cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / serve_cfg.temperature, axis=-1).astype(jnp.int32)
