"""Batched serving engine: prefill + greedy/temperature decode with a
static KV cache, jitted end-to-end.  The approximate-multiplier backend
(int8 + LUT/lowrank) is selected per request batch via ApproxPolicy —
this is the "accelerator being emulated" serving path.

Policies are spec-first (DESIGN.md §2): a request may carry a
serialized policy (``ServeConfig.policy``, the ``to_json_dict`` form),
and the engine materializes it against its library and keeps one jitted
(prefill, decode) pair per distinct policy — switching the emulated
accelerator per request costs a dict lookup after the first use.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import ApproxPolicy, EXACT_POLICY
from repro.models.common import LMConfig
from repro.models.registry import model_fns


@dataclass
class ServeConfig:
    max_new_tokens: int = 16
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0
    # Per-request accelerator selection: a serialized ApproxPolicy —
    # the ``to_json_dict()`` dict or the ``to_json()`` string, either
    # uniform or heterogeneous (one override per layer, e.g. an
    # ``explore_heterogeneous`` selection); None = the engine default.
    # Width-generic (DESIGN.md §2.6): specs may name composed 12/16-bit
    # entries and carry ``bit_width``/``reduce_adder`` — the JSON shape
    # is unchanged and width claims are validated at materialization
    # (typed WidthMismatchError/LutWidthError on disagreement).
    policy: Optional[Union[dict, str]] = None


class Engine:
    def __init__(self, cfg: LMConfig, params,
                 policy: ApproxPolicy = EXACT_POLICY,
                 library=None):
        self.cfg = cfg
        self.params = params
        self._library = library
        self.policy = policy.materialize(library)
        # LRU of jitted (prefill, decode) pairs keyed by policy spec —
        # bounded so a client sweeping per-request policies cannot grow
        # compile caches without limit.
        self._steps: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._steps_max = 8
        self.fns = model_fns(cfg)
        self._prefill, self._decode = self._steps_for(self.policy)

    def _steps_for(self, policy: ApproxPolicy) -> tuple:
        """One jitted (prefill, decode) pair per distinct policy spec."""
        key = policy.cache_key()
        if key in self._steps:
            self._steps.move_to_end(key)
            return self._steps[key]
        cfg = self.cfg
        prefill = jax.jit(
            lambda p, b, c: self.fns.forward_prefill(p, b, c, cfg,
                                                     policy))
        decode = jax.jit(
            lambda p, t, c: self.fns.forward_decode(p, t, c, cfg,
                                                    policy))
        self._steps[key] = (prefill, decode)
        while len(self._steps) > self._steps_max:
            self._steps.popitem(last=False)
        return self._steps[key]

    def _request_policy(self, serve_cfg: "ServeConfig") -> ApproxPolicy:
        if serve_cfg.policy is None:
            return self.policy
        req = ApproxPolicy.from_json(serve_cfg.policy)
        return req.materialize(self._library)

    def generate(self, prompts: np.ndarray, serve_cfg: ServeConfig,
                 extras: Optional[dict] = None) -> np.ndarray:
        """prompts: (B, S) int32. Returns (B, max_new_tokens) int32."""
        prefill, decode = self._steps_for(self._request_policy(serve_cfg))
        b, s = prompts.shape
        max_len = s + serve_cfg.max_new_tokens
        cache = self.fns.init_cache(self.cfg, b, max_len)
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        logits, cache = prefill(self.params, batch, cache)
        key = jax.random.PRNGKey(serve_cfg.seed)
        out = []
        tok = self._sample(logits, serve_cfg, key)
        out.append(tok)
        for i in range(serve_cfg.max_new_tokens - 1):
            logits, cache = decode(self.params, tok, cache)
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, serve_cfg, key)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)

    @staticmethod
    def _sample(logits, serve_cfg: ServeConfig, key) -> jax.Array:
        if serve_cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / serve_cfg.temperature, axis=-1).astype(jnp.int32)
