"""Batched serving engine: prefill + greedy/temperature decode with a
static KV cache, jitted end-to-end.  The approximate-multiplier backend
(int8 + LUT/lowrank) is selected per request batch via ApproxPolicy —
this is the "accelerator being emulated" serving path.

Policies are spec-first (DESIGN.md §2): a request may carry a
serialized policy (``ServeConfig.policy``, the ``to_json_dict`` form),
and the engine materializes it against its library and keeps one jitted
(prefill, decode) pair per distinct policy — switching the emulated
accelerator per request costs a dict lookup after the first use.
"""
from __future__ import annotations

import time
from collections import Counter, OrderedDict
from contextlib import contextmanager
from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx.layers import (ApproxPolicy, EXACT_POLICY,
                                 bank_assignment_overrides)
from repro.approx.specs import BackendSpec, bank_for, policy_assignment
from repro.models.common import LMConfig
from repro.models.registry import (input_extras, model_fns,
                                   probe_layer_tags, prompt_extra_len)


@dataclass
class ServeConfig:
    max_new_tokens: int = 16
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0
    # Per-request accelerator selection: a serialized ApproxPolicy —
    # the ``to_json_dict()`` dict or the ``to_json()`` string, either
    # uniform or heterogeneous (one override per layer, e.g. an
    # ``explore_heterogeneous`` selection); None = the engine default.
    # Width-generic (DESIGN.md §2.6): specs may name composed 12/16-bit
    # entries and carry ``bit_width``/``reduce_adder`` — the JSON shape
    # is unchanged and width claims are validated at materialization
    # (typed WidthMismatchError/LutWidthError on disagreement).
    policy: Optional[Union[dict, str]] = None


class Engine:
    def __init__(self, cfg: LMConfig, params,
                 policy: ApproxPolicy = EXACT_POLICY,
                 library=None):
        self.cfg = cfg
        self.params = params
        self._library = library
        self.policy = policy.materialize(library)
        # LRU of jitted (prefill, decode) pairs keyed by policy spec —
        # bounded so a client sweeping per-request policies cannot grow
        # compile caches without limit.
        self._steps: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._steps_max = 8
        # keys with in-flight generates: eviction must skip these — an
        # evicted-then-reinserted pair would recompile mid-decode (and
        # a concurrent sweep of other policies could thrash it every
        # step).  The cache may temporarily exceed _steps_max when all
        # entries are pinned.
        self._pinned: "Counter[tuple]" = Counter()
        self.fns = model_fns(cfg)
        self._prefill, self._decode = self._steps_for(self.policy)

    @contextmanager
    def _pin(self, key: tuple):
        """Hold a policy's (prefill, decode) pair in the LRU for the
        duration of a request (re-entrant: a Counter, not a set)."""
        self._pinned[key] += 1
        try:
            yield
        finally:
            self._pinned[key] -= 1
            if self._pinned[key] <= 0:
                del self._pinned[key]

    def _steps_for(self, policy: ApproxPolicy) -> tuple:
        """One jitted (prefill, decode) pair per distinct policy spec."""
        key = policy.cache_key()
        if key in self._steps:
            self._steps.move_to_end(key)
            return self._steps[key]
        cfg = self.cfg
        prefill = jax.jit(
            lambda p, b, c: self.fns.forward_prefill(p, b, c, cfg,
                                                     policy))
        decode = jax.jit(
            lambda p, t, c: self.fns.forward_decode(p, t, c, cfg,
                                                    policy))
        self._steps[key] = (prefill, decode)
        while len(self._steps) > self._steps_max:
            victim = next((k for k in self._steps
                           if k != key and not self._pinned.get(k)),
                          None)
            if victim is None:
                break                   # everything in flight: overshoot
            del self._steps[victim]
        return self._steps[key]

    def _request_policy(self, serve_cfg: "ServeConfig") -> ApproxPolicy:
        if serve_cfg.policy is None:
            return self.policy
        req = ApproxPolicy.from_json(serve_cfg.policy)
        return req.materialize(self._library)

    def generate(self, prompts: np.ndarray, serve_cfg: ServeConfig,
                 extras: Optional[dict] = None) -> np.ndarray:
        """prompts: (B, S) int32. Returns (B, max_new_tokens) int32."""
        policy = self._request_policy(serve_cfg)
        with self._pin(policy.cache_key()):
            prefill, decode = self._steps_for(policy)
            b, s = prompts.shape
            max_len = s + serve_cfg.max_new_tokens
            if extras:
                max_len += prompt_extra_len(self.cfg, extras)
            cache = self.fns.init_cache(self.cfg, b, max_len)
            batch = {"tokens": jnp.asarray(prompts)}
            if extras:
                batch.update({k: jnp.asarray(v)
                              for k, v in extras.items()})
            logits, cache = prefill(self.params, batch, cache)
            key = jax.random.PRNGKey(serve_cfg.seed)
            out = []
            tok = self._sample(logits, serve_cfg, key)
            out.append(tok)
            for i in range(serve_cfg.max_new_tokens - 1):
                logits, cache = decode(self.params, tok, cache)
                key = jax.random.fold_in(key, i)
                tok = self._sample(logits, serve_cfg, key)
                out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)

    @staticmethod
    def _sample(logits, serve_cfg: ServeConfig, key) -> jax.Array:
        if serve_cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / serve_cfg.temperature, axis=-1).astype(jnp.int32)


# ----------------------------------------------------------------------
# Continuous batching (DESIGN.md §2.8)
# ----------------------------------------------------------------------
def _sample_lane(logits, temp, key) -> jax.Array:
    """Traced per-slot sampler, semantics-identical to
    ``Engine._sample`` on a (1, V) logits row but with the temperature
    branch resolved by ``jnp.where`` so one program serves greedy and
    sampled slots in the same batch."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    sampled = jax.random.categorical(
        key, logits / jnp.maximum(temp, 1e-6), axis=-1).astype(jnp.int32)
    return jnp.where(temp > 0.0, sampled, greedy)[0]


class ContinuousEngine:
    """Continuous-batching multi-tenant engine: request scheduler +
    paged KV cache + mixed-policy decode in ONE compiled program.

    Each in-flight request occupies a *slot* of a fixed-shape decode
    step; requests join at decode-step boundaries (prefill on
    admission, one banked jit trace per prompt shape) and retire on
    max-tokens, so the compiled step never reshapes.  Per-request
    ``ServeConfig.policy`` entries are resolved against the model's
    probed layer tags (``policy_assignment``) into lanes of a shared
    ``LutBank``; the decode step vmaps over slots, each lane rebuilding
    its policy from traced ``luts[assign[slot, j]]`` gathers
    (``bank_assignment_overrides`` — the same machinery as
    ``policy_bank_eval``), so N distinct tenant policies decode in O(1)
    compiled programs.  KV state lives in a ``PagedKVCache``
    (fixed-size blocks, free-list allocator, per-slot block tables);
    every registry family serves through the same structural probing.

    Token streams are bit-identical to per-request sequential
    ``Engine.generate`` with the same ``ServeConfig`` (asserted by
    ``tests/test_serve.py`` and gated in ``BENCH_serve.json``): paged
    gathers reproduce the contiguous cache exactly where attention can
    see it, vmap lanes match B=1 sequential math bitwise, and the
    per-slot PRNG chain replays ``generate``'s iterative ``fold_in``.

    ``multipliers`` optionally fixes the bank's lane set up front
    (anything outside it is rejected at submit); by default the bank
    grows on first use of a new multiplier, recompiling the step once
    per growth (counted in ``trace_counts['decode']``).  ``sharding``
    (``repro.launch.mesh.slot_sharding``) places the slot axis — and
    with it the whole vmapped decode — across devices.
    """

    def __init__(self, cfg: LMConfig, params, *, library=None,
                 multipliers=None, default_policy=None,
                 n_slots: int = 4, capacity: int = 64,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 mode: str = "lut", variant: str = "ref",
                 block_m: int = 512, base: Optional[BackendSpec] = None,
                 sharding=None):
        from .kv_cache import PagedKVCache
        from .scheduler import Request, RequestState, Scheduler
        self._Request, self._RequestState = Request, RequestState
        self.cfg = cfg
        self.params = params
        self.fns = model_fns(cfg)
        self._library = library
        self.mode, self.variant, self.block_m = mode, variant, block_m
        self.capacity, self.n_slots = int(capacity), int(n_slots)
        self.layers = probe_layer_tags(cfg, params)
        if default_policy is None:
            default_policy = ApproxPolicy(default=BackendSpec(
                mode=mode, multiplier="mul8u_exact", block_m=block_m,
                ste=False, variant=variant))
        elif not isinstance(default_policy, ApproxPolicy):
            default_policy = ApproxPolicy.from_json(default_policy)
        self.default_policy = default_policy
        self.base = (base if base is not None
                     else BackendSpec.golden()).materialize(library)
        self.kv = PagedKVCache(self.fns, cfg, n_slots=self.n_slots,
                               capacity=self.capacity,
                               block_size=block_size, n_blocks=n_blocks)
        self.scheduler = Scheduler(self.n_slots)
        self._sharding = sharding
        # per-slot host state (device-transferred each step)
        n = self.n_slots
        self._tokens = np.zeros(n, np.int32)
        self._lengths = np.zeros(n, np.int32)
        self._n_gen = np.zeros(n, np.int32)
        self._active = np.zeros(n, bool)
        self._temps = np.zeros(n, np.float32)
        self._keys = np.zeros((n, 2), np.uint32)
        self._assign = np.zeros((n, len(self.layers)), np.int32)
        # shared bank (grows unless `multipliers` fixes it)
        self.trace_counts = {"prefill": 0, "decode": 0, "bank_builds": 0}
        self._fixed_bank = multipliers is not None
        self._names: list[str] = []
        self._bank = None
        self._rid = 0
        self.step_count = 0
        seed_names = list(multipliers) if multipliers else []
        for m in policy_assignment(self.default_policy, self.layers,
                                   mode=mode, block_m=block_m).values():
            if m not in seed_names:
                if self._fixed_bank:
                    raise ValueError(
                        f"default policy needs {m!r}, which is not in "
                        f"the fixed multiplier set {multipliers}")
                seed_names.append(m)
        self._fixed_bank = False        # allow the seed build
        self._grow_bank(seed_names)
        self._fixed_bank = multipliers is not None

    # -- bank assembly --------------------------------------------------
    def _grow_bank(self, new_names) -> None:
        self._names.extend(n for n in new_names if n not in self._names)
        self._bank = bank_for(tuple(self._names), self._library,
                              block_m=self.block_m)
        self._luts = jnp.asarray(self._bank.luts)
        self._bits = jnp.asarray(self._bank.lane_bits, jnp.int32)
        self._masks = jnp.asarray(self._bank.lane_masks, jnp.uint32)
        # any_wide / reduce are static program structure: rebuild the
        # jitted steps (the lut-count change would retrace them anyway)
        self._decode_fn = self._make_decode(self._bank)
        self._prefill_fn = self._make_prefill(self._bank)
        self.trace_counts["bank_builds"] += 1

    def _resolve_policy(self, serve: ServeConfig) -> np.ndarray:
        """Request policy → per-layer bank-lane row, growing the shared
        bank when a (non-fixed) engine first sees a multiplier."""
        policy = (self.default_policy if serve.policy is None
                  else ApproxPolicy.from_json(serve.policy))
        assignment = policy_assignment(policy, self.layers,
                                       mode=self.mode,
                                       block_m=self.block_m)
        new = [m for m in dict.fromkeys(assignment.values())
               if m not in self._names]
        if new:
            if self._fixed_bank:
                raise ValueError(
                    f"request needs multipliers {new} outside the "
                    f"engine's fixed bank {self._names}")
            self._grow_bank(new)
        index = {m: i for i, m in enumerate(self._bank.names)}
        return np.asarray([index[assignment[l]] for l in self.layers],
                          np.int32)

    def lane_policy(self, serve: ServeConfig) -> ApproxPolicy:
        """The sequential (materialized) policy a slot running this
        request emulates — ``base`` everywhere, request multiplier per
        probed layer.  Sequential ``Engine.generate`` under this policy
        is the bit-identity reference for the banked lane."""
        policy = (self.default_policy if serve.policy is None
                  else ApproxPolicy.from_json(serve.policy))
        assignment = policy_assignment(policy, self.layers,
                                       mode=self.mode,
                                       block_m=self.block_m)
        overrides = [
            (layer, BackendSpec(mode=self.mode, multiplier=name,
                                block_m=self.block_m, ste=False,
                                variant=self.variant))
            for layer, name in assignment.items()]
        return ApproxPolicy(default=self.base,
                            overrides=overrides).materialize(self._library)

    # -- compiled steps -------------------------------------------------
    def _overrides(self, bank, luts, bits, masks, assign_row):
        return bank_assignment_overrides(
            bank, luts, assign_row, self.layers, mode=self.mode,
            variant=self.variant,
            lane_bits=bits if bank.any_wide else None,
            lane_masks=masks if bank.any_wide else None)

    def _make_prefill(self, bank):
        cfg, fns, counts = self.cfg, self.fns, self.trace_counts
        capacity, base = self.capacity, self.base

        def prefill(params, luts, bits, masks, assign_row, batch, temp,
                    key0):
            counts["prefill"] += 1
            cache = fns.init_cache(cfg, 1, capacity)
            policy = ApproxPolicy(
                default=base,
                overrides=self._overrides(bank, luts, bits, masks,
                                          assign_row))
            logits, cache = fns.forward_prefill(params, batch, cache,
                                                cfg, policy)
            return _sample_lane(logits, temp, key0), cache

        return jax.jit(prefill)

    def _make_decode(self, bank):
        cfg, fns, counts = self.cfg, self.fns, self.trace_counts
        layout, base = self.kv.layout, self.base
        bs = self.kv.block_size
        n_rows = self.kv.n_blocks * self.kv.block_size
        from .kv_cache import (physical_indices, slot_gather_leaves,
                               token_rows)

        def step(params, luts, bits, masks, assign, pools, dense,
                 tables, tokens, lengths, active, temps, keys, n_gen):
            counts["decode"] += 1
            phys = physical_indices(tables, layout.capacity, bs)

            def lane(assign_row, phys_s, dense_row, token, length,
                     temp, key0, gen):
                leaves = slot_gather_leaves(layout, pools, dense_row,
                                            phys_s)
                cache = jax.tree_util.tree_unflatten(layout.treedef,
                                                     leaves)
                policy = ApproxPolicy(
                    default=base,
                    overrides=self._overrides(bank, luts, bits, masks,
                                              assign_row))
                logits, new_cache = fns.forward_decode(
                    params, token[None], cache, cfg, policy)
                new_leaves = jax.tree_util.tree_leaves(new_cache)
                rows = token_rows(layout, new_leaves, length)
                dense_new = tuple(
                    l for l, t in zip(new_leaves, layout.seq_axes)
                    if t is None)
                # replay generate()'s iterative key chain for this
                # slot's step index (gen = tokens already emitted)
                key = jax.lax.fori_loop(
                    0, gen, lambda i, k: jax.random.fold_in(k, i), key0)
                return _sample_lane(logits, temp, key), tuple(rows), \
                    dense_new

            toks, rows, dense_new = jax.vmap(lane)(
                assign, phys, tuple(dense), tokens, lengths, temps,
                keys, n_gen)
            # scatter each slot's new row at its next logical position.
            # Inactive slots get an out-of-bounds POSITIVE sentinel so
            # mode="drop" really drops them: -1 would WRAP (negative
            # indices are in-bounds in JAX) and clobber the last pool
            # row of whichever request owns the last block.
            widx = jnp.where(
                active,
                jnp.take_along_axis(phys, lengths[:, None], axis=1)[:, 0],
                n_rows)
            new_pools = tuple(
                p.at[widx].set(r.astype(p.dtype), mode="drop")
                for p, r in zip(pools, rows))

            def keep_active(new, old):
                m = active.reshape((-1,) + (1,) * (new.ndim - 1))
                return jnp.where(m, new.astype(old.dtype), old)

            new_dense = tuple(keep_active(n_, o)
                              for n_, o in zip(dense_new, dense))
            return jnp.where(active, toks, tokens), new_pools, new_dense

        return jax.jit(step)

    # -- request lifecycle ----------------------------------------------
    def submit(self, prompt, serve: Optional[ServeConfig] = None,
               extras: Optional[dict] = None,
               rid: Optional[str] = None) -> str:
        """Queue one request.  Policy resolution (and therefore bank
        membership validation) happens here, so a bad policy fails the
        submit, not a later step."""
        serve = serve if serve is not None else ServeConfig()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if rid is None:
            rid = f"r{self._rid}"
            self._rid += 1
        if extras is None:
            extras = input_extras(self.cfg, 1) or None
        assign_row = self._resolve_policy(serve)
        prefill_len = len(prompt) + prompt_extra_len(self.cfg, extras)
        total_len = prefill_len + serve.max_new_tokens
        # decode at the last position still writes row total_len - 1
        if total_len > self.capacity:
            raise ValueError(
                f"request {rid!r} needs {total_len} cache rows "
                f"(prefill {prefill_len} + {serve.max_new_tokens} new); "
                f"engine capacity is {self.capacity}")
        state = self._RequestState(
            request=self._Request(rid=rid, prompt=prompt, serve=serve,
                                  extras=extras),
            assign_row=assign_row, prefill_len=prefill_len,
            total_len=total_len)
        self.scheduler.submit(state, self.step_count)
        return rid

    def _retire(self) -> list:
        done = [st for st in self.scheduler.running.values() if st.done]
        for st in done:
            slot = st.slot
            self.kv.release(slot)
            self._active[slot] = False
            self.scheduler.finish(st, self.step_count)
        return done

    def _admit(self) -> list:
        admitted = []
        while True:
            st = self.scheduler.head()
            if st is None or not self.scheduler.free_slots():
                break
            if not self.kv.can_allocate(self.kv.blocks_needed(
                    st.total_len)):
                break                   # strict FIFO: head blocks queue
            st = self.scheduler.admit(self.step_count)
            slot = st.slot
            self.kv.allocate(slot, st.total_len)
            serve = st.request.serve
            batch = {"tokens": jnp.asarray(st.request.prompt[None])}
            if st.request.extras:
                batch.update({k: jnp.asarray(np.asarray(v))
                              for k, v in st.request.extras.items()})
            key0 = np.asarray(jax.random.PRNGKey(serve.seed))
            tok, cache = self._prefill_fn(
                self.params, self._luts, self._bits, self._masks,
                jnp.asarray(st.assign_row), batch,
                jnp.float32(serve.temperature), jnp.asarray(key0))
            self.kv.write_prefill(slot, cache, st.prefill_len)
            st.tokens.append(int(tok))
            self._tokens[slot] = int(tok)
            self._lengths[slot] = st.prefill_len
            self._n_gen[slot] = 1
            self._temps[slot] = serve.temperature
            self._keys[slot] = key0
            self._assign[slot] = st.assign_row
            self._active[slot] = not st.done    # max_new==1: retire next
            admitted.append(st)
        return admitted

    def _place(self, x):
        if self._sharding is None:
            return jnp.asarray(x)
        from repro.launch.mesh import leading_axis_sharding
        return jax.device_put(
            jnp.asarray(x),
            leading_axis_sharding(self._sharding, np.ndim(x)))

    def _decode_once(self) -> bool:
        if not self._active.any():
            return False
        toks, pools, dense = self._decode_fn(
            self.params, self._luts, self._bits, self._masks,
            self._place(self._assign), tuple(self.kv.pools),
            tuple(self._place(d) for d in self.kv.dense),
            self._place(self.kv.block_tables),
            self._place(self._tokens), self._place(self._lengths),
            self._place(self._active), self._place(self._temps),
            self._place(self._keys), self._place(self._n_gen))
        self.kv.pools = list(pools)
        self.kv.dense = list(dense)
        toks = np.asarray(toks)
        for slot, st in self.scheduler.running.items():
            if not self._active[slot]:
                continue
            st.tokens.append(int(toks[slot]))
            self._tokens[slot] = toks[slot]
            self._lengths[slot] += 1
            self._n_gen[slot] += 1
            if st.done:
                self._active[slot] = False   # retired next step
        return True

    def step(self) -> dict:
        """One decode-step boundary: retire finished requests, admit
        from the queue (prefill + KV block reservation), run one
        mixed-policy decode step over all active slots."""
        self.step_count += 1
        finished = self._retire()
        admitted = self._admit()
        decoded = self._decode_once()
        if not (finished or admitted or decoded) and \
                self.scheduler.pending:
            st = self.scheduler.head()
            raise RuntimeError(
                f"scheduler stalled: request {st.rid!r} needs "
                f"{self.kv.blocks_needed(st.total_len)} blocks / a "
                f"free slot and none can ever free up")
        return {"step": self.step_count, "finished": finished,
                "admitted": admitted, "decoded": decoded,
                "n_active": int(self._active.sum()),
                "n_pending": len(self.scheduler.pending)}

    def run(self) -> dict:
        """Drive steps until the queue and batch drain; returns
        {rid: (max_new_tokens,) int32} in submission order."""
        while not self.scheduler.idle:
            self.step()
        return {st.rid: np.asarray(st.tokens, np.int32)
                for st in self.scheduler.finished.values()}
