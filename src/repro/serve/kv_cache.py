"""Paged/blocked KV cache for continuous batching (DESIGN.md §2.8).

The contiguous per-request caches the model families build
(``init_cache(cfg, batch, max_len)``) don't compose into a multi-tenant
server: a request's cache is sized to ITS max length, and joining /
retiring requests would reshape the batch axis and retrace.  This
module virtualizes the *sequence* axis instead, vLLM-style:

  * ``cache_layout`` probes a family's cache pytree structurally — it
    abstractly initializes at two capacities and marks, per leaf, the
    axis whose extent changed as the sequence (T) axis.  No per-family
    code: dense KV ``(G,B,T,H,D)``, MLA ``(B,T,kv_lora)``, encdec
    self-KV ``(L,B,T,H,D)`` all identify their own T axis, while
    non-sequence leaves (``pos`` scalars, mamba conv/ssm state, encdec
    cross-KV) are marked dense.
  * Sequence leaves live in fixed-size-block *pools* shaped
    ``(n_blocks * block_size, *rest)`` (T axis moved to the front);
    a free-list allocator hands blocks to requests, and a per-slot
    block table maps logical position → physical pool row.
  * Non-sequence leaves live in a slot-major dense store
    ``(n_slots, *leaf_shape)``.

``slot_gather_leaves`` / ``token_rows`` are the *traced* halves: inside
the engine's jitted decode step each vmap lane gathers its slot's
logical view ``pool[block_table[t // bs] * bs + t % bs]`` back into the
exact pytree ``init_cache`` would have built, runs the unmodified model
``forward_decode``, and returns the one new row to scatter.  Because
attention masks with a -1e30 bias (exact zeros after softmax), the
gathered tail garbage beyond a request's length never contributes —
paged decode is bit-identical to contiguous decode, which
``tests/test_serve.py`` asserts per family.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class CacheLayout:
    """Structural description of ONE request's cache pytree: treedef +
    per-leaf shape/dtype, with the sequence axis identified per leaf
    (None = non-sequence leaf).  ``capacity`` is the probed max_len —
    every slot's logical sequence space."""

    treedef: Any
    shapes: tuple
    dtypes: tuple
    seq_axes: tuple          # per leaf: T-axis index, or None
    capacity: int

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    @property
    def seq_positions(self) -> tuple:
        return tuple(i for i, t in enumerate(self.seq_axes)
                     if t is not None)

    @property
    def dense_positions(self) -> tuple:
        return tuple(i for i, t in enumerate(self.seq_axes) if t is None)


def cache_layout(fns, cfg, capacity: int) -> CacheLayout:
    """Probe ``fns.init_cache``'s pytree for the sequence axes by
    abstract double-initialization at ``capacity`` and ``capacity+1``:
    the axis whose extent differs is the T axis.  eval_shape only — no
    cache is materialized."""
    a = jax.eval_shape(lambda: fns.init_cache(cfg, 1, capacity))
    b = jax.eval_shape(lambda: fns.init_cache(cfg, 1, capacity + 1))
    la, treedef = jax.tree_util.tree_flatten(a)
    lb, treedef_b = jax.tree_util.tree_flatten(b)
    if treedef != treedef_b:
        raise ValueError("init_cache structure depends on max_len; "
                         "cannot page this family")
    seq_axes = []
    for xa, xb in zip(la, lb):
        diff = [i for i, (p, q) in enumerate(zip(xa.shape, xb.shape))
                if p != q]
        if len(diff) > 1:
            raise ValueError(
                f"cache leaf {xa.shape} varies on {len(diff)} axes with "
                "max_len; paging supports exactly one sequence axis")
        seq_axes.append(diff[0] if diff else None)
    return CacheLayout(treedef=treedef,
                       shapes=tuple(x.shape for x in la),
                       dtypes=tuple(x.dtype for x in la),
                       seq_axes=tuple(seq_axes),
                       capacity=capacity)


# ----------------------------------------------------------------------
# Traced helpers (used inside the engine's jitted step)
# ----------------------------------------------------------------------
def physical_indices(block_tables, capacity: int, block_size: int):
    """(n_slots, blocks_per_slot) block tables → (n_slots, capacity)
    physical pool rows: ``table[t // bs] * bs + t % bs``.  Unallocated
    table entries (-1) yield negative rows — gathers clip them (the
    rows they'd read are masked out of attention anyway); scatters must
    NOT rely on ``mode="drop"`` for them (negative indices wrap in
    JAX) and replace them with an out-of-range positive sentinel."""
    logical = jnp.arange(capacity, dtype=jnp.int32)
    return (jnp.take(block_tables, logical // block_size, axis=-1)
            * block_size + logical % block_size)


def slot_gather_leaves(layout: CacheLayout, pools, dense_row, phys):
    """Rebuild ONE slot's cache leaves (request-shaped, B=1) from the
    pools + its dense-store row.  ``phys``: (capacity,) physical rows.
    Returns leaves in ``layout.treedef`` order."""
    leaves, pi, di = [], 0, 0
    for t in layout.seq_axes:
        if t is None:
            leaves.append(dense_row[di])
            di += 1
        else:
            pool = pools[pi]
            idx = jnp.clip(phys, 0, pool.shape[0] - 1)
            # clip, don't rely on jnp.take's OOB fill: NaN fill would
            # poison masked attention scores (NaN survives the mask)
            leaves.append(jnp.moveaxis(jnp.take(pool, idx, axis=0),
                                       0, t))
            pi += 1
    return leaves


def token_rows(layout: CacheLayout, new_leaves, pos):
    """Extract the one new row (logical position ``pos``) each sequence
    leaf gained this decode step — the rows the engine scatters back
    into the pools."""
    rows = []
    for leaf, t in zip(new_leaves, layout.seq_axes):
        if t is not None:
            rows.append(jax.lax.dynamic_index_in_dim(
                leaf, pos, axis=t, keepdims=False))
    return rows


# ----------------------------------------------------------------------
# Host-side cache object
# ----------------------------------------------------------------------
class PagedKVCache:
    """Block pools + dense store + free-list allocator + block tables.

    One instance serves all slots of a ``ContinuousEngine``; a family
    with no sequence leaves (pure SSM: conv + state carry, O(1) decode)
    simply has zero pools and allocates zero blocks per request.
    """

    def __init__(self, fns, cfg, *, n_slots: int, capacity: int,
                 block_size: int = 16, n_blocks: Optional[int] = None):
        self.layout = cache_layout(fns, cfg, capacity)
        self.n_slots = int(n_slots)
        self.block_size = int(block_size)
        self.blocks_per_slot = -(-capacity // block_size)   # ceil
        self.n_blocks = (int(n_blocks) if n_blocks is not None
                         else self.n_slots * self.blocks_per_slot)
        rows = self.n_blocks * self.block_size
        lay = self.layout
        # pools: sequence leaves, T axis first, request dims preserved
        self.pools = [
            jnp.zeros((rows, *[d for i, d in enumerate(lay.shapes[p])
                               if i != lay.seq_axes[p]]), lay.dtypes[p])
            for p in lay.seq_positions]
        # dense store: one request-shaped row per slot
        self.dense = [jnp.zeros((self.n_slots, *lay.shapes[p]),
                                lay.dtypes[p])
                      for p in lay.dense_positions]
        self.block_tables = np.full((self.n_slots, self.blocks_per_slot),
                                    -1, np.int32)
        self._free: list[int] = list(range(self.n_blocks))

    # -- allocator ------------------------------------------------------
    @property
    def n_free_blocks(self) -> int:
        return len(self._free)

    def blocks_needed(self, total_len: int) -> int:
        """Blocks to reserve for a request whose cache will hold
        ``total_len`` rows (prefill + all generated tokens — reserved
        up front so admission can never OOM mid-decode).  Zero for
        sequence-leaf-less families."""
        if not self.layout.seq_positions:
            return 0
        if total_len > self.layout.capacity:
            raise ValueError(f"request needs {total_len} cache rows; "
                             f"engine capacity is {self.layout.capacity}")
        return -(-total_len // self.block_size)

    def can_allocate(self, n_blocks: int) -> bool:
        return n_blocks <= len(self._free)

    def allocate(self, slot: int, total_len: int) -> list[int]:
        n = self.blocks_needed(total_len)
        if not self.can_allocate(n):
            raise RuntimeError(
                f"paged KV exhausted: need {n} blocks, "
                f"{len(self._free)} free")
        if (self.block_tables[slot] >= 0).any():
            raise RuntimeError(f"slot {slot} already holds blocks")
        blocks = [self._free.pop(0) for _ in range(n)]
        self.block_tables[slot, :n] = blocks
        return blocks

    def release(self, slot: int) -> None:
        held = [int(b) for b in self.block_tables[slot] if b >= 0]
        self._free.extend(held)
        self.block_tables[slot] = -1

    def phys_indices(self, slot: int) -> np.ndarray:
        """(capacity,) physical rows for one slot (host-side mirror of
        ``physical_indices``; negative where unallocated)."""
        table = self.block_tables[slot]
        logical = np.arange(self.layout.capacity)
        return (table[logical // self.block_size] * self.block_size
                + logical % self.block_size).astype(np.int32)

    # -- data movement --------------------------------------------------
    def write_prefill(self, slot: int, cache, length: int) -> None:
        """Scatter a freshly prefilled request-shaped cache into this
        slot: the first ``length`` rows of each sequence leaf go to the
        slot's allocated pool rows, non-sequence leaves overwrite the
        slot's dense-store row."""
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        if treedef != self.layout.treedef:
            raise ValueError("prefill cache structure does not match "
                             "the probed layout")
        phys = jnp.asarray(self.phys_indices(slot)[:length])
        pi, di = 0, 0
        for leaf, t in zip(leaves, self.layout.seq_axes):
            if t is None:
                self.dense[di] = self.dense[di].at[slot].set(leaf)
                di += 1
            else:
                rows = jnp.moveaxis(leaf, t, 0)[:length]
                self.pools[pi] = self.pools[pi].at[phys].set(rows)
                pi += 1

    def gather_slot(self, slot: int):
        """Eagerly rebuild one slot's full cache pytree (tests /
        debugging; the jitted path uses ``slot_gather_leaves``)."""
        phys = jnp.asarray(self.phys_indices(slot))
        dense_row = [d[slot] for d in self.dense]
        leaves = slot_gather_leaves(self.layout, self.pools, dense_row,
                                    phys)
        return jax.tree_util.tree_unflatten(self.layout.treedef, leaves)

    def stats(self) -> dict:
        used = self.n_blocks - len(self._free)
        return {"n_blocks": self.n_blocks, "used_blocks": used,
                "free_blocks": len(self._free),
                "block_size": self.block_size,
                "n_pools": len(self.pools), "n_dense": len(self.dense)}
