"""Request queue + admission control for continuous batching
(DESIGN.md §2.8).

The scheduler is pure host-side bookkeeping: requests enter a FIFO
queue on ``submit``, join the running batch at a decode-step boundary
when (a) a slot is free and (b) the paged KV cache can reserve every
block the request will EVER need (prefill + max_new_tokens — reserved
up front, so an admitted request can never be evicted or OOM
mid-decode), and retire on completion (max-tokens), freeing their slot
and blocks for the next queued request.  Admission is strict FIFO: a
head request that doesn't fit blocks the queue rather than being
overtaken (no starvation).

The engine owns the device work; the scheduler only decides *who* is
in the batch each step, and records per-request timing for the load
generator's latency percentiles.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional

import numpy as np

if TYPE_CHECKING:                      # engine imports us at runtime
    from .engine import ServeConfig


@dataclass
class Request:
    """One tenant request: a prompt, a ``ServeConfig`` (which carries
    the per-request serialized ``ApproxPolicy`` — the accelerator this
    tenant selected), and optional prefill extras (encdec frames / vlm
    image embeddings)."""
    rid: str
    prompt: np.ndarray                  # (S,) int32
    serve: ServeConfig
    extras: Optional[dict] = None


@dataclass
class RequestState:
    """Scheduler-side lifecycle record of one request."""
    request: Request
    assign_row: np.ndarray              # (n_layers,) bank lane per layer
    prefill_len: int                    # prompt + prepended extras rows
    total_len: int                      # prefill + max_new (KV budget)
    slot: int = -1
    tokens: list = field(default_factory=list)
    submitted_step: int = -1
    admitted_step: int = -1
    finished_step: int = -1
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def rid(self) -> str:
        return self.request.rid

    @property
    def max_new(self) -> int:
        return self.request.serve.max_new_tokens

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new


class Scheduler:
    def __init__(self, n_slots: int):
        self.n_slots = int(n_slots)
        self.pending: "deque[RequestState]" = deque()
        self.running: dict[int, RequestState] = {}
        self.finished: "OrderedDict[str, RequestState]" = OrderedDict()

    # -- queue ----------------------------------------------------------
    def submit(self, state: RequestState, step: int) -> None:
        state.submitted_step = step
        state.submitted_at = time.monotonic()
        self.pending.append(state)

    def head(self) -> Optional[RequestState]:
        return self.pending[0] if self.pending else None

    def free_slots(self) -> list[int]:
        return sorted(set(range(self.n_slots)) - set(self.running))

    # -- lifecycle ------------------------------------------------------
    def admit(self, step: int) -> RequestState:
        """Pop the FIFO head into the lowest free slot.  The engine
        checks admissibility (free slot + KV blocks) first."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("admit() with no free slot")
        if not self.pending:
            raise RuntimeError("admit() with an empty queue")
        state = self.pending.popleft()
        state.slot = free[0]
        state.admitted_step = step
        state.admitted_at = time.monotonic()
        self.running[state.slot] = state
        return state

    def finish(self, state: RequestState, step: int) -> None:
        if self.running.get(state.slot) is not state:
            raise RuntimeError(f"finish() of a non-running request "
                               f"{state.rid!r}")
        del self.running[state.slot]
        state.finished_step = step
        state.finished_at = time.monotonic()
        self.finished[state.rid] = state
        state.slot = -1

    # -- introspection --------------------------------------------------
    @property
    def idle(self) -> bool:
        return not self.pending and not self.running

    def check_invariants(self, cache=None) -> None:
        """Assert the scheduler/cache joint state is consistent (used
        by tests after every step): slots unique and in range, running
        requests neither pending nor finished, and — given the cache —
        block ownership disjoint with the free list complete."""
        slots = list(self.running)
        assert len(slots) == len(set(slots))
        assert all(0 <= s < self.n_slots for s in slots)
        for slot, st in self.running.items():
            assert st.slot == slot
            assert st not in self.pending
            assert st.rid not in self.finished
            assert len(st.tokens) <= st.max_new
        for st in self.pending:
            assert st.slot == -1 and st.admitted_step == -1
        if cache is not None:
            held = []
            for slot in range(cache.n_slots):
                blocks = [int(b) for b in cache.block_tables[slot]
                          if b >= 0]
                if slot not in self.running:
                    assert not blocks, \
                        f"idle slot {slot} holds blocks {blocks}"
                held.extend(blocks)
            assert len(held) == len(set(held)), "block double-ownership"
            assert not set(held) & set(cache._free)
            assert len(held) + cache.n_free_blocks == cache.n_blocks

    def stats(self) -> dict:
        return {"pending": len(self.pending),
                "running": len(self.running),
                "finished": len(self.finished)}
