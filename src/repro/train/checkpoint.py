"""Fault-tolerant checkpoint manager (DESIGN.md §6).

Design for 1000+ nodes:
  * each host writes only its addressable shards (npz per host) plus a
    tiny JSON manifest — no host ever materializes the global state;
  * writes go to ``<dir>/tmp-<step>`` then one atomic ``os.replace`` to
    ``step-<step>`` (a crashed writer never corrupts the latest ckpt);
  * ``restore`` reads the manifest and reassembles, resharding onto the
    *current* mesh — restoring onto a different device count or mesh
    shape is the elastic-scaling path;
  * ``keep`` latest K checkpoints are retained, older ones GC'd;
  * optional async save on a background thread (the train loop only
    blocks on the previous save's completion).

On this single-process container there is one host shard; the layout,
manifest and reshard-on-restore logic are identical for N hosts.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(template, arrays: dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        treedef, [l for _, l in zip(flat, leaves)])


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- paths ----------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step-{step:09d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step-"):
                try:
                    steps.append(int(d.split("-")[1]))
                except ValueError:
                    continue
        return max(steps) if steps else None

    # -- save -------------------------------------------------------------
    def save(self, step: int, state: Any, metadata: Optional[dict] = None,
             block: bool = True, policy: Optional[Any] = None) -> None:
        """``policy`` (an ``repro.approx.layers.ApproxPolicy``) is
        serialized spec-first into the manifest metadata, so the chosen
        accelerator configuration ships with the weights; recover it
        with ``policy_from_metadata(restore(...)[1])``."""
        if policy is not None:
            metadata = dict(metadata or {})
            metadata["approx_policy"] = policy.to_json_dict()
        self.wait()  # one outstanding async save at a time
        if self.async_save and not block:
            host_state = jax.tree.map(np.asarray, state)  # device->host now
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, metadata))
            self._thread.start()
        else:
            self._write(step, state, metadata)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, state: Any, metadata: Optional[dict]
               ) -> None:
        final = self._step_dir(step)
        tmp = os.path.join(self.directory, f"tmp-{step:09d}-{os.getpid()}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays = _flatten(state)
        host_id = jax.process_index()
        np.savez(os.path.join(tmp, f"shard-{host_id:05d}.npz"), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_hosts": jax.process_count(),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in arrays.items()},
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)   # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("-")[1]) for d in os.listdir(self.directory)
            if d.startswith("step-"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> tuple[Any, dict]:
        """Reassemble the checkpoint into ``template``'s structure; if
        ``shardings`` (a matching pytree of NamedSharding) is given the
        arrays are placed onto the current mesh — this is how a restart
        onto a different topology reshards."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays: dict[str, np.ndarray] = {}
        for fn in sorted(os.listdir(d)):
            if fn.startswith("shard-") and fn.endswith(".npz"):
                with np.load(os.path.join(d, fn)) as z:
                    for k in z.files:
                        arrays[k] = z[k]
        state = _unflatten_into(template, arrays)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        else:
            state = jax.tree.map(jnp.asarray, state)
        return state, manifest.get("metadata", {})


def policy_from_metadata(metadata: dict):
    """Recover the ApproxPolicy stored by ``save(..., policy=...)``,
    or None when the checkpoint predates policy shipping."""
    d = (metadata or {}).get("approx_policy")
    if d is None:
        return None
    from repro.approx.layers import ApproxPolicy
    return ApproxPolicy.from_json_dict(d)
