"""Gradient compression for cross-pod all-reduce (DESIGN.md §6).

int8 uniform quantization with per-leaf scale and *error feedback*
(residual carried to the next step — keeps SGD convergence, Karimireddy
et al. 2019).  ``compressed_psum`` is the shard_map building block that
turns a bf16/f32 DCN all-reduce into an int8 one (4x fewer bytes on the
slowest link); the §Perf collective-bound experiment lowers it on the
multi-pod mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """f32 -> (int8 codes, scale).  Symmetric uniform quantization."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads: Any, residual: Any
                           ) -> tuple[Any, Any]:
    """Quantize (grads + residual); return (dequantized grads, new
    residual).  Round-trip error is carried, not dropped."""
    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, s = quantize_leaf(target)
        deq = dequantize_leaf(q, s)
        return deq, target - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    deqs = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return deqs, res


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(tree: Any, axis_name: str) -> Any:
    """Inside shard_map: all-reduce a gradient pytree over ``axis_name``
    in int8 (codes summed in int32, rescaled by the max participating
    scale).  Bytes on the wire: 1 per element instead of 4."""
    def one(g):
        q, s = quantize_leaf(g.astype(jnp.float32))
        # common scale across participants so summed codes are coherent
        s_max = jax.lax.pmax(s, axis_name)
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / s_max), -127, 127
                     ).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return total.astype(jnp.float32) * s_max / n

    return jax.tree.map(one, tree)
