"""Fault-tolerant training loop (DESIGN.md §6).

* microbatched gradient accumulation (``lax.scan`` — XLA overlaps each
  microbatch's reduce with the next microbatch's backward),
* NaN/Inf guard: a non-finite loss triggers restore-from-last-checkpoint
  and a data-window skip (the poisoned batches are never replayed),
* straggler monitor: per-step wall times, flags steps slower than
  ``straggler_factor`` x running median (on a real cluster this feeds
  the re-slicing controller; here it logs),
* periodic atomic checkpoints via ``CheckpointManager``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .checkpoint import CheckpointManager
from .optimizer import OptimizerConfig, OptState, adamw_update, init_opt_state


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    microbatches: int = 1
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    nan_skip_window: int = 8           # batches skipped after a NaN event
    straggler_factor: float = 3.0
    async_checkpoint: bool = False


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                    microbatches: int = 1) -> Callable:
    """loss_fn(params, batch) -> scalar.  Returns
    train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1 every batch leaf must be shaped
    (microbatches, mb, ...); gradients are accumulated in f32.
    """

    def train_step(params, opt_state: OptState, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc_l + l,
                        tree_add(acc_g, jax.tree.map(
                            lambda x: x.astype(jnp.float32), g))), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zero), batch)
            loss = loss / microbatches
            grads = tree_scale(grads, 1.0 / microbatches)
        new_params, new_state, metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, window: int = 50):
        self.factor = factor
        self.times: list[float] = []
        self.window = window
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) >= 8:
            med = float(np.median(hist[:-1]))
            if dt > self.factor * med:
                self.flagged.append((step, dt))
                return True
        return False


class Trainer:
    """Host-side orchestration: data, jitted step, guard, checkpoints."""

    def __init__(self, loss_fn: Callable, params: Any,
                 opt_cfg: OptimizerConfig, loop_cfg: TrainLoopConfig,
                 donate: bool = True):
        self.loop_cfg = loop_cfg
        self.params = params
        self.opt_state = init_opt_state(params)
        self.step_fn = jax.jit(
            make_train_step(loss_fn, opt_cfg, loop_cfg.microbatches),
            donate_argnums=(0, 1) if donate else ())
        self.ckpt = CheckpointManager(loop_cfg.ckpt_dir,
                                      keep=loop_cfg.ckpt_keep,
                                      async_save=loop_cfg.async_checkpoint)
        self.monitor = StragglerMonitor(loop_cfg.straggler_factor)
        self.step = 0
        self.nan_events: list[int] = []
        self.history: list[dict] = []

    def maybe_resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        (self.params, self.opt_state), meta = self.ckpt.restore(
            (self.params, self.opt_state))
        self.step = int(meta.get("step", latest))
        return True

    def _save(self) -> None:
        self.ckpt.save(self.step, (self.params, self.opt_state),
                       metadata={"step": self.step},
                       block=not self.loop_cfg.async_checkpoint)

    def run(self, batch_iter, log: Optional[Callable[[str], None]] = None
            ) -> list[dict]:
        log = log or (lambda s: print(s, flush=True))
        cfg = self.loop_cfg
        self._save()  # step-0 baseline for NaN recovery
        skip_until = -1
        while self.step < cfg.total_steps:
            batch = next(batch_iter)
            if self.step <= skip_until:
                self.step += 1
                continue
            t0 = time.perf_counter()
            new_params, new_opt, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if not np.isfinite(loss):
                # fault path: restore last good state, skip the window
                self.nan_events.append(self.step)
                log(f"[guard] non-finite loss at step {self.step}; "
                    f"restoring + skipping {cfg.nan_skip_window} batches")
                (self.params, self.opt_state), meta = self.ckpt.restore(
                    (jax.tree.map(np.asarray, new_params),
                     jax.tree.map(np.asarray, new_opt)))
                skip_until = self.step + cfg.nan_skip_window
                self.step += 1
                continue
            self.params, self.opt_state = new_params, new_opt
            if self.monitor.record(self.step, dt):
                log(f"[straggler] step {self.step} took {dt * 1e3:.0f}ms "
                    f"(>{cfg.straggler_factor}x median)")
            rec = {"step": self.step, "loss": loss, "ms": dt * 1e3,
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"])}
            self.history.append(rec)
            if self.step % cfg.log_every == 0:
                log(f"step {rec['step']:>6} loss {rec['loss']:.4f} "
                    f"gnorm {rec['grad_norm']:.3f} {rec['ms']:.0f}ms")
            self.step += 1
            if self.step % cfg.ckpt_every == 0:
                self._save()
        self._save()
        self.ckpt.wait()
        return self.history
