"""AdamW + schedules + global-norm clipping, implemented directly in JAX
(no optax dependency).  Optimizer state shards exactly like the params
(same pytree structure), which is what lets GSPMD place m/v alongside
the fully-sharded parameters (ZeRO-style, DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


_DECAY_EXEMPT = ("norm", "bn_g", "bn_b", "bias", "b", "dt_bias", "a_log",
                 "d_skip", "qn", "kvn", "qnorm", "knorm")


def _decayable(path: str) -> bool:
    last = path.split("/")[-1]
    return not any(last.startswith(e) or last == e for e in _DECAY_EXEMPT)


def _tree_paths(tree) -> dict:
    out = {}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        out["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)] = leaf
    return out


def adamw_update(params, grads, state: OptState, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1, b2 = cfg.betas
    lr = lr_at(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                         * g.astype(jnp.float32), state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state.v, grads)

    # weight decay mask by param-path name
    paths = _tree_paths(params)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    keys = list(paths.keys())

    def upd(p, m, v, path):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        wd = cfg.weight_decay if _decayable(path) else 0.0
        return (p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
                ).astype(p.dtype)

    flat_m = jax.tree_util.tree_leaves(new_m)
    flat_v = jax.tree_util.tree_leaves(new_v)
    new_flat = [upd(p, m, v, k)
                for p, m, v, k in zip(flat_p, flat_m, flat_v, keys)]
    new_params = jax.tree_util.tree_unflatten(treedef, new_flat)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step=step, m=new_m, v=new_v), metrics
