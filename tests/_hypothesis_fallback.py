"""Deterministic stand-in for ``hypothesis`` when it is not installed.

Installed into ``sys.modules`` by ``conftest.py`` ONLY on ImportError,
so test collection never hard-errors in minimal environments (the CI
image installs the real hypothesis from requirements-dev.txt and never
sees this).  Property tests then run a small fixed set of samples:
both endpoints plus seeded-random interior draws — strictly weaker than
real hypothesis, but the invariants still execute.

Covers exactly the API surface this repo uses: ``given``, ``settings``,
``strategies.integers``, ``strategies.floats``, ``strategies.booleans``,
``strategies.sampled_from``.
"""
from __future__ import annotations


import random
from types import ModuleType, SimpleNamespace

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, lo, hi, cast):
        self.lo, self.hi, self.cast = lo, hi, cast

    def draw(self, rng: random.Random, i: int):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        if self.cast is int:
            return rng.randint(self.lo, self.hi)
        return rng.uniform(self.lo, self.hi)


def integers(min_value, max_value) -> _Strategy:
    return _Strategy(int(min_value), int(max_value), int)


def floats(min_value, max_value) -> _Strategy:
    return _Strategy(float(min_value), float(max_value), float)


class _BoolStrategy:
    def draw(self, rng: random.Random, i: int):
        if i < 2:
            return bool(i)
        return bool(rng.getrandbits(1))


def booleans() -> _BoolStrategy:
    return _BoolStrategy()


class _SampledStrategy:
    def __init__(self, elements):
        self.elements = list(elements)

    def draw(self, rng: random.Random, i: int):
        if i < len(self.elements):
            return self.elements[i]        # cover every element first
        return self.elements[rng.randrange(len(self.elements))]


def sampled_from(elements) -> _SampledStrategy:
    return _SampledStrategy(elements)


def given(*strats: _Strategy):
    def deco(fn):
        # NOTE: deliberately not functools.wraps — pytest must see a
        # zero-argument signature, not the generated-parameter one
        # (wraps sets __wrapped__, which inspect.signature follows).
        def wrapper():
            rng = random.Random(0xC0FFEE)
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            for i in range(max(2, min(n, _DEFAULT_EXAMPLES))):
                fn(*(s.draw(rng, i) for s in strats))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._hypothesis_fallback = True
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def build_module() -> ModuleType:
    mod = ModuleType("hypothesis")
    mod.__doc__ = __doc__
    mod.given = given
    mod.settings = settings
    strategies = ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.booleans = booleans
    strategies.sampled_from = sampled_from
    mod.strategies = strategies
    mod.HealthCheck = SimpleNamespace()   # occasionally referenced
    mod.__fallback__ = True
    return mod
