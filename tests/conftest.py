import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device (the 512-device placeholder mesh
# belongs exclusively to repro.launch.dryrun).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_root, "src"))
sys.path.insert(0, _root)  # for `import benchmarks` in integration tests

# Property tests must never hard-error collection when hypothesis is
# absent (requirements-dev.txt installs the real one for CI); fall back
# to a small deterministic sampler with the same decorator API.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_fallback import build_module
    mod = build_module()
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies
