import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device (the 512-device placeholder mesh
# belongs exclusively to repro.launch.dryrun).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_root, "src"))
sys.path.insert(0, _root)  # for `import benchmarks` in integration tests
