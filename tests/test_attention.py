"""Chunked (flash-style) attention vs the vanilla path — train,
prefill-into-cache, and decode; plus GQA grouping invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.approx.layers import EXACT_POLICY
from repro.configs import get_config
from repro.models import common


def _setup(arch="qwen1.5-0.5b", **over):
    cfg_v = get_config(arch).reduced(**over)
    cfg_c = dataclasses.replace(cfg_v, attn_impl="chunked", kv_chunk=8)
    params = common.init_attention(jax.random.PRNGKey(0), cfg_v)
    return cfg_v, cfg_c, params


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(st.integers(1, 40), st.integers(1, 3), st.integers(0, 2 ** 16))
def test_chunked_equals_vanilla_selfattn(s, b, seed):
    cfg_v, cfg_c, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, s, cfg_v.d_model),
                          jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    ya, _ = common.attention(params, x, cfg_v, EXACT_POLICY, positions=pos)
    yb, _ = common.attention(params, x, cfg_c, EXACT_POLICY, positions=pos)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=1e-4,
                               atol=1e-5)


def test_chunked_equals_vanilla_cache_paths():
    cfg_v, cfg_c, params = _setup()
    b, s = 2, 13
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg_v.d_model),
                          jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    cache = common.init_attention_cache(cfg_v, b, s + 5)
    ya, ca = common.attention(params, x, cfg_v, EXACT_POLICY,
                              positions=pos, cache=cache)
    yb, cb = common.attention(params, x, cfg_c, EXACT_POLICY,
                              positions=pos, cache=cache)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=1e-4,
                               atol=1e-5)
    x1 = jax.random.normal(jax.random.PRNGKey(2), (b, 1, cfg_v.d_model),
                           jnp.float32)
    pos1 = jnp.asarray([s], jnp.int32)
    ya, _ = common.attention(params, x1, cfg_v, EXACT_POLICY,
                             positions=pos1, cache=ca)
    yb, _ = common.attention(params, x1, cfg_c, EXACT_POLICY,
                             positions=pos1, cache=cb)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.slow
def test_chunked_gradients_finite():
    cfg_v, cfg_c, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 17, cfg_v.d_model),
                          jnp.float32)
    pos = jnp.arange(17, dtype=jnp.int32)

    def loss(p, cfg):
        y, _ = common.attention(p, x, cfg, EXACT_POLICY, positions=pos)
        return jnp.sum(y ** 2)

    gv = jax.grad(lambda p: loss(p, cfg_v))(params)
    gc = jax.grad(lambda p: loss(p, cfg_c))(params)
    for a, b in zip(jax.tree.leaves(gv), jax.tree.leaves(gc)):
        assert np.isfinite(np.asarray(b)).all()
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_causality():
    """Future tokens must not influence earlier positions."""
    for impl in ("vanilla", "chunked"):
        cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                                  attn_impl=impl, kv_chunk=4)
        params = common.init_attention(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 10, cfg.d_model),
                              jnp.float32)
        pos = jnp.arange(10, dtype=jnp.int32)
        y1, _ = common.attention(params, x, cfg, EXACT_POLICY,
                                 positions=pos)
        x2 = x.at[0, -1].set(123.0)   # perturb the LAST token only
        y2, _ = common.attention(params, x2, cfg, EXACT_POLICY,
                                 positions=pos)
        np.testing.assert_allclose(np.asarray(y1[:, :-1]),
                                   np.asarray(y2[:, :-1]), rtol=1e-4,
                                   atol=1e-5)
