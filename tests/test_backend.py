"""Quantization + matmul backends + approx conv (vs lax.conv oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# real hypothesis, or the deterministic fallback conftest.py installs
from hypothesis import given, settings, strategies as st

from repro.approx.backend import MatmulBackend, backend_matmul
from repro.approx.layers import ApproxPolicy, conv2d, conv_mult_count
from repro.approx.quant import calibrate, dequantize, quantize
from repro.core.luts import decompose_lut, exact_mul_lut

RNG = np.random.default_rng(0)


@settings(max_examples=20, deadline=None)
@given(st.floats(-100, 100), st.floats(0.01, 50), st.integers(0, 2 ** 31))
def test_quant_roundtrip_bounded(center, spread, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(center + spread * rng.standard_normal(128),
                    jnp.float32)
    qp = calibrate(x)
    err = jnp.abs(dequantize(quantize(x, qp), qp) - x)
    # round-trip error bounded by one quantization step
    assert float(err.max()) <= float(qp.scale) * 0.5001 + 1e-6


def test_int8_close_to_float():
    x = jnp.asarray(RNG.normal(size=(40, 64)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32)
    y = backend_matmul(x, w, MatmulBackend(mode="int8"))
    rel = float(jnp.abs(y - x @ w).max() / jnp.abs(x @ w).max())
    assert rel < 0.05


def test_lut_exact_equals_int8():
    """LUT emulation with the exact multiplier == the exact int8 path."""
    x = jnp.asarray(RNG.normal(size=(3, 5, 32)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(32, 16)), jnp.float32)
    y_lut = backend_matmul(x, w, MatmulBackend(mode="lut",
                                               lut=exact_mul_lut(8)))
    y_int8 = backend_matmul(x, w, MatmulBackend(mode="int8"))
    np.testing.assert_allclose(np.asarray(y_lut), np.asarray(y_int8),
                               rtol=1e-6, atol=1e-6)


def test_lowrank_rank1_exact():
    fac = decompose_lut(exact_mul_lut(8), 1)
    be = MatmulBackend(mode="lowrank", factors_u=np.asarray(fac.u),
                       factors_v=np.asarray(fac.v))
    x = jnp.asarray(RNG.normal(size=(17, 48)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(48, 9)), jnp.float32)
    y = backend_matmul(x, w, be)
    y8 = backend_matmul(x, w, MatmulBackend(mode="int8"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y8), rtol=1e-4,
                               atol=1e-3)


def test_ste_gradient_matches_exact_vjp():
    x = jnp.asarray(RNG.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(16, 4)), jnp.float32)
    be = MatmulBackend(mode="lut", lut=exact_mul_lut(8))

    g_approx = jax.grad(lambda w_: jnp.sum(backend_matmul(x, w_, be) ** 2))(w)
    assert np.isfinite(np.asarray(g_approx)).all()
    # STE backward uses the *forward output* cotangent with exact matmul
    # vjp: for the exact-multiplier LUT they coincide up to quant noise.
    g_true = jax.grad(lambda w_: jnp.sum((x @ w_) ** 2))(w)
    rel = float(jnp.abs(g_approx - g_true).max() / jnp.abs(g_true).max())
    assert rel < 0.1


def test_policy_override_precedence():
    be_a = MatmulBackend(mode="f32")
    be_b = MatmulBackend(mode="int8")
    pol = ApproxPolicy(default=be_a, overrides=[("layer1*", be_b)])
    assert pol.backend_for("layer1.conv") is be_b
    assert pol.backend_for("layer2.conv") is be_a


@pytest.mark.parametrize("stride,pad", [(1, "SAME"), (2, "SAME")])
def test_conv2d_matches_lax_conv(stride, pad):
    x = jnp.asarray(RNG.normal(size=(2, 16, 16, 3)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(3, 3, 3, 8)), jnp.float32)
    pol = ApproxPolicy(default=MatmulBackend(mode="f32"))
    got = conv2d(pol, "c", x, w, stride=stride, padding=pad)
    want = jax.lax.conv_general_dilated(
        x, w, (stride, stride), pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv_mult_count():
    # 32x32x3 -> 16 channels 3x3 SAME stride 1: B*32*32*9*3*16
    assert conv_mult_count((2, 32, 32, 3), (3, 3, 3, 16)) \
        == 2 * 32 * 32 * 9 * 3 * 16
    # SAME with stride on an odd extent is a ceil-div: 33 -> 17
    assert conv_mult_count((1, 33, 33, 3), (3, 3, 3, 16), stride=2) \
        == 17 * 17 * 9 * 3 * 16
    # VALID shrinks by the kernel: 32 - 3 + 1 = 30
    assert conv_mult_count((1, 32, 32, 3), (3, 3, 3, 16),
                           padding="VALID") == 30 * 30 * 9 * 3 * 16
    # VALID with stride: floor((32-3)/2)+1 = 15
    assert conv_mult_count((1, 32, 32, 3), (3, 3, 3, 16), stride=2,
                           padding="VALID") == 15 * 15 * 9 * 3 * 16


@pytest.mark.parametrize("stride,pad,size", [
    (1, "SAME", 16), (2, "SAME", 15), (1, "VALID", 16), (2, "VALID", 15),
])
def test_conv_mult_count_matches_executed_output(stride, pad, size):
    """Power accounting must count the dims conv2d actually produces."""
    x = jnp.asarray(RNG.normal(size=(2, size, size, 3)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(3, 3, 3, 8)), jnp.float32)
    pol = ApproxPolicy(default=MatmulBackend(mode="f32"))
    y = conv2d(pol, "c", x, w, stride=stride, padding=pad)
    _, ho, wo, cout = y.shape
    assert conv_mult_count(x.shape, w.shape, stride, pad) \
        == 2 * ho * wo * 3 * 3 * 3 * cout


def test_prepared_weights_match_lowrank():
    """Offline-packed weight tables (serving path) == on-the-fly lowrank."""
    from repro.approx.backend import prepare_weight, prepare_tree
    fac = decompose_lut(exact_mul_lut(8), 2)
    be = MatmulBackend(mode="lowrank", factors_u=np.asarray(fac.u),
                       factors_v=np.asarray(fac.v), rank=2)
    x = jnp.asarray(RNG.normal(size=(9, 48)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(48, 24)), jnp.float32)
    y_ref = backend_matmul(x, w, be)
    y_prep = backend_matmul(x, prepare_weight(w, be), be)
    scale = float(jnp.abs(y_ref).max())
    assert float(jnp.abs(y_prep - y_ref).max()) < 0.02 * scale + 0.05

    # tree packing: projection leaves become dicts, others untouched
    tree = {"blocks": {"wq": jnp.ones((4, 8, 8)), "norm1": jnp.ones((8,))},
            "embed": jnp.ones((16, 8))}
    packed = prepare_tree(tree, be)
    assert "tabs" in packed["blocks"]["wq"]
    assert packed["blocks"]["wq"]["tabs"].shape == (4, 2, 8, 8)
    assert packed["blocks"]["norm1"].shape == (8,)
    assert packed["embed"].shape == (16, 8)


def test_pallas_backend_matches_jnp_backend():
    lut = exact_mul_lut(8)
    x = jnp.asarray(RNG.normal(size=(9, 40)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(40, 7)), jnp.float32)
    y_jnp = backend_matmul(x, w, MatmulBackend(mode="lut", lut=lut))
    y_pal = backend_matmul(x, w, MatmulBackend(mode="lut", lut=lut,
                                               use_pallas=True))
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pal),
                               rtol=1e-5, atol=1e-5)
