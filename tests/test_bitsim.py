"""Differential harness for the Pallas bitsim kernels (DESIGN.md §2.9).

Every test is a bit-identity check of ``bitsim_pallas`` /
``bitsim_pop_pallas`` (interpret mode on CPU — the kernel body runs
verbatim) against the pure-python ``Netlist.eval_words`` simulator and
the ``ref.py`` oracles, over random valid netlists covering all 10 gate
functions and plane widths that are NOT multiples of the kernel block.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import gates
from repro.core.netlist import Netlist, stack_netlists
from repro.kernels import ops
from repro.kernels.bitsim import W_BLOCK, bitsim_pop_pallas
from repro.kernels.ref import bitsim_pop_ref, bitsim_ref


def random_netlist(rng: np.random.Generator, n_i: int, n_o: int,
                   n_nodes: int) -> Netlist:
    """Random VALID netlist; with n_nodes >= N_FUNCS the first nodes
    enumerate every gate function (identity..const1) so each draw
    exercises the full switch table."""
    funcs = rng.integers(0, gates.N_FUNCS, n_nodes)
    k = min(gates.N_FUNCS, n_nodes)
    funcs[:k] = rng.permutation(gates.N_FUNCS)[:k]
    in0 = np.array([rng.integers(0, n_i + j) for j in range(n_nodes)])
    in1 = np.array([rng.integers(0, n_i + j) for j in range(n_nodes)])
    outputs = rng.integers(0, n_i + n_nodes, n_o)
    nl = Netlist(n_i=n_i, n_o=n_o, funcs=funcs.astype(np.int32),
                 in0=in0.astype(np.int32), in1=in1.astype(np.int32),
                 outputs=outputs.astype(np.int32))
    nl.validate()
    return nl


# uint64 plane widths: 1 word, and counts whose uint32 lane totals
# (2, 6, 514) are not multiples of W_BLOCK — the pad/trim path
PLANE_WORDS = (1, 3, 257)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 32), st.sampled_from(PLANE_WORDS))
def test_bitsim_matches_eval_words(seed, w64):
    rng = np.random.default_rng(seed)
    n_i = int(rng.integers(1, 12))
    n_o = int(rng.integers(1, 8))
    n_nodes = int(rng.integers(gates.N_FUNCS, 60))
    nl = random_netlist(rng, n_i, n_o, n_nodes)
    planes = rng.integers(0, 2 ** 64, (n_i, w64), dtype=np.uint64)
    got = ops.bitsim(nl, planes)
    want = nl.eval_words(planes)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 32), st.sampled_from(PLANE_WORDS))
def test_bitsim_pop_matches_sequential(seed, w64):
    """Population row p must equal netlists[p].eval_words — including
    mixed node counts (padded with inactive const0 nodes)."""
    rng = np.random.default_rng(seed)
    n_i = int(rng.integers(1, 10))
    n_o = int(rng.integers(1, 6))
    pop = [random_netlist(rng, n_i, n_o,
                          int(rng.integers(gates.N_FUNCS, 40)))
           for _ in range(int(rng.integers(1, 7)))]
    planes = rng.integers(0, 2 ** 64, (n_i, w64), dtype=np.uint64)
    got = ops.bitsim_pop(pop, planes)
    assert got.shape == (len(pop), n_o, w64)
    for p, nl in enumerate(pop):
        np.testing.assert_array_equal(got[p], nl.eval_words(planes))


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2 ** 32))
def test_bitsim_pop_matches_ref_oracle(seed):
    """Kernel vs the pure-jnp population oracle on uint32 lanes."""
    rng = np.random.default_rng(seed)
    n_i, n_o = int(rng.integers(2, 9)), int(rng.integers(1, 5))
    pop = [random_netlist(rng, n_i, n_o, 24) for _ in range(4)]
    funcs, in0, in1, outs = stack_netlists(pop)
    planes32 = rng.integers(0, 2 ** 32, (n_i, 10), dtype=np.uint32)
    got = bitsim_pop_pallas(
        jnp.asarray(funcs), jnp.asarray(in0), jnp.asarray(in1),
        jnp.asarray(outs), jnp.asarray(planes32),
        n_nodes=funcs.shape[1], n_i=n_i, n_o=n_o, interpret=True)
    want = bitsim_pop_ref(funcs, in0, in1, outs, jnp.asarray(planes32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_const_only_netlist():
    """const0/const1 gates take no inputs; planes must not leak in."""
    funcs = np.array([gates.CONST0, gates.CONST1], dtype=np.int32)
    zeros = np.zeros(2, dtype=np.int32)
    nl = Netlist(n_i=2, n_o=2, funcs=funcs, in0=zeros, in1=zeros,
                 outputs=np.array([2, 3], dtype=np.int32))
    planes = np.random.default_rng(0).integers(
        0, 2 ** 64, (2, 1), dtype=np.uint64)
    got = ops.bitsim(nl, planes)
    assert got[0, 0] == 0 and got[1, 0] == np.uint64(2 ** 64 - 1)
    got_pop = ops.bitsim_pop([nl, nl], planes)
    np.testing.assert_array_equal(got_pop[0], got)
    np.testing.assert_array_equal(got_pop[1], got)


def test_pop_single_word_single_candidate():
    """P=1, w=1: the smallest grid still pads/trims correctly."""
    rng = np.random.default_rng(42)
    nl = random_netlist(rng, 4, 2, 12)
    planes = rng.integers(0, 2 ** 64, (4, 1), dtype=np.uint64)
    np.testing.assert_array_equal(ops.bitsim_pop([nl], planes)[0],
                                  nl.eval_words(planes))


def test_stack_netlists_pads_with_inactive_nodes():
    rng = np.random.default_rng(3)
    a = random_netlist(rng, 3, 2, 10)
    b = random_netlist(rng, 3, 2, 25)
    funcs, in0, in1, outs = stack_netlists([a, b])
    assert funcs.shape == (2, 25)
    assert np.all(funcs[0, 10:] == gates.CONST0)
    assert outs.shape == (2, 2)
    with pytest.raises(ValueError):
        stack_netlists([a, random_netlist(rng, 4, 2, 10)])
    with pytest.raises(ValueError):
        stack_netlists([])


def test_block_boundary_widths():
    """uint32 lane counts straddling W_BLOCK: 512±1 lanes (256 words
    exactly hits the block; 255/257 exercise the remainder path)."""
    rng = np.random.default_rng(9)
    nl = random_netlist(rng, 6, 3, 30)
    for w64 in (W_BLOCK // 2 - 1, W_BLOCK // 2, W_BLOCK // 2 + 1):
        planes = rng.integers(0, 2 ** 64, (6, w64), dtype=np.uint64)
        np.testing.assert_array_equal(ops.bitsim(nl, planes),
                                      nl.eval_words(planes))
