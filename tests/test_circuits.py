"""Circuit core: seeds, families, metrics, cost, CGP."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import families, gates, seeds
from repro.core.cgp import CgpParams, ParetoArchive, dominates, evolve, mutate
from repro.core.cost import evaluate_cost, relative_power
from repro.core.metrics import ErrorReport, evaluate_errors
from repro.core.netlist import (Netlist, exhaustive_inputs, pack_operands,
                                unpack_outputs, unpack_outputs_object)


# ---------------------------------------------------------------- seeds
@pytest.mark.parametrize("w", [2, 3, 4, 8])
def test_array_multiplier_exact(w):
    mul = seeds.array_multiplier(w)
    a = np.arange(2 ** w, dtype=np.uint64)
    A, B = np.meshgrid(a, a, indexing="ij")
    out = mul.eval_ints(A.reshape(-1), B.reshape(-1), widths=[w, w])
    assert np.array_equal(out, (A * B).reshape(-1))


@pytest.mark.parametrize("w", [2, 4, 8, 16])
def test_ripple_adder_exact(w):
    add = seeds.ripple_carry_adder(w)
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2 ** w, 500).astype(np.uint64)
    b = rng.integers(0, 2 ** w, 500).astype(np.uint64)
    out = add.eval_ints(a, b, widths=[w, w])
    assert np.array_equal(out, a + b)


def test_wide_adder_object_path():
    add = seeds.ripple_carry_adder(128)
    rep = evaluate_errors(add, add, samples=256)
    assert rep.mae == 0.0 and rep.er == 0.0 and not rep.exhaustive


# ---------------------------------------------------------------- families
def test_truncated_multiplier_semantics():
    tr = families.truncated_multiplier(8, 2)
    a = np.arange(256, dtype=np.uint64)
    A, B = np.meshgrid(a, a, indexing="ij")
    got = tr.eval_ints(A.reshape(-1), B.reshape(-1), widths=[8, 8])
    want = ((A >> 2 << 2) * (B >> 2 << 2)).reshape(-1)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("h,v", [(0, 2), (1, 3), (2, 7), (0, 6)])
def test_bam_semantics(h, v):
    bm = families.bam_multiplier(8, h, v)
    a = np.arange(0, 256, 7, dtype=np.uint64)
    A, B = np.meshgrid(a, a, indexing="ij")
    want = np.zeros_like(A)
    for i in range(8):
        for j in range(8):
            if i >= h and i + j >= v:
                want += (((A >> j) & 1) * ((B >> i) & 1)) << (i + j)
    got = bm.eval_ints(A.reshape(-1), B.reshape(-1), widths=[8, 8])
    assert np.array_equal(got, want.reshape(-1))


def test_loa_adder_semantics():
    loa = families.loa_adder(8, 3)
    a = np.arange(256, dtype=np.uint64)
    A, B = np.meshgrid(a, a, indexing="ij")
    low = (A & 7) | (B & 7)
    cin = ((A >> 2) & 1) & ((B >> 2) & 1)
    want = (low | (((A >> 3) + (B >> 3) + cin) << 3)).reshape(-1)
    got = loa.eval_ints(A.reshape(-1), B.reshape(-1), widths=[8, 8])
    assert np.array_equal(got, want)


def test_family_power_ordering():
    """More truncation => strictly less power (paper Table II trend)."""
    exact = seeds.array_multiplier(8)
    pw = [relative_power(families.truncated_multiplier(8, k), exact)
          for k in (1, 2, 3)]
    assert pw[0] > pw[1] > pw[2]
    assert all(0 < p < 1 for p in pw)


# ---------------------------------------------------------------- metrics
def test_error_report_paper_case():
    """BAM(0,2) has analytic MAE = 1.25 (3 dropped partial products)."""
    exact = seeds.array_multiplier(8)
    rep = evaluate_errors(families.bam_multiplier(8, 0, 2), exact)
    assert abs(rep.mae - 1.25) < 1e-12
    assert rep.exhaustive


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 32))
def test_metric_invariants(seed):
    """MAE <= WCE, MSE >= MAE^2, 0 <= ER <= 1, metrics vanish iff equal."""
    rng = np.random.default_rng(seed)
    exact = rng.integers(0, 1000, 64).astype(np.float64)
    approx = exact + rng.integers(-5, 6, 64)
    from repro.core.metrics import error_report_from_values
    rep = error_report_from_values(approx, exact)
    assert rep.mae <= rep.wce + 1e-12
    assert rep.mse + 1e-9 >= rep.mae ** 2   # Jensen
    assert 0.0 <= rep.er <= 1.0
    if np.array_equal(approx, exact):
        assert rep.wce == 0.0


# ---------------------------------------------------------------- packing
@settings(max_examples=10, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2 ** 31))
def test_pack_unpack_roundtrip(num, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 2 ** 16, num).astype(np.uint64)
    planes = pack_operands([vals], [16])
    back = unpack_outputs(planes, 16, num)
    assert np.array_equal(vals, back)
    back_obj = unpack_outputs_object(planes, 16, num)
    assert all(int(a) == int(b) for a, b in zip(vals, back_obj))


# ---------------------------------------------------------------- CGP
def test_mutation_validity():
    nl = seeds.array_multiplier(4)
    rng = np.random.default_rng(0)
    for _ in range(50):
        nl = mutate(nl, rng, 5)
        nl.validate()


def test_evolution_reduces_area():
    exact = seeds.array_multiplier(6)
    res = evolve(exact, exact,
                 CgpParams(metric="mae", e_max=100.0, generations=120,
                           seed=3))
    assert res.errors.mae <= 100.0
    assert res.cost_area <= evaluate_cost(exact).area
    assert res.cost_area < evaluate_cost(exact).area  # some progress


def test_pareto_archive():
    a = ParetoArchive()
    assert a.add((1.0, 5.0), "a")
    assert a.add((2.0, 1.0), "b")
    assert not a.add((2.0, 6.0), "dominated")
    assert a.add((0.5, 0.5), "dominates-all")
    assert len(a) == 1
    assert dominates((1, 1), (2, 2)) and not dominates((1, 2), (2, 1))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 32))
def test_pareto_archive_invariants(seed):
    """Under ANY add sequence: the archive stays mutually
    non-dominated, exact duplicates are rejected, and ``add`` returns
    True iff the point survives into the archive."""
    rng = np.random.default_rng(seed)
    a = ParetoArchive()
    for k in range(60):
        pt = (float(rng.integers(0, 8)), float(rng.integers(0, 8)))
        accepted = a.add(pt, k)
        if accepted:
            assert pt in a.points
            assert a.payloads[a.points.index(pt)] == k
        else:
            assert any(dominates(q, pt) or q == pt for q in a.points)
        # duplicates of a live point are always rejected
        if a.points:
            assert not a.add(a.points[0], "dup")
        for p in a.points:
            for q in a.points:
                assert p is q or not dominates(p, q)
        assert len(a.points) == len(a.payloads) == len(a)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 32), st.integers(1, 12))
def test_mutate_respects_gene_bounds(seed, h):
    """Every mutated gene stays a valid CGP gene: node j's inputs point
    below n_i + j (feed-forward), outputs below n_i + n_nodes, and
    function genes stay inside the gate table."""
    rng = np.random.default_rng(seed)
    nl = seeds.array_multiplier(4)
    for _ in range(20):
        nl = mutate(nl, rng, h)
        n_i = nl.n_i
        assert np.all((0 <= nl.funcs) & (nl.funcs < gates.N_FUNCS))
        for j in range(nl.n_nodes):
            assert 0 <= nl.in0[j] < n_i + j or (n_i + j == 0)
            assert 0 <= nl.in1[j] < n_i + j or (n_i + j == 0)
        assert np.all((0 <= nl.outputs)
                      & (nl.outputs < n_i + nl.n_nodes))


def test_search_planes_cover_all_input_bits():
    """Regression for the >24-input operand sampler: the old 63-bit
    integer draw left input bit 63 constant zero and silently dropped
    every plane past bit 63.  Every bit-row of the sampled planes must
    now toggle — including row 63 of a 64-input (32-bit adder) circuit
    and the rows >= 64 of a 66-input one."""
    from repro.core.cgp import search_planes
    for n_i in (64, 66):
        planes, num = search_planes(n_i, 8192, np.random.default_rng(0))
        assert planes.shape[0] == n_i and num == 8192
        for row in range(n_i):
            assert planes[row].any(), f"bit {row} stuck at 0"
            assert (~planes[row]).any(), f"bit {row} stuck at 1"
    # distinct high rows must be independent draws, not copies
    planes, _ = search_planes(66, 8192, np.random.default_rng(0))
    assert not np.array_equal(planes[63], planes[64])
    assert not np.array_equal(planes[64], planes[65])


def test_evaluator_scores_wide_adder_approximations():
    """End-to-end regression: with >24 inputs the evaluator must rank a
    high-bit truncation as WORSE than a low-bit one — impossible while
    the high input bits never toggled."""
    from repro.core.cgp import CgpParams, _Evaluator
    exact = seeds.ripple_carry_adder(32)
    ev = _Evaluator(exact, CgpParams(metric="mae", search_samples=4096,
                                     seed=1))
    lo = ev.error_of(families.truncated_adder(32, 4))
    hi = ev.error_of(families.truncated_adder(32, 28))
    assert 0 < lo < hi


def test_compact_preserves_function():
    nl = families.bam_multiplier(8, 1, 4)
    c = nl.compact()
    planes = exhaustive_inputs(16)
    assert np.array_equal(nl.eval_words(planes), c.eval_words(planes))
    assert c.n_nodes <= nl.n_nodes
