"""Persistent-compilation-cache unit tests (DESIGN.md §2.10).

The cache-hit test is the contract the benchmark lanes rely on: a
second process (simulated here by ``jax.clear_caches()``) re-running
the same program must be served from disk, observable as
``cache_hits > 0`` and ``fresh_compiles == 0`` through ``trace_audit``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch.compile_cache import (XLA_BENCH_FLAGS,
                                        enable_compile_cache, trace_audit,
                                        xla_flags_env)


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    """Point the persistent cache at a throwaway dir, restore after."""
    monkeypatch.delenv("JAX_COMPILATION_CACHE_DIR", raising=False)
    yield str(tmp_path / "jax_cache")
    jax.config.update("jax_compilation_cache_dir", None)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)


def test_env_var_wins(cache_dir, monkeypatch, tmp_path):
    env_dir = str(tmp_path / "from_env")
    monkeypatch.setenv("JAX_COMPILATION_CACHE_DIR", env_dir)
    assert enable_compile_cache(cache_dir) == env_dir


def test_persistent_cache_hit(cache_dir):
    d = enable_compile_cache(cache_dir)
    assert d == cache_dir

    def fn(x):
        return jnp.tanh(x) * 3.25 + 0.125

    cold_x = jnp.full((17,), 0.5)
    with trace_audit() as cold:
        jax.jit(fn)(cold_x).block_until_ready()
    assert cold.fresh_compiles >= 1
    assert cold.cache_hits == 0

    # simulate a process restart: in-memory jit caches dropped, the
    # persistent cache on disk survives
    jax.clear_caches()
    with trace_audit() as warm:
        jax.jit(fn)(cold_x).block_until_ready()
    assert warm.cache_hits >= 1
    assert warm.fresh_compiles == 0
    assert warm.traced_programs == cold.traced_programs


def test_trace_audit_counts_compiles():
    with trace_audit() as counts:
        jax.jit(lambda x: x * 2.5 - 1.0)(jnp.ones((13,))).block_until_ready()
    assert counts.traced_programs >= 1
    assert counts.compile_secs >= 0.0


def test_xla_flags_env_merges_and_dedups(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--foo --foo")
    merged = xla_flags_env(("--bar",)).split()
    assert merged.count("--foo") == 1
    assert "--bar" in merged
    for f in XLA_BENCH_FLAGS:
        assert f in merged
