"""Width-generic composed datapaths (DESIGN.md §2.6).

The contracts under test:
  * the composed 12/16-bit product engine (ref and pallas-interpret)
    is BIT-IDENTICAL to ``bitsim_pallas`` netlist simulation of the
    corresponding composed circuit on sampled operand tiles;
  * the composed matmul accumulates products exactly (two int32 limbs)
    — matmul outputs equal the oracle-derived limb recombination;
  * mixed-width banked sweeps stay O(1) compiled programs and
    bit-identical to sequential per-spec evaluation;
  * 8-bit paths through the refactored width-generic stack remain
    bit-identical to the pre-refactor formulas;
  * the typed library errors (LutWidthError / UnknownCircuitError /
    WidthMismatchError) fire with actionable guidance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.approx.backend import backend_matmul
from repro.approx.layers import bank_eval, policy_for_lane, policy_bank_eval
from repro.approx.quant import calibrate, quantize
from repro.approx.registry import composed_product
from repro.approx.resilience import BankableEval, all_layers_sweep
from repro.approx.specs import BackendSpec, LutBank, PolicyBank
from repro.core.families import composed_multiplier, parse_reduce
from repro.core.library import (LutWidthError, UnknownCircuitError,
                                WidthMismatchError, build_default_library)
from repro.core.luts import lut_from_netlist
from repro.core.netlist import pack_operands, unpack_outputs
from repro.kernels import ops
from repro.kernels.composed_matmul import (composed_matmul_bank_pallas,
                                           composed_matmul_pallas,
                                           composed_matmul_ref)

RNG = np.random.default_rng(23)

TILES = ("mul8u_exact", "mul8u_trunc6", "mul8u_bam_h1_v4")
REDUCES = ("exact", "loa4", "trunc3")


@pytest.fixture(scope="module")
def lib():
    lib = build_default_library("tiny")
    # executable wide-width entries for the sweep/bank tests
    lib.add_composed("mul8u_trunc6", 16, "loa4", samples=512)
    lib.add_composed("mul8u_exact", 16, "loa4", samples=512)
    lib.add_composed("mul8u_exact", 12, "loa4", samples=512)
    return lib


def _bitsim_products(nl, a, b, width):
    """Per-element composed products via the Pallas gate-level
    simulator — the ground-truth oracle."""
    planes = pack_operands([a.astype(np.uint64), b.astype(np.uint64)],
                           [width, width])
    out = ops.bitsim(nl, planes)
    return unpack_outputs(out, nl.n_o, a.size)


# ----------------------------------------------------------------------
# Product-level bit-identity vs the gate-level oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("width", [12, 16])
@pytest.mark.parametrize("reduce", REDUCES)
@pytest.mark.parametrize("tile_name", TILES)
def test_composed_product_bit_identical_to_bitsim(lib, width, reduce,
                                                  tile_name):
    tile = lib.entry(tile_name).netlist
    nl = composed_multiplier(tile, width, reduce)
    flat = jnp.asarray(lut_from_netlist(tile, 8).reshape(-1))
    a = RNG.integers(0, 1 << width, 256, dtype=np.uint64)
    b = RNG.integers(0, 1 << width, 256, dtype=np.uint64)
    want = _bitsim_products(nl, a, b, width)
    got = np.asarray(composed_product(
        jnp.asarray(a.astype(np.int64), jnp.int32),
        jnp.asarray(b.astype(np.int64), jnp.int32),
        flat, parse_reduce(reduce), bits=width)).astype(np.uint64)
    np.testing.assert_array_equal(got, want)


def test_composed_product_evolved_tile_and_12bit_truncation(lib):
    """Evolved CGP tiles are first-class: their compacted netlists keep
    stale unused-operand indices (forward refs) the embedder must not
    dereference, and their LUTs can OVER-estimate — pushing the W=12
    tree past 2^24, where the netlist keeps only 2W output bits.  The
    engine must track the netlist, not the untruncated tree."""
    evolved = [e for e in lib.entries.values()
               if e.kind == "multiplier" and e.width == 8
               and e.source == "evolved"]
    if not evolved:
        pytest.skip("tiny library built without evolved entries")
    # prefer tiles that over-estimate on the hi-digit corner (their
    # pp11 << 16 term overflows 2^24), so the truncation path really
    # executes; the deterministic tiny build contains such entries
    def corner_max(e):
        return int(lib.lut(e.name)[:16, :16].max())

    evolved.sort(key=corner_max, reverse=True)
    picked = evolved[:2] + evolved[-1:]
    hit_truncation = False
    for e in picked:
        entry = lib.add_composed(e.name, 12, "exact", samples=64)
        flat = jnp.asarray(lib.tile_lut(entry.name).reshape(-1))
        a = RNG.integers(0, 1 << 12, 512, dtype=np.uint64)
        b = RNG.integers(0, 1 << 12, 512, dtype=np.uint64)
        # include the max-operand corner, the likeliest to overflow 2^24
        a[0] = b[0] = (1 << 12) - 1
        want = _bitsim_products(entry.netlist, a, b, 12)
        got = np.asarray(composed_product(
            jnp.asarray(a.astype(np.int64), jnp.int32),
            jnp.asarray(b.astype(np.int64), jnp.int32),
            flat, ("exact", 0), bits=12)).astype(np.uint64)
        np.testing.assert_array_equal(got, want, err_msg=entry.name)
        hi = np.asarray(flat).reshape(256, 256)[a >> 8, b >> 8]
        hit_truncation |= bool(
            (hi.astype(np.int64) * 65536 > (1 << 24)).any())
    if not hit_truncation:
        pytest.skip("no evolved tile over-estimates past 2^24 in this "
                    "build — truncation path not exercised")


@pytest.mark.parametrize("variant", ["ref", "pallas"])
@pytest.mark.parametrize("width", [12, 16])
def test_composed_matmul_bit_identical_to_bitsim_oracle(lib, variant,
                                                        width):
    """The acceptance gate: composed matmul (both variants) on random
    operand tiles == netlist-simulated products, limb-accumulated and
    recombined identically."""
    name = lib.add_composed("mul8u_trunc6", width, "loa4",
                            samples=128).name
    e = lib.entry(name)
    M, K, N = 6, 9, 5
    qa = RNG.integers(0, 1 << width, (M, K)).astype(np.int32)
    qw = RNG.integers(0, 1 << width, (K, N)).astype(np.int32)
    prods = np.stack([
        _bitsim_products(e.netlist,
                         np.repeat(qa[:, k].astype(np.uint64), N),
                         np.tile(qw[k].astype(np.uint64), M),
                         width).reshape(M, N)
        for k in range(K)])
    lo = (prods & 0xFFFF).astype(np.int64).sum(0)
    hi = (prods >> 16).astype(np.int64).sum(0)
    assert lo.max() < 2 ** 31 and hi.max() < 2 ** 31
    want = lo.astype(np.float32) + np.float32(65536.0) * \
        hi.astype(np.float32)
    mb = BackendSpec.from_library(name, variant=variant).materialize(lib)
    got = np.asarray(mb.datapath.forward_q(jnp.asarray(qa),
                                           jnp.asarray(qw), mb.consts))
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------------
# Kernel-level: pallas vs ref oracle across shapes (incl. padding)
# ----------------------------------------------------------------------
MASK12 = (1 << 24) - 1
MASK16 = 0xFFFFFFFF


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 140), st.integers(1, 150), st.integers(1, 140),
       st.sampled_from(REDUCES), st.sampled_from((0, MASK12, MASK16)))
def test_composed_kernel_matches_ref(m, k, n, reduce, mask):
    qa = jnp.asarray(RNG.integers(0, 1 << 16, (m, k)), jnp.int32)
    qw = jnp.asarray(RNG.integers(0, 1 << 16, (k, n)), jnp.int32)
    lut = jnp.asarray(RNG.integers(0, 1 << 16, (256, 256)), jnp.int32)
    red = parse_reduce(reduce)
    got = composed_matmul_pallas(qa, qw, lut, jnp.uint32(mask),
                                 reduce=red, interpret=True)
    want = composed_matmul_ref(qa, qw, lut, mask, red)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=4, deadline=None)
@given(st.integers(1, 140), st.integers(1, 150), st.integers(1, 140),
       st.integers(1, 4), st.booleans())
def test_composed_bank_kernel_matches_per_lane_single(m, k, n, n_mult,
                                                      banked_qa):
    qa = jnp.asarray(RNG.integers(0, 1 << 16, (m, k)), jnp.int32)
    if banked_qa:
        qa = jnp.asarray(RNG.integers(0, 1 << 16, (n_mult, m, k)),
                         jnp.int32)
    qw = jnp.asarray(RNG.integers(0, 1 << 16, (k, n)), jnp.int32)
    luts = jnp.asarray(RNG.integers(0, 1 << 16, (n_mult, 256, 256)),
                       jnp.int32)
    mask = jnp.asarray(RNG.choice([0, MASK12, MASK16], n_mult),
                       jnp.uint32)
    red = parse_reduce("loa4")
    got = composed_matmul_bank_pallas(qa, qw, luts, mask, reduce=red,
                                      interpret=True)
    for b in range(n_mult):
        qa_b = qa[b] if banked_qa else qa
        want = composed_matmul_pallas(qa_b, qw, luts[b], mask[b],
                                      reduce=red, interpret=True)
        np.testing.assert_array_equal(np.asarray(got[b]),
                                      np.asarray(want))


def test_composed_op_vmap_routes_to_banked_kernel():
    """vmap over (lut, mask) must collapse into ONE banked launch and
    stay bit-identical to the single-tile kernel per lane."""
    qa = jnp.asarray(RNG.integers(0, 1 << 16, (9, 17)), jnp.int32)
    qw = jnp.asarray(RNG.integers(0, 1 << 16, (17, 6)), jnp.int32)
    luts = jnp.asarray(RNG.integers(0, 1 << 16, (3, 256, 256)), jnp.int32)
    mask = jnp.asarray([MASK16, 0, MASK12], jnp.uint32)
    red = ("loa", 4)
    got = jax.vmap(lambda l, mk: ops.composed_matmul_lut(qa, qw, l, mk,
                                                         reduce=red)
                   )(luts, mask)
    for b in range(3):
        want = ops.composed_matmul_lut(qa, qw, luts[b], mask[b],
                                       reduce=red)
        np.testing.assert_array_equal(np.asarray(got[b]),
                                      np.asarray(want))


# ----------------------------------------------------------------------
# Mixed-width banked sweeps: bit-identity + O(1) compiled programs
# ----------------------------------------------------------------------
MIXED = ["mul8u_exact", "mul8u_trunc6", "mul16u_c_mul8u_trunc6_loa4",
         "mul12u_c_mul8u_exact_loa4", "mul16u_c_mul8u_exact_loa4"]
LAYERS = ("lin_a", "lin_b")
COUNTS = {"lin_a": 100, "lin_b": 300}


@pytest.fixture(scope="module")
def toy_eval():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w_a = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    w_b = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    traces = []

    def traceable(policy):
        traces.append(1)
        y = policy.matmul("lin_a", x, w_a)
        y = policy.matmul("lin_b", jax.nn.relu(y), w_b)
        return jnp.mean(y)

    def fn(policy):
        return float(jax.jit(lambda: traceable(policy))())

    return BankableEval(fn=fn, traceable=traceable), traces


@pytest.mark.parametrize("variant", ["ref", "pallas"])
def test_mixed_width_bank_eval_bit_identical(lib, toy_eval, variant):
    eval_fn, _ = toy_eval
    bank = LutBank.from_library(MIXED, lib)
    assert bank.any_wide and tuple(bank.lane_bits) == (8, 8, 16, 12, 16)
    bat = np.asarray(bank_eval(eval_fn.traceable, bank, mode="lut",
                               variant=variant))
    seq = np.asarray(
        [eval_fn(ApproxPolicyDefault(n, variant, lib))
         for n in MIXED], dtype=bat.dtype)
    np.testing.assert_array_equal(bat, seq)


def ApproxPolicyDefault(name, variant, lib):
    from repro.approx.layers import ApproxPolicy
    return ApproxPolicy(
        default=BackendSpec.from_library(name,
                                         variant=variant).materialize(lib))


def test_mixed_width_sweep_one_trace(lib, toy_eval):
    """The satellite trace-count gate: a banked all-layers sweep over a
    MIXED-width candidate set compiles O(1) programs."""
    eval_fn, traces = toy_eval
    traces.clear()
    rows = all_layers_sweep(eval_fn, COUNTS, MIXED, lib, mode="lut",
                            batch=True)
    assert len(traces) == 1, "mixed-width bank must stay one program"
    assert [r.multiplier for r in rows] == MIXED
    traces.clear()
    seq = all_layers_sweep(eval_fn, COUNTS, MIXED, lib, mode="lut")
    assert [r.accuracy for r in rows] == [r.accuracy for r in seq]


def test_mixed_width_policy_bank_bit_identical(lib, toy_eval):
    eval_fn, traces = toy_eval
    pb = PolicyBank.from_assignments(
        [{"lin_a": "mul8u_exact",
          "lin_b": "mul16u_c_mul8u_trunc6_loa4"},
         {"lin_a": "mul12u_c_mul8u_exact_loa4",
          "lin_b": "mul8u_trunc6"}],
        lib, layers=LAYERS)
    traces.clear()
    bat = np.asarray(policy_bank_eval(eval_fn.traceable, pb, mode="lut"))
    assert len(traces) == 1
    seq = np.asarray(
        [eval_fn(policy_for_lane(pb, p).materialize(lib))
         for p in range(pb.n_policies)], dtype=bat.dtype)
    np.testing.assert_array_equal(bat, seq)


def test_explore_accepts_mixed_width_candidates(lib, toy_eval):
    from repro.approx.dse import explore
    from repro.approx.power import rel_power_map
    eval_fn, _ = toy_eval
    rp = rel_power_map(lib, MIXED, ref="mul8u_exact")
    # wide entries must cost more than their 8-bit tile on the common axis
    assert rp["mul16u_c_mul8u_exact_loa4"] > rp["mul8u_exact"]
    res = explore(eval_fn, COUNTS, lib, multipliers=MIXED,
                  quality_bound=10.0, batch=True, rel_power=rp)
    assert [p.multiplier for p in res.all_layers] == MIXED
    powers = {p.multiplier: p.network_rel_power for p in res.all_layers}
    assert powers == pytest.approx({n: rp[n] for n in MIXED})
    assert res.selected is not None


def test_mixed_width_power_auto_rebased_without_override(lib, toy_eval):
    """Omitting rel_power on a MIXED-width sweep must not silently
    compare same-width conventions: auto_rel_power rebases onto the
    narrowest exact multiplier, so a composed 16-bit entry costs more
    than 8-bit exact instead of looking ~5x cheaper."""
    from repro.approx.dse import explore
    from repro.approx.power import auto_rel_power
    eval_fn, _ = toy_eval
    assert auto_rel_power(lib, MIXED[:2]) is None  # single-width: as-is
    res = explore(eval_fn, COUNTS, lib, multipliers=MIXED,
                  quality_bound=10.0, batch=True, per_layer=False)
    powers = {p.multiplier: p.network_rel_power for p in res.all_layers}
    assert powers["mul16u_c_mul8u_exact_loa4"] > powers["mul8u_exact"]
    # the same-width library convention would have scored this ~4x
    # cheaper (relative to exact SIXTEEN-bit) than the rebased value
    wide16 = "mul16u_c_mul8u_trunc6_loa4"
    assert lib.entry(wide16).rel_power < 1.0 < powers[wide16]


def test_add_composed_name_collision_across_recipes_raises(lib):
    lib.add_composed("mul8u_exact", 16, "loa4", name="clash",
                     samples=64)
    with pytest.raises(ValueError, match="different recipe"):
        lib.add_composed("mul8u_trunc6", 12, "trunc3", name="clash",
                         samples=64)
    # equivalent reduce spellings are NOT a collision
    e = lib.add_composed("mul8u_exact", 16, "add32u_loa4", name="clash",
                         samples=64)
    assert e.name == "clash"


# ----------------------------------------------------------------------
# 8-bit regression through the width-generic stack
# ----------------------------------------------------------------------
def test_8bit_path_bit_identical_to_pre_refactor(lib):
    """An 8-bit spec through the refactored stack reproduces the
    historical formula exactly: int32 LUT sums + f32 zero-point
    correction at qmax=255."""
    mb = BackendSpec.from_library("mul8u_trunc6").materialize(lib)
    assert "composed" not in mb.consts and "bits" not in mb.consts
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(12, 19)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(19, 7)).astype(np.float32))
    got = np.asarray(backend_matmul(x, w, mb))
    # pre-refactor reference, verbatim
    lut = jnp.asarray(lib.lut("mul8u_trunc6"))
    qp_a, qp_w = calibrate(x), calibrate(w)
    qa, qw = quantize(x, qp_a), quantize(w, qp_w)
    flat = lut.reshape(-1)
    idx = qa[:, :, None] * 256 + qw[None, :, :]
    s = jnp.sum(jnp.take(flat, idx, axis=0), axis=1,
                dtype=jnp.int32).astype(jnp.float32)
    row = jnp.sum(qa, axis=1, dtype=jnp.int32).astype(jnp.float32)
    col = jnp.sum(qw, axis=0, dtype=jnp.int32).astype(jnp.float32)
    zaf = qp_a.zero_point.astype(jnp.float32)
    zwf = qp_w.zero_point.astype(jnp.float32)
    acc = s - zwf * row[:, None] - zaf * col[None, :] + 19 * zaf * zwf
    want = np.asarray(acc * (qp_a.scale * qp_w.scale))
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------------
# Typed library errors (satellite)
# ----------------------------------------------------------------------
def test_wide_lut_raises_typed_actionable_error(lib):
    name = "mul16u_c_mul8u_trunc6_loa4"
    with pytest.raises(LutWidthError, match="composed"):
        lib.lut(name)
    err = None
    try:
        lib.lut(name)
    except LutWidthError as e:
        err = e
    assert err.width == 16 and err.circuit == name
    assert "add_composed" in str(err) and "DESIGN.md" in str(err)
    # ... but the tile LUT executes it
    assert lib.tile_lut(name).shape == (256, 256)


def test_wide_entry_without_composition_raises(lib):
    # a raw wide netlist (no composition recipe) is not executable
    wide_raw = [e.name for e in lib.entries.values()
                if e.kind == "multiplier" and e.width > 8
                and e.composition is None]
    if not wide_raw:     # tiny library builds only 8-bit families
        from repro.core.seeds import array_multiplier
        lib.add_netlist(array_multiplier(16), "multiplier", 16, "exact",
                        array_multiplier(16), name="mul16u_exact_raw")
        wide_raw = ["mul16u_exact_raw"]
    with pytest.raises(LutWidthError):
        lib.composition_of(wide_raw[0])


def test_lookup_validation_typed_errors(lib):
    with pytest.raises(UnknownCircuitError, match="unknown circuit"):
        lib.entry("mul8u_nope")
    with pytest.raises(WidthMismatchError, match="16-bit"):
        lib.entry("mul16u_c_mul8u_trunc6_loa4", bit_width=8)
    with pytest.raises(WidthMismatchError):
        BackendSpec.from_library("mul8u_exact",
                                 bit_width=16).materialize(lib)
    # matching declaration passes and packs the tile
    mb = BackendSpec.from_library("mul16u_c_mul8u_trunc6_loa4",
                                  bit_width=16).materialize(lib)
    assert mb.consts["bits"] == 16


def test_spec_reduce_adder_validation(lib):
    with pytest.raises(ValueError, match="unknown reduction"):
        BackendSpec(mode="lut", reduce_adder="nope9")
    spec = BackendSpec(mode="lut",
                       multiplier="mul16u_c_mul8u_trunc6_loa4",
                       reduce_adder="trunc3")
    with pytest.raises(ValueError, match="reduces with"):
        spec.materialize(lib)
    ok = BackendSpec(mode="lut",
                     multiplier="mul16u_c_mul8u_trunc6_loa4",
                     reduce_adder="add32u_loa4")   # library-name form
    assert ok.materialize(lib).consts["reduce"] == ("loa", 4)
    with pytest.raises(ValueError, match="composed wide"):
        BackendSpec(mode="lut", multiplier="mul8u_exact",
                    reduce_adder="loa4").materialize(lib)


def test_spec_json_round_trip_with_width_fields(lib):
    spec = BackendSpec(mode="lut",
                       multiplier="mul16u_c_mul8u_trunc6_loa4",
                       bit_width=16, reduce_adder="loa4")
    assert BackendSpec.from_json(spec.to_json()) == spec
    # pre-width JSONs (no new fields) still deserialize
    legacy = {"mode": "lut", "multiplier": "mul8u_exact", "rank": None,
              "block_m": 512, "ste": True, "variant": "ref"}
    old = BackendSpec.from_dict(legacy)
    assert old.bit_width is None and old.reduce_adder is None


def test_add_composed_idempotent_and_persistent(lib, tmp_path):
    e1 = lib.add_composed("mul8u_trunc6", 16, "loa4", samples=64)
    e2 = lib.add_composed("mul8u_trunc6", 16, "loa4", samples=64)
    assert e1 is e2
    assert e1.composition == {"tile": "mul8u_trunc6", "reduce": "loa4"}
    assert 0 < e1.rel_power < 1.0   # cheaper than exact 16-bit
    path = str(tmp_path / "lib.json")
    lib.save(path)
    from repro.core.library import ApproxLibrary
    lib2 = ApproxLibrary.load(path)
    e3 = lib2.entry(e1.name)
    assert e3.composition == e1.composition
    assert lib2.tile_lut(e1.name).shape == (256, 256)


def test_composed_12bit_lut_materialization_refused(lib):
    """A 12-bit composed entry's full LUT would fit the width cap, but
    materializing it is minutes of gate-level simulation for a table
    the engine never reads — lut() must redirect to the tile."""
    with pytest.raises(ValueError, match="tile LUT"):
        lib.lut("mul12u_c_mul8u_exact_loa4")
    assert lib.tile_lut("mul12u_c_mul8u_exact_loa4").shape == (256, 256)


def test_bank_rejects_unsupported_lane_widths(lib):
    luts = np.zeros((1, 256, 256), np.int32)
    with pytest.raises(ValueError, match="unsupported lane widths"):
        LutBank(names=("x",), luts=luts, bit_widths=(10,))


def test_bank_rejects_mixed_reduction_trees(lib):
    lib.add_composed("mul8u_exact", 16, "trunc3", samples=64)
    with pytest.raises(ValueError, match="mixed reduction"):
        LutBank.from_library(["mul16u_c_mul8u_exact_trunc3",
                              "mul16u_c_mul8u_exact_loa4"], lib)
