"""explore()/select_multiplier DSE facade: equivalence with the raw
sweeps, eval caching, and materialization reuse across sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import clear_materialize_cache, materialize_cache_stats
from repro.approx.dse import explore, pareto_points, select_multiplier
from repro.approx.layers import ApproxPolicy
from repro.approx.resilience import all_layers_sweep, per_layer_sweep
from repro.approx.specs import BackendSpec
from repro.core.families import truncated_multiplier
from repro.core.library import ApproxLibrary
from repro.core.seeds import array_multiplier

RNG = np.random.default_rng(7)
LAYER_COUNTS = {"layer_a": 100, "layer_b": 300}
MULTS = ["mul8u_exact", "mul8u_trunc6", "mul8u_trunc3"]


@pytest.fixture(scope="module")
def lib():
    lib = ApproxLibrary()
    exact = array_multiplier(8)
    lib.add_netlist(exact, "multiplier", 8, "exact", exact,
                    name="mul8u_exact")
    for k in (2, 5):
        lib.add_netlist(truncated_multiplier(8, k), "multiplier", 8,
                        "truncation", exact)
    return lib


def make_eval(counter):
    """Deterministic two-'layer' toy model; accuracy = 1/(1+error)."""
    x = jnp.asarray(RNG.normal(size=(12, 32)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(32, 8)), jnp.float32)
    ref = np.asarray(x) @ np.asarray(w)

    def eval_fn(policy: ApproxPolicy) -> float:
        counter[0] += 1
        err = 0.0
        for name in LAYER_COUNTS:
            y = np.asarray(policy.matmul(name, x, w))
            err += float(np.abs(y - ref).mean())
        return 1.0 / (1.0 + err)

    return eval_fn


def test_explore_reproduces_raw_sweeps(lib):
    eval_fn = make_eval([0])
    result = explore(eval_fn, LAYER_COUNTS, lib, multipliers=MULTS,
                     mode="lut")

    golden = BackendSpec.golden().materialize()
    ref_all = all_layers_sweep(eval_fn, LAYER_COUNTS, MULTS, lib,
                               mode="lut")
    ref_per = per_layer_sweep(eval_fn, LAYER_COUNTS, MULTS, lib,
                              mode="lut", base=golden)

    assert [(p.multiplier, p.layer) for p in result.all_layers] \
        == [(r.multiplier, r.layer) for r in ref_all]
    for p, r in zip(result.all_layers, ref_all):
        assert p.accuracy == r.accuracy
        assert p.network_rel_power == r.network_rel_power
    assert len(result.per_layer) == len(ref_per) \
        == len(MULTS) * len(LAYER_COUNTS)
    for p, r in zip(result.per_layer, ref_per):
        assert (p.multiplier, p.layer, p.accuracy) \
            == (r.multiplier, r.layer, r.accuracy)
        assert p.mult_share == r.mult_share


def test_explore_caches_evals_across_calls(lib):
    counter = [0]
    eval_fn = make_eval(counter)
    cache: dict = {}
    explore(eval_fn, LAYER_COUNTS, lib, multipliers=MULTS, mode="lut",
            cache=cache)
    n_first = counter[0]
    # baseline + all-layers (3) + per-layer (3 mults x 2 layers)
    assert n_first == 1 + len(MULTS) + len(MULTS) * len(LAYER_COUNTS)
    explore(eval_fn, LAYER_COUNTS, lib, multipliers=MULTS, mode="lut",
            cache=cache)
    assert counter[0] == n_first, "second exploration must be all cache"


def test_sweeps_share_materialized_backends(lib):
    """Two sweeps over the same multiplier pack (and trace) once."""
    clear_materialize_cache()
    eval_fn = make_eval([0])
    explore(eval_fn, LAYER_COUNTS, lib, multipliers=MULTS, mode="lut")
    # one pack per multiplier + golden int8 + the bf16 default is never
    # touched here; per-layer and all-layers sweeps share all entries
    assert materialize_cache_stats()["misses"] == len(MULTS) + 1


def test_select_multiplier_picks_lowest_power_within_budget(lib):
    result = explore(make_eval([0]), LAYER_COUNTS, lib, multipliers=MULTS,
                     mode="lut", quality_bound=1.0)
    # generous budget: everything qualifies -> lowest-power circuit
    powers = {p.multiplier: p.network_rel_power for p in result.all_layers}
    assert result.selected is not None
    assert result.selected.multiplier == min(powers, key=powers.get)

    # zero budget: only the exact multiplier matches the golden baseline
    tight = select_multiplier(result, max_accuracy_drop=0.0)
    assert tight is not None and tight.multiplier == "mul8u_exact"

    # impossible budget: nothing qualifies
    assert select_multiplier(result, max_accuracy_drop=-1.0) is None


def test_selected_point_yields_deployable_policy(lib):
    result = explore(make_eval([0]), LAYER_COUNTS, lib, multipliers=MULTS,
                     mode="lut", quality_bound=1.0)
    pol = result.selected.policy()
    blob = pol.to_json()
    assert ApproxPolicy.from_json(blob).cache_key() == pol.cache_key()
    # and it actually runs
    acc = make_eval([0])(pol.materialize(lib))
    assert 0.0 < acc <= 1.0


def test_pareto_points_nondominated():
    from repro.approx.dse import DesignPoint
    pts = [DesignPoint("a", "all", 0.9, 1.0, 1.0, 1.0),
           DesignPoint("b", "all", 0.8, 0.5, 0.5, 1.0),
           DesignPoint("c", "all", 0.7, 0.6, 0.6, 1.0),   # dominated by b
           DesignPoint("d", "all", 0.5, 0.2, 0.2, 1.0)]
    front = pareto_points(pts)
    assert [p.multiplier for p in front] == ["d", "b", "a"]


def test_pareto_points_keeps_ties_on_both_axes():
    from repro.approx.dse import DesignPoint
    pts = [DesignPoint("a", "all", 0.8, 0.5, 0.5, 1.0),
           DesignPoint("b", "all", 0.8, 0.5, 0.5, 1.0),   # exact tie
           DesignPoint("c", "all", 0.7, 0.5, 0.5, 1.0)]   # dominated
    front = pareto_points(pts)
    assert sorted(p.multiplier for p in front) == ["a", "b"]
