"""Population-parallel CGP engine (DESIGN.md §2.9): engine
determinism, metric bit-identity, fused-ladder equivalence, sharding."""
from dataclasses import replace

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cgp import CgpParams, pad_nodes
from repro.core.evolve_pop import (DEVICE_METRICS, POP_PAD, PopEvaluator,
                                   evolve_ladder, evolve_pop)
from repro.core.metrics import METRIC_NAMES
from repro.core.seeds import array_multiplier, ripple_carry_adder
from tests.test_bitsim import random_netlist


def _same_genome(a, b) -> bool:
    return (np.array_equal(a.funcs, b.funcs)
            and np.array_equal(a.in0, b.in0)
            and np.array_equal(a.in1, b.in1)
            and np.array_equal(a.outputs, b.outputs))


@pytest.fixture(scope="module")
def mult6():
    return array_multiplier(6)


@pytest.fixture(scope="module")
def params():
    return CgpParams(metric="mae", e_max=40.0, generations=25, seed=5,
                     search_samples=4096)


def test_engines_walk_identical_trajectories(mult6, params):
    """Same seed => numpy and device engines return the SAME netlist
    and the SAME exhaustively-verified ErrorReport."""
    seed_nl = pad_nodes(mult6, mult6.n_nodes + 10, seed=99)
    rn = evolve_pop(seed_nl, mult6, params, engine="numpy")
    rd = evolve_pop(seed_nl, mult6, params, engine="device")
    assert _same_genome(rn.netlist, rd.netlist)
    assert rn.errors.as_dict() == rd.errors.as_dict()
    assert rn.cost_area == rd.cost_area
    assert rn.errors.mae <= params.e_max


@pytest.mark.parametrize("metric", METRIC_NAMES)
def test_metric_bit_identity_across_engines(mult6, metric):
    """Every metric — device-reduced (er/mae/wce: exact integer sums
    finished in float64) and host-reduced fallback alike — must equal
    the numpy engine's float64 value EXACTLY on every candidate."""
    p = CgpParams(metric=metric, search_samples=2048, seed=3)
    rng = np.random.default_rng(7)
    pop = [random_netlist(rng, mult6.n_i, mult6.n_o, 80)
           for _ in range(POP_PAD + 3)]   # odd count: padding path
    e_np = PopEvaluator(mult6, p, engine="numpy").errors_of(pop)
    e_dev = PopEvaluator(mult6, p, engine="device").errors_of(pop)
    np.testing.assert_array_equal(e_np, e_dev)
    assert e_np.shape == (len(pop),)


def test_device_metrics_are_a_subset():
    assert set(DEVICE_METRICS) <= set(METRIC_NAMES)


def test_ladder_matches_per_rung_runs(mult6, params):
    """Fused-ladder rung i is trajectory-identical to a standalone
    evolve_pop at seed+i — the fusion must not change the search."""
    seed_nl = pad_nodes(mult6, mult6.n_nodes + 10, seed=99)
    ladder = [10.0, 40.0]
    lad = evolve_ladder(seed_nl, mult6, ladder, params, engine="device")
    for i, e_max in enumerate(sorted(ladder)):
        p_i = replace(params, e_max=e_max, seed=params.seed + i)
        solo = evolve_pop(seed_nl, mult6, p_i, engine="device")
        assert _same_genome(lad[i].netlist, solo.netlist)
        assert lad[i].errors.as_dict() == solo.errors.as_dict()


def test_ladder_engines_agree(mult6, params):
    seed_nl = pad_nodes(mult6, mult6.n_nodes + 10, seed=99)
    ladder = [10.0, 40.0]
    lad_d = evolve_ladder(seed_nl, mult6, ladder, params, engine="device")
    lad_n = evolve_ladder(seed_nl, mult6, ladder, params, engine="numpy")
    for a, b in zip(lad_d, lad_n):
        assert _same_genome(a.netlist, b.netlist)
        assert a.errors.as_dict() == b.errors.as_dict()


def test_sharded_evaluator_matches_unsharded(mult6, params):
    """pop_sharding on the 1-device sweep mesh must not change scores
    (shard_map with a trivial split is the degenerate case the
    multi-device path reduces to)."""
    from repro.launch.mesh import pop_sharding, sweep_mesh
    rng = np.random.default_rng(11)
    pop = [random_netlist(rng, mult6.n_i, mult6.n_o, 60)
           for _ in range(POP_PAD)]
    plain = PopEvaluator(mult6, params, engine="device").errors_of(pop)
    sh = pop_sharding(POP_PAD, sweep_mesh())
    sharded = PopEvaluator(mult6, params, engine="device",
                           sharding=sh).errors_of(pop)
    np.testing.assert_array_equal(plain, sharded)


def test_on_candidate_and_instrumentation(mult6, params):
    seed_nl = pad_nodes(mult6, mult6.n_nodes + 10, seed=99)
    seen = []
    ev = PopEvaluator(mult6, params, engine="numpy")
    evolve_pop(seed_nl, mult6, params, on_candidate=lambda nl, e, a:
               seen.append((e, a)), evaluator=ev)
    # 1 parent eval + λ per generation; every callback is feasible
    assert ev.n_scored == 1 + params.generations * params.lam
    assert ev.n_calls == 1 + params.generations
    assert all(e <= params.e_max for e, _ in seen)


def test_evaluator_rejects_bad_config(mult6, params):
    with pytest.raises(ValueError, match="engine"):
        PopEvaluator(mult6, params, engine="cuda")
    with pytest.raises(ValueError, match="metric"):
        PopEvaluator(mult6, replace(params, metric="nope"))
    wide = ripple_carry_adder(40)      # n_o = 41 > device cap
    with pytest.raises(ValueError, match="numpy"):
        PopEvaluator(wide, params, engine="device")


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2 ** 32), st.sampled_from(DEVICE_METRICS))
def test_adder_metric_identity_property(seed, metric):
    """Device-reduced metrics on the adder oracle (n_o=9): random
    populations, exact equality with the numpy engine."""
    add = ripple_carry_adder(8)
    p = CgpParams(metric=metric, search_samples=1024, seed=seed % 997)
    rng = np.random.default_rng(seed)
    pop = [random_netlist(rng, add.n_i, add.n_o, 50) for _ in range(5)]
    e_np = PopEvaluator(add, p, engine="numpy").errors_of(pop)
    e_dev = PopEvaluator(add, p, engine="device").errors_of(pop)
    np.testing.assert_array_equal(e_np, e_dev)
