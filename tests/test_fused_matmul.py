"""Differential + integration contract for the fused datapath
(DESIGN.md §2.10).

Three layers of gates:

* ops-level — every fused kernel (single-LUT, banked, composed wide,
  composed banked) is BIT-IDENTICAL to its jnp oracle in ``ref.py`` at
  8/12/16-bit, including non-block-multiple shapes and the
  ``custom_vmap`` bank collapse;
* integration — the ``variant="fused"`` spec matches ``variant="ref"``
  through ``backend_matmul``/``bank_eval``/``policy_bank_eval`` under
  jit (the incumbent jitted-sequential comparison idiom from
  ``test_composed.py``), plus the mixed-reduce bank capability that
  exists ONLY on the fused variant;
* trace counts — a banked fused sweep stays O(1) compiled programs in
  the number of lanes, audited both by user-function trace counting and
  by ``compile_cache.trace_audit`` backend-compile deltas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.backend import backend_matmul
from repro.approx.layers import (ApproxPolicy, bank_eval, policy_bank_eval,
                                 policy_for_lane)
from repro.approx.quant import calibrate, scalar_params
from repro.approx.registry import encode_reduce, product_mask
from repro.approx.specs import BackendSpec, PolicyBank, bank_for
from repro.core.library import build_default_library
from repro.kernels import ops, ref
from repro.launch.compile_cache import trace_audit

N16 = "mul16u_c_mul8u_trunc6_loa4"
N16B = "mul16u_c_mul8u_exact_trunc3"
N12 = "mul12u_c_mul8u_exact_loa4"


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="module")
def lut8(rng):
    return jnp.asarray(rng.integers(0, 255 * 255,
                                    (256, 256)).astype(np.int32))


@pytest.fixture(scope="module")
def lib():
    lib = build_default_library("tiny")
    for base, width, red in (("mul8u_trunc6", 16, "loa4"),
                             ("mul8u_exact", 12, "loa4"),
                             ("mul8u_exact", 16, "trunc3")):
        lib.add_composed(base, width, red, samples=512)
    return lib


# ----------------------------------------------------------------------
# ops-level differential suite: fused kernels vs jnp oracles
# ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 96, 64), (7, 150, 9),
                                   (130, 260, 200)])
def test_fused_matmul_identical(rng, lut8, shape):
    m, k, n = shape
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    sp = scalar_params(calibrate(x), calibrate(w))
    got = ops.fused_matmul_lut(x, w, lut8, *sp)
    want = ref.fused_matmul_ref(x, w, lut8, *sp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _bank_inputs(rng, n_lanes=3, m=9, k=200, n=70):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    luts = jnp.asarray(rng.integers(0, 255 * 255,
                                    (n_lanes, 256, 256)).astype(np.int32))
    sp = scalar_params(calibrate(x), calibrate(w))
    sp_n = tuple(jnp.broadcast_to(jnp.asarray(v), (n_lanes,)) for v in sp)
    return x, w, luts, sp_n


def test_fused_bank_shared_x_identical(rng):
    x, w, luts, sp_n = _bank_inputs(rng)
    got = ops.fused_matmul_lut_bank(x, w, luts, *sp_n)
    want = ref.fused_matmul_bank_ref(x, w, luts, *sp_n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_vmap_collapses_to_bank(rng):
    x, w, luts, sp_n = _bank_inputs(rng)
    got = jax.vmap(ops.fused_matmul_lut,
                   in_axes=(None, None, 0, 0, 0, 0, 0, 0))(x, w, luts,
                                                           *sp_n)
    want = ref.fused_matmul_bank_ref(x, w, luts, *sp_n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_bank_batched_x_identical(rng):
    _, w, luts, _ = _bank_inputs(rng)
    xb = jnp.asarray(rng.normal(size=(3, 9, 200)).astype(np.float32))
    per = [scalar_params(calibrate(xb[i]), calibrate(w)) for i in range(3)]
    sp_n = tuple(jnp.stack([jnp.asarray(per[i][j]) for i in range(3)])
                 for j in range(5))
    got = ops.fused_matmul_lut_bank(xb, w, luts, *sp_n)
    want = ref.fused_matmul_bank_ref(xb, w, luts, *sp_n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [12, 16])
@pytest.mark.parametrize("red", [("exact", 0), ("trunc", 4), ("loa", 6)])
def test_fused_composed_identical(rng, lut8, bits, red):
    mask = product_mask(2 * bits)
    rcode = jnp.asarray(encode_reduce(red), jnp.int32)
    x = jnp.asarray(rng.normal(size=(5, 100)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(100, 33)).astype(np.float32))
    sp = scalar_params(calibrate(x, bits=bits), calibrate(w, bits=bits))
    got = ops.fused_composed_matmul_lut(x, w, lut8, mask, rcode, *sp)
    want = ref.fused_composed_matmul_ref(x, w, lut8, mask, *sp, reduce=red)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _composed_bank_inputs(rng):
    """Mixed width AND mixed reduce AND a narrow lane (mask=0)."""
    tiles = jnp.asarray(rng.integers(0, 255 * 255,
                                     (3, 256, 256)).astype(np.int32))
    masks = jnp.asarray([int(product_mask(24)), 0, int(product_mask(32))],
                        dtype=jnp.uint32)
    reduces = [("trunc", 3), ("exact", 0), ("loa", 8)]
    rcodes = jnp.asarray([encode_reduce(r) for r in reduces], jnp.int32)
    x = jnp.asarray(rng.normal(size=(6, 90)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(90, 40)).astype(np.float32))
    sps = [scalar_params(calibrate(x, bits=b), calibrate(w, bits=b))
           for b in (12, 8, 16)]
    sp_n = tuple(jnp.stack([jnp.asarray(sps[i][j]) for i in range(3)])
                 for j in range(5))
    return x, w, tiles, masks, rcodes, reduces, sp_n


def test_fused_composed_bank_mixed_identical(rng):
    x, w, tiles, masks, rcodes, reduces, sp_n = _composed_bank_inputs(rng)
    got = ops.fused_composed_matmul_lut_bank(x, w, tiles, masks, rcodes,
                                             *sp_n)
    want = ref.fused_composed_matmul_bank_ref(x, w, tiles, masks, reduces,
                                              *sp_n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_composed_vmap_collapses_to_bank(rng):
    x, w, tiles, masks, rcodes, reduces, sp_n = _composed_bank_inputs(rng)
    got = jax.vmap(ops.fused_composed_matmul_lut,
                   in_axes=(None, None, 0, 0, 0, 0, 0, 0, 0, 0))(
        x, w, tiles, masks, rcodes, *sp_n)
    want = ref.fused_composed_matmul_bank_ref(x, w, tiles, masks, reduces,
                                              *sp_n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------------------
# integration: the fused spec variant through the backend + engines
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mult,bw", [("mul8u_trunc2", None),
                                     (N12, 12), (N16, 16)])
def test_spec_fused_matches_ref_variant(rng, lib, mult, bw):
    x = jnp.asarray(rng.normal(size=(8, 96)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(96, 24)).astype(np.float32))
    outs = {}
    for variant in ("ref", "fused"):
        be = BackendSpec(mode="lut", multiplier=mult, variant=variant,
                         bit_width=bw).materialize(lib)
        fn = jax.jit(lambda a, b, _be=be: backend_matmul(a, b, _be))
        outs[variant] = np.asarray(fn(x, w))
    np.testing.assert_array_equal(outs["ref"], outs["fused"])


@pytest.fixture(scope="module")
def toy_eval(rng):
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w_a = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    w_b = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    traces = []

    def traceable(policy):
        traces.append(1)
        y = policy.matmul("lin_a", x, w_a)
        y = policy.matmul("lin_b", jax.nn.relu(y), w_b)
        return jnp.mean(y)

    def sequential(policy):
        # the incumbent comparison idiom: the sequential leg runs under
        # jit too, so both legs see the same compilation context
        return float(jax.jit(lambda: traceable(policy))())

    return traceable, sequential, traces


MIXED = ["mul8u_exact", "mul8u_trunc6", N16, N12]


def test_bank_eval_fused_bit_identical(lib, toy_eval):
    traceable, sequential, _ = toy_eval
    bank = bank_for(MIXED, lib)
    banked = np.asarray(bank_eval(traceable, bank, variant="fused"))
    seq = np.asarray(
        [sequential(ApproxPolicy(default=BackendSpec.from_library(
            n, variant="fused").materialize(lib))) for n in MIXED],
        dtype=banked.dtype)
    np.testing.assert_array_equal(banked, seq)


def test_mixed_reduce_bank_requires_optin(lib):
    with pytest.raises(ValueError, match="mixed"):
        bank_for([N16, N16B], lib)


def test_mixed_reduce_bank_fused_only(lib, toy_eval):
    traceable, _, _ = toy_eval
    bank = bank_for([N16, N16B, "mul8u_exact"], lib, mixed_reduce=True)
    assert bank.is_mixed_reduce
    with pytest.raises(ValueError, match="fused"):
        bank_eval(traceable, bank, variant="ref")


def test_mixed_reduce_bank_fused_bit_identical(lib, toy_eval):
    traceable, sequential, _ = toy_eval
    names = [N16, N16B, "mul8u_exact"]
    bank = bank_for(names, lib, mixed_reduce=True)
    banked = np.asarray(bank_eval(traceable, bank, variant="fused"))
    seq = np.asarray(
        [sequential(ApproxPolicy(default=BackendSpec.from_library(
            n, variant="fused").materialize(lib))) for n in names],
        dtype=banked.dtype)
    np.testing.assert_array_equal(banked, seq)


def test_policy_bank_fused_bit_identical(lib, toy_eval):
    traceable, sequential, _ = toy_eval
    pbank = PolicyBank.from_assignments(
        [{"lin_a": "mul8u_exact", "lin_b": N16},
         {"lin_a": N12, "lin_b": "mul8u_trunc6"}],
        lib, layers=("lin_a", "lin_b"))
    banked = np.asarray(policy_bank_eval(traceable, pbank, variant="fused"))
    seq = np.asarray(
        [sequential(policy_for_lane(pbank, p,
                                    variant="fused").materialize(lib))
         for p in range(2)], dtype=banked.dtype)
    np.testing.assert_array_equal(banked, seq)


# ----------------------------------------------------------------------
# trace-count gates: banked fused sweeps are O(1) compiled programs
# ----------------------------------------------------------------------
def test_fused_bank_sweep_single_trace(lib, toy_eval):
    traceable, _, traces = toy_eval
    bank = bank_for(MIXED, lib)
    traces.clear()
    bank_eval(traceable, bank, variant="fused")
    assert len(traces) == 1, (
        f"mixed-width fused bank sweep traced the model "
        f"{len(traces)} times; the banked engine must lower ONE program")


def test_fused_bank_sweep_o1_compiles(lib, toy_eval):
    """Backend-compile count must not grow with the number of lanes."""
    traceable, _, _ = toy_eval

    def _compiles(names):
        bank = bank_for(tuple(names), lib)
        jax.clear_caches()
        with trace_audit() as counts:
            bank_eval(traceable, bank, variant="fused")
        return counts.traced_programs

    # both lane counts exercise the wide (mixed-width) banked path
    n2 = _compiles([N16, N12])
    n4 = _compiles(MIXED)
    assert n4 <= n2, (
        f"fused bank sweep compiled {n4} programs for 4 lanes vs "
        f"{n2} for 2 — lane count leaked into compilation")