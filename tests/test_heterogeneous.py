"""Heterogeneous per-layer composition (DESIGN.md §2.5): PolicyBank,
policy_bank_eval bit-identity + O(1) traces, component models, the
two-stage explore_heterogeneous, and heterogeneous policy round-trips
through JSON / checkpoints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.dse import (DesignPoint, compose_assignments,
                              explore_heterogeneous, verify_assignments)
from repro.approx.layers import (ApproxPolicy, policy_bank_eval,
                                 policy_for_lane)
from repro.approx.power import (LayerPower, network_power_for_assignment,
                                per_layer_share)
from repro.approx.resilience import BankableEval, LayerComponents
from repro.approx.specs import BackendSpec, PolicyBank
from repro.core.library import build_default_library

MULTS = ["mul8u_exact", "mul8u_trunc4", "mul8u_trunc2"]
LAYERS = ("lin_a", "lin_b")
COUNTS = {"lin_a": 100, "lin_b": 300}


@pytest.fixture(scope="module")
def lib():
    return build_default_library("tiny")


@pytest.fixture(scope="module")
def toy_eval():
    """Two-matmul toy network with a traceable core instrumented to
    count jax traces (runs once per trace, not per policy)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w_a = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    w_b = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    traces = []

    def traceable(policy):
        traces.append(1)
        y = policy.matmul("lin_a", x, w_a)
        y = policy.matmul("lin_b", jax.nn.relu(y), w_b)
        return jnp.mean(y)

    def fn(policy):
        return float(jax.jit(lambda: traceable(policy))())

    return BankableEval(fn=fn, traceable=traceable), traces


def _random_bank(lib, n_policies=5, seed=0) -> PolicyBank:
    rng = np.random.default_rng(seed)
    assignments = [{l: MULTS[rng.integers(0, len(MULTS))] for l in LAYERS}
                   for _ in range(n_policies)]
    return PolicyBank.from_assignments(assignments, lib, layers=LAYERS)


# ----------------------------------------------------------------------
# PolicyBank construction
# ----------------------------------------------------------------------
def test_policy_bank_construction_and_validation(lib):
    pb = PolicyBank.from_assignments(
        [{"lin_a": "mul8u_trunc4", "lin_b": "mul8u_exact"},
         {"lin_a": "mul8u_trunc2", "lin_b": "mul8u_trunc4"}], lib)
    assert pb.n_policies == 2 and pb.n_layers == 2
    assert pb.layers == ("lin_a", "lin_b")
    # dedup: three distinct multipliers across 4 cells
    assert sorted(pb.bank.names) == sorted(MULTS)
    assert pb.assignment(0) == {"lin_a": "mul8u_trunc4",
                                "lin_b": "mul8u_exact"}
    with pytest.raises(ValueError, match="misses"):
        PolicyBank.from_assignments([{"lin_a": "mul8u_exact"}], lib,
                                    layers=LAYERS)
    with pytest.raises(ValueError, match="assign"):
        PolicyBank(bank=pb.bank, layers=LAYERS,
                   assign=np.zeros((2, 3), np.int32))
    with pytest.raises(ValueError, match="indices"):
        PolicyBank(bank=pb.bank, layers=LAYERS,
                   assign=np.full((1, 2), 99, np.int32))


def test_policy_bank_uniform_rows(lib):
    pb = PolicyBank.uniform(MULTS, LAYERS, lib)
    assert pb.n_policies == len(MULTS)
    for p, name in enumerate(MULTS):
        assert set(pb.assignment(p).values()) == {name}


# ----------------------------------------------------------------------
# The engine contract: bit-identity + O(1) compiled programs
# ----------------------------------------------------------------------
def test_policy_bank_eval_bit_identical_to_sequential(lib, toy_eval):
    eval_fn, traces = toy_eval
    pb = _random_bank(lib)
    traces.clear()
    batched = np.asarray(policy_bank_eval(eval_fn.traceable, pb,
                                          mode="lut"))
    assert len(traces) == 1, "K policies must compile O(1) programs"
    seq = np.asarray(
        [eval_fn(policy_for_lane(pb, p).materialize(lib))
         for p in range(pb.n_policies)], dtype=batched.dtype)
    np.testing.assert_array_equal(batched, seq)


def test_policy_bank_eval_pallas_variant_bit_identical(lib, toy_eval):
    eval_fn, _ = toy_eval
    pb = _random_bank(lib, n_policies=3, seed=1)
    batched = np.asarray(policy_bank_eval(eval_fn.traceable, pb,
                                          mode="lut", variant="pallas"))
    seq = np.asarray(
        [eval_fn(policy_for_lane(pb, p, variant="pallas").materialize(lib))
         for p in range(pb.n_policies)], dtype=batched.dtype)
    np.testing.assert_array_equal(batched, seq)


def test_policy_bank_eval_sharded_matches_unsharded(lib, toy_eval):
    from repro.launch.mesh import policy_sharding, sweep_mesh
    eval_fn, _ = toy_eval
    pb = _random_bank(lib, n_policies=4, seed=2)
    got = np.asarray(policy_bank_eval(
        eval_fn.traceable, pb,
        assign_sharding=policy_sharding(pb.n_policies, sweep_mesh())))
    want = np.asarray(policy_bank_eval(eval_fn.traceable, pb))
    np.testing.assert_array_equal(got, want)


def test_verify_assignments_batched_equals_sequential(lib, toy_eval):
    eval_fn, _ = toy_eval
    assignments = [{"lin_a": "mul8u_trunc4", "lin_b": "mul8u_exact"},
                   {"lin_a": "mul8u_trunc2", "lin_b": "mul8u_trunc4"}]
    bat = verify_assignments(eval_fn, assignments, COUNTS, lib,
                             batch=True)
    seq = verify_assignments(eval_fn, assignments, COUNTS, lib,
                             batch=False)
    assert [p.accuracy for p in bat] == [p.accuracy for p in seq]
    assert [p.network_rel_power for p in bat] == \
        [p.network_rel_power for p in seq]
    assert [p.assignment for p in bat] == [p.assignment for p in seq]


# ----------------------------------------------------------------------
# Heterogeneous policy serialization
# ----------------------------------------------------------------------
def test_heterogeneous_policy_json_round_trip_preserves_ordering(lib):
    overrides = [("lin_b", BackendSpec(mode="lut",
                                       multiplier="mul8u_trunc4")),
                 ("lin_a", BackendSpec(mode="lut",
                                       multiplier="mul8u_trunc2")),
                 ("lin_*", BackendSpec(mode="lut",
                                       multiplier="mul8u_exact"))]
    pol = ApproxPolicy(default=BackendSpec.golden(), overrides=overrides)
    rt = ApproxPolicy.from_json(pol.to_json())
    # ordering is semantic (first match wins for overlapping patterns)
    assert [(p, spec_of_entry(be)) for p, be in rt.overrides] \
        == [(p, s) for p, s in overrides]
    assert rt.cache_key() == pol.cache_key()
    assert rt.backend_for("lin_a") == overrides[1][1]


def spec_of_entry(be):
    from repro.approx.layers import spec_of
    return spec_of(be)


def test_heterogeneous_policy_materialize_idempotent(lib):
    pb = _random_bank(lib, n_policies=1, seed=4)
    pol = policy_for_lane(pb, 0)
    m1 = pol.materialize(lib)
    m2 = m1.materialize(lib)
    # materializing a materialized policy changes nothing: same backend
    # objects (the cache guarantees identity), same cache key
    assert m2.cache_key() == m1.cache_key()
    for (p1, b1), (p2, b2) in zip(m1.overrides, m2.overrides):
        assert p1 == p2 and b1 is b2
    assert m1.default is m2.default


def test_heterogeneous_policy_ships_in_checkpoint_metadata(tmp_path, lib):
    from repro.train.checkpoint import CheckpointManager, \
        policy_from_metadata
    pb = _random_bank(lib, n_policies=1, seed=5)
    pol = policy_for_lane(pb, 0)
    mgr = CheckpointManager(str(tmp_path), keep=1)
    state = {"w": np.ones((2, 2), np.float32)}
    mgr.save(1, state, policy=pol)
    _, meta = mgr.restore(state)
    rt = policy_from_metadata(meta)
    assert rt is not None and rt.cache_key() == pol.cache_key()


def test_design_point_from_assignment_policy(lib):
    a = {"lin_a": "mul8u_trunc4", "lin_b": "mul8u_trunc2"}
    pt = DesignPoint.from_assignment(a, accuracy=0.9,
                                     network_rel_power=0.25)
    assert pt.layer == "hetero" and pt.multiplier == "hetero[2]"
    pol = pt.policy()
    assert [p for p, _ in pol.overrides] == list(a)
    assert pt.to_dict()["assignment"] == a
    # the policy reproduces the datapath the point was verified under
    pt_pallas = DesignPoint.from_assignment(a, 0.9, 0.25,
                                            variant="pallas")
    assert all(be.variant == "pallas"
               for _, be in pt_pallas.policy().overrides)
    uniform = DesignPoint.from_assignment(
        {"lin_a": "mul8u_trunc4", "lin_b": "mul8u_trunc4"}, 0.9, 0.2)
    assert uniform.multiplier == "mul8u_trunc4"


# ----------------------------------------------------------------------
# Component models + composition
# ----------------------------------------------------------------------
def _toy_components() -> LayerComponents:
    return LayerComponents(
        layers=LAYERS, multipliers=tuple(MULTS),
        quality=np.asarray([[0.9, 0.88, 0.6],     # lin_a tolerates trunc4
                            [0.9, 0.7, 0.5]]),    # lin_b only exact
        rel_power=np.asarray([1.0, 0.2, 0.02]),
        counts=(100, 300), total_count=400, baseline=0.9)


def test_layer_components_drop_and_power():
    c = _toy_components()
    d = c.drop()
    assert d[0, 0] == 0.0 and d[1, 1] == pytest.approx(0.2)
    # exact everywhere
    assert c.predict_power(np.asarray([0, 0])) == pytest.approx(1.0)
    assert c.predict_accuracy(np.asarray([0, 0])) == pytest.approx(0.9)
    # trunc4 in lin_a only: count-weighted power
    assert c.predict_power(np.asarray([1, 0])) == pytest.approx(
        (100 * 0.2 + 300 * 1.0) / 400)
    fronts = c.layer_pareto()
    # every multiplier is non-dominated in both layers here (cheaper is
    # always more damaged), sorted by ascending power
    assert fronts[0] == [2, 1, 0] and fronts[1] == [2, 1, 0]


def test_layer_components_from_rows_matches_power_model(lib, toy_eval):
    from repro.approx.resilience import per_layer_sweep
    eval_fn, _ = toy_eval
    rows = per_layer_sweep(eval_fn, COUNTS, MULTS, lib, mode="lut")
    c = LayerComponents.from_rows(rows, COUNTS, baseline=0.9)
    assert c.layers == tuple(COUNTS) and c.multipliers == tuple(MULTS)
    i = c.multipliers.index("mul8u_trunc4")
    rp = lib.entries["mul8u_trunc4"].rel_power
    assert c.rel_power[i] == pytest.approx(rp)
    # predict_power for a one-layer assignment equals the shared
    # assignment power model (and therefore the per-layer row's power)
    row = next(r for r in rows if r.multiplier == "mul8u_trunc4"
               and r.layer == "lin_a")
    assign = np.asarray([i, c.multipliers.index("mul8u_exact")])
    want = network_power_for_assignment(
        COUNTS, {"lin_a": "mul8u_trunc4", "lin_b": "mul8u_exact"},
        {"mul8u_trunc4": rp, "mul8u_exact": 1.0})
    assert c.predict_power(assign) == pytest.approx(want)
    assert row.network_rel_power == pytest.approx(
        network_power_for_assignment(COUNTS, {"lin_a": "mul8u_trunc4"},
                                     {"mul8u_trunc4": rp}))


def test_compose_assignments_respects_bound_and_budget():
    c = _toy_components()
    rows = compose_assignments(c, quality_bound=0.05, top_k=4)
    assert rows, "beam must return candidates"
    # within the bound's ladder no candidate may use trunc2 in lin_b
    # (drop 0.4 > 2x bound); the cheapest feasible uses trunc4 in lin_a
    for r in rows:
        assert c.multipliers[r[1]] != "mul8u_trunc2"
    best = rows[0]
    assert c.multipliers[best[0]] in ("mul8u_trunc4", "mul8u_trunc2")
    budget = compose_assignments(c, quality_bound=0.05,
                                 power_budget=0.5, top_k=4)
    assert all(c.predict_power(r) <= 0.5 for r in budget)


# ----------------------------------------------------------------------
# explore_heterogeneous end-to-end
# ----------------------------------------------------------------------
def test_explore_heterogeneous_end_to_end(lib, toy_eval):
    eval_fn, traces = toy_eval
    cache: dict = {}
    res = explore_heterogeneous(eval_fn, COUNTS, lib, multipliers=MULTS,
                                quality_bound=0.5, top_k=4, cache=cache)
    assert res.per_layer, "stage 1 fills the per-layer axis"
    assert res.heterogeneous, "stage 2 fills the heterogeneous axis"
    for p in res.heterogeneous:
        assert p.layer == "hetero" and p.assignment is not None
        assert set(dict(p.assignment)) == set(COUNTS)
    assert res.selected is not None
    assert res.selected.accuracy >= res.baseline_accuracy - 0.5
    # verified results were seeded into the cache under
    # sequential-compatible policy keys: re-verifying sequentially with
    # the cache runs zero extra evals
    calls = [0]

    def counting(policy):
        calls[0] += 1
        return 0.0

    verify_assignments(
        BankableEval(fn=counting, traceable=None),
        [dict(p.assignment) for p in res.heterogeneous],
        COUNTS, lib, batch=False, cache=cache)
    assert calls[0] == 0
    # combined selection + pareto axes are well-formed
    assert res.within(1.0, axis="combined")
    assert res.pareto(axis="heterogeneous")


def test_explore_heterogeneous_sequential_fallback(lib):
    calls = [0]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    w = jnp.asarray(np.eye(8, dtype=np.float32))

    def plain(policy):          # no traceable core -> sequential path
        calls[0] += 1
        return float(jnp.mean(policy.matmul("lin_a", x, w)))

    res = explore_heterogeneous(plain, {"lin_a": 10}, lib,
                                multipliers=MULTS[:2], quality_bound=9.9,
                                top_k=2)
    assert res.heterogeneous and calls[0] > 0


# ----------------------------------------------------------------------
# Power model satellites
# ----------------------------------------------------------------------
def test_per_layer_share_zero_total_regression():
    layers = [LayerPower("a", 0, "m1", 0.5), LayerPower("b", 0, "m2", 1.0)]
    # regression: used to raise ZeroDivisionError; mirrors the
    # network_relative_power guard
    assert per_layer_share(layers) == {"a": 0.0, "b": 0.0}
    assert per_layer_share([]) == {}


def test_network_power_for_assignment_partial_coverage():
    counts = {"a": 100, "b": 300}
    got = network_power_for_assignment(counts, {"a": "m"}, {"m": 0.5})
    assert got == pytest.approx((100 * 0.5 + 300 * 1.0) / 400)
    assert network_power_for_assignment({}, {}, {}) == 1.0


# ----------------------------------------------------------------------
# Predict-stage regression pins (DESIGN.md §2.11): the surrogate
# refactor added predictor=/train_fraction= plumbing around stage 1 —
# these pins freeze the exact-predict behavior it must not move.
# ----------------------------------------------------------------------
def test_compose_assignments_min_primary_shortlist_pin():
    """Beam shortlist under a min-direction primary (logit-MAE-style
    components), pinned bit-identically: same order, same rows."""
    c = LayerComponents(
        layers=LAYERS, multipliers=tuple(MULTS),
        quality=np.asarray([[0.001, 0.010, 0.200],
                            [0.001, 0.080, 0.500]]),
        rel_power=np.asarray([1.0, 0.2, 0.02]),
        counts=(100, 300), total_count=400, baseline=0.001,
        direction="min")
    rows = compose_assignments(c, quality_bound=0.05, top_k=6)
    assert [tuple(r.tolist()) for r in rows] == \
        [(1, 1), (0, 1), (1, 0), (0, 0)]


@pytest.fixture(scope="module")
def min_primary_workload():
    """Min-primary (logit_mae) toy workload over the two-matmul net —
    the seed/weights behind the exact-predict pin."""
    from repro.approx.workload import logit_fidelity

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w_a = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    w_b = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)

    def forward(policy, xb):
        y = policy.matmul("lin_a", xb, w_a)
        return policy.matmul("lin_b", jax.nn.relu(y), w_b)

    return logit_fidelity(forward, [x], layer_counts=dict(COUNTS))


def test_explore_heterogeneous_exact_predictor_pin(lib,
                                                   min_primary_workload):
    """Same seed + predictor="exact" reproduces today's shortlist
    bit-identically: baseline, verified points (order, accuracy,
    power), selection, and the JSON surface (no surrogate key)."""
    res = explore_heterogeneous(
        min_primary_workload, dict(COUNTS), lib, multipliers=MULTS,
        quality_bound=30.0, top_k=6)
    assert res.baseline_accuracy == 0.12060075998306274
    expected = [
        ({"lin_a": "mul8u_trunc2", "lin_b": "mul8u_trunc2"},
         8.694466590881348, 0.023479520066197766),
        ({"lin_a": "mul8u_trunc4", "lin_b": "mul8u_trunc2"},
         8.662344932556152, 0.06710281340504759),
        ({"lin_a": "mul8u_trunc2", "lin_b": "mul8u_trunc4"},
         8.694466590881348, 0.15434940008274725),
        ({"lin_a": "mul8u_trunc4", "lin_b": "mul8u_trunc4"},
         8.666534423828125, 0.1979726934215971),
        ({"lin_a": "mul8u_exact", "lin_b": "mul8u_trunc2"},
         39.7521858215332, 0.2676096400496483),
        ({"lin_a": "mul8u_exact", "lin_b": "mul8u_trunc4"},
         11.416650772094727, 0.3984795200661978),
    ]
    assert len(res.heterogeneous) == len(expected)
    for p, (assign, acc, pw) in zip(res.heterogeneous, expected):
        assert dict(p.assignment) == assign
        assert p.accuracy == acc
        assert p.network_rel_power == pw
    assert res.selected is not None
    assert res.selected.accuracy == 8.694466590881348
    # per-layer stage-1 rows are the exact sweep, pinned
    by_cell = {(p.multiplier, p.layer): p.accuracy for p in res.per_layer}
    assert by_cell[("mul8u_exact", "lin_a")] == 0.12060081958770752
    assert by_cell[("mul8u_trunc4", "lin_a")] == 8.670662879943848
    assert by_cell[("mul8u_trunc4", "lin_b")] == 11.416650772094727
    assert by_cell[("mul8u_trunc2", "lin_a")] == 8.694466590881348
    assert by_cell[("mul8u_trunc2", "lin_b")] == 39.7521858215332
    # JSON surface unchanged: no surrogate key on the exact path, and
    # a faithful round-trip
    d = res.to_json_dict()
    assert sorted(d.keys()) == [
        "all_layers", "baseline_accuracy", "baseline_metrics",
        "heterogeneous", "objective_directions", "objectives",
        "per_layer", "primary", "selected"]
    from repro.approx.dse import ExploreResult
    assert ExploreResult.from_json_dict(d).to_json_dict() == d
