"""HLO-text analysis: the collective-byte accounting that feeds the
roofline (regression tests for the shape-vs-opname parsing bug)."""
from repro.launch import hlo_analysis as ha


SAMPLE = """
HloModule jit_step

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %convert.1 = bf16[128,256]{1,0} convert(%p0)
  %all-gather.2 = bf16[128,4096]{1,0} all-gather(%convert.1), dimensions={1}
  %all-reduce.3 = f32[128,256]{1,0} all-reduce(%p0), to_apply=%add
  %ars.4 = f32[128,256]{1,0} all-reduce-start(%p0), to_apply=%add
  %ard.5 = f32[128,256]{1,0} all-reduce-done(%ars.4)
  %tup.6 = (f32[2,2]{1,0}, s32[4]{0}) all-to-all(%p0, %p0)
  ROOT %copy.7 = f32[128,256]{1,0} copy(%all-reduce.3)
}
"""


def test_parse_def_basic():
    d = ha._parse_def("  %convert.1 = bf16[128,256]{1,0} convert(%p0)")
    assert d.op == "convert"
    assert d.shape.startswith("bf16[128,256]")
    assert d.name == "convert.1"


def test_parse_def_tuple_shape():
    d = ha._parse_def(
        "  %t = (f32[2,2]{1,0}, s32[4]{0}) all-to-all(%a, %b)")
    assert d.op == "all-to-all"
    assert ha.shape_bytes(d.shape) == 2 * 2 * 4 + 4 * 4


def test_parse_def_root():
    d = ha._parse_def("  ROOT %copy.7 = f32[8]{0} copy(%x)")
    assert d.op == "copy" and d.name == "copy.7"


def test_shape_bytes():
    assert ha.shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert ha.shape_bytes("pred[3]") == 3
    assert ha.shape_bytes("f32[]") == 4  # scalar


def test_collective_bytes_sample():
    out = ha.collective_bytes(SAMPLE)
    # all-gather operand = bf16[128,256] = 65536 B
    assert out["all-gather"]["bytes"] == 128 * 256 * 2
    # two all-reduce contributions (plain + -start), NOT the -done
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["bytes"] == 2 * 128 * 256 * 4
    # all-to-all: two f32[128,256] operands
    assert out["all-to-all"]["bytes"] == 2 * 128 * 256 * 4
    assert out["total_bytes"] == (out["all-gather"]["bytes"]
                                  + out["all-reduce"]["bytes"]
                                  + out["all-to-all"]["bytes"])


def test_convert_not_confused_with_collective():
    """Regression: a greedy shape regex chopped 'convert(' into op 't'
    and mis-binned collective lines."""
    hist = dict(ha.op_histogram(SAMPLE))
    assert "convert" in hist and "t" not in hist
    assert hist["parameter"] == 1
