"""End-to-end behaviour: tiny LM training run converges; resume works;
serving engine generates; resilience pipeline produces the paper's
qualitative orderings on a trained model (tiny scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import token_stream
from repro.models.registry import model_fns
from repro.serve.engine import Engine, ServeConfig
from repro.train.loop import Trainer, TrainLoopConfig
from repro.train.optimizer import OptimizerConfig


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("qwen1.5-0.5b").reduced(n_layers=2, vocab=256)
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, fns, params


def test_lm_training_reduces_loss(tiny_lm, tmp_path):
    cfg, fns, params = tiny_lm

    def loss_fn(p, batch):
        return fns.forward_train(p, batch, cfg)

    def batches():
        step = 0
        while True:
            toks, tgts = token_stream(cfg.vocab, 4, 32, step)
            yield {"tokens": jnp.asarray(toks),
                   "targets": jnp.asarray(tgts)}
            step += 1

    # donate=False: `params` belongs to a module-scoped fixture shared
    # with the engine tests — donation would delete their buffers.
    trainer = Trainer(loss_fn, params,
                      OptimizerConfig(lr=2e-3, warmup_steps=5,
                                      total_steps=40),
                      TrainLoopConfig(total_steps=40, ckpt_every=20,
                                      ckpt_dir=str(tmp_path),
                                      log_every=1000),
                      donate=False)
    hist = trainer.run(batches(), log=lambda s: None)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)

    # resume restores step counter and params
    t2 = Trainer(loss_fn, params,
                 OptimizerConfig(lr=2e-3, warmup_steps=5, total_steps=40),
                 TrainLoopConfig(total_steps=40, ckpt_every=20,
                                 ckpt_dir=str(tmp_path), log_every=1000))
    assert t2.maybe_resume()
    assert t2.step == 40
    ref = jax.tree.leaves(trainer.params)[0]
    got = jax.tree.leaves(t2.params)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref))


def test_engine_generates(tiny_lm):
    cfg, fns, params = tiny_lm
    engine = Engine(cfg, params)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 8)).astype(np.int32)
    out = engine.generate(prompts, ServeConfig(max_new_tokens=4))
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()


@pytest.mark.slow
def test_engine_approx_vs_exact_agree_mostly(tiny_lm):
    """int8-exact vs rank-4 approx datapath: same greedy tokens for an
    untrained model most of the time (faithful emulation)."""
    from repro.launch.steps import serve_policy
    cfg, fns, params = tiny_lm
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab, (2, 8)).astype(np.int32)
    out_a = Engine(cfg, params, serve_policy("mul8u_exact", "int8")
                   ).generate(prompts, ServeConfig(max_new_tokens=3))
    out_b = Engine(cfg, params, serve_policy("mul8u_exact", "lowrank",
                                             rank=1)
                   ).generate(prompts, ServeConfig(max_new_tokens=3))
    # exact multiplier emulated at rank 1 == exact int8 path
    assert (out_a == out_b).mean() >= 0.5


@pytest.mark.slow
def test_engine_per_request_policy_selection(tiny_lm):
    """One engine, two requests with different serialized policies:
    the accelerator is selected per request, and repeated policies
    reuse the engine's jitted step pair."""
    from repro.approx.layers import ApproxPolicy
    from repro.approx.specs import BackendSpec
    cfg, fns, params = tiny_lm
    engine = Engine(cfg, params)
    prompts = np.random.default_rng(2).integers(
        0, cfg.vocab, (2, 8)).astype(np.int32)

    pol_int8 = ApproxPolicy(default=BackendSpec.golden())
    pol_f32 = ApproxPolicy(default=BackendSpec.exact("f32"))
    out_a = engine.generate(prompts, ServeConfig(
        max_new_tokens=3, policy=pol_int8.to_json_dict()))
    out_b = engine.generate(prompts, ServeConfig(
        max_new_tokens=3, policy=pol_f32.to_json_dict()))
    assert out_a.shape == out_b.shape == (2, 3)

    n_compiled = len(engine._steps)
    engine.generate(prompts, ServeConfig(
        max_new_tokens=2, policy=pol_int8.to_json_dict()))
    assert len(engine._steps) == n_compiled, \
        "repeated policy must reuse the jitted steps"


@pytest.mark.slow
def test_resilience_ordering_on_trained_model():
    """Paper's qualitative claim: aggressive multipliers degrade a
    TRAINED classifier; near-exact ones do not."""
    import benchmarks.resilience_common as rc
    from repro.approx.backend import MatmulBackend
    from repro.approx.layers import ApproxPolicy
    from repro.core.families import truncated_multiplier
    from repro.core.luts import lut_from_netlist

    cfg, params = rc.trained_resnet(8)
    eval_fn = rc.make_eval_fn(cfg, params, eval_n=128)
    acc_int8 = eval_fn(ApproxPolicy(default=MatmulBackend(mode="int8")))
    assert acc_int8 > 0.5, "trained model must beat chance by a margin"

    lut_mild = lut_from_netlist(truncated_multiplier(8, 1), 8)
    lut_harsh = lut_from_netlist(truncated_multiplier(8, 5), 8)
    acc_mild = eval_fn(ApproxPolicy(
        default=MatmulBackend(mode="lut", lut=lut_mild)))
    acc_harsh = eval_fn(ApproxPolicy(
        default=MatmulBackend(mode="lut", lut=lut_harsh)))
    assert acc_mild >= acc_int8 - 0.08
    assert acc_harsh < acc_mild - 0.1, (acc_int8, acc_mild, acc_harsh)
