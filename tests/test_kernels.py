"""Per-kernel allclose vs the ref.py pure-jnp oracles, with hypothesis
shape sweeps (interpret=True executes the kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import families, seeds
from repro.core.luts import decompose_lut, exact_mul_lut, lut_from_netlist
from repro.core.netlist import exhaustive_inputs, random_input_planes
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _codes(m, k, n):
    qa = jnp.asarray(RNG.integers(0, 256, (m, k)), jnp.int32)
    qw = jnp.asarray(RNG.integers(0, 256, (k, n)), jnp.int32)
    return qa, qw


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 140), st.integers(1, 150), st.integers(1, 140))
def test_lut_kernel_matches_ref(m, k, n):
    qa, qw = _codes(m, k, n)
    lut = jnp.asarray(exact_mul_lut(8) + 5)   # LUT[0,0] != 0: pad check
    got = ops.approx_matmul_lut(qa, qw, lut)
    want = ref.approx_matmul_lut_ref(qa, qw, lut)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mult", ["bam", "trunc"])
def test_lut_kernel_real_multipliers(mult):
    nl = (families.bam_multiplier(8, 1, 4) if mult == "bam"
          else families.truncated_multiplier(8, 2))
    lut = jnp.asarray(lut_from_netlist(nl, 8))
    qa, qw = _codes(64, 96, 32)
    got = ops.approx_matmul_lut(qa, qw, lut)
    want = ref.approx_matmul_lut_ref(qa, qw, lut)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=4, deadline=None)
@given(st.integers(1, 140), st.integers(1, 150), st.integers(1, 140),
       st.integers(1, 4), st.booleans())
def test_lut_bank_kernel_matches_ref(m, k, n, n_mult, banked_qa):
    qa, qw = _codes(m, k, n)
    if banked_qa:
        qa = jnp.asarray(RNG.integers(0, 256, (n_mult, m, k)), jnp.int32)
    luts = jnp.asarray(RNG.integers(0, 255 * 255, (n_mult, 256, 256)),
                       jnp.int32)
    got = ops.approx_matmul_lut_bank(qa, qw, luts)
    want = ref.approx_matmul_lut_bank_ref(qa, qw, luts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lut_bank_lane_matches_single_lut_kernel():
    """Equivalence contract: bank lane b == single-LUT kernel with
    luts[b] (what the batched resilience engine relies on)."""
    qa, qw = _codes(70, 130, 50)
    luts = jnp.asarray(RNG.integers(0, 255 * 255, (3, 256, 256)),
                       jnp.int32)
    bank = np.asarray(ops.approx_matmul_lut_bank(qa, qw, luts))
    for b in range(3):
        single = np.asarray(ops.approx_matmul_lut(qa, qw, luts[b]))
        np.testing.assert_array_equal(bank[b], single)


def test_lut_kernel_vmap_dispatches_to_bank():
    """vmap over the LUT axis must reroute to the banked kernel (one
    launch), not batch the single-LUT kernel lane by lane."""
    import jax

    qa, qw = _codes(40, 64, 24)
    luts = jnp.asarray(RNG.integers(0, 255 * 255, (4, 256, 256)),
                       jnp.int32)
    got = jax.vmap(lambda lut: ops.approx_matmul_lut(qa, qw, lut))(luts)
    want = ref.approx_matmul_lut_bank_ref(qa, qw, luts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lut_kernel_vmap_batched_weights():
    """Batched weights (experts vmapping backend_matmul, NOT a LUT
    bank) stay correct through the custom batching rule."""
    import jax

    qa = jnp.asarray(RNG.integers(0, 256, (3, 20, 40)), jnp.int32)
    qw = jnp.asarray(RNG.integers(0, 256, (3, 40, 24)), jnp.int32)
    lut = jnp.asarray(RNG.integers(0, 255 * 255, (256, 256)), jnp.int32)
    got = jax.vmap(lambda a, w: ops.approx_matmul_lut(a, w, lut))(qa, qw)
    want = np.stack([np.asarray(ref.approx_matmul_lut_ref(qa[i], qw[i],
                                                          lut))
                     for i in range(3)])
    np.testing.assert_array_equal(np.asarray(got), want)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 130), st.integers(1, 140), st.integers(1, 130),
       st.integers(1, 6))
def test_lowrank_kernel_matches_ref(m, k, n, r):
    qa, qw = _codes(m, k, n)
    u = jnp.asarray(RNG.normal(size=(r, 256)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(r, 256)).astype(np.float32))
    got = ops.lowrank_matmul(qa, qw, u, v)
    want = ref.lowrank_matmul_ref(qa, qw, u, v)
    # f32 reduction-order noise grows with K (blocked vs flat accumulate)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-2)


def test_lowrank_kernel_emulates_exact_multiplier():
    """rank-1 factorization of the exact LUT == exact integer matmul."""
    lut = exact_mul_lut(8)
    fac = decompose_lut(lut, 1)
    qa, qw = _codes(32, 64, 16)
    got = ops.lowrank_matmul(qa, qw, jnp.asarray(fac.u), jnp.asarray(fac.v))
    want = ref.approx_matmul_lut_ref(qa, qw, jnp.asarray(lut)
                                     ).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=2.0)


@pytest.mark.parametrize("builder,args", [
    (seeds.array_multiplier, (8,)),
    (seeds.ripple_carry_adder, (8,)),
    (families.bam_multiplier, (8, 1, 3)),
    (families.loa_adder, (8, 3)),
])
def test_bitsim_kernel_exhaustive(builder, args):
    nl = builder(*args)
    planes = exhaustive_inputs(nl.n_i)
    got = ops.bitsim(nl, planes)
    want = nl.eval_words(planes)
    assert np.array_equal(got, want)


def test_bitsim_kernel_wide_random():
    nl = seeds.ripple_carry_adder(32)
    planes = random_input_planes(64, 4096, np.random.default_rng(3))
    got = ops.bitsim(nl, planes)
    want = nl.eval_words(planes)
    assert np.array_equal(got, want)


def test_bitsim_ref_oracle_agrees():
    nl = families.bam_multiplier(8, 0, 4).compact()
    planes = exhaustive_inputs(16)
    lo = (planes & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (planes >> np.uint64(32)).astype(np.uint32)
    planes32 = np.empty((planes.shape[0], 2 * planes.shape[1]),
                        dtype=np.uint32)
    planes32[:, 0::2] = lo
    planes32[:, 1::2] = hi
    got = ref.bitsim_ref(nl.funcs, nl.in0, nl.in1, nl.outputs,
                         jnp.asarray(planes32))
    want_words = nl.eval_words(planes)
    want32 = np.empty_like(planes32[: nl.n_o])
    want32[:, 0::2] = (want_words & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    want32[:, 1::2] = (want_words >> np.uint64(32)).astype(np.uint32)
    assert np.array_equal(np.asarray(got), want32)
