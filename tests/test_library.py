"""Library: build, Pareto selection, persistence, LUTs, low-rank."""
import numpy as np
import pytest

from repro.core.library import (ApproxLibrary, UnknownCircuitError,
                                WidthMismatchError, build_default_library,
                                CircuitEntry)
from repro.core.luts import (LutWidthError, decompose_lut, exact_mul_lut,
                             lut_from_netlist, rank_for_tolerance,
                             rank_profile)
from repro.core import families, seeds


@pytest.fixture(scope="module")
def tiny_lib():
    return build_default_library("tiny")


def test_entry_lookup_is_validated(tiny_lib):
    e = tiny_lib.entry("mul8u_exact", bit_width=8)
    assert e.width == 8
    with pytest.raises(UnknownCircuitError):
        tiny_lib.entry("does_not_exist")
    with pytest.raises(WidthMismatchError):
        tiny_lib.entry("mul8u_exact", bit_width=12)
    # UnknownCircuitError stays a KeyError for legacy except-clauses
    assert issubclass(UnknownCircuitError, KeyError)
    assert issubclass(WidthMismatchError, ValueError)
    assert issubclass(LutWidthError, ValueError)


def test_composed_entries_enter_counts_table(tiny_lib):
    tiny_lib.add_composed("mul8u_trunc4", 16, "exact", samples=64)
    table = tiny_lib.counts_table()
    kinds = {(r["circuit"], r["bit_width"]) for r in table}
    assert ("multiplier", 16) in kinds
    sel = tiny_lib.select("multiplier", 16, source="composed")
    assert sel and all(e.composition is not None for e in sel)


def test_exact_mul_lut_width_cap():
    assert exact_mul_lut(8).shape == (256, 256)
    with pytest.raises(LutWidthError, match="composed"):
        exact_mul_lut(16)


def test_library_counts(tiny_lib):
    table = tiny_lib.counts_table()
    kinds = {(r["circuit"], r["bit_width"]) for r in table}
    assert ("multiplier", 8) in kinds and ("adder", 8) in kinds
    assert len(tiny_lib.entries) > 50


def test_pareto_front_is_nondominated(tiny_lib):
    front = tiny_lib.pareto_front("multiplier", 8, "mae")
    assert front, "empty front"
    for a in front:
        for b in front:
            if a is b:
                continue
            assert not (b.rel_power <= a.rel_power
                        and b.errors.mae <= a.errors.mae
                        and (b.rel_power < a.rel_power
                             or b.errors.mae < a.errors.mae)), \
                f"{a.name} dominated by {b.name}"


def test_pareto_front_matches_quadratic_reference(tiny_lib):
    """The O(n log n) sweep must reproduce the exhaustive dominance
    scan exactly, ties included."""
    for metric in ("mae", "wce", "er"):
        cands = tiny_lib.select(kind="multiplier", width=8)
        ref = []
        for e in cands:
            p, m = e.rel_power, e.errors.get(metric)
            if not any((o.rel_power <= p and o.errors.get(metric) <= m
                        and (o.rel_power < p or o.errors.get(metric) < m))
                       for o in cands):
                ref.append(e.name)
        got = [e.name for e in tiny_lib.pareto_front("multiplier", 8,
                                                     metric)]
        assert sorted(got) == sorted(ref)


def test_exact_is_on_every_front(tiny_lib):
    """The exact multiplier has zero error: it must be Pareto optimal."""
    for metric in ("mae", "wce", "mre"):
        front = tiny_lib.pareto_front("multiplier", 8, metric)
        assert any(e.source == "exact" for e in front)


def test_case_study_selection(tiny_lib):
    sel = tiny_lib.case_study_selection(per_metric=5)
    assert 3 <= len(sel) <= 25      # union of 5 fronts, deduped
    names = [e.name for e in sel]
    assert len(names) == len(set(names))


def test_spread_along_power(tiny_lib):
    front = tiny_lib.pareto_front("multiplier", 8, "mae")
    sel = ApproxLibrary.spread_along_power(front, 4)
    assert len(sel) <= 4
    powers = [e.rel_power for e in sel]
    assert powers == sorted(powers) or powers == sorted(powers,
                                                        reverse=True) \
        or len(set(powers)) == len(powers)


def test_save_load_roundtrip(tiny_lib, tmp_path):
    path = str(tmp_path / "lib.json")
    tiny_lib.save(path)
    lib2 = ApproxLibrary.load(path)
    assert set(lib2.entries) == set(tiny_lib.entries)
    name = next(iter(tiny_lib.entries))
    a, b = tiny_lib.entries[name], lib2.entries[name]
    assert a.errors.mae == b.errors.mae
    assert a.cost.power == b.cost.power
    np.testing.assert_array_equal(a.netlist.funcs, b.netlist.funcs)


def test_lut_materialization(tiny_lib):
    lut = tiny_lib.lut("mul8u_exact")
    assert lut.shape == (256, 256)
    np.testing.assert_array_equal(lut, exact_mul_lut(8))


def test_rel_power_of_exact_is_one(tiny_lib):
    assert tiny_lib.entries["mul8u_exact"].rel_power == pytest.approx(1.0)


# ---------------------------------------------------------------- low-rank
def test_rank_profile_monotone():
    lut = lut_from_netlist(families.bam_multiplier(8, 1, 4), 8)
    prof = rank_profile(lut, 8)
    maes = [p["mae"] for p in prof]
    assert all(maes[i] >= maes[i + 1] - 1e-9 for i in range(len(maes) - 1))


def test_structured_multipliers_are_low_rank():
    """Truncation is exactly rank 1 (separable).  BAM error is a sum of
    dropped rank-1 partial products a_i ⊗ b_j: BAM(1,3) drops rows {0}
    and weights <3 whose union spans exactly 2 extra directions -> the
    LUT is exactly rank 3 (measured)."""
    tr = lut_from_netlist(families.truncated_multiplier(8, 3), 8)
    assert rank_for_tolerance(tr, 1e-6) == 1
    bam13 = lut_from_netlist(families.bam_multiplier(8, 1, 3), 8)
    assert rank_for_tolerance(bam13, 1e-6) == 3
    # BAM(0,4) drops 10 separate rank-1 cells: NOT exactly low-rank, but
    # rank-4 already reduces decomposition MAE below 1 LSB.
    bam04 = lut_from_netlist(families.bam_multiplier(8, 0, 4), 8)
    prof = {p["rank"]: p["mae"] for p in
            __import__("repro.core.luts", fromlist=["rank_profile"]
                       ).rank_profile(bam04, 4)}
    assert prof[4] < 1.0


def test_decompose_reconstruction_error_bounded():
    lut = exact_mul_lut(8)
    fac = decompose_lut(lut, 1)
    assert fac.mae_vs(lut) < 1e-6


# ------------------------------------- population-engine regeneration
# (DESIGN.md §2.9: the library regenerated with the device engine)
@pytest.fixture(scope="module")
def pop_lib():
    return build_default_library("tiny", engine="device")


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="engine"):
        build_default_library("tiny", engine="cuda")


@pytest.mark.slow
def test_pop_engine_grows_archive(pop_lib, tiny_lib):
    """At equal (tiny) budget the population ladder must admit MORE
    evolved entries than the legacy chained ladder (no parent
    thinning), plus composed wide rows over the evolved tiles."""
    n_dev = len(pop_lib.select(source="evolved"))
    n_leg = len(tiny_lib.select(source="evolved"))
    assert n_dev > n_leg
    comp = pop_lib.select(width=12, source="composed")
    assert comp and all(e.composition is not None for e in comp)
    tiles = {e.composition["tile"] for e in comp}
    assert all(pop_lib.entries[t].source == "evolved" for t in tiles)


@pytest.mark.slow
def test_pop_entries_reverify_exhaustively(pop_lib):
    """Admission re-verifies on the FULL input space: recomputing every
    evolved entry's ErrorReport from its stored netlist must reproduce
    the stored report exactly (search-plane scores never leak into the
    archive)."""
    from repro.core.metrics import evaluate_errors
    checked = 0
    for e in pop_lib.select(source="evolved"):
        exact = pop_lib.entries[
            ("mul" if e.kind == "multiplier" else "add")
            + f"{e.width}u_exact"].netlist
        rep = evaluate_errors(e.netlist, exact)
        assert rep.as_dict() == e.errors.as_dict(), e.name
        assert rep.exhaustive
        checked += 1
    assert checked > 20


@pytest.mark.slow
def test_pop_lib_save_load_roundtrip(pop_lib, tmp_path):
    path = str(tmp_path / "pop_lib.json")
    pop_lib.save(path)
    lib2 = ApproxLibrary.load(path)
    assert set(lib2.entries) == set(pop_lib.entries)
    for name in pop_lib.entries:
        a, b = pop_lib.entries[name], lib2.entries[name]
        assert a.errors.as_dict() == b.errors.as_dict()
        assert a.composition == b.composition
        np.testing.assert_array_equal(a.netlist.funcs, b.netlist.funcs)


@pytest.mark.slow
def test_pop_lib_banked_sweep_smoke(pop_lib):
    """Evolved entries of the regenerated library execute through the
    banked all-layers resilience sweep (one compiled program)."""
    import jax
    import jax.numpy as jnp
    from repro.approx.resilience import BankableEval, all_layers_sweep
    from repro.data.synthetic import CifarBatches
    from repro.models import resnet

    front = pop_lib.pareto_front("multiplier", 8, "mae")
    names = ["mul8u_exact"] + [e.name for e in front
                               if e.source == "evolved"][:3]
    assert len(names) >= 2
    cfg = resnet.resnet_config(8)
    params = resnet.init_params(jax.random.PRNGKey(0), cfg)
    batch = next(iter(CifarBatches("test", 32, 32, seed=0)
                      .eval_batches()))
    images = jnp.asarray(batch["images"])
    labels = jnp.asarray(batch["labels"])

    def traceable(policy):
        logits = resnet.forward(params, images, cfg, policy)
        return jnp.mean((jnp.argmax(logits, -1) == labels
                         ).astype(jnp.float32))

    ev = BankableEval(fn=lambda p: float(jax.jit(
        lambda: traceable(p))()), traceable=traceable)
    rows = all_layers_sweep(ev, resnet.layer_mult_counts(cfg), names,
                            pop_lib, mode="lut", batch=True)
    assert len(rows) == len(names)
    assert all(0.0 <= r.accuracy <= 1.0 for r in rows)
